#!/usr/bin/env bash
# Seal (or intentionally regenerate) the solver golden fixtures.
#
# The drift detector (rust/tests/solver_golden.rs) replays every iterative
# solver against committed JSON fixtures under rust/tests/golden/. On a
# branch the test self-seals missing fixtures (bootstrap mode); on main the
# CI golden step FAILS when no fixtures are committed — this script is the
# supported way to produce them:
#
#   scripts/seal_golden.sh            # generate missing fixtures
#   scripts/seal_golden.sh --regen    # wipe + regenerate (intentional
#                                     # numerics change)
#
# then commit the rust/tests/golden/*.json files it leaves behind. The
# script runs the suite twice: the second run must replay the sealed
# fixtures bit-for-bit, so a flaky environment can never seal a flaky
# fixture.
set -euo pipefail
cd "$(dirname "$0")/.."

if [ "${1:-}" = "--regen" ]; then
  echo "regenerating: removing committed fixtures"
  rm -f rust/tests/golden/*.json
fi

# fixtures must not depend on the CI env variants
unset HDPW_FORMAT HDPW_REUSE_PRECOND HDPW_WARM_START HDPW_MEM_MB

echo "== pass 1: seal =="
cargo test --test solver_golden
echo "== pass 2: verify the sealed fixtures replay =="
cargo test --test solver_golden

echo
echo "sealed fixtures:"
ls -l rust/tests/golden/*.json
echo "commit these files to arm the drift detector on main."
