//! Quickstart: solve one constrained regression problem three ways.
//!
//!     cargo run --release --example quickstart
//!
//! Generates an ill-conditioned synthetic dataset (Table 3 "Syn2" shape),
//! computes the exact optimum for reference, then solves it with the
//! paper's two contributions (HDpwBatchSGD for low precision, pwGradient
//! for high precision) and one classical baseline (SGD), printing the
//! relative error and timing of each.

use hdpw::backend::Backend;
use hdpw::coordinator::{Coordinator, CoordinatorConfig, JobRequest};

fn main() -> anyhow::Result<()> {
    // Backend::auto() uses the AOT-compiled PJRT artifacts when
    // `make artifacts` has produced them, and the native Rust kernels
    // otherwise — same numerics either way.
    let backend = Backend::auto();
    println!(
        "backend: {}",
        if backend.has_pjrt() {
            "pjrt artifacts + native fallback"
        } else {
            "native (run `make artifacts` to enable the PJRT path)"
        }
    );
    let coord = Coordinator::new(backend, CoordinatorConfig::default());

    for (solver, constraint, note) in [
        ("exact", "unc", "QR ground truth"),
        ("hdpwbatchsgd", "unc", "Algorithm 2, low precision"),
        ("hdpwbatchsgd", "l1", "Algorithm 2, l1 ball"),
        ("pwgradient", "unc", "Algorithm 4, high precision"),
        ("pwgradient", "l2", "Algorithm 4, l2 ball"),
        ("sgd", "unc", "classical baseline"),
    ] {
        let mut req = JobRequest::default();
        req.dataset = "syn2".into();
        req.n = 16_384;
        req.solver = solver.into();
        req.constraint = constraint.into();
        req.batch_size = 64;
        req.max_iters = if solver == "pwgradient" { 200 } else { 4_000 };
        req.target_rel_err = if solver == "pwgradient" { 1e-10 } else { 0.0 };
        req.time_budget = 20.0;
        req.normalize = solver != "exact" && solver != "pwgradient";
        let res = coord.run_job(&req)?;
        println!(
            "{:<14} {:<4} rel_err={:<10.3e} iters={:<6} setup={:<9} solve={:<9} ({note})",
            res.solver,
            constraint,
            res.best_rel_err,
            res.best.iters,
            hdpw::util::stats::fmt_duration(res.best.setup_secs),
            hdpw::util::stats::fmt_duration(res.best.solve_secs),
        );
    }
    Ok(())
}
