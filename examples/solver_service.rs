//! Solver-as-a-service demo: spin up the coordinator's TCP server, connect
//! as a client, stream a mixed batch of jobs and collect results — the
//! deployment mode of the L3 layer.
//!
//!     cargo run --release --example solver_service
//!
//! Demonstrates: concurrent jobs over one connection, the JSON wire
//! protocol, backpressure-bounded scheduling, and service metrics.

use hdpw::backend::Backend;
use hdpw::coordinator::{server, Coordinator, CoordinatorConfig};
use hdpw::util::json::Json;
use hdpw::util::stats::Timer;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // --- boot the service on an ephemeral port ------------------------------
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let coord = Arc::new(Coordinator::new(
        Backend::auto(),
        CoordinatorConfig {
            workers: 3,
            max_queue: 8,
            ..CoordinatorConfig::default()
        },
    ));
    {
        let coord = Arc::clone(&coord);
        std::thread::spawn(move || {
            for stream in listener.incoming().flatten() {
                let reader = BufReader::new(stream.try_clone().unwrap());
                let coord = Arc::clone(&coord);
                std::thread::spawn(move || {
                    let _ = server::handle_connection(&coord, reader, stream);
                });
            }
        });
    }
    println!("service listening on {addr}");

    // --- client: stream a mixed workload -------------------------------------
    let stream = TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);

    let jobs = [
        r#"{"id":1,"solver":"pwgradient","dataset":"syn2","n":8192,"max_iters":200}"#,
        r#"{"id":2,"solver":"hdpwbatchsgd","dataset":"syn1","n":8192,"batch_size":128,"max_iters":3000,"normalize":true}"#,
        r#"{"id":3,"solver":"ihs","dataset":"syn2","n":8192,"max_iters":60}"#,
        r#"{"id":4,"solver":"pwgradient","dataset":"year","n":8192,"constraint":"l2","max_iters":200}"#,
        r#"{"id":5,"solver":"pwsvrg","dataset":"syn2","n":8192,"batch_size":64,"max_iters":4000}"#,
        r#"{"id":6,"solver":"exact","dataset":"buzz","n":4096}"#,
    ];
    let t = Timer::start();
    for j in &jobs {
        writeln!(writer, "{j}")?;
    }
    writeln!(writer, "{{\"cmd\":\"metrics\"}}")?;
    writeln!(writer, "{{\"cmd\":\"quit\"}}")?;
    writer.flush()?;

    let mut completed = 0;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line).map_err(|e| anyhow::anyhow!("{e}"))?;
        if let Some(m) = j.get("metrics") {
            println!("service metrics: {}", m.as_str().unwrap_or("?"));
            continue;
        }
        if let Some(err) = j.get("error") {
            println!("job error: {err}");
            continue;
        }
        completed += 1;
        println!(
            "job {:>2} {:<14} {:<6} rel_err={:<10.3e} solve={}",
            j.get("id").and_then(Json::as_f64).unwrap_or(-1.0),
            j.get("solver").and_then(Json::as_str).unwrap_or("?"),
            j.get("dataset").and_then(Json::as_str).unwrap_or("?"),
            j.get("best_rel_err").and_then(Json::as_f64).unwrap_or(-1.0),
            hdpw::util::stats::fmt_duration(
                j.get("solve_secs").and_then(Json::as_f64).unwrap_or(0.0)
            ),
        );
    }
    println!(
        "{completed}/{} jobs completed in {} (3 workers, queue bound 8)",
        jobs.len(),
        hdpw::util::stats::fmt_duration(t.secs())
    );
    anyhow::ensure!(completed == jobs.len(), "missing results");
    Ok(())
}
