//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//!     make artifacts && cargo run --release --example e2e_paper_run
//!
//! Exercises every layer in composition:
//!   L1/L2 — the Pallas/JAX graphs, AOT-compiled to `artifacts/*.hlo.txt`,
//!           executed via PJRT for the canonical (n=8192, d=32) shapes;
//!   runtime — artifact registry + engine actor thread;
//!   L3   — the coordinator running the paper's evaluation protocol
//!           (best-of-k trials, radius-from-optimum constrained setup).
//!
//! Workload: the `pjrt8k` dataset (kappa = 1e6, the canonical artifact
//! shape), solved by the paper's methods and the baselines they are
//! compared against, in the unconstrained and l1/l2-constrained settings.
//! Reports the paper's headline metrics: time-to-1e-2 (low precision),
//! time-to-1e-8 (high precision), and the HDpwBatchSGD batch-size speed-up.
//! Asserts that the PJRT path actually served the artifact-shaped calls.

use hdpw::backend::Backend;
use hdpw::coordinator::{Coordinator, CoordinatorConfig, JobRequest};
use hdpw::util::stats::fmt_duration;

fn main() -> anyhow::Result<()> {
    let backend = Backend::auto();
    let pjrt = backend.has_pjrt();
    println!("=== hdpw end-to-end paper run ===");
    println!(
        "backend: {}",
        if pjrt {
            "PJRT artifacts (L1 Pallas + L2 JAX via XLA) + native fallback"
        } else {
            "NATIVE ONLY — run `make artifacts` first for the full stack"
        }
    );
    let coord = Coordinator::new(backend.clone(), CoordinatorConfig::default());

    let base = || {
        let mut req = JobRequest::default();
        req.dataset = "pjrt8k".into();
        req.n = 8_192;
        req.trials = 3;
        req.time_budget = 30.0;
        req.seed = 20180201;
        req
    };

    // ---------------- low precision (target 1e-2) ---------------------------
    println!("\n-- low precision (relative error target 1e-3, unconstrained) --");
    let mut low_rows = Vec::new();
    for (label, solver, r) in [
        ("HDpwBatchSGD r=64", "hdpwbatchsgd", 64usize),
        ("HDpwBatchSGD r=256", "hdpwbatchsgd", 256),
        ("HDpwAccBatchSGD r=64", "hdpwaccbatchsgd", 64),
        ("pwSGD", "pwsgd", 1),
        ("SGD", "sgd", 64),
        ("Adagrad", "adagrad", 64),
    ] {
        let mut req = base();
        req.solver = solver.into();
        req.batch_size = r;
        req.max_iters = 60_000;
        req.target_rel_err = 1e-3;
        let res = coord.run_job(&req)?;
        let tt = res.best.time_to_rel_err(res.f_star, 1e-3);
        println!(
            "  {label:<22} rel_err={:<10.3e} time_to_1e-3={}",
            res.best_rel_err,
            tt.map(fmt_duration).unwrap_or_else(|| "not reached".into())
        );
        low_rows.push((label, tt));
    }

    // ---------------- high precision (target 1e-8) --------------------------
    println!("\n-- high precision (relative error target 1e-8) --");
    for constraint in ["unc", "l1", "l2"] {
        println!("  [{constraint}]");
        for (label, solver) in [
            ("pwGradient", "pwgradient"),
            ("IHS", "ihs"),
            ("pwSVRG r=64", "pwsvrg"),
        ] {
            let mut req = base();
            req.solver = solver.into();
            req.constraint = constraint.into();
            req.batch_size = 64;
            req.max_iters = if solver == "pwsvrg" { 60_000 } else { 300 };
            req.target_rel_err = 1e-8;
            let res = coord.run_job(&req)?;
            let tt = res.best.time_to_rel_err(res.f_star, 1e-8);
            println!(
                "    {label:<14} rel_err={:<10.3e} time_to_1e-8={}",
                res.best_rel_err,
                tt.map(fmt_duration).unwrap_or_else(|| "not reached".into())
            );
        }
    }

    // ---------------- headline verdicts -------------------------------------
    println!("\n-- verdicts (paper claims on this testbed) --");
    let t = |label: &str| {
        low_rows
            .iter()
            .find(|(l, _)| *l == label)
            .and_then(|(_, t)| *t)
    };
    if let (Some(h64), Some(h256)) = (t("HDpwBatchSGD r=64"), t("HDpwBatchSGD r=256")) {
        println!(
            "  batch-size speed-up (time): r=64 {} -> r=256 {}",
            fmt_duration(h64),
            fmt_duration(h256)
        );
    }
    match (t("HDpwBatchSGD r=256"), t("SGD")) {
        (Some(h), Some(s)) => println!(
            "  HDpwBatchSGD vs SGD time-to-1e-3: {} vs {} ({})",
            fmt_duration(h),
            fmt_duration(s),
            if h < s {
                "HDpw wins — matches paper"
            } else {
                "SGD wins at this small scale (setup not amortized)"
            }
        ),
        (Some(_), None) => println!(
            "  SGD never reached 1e-3 (kappa=1e6) while HDpwBatchSGD did — matches paper"
        ),
        _ => println!("  (low-precision comparison incomplete)"),
    }

    if pjrt {
        println!(
            "\nPJRT dispatches: {} (native fallbacks: {})",
            backend.pjrt_calls(),
            backend.native_calls()
        );
        anyhow::ensure!(
            backend.pjrt_calls() > 0,
            "e2e run never hit the PJRT path — artifact shapes desynced?"
        );
        println!("FULL STACK VERIFIED: L1 Pallas -> L2 JAX -> HLO -> PJRT -> L3 coordinator");
    }
    Ok(())
}
