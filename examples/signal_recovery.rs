//! Compressed-sensing signal recovery — the l1-constrained workload the
//! paper's introduction motivates.
//!
//!     cargo run --release --example signal_recovery
//!
//! A k-sparse signal x0 (k = 10 nonzeros in d = 256 dims) is observed
//! through an underdetermined-in-information but overdetermined-in-rows
//! gaussian design with noise: b = A x0 + e. Solving
//!     min ||Ax - b||^2  s.t.  ||x||_1 <= ||x0||_1
//! with the paper's solvers recovers the support. We compare HDpwBatchSGD
//! (low precision — support identification) and pwGradient (high precision
//! — coefficient accuracy) against an ISTA baseline built from the same
//! substrate (soft-thresholding on the unpreconditioned problem).

use hdpw::backend::Backend;
use hdpw::data::Dataset;
use hdpw::linalg::{blas, Mat};
use hdpw::constraints::l1_ball;
use hdpw::prox::soft_threshold;
use hdpw::solvers::{HdpwBatchSgd, PwGradient, Solver, SolverOpts};
use hdpw::util::rng::Rng;
use hdpw::util::stats::Timer;

fn support_f1(truth: &[f64], est: &[f64], thresh: f64) -> f64 {
    let t: Vec<bool> = truth.iter().map(|v| v.abs() > thresh).collect();
    let e: Vec<bool> = est.iter().map(|v| v.abs() > thresh).collect();
    let tp = t.iter().zip(&e).filter(|(a, b)| **a && **b).count() as f64;
    let fp = t.iter().zip(&e).filter(|(a, b)| !**a && **b).count() as f64;
    let fnn = t.iter().zip(&e).filter(|(a, b)| **a && !**b).count() as f64;
    if tp == 0.0 {
        return 0.0;
    }
    2.0 * tp / (2.0 * tp + fp + fnn)
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(42);
    let (n, d, k) = (8_192usize, 256usize, 10usize);
    // k-sparse ground-truth signal
    let mut x0 = vec![0.0; d];
    for _ in 0..k {
        let i = rng.below(d);
        x0[i] = rng.gaussian() * 3.0;
    }
    let a = Mat::gaussian(n, d, &mut rng);
    let mut b = blas::gemv(&a, &x0);
    for v in &mut b {
        *v += 0.05 * rng.gaussian();
    }
    let ds = Dataset::dense("signal", a, b, Some(x0.clone()));
    let l1_radius: f64 = x0.iter().map(|v| v.abs()).sum();
    println!("signal recovery: n={n} d={d} k={k} ||x0||_1={l1_radius:.3}");

    let backend = Backend::auto();
    let cons = l1_ball(l1_radius);

    // --- paper solvers -----------------------------------------------------
    let mut opts = SolverOpts::default();
    opts.constraint = cons.clone();
    opts.batch_size = 64;
    opts.max_iters = 6_000;
    opts.time_budget = 30.0;
    let rep = HdpwBatchSgd.solve(&backend, &ds, &opts)?;
    report("HDpwBatchSGD (l1)", &x0, &rep.x, rep.solve_secs);

    let mut opts = SolverOpts::default();
    opts.constraint = cons.clone();
    opts.max_iters = 200;
    opts.time_budget = 30.0;
    let rep = PwGradient.solve(&backend, &ds, &opts)?;
    report("pwGradient   (l1)", &x0, &rep.x, rep.solve_secs);

    // --- ISTA baseline (same substrate, no preconditioning) ------------------
    let t = Timer::start();
    let mut x = vec![0.0; d];
    // step 1/L with L = 2 sigma_max^2(A) ~ 2 (sqrt n + sqrt d)^2
    let l = 2.0 * ((n as f64).sqrt() + (d as f64).sqrt()).powi(2);
    let lambda = 0.05 * 2.0 * n as f64 * 0.05; // ~ noise-scaled
    for _ in 0..400 {
        let g = blas::fused_grad(ds.dense_if_ready().expect("dense"), &ds.b, &x, 2.0);
        for (xi, gi) in x.iter_mut().zip(&g) {
            *xi -= gi / l;
        }
        soft_threshold(&mut x, lambda / l);
    }
    report("ISTA baseline    ", &x0, &x, t.secs());
    Ok(())
}

fn report(name: &str, truth: &[f64], est: &[f64], secs: f64) {
    let err: f64 = truth
        .iter()
        .zip(est)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f64>()
        .sqrt()
        / blas::nrm2(truth).max(1e-300);
    let f1 = support_f1(truth, est, 0.1);
    println!(
        "{name}: signal rel_l2_err={err:.4}  support_F1={f1:.3}  time={}",
        hdpw::util::stats::fmt_duration(secs)
    );
}
