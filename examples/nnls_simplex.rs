//! Nonnegative least squares + probability-simplex regression — the two
//! constraint classes ISSUE 5 opens, end to end.
//!
//!     cargo run --release --example nnls_simplex
//!
//! **When do these sets arise?**
//!
//! * *Nonnegative orthant* (`--constraint nonneg`): whenever the
//!   coefficients are physically nonnegative quantities — spectral
//!   unmixing (material abundances), chemometrics (concentrations),
//!   intensity estimation. The unconstrained least-squares fit of noisy
//!   data routinely goes negative on small coefficients; projecting onto
//!   `x >= 0` is the classical NNLS remedy.
//! * *Probability simplex* (`--constraint simplex`): whenever the
//!   coefficients are weights that must be nonnegative AND sum to one —
//!   portfolio allocation (fully-invested long-only weights), mixture /
//!   topic proportions, model averaging.
//!
//! The script plants a solution ON the simplex (so it is feasible for both
//! sets), observes it through a tall gaussian design with noise, and
//! solves with pwSGD (the paper's preconditioned weighted SGD — here with
//! the R-metric projection doing the constrained Step 6) against the
//! `exact` unconstrained oracle. Because the planted solution is feasible,
//! the constrained and unconstrained optima coincide to O(1/n), and the
//! reported relative errors show pwSGD landing on the constrained optimum.

use hdpw::backend::Backend;
use hdpw::constraints::{nonneg, simplex, ConstraintSet};
use hdpw::data::Dataset;
use hdpw::linalg::{blas, Mat};
use hdpw::solvers::exact::ground_truth;
use hdpw::solvers::{PwSgd, Solver, SolverOpts};
use hdpw::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let (n, d) = (8_192usize, 16usize);
    let mut rng = Rng::new(7);
    // planted solution on the simplex: positive weights summing to 1
    let mut xt: Vec<f64> = (0..d).map(|_| 0.5 + rng.uniform()).collect();
    let total: f64 = xt.iter().sum();
    for v in &mut xt {
        *v /= total;
    }
    let a = Mat::gaussian(n, d, &mut rng);
    let mut b = blas::gemv(&a, &xt);
    for v in &mut b {
        *v += 1e-3 * rng.gaussian();
    }
    let ds = Dataset::dense("nnls_simplex", a, b, Some(xt));
    println!("nnls/simplex demo: n={n} d={d}, planted weights sum to 1");

    // the unconstrained oracle: with the solution planted inside both
    // sets, f* doubles as the constrained reference
    let gt = ground_truth(&ds);
    println!("exact            : f* = {:.6e}", gt.f_star);

    let backend = Backend::auto();
    for cons in [nonneg(), simplex(1.0)] {
        let mut opts = SolverOpts::default();
        opts.constraint = cons.clone();
        opts.batch_size = 8;
        opts.max_iters = 20_000;
        opts.chunk = 500;
        opts.time_budget = 60.0;
        opts.f_star = Some(gt.f_star);
        opts.eps_abs = Some(5e-4 * gt.f_star);
        let rep = PwSgd.solve(&backend, &ds, &opts)?;
        let rel = ((rep.f_final - gt.f_star) / gt.f_star).max(0.0);
        println!(
            "pwsgd {:<10} : rel_err={rel:.3e} iters={} feasible={} time={}",
            cons.tag(),
            rep.iters,
            cons.contains(&rep.x, 1e-9),
            hdpw::util::stats::fmt_duration(rep.solve_secs)
        );
        assert!(
            cons.contains(&rep.x, 1e-9),
            "{} iterate left the set",
            cons.tag()
        );
    }
    println!("(the same runs via the CLI: cargo run --release -- solve \\");
    println!("   --solver pwsgd --constraint simplex --n 8192)");
    Ok(())
}
