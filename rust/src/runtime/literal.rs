//! Conversions between the native [`Mat`]/vector types and XLA literals.
//!
//! `Mat` is row-major; XLA's default layout is also major-to-minor row-major,
//! so the byte payloads line up and conversions are a reshape over a flat
//! copy.

use crate::linalg::Mat;
use anyhow::Result;

/// Value passed into / received from a compiled artifact.
#[derive(Clone, Debug)]
pub enum Value {
    /// 0-d f64 scalar.
    Scalar(f64),
    /// 1-d f64 vector.
    Vec(Vec<f64>),
    /// 2-d f64 row-major matrix.
    Mat(Mat),
    /// 2-d i32 row-major matrix (sample index blocks).
    MatI32 {
        rows: usize,
        cols: usize,
        data: Vec<i32>,
    },
    /// 1-d i64 vector (shape metadata etc.).
    VecI64(Vec<i64>),
}

impl Value {
    /// Shape as a dims list (empty = scalar).
    pub fn dims(&self) -> Vec<usize> {
        match self {
            Value::Scalar(_) => vec![],
            Value::Vec(v) => vec![v.len()],
            Value::Mat(m) => vec![m.rows, m.cols],
            Value::MatI32 { rows, cols, .. } => vec![*rows, *cols],
            Value::VecI64(v) => vec![v.len()],
        }
    }

    /// Manifest dtype tag ("f64" | "i32" | "i64") for signature checks.
    pub fn dtype_tag(&self) -> &'static str {
        match self {
            Value::MatI32 { .. } => "i32",
            Value::VecI64(_) => "i64",
            _ => "f64",
        }
    }

    /// Convert to an XLA literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Value::Scalar(x) => xla::Literal::scalar(*x),
            Value::Vec(v) => xla::Literal::vec1(v),
            Value::Mat(m) => {
                xla::Literal::vec1(&m.data[..]).reshape(&[m.rows as i64, m.cols as i64])?
            }
            Value::MatI32 { rows, cols, data } => {
                xla::Literal::vec1(data).reshape(&[*rows as i64, *cols as i64])?
            }
            Value::VecI64(v) => xla::Literal::vec1(v),
        })
    }
}

/// Read a literal back as an f64 vector (works for any f64 array shape).
pub fn literal_to_f64s(lit: &xla::Literal) -> Result<Vec<f64>> {
    Ok(lit.to_vec::<f64>()?)
}

/// Read a literal back as a Mat with the given shape.
pub fn literal_to_mat(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Mat> {
    let data = literal_to_f64s(lit)?;
    anyhow::ensure!(
        data.len() == rows * cols,
        "literal has {} elems, want {}x{}",
        data.len(),
        rows,
        cols
    );
    Ok(Mat::from_vec(rows, cols, data))
}

/// Read a scalar f64 result.
pub fn literal_to_scalar(lit: &xla::Literal) -> Result<f64> {
    let v = literal_to_f64s(lit)?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_and_dtypes() {
        assert_eq!(Value::Scalar(1.0).dims(), Vec::<usize>::new());
        assert_eq!(Value::Vec(vec![1.0; 3]).dims(), vec![3]);
        let m = Mat::zeros(2, 5);
        assert_eq!(Value::Mat(m).dims(), vec![2, 5]);
        let i = Value::MatI32 {
            rows: 4,
            cols: 2,
            data: vec![0; 8],
        };
        assert_eq!(i.dims(), vec![4, 2]);
        assert_eq!(i.dtype_tag(), "i32");
        assert_eq!(Value::Scalar(0.0).dtype_tag(), "f64");
    }

    #[test]
    fn literal_roundtrip_f64() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let lit = Value::Mat(m.clone()).to_literal().unwrap();
        let back = literal_to_mat(&lit, 2, 3).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn literal_scalar_roundtrip() {
        let lit = Value::Scalar(2.5).to_literal().unwrap();
        assert_eq!(literal_to_scalar(&lit).unwrap(), 2.5);
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        let lit = Value::Vec(vec![1.0; 6]).to_literal().unwrap();
        assert!(literal_to_mat(&lit, 2, 4).is_err());
    }
}
