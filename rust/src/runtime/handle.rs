//! Thread-safe handle to the PJRT engine.
//!
//! The `xla` crate's PJRT client is `Rc`-based (neither `Send` nor `Sync`),
//! so the [`Engine`] lives on a dedicated actor thread; this handle is a
//! cloneable, `Send + Sync` facade that forwards execute requests over a
//! channel and blocks for the reply. Manifest metadata and op signatures are
//! snapshotted at spawn so lookups never cross the channel.

use super::engine::{Engine, ManifestMeta, OpSignature};
use super::literal::Value;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;

enum Request {
    Execute {
        op: String,
        inputs: Vec<Value>,
        reply: mpsc::Sender<Result<Vec<Vec<f64>>>>,
    },
    Shutdown,
}

struct Shared {
    tx: std::sync::Mutex<mpsc::Sender<Request>>,
    meta: ManifestMeta,
    signatures: HashMap<String, OpSignature>,
    dir: PathBuf,
}

/// Cloneable, thread-safe engine facade.
#[derive(Clone)]
pub struct EngineHandle {
    shared: Arc<Shared>,
}

impl EngineHandle {
    /// Load artifacts on a fresh actor thread. Fails fast (before
    /// returning) if the manifest is missing or any artifact fails to
    /// compile.
    pub fn spawn(dir: &Path) -> Result<EngineHandle> {
        let (ready_tx, ready_rx) = mpsc::channel();
        let (tx, rx) = mpsc::channel::<Request>();
        let dir_owned = dir.to_path_buf();
        std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                let engine = match Engine::load(&dir_owned) {
                    Ok(e) => {
                        let sigs: HashMap<String, OpSignature> = e
                            .op_names()
                            .iter()
                            .map(|n| (n.to_string(), e.signature(n).unwrap().clone()))
                            .collect();
                        let _ = ready_tx.send(Ok((e.manifest_meta.clone(), sigs)));
                        e
                    }
                    Err(err) => {
                        let _ = ready_tx.send(Err(err));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Execute { op, inputs, reply } => {
                            let _ = reply.send(engine.execute(&op, &inputs));
                        }
                        Request::Shutdown => break,
                    }
                }
            })?;
        let (meta, signatures) = ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during load"))??;
        Ok(EngineHandle {
            shared: Arc::new(Shared {
                tx: std::sync::Mutex::new(tx),
                meta,
                signatures,
                dir: dir.to_path_buf(),
            }),
        })
    }

    /// Manifest metadata, snapshotted at spawn (no channel round-trip).
    pub fn meta(&self) -> &ManifestMeta {
        &self.shared.meta
    }

    /// The artifact directory the engine was spawned from.
    pub fn dir(&self) -> &Path {
        &self.shared.dir
    }

    /// Whether an artifact named `name` was compiled.
    pub fn has_op(&self, name: &str) -> bool {
        self.shared.signatures.contains_key(name)
    }

    /// The manifest signature of one artifact (None if not compiled).
    pub fn signature(&self, name: &str) -> Option<&OpSignature> {
        self.shared.signatures.get(name)
    }

    /// Sorted names of every compiled artifact.
    pub fn op_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.shared.signatures.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// Execute an artifact on the engine thread (blocking).
    pub fn execute(&self, op: &str, inputs: Vec<Value>) -> Result<Vec<Vec<f64>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        {
            let tx = self.shared.tx.lock().unwrap();
            tx.send(Request::Execute {
                op: op.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        }
        reply_rx
            .recv()
            .map_err(|_| anyhow!("engine thread dropped the reply"))?
    }

    /// Ask the actor thread to exit (idempotent; in-flight work completes).
    pub fn shutdown(&self) {
        if let Ok(tx) = self.shared.tx.lock() {
            let _ = tx.send(Request::Shutdown);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_on_missing_dir_errors() {
        assert!(EngineHandle::spawn(Path::new("/nonexistent/x")).is_err());
    }

    // Happy-path behavior is covered by rust/tests/pjrt_parity.rs (needs
    // generated artifacts).
}
