//! Artifact registry + PJRT execution engine.
//!
//! The engine owns one PJRT CPU client and one compiled executable per
//! manifest entry. Dispatch is by op name; input shapes are validated
//! against the manifest signature before execution so shape bugs surface as
//! errors, not garbage numerics.

use super::literal::{self, Value};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Declared signature of one artifact (from manifest.json).
#[derive(Clone, Debug)]
pub struct OpSignature {
    /// Dispatch name (the key solvers/backends route on).
    pub name: String,
    /// HLO-text file name relative to the artifact directory.
    pub file: String,
    /// per-input (dims, dtype tag) — dims [] means scalar
    pub inputs: Vec<(Vec<usize>, String)>,
    /// number of tuple outputs
    pub outputs: usize,
}

struct CompiledOp {
    sig: OpSignature,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT engine: client + compiled artifact registry.
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    ops: HashMap<String, CompiledOp>,
    /// Canonical shapes the loaded artifacts were compiled for.
    pub manifest_meta: ManifestMeta,
    /// The artifact directory the manifest was loaded from.
    pub dir: PathBuf,
}

/// Top-level manifest metadata (canonical shapes the artifacts were built
/// for — the backend uses these to decide PJRT vs native dispatch).
#[derive(Clone, Debug, Default)]
pub struct ManifestMeta {
    /// Canonical row count (padded) the artifacts were lowered at.
    pub n: usize,
    /// Canonical column count.
    pub d: usize,
    /// Mini-batch sizes with a compiled chunk artifact.
    pub rs: Vec<usize>,
    /// Iterations fused into one stochastic chunk dispatch.
    pub chunk_t: usize,
    /// Iterations fused into one pwGradient chunk dispatch.
    pub pw_t: usize,
}

impl Engine {
    /// Load + compile every artifact listed in `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts`"))?;
        let json = Json::parse(&text).context("parsing manifest.json")?;
        let meta = ManifestMeta {
            n: json.req("n")?.as_usize().context("manifest n")?,
            d: json.req("d")?.as_usize().context("manifest d")?,
            rs: json
                .req("rs")?
                .as_arr()
                .context("manifest rs")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            chunk_t: json.req("chunk_t")?.as_usize().context("chunk_t")?,
            pw_t: json.req("pw_t")?.as_usize().context("pw_t")?,
        };
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut ops = HashMap::new();
        for op in json.req("ops")?.as_arr().context("manifest ops")? {
            let sig = parse_signature(op)?;
            let path = dir.join(&sig.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path utf8")?,
            )
            .with_context(|| format!("loading HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", sig.name))?;
            ops.insert(sig.name.clone(), CompiledOp { sig, exe });
        }
        Ok(Engine {
            client,
            ops,
            manifest_meta: meta,
            dir: dir.to_path_buf(),
        })
    }

    /// Default artifact directory: $HDPW_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("HDPW_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Whether an artifact named `name` was compiled.
    pub fn has_op(&self, name: &str) -> bool {
        self.ops.contains_key(name)
    }

    /// Sorted names of every compiled artifact.
    pub fn op_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.ops.keys().map(String::as_str).collect();
        v.sort_unstable();
        v
    }

    /// The manifest signature of one artifact (None if not compiled).
    pub fn signature(&self, name: &str) -> Option<&OpSignature> {
        self.ops.get(name).map(|c| &c.sig)
    }

    /// Execute an artifact. Inputs are shape/dtype-checked against the
    /// manifest signature; outputs come back as flat f64 vectors (all
    /// artifact outputs are f64 arrays or scalars).
    pub fn execute(&self, name: &str, inputs: &[Value]) -> Result<Vec<Vec<f64>>> {
        let op = self
            .ops
            .get(name)
            .with_context(|| format!("no artifact named {name:?} (have: {:?})", self.op_names()))?;
        // validate
        if inputs.len() != op.sig.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                op.sig.inputs.len(),
                inputs.len()
            );
        }
        for (i, (val, (dims, dtype))) in inputs.iter().zip(&op.sig.inputs).enumerate() {
            if &val.dims() != dims || val.dtype_tag() != dtype {
                bail!(
                    "{name}: input {i} is {:?}/{} but manifest wants {:?}/{}",
                    val.dims(),
                    val.dtype_tag(),
                    dims,
                    dtype
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(Value::to_literal)
            .collect::<Result<_>>()?;
        let result = op.exe.execute::<xla::Literal>(&literals)?;
        let out = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the tuple
        let parts = out.to_tuple()?;
        if parts.len() != op.sig.outputs {
            bail!(
                "{name}: artifact returned {} outputs, manifest says {}",
                parts.len(),
                op.sig.outputs
            );
        }
        parts.iter().map(literal::literal_to_f64s).collect()
    }
}

fn parse_signature(op: &Json) -> Result<OpSignature> {
    let name = op.req("name")?.as_str().context("op name")?.to_string();
    let file = op.req("file")?.as_str().context("op file")?.to_string();
    let mut inputs = Vec::new();
    for inp in op.req("inputs")?.as_arr().context("op inputs")? {
        let dims: Vec<usize> = inp
            .req("shape")?
            .as_arr()
            .context("input shape")?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let dtype = inp
            .req("dtype")?
            .as_str()
            .context("input dtype")?
            .to_string();
        inputs.push((dims, dtype));
    }
    let outputs = op.req("outputs")?.as_usize().context("op outputs")?;
    Ok(OpSignature {
        name,
        file,
        inputs,
        outputs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signature_parsing() {
        let j = Json::parse(
            r#"{"name": "op1", "file": "op1.hlo.txt",
                "inputs": [{"shape": [4, 2], "dtype": "f64"},
                           {"shape": [], "dtype": "f64"}],
                "outputs": 2}"#,
        )
        .unwrap();
        let sig = parse_signature(&j).unwrap();
        assert_eq!(sig.name, "op1");
        assert_eq!(sig.inputs.len(), 2);
        assert_eq!(sig.inputs[0], (vec![4, 2], "f64".to_string()));
        assert_eq!(sig.inputs[1], (vec![], "f64".to_string()));
        assert_eq!(sig.outputs, 2);
    }

    #[test]
    fn signature_missing_field_errors() {
        let j = Json::parse(r#"{"name": "x"}"#).unwrap();
        assert!(parse_signature(&j).is_err());
    }

    #[test]
    fn default_dir_env_override() {
        // NOTE: env-var manipulation is process-global; keep this the only
        // test touching HDPW_ARTIFACTS.
        std::env::set_var("HDPW_ARTIFACTS", "/tmp/some_artifacts");
        assert_eq!(
            Engine::default_dir(),
            PathBuf::from("/tmp/some_artifacts")
        );
        std::env::remove_var("HDPW_ARTIFACTS");
        assert_eq!(Engine::default_dir(), PathBuf::from("artifacts"));
    }

    #[test]
    fn load_missing_dir_is_helpful() {
        let msg = match Engine::load(Path::new("/nonexistent/path")) {
            Ok(_) => panic!("expected error"),
            Err(e) => format!("{e:#}"),
        };
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
