//! PJRT runtime bridge: load AOT artifacts, compile once, execute on the
//! request path.
//!
//! `make artifacts` (python/compile/aot.py) writes HLO-text modules plus
//! `manifest.json`; [`Engine::load`] parses the manifest, compiles every
//! artifact on a PJRT CPU client and exposes typed execution entry points.
//! Python is never involved at runtime — the `hdpw` binary plus the
//! `artifacts/` directory is a complete deployment.

pub mod literal;
pub mod engine;
pub mod handle;

pub use engine::{Engine, OpSignature};
pub use handle::EngineHandle;
