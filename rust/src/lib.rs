//! # hdpw — large-scale constrained linear regression via two-step preconditioning
//!
//! A production-grade reproduction of *"Large Scale Constrained Linear
//! Regression Revisited: Faster Algorithms via Preconditioning"* (Di Wang,
//! Jinhui Xu, AAAI 2018).
//!
//! The library solves `min_{x in W} ||Ax - b||^2` for tall matrices
//! `A in R^{n x d}` (n >> d) and convex constraint sets `W` (unconstrained,
//! l1-ball, l2-ball), implementing the paper's algorithms:
//!
//! * [`solvers::HdpwBatchSgd`] — Algorithm 2: two-step preconditioning
//!   (sketch-QR + randomized Hadamard transform) followed by uniform
//!   mini-batch SGD with *optimal* batch-size speed-up.
//! * [`solvers::HdpwAccBatchSgd`] — Algorithm 6: same preconditioning with
//!   multi-epoch accelerated (Ghadimi–Lan) mini-batch SGD.
//! * [`solvers::PwGradient`] — Algorithm 4: preconditioned projected full
//!   gradient descent; a one-sketch reformulation of Iterative Hessian
//!   Sketch with linear convergence.
//! * Baselines from the paper's evaluation: [`solvers::Ihs`] (Pilanci &
//!   Wainwright), [`solvers::PwSgd`] (Yang et al. leverage-score SGD),
//!   plain [`solvers::Sgd`], [`solvers::Adagrad`], [`solvers::Svrg`] /
//!   pwSVRG, and an exact QR solver for ground truth.
//!
//! ## Architecture
//!
//! Three layers (see `DESIGN.md`):
//!
//! 1. **L1 Pallas kernels + L2 JAX graphs** (`python/compile/`) are lowered
//!    *once* at build time (`make artifacts`) to HLO text artifacts.
//! 2. **Runtime bridge** ([`runtime`]) loads the artifacts into a PJRT CPU
//!    client; the [`backend`] abstraction dispatches each numerical op to a
//!    compiled executable when the shape matches the manifest, falling back
//!    to the from-scratch native implementations in [`linalg`]/[`sketch`].
//! 3. **L3 coordinator** ([`coordinator`]) owns jobs, scheduling, trials,
//!    metrics and the serve loop. Python is never on the request path.

pub mod util;
pub mod linalg;
pub mod sketch;
pub mod prox;
pub mod precond;
pub mod data;
pub mod solvers;
pub mod runtime;
pub mod backend;
pub mod coordinator;
pub mod experiments;

pub use linalg::matrix::Mat;
pub use linalg::sparse::CsrMat;
pub use util::rng::Rng;
