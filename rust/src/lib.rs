//! # hdpw — large-scale constrained linear regression via two-step preconditioning
//!
//! A production-grade reproduction of *"Large Scale Constrained Linear
//! Regression Revisited: Faster Algorithms via Preconditioning"* (Di Wang,
//! Jinhui Xu, AAAI 2018).
//!
//! The library solves `min_{x in W} ||Ax - b||^2` for tall matrices
//! `A in R^{n x d}` (n >> d) and arbitrary convex constraint sets `W`
//! (see [`constraints`]: unconstrained, l1/l2 balls, boxes, the probability
//! simplex, the nonnegative orthant, elastic-net balls, affine equalities —
//! or your own [`constraints::ConstraintSet`] implementation),
//! implementing the paper's algorithms:
//!
//! * [`solvers::HdpwBatchSgd`] — Algorithm 2: two-step preconditioning
//!   (sketch-QR + randomized Hadamard transform) followed by uniform
//!   mini-batch SGD with *optimal* batch-size speed-up.
//! * [`solvers::HdpwAccBatchSgd`] — Algorithm 6: same preconditioning with
//!   multi-epoch accelerated (Ghadimi–Lan) mini-batch SGD.
//! * [`solvers::PwGradient`] — Algorithm 4: preconditioned projected full
//!   gradient descent; a one-sketch reformulation of Iterative Hessian
//!   Sketch with linear convergence.
//! * Baselines from the paper's evaluation: [`solvers::Ihs`] (Pilanci &
//!   Wainwright), [`solvers::PwSgd`] (Yang et al. leverage-score SGD),
//!   plain [`solvers::Sgd`], [`solvers::Adagrad`], [`solvers::Svrg`] /
//!   pwSVRG, and an exact QR solver for ground truth.
//!
//! ## Quickstart (library)
//!
//! ```no_run
//! use hdpw::backend::Backend;
//! use hdpw::coordinator::{Coordinator, CoordinatorConfig, JobRequest};
//!
//! let coord = Coordinator::new(Backend::native(), CoordinatorConfig::default());
//! let mut req = JobRequest::default();
//! req.solver = "pwgradient".into();
//! req.constraint = "simplex".into(); // any ConstraintSpec form
//! let result = coord.run_job(&req).unwrap();
//! println!("f(best) = {:.3e} under {}", result.best_f, result.constraint);
//! ```
//!
//! The `hdpw` binary wraps the same coordinator (`hdpw solve`, `hdpw
//! serve`, `hdpw experiment`, `hdpw bench-info` — see the README).
//!
//! ## Architecture
//!
//! Three layers (see `DESIGN.md` §§1–11; §12 is the constraint guide):
//!
//! 1. **L1 Pallas kernels + L2 JAX graphs** (`python/compile/`) are lowered
//!    *once* at build time (`make artifacts`) to HLO text artifacts.
//! 2. **Runtime bridge** ([`runtime`]) loads the artifacts into a PJRT CPU
//!    client; the [`backend`] abstraction dispatches each numerical op to a
//!    compiled executable when the shape matches the manifest, falling back
//!    to the arch-dispatched [`simd`] microkernels and the from-scratch
//!    native implementations in [`linalg`]/[`sketch`].
//! 3. **L3 coordinator** ([`coordinator`]) owns jobs, scheduling, trials,
//!    metrics and the serve loop. Python is never on the request path.
//!
//! ## Documentation policy
//!
//! `#![warn(missing_docs)]` is enforced (CI runs `cargo doc` with
//! `RUSTDOCFLAGS="-D warnings"`) on the *entire* public surface — every
//! module, [`experiments`] included. There are no `#[allow(missing_docs)]`
//! escape hatches left: a new public item without a doc comment fails the
//! docs job, so the rustdoc output is always complete.

#![warn(missing_docs)]

pub mod util;
pub mod linalg;
pub mod simd;
pub mod sketch;
pub mod prox;
pub mod constraints;
pub mod precond;
pub mod data;
pub mod solvers;
pub mod runtime;
pub mod backend;
pub mod coordinator;
pub mod experiments;

pub use constraints::{ConstraintRef, ConstraintSet, ConstraintSpec};
pub use linalg::matrix::Mat;
pub use linalg::sparse::CsrMat;
pub use util::rng::Rng;
