//! aarch64 NEON vector type: 2 x f64 in a 128-bit register.
//!
//! NEON (with f64 arithmetic and FMA) is a mandatory part of the aarch64
//! baseline, so detection on that arch is unconditional. With only 2 lanes
//! the accumulation tree differs from the 4-lane reference — parity against
//! native is tolerance-gated, not bitwise.

use super::kernels::simd_kernel_wrappers;
use super::vector::SimdF64;
use core::arch::aarch64::*;

/// 2 x f64 in a NEON `float64x2_t`, FMA via `vfmaq_f64`.
#[derive(Clone, Copy)]
pub(crate) struct F64x2Neon(float64x2_t);

impl SimdF64 for F64x2Neon {
    const LANES: usize = 2;

    unsafe fn splat(v: f64) -> Self {
        F64x2Neon(vdupq_n_f64(v))
    }

    unsafe fn load(ptr: *const f64) -> Self {
        F64x2Neon(vld1q_f64(ptr))
    }

    unsafe fn store(self, ptr: *mut f64) {
        vst1q_f64(ptr, self.0)
    }

    unsafe fn add(self, rhs: Self) -> Self {
        F64x2Neon(vaddq_f64(self.0, rhs.0))
    }

    unsafe fn sub(self, rhs: Self) -> Self {
        F64x2Neon(vsubq_f64(self.0, rhs.0))
    }

    unsafe fn mul(self, rhs: Self) -> Self {
        F64x2Neon(vmulq_f64(self.0, rhs.0))
    }

    unsafe fn mul_add(self, a: Self, b: Self) -> Self {
        // vfmaq_f64(acc, x, y) = acc + x*y; our contract is self*a + b
        F64x2Neon(vfmaq_f64(b.0, self.0, a.0))
    }

    unsafe fn hsum(self) -> f64 {
        vaddvq_f64(self.0)
    }

    unsafe fn gather(base: *const f64, idx: *const u32) -> Self {
        let lo = *base.add(*idx as usize);
        let hi = *base.add(*idx.add(1) as usize);
        let buf = [lo, hi];
        Self::load(buf.as_ptr())
    }
}

/// NEON kernel entry points.
pub(crate) mod neon {
    super::simd_kernel_wrappers!(
        super::F64x2Neon,
        #[target_feature(enable = "neon")]
    );
}
