//! Arch-dispatched SIMD microkernels for the five hot paths: dense
//! `gemv`/`gemv_t`/`gemm`, the fused gradient/residual kernels, the FWHT
//! butterfly, the sketch row-scatter primitives, and CSR row gathers.
//!
//! ## Structure (DESIGN.md §13)
//!
//! * [`vector`] — the [`SimdF64`] lane trait plus the bit-faithful
//!   [`F64x4Scalar`] fallback; AVX2/AVX-512 live in `x86`, NEON in `neon`.
//! * [`kernels`] — generic register-tiled kernels, monomorphized per vector
//!   type inside `#[target_feature]` wrappers.
//! * this module — one-time runtime detection ([`arch`]), the resulting
//!   function-pointer [`KernelTable`], and the safe, thread-parallel public
//!   ops the [`crate::backend::SimdExecutor`] calls.
//!
//! Detection runs once (`OnceLock`) at first use — registry init in
//! practice. `HDPW_SIMD` overrides it: `scalar` is always honored (that is
//! the reproducibility escape hatch), `avx2`/`avx512`/`neon` only when the
//! CPU/build supports them (otherwise a warning and auto-detection).
//!
//! ## Numerics contract
//!
//! The native executor stays the bit-exact reference. These kernels change
//! accumulation order (lane-parallel partial sums) and contract mul+add
//! into FMA, so results differ from native by floating-point
//! re-association only: for the shapes in this crate the parity suite
//! pins `|simd - native| <= 1e-12 * (1 + |native|)` elementwise. The
//! elementwise `row_add`/`row_sub` scatter ops reorder nothing and are
//! bit-identical on every arch.

#![deny(clippy::undocumented_unsafe_blocks)]

pub mod kernels;
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;
pub mod vector;
#[cfg(target_arch = "x86_64")]
pub(crate) mod x86;

pub use vector::{F64x4Scalar, SimdF64};

use crate::linalg::{CsrMat, Mat};
use crate::util::threadpool::parallel_for_each_index;
use std::sync::{Mutex, OnceLock};

/// Instruction set selected by runtime detection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdArch {
    /// AVX-512F, 8 lanes (only selectable with the `avx512` cargo feature).
    Avx512,
    /// AVX2 + FMA, 4 lanes.
    Avx2,
    /// aarch64 NEON, 2 lanes.
    Neon,
    /// Portable scalar fallback (4 virtual lanes, `f64::mul_add`).
    Scalar,
}

impl SimdArch {
    /// Short label for reports and `bench-info`.
    pub fn name(self) -> &'static str {
        match self {
            SimdArch::Avx512 => "avx512",
            SimdArch::Avx2 => "avx2",
            SimdArch::Neon => "neon",
            SimdArch::Scalar => "scalar",
        }
    }

    /// f64 lanes per vector register on this arch.
    pub fn lanes(self) -> usize {
        match self {
            SimdArch::Avx512 => 8,
            SimdArch::Avx2 => 4,
            SimdArch::Neon => 2,
            SimdArch::Scalar => 4,
        }
    }
}

static ARCH: OnceLock<SimdArch> = OnceLock::new();

/// The arch every simd op in this process dispatches to. Detected once on
/// first call (honoring `HDPW_SIMD`), then cached.
pub fn arch() -> SimdArch {
    *ARCH.get_or_init(detect)
}

/// Whether [`crate::backend::Backend::auto`] should prefer the simd
/// executor: true when a real vector unit was detected (the scalar
/// fallback buys nothing over native).
pub fn preferred() -> bool {
    arch() != SimdArch::Scalar
}

fn detect() -> SimdArch {
    if let Ok(req) = std::env::var("HDPW_SIMD") {
        let req = req.trim().to_ascii_lowercase();
        match req.as_str() {
            "" | "auto" => {}
            "scalar" => return SimdArch::Scalar,
            other => {
                if let Some(a) = try_forced(other) {
                    return a;
                }
                crate::log_warn!(
                    "HDPW_SIMD={other:?} not supported by this CPU/build; auto-detecting"
                );
            }
        }
    }
    detect_native()
}

/// Honor an explicit `HDPW_SIMD` arch request iff this build and CPU
/// support it.
fn try_forced(name: &str) -> Option<SimdArch> {
    match name {
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        "avx512" if is_x86_feature_detected!("avx512f") => Some(SimdArch::Avx512),
        #[cfg(target_arch = "x86_64")]
        "avx2" if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") => {
            Some(SimdArch::Avx2)
        }
        #[cfg(target_arch = "aarch64")]
        "neon" => Some(SimdArch::Neon),
        _ => None,
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_native() -> SimdArch {
    #[cfg(feature = "avx512")]
    if is_x86_feature_detected!("avx512f") {
        return SimdArch::Avx512;
    }
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        return SimdArch::Avx2;
    }
    SimdArch::Scalar
}

#[cfg(target_arch = "aarch64")]
fn detect_native() -> SimdArch {
    // NEON with f64 FMA is part of the aarch64 baseline
    SimdArch::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_native() -> SimdArch {
    SimdArch::Scalar
}

// ---------------------------------------------------------------------------
// dispatch table
// ---------------------------------------------------------------------------

/// Function-pointer table of the per-arch kernel entry points — built once
/// from [`arch`], so the per-call cost of dispatch is one indirect call
/// (amortized over whole row ranges / panels).
pub(crate) struct KernelTable {
    pub gemv_rows: unsafe fn(&Mat, &[f64], &mut [f64], usize, usize),
    pub gemv_t_rows: unsafe fn(&Mat, &[f64], &mut [f64], usize, usize),
    pub fused_grad_rows: unsafe fn(&Mat, &[f64], &[f64], &mut [f64], usize, usize),
    pub residual_sq_rows: unsafe fn(&Mat, &[f64], &[f64], usize, usize) -> f64,
    pub gemm_rows: unsafe fn(&Mat, &Mat, *mut f64, usize, usize),
    pub fwht_butterflies: unsafe fn(&mut [f64]),
    pub fwht_panel: unsafe fn(*mut f64, usize, usize, usize, usize),
    pub scale_slice: unsafe fn(&mut [f64], f64),
    pub row_add: unsafe fn(&mut [f64], &[f64]),
    pub row_sub: unsafe fn(&mut [f64], &[f64]),
    pub row_axpy: unsafe fn(&mut [f64], f64, &[f64]),
    pub csr_row_dot: unsafe fn(&[u32], &[f64], &[f64]) -> f64,
    pub hd_scatter_row: unsafe fn(&[u32], &[f64], f64, &[f64], &mut [f64], usize, &mut [f64]),
    pub lanes: usize,
}

macro_rules! kernel_table {
    ($m:path) => {{
        use $m as k;
        KernelTable {
            gemv_rows: k::gemv_rows,
            gemv_t_rows: k::gemv_t_rows,
            fused_grad_rows: k::fused_grad_rows,
            residual_sq_rows: k::residual_sq_rows,
            gemm_rows: k::gemm_rows,
            fwht_butterflies: k::fwht_butterflies,
            fwht_panel: k::fwht_panel,
            scale_slice: k::scale_slice,
            row_add: k::row_add,
            row_sub: k::row_sub,
            row_axpy: k::row_axpy,
            csr_row_dot: k::csr_row_dot,
            hd_scatter_row: k::hd_scatter_row,
            lanes: k::LANES,
        }
    }};
}

static TABLE: OnceLock<KernelTable> = OnceLock::new();

pub(crate) fn table() -> &'static KernelTable {
    TABLE.get_or_init(|| match arch() {
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        SimdArch::Avx512 => kernel_table!(crate::simd::x86::avx512),
        #[cfg(target_arch = "x86_64")]
        SimdArch::Avx2 => kernel_table!(crate::simd::x86::avx2),
        #[cfg(target_arch = "aarch64")]
        SimdArch::Neon => kernel_table!(crate::simd::neon::neon),
        _ => kernel_table!(crate::simd::kernels::scalar),
    })
}

/// Lane width of the dispatched kernels (after any `HDPW_SIMD` override).
pub fn lanes() -> usize {
    table().lanes
}

// ---------------------------------------------------------------------------
// safe, thread-parallel public ops (the SimdExecutor's kernel surface)
// ---------------------------------------------------------------------------

struct SendPtr(*mut f64);
// SAFETY: workers write disjoint regions behind this pointer (enforced by
// the row/panel partitioning at each use site) and the owner outlives the
// pool join.
unsafe impl Send for SendPtr {}
// SAFETY: as above — shared access is only used to derive disjoint ranges.
unsafe impl Sync for SendPtr {}
impl SendPtr {
    #[inline]
    fn get(&self) -> *mut f64 {
        self.0
    }
}

/// `y = A x`, row-parallel (same blocking/thresholds as `blas::gemv`).
pub fn gemv(a: &Mat, x: &[f64], threads: usize) -> Vec<f64> {
    assert_eq!(a.cols, x.len());
    let k = table();
    let mut y = vec![0.0; a.rows];
    let t = if a.rows * a.cols > 1 << 16 { threads.max(1) } else { 1 };
    if t <= 1 {
        // SAFETY: table kernels match the CPU features verified at
        // detection; `y` has `a.rows` elements and `x` matches `a.cols`.
        unsafe { (k.gemv_rows)(a, x, &mut y, 0, a.rows) };
        return y;
    }
    let block = a.rows.div_ceil(t * 4).max(64);
    let nblocks = a.rows.div_ceil(block);
    let yptr = SendPtr(y.as_mut_ptr());
    parallel_for_each_index(nblocks, t, |bi| {
        let lo = bi * block;
        let hi = (lo + block).min(a.rows);
        // SAFETY: each block writes only indices [lo, hi) — disjoint across
        // workers — and `y` outlives the pool join; kernel preconditions as
        // in the serial branch.
        unsafe {
            let out = std::slice::from_raw_parts_mut(yptr.get(), a.rows);
            (k.gemv_rows)(a, x, out, lo, hi);
        }
    });
    y
}

/// `y = A^T x` with per-block partials merged in block order
/// (deterministic for a fixed thread count).
pub fn gemv_t(a: &Mat, x: &[f64], threads: usize) -> Vec<f64> {
    assert_eq!(a.rows, x.len());
    let k = table();
    let t = if a.rows * a.cols > 1 << 16 { threads.max(1) } else { 1 };
    if t <= 1 {
        let mut y = vec![0.0; a.cols];
        // SAFETY: verified table kernels; `y.len() == a.cols`,
        // `x.len() == a.rows`.
        unsafe { (k.gemv_t_rows)(a, x, &mut y, 0, a.rows) };
        return y;
    }
    let block = a.rows.div_ceil(t).max(64);
    let nblocks = a.rows.div_ceil(block);
    let partials: Vec<Mutex<Vec<f64>>> = (0..nblocks)
        .map(|_| Mutex::new(vec![0.0; a.cols]))
        .collect();
    parallel_for_each_index(nblocks, t, |bi| {
        let lo = bi * block;
        let hi = (lo + block).min(a.rows);
        let mut local = partials[bi].lock().unwrap();
        // SAFETY: verified table kernels; `local.len() == a.cols`.
        unsafe { (k.gemv_t_rows)(a, x, &mut local, lo, hi) };
    });
    let mut y = vec![0.0; a.cols];
    for p in &partials {
        // SAFETY: verified table kernels; equal lengths by construction.
        unsafe { (k.row_add)(&mut y, &p.lock().unwrap()) };
    }
    y
}

/// `g = scale * A^T (A x - b)` — the fused gradient, partials merged in
/// block order.
pub fn fused_grad(a: &Mat, b: &[f64], x: &[f64], scale: f64, threads: usize) -> Vec<f64> {
    assert_eq!(a.rows, b.len());
    assert_eq!(a.cols, x.len());
    let k = table();
    let t = if a.rows * a.cols > 1 << 16 { threads.max(1) } else { 1 };
    let block = a.rows.div_ceil(t).max(64);
    let nblocks = a.rows.div_ceil(block);
    let mut g = vec![0.0; a.cols];
    if nblocks <= 1 {
        // SAFETY: verified table kernels; shapes asserted above.
        unsafe {
            (k.fused_grad_rows)(a, b, x, &mut g, 0, a.rows);
            (k.scale_slice)(&mut g, scale);
        }
        return g;
    }
    let partials: Vec<Mutex<Vec<f64>>> = (0..nblocks)
        .map(|_| Mutex::new(vec![0.0; a.cols]))
        .collect();
    parallel_for_each_index(nblocks, t, |bi| {
        let lo = bi * block;
        let hi = (lo + block).min(a.rows);
        let mut local = partials[bi].lock().unwrap();
        // SAFETY: verified table kernels; `local.len() == a.cols`.
        unsafe { (k.fused_grad_rows)(a, b, x, &mut local, lo, hi) };
    });
    for p in &partials {
        // SAFETY: verified table kernels; equal lengths by construction.
        unsafe { (k.row_add)(&mut g, &p.lock().unwrap()) };
    }
    // SAFETY: verified table kernels.
    unsafe { (k.scale_slice)(&mut g, scale) };
    g
}

/// `||A x - b||^2`, block partials summed in block order.
pub fn residual_sq(a: &Mat, b: &[f64], x: &[f64], threads: usize) -> f64 {
    assert_eq!(a.rows, b.len());
    assert_eq!(a.cols, x.len());
    let k = table();
    let t = if a.rows * a.cols > 1 << 16 { threads.max(1) } else { 1 };
    let block = a.rows.div_ceil(t).max(64);
    let nblocks = a.rows.div_ceil(block);
    if nblocks <= 1 {
        // SAFETY: verified table kernels; shapes asserted above.
        return unsafe { (k.residual_sq_rows)(a, b, x, 0, a.rows) };
    }
    let partials: Vec<Mutex<f64>> = (0..nblocks).map(|_| Mutex::new(0.0)).collect();
    parallel_for_each_index(nblocks, t, |bi| {
        let lo = bi * block;
        let hi = (lo + block).min(a.rows);
        // SAFETY: verified table kernels; row range within bounds.
        let s = unsafe { (k.residual_sq_rows)(a, b, x, lo, hi) };
        *partials[bi].lock().unwrap() = s;
    });
    partials.iter().map(|p| *p.lock().unwrap()).sum()
}

/// `C = A B`, register-tiled and row-block parallel (same `MB = 64`
/// blocking as `blas::gemm`).
pub fn gemm(a: &Mat, b: &Mat, threads: usize) -> Mat {
    assert_eq!(a.cols, b.rows);
    let k = table();
    let mut c = Mat::zeros(a.rows, b.cols);
    let flops = 2.0 * a.rows as f64 * b.cols as f64 * a.cols as f64;
    let t = if flops > 1e6 { threads.max(1) } else { 1 };
    const MB: usize = 64;
    let nblocks = a.rows.div_ceil(MB);
    let cptr = SendPtr(c.data.as_mut_ptr());
    parallel_for_each_index(nblocks, t, |bi| {
        let i0 = bi * MB;
        let i1 = (i0 + MB).min(a.rows);
        // SAFETY: each block writes only C rows [i0, i1) — disjoint across
        // workers — behind a buffer valid for `a.rows * b.cols` elements;
        // verified table kernels, dims asserted above.
        unsafe { (k.gemm_rows)(a, b, cptr.get(), i0, i1) };
    });
    c
}

/// In-place FWHT of a vector (power-of-two length), orthonormal
/// `1/sqrt(n)` convention — the simd counterpart of
/// [`crate::sketch::fwht::fwht_vec`].
pub fn fwht_vec(x: &mut [f64]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "fwht length must be a power of two");
    let k = table();
    // SAFETY: verified table kernels; `n` asserted a power of two.
    unsafe {
        (k.fwht_butterflies)(x);
        (k.scale_slice)(x, 1.0 / (n as f64).sqrt());
    }
}

/// In-place FWHT along axis 0 of a row-major matrix, parallel over column
/// panels — the simd counterpart of [`crate::sketch::fwht::fwht_mat`]
/// (same thresholds and panel split).
pub fn fwht_mat(a: &mut Mat, threads: usize) {
    let n = a.rows;
    let d = a.cols;
    assert!(n.is_power_of_two(), "fwht rows must be a power of two");
    let k = table();
    let t = if n * d > 1 << 15 { threads.max(1) } else { 1 };
    let scale = 1.0 / (n as f64).sqrt();
    if t <= 1 || d < 2 {
        // SAFETY: verified table kernels; buffer holds `n * d` elements and
        // `n` is a power of two.
        unsafe {
            (k.fwht_panel)(a.data.as_mut_ptr(), n, d, 0, d);
            (k.scale_slice)(&mut a.data, scale);
        }
        return;
    }
    let panel = d.div_ceil(t).max(8);
    let npanels = d.div_ceil(panel);
    let ptr = SendPtr(a.data.as_mut_ptr());
    parallel_for_each_index(npanels, t, |pi| {
        let lo = pi * panel;
        let hi = (lo + panel).min(d);
        // SAFETY: butterflies never mix columns, and each worker touches
        // only columns [lo, hi) — disjoint across workers; buffer valid for
        // `n * d` elements and outlives the pool join.
        unsafe {
            (k.fwht_panel)(ptr.get(), n, d, lo, hi);
            for i in 0..n {
                let row_seg = std::slice::from_raw_parts_mut(ptr.get().add(i * d + lo), hi - lo);
                (k.scale_slice)(row_seg, scale);
            }
        }
    });
}

/// The paper's Randomized Hadamard Transform `HD` in place — the simd
/// counterpart of [`crate::sketch::fwht::randomized_hadamard`]. The sign
/// flip is exact (negation), so all re-association lives in the FWHT.
pub fn randomized_hadamard(a: &mut Mat, signs: &[f64], threads: usize) {
    assert_eq!(a.rows, signs.len());
    for i in 0..a.rows {
        if signs[i] < 0.0 {
            for v in a.row_mut(i) {
                *v = -*v;
            }
        }
    }
    fwht_mat(a, threads);
}

/// `dst += src` via the dispatched lanewise kernel (bit-identical to the
/// scalar loop — no re-association).
pub fn row_add(dst: &mut [f64], src: &[f64]) {
    assert_eq!(dst.len(), src.len());
    // SAFETY: verified table kernels; equal lengths asserted.
    unsafe { (table().row_add)(dst, src) }
}

/// `dst -= src` via the dispatched lanewise kernel (bit-identical).
pub fn row_sub(dst: &mut [f64], src: &[f64]) {
    assert_eq!(dst.len(), src.len());
    // SAFETY: verified table kernels; equal lengths asserted.
    unsafe { (table().row_sub)(dst, src) }
}

/// `dst += c * src` via the dispatched fused kernel (FMA — equal to the
/// scalar loop up to one rounding per element).
pub fn row_axpy(dst: &mut [f64], c: f64, src: &[f64]) {
    assert_eq!(dst.len(), src.len());
    // SAFETY: verified table kernels; equal lengths asserted.
    unsafe { (table().row_axpy)(dst, c, src) }
}

/// The sketch-scatter primitive bundle backed by the kernels above — what
/// the simd executor threads through `sketch::apply_streamed_with`.
pub fn row_ops() -> crate::sketch::RowOps {
    crate::sketch::RowOps {
        add: row_add,
        sub: row_sub,
        axpy: row_axpy,
    }
}

/// `A_i · x` for a CSR row via lane gathers — the simd counterpart of
/// [`CsrMat::row_dot`].
pub fn csr_row_dot(a: &CsrMat, i: usize, x: &[f64]) -> f64 {
    assert!(x.len() >= a.cols, "x too short for gather");
    let (cols, vals) = a.row(i);
    // SAFETY: verified table kernels; CsrMat guarantees every column index
    // is below `a.cols <= x.len()` (asserted).
    unsafe { (table().csr_row_dot)(cols, vals, x) }
}

/// One source-row scatter of the blockwise implicit-HD gather (see
/// [`crate::precond::ImplicitHd::gather_rows_csr`]): adds
/// `coeffs[k] * [row | bj]` into output row `k` of the contiguous row-major
/// tile `out` (leading dimension `ld`) and into `outb[k]`, for every `k`,
/// while the CSR row stays cache-hot. Bit-identical to the per-row scalar
/// reference on every arch — the kernel uses plain `mul`+`add`, never FMA,
/// and reorders nothing — so precond can route through the dispatched
/// table unconditionally without perturbing the native numerics contract
/// (`HDPW_SIMD=scalar` still forces the scalar instantiation).
pub fn hd_scatter_row(
    cols: &[u32],
    vals: &[f64],
    bj: f64,
    coeffs: &[f64],
    out: &mut [f64],
    ld: usize,
    outb: &mut [f64],
) {
    assert_eq!(cols.len(), vals.len());
    assert_eq!(coeffs.len(), outb.len());
    assert_eq!(out.len(), coeffs.len() * ld);
    assert!(
        cols.iter().all(|&c| (c as usize) < ld),
        "column index outside the output tile"
    );
    // SAFETY: verified table kernels; lengths and column bounds asserted
    // above match the kernel's documented preconditions.
    unsafe { (table().hd_scatter_row)(cols, vals, bj, coeffs, out, ld, outb) }
}

/// Mini-batch gradient `scale * A_tau^T (A_tau x - b_tau)` on CSR rows —
/// the simd counterpart of [`CsrMat::batch_grad`]: gathered row dots, with
/// the O(nnz) scatter kept scalar (scattered writes do not vectorize
/// profitably without conflict detection).
pub fn csr_batch_grad(a: &CsrMat, tau: &[usize], b: &[f64], x: &[f64], scale: f64) -> Vec<f64> {
    assert!(x.len() >= a.cols, "x too short for gather");
    let k = table();
    let mut g = vec![0.0; a.cols];
    for &i in tau {
        let (cols, vals) = a.row(i);
        // SAFETY: verified table kernels; column indices bounded by
        // `a.cols <= x.len()` (asserted).
        let r = unsafe { (k.csr_row_dot)(cols, vals, x) } - b[i];
        for (c, v) in cols.iter().zip(vals) {
            g[*c as usize] += r * v;
        }
    }
    // SAFETY: verified table kernels.
    unsafe { (k.scale_slice)(&mut g, scale) };
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::rng::Rng;

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-12 * (1.0 + b.abs())
    }

    #[test]
    fn arch_and_table_are_consistent() {
        let a = arch();
        assert_eq!(a, arch(), "detection must be cached");
        assert_eq!(lanes(), table().lanes);
        assert!(lanes() >= 2);
        assert!(!a.name().is_empty());
        assert_eq!(a.lanes(), lanes());
    }

    #[test]
    fn gemv_matches_blas_serial_and_parallel() {
        let mut rng = Rng::new(1);
        for (n, d, t) in [(7usize, 3usize, 1usize), (129, 17, 1), (1 << 10, 300, 4)] {
            let a = Mat::gaussian(n, d, &mut rng);
            let x = rng.gaussians(d);
            let got = gemv(&a, &x, t);
            let want = blas::gemv(&a, &x);
            for (g, w) in got.iter().zip(&want) {
                assert!(close(*g, *w), "n={n} d={d}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn fused_grad_and_residual_match_blas() {
        let mut rng = Rng::new(2);
        let a = Mat::gaussian(211, 13, &mut rng);
        let b = rng.gaussians(211);
        let x = rng.gaussians(13);
        let got = fused_grad(&a, &b, &x, 2.0, 2);
        let want = blas::fused_grad(&a, &b, &x, 2.0);
        for (g, w) in got.iter().zip(&want) {
            assert!(close(*g, *w), "{g} vs {w}");
        }
        let fr = residual_sq(&a, &b, &x, 2);
        assert!(close(fr, blas::residual_sq(&a, &b, &x)));
    }

    #[test]
    fn fwht_matches_native_convention() {
        let mut rng = Rng::new(3);
        let mut v = rng.gaussians(256);
        let mut want = v.clone();
        crate::sketch::fwht::fwht_vec(&mut want);
        fwht_vec(&mut v);
        for (g, w) in v.iter().zip(&want) {
            assert!(close(*g, *w), "{g} vs {w}");
        }
        let m = Mat::gaussian(128, 5, &mut rng);
        let mut got = m.clone();
        let mut nat = m.clone();
        fwht_mat(&mut got, 2);
        crate::sketch::fwht::fwht_mat(&mut nat);
        assert!(got.max_abs_diff(&nat) < 1e-10);
    }

    #[test]
    fn row_ops_add_sub_bit_identical_axpy_close() {
        let mut rng = Rng::new(4);
        for len in [1usize, 3, 4, 5, 8, 31, 257] {
            let src = rng.gaussians(len);
            let base = rng.gaussians(len);
            let mut simd_dst = base.clone();
            let mut ref_dst = base.clone();
            row_add(&mut simd_dst, &src);
            for (o, v) in ref_dst.iter_mut().zip(&src) {
                *o += v;
            }
            assert_eq!(
                simd_dst.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                ref_dst.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "row_add must be bit-identical (len {len})"
            );
            row_sub(&mut simd_dst, &src);
            for (o, v) in ref_dst.iter_mut().zip(&src) {
                *o -= v;
            }
            assert_eq!(
                simd_dst.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                ref_dst.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "row_sub must be bit-identical (len {len})"
            );
            row_axpy(&mut simd_dst, 1.5, &src);
            for (o, v) in ref_dst.iter_mut().zip(&src) {
                *o += 1.5 * v;
            }
            for (g, w) in simd_dst.iter().zip(&ref_dst) {
                assert!(close(*g, *w), "len {len}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn csr_kernels_match_sparse_reference() {
        let mut rng = Rng::new(5);
        let dense = Mat::from_fn(40, 9, |_, _| {
            if rng.uniform() < 0.4 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let csr = CsrMat::from_dense(&dense);
        let x = rng.gaussians(9);
        for i in 0..40 {
            assert!(close(csr_row_dot(&csr, i, &x), csr.row_dot(i, &x)), "row {i}");
        }
        let b = rng.gaussians(40);
        let tau: Vec<usize> = (0..16).map(|_| rng.below(40)).collect();
        let got = csr_batch_grad(&csr, &tau, &b, &x, 8.0);
        let want = csr.batch_grad(&tau, &b, &x, 8.0);
        for (g, w) in got.iter().zip(&want) {
            assert!(close(*g, *w), "{g} vs {w}");
        }
    }

    #[test]
    fn hd_scatter_row_is_bit_identical_to_scalar_loop() {
        let mut rng = Rng::new(6);
        let dense = Mat::from_fn(24, 7, |_, _| {
            if rng.uniform() < 0.5 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let csr = CsrMat::from_dense(&dense);
        for r in [1usize, 2, 3, 4, 5, 9, 17] {
            let coeffs = rng.gaussians(r);
            let base = rng.gaussians(r * 7);
            let baseb = rng.gaussians(r);
            for j in 0..24 {
                let (cols, vals) = csr.row(j);
                let bj = rng.gaussian();
                let mut got = base.clone();
                let mut gotb = baseb.clone();
                hd_scatter_row(cols, vals, bj, &coeffs, &mut got, 7, &mut gotb);
                // scalar reference: same mul+add per element, ascending order
                let mut want = base.clone();
                let mut wantb = baseb.clone();
                for (k, &c) in coeffs.iter().enumerate() {
                    wantb[k] += c * bj;
                    for (ci, v) in cols.iter().zip(vals) {
                        want[k * 7 + *ci as usize] += c * v;
                    }
                }
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "design panel must be bit-identical (r={r} j={j})"
                );
                assert_eq!(
                    gotb.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    wantb.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "response panel must be bit-identical (r={r} j={j})"
                );
            }
        }
    }
}
