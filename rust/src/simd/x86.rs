//! x86-64 vector types: AVX2+FMA (4 lanes) and, behind the off-by-default
//! `avx512` cargo feature, AVX-512F (8 lanes).
//!
//! The trait impl methods call intrinsics directly (intrinsics are
//! themselves feature-gated functions, so this is *correct* on any CPU once
//! the dispatch table has verified support); the `#[target_feature]`
//! wrappers generated at the bottom are what makes it *fast*, by compiling
//! each monomorphized kernel inside the feature region.

use super::kernels::simd_kernel_wrappers;
use super::vector::SimdF64;
use core::arch::x86_64::*;

/// 4 x f64 in a 256-bit AVX2 register, FMA arithmetic.
#[derive(Clone, Copy)]
pub(crate) struct F64x4Avx2(__m256d);

impl SimdF64 for F64x4Avx2 {
    const LANES: usize = 4;

    unsafe fn splat(v: f64) -> Self {
        F64x4Avx2(_mm256_set1_pd(v))
    }

    unsafe fn zero() -> Self {
        F64x4Avx2(_mm256_setzero_pd())
    }

    unsafe fn load(ptr: *const f64) -> Self {
        F64x4Avx2(_mm256_loadu_pd(ptr))
    }

    unsafe fn store(self, ptr: *mut f64) {
        _mm256_storeu_pd(ptr, self.0)
    }

    unsafe fn add(self, rhs: Self) -> Self {
        F64x4Avx2(_mm256_add_pd(self.0, rhs.0))
    }

    unsafe fn sub(self, rhs: Self) -> Self {
        F64x4Avx2(_mm256_sub_pd(self.0, rhs.0))
    }

    unsafe fn mul(self, rhs: Self) -> Self {
        F64x4Avx2(_mm256_mul_pd(self.0, rhs.0))
    }

    unsafe fn mul_add(self, a: Self, b: Self) -> Self {
        F64x4Avx2(_mm256_fmadd_pd(self.0, a.0, b.0))
    }

    unsafe fn hsum(self) -> f64 {
        // fold 256 -> 128: [l0+l2, l1+l3], then the two 64-bit halves —
        // the tree F64x4Scalar::hsum mirrors bit-for-bit
        let lo = _mm256_castpd256_pd128(self.0);
        let hi = _mm256_extractf128_pd::<1>(self.0);
        let pair = _mm_add_pd(lo, hi);
        let high64 = _mm_unpackhi_pd(pair, pair);
        _mm_cvtsd_f64(_mm_add_sd(pair, high64))
    }

    unsafe fn gather(base: *const f64, idx: *const u32) -> Self {
        // i32 gather sign-extends: u32 indices must stay below 2^31 —
        // guaranteed by CsrMat's `cols <= u32::MAX` bound in practice (a
        // 2^31-column dense x would not fit memory); documented on the trait
        let iv = _mm_loadu_si128(idx as *const __m128i);
        F64x4Avx2(_mm256_i32gather_pd::<8>(base, iv))
    }
}

/// 8 x f64 in a 512-bit register. Off by default: enable the `avx512` cargo
/// feature on toolchains/CPUs that support it. Not bit-faithful to the
/// 4-lane types (different reduction width) — parity is tolerance-gated.
#[cfg(feature = "avx512")]
#[derive(Clone, Copy)]
pub(crate) struct F64x8Avx512(__m512d);

#[cfg(feature = "avx512")]
impl SimdF64 for F64x8Avx512 {
    const LANES: usize = 8;

    unsafe fn splat(v: f64) -> Self {
        F64x8Avx512(_mm512_set1_pd(v))
    }

    unsafe fn zero() -> Self {
        F64x8Avx512(_mm512_setzero_pd())
    }

    unsafe fn load(ptr: *const f64) -> Self {
        F64x8Avx512(_mm512_loadu_pd(ptr))
    }

    unsafe fn store(self, ptr: *mut f64) {
        _mm512_storeu_pd(ptr, self.0)
    }

    unsafe fn add(self, rhs: Self) -> Self {
        F64x8Avx512(_mm512_add_pd(self.0, rhs.0))
    }

    unsafe fn sub(self, rhs: Self) -> Self {
        F64x8Avx512(_mm512_sub_pd(self.0, rhs.0))
    }

    unsafe fn mul(self, rhs: Self) -> Self {
        F64x8Avx512(_mm512_mul_pd(self.0, rhs.0))
    }

    unsafe fn mul_add(self, a: Self, b: Self) -> Self {
        F64x8Avx512(_mm512_fmadd_pd(self.0, a.0, b.0))
    }

    unsafe fn hsum(self) -> f64 {
        _mm512_reduce_add_pd(self.0)
    }

    unsafe fn gather(base: *const f64, idx: *const u32) -> Self {
        // compose from scalar reads: dodges the wide-gather intrinsic's
        // byte-pointer signature; the dominant cost here is the memory
        // traffic either way
        let mut buf = [0.0f64; 8];
        for (k, b) in buf.iter_mut().enumerate() {
            *b = *base.add(*idx.add(k) as usize);
        }
        Self::load(buf.as_ptr())
    }
}

/// AVX2+FMA kernel entry points.
pub(crate) mod avx2 {
    super::simd_kernel_wrappers!(
        super::F64x4Avx2,
        #[target_feature(enable = "avx2", enable = "fma")]
    );
}

/// AVX-512F kernel entry points (feature-gated).
#[cfg(feature = "avx512")]
pub(crate) mod avx512 {
    super::simd_kernel_wrappers!(
        super::F64x8Avx512,
        #[target_feature(enable = "avx512f")]
    );
}
