//! The portable `f64` lane abstraction the microkernels are generic over.
//!
//! One trait, [`SimdF64`], with one implementation per instruction set
//! (AVX2/AVX-512 in [`super::x86`], NEON in [`super::neon`]) plus the
//! [`F64x4Scalar`] fallback defined here. Kernels in [`super::kernels`] are
//! written once against the trait and monomorphized per vector type; the
//! per-arch entry points wrap them in `#[target_feature]` functions so the
//! whole kernel body compiles inside the feature region (the rten pattern).
//!
//! ## Bit-faithfulness
//!
//! [`F64x4Scalar`] mirrors the 4-lane AVX2 type exactly: same lane count,
//! `f64::mul_add` for [`SimdF64::mul_add`] (IEEE-754 fused, identical to
//! hardware FMA), and the same pairwise [`SimdF64::hsum`] reduction tree
//! `(l0+l2) + (l1+l3)`. A kernel monomorphized over either type therefore
//! produces bit-identical results; archs with other lane counts (NEON x2,
//! AVX-512 x8) agree only up to floating-point re-association and are
//! covered by the parity suite's relative tolerance instead.

/// A fixed-width vector of `f64` lanes.
///
/// All methods are `unsafe`: the arch implementations compile to intrinsics
/// that are only valid once the matching CPU feature has been verified at
/// runtime (the dispatch table in [`super`] does this exactly once), and
/// `load`/`store`/`gather` take raw pointers with the usual validity
/// requirements.
pub trait SimdF64: Copy {
    /// Number of `f64` lanes.
    const LANES: usize;

    /// Broadcast `v` into every lane.
    ///
    /// # Safety
    /// The CPU must support this type's instruction set.
    unsafe fn splat(v: f64) -> Self;

    /// The zero vector.
    ///
    /// # Safety
    /// The CPU must support this type's instruction set.
    unsafe fn zero() -> Self {
        Self::splat(0.0)
    }

    /// Unaligned load of `LANES` consecutive values from `ptr`.
    ///
    /// # Safety
    /// The CPU must support this type's instruction set and
    /// `ptr..ptr+LANES` must be valid, initialized `f64`s.
    unsafe fn load(ptr: *const f64) -> Self;

    /// Unaligned store of the `LANES` lanes to `ptr`.
    ///
    /// # Safety
    /// The CPU must support this type's instruction set and
    /// `ptr..ptr+LANES` must be valid for writes.
    unsafe fn store(self, ptr: *mut f64);

    /// Lanewise `self + rhs`.
    ///
    /// # Safety
    /// The CPU must support this type's instruction set.
    unsafe fn add(self, rhs: Self) -> Self;

    /// Lanewise `self - rhs`.
    ///
    /// # Safety
    /// The CPU must support this type's instruction set.
    unsafe fn sub(self, rhs: Self) -> Self;

    /// Lanewise `self * rhs`.
    ///
    /// # Safety
    /// The CPU must support this type's instruction set.
    unsafe fn mul(self, rhs: Self) -> Self;

    /// Fused lanewise `self * a + b` (single rounding, like `f64::mul_add`).
    ///
    /// # Safety
    /// The CPU must support this type's instruction set.
    unsafe fn mul_add(self, a: Self, b: Self) -> Self;

    /// Horizontal sum of all lanes. For the 4-lane types the reduction tree
    /// is pinned to `(l0+l2) + (l1+l3)` so scalar and AVX2 agree bitwise.
    ///
    /// # Safety
    /// The CPU must support this type's instruction set.
    unsafe fn hsum(self) -> f64;

    /// Gather `LANES` values: lane `k` reads `base[idx[k]]` with `u32`
    /// indices (the CSR column type; columns therefore must stay below
    /// `2^31` where the AVX2 gather sign-extends — enforced by `CsrMat`'s
    /// `cols <= u32::MAX` construction bound plus the `i32` headroom of
    /// every realistic `d`).
    ///
    /// # Safety
    /// The CPU must support this type's instruction set, `idx..idx+LANES`
    /// must be readable, and every `base[idx[k]]` must be in bounds.
    unsafe fn gather(base: *const f64, idx: *const u32) -> Self;
}

/// Bit-faithful scalar stand-in for the 4-lane FMA types: an `[f64; 4]`
/// register file driven by `f64::mul_add`. Compiles on every arch; this is
/// what the dispatch table selects when no vector unit is detected (and
/// what `HDPW_SIMD=scalar` forces).
#[derive(Clone, Copy, Debug)]
pub struct F64x4Scalar([f64; 4]);

impl SimdF64 for F64x4Scalar {
    const LANES: usize = 4;

    unsafe fn splat(v: f64) -> Self {
        F64x4Scalar([v; 4])
    }

    unsafe fn load(ptr: *const f64) -> Self {
        F64x4Scalar([ptr.read(), ptr.add(1).read(), ptr.add(2).read(), ptr.add(3).read()])
    }

    unsafe fn store(self, ptr: *mut f64) {
        ptr.write(self.0[0]);
        ptr.add(1).write(self.0[1]);
        ptr.add(2).write(self.0[2]);
        ptr.add(3).write(self.0[3]);
    }

    unsafe fn add(self, rhs: Self) -> Self {
        F64x4Scalar([
            self.0[0] + rhs.0[0],
            self.0[1] + rhs.0[1],
            self.0[2] + rhs.0[2],
            self.0[3] + rhs.0[3],
        ])
    }

    unsafe fn sub(self, rhs: Self) -> Self {
        F64x4Scalar([
            self.0[0] - rhs.0[0],
            self.0[1] - rhs.0[1],
            self.0[2] - rhs.0[2],
            self.0[3] - rhs.0[3],
        ])
    }

    unsafe fn mul(self, rhs: Self) -> Self {
        F64x4Scalar([
            self.0[0] * rhs.0[0],
            self.0[1] * rhs.0[1],
            self.0[2] * rhs.0[2],
            self.0[3] * rhs.0[3],
        ])
    }

    unsafe fn mul_add(self, a: Self, b: Self) -> Self {
        F64x4Scalar([
            self.0[0].mul_add(a.0[0], b.0[0]),
            self.0[1].mul_add(a.0[1], b.0[1]),
            self.0[2].mul_add(a.0[2], b.0[2]),
            self.0[3].mul_add(a.0[3], b.0[3]),
        ])
    }

    unsafe fn hsum(self) -> f64 {
        // same tree as the AVX2 128-bit fold: low+high halves, then lanes
        (self.0[0] + self.0[2]) + (self.0[1] + self.0[3])
    }

    unsafe fn gather(base: *const f64, idx: *const u32) -> Self {
        F64x4Scalar([
            base.add(idx.read() as usize).read(),
            base.add(idx.add(1).read() as usize).read(),
            base.add(idx.add(2).read() as usize).read(),
            base.add(idx.add(3).read() as usize).read(),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_lane_arithmetic() {
        let data = [1.0, 2.0, 3.0, 4.0];
        let ones = [1.0; 4];
        let mut out = [0.0; 4];
        // SAFETY: scalar impl, in-bounds stack arrays.
        unsafe {
            let v = F64x4Scalar::load(data.as_ptr());
            let w = F64x4Scalar::load(ones.as_ptr());
            v.add(w).store(out.as_mut_ptr());
            assert_eq!(out, [2.0, 3.0, 4.0, 5.0]);
            v.sub(w).store(out.as_mut_ptr());
            assert_eq!(out, [0.0, 1.0, 2.0, 3.0]);
            v.mul(v).store(out.as_mut_ptr());
            assert_eq!(out, [1.0, 4.0, 9.0, 16.0]);
            assert_eq!(v.hsum(), 10.0);
            let f = v.mul_add(v, w);
            f.store(out.as_mut_ptr());
            assert_eq!(out, [2.0, 5.0, 10.0, 17.0]);
        }
    }

    #[test]
    fn scalar_gather_reads_indices() {
        let base = [10.0, 11.0, 12.0, 13.0, 14.0];
        let idx: [u32; 4] = [4, 0, 2, 2];
        // SAFETY: indices all within `base`.
        let v = unsafe { F64x4Scalar::gather(base.as_ptr(), idx.as_ptr()) };
        let mut out = [0.0; 4];
        // SAFETY: in-bounds stack array.
        unsafe { v.store(out.as_mut_ptr()) };
        assert_eq!(out, [14.0, 10.0, 12.0, 12.0]);
    }
}
