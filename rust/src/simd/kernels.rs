//! Register-tiled microkernels, written once and monomorphized per
//! [`SimdF64`] vector type.
//!
//! Every kernel is `#[inline(always)]` so that when it is instantiated
//! inside a per-arch `#[target_feature]` wrapper (see the
//! [`simd_kernel_wrappers`] macro at the bottom), the whole body compiles
//! inside the feature region and the intrinsics fold into straight-line
//! vector code. Correctness never depends on that inlining — the intrinsics
//! are themselves feature-gated functions — only performance does.
//!
//! Tiling parameters: dot-product kernels keep [`ACC_REGS`] independent
//! vector accumulators in flight (breaking the FMA dependency chain), and
//! `gemm` computes [`NR_REGS`]-vector-wide output tiles per row. Tails that
//! do not fill a lane run scalar `f64::mul_add` code, so every shape is
//! handled; `gemm`'s ragged column tail stages through a zero-padded load
//! buffer and a `MaybeUninit` store tile so vector loads/stores never touch
//! memory outside the matrix.

use super::vector::SimdF64;
use crate::linalg::Mat;
use std::mem::MaybeUninit;

/// Independent accumulator registers in the dot-product kernels.
pub const ACC_REGS: usize = 4;
/// Output-tile width of `gemm_rows`, in vectors per row.
pub const NR_REGS: usize = 4;
/// Upper bound on `LANES * NR_REGS` across all arches (AVX-512 x 4).
pub const MAX_TILE: usize = 32;

/// `row · x` with [`ACC_REGS`]-way unrolled fused accumulation.
///
/// # Safety
/// The CPU must support `V`'s instruction set; `row` and `x` must have
/// equal length.
#[inline(always)]
pub unsafe fn row_dot<V: SimdF64>(row: &[f64], x: &[f64]) -> f64 {
    debug_assert_eq!(row.len(), x.len());
    let n = row.len();
    let l = V::LANES;
    let rp = row.as_ptr();
    let xp = x.as_ptr();
    let mut acc0 = V::zero();
    let mut acc1 = V::zero();
    let mut acc2 = V::zero();
    let mut acc3 = V::zero();
    let mut j = 0;
    while j + ACC_REGS * l <= n {
        acc0 = V::load(rp.add(j)).mul_add(V::load(xp.add(j)), acc0);
        acc1 = V::load(rp.add(j + l)).mul_add(V::load(xp.add(j + l)), acc1);
        acc2 = V::load(rp.add(j + 2 * l)).mul_add(V::load(xp.add(j + 2 * l)), acc2);
        acc3 = V::load(rp.add(j + 3 * l)).mul_add(V::load(xp.add(j + 3 * l)), acc3);
        j += ACC_REGS * l;
    }
    while j + l <= n {
        acc0 = V::load(rp.add(j)).mul_add(V::load(xp.add(j)), acc0);
        j += l;
    }
    // pairwise register fold, then the pinned in-register tree
    let mut s = acc0.add(acc2).add(acc1.add(acc3)).hsum();
    while j < n {
        s = row[j].mul_add(x[j], s);
        j += 1;
    }
    s
}

/// `out[i] = A_i · x` for rows `r0..r1`.
///
/// # Safety
/// The CPU must support `V`'s instruction set; `x.len() == a.cols`,
/// `out.len() >= r1 <= a.rows`.
#[inline(always)]
pub unsafe fn gemv_rows<V: SimdF64>(a: &Mat, x: &[f64], out: &mut [f64], r0: usize, r1: usize) {
    for i in r0..r1 {
        out[i] = row_dot::<V>(a.row(i), x);
    }
}

/// `dst += c * src` (fused).
///
/// # Safety
/// The CPU must support `V`'s instruction set; equal lengths.
#[inline(always)]
pub unsafe fn row_axpy<V: SimdF64>(dst: &mut [f64], c: f64, src: &[f64]) {
    debug_assert_eq!(dst.len(), src.len());
    let n = dst.len();
    let l = V::LANES;
    let cv = V::splat(c);
    let dp = dst.as_mut_ptr();
    let sp = src.as_ptr();
    let mut j = 0;
    while j + l <= n {
        cv.mul_add(V::load(sp.add(j)), V::load(dp.add(j))).store(dp.add(j));
        j += l;
    }
    while j < n {
        dst[j] = c.mul_add(src[j], dst[j]);
        j += 1;
    }
}

/// `dst += src`, lanewise. No FMA anywhere, so the result is bit-identical
/// to the scalar loop on every arch — the property the CountSketch scatter
/// parity relies on.
///
/// # Safety
/// The CPU must support `V`'s instruction set; equal lengths.
#[inline(always)]
pub unsafe fn row_add<V: SimdF64>(dst: &mut [f64], src: &[f64]) {
    debug_assert_eq!(dst.len(), src.len());
    let n = dst.len();
    let l = V::LANES;
    let dp = dst.as_mut_ptr();
    let sp = src.as_ptr();
    let mut j = 0;
    while j + l <= n {
        V::load(dp.add(j)).add(V::load(sp.add(j))).store(dp.add(j));
        j += l;
    }
    while j < n {
        dst[j] += src[j];
        j += 1;
    }
}

/// `dst -= src`, lanewise; bit-identical to the scalar loop (see
/// [`row_add`]).
///
/// # Safety
/// The CPU must support `V`'s instruction set; equal lengths.
#[inline(always)]
pub unsafe fn row_sub<V: SimdF64>(dst: &mut [f64], src: &[f64]) {
    debug_assert_eq!(dst.len(), src.len());
    let n = dst.len();
    let l = V::LANES;
    let dp = dst.as_mut_ptr();
    let sp = src.as_ptr();
    let mut j = 0;
    while j + l <= n {
        V::load(dp.add(j)).sub(V::load(sp.add(j))).store(dp.add(j));
        j += l;
    }
    while j < n {
        dst[j] -= src[j];
        j += 1;
    }
}

/// `v *= s`, lanewise.
///
/// # Safety
/// The CPU must support `V`'s instruction set.
#[inline(always)]
pub unsafe fn scale_slice<V: SimdF64>(v: &mut [f64], s: f64) {
    let n = v.len();
    let l = V::LANES;
    let sv = V::splat(s);
    let p = v.as_mut_ptr();
    let mut j = 0;
    while j + l <= n {
        V::load(p.add(j)).mul(sv).store(p.add(j));
        j += l;
    }
    while j < n {
        v[j] *= s;
        j += 1;
    }
}

/// `acc += Σ_{i in r0..r1} x[i] * A_i` — the row-major transposed matvec
/// partial used by `gemv_t`.
///
/// # Safety
/// The CPU must support `V`'s instruction set; `acc.len() == a.cols`,
/// `x.len() >= r1 <= a.rows`.
#[inline(always)]
pub unsafe fn gemv_t_rows<V: SimdF64>(a: &Mat, x: &[f64], acc: &mut [f64], r0: usize, r1: usize) {
    for i in r0..r1 {
        row_axpy::<V>(acc, x[i], a.row(i));
    }
}

/// `g += Σ_{i in r0..r1} (A_i · x - b[i]) * A_i` — the unscaled fused
/// residual/gradient partial (the caller applies `scale` once at the end,
/// matching `blas::fused_grad`'s structure).
///
/// # Safety
/// The CPU must support `V`'s instruction set; `g.len() == a.cols == x.len()`,
/// `b.len() >= r1 <= a.rows`.
#[inline(always)]
pub unsafe fn fused_grad_rows<V: SimdF64>(
    a: &Mat,
    b: &[f64],
    x: &[f64],
    g: &mut [f64],
    r0: usize,
    r1: usize,
) {
    for i in r0..r1 {
        let r = row_dot::<V>(a.row(i), x) - b[i];
        row_axpy::<V>(g, r, a.row(i));
    }
}

/// `Σ_{i in r0..r1} (A_i · x - b[i])^2`.
///
/// # Safety
/// The CPU must support `V`'s instruction set; `x.len() == a.cols`,
/// `b.len() >= r1 <= a.rows`.
#[inline(always)]
pub unsafe fn residual_sq_rows<V: SimdF64>(
    a: &Mat,
    b: &[f64],
    x: &[f64],
    r0: usize,
    r1: usize,
) -> f64 {
    let mut s = 0.0;
    for i in r0..r1 {
        let r = row_dot::<V>(a.row(i), x) - b[i];
        s = r.mul_add(r, s);
    }
    s
}

/// Rows `r0..r1` of `C = A B` into the raw row-major buffer `c` (row `i` at
/// `c + i * b.cols`), register-tiled: [`NR_REGS`] vector accumulators per
/// row held across the full `k` loop, broadcast-A times streamed-B. The
/// ragged column tail (width not a multiple of `LANES * NR_REGS`) loads B
/// through a zero-padded bounce buffer and stores through a partially
/// initialized `MaybeUninit` tile, of which only the in-bounds prefix is
/// copied back.
///
/// # Safety
/// The CPU must support `V`'s instruction set; `a.cols == b.rows`,
/// `r1 <= a.rows`, and `c` must be valid for `a.rows * b.cols` writes with
/// rows `r0..r1` unaliased by concurrent writers.
#[inline(always)]
pub unsafe fn gemm_rows<V: SimdF64>(a: &Mat, b: &Mat, c: *mut f64, r0: usize, r1: usize) {
    debug_assert_eq!(a.cols, b.rows);
    let kk = b.rows;
    let n = b.cols;
    let l = V::LANES;
    let tile = l * NR_REGS;
    debug_assert!(tile <= MAX_TILE);
    for i in r0..r1 {
        let arow = a.row(i);
        let crow = c.add(i * n);
        let mut j0 = 0;
        while j0 + tile <= n {
            let mut acc = [V::zero(); NR_REGS];
            for (k, &av) in arow.iter().enumerate().take(kk) {
                let avv = V::splat(av);
                let bp = b.row(k).as_ptr();
                for (r, accr) in acc.iter_mut().enumerate() {
                    *accr = avv.mul_add(V::load(bp.add(j0 + r * l)), *accr);
                }
            }
            for (r, accr) in acc.iter().enumerate() {
                accr.store(crow.add(j0 + r * l));
            }
            j0 += tile;
        }
        if j0 < n {
            let width = n - j0;
            let vecs = width.div_ceil(l);
            let mut acc = [V::zero(); NR_REGS];
            // zero-padded bounce buffer: vector loads of the ragged B tail
            // stay inside this stack array instead of running past the row
            let mut pad = [0.0f64; MAX_TILE];
            for (k, &av) in arow.iter().enumerate().take(kk) {
                let avv = V::splat(av);
                pad[..width].copy_from_slice(&b.row(k)[j0..]);
                for (r, accr) in acc.iter_mut().enumerate().take(vecs) {
                    *accr = avv.mul_add(V::load(pad.as_ptr().add(r * l)), *accr);
                }
            }
            // spill through a MaybeUninit tile: the vector stores initialize
            // exactly `vecs * l >= width` lanes, and only the first `width`
            // (all initialized) are copied into C
            let mut spill: [MaybeUninit<f64>; MAX_TILE] = [MaybeUninit::uninit(); MAX_TILE];
            let sp = spill.as_mut_ptr() as *mut f64;
            for (r, accr) in acc.iter().enumerate().take(vecs) {
                accr.store(sp.add(r * l));
            }
            for j in 0..width {
                crow.add(j0 + j).write(sp.add(j).read());
            }
        }
    }
}

/// In-place radix-2 FWHT butterflies over a single vector (no
/// normalization — the caller scales). Stages with stride `h >= LANES` run
/// vectorized; the first `log2(LANES)` stages are scalar, exactly as the
/// tentpole prescribes ("vectorized inner stages once stride ≥ lane
/// width").
///
/// # Safety
/// The CPU must support `V`'s instruction set; `v.len()` must be a power of
/// two (or 0/1).
#[inline(always)]
pub unsafe fn fwht_butterflies<V: SimdF64>(v: &mut [f64]) {
    let n = v.len();
    debug_assert!(n <= 1 || n.is_power_of_two());
    let l = V::LANES;
    let p = v.as_mut_ptr();
    let mut h = 1;
    while h < n {
        if h >= l {
            let mut i = 0;
            while i < n {
                let mut j = i;
                while j < i + h {
                    let x = V::load(p.add(j));
                    let y = V::load(p.add(j + h));
                    x.add(y).store(p.add(j));
                    x.sub(y).store(p.add(j + h));
                    j += l;
                }
                i += 2 * h;
            }
        } else {
            let mut i = 0;
            while i < n {
                for j in i..i + h {
                    let x = *p.add(j);
                    let y = *p.add(j + h);
                    *p.add(j) = x + y;
                    *p.add(j + h) = x - y;
                }
                i += 2 * h;
            }
        }
        h *= 2;
    }
}

/// Radix-2 FWHT butterflies along axis 0 of the row-major `n x d` buffer
/// `data`, restricted to columns `[c0, c1)` (no normalization). The
/// row-pair combine is a contiguous `row ± row` over the panel, vectorized
/// whenever the panel is at least a lane wide, scalar tail columns
/// otherwise — column panels never interact, so panels parallelize.
///
/// # Safety
/// The CPU must support `V`'s instruction set; `data` must be valid for
/// `n * d` elements, `n` a power of two, `c0 <= c1 <= d`, and no concurrent
/// writer may touch columns `[c0, c1)`.
#[inline(always)]
pub unsafe fn fwht_panel<V: SimdF64>(data: *mut f64, n: usize, d: usize, c0: usize, c1: usize) {
    debug_assert!(n <= 1 || n.is_power_of_two());
    let w = c1 - c0;
    let l = V::LANES;
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for row in i..i + h {
                let pa = data.add(row * d + c0);
                let pb = data.add((row + h) * d + c0);
                let mut j = 0;
                while j + l <= w {
                    let x = V::load(pa.add(j));
                    let y = V::load(pb.add(j));
                    x.add(y).store(pa.add(j));
                    x.sub(y).store(pb.add(j));
                    j += l;
                }
                while j < w {
                    let x = *pa.add(j);
                    let y = *pb.add(j);
                    *pa.add(j) = x + y;
                    *pb.add(j) = x - y;
                    j += 1;
                }
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// One source-row scatter of the blockwise implicit-HD gather: adds
/// `coeffs[k] * [row | bj]` into output row `k` for every `k`, while the
/// CSR row (`cols`/`vals`) is cache-hot. `out` is the contiguous row-major
/// output tile (`coeffs.len()` rows of leading dimension `ld`), `outb` the
/// matching response panel.
///
/// Numerics: the response panel runs lane-parallel `mul` + `add` (never
/// `mul_add`), and the design scatter is plain scalar `out += c * v` — no
/// FMA and no re-association anywhere, so the result is bit-identical to
/// the per-row reference loop on every arch (the property
/// `tests/implicit_gather.rs` gates). The vector win is the response panel
/// and the cache blocking; the scattered column writes stay scalar
/// (no profitable f64 scatter without conflict detection).
///
/// # Safety
/// The CPU must support `V`'s instruction set; `cols`/`vals` equal length,
/// `coeffs.len() == outb.len()`, `out.len() == coeffs.len() * ld`, and
/// every `cols[k] < ld`.
#[inline(always)]
pub unsafe fn hd_scatter_row<V: SimdF64>(
    cols: &[u32],
    vals: &[f64],
    bj: f64,
    coeffs: &[f64],
    out: &mut [f64],
    ld: usize,
    outb: &mut [f64],
) {
    debug_assert_eq!(cols.len(), vals.len());
    debug_assert_eq!(coeffs.len(), outb.len());
    debug_assert_eq!(out.len(), coeffs.len() * ld);
    let r = coeffs.len();
    let l = V::LANES;
    let cp = coeffs.as_ptr();
    // response panel: outb[k] += coeffs[k] * bj, lanewise mul+add
    let bv = V::splat(bj);
    let op = outb.as_mut_ptr();
    let mut k = 0;
    while k + l <= r {
        V::load(cp.add(k)).mul(bv).add(V::load(op.add(k))).store(op.add(k));
        k += l;
    }
    while k < r {
        outb[k] += coeffs[k] * bj;
        k += 1;
    }
    // design panel: scatter the hot source row into all r output rows
    let outp = out.as_mut_ptr();
    for t in 0..r {
        let c = *cp.add(t);
        let row = outp.add(t * ld);
        for (ci, v) in cols.iter().zip(vals) {
            let p = row.add(*ci as usize);
            *p += c * *v;
        }
    }
}

/// Sparse row dot `Σ_k vals[k] * x[cols[k]]` via lane gathers.
///
/// # Safety
/// The CPU must support `V`'s instruction set; `cols`/`vals` equal length
/// and every `cols[k] < x.len()`.
#[inline(always)]
pub unsafe fn csr_row_dot<V: SimdF64>(cols: &[u32], vals: &[f64], x: &[f64]) -> f64 {
    debug_assert_eq!(cols.len(), vals.len());
    let n = vals.len();
    let l = V::LANES;
    let cp = cols.as_ptr();
    let vp = vals.as_ptr();
    let xp = x.as_ptr();
    let mut acc = V::zero();
    let mut j = 0;
    while j + l <= n {
        let xv = V::gather(xp, cp.add(j));
        acc = V::load(vp.add(j)).mul_add(xv, acc);
        j += l;
    }
    let mut s = acc.hsum();
    while j < n {
        s = vals[j].mul_add(x[cols[j] as usize], s);
        j += 1;
    }
    s
}

/// Generates the per-arch kernel entry points: one thin `unsafe fn` per
/// kernel, carrying the arch's `#[target_feature]` attributes so the
/// generic bodies above monomorphize *inside* the feature region. Invoked
/// once per vector type (scalar / AVX2 / AVX-512 / NEON); the resulting
/// functions all share one signature set and populate
/// [`super::KernelTable`].
macro_rules! simd_kernel_wrappers {
    ($vec:ty $(, #[$attr:meta])*) => {
        $(#[$attr])*
        pub(crate) unsafe fn gemv_rows(
            a: &crate::linalg::Mat,
            x: &[f64],
            out: &mut [f64],
            r0: usize,
            r1: usize,
        ) {
            crate::simd::kernels::gemv_rows::<$vec>(a, x, out, r0, r1)
        }

        $(#[$attr])*
        pub(crate) unsafe fn gemv_t_rows(
            a: &crate::linalg::Mat,
            x: &[f64],
            acc: &mut [f64],
            r0: usize,
            r1: usize,
        ) {
            crate::simd::kernels::gemv_t_rows::<$vec>(a, x, acc, r0, r1)
        }

        $(#[$attr])*
        pub(crate) unsafe fn fused_grad_rows(
            a: &crate::linalg::Mat,
            b: &[f64],
            x: &[f64],
            g: &mut [f64],
            r0: usize,
            r1: usize,
        ) {
            crate::simd::kernels::fused_grad_rows::<$vec>(a, b, x, g, r0, r1)
        }

        $(#[$attr])*
        pub(crate) unsafe fn residual_sq_rows(
            a: &crate::linalg::Mat,
            b: &[f64],
            x: &[f64],
            r0: usize,
            r1: usize,
        ) -> f64 {
            crate::simd::kernels::residual_sq_rows::<$vec>(a, b, x, r0, r1)
        }

        $(#[$attr])*
        pub(crate) unsafe fn gemm_rows(
            a: &crate::linalg::Mat,
            b: &crate::linalg::Mat,
            c: *mut f64,
            r0: usize,
            r1: usize,
        ) {
            crate::simd::kernels::gemm_rows::<$vec>(a, b, c, r0, r1)
        }

        $(#[$attr])*
        pub(crate) unsafe fn fwht_butterflies(v: &mut [f64]) {
            crate::simd::kernels::fwht_butterflies::<$vec>(v)
        }

        $(#[$attr])*
        pub(crate) unsafe fn fwht_panel(
            data: *mut f64,
            n: usize,
            d: usize,
            c0: usize,
            c1: usize,
        ) {
            crate::simd::kernels::fwht_panel::<$vec>(data, n, d, c0, c1)
        }

        $(#[$attr])*
        pub(crate) unsafe fn scale_slice(v: &mut [f64], s: f64) {
            crate::simd::kernels::scale_slice::<$vec>(v, s)
        }

        $(#[$attr])*
        pub(crate) unsafe fn row_add(dst: &mut [f64], src: &[f64]) {
            crate::simd::kernels::row_add::<$vec>(dst, src)
        }

        $(#[$attr])*
        pub(crate) unsafe fn row_sub(dst: &mut [f64], src: &[f64]) {
            crate::simd::kernels::row_sub::<$vec>(dst, src)
        }

        $(#[$attr])*
        pub(crate) unsafe fn row_axpy(dst: &mut [f64], c: f64, src: &[f64]) {
            crate::simd::kernels::row_axpy::<$vec>(dst, c, src)
        }

        $(#[$attr])*
        pub(crate) unsafe fn csr_row_dot(cols: &[u32], vals: &[f64], x: &[f64]) -> f64 {
            crate::simd::kernels::csr_row_dot::<$vec>(cols, vals, x)
        }

        $(#[$attr])*
        pub(crate) unsafe fn hd_scatter_row(
            cols: &[u32],
            vals: &[f64],
            bj: f64,
            coeffs: &[f64],
            out: &mut [f64],
            ld: usize,
            outb: &mut [f64],
        ) {
            crate::simd::kernels::hd_scatter_row::<$vec>(cols, vals, bj, coeffs, out, ld, outb)
        }

        /// Lane width of this entry-point set.
        pub(crate) const LANES: usize = <$vec as crate::simd::vector::SimdF64>::LANES;
    };
}
pub(crate) use simd_kernel_wrappers;

/// The scalar-fallback entry points: same shape as the arch modules, no
/// feature attributes, valid on every CPU.
pub(crate) mod scalar {
    crate::simd::kernels::simd_kernel_wrappers!(crate::simd::vector::F64x4Scalar);
}
