//! R-metric projections — the paper's Step-6 quadratic subproblem.
//!
//! Algorithm 2 Step 6 (and Algorithms 4/6 analogously) requires
//!     x_t = argmin_{x in W} 1/2 ||R(x~ - x)||^2
//! where x~ is the unconstrained preconditioned step. For W = R^d this is
//! x~ itself; otherwise it is a *metric* projection under H = R^T R, which
//! the paper prices at poly(d) ("just a quadratic optimization problem in d
//! dimensions"). Using the plain Euclidean projection instead breaks
//! convergence on ill-conditioned data: H has eigenvalue spread kappa(A)^2
//! (1e12 and beyond on Syn1/Buzz), so pulling an iterate back radially can
//! *increase* the R-metric distance and the iteration diverges — our
//! integration tests reproduce exactly that failure mode.
//!
//! This module owns the *metric machinery*: the one-per-job H = Q diag(lam)
//! Q^T eigendecomposition and three reusable primitives —
//!
//! * [`MetricProjector::project_l2_ball`] — dual Newton/bisection on the
//!   Lagrange multiplier: in the eigenbasis x(mu) = Q diag(lam/(lam+mu))
//!   Q^T x~, with ||x(mu)|| monotone in mu; exact to tolerance in ~60
//!   bisections, each O(d).
//! * [`MetricProjector::project_admm`] — generic ADMM splitting
//!   min 1/2 (x-x~)^T H (x-x~) + I_C(u), x = u: the x-update is diagonal in
//!   the eigenbasis, the u-update is any *Euclidean* projection oracle.
//!   This is the documented fallback contract every
//!   [`crate::constraints::ConstraintSet`] inherits: a set only needs its
//!   Euclidean projector, and the metric projection reduces to repeated
//!   Euclidean projections (with H = I it collapses to a single one).
//! * [`MetricProjector::h_inv_apply`] — apply H^{-1} through the eigenbasis
//!   (the KKT building block for sets with closed-form metric projections,
//!   e.g. affine equality).
//!
//! *Which* primitive a constraint set uses is the set's decision:
//! [`MetricProjector::project`] just dispatches to
//! [`crate::constraints::ConstraintSet::project_metric`].

use crate::constraints::ConstraintSet;
use crate::linalg::blas::{self, nrm2};
use crate::linalg::eigen::{sym_eigen, SymEigen};
use crate::linalg::Mat;

/// Precomputed H = R^T R eigendecomposition + scratch for projections.
pub struct MetricProjector {
    eig: SymEigen,
    d: usize,
    /// ADMM penalty (geometric mean of the eigenvalue range).
    rho_admm: f64,
}

impl MetricProjector {
    /// Build from the triangular preconditioner factor R (H = R^T R).
    pub fn from_r(r: &Mat) -> MetricProjector {
        let h = blas::gemm(&r.transpose(), r);
        Self::from_h(&h)
    }

    /// Build from an explicit symmetric positive-definite H.
    pub fn from_h(h: &Mat) -> MetricProjector {
        let eig = sym_eigen(h);
        let d = h.rows;
        let lmin = eig.vals.first().copied().unwrap_or(1.0).max(1e-300);
        let lmax = eig.vals.last().copied().unwrap_or(1.0).max(lmin);
        MetricProjector {
            eig,
            d,
            rho_admm: (lmin * lmax).sqrt(),
        }
    }

    /// Project z onto the constraint set in the H-metric. Dispatches to the
    /// set's own [`ConstraintSet::project_metric`] strategy (exact
    /// bisection for the l2 ball, ADMM around the Euclidean oracle for most
    /// sets, a closed-form KKT solve for affine equality, identity when
    /// unconstrained).
    pub fn project(&self, z: &[f64], cons: &dyn ConstraintSet) -> Vec<f64> {
        cons.project_metric(self, z)
    }

    /// Exact metric projection onto the l2 ball: x(mu) = (H + mu I)^{-1} H z
    /// with ||x(mu)|| decreasing in mu; bisect on the multiplier. Interior
    /// points are returned untouched.
    pub fn project_l2_ball(&self, z: &[f64], radius: f64) -> Vec<f64> {
        if nrm2(z) <= radius {
            return z.to_vec();
        }
        // work in the eigenbasis: w = Q^T z
        let w = blas::gemv(&self.eig.v.transpose(), z);
        let norm_at = |mu: f64| -> f64 {
            let mut s = 0.0;
            for (wi, li) in w.iter().zip(&self.eig.vals) {
                let xi = wi * li / (li + mu);
                s += xi * xi;
            }
            s.sqrt()
        };
        // bracket: mu = 0 gives ||z|| > radius; grow hi until below
        let mut lo = 0.0;
        let mut hi = self.eig.vals.last().copied().unwrap_or(1.0).max(1e-300);
        while norm_at(hi) > radius {
            hi *= 4.0;
            if hi > 1e300 {
                break;
            }
        }
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if norm_at(mid) > radius {
                lo = mid;
            } else {
                hi = mid;
            }
            if (hi - lo) <= 1e-14 * hi {
                break;
            }
        }
        let mu = 0.5 * (lo + hi);
        let xw: Vec<f64> = w
            .iter()
            .zip(&self.eig.vals)
            .map(|(wi, li)| wi * li / (li + mu))
            .collect();
        blas::gemv(&self.eig.v, &xw)
    }

    /// Apply H^{-1} through the eigenbasis: H^{-1} v = Q diag(1/lam) Q^T v.
    /// O(d^2) per call; used by closed-form KKT metric projections (affine
    /// equality solves (C H^{-1} C^T) lam = Cz - e with this).
    pub fn h_inv_apply(&self, v: &[f64]) -> Vec<f64> {
        let w = blas::gemv(&self.eig.v.transpose(), v);
        let scaled: Vec<f64> = w
            .iter()
            .zip(&self.eig.vals)
            .map(|(wi, li)| wi / li.max(1e-300))
            .collect();
        blas::gemv(&self.eig.v, &scaled)
    }

    /// Generic ADMM: min 1/2 (x-z)^T H (x-z) + I_C(u), x = u, where
    /// `proj_c` is the *Euclidean* projection onto C. This is the fallback
    /// contract behind [`ConstraintSet::project_metric`]: any convex set
    /// with a Euclidean oracle gets a correct metric projection.
    pub fn project_admm(&self, z: &[f64], proj_c: impl Fn(&mut [f64])) -> Vec<f64> {
        let d = self.d;
        let rho = self.rho_admm;
        // eigenbasis coordinates of z
        let qtz = blas::gemv(&self.eig.v.transpose(), z);
        // (H + rho I)^{-1} applied in eigenbasis: divide by (lam + rho)
        // NOTE: no warm start across calls — a stale scaled dual `w` from a
        // different z biases the fixed point and stalls the outer solver at
        // the ADMM tolerance (observed as pwGradient/l1 plateauing at 1e-3
        // while fresh-start IHS reached 1e-10).
        let mut u = z.to_vec();
        let mut w = vec![0.0; d];
        let mut x = z.to_vec();
        for _ in 0..200 {
            // x = (H + rho I)^{-1} (H z + rho (u - w))
            let t: Vec<f64> = u.iter().zip(&w).map(|(ui, wi)| ui - wi).collect();
            let qtt = blas::gemv(&self.eig.v.transpose(), &t);
            let xw: Vec<f64> = (0..d)
                .map(|i| {
                    (self.eig.vals[i] * qtz[i] + rho * qtt[i]) / (self.eig.vals[i] + rho)
                })
                .collect();
            x = blas::gemv(&self.eig.v, &xw);
            // u = proj_C(x + w)
            let mut unew: Vec<f64> = x.iter().zip(&w).map(|(xi, wi)| xi + wi).collect();
            proj_c(&mut unew);
            // primal residual for early exit
            let mut r2 = 0.0;
            for (xi, ui) in x.iter().zip(&unew) {
                r2 += (xi - ui) * (xi - ui);
            }
            for ((wi, xi), ui) in w.iter_mut().zip(&x).zip(&unew) {
                *wi += xi - ui;
            }
            u = unew;
            if r2.sqrt() <= 1e-12 * (1.0 + nrm2(&x)) {
                break;
            }
        }
        // return the feasible iterate
        let _ = x;
        u
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::{L1Ball, L2Ball, Unconstrained};
    use crate::util::rng::Rng;

    fn h_matrix(d: usize, kappa: f64, rng: &mut Rng) -> Mat {
        // H = Q diag(spread) Q^T
        let g = Mat::gaussian(d, d, rng);
        let q = crate::linalg::qr::qr(&g).q.unwrap();
        let mut h = Mat::zeros(d, d);
        for j in 0..d {
            let lam = kappa.powf(-(j as f64) / (d as f64 - 1.0));
            for i in 0..d {
                for k in 0..d {
                    h.data[i * d + k] += q.at(i, j) * lam * q.at(k, j);
                }
            }
        }
        h
    }

    fn metric_dist(h: &Mat, a: &[f64], b: &[f64]) -> f64 {
        let diff = blas::sub(a, b);
        blas::dot(&diff, &blas::gemv(h, &diff))
    }

    #[test]
    fn l2_projection_lands_on_boundary_and_is_optimal() {
        let mut rng = Rng::new(1);
        let h = h_matrix(8, 1e8, &mut rng);
        let proj = MetricProjector::from_h(&h);
        let z: Vec<f64> = rng.gaussians(8).iter().map(|v| v * 5.0).collect();
        let radius = 1.0;
        let x = proj.project(&z, &L2Ball { radius });
        assert!((nrm2(&x) - radius).abs() < 1e-8, "||x|| = {}", nrm2(&x));
        // optimality: no feasible random candidate is metric-closer to z
        let dx = metric_dist(&h, &z, &x);
        for _ in 0..500 {
            let mut c = rng.gaussians(8);
            let nc = nrm2(&c);
            if nc > radius {
                for v in &mut c {
                    *v *= radius / nc;
                }
            }
            assert!(metric_dist(&h, &z, &c) >= dx - 1e-8);
        }
    }

    #[test]
    fn l1_projection_feasible_and_optimal_vs_candidates() {
        let mut rng = Rng::new(2);
        let h = h_matrix(6, 1e6, &mut rng);
        let proj = MetricProjector::from_h(&h);
        let z: Vec<f64> = rng.gaussians(6).iter().map(|v| v * 3.0).collect();
        let radius = 1.0;
        let x = proj.project(&z, &L1Ball { radius });
        let l1: f64 = x.iter().map(|v| v.abs()).sum();
        assert!(l1 <= radius + 1e-7, "||x||_1 = {l1}");
        let dx = metric_dist(&h, &z, &x);
        for _ in 0..500 {
            let mut c = rng.gaussians(6);
            let nc: f64 = c.iter().map(|v| v.abs()).sum();
            if nc > radius {
                for v in &mut c {
                    *v *= radius / nc;
                }
            }
            assert!(
                metric_dist(&h, &z, &c) >= dx - 1e-6 * (1.0 + dx),
                "candidate beats ADMM: {} vs {dx}",
                metric_dist(&h, &z, &c)
            );
        }
    }

    #[test]
    fn interior_points_untouched() {
        let mut rng = Rng::new(3);
        let h = h_matrix(5, 100.0, &mut rng);
        let proj = MetricProjector::from_h(&h);
        let z = vec![0.01; 5];
        let x2 = proj.project(&z, &L2Ball { radius: 1.0 });
        let x1 = proj.project(&z, &L1Ball { radius: 1.0 });
        for i in 0..5 {
            assert!((x2[i] - z[i]).abs() < 1e-12);
            assert!((x1[i] - z[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_metric_reduces_to_euclidean() {
        let mut rng = Rng::new(4);
        let h = Mat::eye(7);
        let proj = MetricProjector::from_h(&h);
        let z: Vec<f64> = rng.gaussians(7).iter().map(|v| v * 4.0).collect();
        // l2
        let got = proj.project(&z, &L2Ball { radius: 1.0 });
        let mut want = z.clone();
        crate::prox::project_l2(&mut want, 1.0);
        for i in 0..7 {
            assert!((got[i] - want[i]).abs() < 1e-8);
        }
        // l1
        let got = proj.project(&z, &L1Ball { radius: 1.5 });
        let mut want = z.clone();
        crate::prox::project_l1(&mut want, 1.5);
        for i in 0..7 {
            assert!((got[i] - want[i]).abs() < 1e-6, "{} vs {}", got[i], want[i]);
        }
    }

    #[test]
    fn h_inv_apply_inverts_h() {
        let mut rng = Rng::new(6);
        let h = h_matrix(7, 1e6, &mut rng);
        let proj = MetricProjector::from_h(&h);
        let v = rng.gaussians(7);
        let hv = blas::gemv(&h, &v);
        let back = proj.h_inv_apply(&hv);
        for i in 0..7 {
            assert!((back[i] - v[i]).abs() < 1e-6, "{} vs {}", back[i], v[i]);
        }
    }

    #[test]
    fn unconstrained_metric_projection_is_identity() {
        let mut rng = Rng::new(8);
        let h = h_matrix(5, 1e4, &mut rng);
        let proj = MetricProjector::from_h(&h);
        let z = rng.gaussians(5);
        assert_eq!(proj.project(&z, &Unconstrained), z);
    }

    #[test]
    fn from_r_equals_from_h() {
        let mut rng = Rng::new(5);
        let a = Mat::gaussian(50, 6, &mut rng);
        let r = crate::linalg::qr::qr_r(&a);
        let p1 = MetricProjector::from_r(&r);
        let h = blas::gemm(&r.transpose(), &r);
        let p2 = MetricProjector::from_h(&h);
        let z: Vec<f64> = rng.gaussians(6).iter().map(|v| v * 3.0).collect();
        let c = L2Ball { radius: 0.5 };
        let x1 = p1.project(&z, &c);
        let x2 = p2.project(&z, &c);
        for i in 0..6 {
            assert!((x1[i] - x2[i]).abs() < 1e-8);
        }
    }
}
