//! Euclidean projection math for the constraint sets W.
//!
//! This module is the *arithmetic* layer: pure in-place projection
//! operators (l2/l1 balls, probability simplex, elastic-net ball) and the
//! soft-threshold prox. The *policy* layer — which set a solve runs under,
//! how sets are described on the wire, and how the R-metric variant of each
//! projection is obtained — lives in [`crate::constraints`], whose
//! [`crate::constraints::ConstraintSet`] trait dispatches into these
//! functions. The paper evaluates the unconstrained case and l1-/l2-ball
//! constraints (ball radii set to the norms of the unconstrained optimum);
//! the wider family exists because the projection oracle is the pluggable
//! part of every algorithm here (`x <- Proj_W(x - eta g)`).
//!
//! The ball projections mirror the `_project` functions in the L2 graphs
//! (python/compile/model.py) and are cross-checked against them in the
//! integration tests; every operator is checked against an O(d^2)
//! brute-force reference in `tests/prox_reference.rs`.

pub mod metric;

use crate::linalg::blas::nrm2;

/// Project onto the l2 ball (in place).
pub fn project_l2(x: &mut [f64], radius: f64) {
    let n = nrm2(x);
    if n > radius {
        let s = radius / n;
        for v in x {
            *v *= s;
        }
    }
}

/// Project onto the l1 ball via the Duchi et al. (2008) pivot algorithm
/// (O(d log d) with a sort — d is small here so the sort variant is right).
pub fn project_l1(x: &mut [f64], radius: f64) {
    assert!(radius >= 0.0);
    let l1: f64 = x.iter().map(|v| v.abs()).sum();
    if l1 <= radius {
        return;
    }
    let mut u: Vec<f64> = x.iter().map(|v| v.abs()).collect();
    u.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut css = 0.0;
    let mut theta = 0.0;
    let mut rho = 0;
    for (j, &uj) in u.iter().enumerate() {
        css += uj;
        let t = (css - radius) / (j + 1) as f64;
        if uj - t > 0.0 {
            rho = j + 1;
            theta = t;
        }
    }
    debug_assert!(rho > 0);
    for v in x.iter_mut() {
        let mag = (v.abs() - theta).max(0.0);
        *v = v.signum() * mag;
    }
}

/// Project onto the scaled probability simplex
/// `{x : x_i >= 0, sum_i x_i = total}` (in place) — the sort-based
/// O(d log d) algorithm (Held/Wolfe/Crowder; the same pivot structure as
/// [`project_l1`]). Unlike the ball projections there is no interior
/// short-circuit: points off the `sum = total` hyperplane always move.
pub fn project_simplex(x: &mut [f64], total: f64) {
    assert!(total > 0.0, "simplex total must be positive");
    let mut u = x.to_vec();
    u.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut css = 0.0;
    let mut theta = 0.0;
    for (j, &uj) in u.iter().enumerate() {
        css += uj;
        let t = (css - total) / (j + 1) as f64;
        // valid pivot while the j-th largest coordinate stays positive
        if uj - t > 0.0 {
            theta = t;
        }
    }
    for v in x.iter_mut() {
        *v = (*v - theta).max(0.0);
    }
}

/// The elastic-net penalty `alpha ||x||_1 + (1 - alpha)/2 ||x||_2^2` —
/// the sublevel-set value the elastic-net ball constrains.
pub fn elastic_net_value(x: &[f64], alpha: f64) -> f64 {
    let mut l1 = 0.0;
    let mut l2sq = 0.0;
    for &v in x {
        l1 += v.abs();
        l2sq += v * v;
    }
    alpha * l1 + 0.5 * (1.0 - alpha) * l2sq
}

/// Project onto the elastic-net ball
/// `{x : alpha ||x||_1 + (1 - alpha)/2 ||x||_2^2 <= radius}` (in place)
/// by bisection on the scalar dual multiplier `nu`.
///
/// KKT structure: the projection of `x` is coordinate-separable given `nu`,
///     y_i(nu) = sign(x_i) * max(|x_i| - nu*alpha, 0) / (1 + nu*(1-alpha)),
/// and the constraint value `g(y(nu))` is continuous and strictly
/// decreasing in `nu` wherever `y != 0`, so the active multiplier is the
/// root of `g(y(nu)) = radius` — bracketed by doubling, then bisected to
/// relative width ~1e-16 (far below the 1e-10 test acceptance). At
/// `alpha = 1` the set degenerates to the l1 ball, at `alpha = 0` to the
/// l2 ball of radius `sqrt(2 radius)`.
pub fn project_elastic_net(x: &mut [f64], alpha: f64, radius: f64) {
    assert!((0.0..=1.0).contains(&alpha), "alpha must be in [0, 1]");
    assert!(radius > 0.0, "elastic-net radius must be positive");
    if elastic_net_value(x, alpha) <= radius {
        return;
    }
    let shrink = |nu: f64, xi: f64| -> f64 {
        let mag = (xi.abs() - nu * alpha).max(0.0) / (1.0 + nu * (1.0 - alpha));
        xi.signum() * mag
    };
    let value_at = |nu: f64| -> f64 {
        let mut l1 = 0.0;
        let mut l2sq = 0.0;
        for &xi in x.iter() {
            let yi = shrink(nu, xi);
            l1 += yi.abs();
            l2sq += yi * yi;
        }
        alpha * l1 + 0.5 * (1.0 - alpha) * l2sq
    };
    // bracket: nu = 0 is infeasible (checked above); grow hi until feasible
    let mut lo = 0.0;
    let mut hi = 1.0;
    while value_at(hi) > radius {
        hi *= 2.0;
        if hi > 1e300 {
            break;
        }
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if value_at(mid) > radius {
            lo = mid;
        } else {
            hi = mid;
        }
        if (hi - lo) <= 1e-16 * (1.0 + hi) {
            break;
        }
    }
    // take the feasible end of the bracket
    let nu = hi;
    for v in x.iter_mut() {
        *v = shrink(nu, *v);
    }
}

/// Soft-threshold operator (prox of lambda*||.||_1) — used by the
/// signal-recovery example's ISTA baseline.
pub fn soft_threshold(x: &mut [f64], lambda: f64) {
    for v in x.iter_mut() {
        let mag = (v.abs() - lambda).max(0.0);
        *v = v.signum() * mag;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn l1_norm(x: &[f64]) -> f64 {
        x.iter().map(|v| v.abs()).sum()
    }

    #[test]
    fn l2_inside_untouched_outside_scaled() {
        let mut x = vec![0.3, 0.4];
        project_l2(&mut x, 1.0);
        assert_eq!(x, vec![0.3, 0.4]);
        let mut y = vec![3.0, 4.0];
        project_l2(&mut y, 1.0);
        assert!((nrm2(&y) - 1.0).abs() < 1e-12);
        assert!((y[0] / y[1] - 0.75).abs() < 1e-12); // direction preserved
    }

    #[test]
    fn l1_inside_untouched() {
        let mut x = vec![0.2, -0.3];
        project_l1(&mut x, 1.0);
        assert_eq!(x, vec![0.2, -0.3]);
    }

    #[test]
    fn l1_projection_lands_on_boundary() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let mut x = rng.gaussians(20);
            for v in &mut x {
                *v *= 3.0;
            }
            let radius = 1.5;
            if l1_norm(&x) <= radius {
                continue;
            }
            project_l1(&mut x, radius);
            assert!((l1_norm(&x) - radius).abs() < 1e-9);
        }
    }

    #[test]
    fn l1_projection_is_euclidean_optimal() {
        // property: the projection must be at least as close as a grid of
        // feasible candidates (including sign-pattern variations).
        let mut rng = Rng::new(2);
        let orig = rng.gaussians(5);
        let radius = 1.0;
        let mut proj = orig.clone();
        project_l1(&mut proj, radius);
        let d_proj: f64 = orig
            .iter()
            .zip(&proj)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        for _ in 0..2000 {
            let mut cand = rng.gaussians(5);
            let l1 = l1_norm(&cand);
            if l1 > radius {
                for v in &mut cand {
                    *v *= radius / l1;
                }
            }
            let d_cand: f64 = orig
                .iter()
                .zip(&cand)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            assert!(d_cand >= d_proj - 1e-9);
        }
    }

    #[test]
    fn l1_preserves_signs_and_sparsifies() {
        let mut x = vec![2.0, -0.1, 1.0, -3.0];
        project_l1(&mut x, 2.0);
        assert!(x[0] > 0.0 && x[3] < 0.0);
        assert_eq!(x[1], 0.0); // tiny coordinate zeroed
    }

    #[test]
    fn simplex_projection_lands_on_simplex() {
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let mut x = rng.gaussians(12);
            project_simplex(&mut x, 1.0);
            let sum: f64 = x.iter().sum();
            assert!((sum - 1.0).abs() < 1e-12, "sum {sum}");
            assert!(x.iter().all(|&v| v >= 0.0));
        }
        // a point already on the simplex is a fixed point
        let mut y = vec![0.25, 0.25, 0.5];
        project_simplex(&mut y, 1.0);
        assert!((y[0] - 0.25).abs() < 1e-15);
        assert!((y[2] - 0.5).abs() < 1e-15);
    }

    #[test]
    fn simplex_projection_optimal_vs_candidates() {
        let mut rng = Rng::new(5);
        let orig = rng.gaussians(6);
        let mut proj = orig.clone();
        project_simplex(&mut proj, 1.0);
        let d_proj: f64 = orig
            .iter()
            .zip(&proj)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        for _ in 0..2000 {
            // random feasible candidate: normalized absolute gaussians
            let g = rng.gaussians(6);
            let total: f64 = g.iter().map(|v| v.abs()).sum();
            let cand: Vec<f64> = g.iter().map(|v| v.abs() / total).collect();
            let d_cand: f64 = orig
                .iter()
                .zip(&cand)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            assert!(d_cand >= d_proj - 1e-9);
        }
    }

    #[test]
    fn simplex_scaled_total() {
        let mut x = vec![5.0, 1.0, -2.0];
        project_simplex(&mut x, 2.0);
        let sum: f64 = x.iter().sum();
        assert!((sum - 2.0).abs() < 1e-12);
        assert!(x.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn elastic_net_inside_untouched_outside_on_boundary() {
        let mut inside = vec![0.1, -0.1];
        project_elastic_net(&mut inside, 0.5, 1.0);
        assert_eq!(inside, vec![0.1, -0.1]);
        let mut rng = Rng::new(6);
        for _ in 0..25 {
            let mut x: Vec<f64> = rng.gaussians(8).iter().map(|v| v * 4.0).collect();
            let (alpha, radius) = (0.3 + 0.4 * rng.uniform(), 0.5 + rng.uniform());
            if elastic_net_value(&x, alpha) <= radius {
                continue;
            }
            project_elastic_net(&mut x, alpha, radius);
            let g = elastic_net_value(&x, alpha);
            assert!((g - radius).abs() < 1e-10, "g = {g}, radius = {radius}");
        }
    }

    #[test]
    fn elastic_net_degenerates_to_l1_and_l2() {
        let mut rng = Rng::new(7);
        let x0: Vec<f64> = rng.gaussians(7).iter().map(|v| v * 3.0).collect();
        // alpha = 1: exactly the l1 ball
        let mut enet = x0.clone();
        project_elastic_net(&mut enet, 1.0, 1.5);
        let mut l1 = x0.clone();
        project_l1(&mut l1, 1.5);
        for (a, b) in enet.iter().zip(&l1) {
            assert!((a - b).abs() < 1e-9, "alpha=1: {a} vs {b}");
        }
        // alpha = 0: the l2 ball of radius sqrt(2 r)
        let mut enet0 = x0.clone();
        project_elastic_net(&mut enet0, 0.0, 1.0);
        let mut l2 = x0.clone();
        project_l2(&mut l2, 2f64.sqrt());
        for (a, b) in enet0.iter().zip(&l2) {
            assert!((a - b).abs() < 1e-9, "alpha=0: {a} vs {b}");
        }
    }

    #[test]
    fn soft_threshold_shrinks() {
        let mut x = vec![3.0, -0.5, 0.0];
        soft_threshold(&mut x, 1.0);
        assert_eq!(x, vec![2.0, 0.0, 0.0]);
    }

    #[test]
    fn idempotent_projections() {
        let mut rng = Rng::new(3);
        for proj in [
            (|x: &mut [f64]| project_l2(x, 0.8)) as fn(&mut [f64]),
            |x| project_l1(x, 0.8),
            |x| project_simplex(x, 1.0),
            |x| project_elastic_net(x, 0.5, 0.7),
        ] {
            let mut x = rng.gaussians(10);
            proj(&mut x);
            let once = x.clone();
            proj(&mut x);
            for (a, b) in x.iter().zip(&once) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }
}
