//! Euclidean projections onto the constraint sets W.
//!
//! The paper evaluates the unconstrained case and l1-/l2-ball constraints
//! (the ball radii set to the norms of the unconstrained optimum). The
//! projections here mirror the `_project` functions in the L2 graphs
//! (python/compile/model.py) and are cross-checked against them in the
//! integration tests.

pub mod metric;

use crate::linalg::blas::nrm2;

/// The constraint set for a regression job.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Constraint {
    /// W = R^d.
    Unconstrained,
    /// W = {x : ||x||_2 <= radius}.
    L2Ball { radius: f64 },
    /// W = {x : ||x||_1 <= radius}.
    L1Ball { radius: f64 },
    /// W = {x : lo <= x_i <= hi} (box; used by the examples).
    Box { lo: f64, hi: f64 },
}

impl Constraint {
    /// Short tag used in artifact names / reports.
    pub fn tag(&self) -> &'static str {
        match self {
            Constraint::Unconstrained => "unc",
            Constraint::L2Ball { .. } => "l2",
            Constraint::L1Ball { .. } => "l1",
            Constraint::Box { .. } => "box",
        }
    }

    /// Ball radius (0 when not applicable) — artifact scalar input.
    pub fn radius(&self) -> f64 {
        match self {
            Constraint::L2Ball { radius } | Constraint::L1Ball { radius } => *radius,
            _ => 0.0,
        }
    }

    /// Project x onto W in place.
    pub fn project(&self, x: &mut [f64]) {
        match *self {
            Constraint::Unconstrained => {}
            Constraint::L2Ball { radius } => project_l2(x, radius),
            Constraint::L1Ball { radius } => project_l1(x, radius),
            Constraint::Box { lo, hi } => {
                for v in x {
                    *v = v.clamp(lo, hi);
                }
            }
        }
    }

    pub fn contains(&self, x: &[f64], tol: f64) -> bool {
        match *self {
            Constraint::Unconstrained => true,
            Constraint::L2Ball { radius } => nrm2(x) <= radius + tol,
            Constraint::L1Ball { radius } => {
                x.iter().map(|v| v.abs()).sum::<f64>() <= radius + tol
            }
            Constraint::Box { lo, hi } => {
                x.iter().all(|&v| v >= lo - tol && v <= hi + tol)
            }
        }
    }

    /// Diameter term D_W = sqrt(max 0.5||x||^2 - min 0.5||x||^2) from
    /// Theorem 2 (used in the theoretical step size). For the unconstrained
    /// case callers supply an estimate; for balls it is radius/sqrt(2).
    pub fn diameter(&self) -> Option<f64> {
        match *self {
            Constraint::Unconstrained => None,
            Constraint::L2Ball { radius } | Constraint::L1Ball { radius } => {
                Some(radius / 2f64.sqrt())
            }
            Constraint::Box { lo, hi } => {
                let m = lo.abs().max(hi.abs());
                Some(m / 2f64.sqrt())
            }
        }
    }
}

/// Project onto the l2 ball (in place).
pub fn project_l2(x: &mut [f64], radius: f64) {
    let n = nrm2(x);
    if n > radius {
        let s = radius / n;
        for v in x {
            *v *= s;
        }
    }
}

/// Project onto the l1 ball via the Duchi et al. (2008) pivot algorithm
/// (O(d log d) with a sort — d is small here so the sort variant is right).
pub fn project_l1(x: &mut [f64], radius: f64) {
    assert!(radius >= 0.0);
    let l1: f64 = x.iter().map(|v| v.abs()).sum();
    if l1 <= radius {
        return;
    }
    let mut u: Vec<f64> = x.iter().map(|v| v.abs()).collect();
    u.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut css = 0.0;
    let mut theta = 0.0;
    let mut rho = 0;
    for (j, &uj) in u.iter().enumerate() {
        css += uj;
        let t = (css - radius) / (j + 1) as f64;
        if uj - t > 0.0 {
            rho = j + 1;
            theta = t;
        }
    }
    debug_assert!(rho > 0);
    for v in x.iter_mut() {
        let mag = (v.abs() - theta).max(0.0);
        *v = v.signum() * mag;
    }
}

/// Soft-threshold operator (prox of lambda*||.||_1) — used by the
/// signal-recovery example's ISTA baseline.
pub fn soft_threshold(x: &mut [f64], lambda: f64) {
    for v in x.iter_mut() {
        let mag = (v.abs() - lambda).max(0.0);
        *v = v.signum() * mag;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn l1_norm(x: &[f64]) -> f64 {
        x.iter().map(|v| v.abs()).sum()
    }

    #[test]
    fn l2_inside_untouched_outside_scaled() {
        let mut x = vec![0.3, 0.4];
        project_l2(&mut x, 1.0);
        assert_eq!(x, vec![0.3, 0.4]);
        let mut y = vec![3.0, 4.0];
        project_l2(&mut y, 1.0);
        assert!((nrm2(&y) - 1.0).abs() < 1e-12);
        assert!((y[0] / y[1] - 0.75).abs() < 1e-12); // direction preserved
    }

    #[test]
    fn l1_inside_untouched() {
        let mut x = vec![0.2, -0.3];
        project_l1(&mut x, 1.0);
        assert_eq!(x, vec![0.2, -0.3]);
    }

    #[test]
    fn l1_projection_lands_on_boundary() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let mut x = rng.gaussians(20);
            for v in &mut x {
                *v *= 3.0;
            }
            let radius = 1.5;
            if l1_norm(&x) <= radius {
                continue;
            }
            project_l1(&mut x, radius);
            assert!((l1_norm(&x) - radius).abs() < 1e-9);
        }
    }

    #[test]
    fn l1_projection_is_euclidean_optimal() {
        // property: the projection must be at least as close as a grid of
        // feasible candidates (including sign-pattern variations).
        let mut rng = Rng::new(2);
        let orig = rng.gaussians(5);
        let radius = 1.0;
        let mut proj = orig.clone();
        project_l1(&mut proj, radius);
        let d_proj: f64 = orig
            .iter()
            .zip(&proj)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        for _ in 0..2000 {
            let mut cand = rng.gaussians(5);
            let l1 = l1_norm(&cand);
            if l1 > radius {
                for v in &mut cand {
                    *v *= radius / l1;
                }
            }
            let d_cand: f64 = orig
                .iter()
                .zip(&cand)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            assert!(d_cand >= d_proj - 1e-9);
        }
    }

    #[test]
    fn l1_preserves_signs_and_sparsifies() {
        let mut x = vec![2.0, -0.1, 1.0, -3.0];
        project_l1(&mut x, 2.0);
        assert!(x[0] > 0.0 && x[3] < 0.0);
        assert_eq!(x[1], 0.0); // tiny coordinate zeroed
    }

    #[test]
    fn box_projection_clamps() {
        let c = Constraint::Box { lo: -1.0, hi: 1.0 };
        let mut x = vec![-5.0, 0.5, 7.0];
        c.project(&mut x);
        assert_eq!(x, vec![-1.0, 0.5, 1.0]);
        assert!(c.contains(&x, 1e-12));
    }

    #[test]
    fn constraint_dispatch_and_contains() {
        let mut x = vec![3.0, 4.0];
        let c = Constraint::L2Ball { radius: 1.0 };
        assert!(!c.contains(&x, 0.0));
        c.project(&mut x);
        assert!(c.contains(&x, 1e-12));
        assert_eq!(c.tag(), "l2");
        assert_eq!(c.radius(), 1.0);

        let u = Constraint::Unconstrained;
        let mut y = vec![1e9];
        u.project(&mut y);
        assert_eq!(y, vec![1e9]);
        assert!(u.contains(&y, 0.0));
    }

    #[test]
    fn soft_threshold_shrinks() {
        let mut x = vec![3.0, -0.5, 0.0];
        soft_threshold(&mut x, 1.0);
        assert_eq!(x, vec![2.0, 0.0, 0.0]);
    }

    #[test]
    fn idempotent_projections() {
        let mut rng = Rng::new(3);
        for c in [
            Constraint::L2Ball { radius: 0.8 },
            Constraint::L1Ball { radius: 0.8 },
        ] {
            let mut x = rng.gaussians(10);
            c.project(&mut x);
            let once = x.clone();
            c.project(&mut x);
            for (a, b) in x.iter().zip(&once) {
                assert!((a - b).abs() < 1e-12);
            }
        }
    }
}
