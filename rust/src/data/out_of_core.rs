//! Out-of-core design matrices: the dataset lives on disk and streams
//! through a budget-charged shard cache, so a solve can run on data larger
//! than the configured [`MemBudget`] with peak tracked bytes below it.
//!
//! Two flavors, matching the two on-disk formats:
//!
//! * [`MmapDense`] (`mmapdense:<path>`) — dense row-major binary file,
//!   sharded into fixed `chunk_rows`-row blocks. Arithmetic is **dense**:
//!   every kernel replicates the exact `blas` row-block plan of the
//!   in-memory dense path, so traces are bitwise identical to a resident
//!   dense twin (under the native executor).
//! * [`ChunkedCsr`] (`libsvm-chunked:<path>`) — a directory of libsvm
//!   chunks, sharded by the files themselves. Arithmetic is **sparse**:
//!   every kernel replicates [`CsrMat`]'s sequential row-order loops, so
//!   traces are bitwise identical to a resident CSR twin.
//!
//! # The shard cache
//!
//! Random row access (mini-batch gathers, leverage probes) and the
//! streamed full passes all fetch shards through one LRU cache. A miss
//! charges the shard's bytes via [`MemBudget::try_charge`] *before*
//! loading; when the charge is refused the least-recently-used resident
//! shard is evicted (counted via [`MemBudget::note_shard_evict`], like a
//! densify event) and the charge retried — only when nothing is left to
//! evict does the structured [`MemError`] propagate, which the serve loop
//! tags with the request id. Loads are counted as shard faults and
//! resident bytes are reported in serve metrics. Under an unlimited budget
//! a soft byte cap keeps the cache from silently absorbing the whole file.
//!
//! A borrowed shard (`Arc<ShardData>`) can outlive its eviction by the
//! length of one kernel loop; the charge tracks *cache residency*, the
//! brief borrow is transient scratch like a streamed fold's block (see
//! DESIGN.md §17 for the charge-accounting contract).
//!
//! Every disk read is fallible: I/O errors, truncation and non-finite
//! payloads surface as structured errors — never a worker panic.

use crate::data::chunked::ChunkedCsr;
use crate::data::mmap::MmapDense;
use crate::linalg::{blas, CsrMat, Mat};
use crate::util::mem::{MemBudget, MemCharge};
use crate::util::threadpool::default_threads;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Soft resident-byte cap applied only when the budget is unlimited (an
/// armed budget supplies the real pressure): 256 MiB.
const UNLIMITED_SOFT_CAP: usize = 256 << 20;

/// Default dense shard height when no `chunk_rows` knob is given.
pub const DEFAULT_CHUNK_ROWS: usize = 4096;

/// One resident shard's payload, in the flavor's representation.
#[derive(Debug)]
pub enum ShardData {
    /// A dense row block (`mmapdense` flavor).
    Dense(Mat),
    /// A CSR chunk (`libsvm-chunked` flavor).
    Csr(CsrMat),
}

struct CachedShard {
    data: Arc<ShardData>,
    _charge: Option<MemCharge>,
    bytes: usize,
    stamp: u64,
}

struct CacheState {
    resident: HashMap<usize, CachedShard>,
    clock: u64,
    bytes_total: usize,
}

enum Flavor {
    MmapDense(MmapDense),
    Chunked(ChunkedCsr),
}

/// A disk-backed design matrix (see module docs). Lives behind `Arc` so
/// dataset clones share one cache and one set of counters.
pub struct OnDiskDesign {
    flavor: Flavor,
    budget: Arc<MemBudget>,
    cache: Mutex<CacheState>,
    /// Dense shard height (resolved; echoes the request knob for chunked).
    chunk_rows: usize,
    rows: usize,
    cols: usize,
    b: Vec<f64>,
    label: String,
}

impl std::fmt::Debug for OnDiskDesign {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OnDiskDesign")
            .field("flavor", &self.flavor_tag())
            .field("rows", &self.rows)
            .field("cols", &self.cols)
            .field("chunk_rows", &self.chunk_rows)
            .field("label", &self.label)
            .finish()
    }
}

impl OnDiskDesign {
    /// Open an `mmapdense` file, binding shard loads to `budget`.
    /// `chunk_rows == 0` picks [`DEFAULT_CHUNK_ROWS`] (clamped to n).
    pub fn open_mmap(
        path: &Path,
        budget: Arc<MemBudget>,
        chunk_rows: usize,
    ) -> Result<Arc<OnDiskDesign>> {
        let md = MmapDense::open(path)?;
        let b = md.read_b()?;
        let (rows, cols) = (md.rows, md.cols);
        let cr = if chunk_rows == 0 { DEFAULT_CHUNK_ROWS } else { chunk_rows }
            .clamp(1, rows.max(1));
        Ok(Arc::new(OnDiskDesign {
            flavor: Flavor::MmapDense(md),
            budget,
            cache: Mutex::new(CacheState {
                resident: HashMap::new(),
                clock: 0,
                bytes_total: 0,
            }),
            chunk_rows: cr,
            rows,
            cols,
            b,
            label: label_for(path),
        }))
    }

    /// Open a `libsvm-chunked` directory, binding shard loads to `budget`.
    /// The chunk files define the shard partition; `chunk_rows` is kept
    /// only as the knob echo.
    pub fn open_chunked(
        dir: &Path,
        budget: Arc<MemBudget>,
        chunk_rows: usize,
    ) -> Result<Arc<OnDiskDesign>> {
        let cc = ChunkedCsr::open(dir, &budget)?;
        let b = cc.b().to_vec();
        let (rows, cols) = (cc.rows, cc.cols);
        Ok(Arc::new(OnDiskDesign {
            flavor: Flavor::Chunked(cc),
            budget,
            cache: Mutex::new(CacheState {
                resident: HashMap::new(),
                clock: 0,
                bytes_total: 0,
            }),
            chunk_rows,
            rows,
            cols,
            b,
            label: label_for(dir),
        }))
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The response vector (eager at open, untracked like the in-memory
    /// dataset's `b`).
    pub fn b(&self) -> &[f64] {
        &self.b
    }

    /// The resolved dense shard height / knob echo.
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// Whether this flavor computes with sparse (CSR) arithmetic.
    pub fn sparse_arith(&self) -> bool {
        matches!(self.flavor, Flavor::Chunked(_))
    }

    /// The request-format tag ("mmapdense" | "libsvm-chunked").
    pub fn flavor_tag(&self) -> &'static str {
        match self.flavor {
            Flavor::MmapDense(_) => "mmapdense",
            Flavor::Chunked(_) => "libsvm-chunked",
        }
    }

    /// Stored entries: nnz for chunked, `rows * cols` for dense.
    pub fn nnz(&self) -> usize {
        match &self.flavor {
            Flavor::MmapDense(_) => self.rows * self.cols,
            Flavor::Chunked(c) => c.nnz,
        }
    }

    /// nnz / (rows * cols); exactly 1.0 for the dense flavor.
    pub fn density(&self) -> f64 {
        match &self.flavor {
            Flavor::MmapDense(_) => 1.0,
            Flavor::Chunked(c) => {
                c.nnz as f64 / ((self.rows * self.cols).max(1)) as f64
            }
        }
    }

    /// The chunked metadata (nnz prefix for the streamed sketch partition).
    pub fn chunked(&self) -> Option<&ChunkedCsr> {
        match &self.flavor {
            Flavor::Chunked(c) => Some(c),
            Flavor::MmapDense(_) => None,
        }
    }

    // -- shard geometry -----------------------------------------------------

    /// Number of shards in the cache partition.
    pub fn num_shards(&self) -> usize {
        match &self.flavor {
            Flavor::MmapDense(_) => self.rows.div_ceil(self.chunk_rows),
            Flavor::Chunked(c) => c.shards().len(),
        }
    }

    /// Global row range `[start, start + rows)` of shard `s`.
    pub fn shard_range(&self, s: usize) -> (usize, usize) {
        match &self.flavor {
            Flavor::MmapDense(_) => {
                let start = s * self.chunk_rows;
                (start, self.chunk_rows.min(self.rows - start))
            }
            Flavor::Chunked(c) => {
                let m = &c.shards()[s];
                (m.start, m.rows)
            }
        }
    }

    fn shard_of_row(&self, i: usize) -> usize {
        match &self.flavor {
            Flavor::MmapDense(_) => i / self.chunk_rows,
            Flavor::Chunked(c) => {
                // last shard whose start <= i
                c.shards().partition_point(|m| m.start <= i) - 1
            }
        }
    }

    fn shard_bytes(&self, s: usize) -> usize {
        match &self.flavor {
            Flavor::MmapDense(_) => {
                let (_, rows) = self.shard_range(s);
                rows * self.cols * 8
            }
            Flavor::Chunked(c) => {
                let m = &c.shards()[s];
                m.nnz * 12 + (m.rows + 1) * 8
            }
        }
    }

    fn load_shard_data(&self, s: usize) -> Result<ShardData> {
        match &self.flavor {
            Flavor::MmapDense(md) => {
                let (start, rows) = self.shard_range(s);
                Ok(ShardData::Dense(md.read_rows(start, rows)?))
            }
            Flavor::Chunked(c) => Ok(ShardData::Csr(c.load_shard(s, &self.budget)?)),
        }
    }

    /// Fetch shard `s` through the cache (see module docs for the charge /
    /// evict / fault accounting). The returned `Arc` stays valid across a
    /// later eviction.
    pub fn shard(&self, s: usize) -> Result<Arc<ShardData>> {
        let mut st = self.cache.lock().unwrap();
        st.clock += 1;
        let stamp = st.clock;
        if let Some(sh) = st.resident.get_mut(&s) {
            sh.stamp = stamp;
            return Ok(Arc::clone(&sh.data));
        }
        let bytes = self.shard_bytes(s);
        let stage = format!("shard_cache[{}#{s}]", self.label);
        let charge = loop {
            match self.budget.try_charge(bytes, &stage) {
                Ok(c) => break c,
                Err(e) => {
                    if !self.evict_lru(&mut st, &stage) {
                        return Err(e.into());
                    }
                }
            }
        };
        let data = Arc::new(self.load_shard_data(s)?);
        self.budget.note_shard_load(&stage, bytes);
        st.bytes_total += bytes;
        st.resident.insert(
            s,
            CachedShard {
                data: Arc::clone(&data),
                _charge: Some(charge),
                bytes,
                stamp,
            },
        );
        // unlimited budgets never refuse a charge; the soft cap supplies
        // the eviction pressure so the cache stays a cache
        if self.budget.limit_bytes().is_none() {
            while st.bytes_total > UNLIMITED_SOFT_CAP && st.resident.len() > 1 {
                self.evict_lru(&mut st, &stage);
            }
        }
        Ok(data)
    }

    fn evict_lru(&self, st: &mut CacheState, stage: &str) -> bool {
        let victim = st
            .resident
            .iter()
            .min_by_key(|(_, sh)| sh.stamp)
            .map(|(&k, _)| k);
        match victim {
            Some(k) => {
                let sh = st.resident.remove(&k).unwrap();
                st.bytes_total -= sh.bytes;
                self.budget.note_shard_evict(stage, sh.bytes);
                true // dropping `sh` releases its charge
            }
            None => false,
        }
    }

    /// Bytes currently resident in this design's cache (tests/metrics).
    pub fn resident_bytes(&self) -> usize {
        self.cache.lock().unwrap().bytes_total
    }

    // -- row streaming ------------------------------------------------------

    /// Visit dense rows `[lo, hi)` in order (mmapdense flavor only).
    fn for_rows_dense(
        &self,
        lo: usize,
        hi: usize,
        f: &mut dyn FnMut(usize, &[f64]),
    ) -> Result<()> {
        let mut i = lo;
        while i < hi {
            let s = self.shard_of_row(i);
            let arc = self.shard(s)?;
            let ShardData::Dense(m) = &*arc else {
                bail!("dense row stream on a chunked design");
            };
            let (start, rows) = self.shard_range(s);
            let end = (start + rows).min(hi);
            for r in i..end {
                f(r, m.row(r - start));
            }
            i = end;
        }
        Ok(())
    }

    /// Visit CSR rows `[lo, hi)` in order (chunked flavor only).
    fn for_rows_csr(
        &self,
        lo: usize,
        hi: usize,
        f: &mut dyn FnMut(usize, &[u32], &[f64]),
    ) -> Result<()> {
        let mut i = lo;
        while i < hi {
            let s = self.shard_of_row(i);
            let arc = self.shard(s)?;
            let ShardData::Csr(c) = &*arc else {
                bail!("CSR row stream on a dense design");
            };
            let (start, rows) = self.shard_range(s);
            let end = (start + rows).min(hi);
            for r in i..end {
                let (cols, vals) = c.row(r - start);
                f(r, cols, vals);
            }
            i = end;
        }
        Ok(())
    }

    /// Visit every CSR row in global order (the implicit-HD gather's source
    /// stream). Chunked flavor only.
    pub fn stream_csr_rows(&self, f: &mut dyn FnMut(usize, &[u32], &[f64])) -> Result<()> {
        self.for_rows_csr(0, self.rows, f)
    }

    /// The in-memory dense path's row-block plan for this shape — same
    /// thread count and block height as `blas::residual_sq`/`fused_grad`,
    /// so per-block partial merges reproduce the resident bits exactly.
    fn dense_block_plan(&self) -> (usize, usize) {
        let threads = if self.rows * self.cols > 1 << 16 {
            default_threads()
        } else {
            1
        };
        let block = self.rows.div_ceil(threads.max(1)).max(64);
        (block, self.rows.div_ceil(block))
    }

    // -- per-row access (the pwSGD probes) ----------------------------------

    /// `A_i · x` through the shard cache.
    pub fn try_row_dot(&self, i: usize, x: &[f64]) -> Result<f64> {
        let s = self.shard_of_row(i);
        let (start, _) = self.shard_range(s);
        let arc = self.shard(s)?;
        Ok(match &*arc {
            ShardData::Dense(m) => blas::dot(m.row(i - start), x),
            ShardData::Csr(c) => c.row_dot(i - start, x),
        })
    }

    /// `out += coef * A_i` through the shard cache.
    pub fn try_row_axpy(&self, i: usize, coef: f64, out: &mut [f64]) -> Result<()> {
        let s = self.shard_of_row(i);
        let (start, _) = self.shard_range(s);
        let arc = self.shard(s)?;
        match &*arc {
            ShardData::Dense(m) => blas::axpy(coef, m.row(i - start), out),
            ShardData::Csr(c) => c.row_axpy(i - start, coef, out),
        }
        Ok(())
    }

    /// `coef * A_i` as a dense vector through the shard cache. Mirrors the
    /// two in-memory arms of `Dataset::row_scaled` exactly.
    pub fn try_row_scaled(&self, i: usize, coef: f64) -> Result<Vec<f64>> {
        let s = self.shard_of_row(i);
        let (start, _) = self.shard_range(s);
        let arc = self.shard(s)?;
        Ok(match &*arc {
            ShardData::Dense(m) => m.row(i - start).iter().map(|v| coef * v).collect(),
            ShardData::Csr(c) => {
                let mut out = vec![0.0; self.cols];
                c.row_axpy(i - start, coef, &mut out);
                out
            }
        })
    }

    // -- full-pass kernels (bitwise twins of the resident paths) ------------

    /// `||A x - b||^2`. Chunked: `CsrMat::residual_sq`'s sequential row
    /// loop. Dense: `blas::residual_sq`'s block plan with in-order merge.
    pub fn residual_sq(&self, b: &[f64], x: &[f64]) -> Result<f64> {
        assert_eq!(self.rows, b.len());
        match &self.flavor {
            Flavor::Chunked(_) => {
                let mut s = 0.0;
                self.for_rows_csr(0, self.rows, &mut |i, cols, vals| {
                    let mut r = 0.0;
                    for (c, v) in cols.iter().zip(vals) {
                        r += v * x[*c as usize];
                    }
                    let r = r - b[i];
                    s += r * r;
                })?;
                Ok(s)
            }
            Flavor::MmapDense(_) => {
                let (block, nblocks) = self.dense_block_plan();
                let mut total = 0.0;
                for bi in 0..nblocks {
                    let lo = bi * block;
                    let hi = (lo + block).min(self.rows);
                    let mut s = 0.0;
                    self.for_rows_dense(lo, hi, &mut |i, row| {
                        let r = blas::dot(row, x) - b[i];
                        s += r * r;
                    })?;
                    total += s;
                }
                Ok(total)
            }
        }
    }

    /// `||A x_k - b||^2` per iterate in one pass — bitwise per column to
    /// [`OnDiskDesign::residual_sq`], like the resident multi kernels.
    pub fn residual_sq_multi(&self, b: &[f64], xs: &[Vec<f64>]) -> Result<Vec<f64>> {
        assert_eq!(self.rows, b.len());
        if xs.is_empty() {
            return Ok(Vec::new());
        }
        match &self.flavor {
            Flavor::Chunked(_) => {
                let mut s = vec![0.0; xs.len()];
                self.for_rows_csr(0, self.rows, &mut |i, cols, vals| {
                    for (sk, x) in s.iter_mut().zip(xs) {
                        let mut r = 0.0;
                        for (c, v) in cols.iter().zip(vals) {
                            r += v * x[*c as usize];
                        }
                        let r = r - b[i];
                        *sk += r * r;
                    }
                })?;
                Ok(s)
            }
            Flavor::MmapDense(_) => {
                let (block, nblocks) = self.dense_block_plan();
                let mut out = vec![0.0; xs.len()];
                for bi in 0..nblocks {
                    let lo = bi * block;
                    let hi = (lo + block).min(self.rows);
                    let mut local = vec![0.0; xs.len()];
                    self.for_rows_dense(lo, hi, &mut |i, row| {
                        for (sk, x) in local.iter_mut().zip(xs) {
                            let r = blas::dot(row, x) - b[i];
                            *sk += r * r;
                        }
                    })?;
                    for (o, s) in out.iter_mut().zip(&local) {
                        *o += s;
                    }
                }
                Ok(out)
            }
        }
    }

    /// Full gradient `scale * A^T (A x - b)` — `CsrMat::fused_grad`'s row
    /// loop / `blas::fused_grad`'s block plan.
    pub fn fused_grad(&self, b: &[f64], x: &[f64], scale: f64) -> Result<Vec<f64>> {
        assert_eq!(self.rows, b.len());
        let mut g = vec![0.0; self.cols];
        match &self.flavor {
            Flavor::Chunked(_) => {
                self.for_rows_csr(0, self.rows, &mut |i, cols, vals| {
                    let mut r = 0.0;
                    for (c, v) in cols.iter().zip(vals) {
                        r += v * x[*c as usize];
                    }
                    let r = r - b[i];
                    for (c, v) in cols.iter().zip(vals) {
                        g[*c as usize] += r * v;
                    }
                })?;
            }
            Flavor::MmapDense(_) => {
                let (block, nblocks) = self.dense_block_plan();
                for bi in 0..nblocks {
                    let lo = bi * block;
                    let hi = (lo + block).min(self.rows);
                    let mut local = vec![0.0; self.cols];
                    self.for_rows_dense(lo, hi, &mut |i, row| {
                        let r = blas::dot(row, x) - b[i];
                        blas::axpy(r, row, &mut local);
                    })?;
                    blas::axpy(1.0, &local, &mut g);
                }
            }
        }
        blas::scale_vec(&mut g, scale);
        Ok(g)
    }

    /// Mini-batch gradient over sampled rows `tau`. Chunked replicates
    /// `CsrMat::batch_grad`'s loop through cached shards; dense gathers the
    /// sampled rows and calls the same `blas::fused_grad` the in-memory SGD
    /// family feeds its gather buffer to — identical inputs, identical bits.
    pub fn batch_grad(&self, tau: &[usize], b: &[f64], x: &[f64], scale: f64) -> Result<Vec<f64>> {
        match &self.flavor {
            Flavor::Chunked(_) => {
                let mut g = vec![0.0; self.cols];
                for &i in tau {
                    let s = self.shard_of_row(i);
                    let (start, _) = self.shard_range(s);
                    let arc = self.shard(s)?;
                    let ShardData::Csr(c) = &*arc else {
                        bail!("CSR batch on a dense design");
                    };
                    let r = c.row_dot(i - start, x) - b[i];
                    c.row_axpy(i - start, r, &mut g);
                }
                for v in &mut g {
                    *v *= scale;
                }
                Ok(g)
            }
            Flavor::MmapDense(_) => {
                let (m, vb) = self.gather_rows(tau)?;
                Ok(blas::fused_grad(&m, &vb, x, scale))
            }
        }
    }

    /// Gather sampled rows (and their `b` entries) through the cache into a
    /// dense batch — the on-disk analog of `Mat::gather_rows` + `b[tau]`.
    pub fn gather_rows(&self, idx: &[usize]) -> Result<(Mat, Vec<f64>)> {
        let mut m = Mat::zeros(idx.len(), self.cols);
        let mut vb = Vec::with_capacity(idx.len());
        for (k, &i) in idx.iter().enumerate() {
            let s = self.shard_of_row(i);
            let (start, _) = self.shard_range(s);
            let arc = self.shard(s)?;
            let orow = m.row_mut(k);
            match &*arc {
                ShardData::Dense(d) => orow.copy_from_slice(d.row(i - start)),
                ShardData::Csr(c) => {
                    let (cols, vals) = c.row(i - start);
                    for (cj, v) in cols.iter().zip(vals) {
                        orow[*cj as usize] = *v;
                    }
                }
            }
            vb.push(self.b[i]);
        }
        Ok((m, vb))
    }

    /// Sum of squared entries (callers divide by n for `row_mean_sq`).
    /// Streams in the exact order the resident paths sum: row-major data
    /// for dense, stored-value order for CSR.
    pub fn sum_sq(&self) -> Result<f64> {
        let mut s = 0.0;
        match &self.flavor {
            Flavor::Chunked(_) => {
                self.for_rows_csr(0, self.rows, &mut |_, _, vals| {
                    for v in vals {
                        s += v * v;
                    }
                })?;
            }
            Flavor::MmapDense(_) => {
                self.for_rows_dense(0, self.rows, &mut |_, row| {
                    for v in row {
                        s += v * v;
                    }
                })?;
            }
        }
        Ok(s)
    }

    /// `A R` for a dense right factor (the pwSGD JL leverage projection).
    /// Chunked replicates `CsrMat::spmm_dense` row by row; dense runs
    /// `blas::gemm` per shard — gemm's per-output-row arithmetic is
    /// independent of its row-block partition, so each output row is
    /// bitwise the full-matrix product's row.
    pub fn mul_dense(&self, rhs: &Mat) -> Result<Mat> {
        assert_eq!(self.cols, rhs.rows);
        let mut out = Mat::zeros(self.rows, rhs.cols);
        match &self.flavor {
            Flavor::Chunked(_) => {
                self.for_rows_csr(0, self.rows, &mut |i, cols, vals| {
                    let orow = out.row_mut(i);
                    for (c, v) in cols.iter().zip(vals) {
                        let brow = rhs.row(*c as usize);
                        for (o, bv) in orow.iter_mut().zip(brow) {
                            *o += v * bv;
                        }
                    }
                })?;
            }
            Flavor::MmapDense(_) => {
                for s in 0..self.num_shards() {
                    let (start, rows) = self.shard_range(s);
                    let arc = self.shard(s)?;
                    let ShardData::Dense(m) = &*arc else {
                        bail!("dense shard stream on a chunked design");
                    };
                    let prod = blas::gemm(m, rhs);
                    for k in 0..rows {
                        out.row_mut(start + k).copy_from_slice(prod.row(k));
                    }
                }
            }
        }
        Ok(out)
    }

    /// The padded `[A | b]` FWHT buffer, streamed from disk — bitwise the
    /// buffer `Mat::hstack_col_padded` / `CsrMat::hstack_col_padded` build
    /// from a resident twin. The caller charges the buffer's bytes (this is
    /// the HD transform's entry point; see `precond`).
    pub fn hstack_col_padded(&self, col: &[f64], rows_out: usize) -> Result<Mat> {
        assert_eq!(self.rows, col.len());
        assert!(rows_out >= self.rows);
        let d = self.cols;
        let mut out = Mat::zeros(rows_out, d + 1);
        match &self.flavor {
            Flavor::Chunked(_) => {
                self.for_rows_csr(0, self.rows, &mut |i, cols, vals| {
                    let orow = out.row_mut(i);
                    for (c, v) in cols.iter().zip(vals) {
                        orow[*c as usize] = *v;
                    }
                    orow[d] = col[i];
                })?;
            }
            Flavor::MmapDense(_) => {
                self.for_rows_dense(0, self.rows, &mut |i, row| {
                    let orow = out.row_mut(i);
                    orow[..d].copy_from_slice(row);
                    orow[d] = col[i];
                })?;
            }
        }
        Ok(out)
    }

    /// Rows `[lo, hi)` as a scratch [`CsrMat`] — the streamed sketch's
    /// per-block payload (`CsrBlock::from_scratch` re-bases it to global
    /// rows). Block-sized transient scratch, like the in-memory fold's
    /// accumulators; chunked flavor only.
    pub fn csr_range_scratch(&self, lo: usize, hi: usize) -> Result<CsrMat> {
        let mut indptr = Vec::with_capacity(hi - lo + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0);
        self.for_rows_csr(lo, hi, &mut |_, cols, vals| {
            indices.extend_from_slice(cols);
            values.extend_from_slice(vals);
            indptr.push(indices.len());
        })?;
        Ok(CsrMat::new(hi - lo, self.cols, indptr, indices, values))
    }

    // -- charged materializers (one-shot consumers: SRHT, exact oracle) -----

    /// Full dense materialization, budget-charged: the scoped escape hatch
    /// for consumers that need every row at once (SRHT, the dense QR
    /// oracle). The charge releases when dropped. Chunked materializations
    /// count a densify event, mirroring the resident-CSR scoped view; the
    /// dense flavor (already dense arithmetic) does not.
    pub fn dense_scoped(&self, stage: &str) -> Result<(Mat, MemCharge)> {
        let bytes = self.rows * self.cols * 8;
        let charge = self.budget.try_charge(bytes, stage)?;
        let mut out = Mat::zeros(self.rows, self.cols);
        match &self.flavor {
            Flavor::Chunked(_) => {
                self.budget.note_densify(stage, bytes);
                self.for_rows_csr(0, self.rows, &mut |i, cols, vals| {
                    let orow = out.row_mut(i);
                    for (c, v) in cols.iter().zip(vals) {
                        orow[*c as usize] = *v;
                    }
                })?;
            }
            Flavor::MmapDense(_) => {
                self.for_rows_dense(0, self.rows, &mut |i, row| {
                    out.row_mut(i).copy_from_slice(row);
                })?;
            }
        }
        Ok((out, charge))
    }

    /// Full CSR materialization, budget-charged (chunked flavor only) — the
    /// sparse exact oracle's input.
    pub fn csr_scoped(&self, stage: &str) -> Result<(CsrMat, MemCharge)> {
        let Flavor::Chunked(c) = &self.flavor else {
            bail!("csr_scoped on a dense on-disk design");
        };
        let bytes = c.nnz * 12 + (self.rows + 1) * 8;
        let charge = self.budget.try_charge(bytes, stage)?;
        let mut indptr = Vec::with_capacity(self.rows + 1);
        let mut indices = Vec::with_capacity(c.nnz);
        let mut values = Vec::with_capacity(c.nnz);
        indptr.push(0);
        self.for_rows_csr(0, self.rows, &mut |_, cols, vals| {
            indices.extend_from_slice(cols);
            values.extend_from_slice(vals);
            indptr.push(indices.len());
        })?;
        Ok((
            CsrMat::new(self.rows, self.cols, indptr, indices, values),
            charge,
        ))
    }

    /// Untracked full dense copy — diagnostics and tests only (mirrors
    /// `DesignMatrix::dense_clone`'s contract); production paths use the
    /// charged [`OnDiskDesign::dense_scoped`].
    pub fn dense_clone_untracked(&self) -> Result<Mat> {
        let mut out = Mat::zeros(self.rows, self.cols);
        match &self.flavor {
            Flavor::Chunked(_) => {
                self.for_rows_csr(0, self.rows, &mut |i, cols, vals| {
                    let orow = out.row_mut(i);
                    for (c, v) in cols.iter().zip(vals) {
                        orow[*c as usize] = *v;
                    }
                })?;
            }
            Flavor::MmapDense(_) => {
                self.for_rows_dense(0, self.rows, &mut |i, row| {
                    out.row_mut(i).copy_from_slice(row);
                })?;
            }
        }
        Ok(out)
    }
}

impl Drop for OnDiskDesign {
    fn drop(&mut self) {
        // charges release themselves; the residency observability counter
        // needs the explicit hand-back
        let st = self.cache.get_mut().unwrap();
        for (_, sh) in st.resident.drain() {
            self.budget.note_shard_release(sh.bytes);
        }
        st.bytes_total = 0;
    }
}

fn label_for(path: &Path) -> String {
    path.file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "ondisk".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{chunked, mmap};
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hdpw_ooc_{}_{name}", std::process::id()))
    }

    fn dense_fixture(n: usize, d: usize, seed: u64) -> (Mat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        (Mat::gaussian(n, d, &mut rng), rng.gaussians(n))
    }

    fn sparse_fixture(n: usize, d: usize, seed: u64) -> (CsrMat, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let dense = Mat::from_fn(n, d, |_, _| {
            if rng.uniform() < 0.3 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        (CsrMat::from_dense(&dense), rng.gaussians(n))
    }

    #[test]
    fn mmap_kernels_are_bitwise_to_blas_across_chunk_sizes() {
        let (a, b) = dense_fixture(97, 6, 1);
        let path = tmp("kern.bin");
        mmap::write(&path, &a, &b).unwrap();
        let mut rng = Rng::new(2);
        let x = rng.gaussians(6);
        let xs: Vec<Vec<f64>> = (0..3).map(|_| rng.gaussians(6)).collect();
        let tau = rng.indices(16, 97);
        for cr in [1usize, 7, 97, 4096] {
            let od =
                OnDiskDesign::open_mmap(&path, MemBudget::unlimited(), cr).unwrap();
            assert_eq!(od.b(), &b[..]);
            assert_eq!(
                od.residual_sq(&b, &x).unwrap().to_bits(),
                blas::residual_sq(&a, &b, &x).to_bits(),
                "chunk_rows={cr}"
            );
            let multi = od.residual_sq_multi(&b, &xs).unwrap();
            for (k, (got, want)) in
                multi.iter().zip(blas::residual_sq_multi(&a, &b, &xs)).enumerate()
            {
                assert_eq!(got.to_bits(), want.to_bits(), "cr={cr} col {k}");
            }
            let g = od.fused_grad(&b, &x, 2.0).unwrap();
            for (u, w) in g.iter().zip(blas::fused_grad(&a, &b, &x, 2.0)) {
                assert_eq!(u.to_bits(), w.to_bits(), "cr={cr}");
            }
            // mini-batch = gather + the same fused kernel
            let m = a.gather_rows(&tau);
            let vb: Vec<f64> = tau.iter().map(|&i| b[i]).collect();
            let want = blas::fused_grad(&m, &vb, &x, 8.0);
            for (u, w) in od.batch_grad(&tau, &b, &x, 8.0).unwrap().iter().zip(&want) {
                assert_eq!(u.to_bits(), w.to_bits(), "cr={cr}");
            }
            // per-row probes + sum of squares
            for &i in &[0usize, 48, 96] {
                assert_eq!(
                    od.try_row_dot(i, &x).unwrap().to_bits(),
                    blas::dot(a.row(i), &x).to_bits()
                );
            }
            let want_ss: f64 = a.data.iter().map(|v| v * v).sum();
            assert_eq!(od.sum_sq().unwrap().to_bits(), want_ss.to_bits());
            // leverage product: per-row bitwise to full gemm
            let rhs = Mat::gaussian(6, 3, &mut Rng::new(7));
            let prod = od.mul_dense(&rhs).unwrap();
            let want = blas::gemm(&a, &rhs);
            for i in 0..97 {
                for j in 0..3 {
                    assert_eq!(prod.at(i, j).to_bits(), want.at(i, j).to_bits(), "cr={cr}");
                }
            }
            // HD padded buffer
            let pad = od.hstack_col_padded(&b, 128).unwrap();
            assert_eq!(pad, a.hstack_col_padded(&b, 128));
        }
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn chunked_kernels_are_bitwise_to_csr_across_chunk_sizes() {
        let (csr, b) = sparse_fixture(61, 5, 3);
        let mut rng = Rng::new(4);
        let x = rng.gaussians(5);
        let xs: Vec<Vec<f64>> = (0..2).map(|_| rng.gaussians(5)).collect();
        let tau = rng.indices(12, 61);
        for cr in [1usize, 9, 61, 500] {
            let dir = tmp(&format!("ck{cr}"));
            let _ = std::fs::remove_dir_all(&dir);
            chunked::write_chunks(&dir, &csr, &b, cr).unwrap();
            let od =
                OnDiskDesign::open_chunked(&dir, MemBudget::unlimited(), cr).unwrap();
            assert!(od.sparse_arith());
            assert_eq!(od.nnz(), csr.nnz());
            assert_eq!(
                od.residual_sq(&b, &x).unwrap().to_bits(),
                csr.residual_sq(&b, &x).to_bits(),
                "cr={cr}"
            );
            let multi = od.residual_sq_multi(&b, &xs).unwrap();
            for (got, want) in multi.iter().zip(csr.residual_sq_multi(&b, &xs)) {
                assert_eq!(got.to_bits(), want.to_bits(), "cr={cr}");
            }
            for (u, w) in od
                .fused_grad(&b, &x, 2.0)
                .unwrap()
                .iter()
                .zip(csr.fused_grad(&b, &x, 2.0))
            {
                assert_eq!(u.to_bits(), w.to_bits(), "cr={cr}");
            }
            for (u, w) in od
                .batch_grad(&tau, &b, &x, 8.0)
                .unwrap()
                .iter()
                .zip(csr.batch_grad(&tau, &b, &x, 8.0))
            {
                assert_eq!(u.to_bits(), w.to_bits(), "cr={cr}");
            }
            let want_ss: f64 = csr.values.iter().map(|v| v * v).sum();
            assert_eq!(od.sum_sq().unwrap().to_bits(), want_ss.to_bits());
            let rhs = Mat::gaussian(5, 2, &mut Rng::new(8));
            let prod = od.mul_dense(&rhs).unwrap();
            let want = csr.spmm_dense(&rhs);
            for i in 0..61 {
                for j in 0..2 {
                    assert_eq!(prod.at(i, j).to_bits(), want.at(i, j).to_bits(), "cr={cr}");
                }
            }
            assert_eq!(
                od.hstack_col_padded(&b, 64).unwrap(),
                csr.hstack_col_padded(&b, 64)
            );
            let (mat, _ch) = od.csr_scoped("t").unwrap();
            assert_eq!(&mat, &csr);
            std::fs::remove_dir_all(dir).unwrap();
        }
    }

    #[test]
    fn cache_charges_faults_and_evicts_under_pressure() {
        let (a, b) = dense_fixture(64, 8, 5);
        let path = tmp("cache.bin");
        mmap::write(&path, &a, &b).unwrap();
        // shard = 16 rows * 8 cols * 8 B = 1 KiB; budget fits exactly 2
        let budget = MemBudget::with_limit_mb(1);
        let _hog = budget.try_charge((1 << 20) - 2 * 1024 - 100, "hog").unwrap();
        let od = OnDiskDesign::open_mmap(&path, Arc::clone(&budget), 16).unwrap();
        assert_eq!(od.num_shards(), 4);
        let s0 = od.shard(0).unwrap();
        let _s1 = od.shard(1).unwrap();
        assert_eq!(budget.shard_faults(), 2);
        assert_eq!(od.resident_bytes(), 2048);
        assert_eq!(budget.shard_resident_bytes(), 2048);
        // third shard must evict the LRU (shard 0)
        let _s2 = od.shard(2).unwrap();
        assert_eq!(budget.shard_evictions(), 1);
        assert_eq!(od.resident_bytes(), 2048);
        // the borrowed Arc from the evicted shard stays readable
        let ShardData::Dense(m0) = &*s0 else { panic!() };
        assert_eq!(m0.row(0), a.row(0));
        // shard 0 re-faults on next touch
        let _ = od.shard(0).unwrap();
        assert_eq!(budget.shard_faults(), 4);
        assert_eq!(budget.shard_evictions(), 2);
        // a full pass completes under the budget: peak stays below the cap
        let x = vec![0.1; 8];
        let f = od.residual_sq(&b, &x).unwrap();
        assert!(f.is_finite());
        assert!(budget.peak() <= 1 << 20);
        // an exhausted budget with nothing left to evict surfaces the
        // structured MemError (no panic)
        let tight = MemBudget::with_limit_mb(1);
        let _full = tight.try_charge((1 << 20) - 100, "hog2").unwrap();
        let od2 = OnDiskDesign::open_mmap(&path, Arc::clone(&tight), 16).unwrap();
        let err = od2.shard(3).unwrap_err();
        assert!(
            format!("{err:#}").contains("memory budget exceeded"),
            "{err:#}"
        );
        drop(od2);
        drop(od);
        assert_eq!(budget.shard_resident_bytes(), 0, "drop releases residency");
        std::fs::remove_file(path).unwrap();
    }
}
