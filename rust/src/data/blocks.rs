//! Sharded, zero-copy row-block views — the unit of work for the streaming
//! sketch/precondition pipeline.
//!
//! A [`RowBlocks`] view carves a row-major [`Mat`] into contiguous shards of
//! `block_rows` rows each (the last shard may be short). Nothing is copied:
//! each [`RowBlock`] borrows its slice of the parent's payload, so a shard
//! can be handed to a worker thread, folded into a sketch accumulator, or
//! shipped to an executor without touching the heap.
//!
//! Block-size heuristic ([`default_block_rows`]): shards are sized to fit a
//! core's L2 slice (~256 KiB of f64) while still producing enough shards to
//! keep every worker busy with a few tasks each — the same shape the
//! coordinator uses for job-level parallelism, applied at the data level.

use crate::linalg::Mat;
use crate::util::threadpool::default_threads;

/// One contiguous shard of rows, borrowed from the parent matrix.
#[derive(Clone, Copy, Debug)]
pub struct RowBlock<'a> {
    /// Global index (in the parent) of this shard's first row.
    pub start: usize,
    /// Number of rows in this shard.
    pub rows: usize,
    /// Column count (same as the parent).
    pub cols: usize,
    /// Borrowed row-major payload: exactly `rows * cols` elements.
    pub data: &'a [f64],
}

impl<'a> RowBlock<'a> {
    /// Row `k` of the shard (local index).
    #[inline]
    pub fn row(&self, k: usize) -> &'a [f64] {
        &self.data[k * self.cols..(k + 1) * self.cols]
    }

    /// Global row index of local row `k`.
    #[inline]
    pub fn global_row(&self, k: usize) -> usize {
        self.start + k
    }
}

/// Sharded view of a matrix as contiguous row blocks (no copying).
#[derive(Clone, Copy)]
pub struct RowBlocks<'a> {
    mat: &'a Mat,
    block_rows: usize,
}

impl<'a> RowBlocks<'a> {
    /// View `mat` as shards of `block_rows` rows. `block_rows` must be > 0.
    pub fn new(mat: &'a Mat, block_rows: usize) -> RowBlocks<'a> {
        assert!(block_rows > 0, "block_rows must be positive");
        RowBlocks { mat, block_rows }
    }

    /// View with the heuristic shard size for this shape.
    pub fn auto(mat: &'a Mat) -> RowBlocks<'a> {
        RowBlocks::new(mat, default_block_rows(mat.rows, mat.cols))
    }

    pub fn block_rows(&self) -> usize {
        self.block_rows
    }

    /// Number of shards (0 for an empty matrix).
    pub fn num_blocks(&self) -> usize {
        self.mat.rows.div_ceil(self.block_rows)
    }

    /// Shard `i`; the last shard may hold fewer than `block_rows` rows.
    pub fn block(&self, i: usize) -> RowBlock<'a> {
        let start = i * self.block_rows;
        assert!(start < self.mat.rows, "block index {i} out of range");
        let rows = self.block_rows.min(self.mat.rows - start);
        let cols = self.mat.cols;
        RowBlock {
            start,
            rows,
            cols,
            data: &self.mat.data[start * cols..(start + rows) * cols],
        }
    }

    /// Iterate shards in row order.
    pub fn iter(&self) -> RowBlocksIter<'a> {
        RowBlocksIter {
            blocks: *self,
            next: 0,
        }
    }
}

impl<'a> IntoIterator for RowBlocks<'a> {
    type Item = RowBlock<'a>;
    type IntoIter = RowBlocksIter<'a>;

    fn into_iter(self) -> RowBlocksIter<'a> {
        RowBlocksIter {
            blocks: self,
            next: 0,
        }
    }
}

/// Iterator over the shards of a [`RowBlocks`] view.
pub struct RowBlocksIter<'a> {
    blocks: RowBlocks<'a>,
    next: usize,
}

impl<'a> Iterator for RowBlocksIter<'a> {
    type Item = RowBlock<'a>;

    fn next(&mut self) -> Option<RowBlock<'a>> {
        if self.next >= self.blocks.num_blocks() {
            return None;
        }
        let b = self.blocks.block(self.next);
        self.next += 1;
        Some(b)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.blocks.num_blocks().saturating_sub(self.next);
        (left, Some(left))
    }
}

impl ExactSizeIterator for RowBlocksIter<'_> {}

/// Heuristic shard height for an `n x d` matrix.
///
/// Two pressures, take the tighter: (a) a shard should stay within ~256 KiB
/// of f64 payload so a worker's fold runs out of L2; (b) there should be at
/// least ~4 shards per worker thread so the atomic-counter work queue can
/// balance uneven progress. Always at least 1 row and never more than n.
pub fn default_block_rows(n: usize, d: usize) -> usize {
    const TARGET_ELEMS: usize = 32 * 1024; // 256 KiB / 8 bytes
    let n = n.max(1);
    let by_cache = (TARGET_ELEMS / d.max(1)).max(1);
    let by_threads = n.div_ceil(4 * default_threads().max(1)).max(1);
    by_cache.min(by_threads).min(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn blocks_tile_the_matrix_exactly() {
        let mut rng = Rng::new(1);
        for n in [1usize, 7, 64, 100, 127] {
            let m = Mat::gaussian(n, 3, &mut rng);
            for br in [1usize, 2, 5, 64, 200] {
                let view = RowBlocks::new(&m, br);
                let mut covered = 0usize;
                for (bi, blk) in view.iter().enumerate() {
                    assert_eq!(blk.start, bi * br);
                    assert_eq!(blk.cols, 3);
                    for k in 0..blk.rows {
                        assert_eq!(blk.row(k), m.row(blk.global_row(k)));
                    }
                    covered += blk.rows;
                }
                assert_eq!(covered, n, "n={n} br={br}");
                assert_eq!(view.iter().count(), view.num_blocks());
            }
        }
    }

    #[test]
    fn zero_copy_borrows_parent_payload() {
        let m = Mat::from_fn(10, 2, |i, j| (i * 2 + j) as f64);
        let view = RowBlocks::new(&m, 4);
        let b = view.block(1);
        // same addresses, not a copy
        assert!(std::ptr::eq(b.data.as_ptr(), m.row(4).as_ptr()));
        assert_eq!(b.rows, 4);
        let last = view.block(2);
        assert_eq!(last.rows, 2);
        assert_eq!(last.start, 8);
    }

    #[test]
    fn heuristic_bounds() {
        // tiny inputs never exceed n and never hit zero
        assert_eq!(default_block_rows(1, 5), 1);
        assert!(default_block_rows(10, 5) >= 1);
        // large n: cache bound dominates, shards stay modest
        let br = default_block_rows(1 << 20, 50);
        assert!(br >= 1 && br <= 32 * 1024 / 50 + 1, "br={br}");
        // many blocks exist for a big matrix (parallel criterion)
        let n = 1 << 17;
        let br2 = default_block_rows(n, 50);
        assert!(n.div_ceil(br2) > 1, "expected multiple shards");
        // degenerate d=0 must not divide by zero
        assert!(default_block_rows(100, 0) >= 1);
    }

    #[test]
    #[should_panic]
    fn zero_block_rows_rejected() {
        let m = Mat::zeros(4, 2);
        let _ = RowBlocks::new(&m, 0);
    }
}
