//! Sharded, zero-copy row-block views — the unit of work for the streaming
//! sketch/precondition pipeline.
//!
//! A [`RowBlocks`] view carves a row-major [`Mat`] into contiguous shards of
//! `block_rows` rows each (the last shard may be short). Nothing is copied:
//! each [`RowBlock`] borrows its slice of the parent's payload, so a shard
//! can be handed to a worker thread, folded into a sketch accumulator, or
//! shipped to an executor without touching the heap.
//!
//! The sparse analog is [`CsrBlocks`]: contiguous row shards of a
//! [`CsrMat`], sharded by **nnz** rather than row count — on a skewed
//! sparse matrix (a few dense rows among millions of near-empty ones)
//! row-count shards give one worker all the work; nnz shards keep the fold
//! balanced because fold cost is proportional to stored entries, not rows.
//!
//! Block-size heuristic ([`default_block_rows`] / [`default_block_nnz`]):
//! shards are sized to fit a core's L2 slice (~256 KiB of f64) while still
//! producing enough shards to keep every worker busy with a few tasks each
//! — the same shape the coordinator uses for job-level parallelism, applied
//! at the data level.

use crate::linalg::{CsrMat, Mat};
use crate::util::threadpool::default_threads;

/// One contiguous shard of rows, borrowed from the parent matrix.
#[derive(Clone, Copy, Debug)]
pub struct RowBlock<'a> {
    /// Global index (in the parent) of this shard's first row.
    pub start: usize,
    /// Number of rows in this shard.
    pub rows: usize,
    /// Column count (same as the parent).
    pub cols: usize,
    /// Borrowed row-major payload: exactly `rows * cols` elements.
    pub data: &'a [f64],
}

impl<'a> RowBlock<'a> {
    /// Row `k` of the shard (local index).
    #[inline]
    pub fn row(&self, k: usize) -> &'a [f64] {
        &self.data[k * self.cols..(k + 1) * self.cols]
    }

    /// Global row index of local row `k`.
    #[inline]
    pub fn global_row(&self, k: usize) -> usize {
        self.start + k
    }
}

/// Sharded view of a matrix as contiguous row blocks (no copying).
#[derive(Clone, Copy)]
pub struct RowBlocks<'a> {
    mat: &'a Mat,
    block_rows: usize,
}

impl<'a> RowBlocks<'a> {
    /// View `mat` as shards of `block_rows` rows. `block_rows` must be > 0.
    pub fn new(mat: &'a Mat, block_rows: usize) -> RowBlocks<'a> {
        assert!(block_rows > 0, "block_rows must be positive");
        RowBlocks { mat, block_rows }
    }

    /// View with the heuristic shard size for this shape.
    pub fn auto(mat: &'a Mat) -> RowBlocks<'a> {
        RowBlocks::new(mat, default_block_rows(mat.rows, mat.cols))
    }

    /// Rows per shard (the last shard may be shorter).
    pub fn block_rows(&self) -> usize {
        self.block_rows
    }

    /// Number of shards (0 for an empty matrix).
    pub fn num_blocks(&self) -> usize {
        self.mat.rows.div_ceil(self.block_rows)
    }

    /// Shard `i`; the last shard may hold fewer than `block_rows` rows.
    pub fn block(&self, i: usize) -> RowBlock<'a> {
        let start = i * self.block_rows;
        assert!(start < self.mat.rows, "block index {i} out of range");
        let rows = self.block_rows.min(self.mat.rows - start);
        let cols = self.mat.cols;
        RowBlock {
            start,
            rows,
            cols,
            data: &self.mat.data[start * cols..(start + rows) * cols],
        }
    }

    /// Iterate shards in row order.
    pub fn iter(&self) -> RowBlocksIter<'a> {
        RowBlocksIter {
            blocks: *self,
            next: 0,
        }
    }
}

impl<'a> IntoIterator for RowBlocks<'a> {
    type Item = RowBlock<'a>;
    type IntoIter = RowBlocksIter<'a>;

    fn into_iter(self) -> RowBlocksIter<'a> {
        RowBlocksIter {
            blocks: self,
            next: 0,
        }
    }
}

/// Iterator over the shards of a [`RowBlocks`] view.
pub struct RowBlocksIter<'a> {
    blocks: RowBlocks<'a>,
    next: usize,
}

impl<'a> Iterator for RowBlocksIter<'a> {
    type Item = RowBlock<'a>;

    fn next(&mut self) -> Option<RowBlock<'a>> {
        if self.next >= self.blocks.num_blocks() {
            return None;
        }
        let b = self.blocks.block(self.next);
        self.next += 1;
        Some(b)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.blocks.num_blocks().saturating_sub(self.next);
        (left, Some(left))
    }
}

impl ExactSizeIterator for RowBlocksIter<'_> {}

/// Heuristic shard height for an `n x d` matrix.
///
/// Two pressures, take the tighter: (a) a shard should stay within ~256 KiB
/// of f64 payload so a worker's fold runs out of L2; (b) there should be at
/// least ~4 shards per worker thread so the atomic-counter work queue can
/// balance uneven progress. Always at least 1 row and never more than n.
pub fn default_block_rows(n: usize, d: usize) -> usize {
    const TARGET_ELEMS: usize = 32 * 1024; // 256 KiB / 8 bytes
    let n = n.max(1);
    let by_cache = (TARGET_ELEMS / d.max(1)).max(1);
    let by_threads = n.div_ceil(4 * default_threads().max(1)).max(1);
    by_cache.min(by_threads).min(n)
}

/// Heuristic nnz budget per CSR shard — the sparse analog of
/// [`default_block_rows`]: fold cost on CSR is proportional to stored
/// entries, so the cache bound is on nnz directly (a value + an index per
/// entry), and the parallelism bound asks for ~4 shards per worker.
pub fn default_block_nnz(nnz: usize) -> usize {
    const TARGET_ENTRIES: usize = 32 * 1024;
    let nnz = nnz.max(1);
    let by_threads = nnz.div_ceil(4 * default_threads().max(1)).max(1);
    TARGET_ENTRIES.min(by_threads).min(nnz)
}

// ---------------------------------------------------------------------------
// CSR shards (nnz-balanced)
// ---------------------------------------------------------------------------

/// One contiguous shard of CSR rows, borrowed from the parent matrix.
#[derive(Clone, Copy)]
pub struct CsrBlock<'a> {
    mat: &'a CsrMat,
    /// Index into `mat` of this shard's local row 0 — equal to `start` for
    /// shards borrowed from a full-resident parent, 0 for scratch shards
    /// whose payload matrix holds only the shard's own rows.
    local0: usize,
    /// Global index (in the logical matrix) of this shard's first row.
    pub start: usize,
    /// Number of rows in this shard.
    pub rows: usize,
}

impl<'a> CsrBlock<'a> {
    /// The whole matrix as a single shard — lets the hash sketches
    /// implement their single-pass `apply_csr` through the exact same fold
    /// as the streamed path (one scatter loop to maintain, not two).
    pub fn whole(mat: &'a CsrMat) -> CsrBlock<'a> {
        CsrBlock {
            mat,
            local0: 0,
            start: 0,
            rows: mat.rows,
        }
    }

    /// A shard whose payload lives in its own scratch matrix (e.g. a chunk
    /// re-parsed from disk) but whose rows occupy `[base, base + mat.rows)`
    /// of a larger logical matrix. This is the bridge the out-of-core layer
    /// uses to feed disk-resident chunks through the exact same streamed
    /// sketch folds as borrowed shards: `row(k)` reads the scratch matrix,
    /// `global_row(k)` reports `base + k`.
    pub fn from_scratch(mat: &'a CsrMat, base: usize) -> CsrBlock<'a> {
        CsrBlock {
            mat,
            local0: 0,
            start: base,
            rows: mat.rows,
        }
    }

    /// Column count (same as the parent).
    #[inline]
    pub fn cols(&self) -> usize {
        self.mat.cols
    }

    /// Local row `k` as (column-index, value) slices.
    #[inline]
    pub fn row(&self, k: usize) -> (&'a [u32], &'a [f64]) {
        debug_assert!(k < self.rows);
        self.mat.row(self.local0 + k)
    }

    /// Global row index of local row `k`.
    #[inline]
    pub fn global_row(&self, k: usize) -> usize {
        self.start + k
    }

    /// Stored entries in this shard.
    pub fn nnz(&self) -> usize {
        self.mat.indptr[self.local0 + self.rows] - self.mat.indptr[self.local0]
    }

    /// Densify just this shard (rows x cols) — the bounded scratch the
    /// densify-per-shard sketch fallbacks (Gaussian) use.
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.mat.cols);
        for k in 0..self.rows {
            let (cols, vals) = self.row(k);
            let orow = out.row_mut(k);
            for (c, v) in cols.iter().zip(vals) {
                orow[*c as usize] = *v;
            }
        }
        out
    }
}

/// Sharded view of a CSR matrix as contiguous row shards balanced by nnz
/// (no copying). Shard boundaries are chosen greedily: rows accumulate into
/// the current shard until its nnz reaches the budget, then the shard
/// closes (so every shard except possibly the last holds >= `block_nnz`
/// entries, and none holds more than `block_nnz` plus one row's worth).
/// Shards always tile the row range exactly.
#[derive(Clone)]
pub struct CsrBlocks<'a> {
    mat: &'a CsrMat,
    /// Shard boundaries: bounds[i]..bounds[i+1] are shard i's rows.
    bounds: Vec<usize>,
}

impl<'a> CsrBlocks<'a> {
    /// View `mat` as shards of at most ~`block_nnz` stored entries each.
    /// `block_nnz` must be > 0.
    pub fn new(mat: &'a CsrMat, block_nnz: usize) -> CsrBlocks<'a> {
        assert!(block_nnz > 0, "block_nnz must be positive");
        let mut bounds = vec![0usize];
        let mut shard_start_off = 0usize;
        for i in 0..mat.rows {
            let end_off = mat.indptr[i + 1];
            // close the shard once it holds >= block_nnz entries (a single
            // oversize row still forms a one-row shard)
            if end_off - shard_start_off >= block_nnz && i + 1 < mat.rows {
                bounds.push(i + 1);
                shard_start_off = end_off;
            }
        }
        if mat.rows > 0 {
            bounds.push(mat.rows);
        }
        CsrBlocks { mat, bounds }
    }

    /// View with the heuristic nnz budget for this matrix.
    pub fn auto(mat: &'a CsrMat) -> CsrBlocks<'a> {
        CsrBlocks::new(mat, default_block_nnz(mat.nnz()))
    }

    /// Number of shards (0 for an empty matrix).
    pub fn num_blocks(&self) -> usize {
        self.bounds.len().saturating_sub(1)
    }

    /// Shard `i`.
    pub fn block(&self, i: usize) -> CsrBlock<'a> {
        let start = self.bounds[i];
        let end = self.bounds[i + 1];
        CsrBlock {
            mat: self.mat,
            local0: start,
            start,
            rows: end - start,
        }
    }

    /// Iterate shards in row order.
    pub fn iter(&self) -> impl Iterator<Item = CsrBlock<'a>> + '_ {
        (0..self.num_blocks()).map(|i| self.block(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn blocks_tile_the_matrix_exactly() {
        let mut rng = Rng::new(1);
        for n in [1usize, 7, 64, 100, 127] {
            let m = Mat::gaussian(n, 3, &mut rng);
            for br in [1usize, 2, 5, 64, 200] {
                let view = RowBlocks::new(&m, br);
                let mut covered = 0usize;
                for (bi, blk) in view.iter().enumerate() {
                    assert_eq!(blk.start, bi * br);
                    assert_eq!(blk.cols, 3);
                    for k in 0..blk.rows {
                        assert_eq!(blk.row(k), m.row(blk.global_row(k)));
                    }
                    covered += blk.rows;
                }
                assert_eq!(covered, n, "n={n} br={br}");
                assert_eq!(view.iter().count(), view.num_blocks());
            }
        }
    }

    #[test]
    fn zero_copy_borrows_parent_payload() {
        let m = Mat::from_fn(10, 2, |i, j| (i * 2 + j) as f64);
        let view = RowBlocks::new(&m, 4);
        let b = view.block(1);
        // same addresses, not a copy
        assert!(std::ptr::eq(b.data.as_ptr(), m.row(4).as_ptr()));
        assert_eq!(b.rows, 4);
        let last = view.block(2);
        assert_eq!(last.rows, 2);
        assert_eq!(last.start, 8);
    }

    #[test]
    fn heuristic_bounds() {
        // tiny inputs never exceed n and never hit zero
        assert_eq!(default_block_rows(1, 5), 1);
        assert!(default_block_rows(10, 5) >= 1);
        // large n: cache bound dominates, shards stay modest
        let br = default_block_rows(1 << 20, 50);
        assert!(br >= 1 && br <= 32 * 1024 / 50 + 1, "br={br}");
        // many blocks exist for a big matrix (parallel criterion)
        let n = 1 << 17;
        let br2 = default_block_rows(n, 50);
        assert!(n.div_ceil(br2) > 1, "expected multiple shards");
        // degenerate d=0 must not divide by zero
        assert!(default_block_rows(100, 0) >= 1);
    }

    #[test]
    #[should_panic]
    fn zero_block_rows_rejected() {
        let m = Mat::zeros(4, 2);
        let _ = RowBlocks::new(&m, 0);
    }

    /// A skewed sparse matrix: row i holds i % 7 entries.
    fn skewed_csr(n: usize, d: usize, seed: u64) -> CsrMat {
        let mut rng = Rng::new(seed);
        let mut indptr = vec![0usize];
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for i in 0..n {
            let k = (i % 7).min(d);
            for j in 0..k {
                indices.push(j as u32);
                values.push(rng.gaussian());
            }
            indptr.push(indices.len());
        }
        CsrMat::new(n, d, indptr, indices, values)
    }

    #[test]
    fn csr_blocks_tile_rows_and_balance_nnz() {
        let m = skewed_csr(100, 8, 1);
        for budget in [1usize, 5, 17, 64, 100_000] {
            let view = CsrBlocks::new(&m, budget);
            let mut covered = 0usize;
            let mut nnz_total = 0usize;
            let mut prev_end = 0usize;
            for blk in view.iter() {
                assert_eq!(blk.start, prev_end, "shards must be contiguous");
                prev_end = blk.start + blk.rows;
                covered += blk.rows;
                nnz_total += blk.nnz();
                for k in 0..blk.rows {
                    let (cols, vals) = blk.row(k);
                    let (wc, wv) = m.row(blk.global_row(k));
                    assert_eq!(cols, wc);
                    assert_eq!(vals, wv);
                }
            }
            assert_eq!(covered, 100, "budget={budget}");
            assert_eq!(nnz_total, m.nnz());
            // every shard except the last meets the budget
            for i in 0..view.num_blocks().saturating_sub(1) {
                assert!(view.block(i).nnz() >= budget, "budget={budget} shard {i}");
            }
        }
    }

    #[test]
    fn csr_block_to_dense_matches_parent_slice() {
        let m = skewed_csr(30, 6, 2);
        let dense = m.to_dense();
        let view = CsrBlocks::new(&m, 10);
        assert!(view.num_blocks() > 1);
        for blk in view.iter() {
            let d = blk.to_dense();
            assert_eq!(d.rows, blk.rows);
            for k in 0..blk.rows {
                assert_eq!(d.row(k), dense.row(blk.global_row(k)));
            }
        }
    }

    #[test]
    fn csr_blocks_edge_cases() {
        // empty matrix: zero shards
        let empty = CsrMat::new(0, 4, vec![0], vec![], vec![]);
        assert_eq!(CsrBlocks::new(&empty, 8).num_blocks(), 0);
        // all-empty rows: one shard covering everything
        let hollow = CsrMat::new(5, 4, vec![0; 6], vec![], vec![]);
        let view = CsrBlocks::new(&hollow, 8);
        assert_eq!(view.num_blocks(), 1);
        assert_eq!(view.block(0).rows, 5);
        assert_eq!(view.block(0).nnz(), 0);
        // auto heuristic resolves
        let m = skewed_csr(64, 4, 3);
        assert!(CsrBlocks::auto(&m).num_blocks() >= 1);
        // heuristic bounds
        assert_eq!(default_block_nnz(0), 1);
        assert!(default_block_nnz(1 << 24) <= 32 * 1024);
    }

    #[test]
    fn scratch_shard_reports_global_rows_over_local_payload() {
        let m = skewed_csr(30, 6, 5);
        let view = CsrBlocks::new(&m, 10);
        assert!(view.num_blocks() > 1);
        for blk in view.iter() {
            // rebuild the shard's payload as its own scratch matrix (what a
            // disk reload produces) and check the scratch-backed block is
            // indistinguishable from the borrowed one
            let scratch = CsrMat::from_dense(&blk.to_dense());
            let sb = CsrBlock::from_scratch(&scratch, blk.start);
            assert_eq!((sb.start, sb.rows, sb.cols()), (blk.start, blk.rows, blk.cols()));
            assert_eq!(sb.nnz(), blk.nnz());
            for k in 0..blk.rows {
                assert_eq!(sb.global_row(k), blk.global_row(k));
                assert_eq!(sb.row(k), blk.row(k));
            }
        }
    }

    #[test]
    #[should_panic]
    fn zero_block_nnz_rejected() {
        let m = skewed_csr(4, 2, 4);
        let _ = CsrBlocks::new(&m, 0);
    }
}
