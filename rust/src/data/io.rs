//! Dataset persistence: a simple binary format (magic + dims + f64 LE
//! payload) for caching generated datasets between bench runs, plus CSV
//! import for external data.

use super::Dataset;
use crate::linalg::Mat;
use crate::util::csv;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"HDPWDS01";

/// Write a dataset to the binary cache format. Dense payloads only: the
/// disk cache predates the sparse pipeline and sparse formats deliberately
/// skip it (caching a CSR dataset here would densify it on the serve path).
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let a = ds
        .dense_if_ready()
        .ok_or_else(|| anyhow::anyhow!("binary dataset cache stores dense payloads only"))?;
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    let name = ds.name.as_bytes();
    f.write_all(&(name.len() as u32).to_le_bytes())?;
    f.write_all(name)?;
    f.write_all(&(ds.n() as u64).to_le_bytes())?;
    f.write_all(&(ds.d() as u64).to_le_bytes())?;
    for v in &a.data {
        f.write_all(&v.to_le_bytes())?;
    }
    for v in &ds.b {
        f.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Load a dataset from the binary cache format.
pub fn load(path: &Path) -> Result<Dataset> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a hdpw dataset file");
    }
    let mut u32b = [0u8; 4];
    f.read_exact(&mut u32b)?;
    let name_len = u32::from_le_bytes(u32b) as usize;
    if name_len > 4096 {
        bail!("unreasonable name length {name_len}");
    }
    let mut name = vec![0u8; name_len];
    f.read_exact(&mut name)?;
    let mut u64b = [0u8; 8];
    f.read_exact(&mut u64b)?;
    let n = u64::from_le_bytes(u64b) as usize;
    f.read_exact(&mut u64b)?;
    let d = u64::from_le_bytes(u64b) as usize;
    let mut read_f64s = |count: usize| -> Result<Vec<f64>> {
        let mut buf = vec![0u8; count * 8];
        f.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    };
    let a = Mat::from_vec(n, d, read_f64s(n * d)?);
    let b = read_f64s(n)?;
    Ok(Dataset::dense(String::from_utf8(name)?, a, b, None))
}

/// Load from CSV: last column is the response b, earlier columns form A.
pub fn load_csv(path: &Path, skip_header: bool) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)?;
    let (n, cols, data) = csv::parse_numeric(&text, skip_header)?;
    if cols < 2 {
        bail!("need at least 2 columns (features + response)");
    }
    let full = Mat::from_vec(n, cols, data);
    let (a, b) = full.split_last_col();
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "csv".into());
    Ok(Dataset::dense(name, a, b, None))
}

/// Load from cache if present, else generate via `make_ds` and cache.
pub fn load_or_generate(
    cache_dir: &Path,
    key: &str,
    make_ds: impl FnOnce() -> Dataset,
) -> Result<Dataset> {
    let path = cache_dir.join(format!("{key}.ds"));
    if path.exists() {
        if let Ok(ds) = load(&path) {
            return Ok(ds);
        }
    }
    let ds = make_ds();
    std::fs::create_dir_all(cache_dir)?;
    save(&ds, &path)?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "hdpw_io_test_{}_{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn binary_roundtrip() {
        let mut rng = Rng::new(1);
        let a = Mat::gaussian(17, 3, &mut rng);
        let ds = Dataset::dense("roundtrip", a, rng.gaussians(17), None);
        let dir = tmpdir();
        let path = dir.join("x.ds");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.name, "roundtrip");
        assert_eq!(back.dense_clone(), ds.dense_clone());
        assert_eq!(back.b, ds.b);
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn save_rejects_sparse_payloads() {
        use crate::linalg::CsrMat;
        let a = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 2.0]);
        let ds = Dataset::from_csr("sp", CsrMat::from_dense(&a), vec![1.0, 2.0], None);
        let dir = tmpdir();
        let err = save(&ds, &dir.join("sp.ds")).unwrap_err();
        assert!(format!("{err:#}").contains("dense payloads only"), "{err:#}");
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = tmpdir();
        let path = dir.join("bad.ds");
        std::fs::write(&path, b"NOTMAGIC123").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn csv_roundtrip_with_header() {
        let dir = tmpdir();
        let path = dir.join("d.csv");
        std::fs::write(&path, "f1,f2,y\n1,2,3\n4,5,6\n").unwrap();
        let ds = load_csv(&path, true).unwrap();
        assert_eq!((ds.n(), ds.d()), (2, 2));
        assert_eq!(ds.b, vec![3.0, 6.0]);
        assert_eq!(ds.dense_if_ready().unwrap().row(1), &[4.0, 5.0]);
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn load_or_generate_caches() {
        let dir = tmpdir();
        let mut calls = 0;
        let make = || {
            let mut rng = Rng::new(9);
            let a = Mat::gaussian(5, 2, &mut rng);
            Dataset::dense("gen", a, rng.gaussians(5), None)
        };
        let d1 = load_or_generate(&dir, "k", || {
            calls += 1;
            make()
        })
        .unwrap();
        let mut calls2 = 0;
        let d2 = load_or_generate(&dir, "k", || {
            calls2 += 1;
            make()
        })
        .unwrap();
        assert_eq!(calls, 1);
        assert_eq!(calls2, 0); // served from cache
        assert_eq!(d1.dense_clone(), d2.dense_clone());
        std::fs::remove_dir_all(dir).unwrap();
    }
}
