//! Representation-polymorphic design matrix — the end of the dense mirror.
//!
//! `Dataset` used to pair `a: Mat` with `csr: Option<CsrMat>` under the
//! invariant "when csr is present, `a` holds `csr.to_dense()`" — which made
//! every CSR load pay the full dense footprint up front, on the serve path,
//! whether or not any stage ever needed a dense view. [`DesignMatrix`]
//! inverts that: the representation the data *arrived in* is the one that
//! is resident, and a dense view is a **capability** requested through a
//! [`MemBudget`]:
//!
//! * [`DesignMatrix::materialize_dense`] — lazily build (and keep) the
//!   dense mirror, charging its bytes against the budget; fails with a
//!   structured [`MemError`] when over budget instead of OOMing a worker.
//!   The mirror is built at most once and cached (`CsrWithDense` state).
//! * [`DesignMatrix::dense_scoped`] — a drop-after-use dense view for
//!   one-shot consumers (production caller: the SRHT sketch on CSR data,
//!   whose Hadamard butterfly needs every row at once —
//!   `precond::precondition_ds_budgeted`): the charge (and the copy) is
//!   released when the returned [`DenseView`] drops, so a transient
//!   consumer never bloats steady-state residency.
//! * [`DesignMatrix::dense_if_ready`] — the free accessor: `Some` only when
//!   a dense view already exists (dense payload, or a materialized mirror).
//!
//! The HD transform — the other dense object a sparse setup can need — is
//! even cheaper than a capability view: it assembles its padded `[A | b]`
//! buffer straight from CSR (`CsrMat::hstack_col_padded`) and charges those
//! bytes against the same [`MemBudget`] directly, never holding a full
//! mirror. Step-1-only sparse pipelines (CountSketch/SparseEmbed sketching,
//! mini-batch gradients, CGLS ground truth) call none of the dense
//! capabilities, which is what `densify_events == 0` asserts end-to-end.

use crate::data::out_of_core::OnDiskDesign;
use crate::linalg::{CsrMat, Mat};
use crate::util::mem::{MemBudget, MemCharge, MemError};
use std::sync::{Arc, OnceLock};

/// Which representation a design matrix is resident in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Repr {
    /// Row-major dense payload ([`Mat`]).
    Dense,
    /// Compressed sparse rows ([`CsrMat`]); no dense mirror until requested.
    Csr,
    /// Disk-backed shards streamed through a budget-charged cache
    /// ([`OnDiskDesign`]); nothing resident beyond the cache.
    OnDisk,
}

impl Repr {
    /// The cache-key tag ("dense" | "csr" | "ondisk").
    pub fn tag(self) -> &'static str {
        match self {
            Repr::Dense => "dense",
            Repr::Csr => "csr",
            Repr::OnDisk => "ondisk",
        }
    }
}

/// A lazily materialized dense mirror + the budget charge keeping its bytes
/// accounted for as long as it is resident.
struct Mirror {
    mat: Mat,
    _charge: Option<MemCharge>,
}

enum Inner {
    Dense(Mat),
    Csr {
        csr: CsrMat,
        mirror: OnceLock<Mirror>,
    },
    OnDisk(Arc<OnDiskDesign>),
}

/// The design matrix `A` in whichever representation it arrived in; see the
/// module docs for the capability-based densification contract.
pub struct DesignMatrix {
    inner: Inner,
}

/// A dense view that may own a transient materialization: borrowed from the
/// resident representation when one exists, otherwise a budget-charged copy
/// released (bytes and all) on drop.
pub enum DenseView<'a> {
    /// Borrowed from a resident dense payload or mirror — free.
    Borrowed(&'a Mat),
    /// A transient budget-charged copy; bytes release when this drops.
    Owned(Mat, Option<MemCharge>),
}

impl std::ops::Deref for DenseView<'_> {
    type Target = Mat;
    fn deref(&self) -> &Mat {
        match self {
            DenseView::Borrowed(m) => m,
            DenseView::Owned(m, _) => m,
        }
    }
}

impl DesignMatrix {
    /// Wrap a dense payload; dense views are always free.
    pub fn from_dense(a: Mat) -> DesignMatrix {
        DesignMatrix {
            inner: Inner::Dense(a),
        }
    }

    /// Wrap a CSR payload with no dense mirror (built lazily on capability
    /// request).
    pub fn from_csr(csr: CsrMat) -> DesignMatrix {
        DesignMatrix {
            inner: Inner::Csr {
                csr,
                mirror: OnceLock::new(),
            },
        }
    }

    /// Wrap a disk-backed design. The `Arc` is shared by clones, so every
    /// view of the dataset streams through one shard cache (and one set of
    /// fault/eviction counters).
    pub fn from_on_disk(od: Arc<OnDiskDesign>) -> DesignMatrix {
        DesignMatrix {
            inner: Inner::OnDisk(od),
        }
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        match &self.inner {
            Inner::Dense(m) => m.rows,
            Inner::Csr { csr, .. } => csr.rows,
            Inner::OnDisk(od) => od.rows(),
        }
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        match &self.inner {
            Inner::Dense(m) => m.cols,
            Inner::Csr { csr, .. } => csr.cols,
            Inner::OnDisk(od) => od.cols(),
        }
    }

    /// The resident representation.
    pub fn repr(&self) -> Repr {
        match &self.inner {
            Inner::Dense(_) => Repr::Dense,
            Inner::Csr { .. } => Repr::Csr,
            Inner::OnDisk(_) => Repr::OnDisk,
        }
    }

    /// Stored entries: nnz for CSR, rows*cols for dense.
    pub fn nnz(&self) -> usize {
        match &self.inner {
            Inner::Dense(m) => m.rows * m.cols,
            Inner::Csr { csr, .. } => csr.nnz(),
            Inner::OnDisk(od) => od.nnz(),
        }
    }

    /// nnz / (rows*cols); exactly 1.0 for dense.
    pub fn density(&self) -> f64 {
        match &self.inner {
            Inner::Dense(_) => 1.0,
            Inner::Csr { csr, .. } => csr.density(),
            Inner::OnDisk(od) => od.density(),
        }
    }

    /// The CSR payload when this design is resident sparse. `None` for
    /// on-disk designs even when their arithmetic is sparse — callers that
    /// key *arithmetic* (not residency) use [`DesignMatrix::sparse_arith`].
    pub fn csr(&self) -> Option<&CsrMat> {
        match &self.inner {
            Inner::Dense(_) => None,
            Inner::Csr { csr, .. } => Some(csr),
            Inner::OnDisk(_) => None,
        }
    }

    /// The disk-backed design when this matrix is out-of-core.
    pub fn on_disk(&self) -> Option<&Arc<OnDiskDesign>> {
        match &self.inner {
            Inner::OnDisk(od) => Some(od),
            _ => None,
        }
    }

    /// Whether kernels run CSR-style arithmetic on this design: resident
    /// CSR, or the chunked-libsvm on-disk flavor. The cost model, step-2
    /// routing and metrics key on this rather than on residency.
    pub fn sparse_arith(&self) -> bool {
        match &self.inner {
            Inner::Dense(_) => false,
            Inner::Csr { .. } => true,
            Inner::OnDisk(od) => od.sparse_arith(),
        }
    }

    /// Bytes a full dense materialization would charge.
    pub fn dense_bytes(&self) -> usize {
        self.rows() * self.cols() * std::mem::size_of::<f64>()
    }

    /// A dense view that is *already resident* (the dense payload, or a
    /// previously materialized mirror). Never allocates, never charges.
    pub fn dense_if_ready(&self) -> Option<&Mat> {
        match &self.inner {
            Inner::Dense(m) => Some(m),
            Inner::Csr { mirror, .. } => mirror.get().map(|m| &m.mat),
            Inner::OnDisk(_) => None,
        }
    }

    /// Whether a CSR design has its dense mirror resident (tests/metrics).
    pub fn mirror_resident(&self) -> bool {
        matches!(&self.inner, Inner::Csr { mirror, .. } if mirror.get().is_some())
    }

    /// Mutable dense access (dense payload or resident mirror) — generators
    /// post-process dense data through this; it never materializes.
    pub fn dense_mut(&mut self) -> Option<&mut Mat> {
        match &mut self.inner {
            Inner::Dense(m) => Some(m),
            Inner::Csr { mirror, .. } => mirror.get_mut().map(|m| &mut m.mat),
            Inner::OnDisk(_) => None,
        }
    }

    /// The capability call: obtain a dense view, materializing (and keeping)
    /// the mirror on first use. The materialization charges
    /// [`DesignMatrix::dense_bytes`] against `budget` — over budget it
    /// returns the structured error instead of allocating — and records one
    /// densify event tagged with `stage`. Dense designs return their payload
    /// untouched (no charge, no event).
    pub fn materialize_dense(
        &self,
        budget: &Arc<MemBudget>,
        stage: &str,
    ) -> Result<&Mat, MemError> {
        match &self.inner {
            Inner::Dense(m) => Ok(m),
            // on-disk designs never keep a persistent mirror: the whole
            // point is that the matrix does not fit; one-shot consumers go
            // through `dense_scoped` instead. Refusing here is structured
            // (never a panic) so a misrouted stage shows up as a job error.
            Inner::OnDisk(_) => Err(MemError {
                stage: format!("{stage} (on-disk design has no persistent dense mirror)"),
                requested: self.dense_bytes(),
                used: budget.used(),
                limit: budget.limit_bytes().unwrap_or(usize::MAX),
            }),
            Inner::Csr { csr, mirror } => {
                if let Some(m) = mirror.get() {
                    return Ok(&m.mat);
                }
                let bytes = self.dense_bytes();
                let charge = budget.try_charge(bytes, stage)?;
                let mat = csr.to_dense();
                if mirror
                    .set(Mirror {
                        mat,
                        _charge: Some(charge),
                    })
                    .is_ok()
                {
                    budget.note_densify(stage, bytes);
                }
                // a racing loser's charge dropped with its rejected Mirror
                Ok(&mirror.get().expect("mirror just set").mat)
            }
        }
    }

    /// Drop-after-use dense view for one-shot consumers — e.g. the SRHT
    /// sketch on CSR data, which needs every row at once for one transform
    /// and never again: borrows a resident view when one exists, otherwise
    /// charges + copies and releases both on drop. Never populates the
    /// cached mirror.
    pub fn dense_scoped(
        &self,
        budget: &Arc<MemBudget>,
        stage: &str,
    ) -> anyhow::Result<DenseView<'_>> {
        if let Some(m) = self.dense_if_ready() {
            return Ok(DenseView::Borrowed(m));
        }
        if let Inner::OnDisk(od) = &self.inner {
            // the on-disk materializer charges against the design's bound
            // budget (the same one the scheduler threads everywhere); an
            // over-budget or I/O failure propagates as a structured error
            let (mat, charge) = od.dense_scoped(stage)?;
            return Ok(DenseView::Owned(mat, Some(charge)));
        }
        let csr = self.csr().expect("not-ready dense implies CSR");
        let bytes = self.dense_bytes();
        let charge = budget.try_charge(bytes, stage)?;
        budget.note_densify(stage, bytes);
        Ok(DenseView::Owned(csr.to_dense(), Some(charge)))
    }

    /// Fresh dense copy for diagnostics, tests and text serialization
    /// references — NOT budget-tracked and NOT cached. Production paths use
    /// [`DesignMatrix::materialize_dense`] / [`DesignMatrix::dense_scoped`],
    /// which are.
    pub fn dense_clone(&self) -> Mat {
        match &self.inner {
            Inner::Dense(m) => m.clone(),
            Inner::Csr { csr, .. } => csr.to_dense(),
            // diagnostics-only contract: serve paths never call this on an
            // on-disk design (they use the fallible charged materializers)
            Inner::OnDisk(od) => od
                .dense_clone_untracked()
                .expect("dense_clone on on-disk design: shard read failed"),
        }
    }

    /// Scale column `j` of the design by `factors[j]` in place, in whichever
    /// representation is resident (the sparsity-preserving normalization
    /// path). A resident mirror is scaled too, keeping it exact.
    pub fn scale_columns(&mut self, factors: &[f64]) {
        assert_eq!(factors.len(), self.cols());
        match &mut self.inner {
            Inner::Dense(m) => {
                for i in 0..m.rows {
                    for (v, f) in m.row_mut(i).iter_mut().zip(factors) {
                        *v *= f;
                    }
                }
            }
            Inner::Csr { csr, mirror } => {
                for (c, v) in csr.indices.iter().zip(csr.values.iter_mut()) {
                    *v *= factors[*c as usize];
                }
                if let Some(m) = mirror.get_mut() {
                    for i in 0..m.mat.rows {
                        for (v, f) in m.mat.row_mut(i).iter_mut().zip(factors) {
                            *v *= f;
                        }
                    }
                }
            }
            // the scheduler rejects `normalize` for on-disk requests before
            // any solver runs; reaching here is a routing bug, not a data
            // condition, so the panic is the correct failure mode
            Inner::OnDisk(_) => {
                panic!("scale_columns unsupported for on-disk designs (rejected upstream)")
            }
        }
    }
}

/// Cloning clones the resident representation only: a CSR design's lazily
/// materialized mirror is a budget-charged cache, not state, so the clone
/// starts un-materialized (and un-charged). An on-disk design clones its
/// `Arc` — all views share one shard cache and one budget binding.
impl Clone for DesignMatrix {
    fn clone(&self) -> DesignMatrix {
        match &self.inner {
            Inner::Dense(m) => DesignMatrix::from_dense(m.clone()),
            Inner::Csr { csr, .. } => DesignMatrix::from_csr(csr.clone()),
            Inner::OnDisk(od) => DesignMatrix::from_on_disk(Arc::clone(od)),
        }
    }
}

impl std::fmt::Debug for DesignMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DesignMatrix")
            .field("repr", &self.repr())
            .field("rows", &self.rows())
            .field("cols", &self.cols())
            .field("nnz", &self.nnz())
            .field("mirror_resident", &self.mirror_resident())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sparse_mat(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_fn(n, d, |_, _| {
            if rng.uniform() < 0.3 {
                rng.gaussian()
            } else {
                0.0
            }
        })
    }

    #[test]
    fn dense_design_needs_no_capability() {
        let m = sparse_mat(10, 4, 1);
        let dm = DesignMatrix::from_dense(m.clone());
        assert_eq!(dm.repr(), Repr::Dense);
        assert_eq!(dm.repr().tag(), "dense");
        assert!(dm.dense_if_ready().is_some());
        let budget = MemBudget::with_limit_mb(1);
        // no charge, no densify event for an already-dense design
        let got = dm.materialize_dense(&budget, "t").unwrap();
        assert_eq!(*got, m);
        assert_eq!(budget.used(), 0);
        assert_eq!(budget.densify_events(), 0);
    }

    #[test]
    fn csr_mirror_is_lazy_charged_and_cached() {
        let dense = sparse_mat(32, 5, 2);
        let dm = DesignMatrix::from_csr(CsrMat::from_dense(&dense));
        assert_eq!(dm.repr(), Repr::Csr);
        assert!(dm.dense_if_ready().is_none(), "mirror must start absent");
        assert!(!dm.mirror_resident());
        let budget = MemBudget::unlimited();
        let m = dm.materialize_dense(&budget, "test-stage").unwrap();
        assert_eq!(*m, dense);
        assert_eq!(budget.used(), dm.dense_bytes());
        assert_eq!(budget.densify_events(), 1);
        assert!(dm.mirror_resident());
        // second call is a cache read: no new charge, no new event
        let _ = dm.materialize_dense(&budget, "test-stage").unwrap();
        assert_eq!(budget.used(), dm.dense_bytes());
        assert_eq!(budget.densify_events(), 1);
        assert!(dm.dense_if_ready().is_some());
    }

    #[test]
    fn over_budget_materialization_fails_cleanly() {
        let dense = sparse_mat(1024, 16, 3); // 128 KiB dense
        let dm = DesignMatrix::from_csr(CsrMat::from_dense(&dense));
        let budget = MemBudget::with_limit_mb(1);
        let _hog = budget.try_charge((1 << 20) - 1024, "hog").unwrap();
        let err = dm.materialize_dense(&budget, "qr_ground_truth").unwrap_err();
        assert_eq!(err.stage, "qr_ground_truth");
        assert!(dm.dense_if_ready().is_none(), "failed call must not cache");
        assert_eq!(budget.densify_events(), 0);
        assert_eq!(budget.rejections(), 1);
    }

    #[test]
    fn scoped_view_releases_bytes_on_drop() {
        let dense = sparse_mat(64, 6, 4);
        let dm = DesignMatrix::from_csr(CsrMat::from_dense(&dense));
        let budget = MemBudget::unlimited();
        {
            let view = dm.dense_scoped(&budget, "one-shot").unwrap();
            assert_eq!(view.row(0), dense.row(0));
            assert_eq!(budget.used(), dm.dense_bytes());
        }
        assert_eq!(budget.used(), 0, "scoped charge released on drop");
        assert_eq!(budget.peak(), dm.dense_bytes());
        assert_eq!(budget.densify_events(), 1);
        assert!(!dm.mirror_resident(), "scoped view must not cache");
        // after a persistent materialization, scoped borrows for free
        dm.materialize_dense(&budget, "persist").unwrap();
        let before = budget.densify_events();
        let v = dm.dense_scoped(&budget, "reuse").unwrap();
        assert!(matches!(v, DenseView::Borrowed(_)));
        assert_eq!(budget.densify_events(), before);
    }

    #[test]
    fn clone_resets_the_mirror() {
        let dense = sparse_mat(16, 3, 5);
        let dm = DesignMatrix::from_csr(CsrMat::from_dense(&dense));
        let budget = MemBudget::unlimited();
        dm.materialize_dense(&budget, "t").unwrap();
        let cl = dm.clone();
        assert!(!cl.mirror_resident(), "clone starts un-materialized");
        assert_eq!(cl.csr(), dm.csr());
        assert_eq!(budget.used(), dm.dense_bytes(), "clone charged nothing");
    }

    #[test]
    fn scale_columns_updates_both_representations() {
        let dense = sparse_mat(20, 4, 6);
        let mut dm = DesignMatrix::from_csr(CsrMat::from_dense(&dense));
        let budget = MemBudget::unlimited();
        dm.materialize_dense(&budget, "t").unwrap();
        let factors = [2.0, 0.5, 1.0, -1.0];
        dm.scale_columns(&factors);
        let scaled_mirror = dm.dense_if_ready().unwrap().clone();
        assert_eq!(dm.csr().unwrap().to_dense(), scaled_mirror, "mirror kept exact");
        for i in 0..20 {
            for j in 0..4 {
                assert_eq!(scaled_mirror.at(i, j), dense.at(i, j) * factors[j]);
            }
        }
        // dense designs scale too
        let mut dd = DesignMatrix::from_dense(dense.clone());
        dd.scale_columns(&factors);
        assert_eq!(*dd.dense_if_ready().unwrap(), scaled_mirror);
    }
}
