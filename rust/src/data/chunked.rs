//! The `libsvm-chunked` on-disk format: a directory of `chunk_*.svm` files
//! (each a libsvm shard with a `# hdpw: cols=` header), streamed shard by
//! shard so the full CSR payload is never resident.
//!
//! # Open-time validation pass
//!
//! [`ChunkedCsr::open`] fully parses every chunk once ([`libsvm::scan_shard`])
//! keeping only metadata: labels, per-row nnz, the index-convention
//! evidence and the declared dimension. From that single pass it decides
//! the **global** convention — 0-based iff *any* shard used index 0, and
//! `d` as the max of every shard's declared/inferred dimension — and keeps
//! the global per-row nnz prefix, which is what lets the streamed sketch
//! replicate `CsrBlocks`' greedy nnz partition without the matrix. Every
//! later reload re-parses its chunk with the convention **forced**
//! ([`libsvm::parse_shard`]), so per-shard auto-detection can never diverge
//! from the open-time answer; a chunk that contradicts it (the file changed
//! underneath us) errors as corruption. A chunk without the `cols=` header
//! is rejected at open — the "short header" fault class — because a
//! headerless shard's inferred width depends on which rows landed in it.
//!
//! # Fallibility, retries and fault injection
//!
//! Every read returns `Result`; transient I/O kinds (`Interrupted`,
//! `TimedOut`, `WouldBlock`) are retried once at shard granularity (counted
//! via [`MemBudget::note_io_retry`]), everything else — mid-read EOF, parse
//! errors, non-finite payloads, permission errors — propagates immediately
//! as a structured error that the serve loop tags with the request id.
//! Because the test process runs with privileges that make real
//! permission-denied fixtures unreliable, the module exposes a one-shot
//! [`inject_fault`] hook: a path-substring plan that wraps the next
//! matching chunk read in a [`FailingReader`] yielding a chosen
//! `io::ErrorKind` after N bytes.

use crate::data::libsvm;
use crate::linalg::CsrMat;
use crate::util::mem::MemBudget;
use anyhow::{bail, Context, Result};
use std::io::{self, BufReader, Read};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One chunk's placement in the global row space.
#[derive(Debug, Clone)]
pub struct ShardMeta {
    /// The chunk file.
    pub path: PathBuf,
    /// Global index of the chunk's first row.
    pub start: usize,
    /// Rows in this chunk.
    pub rows: usize,
    /// Stored entries in this chunk.
    pub nnz: usize,
}

/// An opened chunk directory: global shape/convention + per-shard metadata.
/// The CSR payload stays on disk; labels (`b`) and the per-row nnz prefix
/// are the only eager state (both O(n), untracked like the in-memory
/// dataset's `b`).
#[derive(Debug)]
pub struct ChunkedCsr {
    /// Total rows across all chunks.
    pub rows: usize,
    /// Global column count (max of declared/inferred across chunks).
    pub cols: usize,
    /// Total stored entries.
    pub nnz: usize,
    base: u64,
    shards: Vec<ShardMeta>,
    b: Vec<f64>,
    /// `rows + 1` monotone global nnz offsets — an indptr without a matrix.
    row_nnz_prefix: Vec<usize>,
}

impl ChunkedCsr {
    /// Open a chunk directory: enumerate `chunk_*.svm` (sorted by name) and
    /// run the validation pass described in the module docs. `budget` is
    /// used only for transient-retry accounting.
    pub fn open(dir: &Path, budget: &MemBudget) -> Result<ChunkedCsr> {
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .with_context(|| format!("open chunk directory {dir:?}"))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("chunk_") && n.ends_with(".svm"))
            })
            .collect();
        paths.sort();
        if paths.is_empty() {
            bail!("chunk directory {dir:?}: no chunk_*.svm files");
        }
        let mut scans = Vec::with_capacity(paths.len());
        for p in &paths {
            let scan = with_transient_retry(budget, &format!("scan {p:?}"), || {
                libsvm::scan_shard(&p.to_string_lossy(), chunk_reader(p)?)
            })?;
            if scan.declared_cols == 0 {
                bail!(
                    "chunk {p:?}: missing '# hdpw: cols=' header (short header) — \
                     a headerless shard's inferred width depends on row placement"
                );
            }
            scans.push(scan);
        }
        let base: u64 = if scans.iter().any(|s| s.saw_zero_index) { 0 } else { 1 };
        let mut cols = 0usize;
        for s in &scans {
            let inferred = if s.row_nnz.iter().any(|&k| k > 0) {
                (s.max_index + 1 - base) as usize
            } else {
                0
            };
            cols = cols.max(s.declared_cols).max(inferred);
        }
        let mut shards = Vec::with_capacity(paths.len());
        let mut b = Vec::new();
        let mut row_nnz_prefix = vec![0usize];
        let mut start = 0usize;
        for (p, s) in paths.into_iter().zip(scans) {
            let rows = s.labels.len();
            if rows == 0 {
                bail!("chunk {p:?}: no data rows");
            }
            let nnz: usize = s.row_nnz.iter().sum();
            for k in &s.row_nnz {
                row_nnz_prefix.push(row_nnz_prefix.last().unwrap() + k);
            }
            b.extend_from_slice(&s.labels);
            shards.push(ShardMeta { path: p, start, rows, nnz });
            start += rows;
        }
        if cols == 0 {
            bail!("chunk directory {dir:?}: no features in any chunk");
        }
        Ok(ChunkedCsr {
            rows: start,
            cols,
            nnz: *row_nnz_prefix.last().unwrap(),
            base,
            shards,
            b,
            row_nnz_prefix,
        })
    }

    /// The response vector (eager at open, untracked).
    pub fn b(&self) -> &[f64] {
        &self.b
    }

    /// Per-shard metadata, in row order.
    pub fn shards(&self) -> &[ShardMeta] {
        &self.shards
    }

    /// Global nnz offset of row `i` (`rows + 1` entries — the indptr the
    /// streamed sketch uses to replicate `CsrBlocks`' greedy partition).
    pub fn row_nnz_prefix(&self) -> &[usize] {
        &self.row_nnz_prefix
    }

    /// Stored entries in rows `[lo, hi)`.
    pub fn range_nnz(&self, lo: usize, hi: usize) -> usize {
        self.row_nnz_prefix[hi] - self.row_nnz_prefix[lo]
    }

    /// Reload shard `i` into its CSR payload, convention forced, with shape
    /// re-validated against the open-time scan (a mismatch means the file
    /// changed underneath us — corruption, not a fresh auto-detection).
    pub fn load_shard(&self, i: usize, budget: &MemBudget) -> Result<CsrMat> {
        let meta = &self.shards[i];
        let (csr, labels) = with_transient_retry(budget, &format!("load {:?}", meta.path), || {
            libsvm::parse_shard(
                &meta.path.to_string_lossy(),
                chunk_reader(&meta.path)?,
                self.base,
                self.cols,
            )
        })?;
        if csr.rows != meta.rows || csr.nnz() != meta.nnz {
            bail!(
                "chunk {:?}: shape changed since open ({}x{} nnz {} on disk, expected {} rows nnz {})",
                meta.path,
                csr.rows,
                csr.cols,
                csr.nnz(),
                meta.rows,
                meta.nnz
            );
        }
        for (k, (got, want)) in labels.iter().zip(&self.b[meta.start..]).enumerate() {
            if got.to_bits() != want.to_bits() {
                bail!("chunk {:?}: label changed since open at local row {k}", meta.path);
            }
        }
        Ok(csr)
    }
}

/// Write a CSR dataset as a chunk directory of `chunk_rows`-row shards —
/// the writer the generators, the CLI and the tests share. Each shard gets
/// the `# hdpw: cols=` header and 1-based indices with shortest-roundtrip
/// float formatting, so a reload is bit-exact (the PR3 round-trip
/// guarantee, now per shard).
pub fn write_chunks(dir: &Path, csr: &CsrMat, b: &[f64], chunk_rows: usize) -> Result<()> {
    assert_eq!(csr.rows, b.len());
    assert!(chunk_rows > 0, "chunk_rows must be positive");
    std::fs::create_dir_all(dir).with_context(|| format!("create chunk directory {dir:?}"))?;
    let mut shard = 0usize;
    let mut lo = 0usize;
    while lo < csr.rows {
        let hi = (lo + chunk_rows).min(csr.rows);
        let mut text = format!("# hdpw: cols={}\n", csr.cols);
        for i in lo..hi {
            text.push_str(&b[i].to_string());
            let (cols, vals) = csr.row(i);
            for (c, v) in cols.iter().zip(vals) {
                text.push_str(&format!(" {}:{}", *c as u64 + 1, v));
            }
            text.push('\n');
        }
        let path = dir.join(format!("chunk_{shard:05}.svm"));
        std::fs::write(&path, text).with_context(|| format!("write chunk {path:?}"))?;
        shard += 1;
        lo = hi;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// fault injection + transient retry
// ---------------------------------------------------------------------------

struct FaultPlan {
    substr: String,
    after_bytes: usize,
    kind: io::ErrorKind,
}

static FAULTS: Mutex<Vec<FaultPlan>> = Mutex::new(Vec::new());

/// Install a one-shot fault: the next chunk read whose path contains
/// `path_substr` fails with `kind` after `after_bytes` bytes have been
/// delivered (`0` = the very first read — the permission-denied shape).
/// The plan is consumed when it arms, so a transient kind that the loader
/// retries succeeds on the second attempt (which is exactly what the
/// `io_retries` counter test needs). Test-only by intent, but compiled in:
/// the hook must exercise the same production read path the tests assert.
pub fn inject_fault(path_substr: &str, after_bytes: usize, kind: io::ErrorKind) {
    FAULTS.lock().unwrap().push(FaultPlan {
        substr: path_substr.to_string(),
        after_bytes,
        kind,
    });
}

/// Remove all pending fault plans (test hygiene).
pub fn clear_faults() {
    FAULTS.lock().unwrap().clear();
}

fn take_plan(path: &Path) -> Option<(usize, io::ErrorKind)> {
    let mut plans = FAULTS.lock().unwrap();
    let s = path.to_string_lossy();
    let idx = plans.iter().position(|p| s.contains(&p.substr))?;
    let p = plans.remove(idx);
    Some((p.after_bytes, p.kind))
}

/// A reader that delivers `after_bytes` bytes faithfully, then fails once
/// with the injected `io::ErrorKind` and passes through afterwards — the
/// fixture layer for mid-read EOF / timeout / permission-denied faults.
pub struct FailingReader<R> {
    inner: R,
    remaining: usize,
    kind: io::ErrorKind,
    fired: bool,
}

impl<R> FailingReader<R> {
    /// Wrap `inner`, arming a single failure of `kind` after `after_bytes`.
    pub fn new(inner: R, after_bytes: usize, kind: io::ErrorKind) -> FailingReader<R> {
        FailingReader {
            inner,
            remaining: after_bytes,
            kind,
            fired: false,
        }
    }
}

impl<R: Read> Read for FailingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if self.fired || buf.is_empty() {
            return self.inner.read(buf);
        }
        if self.remaining == 0 {
            self.fired = true;
            return Err(io::Error::new(self.kind, format!("injected fault: {:?}", self.kind)));
        }
        let cap = buf.len().min(self.remaining);
        let n = self.inner.read(&mut buf[..cap])?;
        self.remaining -= n;
        Ok(n)
    }
}

/// Open a chunk for reading, routing through any armed fault plan.
fn chunk_reader(path: &Path) -> Result<Box<dyn io::BufRead>> {
    let file = std::fs::File::open(path).with_context(|| format!("open chunk {path:?}"))?;
    Ok(match take_plan(path) {
        Some((after, kind)) => Box::new(BufReader::new(FailingReader::new(file, after, kind))),
        None => Box::new(BufReader::new(file)),
    })
}

/// Whether the error chain bottoms out in a transient `io::Error` worth one
/// retry (`Interrupted` is already retried inside `BufRead`; it is listed
/// for completeness against readers that surface it raw).
pub fn is_transient_io(err: &anyhow::Error) -> bool {
    err.chain().any(|c| {
        c.downcast_ref::<io::Error>().is_some_and(|e| {
            matches!(
                e.kind(),
                io::ErrorKind::Interrupted | io::ErrorKind::TimedOut | io::ErrorKind::WouldBlock
            )
        })
    })
}

fn with_transient_retry<T>(
    budget: &MemBudget,
    stage: &str,
    f: impl Fn() -> Result<T>,
) -> Result<T> {
    match f() {
        Err(e) if is_transient_io(&e) => {
            budget.note_io_retry(stage);
            f()
        }
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hdpw_chunked_{}_{name}", std::process::id()))
    }

    fn sparse(n: usize, d: usize, seed: u64) -> CsrMat {
        let mut rng = Rng::new(seed);
        let dense = crate::linalg::Mat::from_fn(n, d, |_, _| {
            if rng.uniform() < 0.3 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        CsrMat::from_dense(&dense)
    }

    #[test]
    fn write_open_reload_roundtrips_bitwise() {
        let csr = sparse(53, 7, 1);
        let mut rng = Rng::new(2);
        let b = rng.gaussians(53);
        for chunk_rows in [1usize, 9, 53, 500] {
            let dir = tmp(&format!("rt{chunk_rows}"));
            let _ = std::fs::remove_dir_all(&dir);
            write_chunks(&dir, &csr, &b, chunk_rows).unwrap();
            let budget = MemBudget::unlimited();
            let od = ChunkedCsr::open(&dir, &budget).unwrap();
            assert_eq!((od.rows, od.cols, od.nnz), (53, 7, csr.nnz()));
            assert_eq!(od.b(), &b[..]);
            assert_eq!(od.row_nnz_prefix().len(), 54);
            assert_eq!(od.shards().len(), 53usize.div_ceil(chunk_rows));
            // reassemble and compare bitwise
            let mut rows_seen = 0usize;
            for (i, meta) in od.shards().iter().enumerate() {
                assert_eq!(meta.start, rows_seen);
                let shard = od.load_shard(i, &budget).unwrap();
                assert_eq!(shard.cols, 7);
                for k in 0..shard.rows {
                    assert_eq!(shard.row(k), csr.row(meta.start + k), "chunk_rows={chunk_rows}");
                }
                assert_eq!(shard.nnz(), od.range_nnz(meta.start, meta.start + meta.rows));
                rows_seen += meta.rows;
            }
            assert_eq!(rows_seen, 53);
            assert_eq!(budget.io_retries(), 0);
            std::fs::remove_dir_all(dir).unwrap();
        }
    }

    #[test]
    fn open_rejects_short_header_and_empty_dirs() {
        let dir = tmp("hdr");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let budget = MemBudget::unlimited();
        let err = ChunkedCsr::open(&dir, &budget).unwrap_err();
        assert!(format!("{err:#}").contains("no chunk_*.svm"), "{err:#}");
        // a shard without the cols header is the "short header" fault class
        std::fs::write(dir.join("chunk_00000.svm"), "1 1:2\n").unwrap();
        let err = ChunkedCsr::open(&dir, &budget).unwrap_err();
        assert!(format!("{err:#}").contains("short header"), "{err:#}");
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn reload_detects_mutation_since_open() {
        let csr = sparse(20, 4, 3);
        let b = Rng::new(4).gaussians(20);
        let dir = tmp("mut");
        let _ = std::fs::remove_dir_all(&dir);
        write_chunks(&dir, &csr, &b, 8).unwrap();
        let budget = MemBudget::unlimited();
        let od = ChunkedCsr::open(&dir, &budget).unwrap();
        // rewrite shard 1 with an extra row
        std::fs::write(
            dir.join("chunk_00001.svm"),
            "# hdpw: cols=4\n1 1:2\n2 2:3\n3 1:1\n4 1:1\n5 1:1\n6 1:1\n7 1:1\n8 1:1\n9 1:1\n",
        )
        .unwrap();
        let err = od.load_shard(1, &budget).unwrap_err();
        assert!(format!("{err:#}").contains("changed since open"), "{err:#}");
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn injected_faults_surface_and_transients_retry_once() {
        let csr = sparse(16, 3, 5);
        let b = Rng::new(6).gaussians(16);
        let dir = tmp("fault");
        let _ = std::fs::remove_dir_all(&dir);
        write_chunks(&dir, &csr, &b, 8).unwrap();
        let budget = MemBudget::unlimited();
        let od = ChunkedCsr::open(&dir, &budget).unwrap();
        // permanent fault: permission denied on the first byte
        inject_fault(&format!("{}/chunk_00000", dir.to_string_lossy()), 0, io::ErrorKind::PermissionDenied);
        let err = od.load_shard(0, &budget).unwrap_err();
        assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
        assert_eq!(budget.io_retries(), 0, "permission denied is not transient");
        // transient fault: TimedOut mid-read → one retry, then success
        inject_fault(&format!("{}/chunk_00001", dir.to_string_lossy()), 10, io::ErrorKind::TimedOut);
        let shard = od.load_shard(1, &budget).unwrap();
        assert_eq!(shard.rows, 8);
        assert_eq!(budget.io_retries(), 1, "transient kinds retry exactly once");
        clear_faults();
        std::fs::remove_dir_all(dir).unwrap();
    }
}
