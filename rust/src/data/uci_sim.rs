//! Simulated UCI datasets (Year / Buzz).
//!
//! SUBSTITUTION (DESIGN.md section 7): the paper evaluates on
//! YearPredictionMSD (5e5 x 90, kappa ~ 3e3) and Buzz-in-social-media
//! (5e5 x 77, kappa ~ 1e8) from the UCI repository; this environment has no
//! network access, so we generate matrices that match the *published
//! statistics the algorithms are sensitive to*: shape, condition number,
//! row-norm (leverage) spread, and noise level. The paper's methods interact
//! with the data only through these quantities — kappa drives the
//! preconditioning benefit, leverage spread drives the HD-step benefit.
//!
//! * `year`: correlated smooth features — exact spectral construction with
//!   kappa = 3e3 and mildly non-uniform leverage scores.
//! * `buzz`: heavy-tailed social-media counts — log-normal row scaling on
//!   top of a kappa-controlled base, giving the extreme leverage spread and
//!   a measured kappa ~ 1e8 that the dataset exhibits after raw ingestion.

use super::synthetic::{generate, SynSpec};
use super::Dataset;
use crate::util::rng::Rng;

/// YearPredictionMSD-like: n x 90, kappa = 3e3 (Table 3).
pub fn year(n: usize, rng: &mut Rng) -> Dataset {
    let spec = SynSpec {
        name: "year".into(),
        n,
        d: 90,
        kappa: 3e3,
        noise: 0.1,
        signal_scale: SynSpec::signal_auto(n),
    };
    let mut ds = generate(&spec, rng);
    // mild leverage spread: scale a random 5% of rows by 3x (audio outliers)
    let boosted = (n / 20).max(1);
    for _ in 0..boosted {
        let i = rng.below(n);
        for v in ds.dense_mut().expect("dense generator").row_mut(i) {
            *v *= 3.0;
        }
        ds.b[i] *= 3.0;
    }
    ds.name = "year".into();
    ds
}

/// Buzz-in-social-media-like: n x 77, heavy tails, kappa ~ 1e8 (Table 3).
pub fn buzz(n: usize, rng: &mut Rng) -> Dataset {
    let spec = SynSpec {
        name: "buzz".into(),
        n,
        d: 77,
        // base spectrum well short of the target: the row scaling inflates
        // the spread to the ~1e8 regime measured on the raw UCI matrix.
        kappa: 1e6,
        noise: 0.1,
        signal_scale: SynSpec::signal_auto(n),
    };
    let mut ds = generate(&spec, rng);
    // heavy-tailed (log-normal, sigma = 2) row scales: social-media counts
    for i in 0..n {
        let s = (2.0 * rng.gaussian()).exp();
        for v in ds.dense_mut().expect("dense generator").row_mut(i) {
            *v *= s;
        }
        ds.b[i] *= s;
    }
    ds.name = "buzz".into();
    ds.x_star_planted = None; // scaling reweights the LS problem
    ds
}

/// Build a dataset by name (coordinator / CLI entry point).
pub fn by_name(name: &str, n: usize, rng: &mut Rng) -> Option<Dataset> {
    match name {
        "syn1" => Some(generate(&SynSpec::syn1(n), rng)),
        "syn2" => Some(generate(&SynSpec::syn2(n), rng)),
        "year" => Some(year(n, rng)),
        "buzz" => Some(buzz(n, rng)),
        // canonical PJRT-artifact shape (n = 8192, d = 32): the dataset the
        // e2e example runs through the compiled L1/L2 graphs end to end
        "pjrt8k" => Some(generate(
            &SynSpec {
                name: "pjrt8k".into(),
                n: 8192,
                d: 32,
                kappa: 1e6,
                noise: 1.0,
                signal_scale: SynSpec::signal_auto(8192),
            },
            rng,
        )),
        _ => None,
    }
}

/// Paper-scale row counts from Table 3 (used with `--paper-scale`).
pub fn paper_scale_n(name: &str) -> usize {
    match name {
        "syn1" | "syn2" => 100_000,
        "year" | "buzz" => 500_000,
        _ => 65_536,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{blas, eigen};

    #[test]
    fn year_shape_and_kappa() {
        let mut rng = Rng::new(1);
        let ds = year(2000, &mut rng);
        assert_eq!(ds.d(), 90);
        assert_eq!(ds.n(), 2000);
        let kappa = eigen::cond(ds.dense_if_ready().unwrap());
        // row boosting perturbs the exact 3e3; stay within a factor ~3
        assert!(kappa > 1e3 && kappa < 1e4, "kappa {kappa}");
    }

    #[test]
    fn buzz_has_heavy_leverage_tails_and_huge_kappa() {
        let mut rng = Rng::new(2);
        let ds = buzz(2000, &mut rng);
        assert_eq!(ds.d(), 77);
        let norms: Vec<f64> = (0..ds.n()).map(|i| blas::nrm2(ds.dense_if_ready().unwrap().row(i))).collect();
        let mean = norms.iter().sum::<f64>() / norms.len() as f64;
        let max = norms.iter().cloned().fold(0.0, f64::max);
        assert!(max / mean > 20.0, "leverage not heavy: {}", max / mean);
        let kappa = eigen::cond(ds.dense_if_ready().unwrap());
        assert!(kappa > 1e6, "kappa {kappa}");
    }

    #[test]
    fn by_name_dispatch() {
        let mut rng = Rng::new(3);
        assert!(by_name("syn1", 128, &mut rng).is_some());
        assert!(by_name("syn2", 128, &mut rng).is_some());
        assert!(by_name("nope", 128, &mut rng).is_none());
        assert_eq!(paper_scale_n("buzz"), 500_000);
    }
}
