//! Synthetic dataset generators with an *exact* target condition number.
//!
//! Construction: A = Q diag(sigma) V^T where Q (n x d) and V (d x d) have
//! orthonormal columns (QR of gaussian matrices) and sigma is log-spaced
//! from 1 down to 1/kappa — so the singular values of A are exactly sigma
//! and kappa(A) = kappa. This realizes Table 3's Syn1 (kappa = 1e8) and
//! Syn2 (kappa = 1e3) at any scale.

use super::Dataset;
use crate::linalg::{blas, qr, Mat};
use crate::util::rng::Rng;

/// Parameters for a synthetic instance.
#[derive(Clone, Debug)]
pub struct SynSpec {
    /// Dataset name carried into the generated [`Dataset`].
    pub name: String,
    /// Number of rows (samples).
    pub n: usize,
    /// Number of columns (features).
    pub d: usize,
    /// Exact target condition number of the generated design.
    pub kappa: f64,
    /// std-dev of the gaussian noise e in b = A x* + e (paper: 0.1)
    pub noise: f64,
    /// scale of the planted solution. The spectral construction has
    /// ||A x*|| = O(||sigma||) for unit-gaussian x*, which is vanishing next
    /// to the O(sqrt n) noise norm; `signal_scale = sqrt(n)` (the default
    /// via [`SynSpec::signal_auto`]) makes the explained and unexplained
    /// variance comparable, as in regression data worth regressing.
    pub signal_scale: f64,
}

impl SynSpec {
    /// sqrt(n) signal scale: explained variance comparable to the noise.
    pub fn signal_auto(n: usize) -> f64 {
        (n as f64).sqrt()
    }
}

impl SynSpec {
    /// Table 3 "Syn1": 1e5 x 20, kappa = 1e8 (scaled by `scale_n`).
    pub fn syn1(n: usize) -> SynSpec {
        SynSpec {
            name: "syn1".into(),
            n,
            d: 20,
            kappa: 1e8,
            noise: 0.1,
            signal_scale: SynSpec::signal_auto(n),
        }
    }

    /// Table 3 "Syn2": 1e5 x 20, kappa = 1e3.
    pub fn syn2(n: usize) -> SynSpec {
        SynSpec {
            name: "syn2".into(),
            n,
            d: 20,
            kappa: 1e3,
            noise: 0.1,
            signal_scale: SynSpec::signal_auto(n),
        }
    }
}

/// Generate a dataset with exact condition number `spec.kappa`.
pub fn generate(spec: &SynSpec, rng: &mut Rng) -> Dataset {
    let (n, d) = (spec.n, spec.d);
    assert!(n > d && d >= 2);
    // Q: orthonormal columns from QR of gaussian (n x d)
    let g = Mat::gaussian(n, d, rng);
    let q = qr::qr(&g).q.expect("thin q");
    // V: orthogonal d x d
    let gv = Mat::gaussian(d, d, rng);
    let v = qr::qr(&gv).q.expect("square q");
    // log-spaced spectrum 1 .. 1/kappa
    let sigmas = log_spaced_spectrum(d, spec.kappa);
    // A = Q diag(sigma) V^T: scale columns of Q then multiply by V^T
    let mut qs = q;
    for i in 0..n {
        let row = qs.row_mut(i);
        for j in 0..d {
            row[j] *= sigmas[j];
        }
    }
    let a = blas::gemm(&qs, &v.transpose());
    // planted solution + noisy response
    let x_star: Vec<f64> = rng
        .gaussians(d)
        .into_iter()
        .map(|v| v * spec.signal_scale)
        .collect();
    let mut b = blas::gemv(&a, &x_star);
    for v in &mut b {
        *v += spec.noise * rng.gaussian();
    }
    Dataset::dense(spec.name.clone(), a, b, Some(x_star))
}

/// d singular values log-spaced from 1 down to 1/kappa.
pub fn log_spaced_spectrum(d: usize, kappa: f64) -> Vec<f64> {
    assert!(kappa >= 1.0);
    let lk = kappa.ln();
    (0..d)
        .map(|j| (-lk * j as f64 / (d - 1) as f64).exp())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigen;

    #[test]
    fn spectrum_endpoints() {
        let s = log_spaced_spectrum(5, 100.0);
        assert!((s[0] - 1.0).abs() < 1e-12);
        assert!((s[4] - 0.01).abs() < 1e-12);
        for w in s.windows(2) {
            assert!(w[0] > w[1]);
        }
    }

    #[test]
    fn generated_condition_number_is_exact() {
        let mut rng = Rng::new(1);
        let spec = SynSpec {
            name: "t".into(),
            n: 400,
            d: 8,
            kappa: 1e4,
            noise: 0.1,
            signal_scale: 1.0,
        };
        let ds = generate(&spec, &mut rng);
        let kappa = eigen::cond(ds.dense_if_ready().unwrap());
        assert!(
            (kappa / 1e4 - 1.0).abs() < 1e-6,
            "kappa {kappa} (target 1e4)"
        );
    }

    #[test]
    fn planted_solution_nearly_fits() {
        let mut rng = Rng::new(2);
        let spec = SynSpec {
            name: "t".into(),
            n: 300,
            d: 6,
            kappa: 10.0,
            noise: 0.01,
            signal_scale: 1.0,
        };
        let ds = generate(&spec, &mut rng);
        let xs = ds.x_star_planted.clone().unwrap();
        let f_star = ds.objective(&xs);
        // residual should be ~ noise^2 * n
        let expect = 0.01 * 0.01 * 300.0;
        assert!(f_star < 4.0 * expect, "f* {f_star} vs {expect}");
    }

    #[test]
    fn syn_specs_match_table3_shapes() {
        let s1 = SynSpec::syn1(1000);
        assert_eq!(s1.d, 20);
        assert_eq!(s1.kappa, 1e8);
        let s2 = SynSpec::syn2(1000);
        assert_eq!(s2.kappa, 1e3);
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = SynSpec::syn2(128);
        let d1 = generate(&spec, &mut Rng::new(5));
        let d2 = generate(&spec, &mut Rng::new(5));
        assert_eq!(d1.dense_clone(), d2.dense_clone());
        assert_eq!(d1.b, d2.b);
    }
}
