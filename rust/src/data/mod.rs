//! Datasets: synthetic generators with controlled spectra, simulated UCI
//! workloads, normalization, and binary/CSV IO.

pub mod blocks;
pub mod synthetic;
pub mod uci_sim;
pub mod io;

pub use blocks::{default_block_rows, RowBlock, RowBlocks};

use crate::linalg::{blas, Mat};

/// A regression problem instance: `min_{x in W} ||Ax - b||^2`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub a: Mat,
    pub b: Vec<f64>,
    /// Planted solution when known (synthetic data): for diagnostics only.
    pub x_star_planted: Option<Vec<f64>>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.a.rows
    }

    pub fn d(&self) -> usize {
        self.a.cols
    }

    /// f(x) = ||Ax - b||^2.
    pub fn objective(&self, x: &[f64]) -> f64 {
        blas::residual_sq(&self.a, &self.b, x)
    }

    /// Contiguous row shards of `A` without copying. `block_rows = None`
    /// picks the cache/thread heuristic for this shape.
    pub fn row_blocks(&self, block_rows: Option<usize>) -> RowBlocks<'_> {
        match block_rows {
            Some(br) => RowBlocks::new(&self.a, br),
            None => RowBlocks::auto(&self.a),
        }
    }

    /// Normalize features to zero mean / unit variance and b to unit
    /// variance (the paper normalizes datasets for the low-precision
    /// solvers). Returns the per-column (mean, std) used.
    pub fn normalize(&mut self) -> Vec<(f64, f64)> {
        let n = self.n() as f64;
        let d = self.d();
        let mut stats = Vec::with_capacity(d + 1);
        for j in 0..d {
            let mut mean = 0.0;
            for i in 0..self.a.rows {
                mean += self.a.at(i, j);
            }
            mean /= n;
            let mut var = 0.0;
            for i in 0..self.a.rows {
                let v = self.a.at(i, j) - mean;
                var += v * v;
            }
            var /= n;
            let std = var.sqrt().max(1e-300);
            for i in 0..self.a.rows {
                let v = self.a.at(i, j);
                *self.a.at_mut(i, j) = (v - mean) / std;
            }
            stats.push((mean, std));
        }
        // scale b only (keep affine relationship simple)
        let bmean = self.b.iter().sum::<f64>() / n;
        let bvar = self.b.iter().map(|v| (v - bmean) * (v - bmean)).sum::<f64>() / n;
        let bstd = bvar.sqrt().max(1e-300);
        for v in &mut self.b {
            *v = (*v - bmean) / bstd;
        }
        stats.push((bmean, bstd));
        self.x_star_planted = None; // invalidated by the affine change
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn objective_matches_manual() {
        let a = Mat::from_vec(2, 1, vec![1.0, 2.0]);
        let ds = Dataset {
            name: "t".into(),
            a,
            b: vec![1.0, 0.0],
            x_star_planted: None,
        };
        // x = 1 -> residuals (0, 2) -> f = 4
        assert!((ds.objective(&[1.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn row_blocks_expose_a_without_copying() {
        let mut rng = Rng::new(2);
        let ds = Dataset {
            name: "t".into(),
            a: Mat::gaussian(10, 2, &mut rng),
            b: vec![0.0; 10],
            x_star_planted: None,
        };
        let view = ds.row_blocks(Some(4));
        assert_eq!(view.num_blocks(), 3);
        let covered: usize = view.iter().map(|blk| blk.rows).sum();
        assert_eq!(covered, ds.n());
        assert!(std::ptr::eq(
            view.block(0).data.as_ptr(),
            ds.a.row(0).as_ptr()
        ));
        // heuristic variant resolves to a valid tiling too
        assert!(ds.row_blocks(None).num_blocks() >= 1);
    }

    #[test]
    fn normalize_zeroes_means_and_unit_vars() {
        let mut rng = Rng::new(1);
        let mut a = Mat::gaussian(500, 3, &mut rng);
        for i in 0..a.rows {
            *a.at_mut(i, 1) = a.at(i, 1) * 100.0 + 5.0; // wildly scaled col
        }
        let b: Vec<f64> = (0..500).map(|_| rng.gaussian() * 10.0 + 3.0).collect();
        let mut ds = Dataset {
            name: "t".into(),
            a,
            b,
            x_star_planted: None,
        };
        ds.normalize();
        for j in 0..3 {
            let col = ds.a.col(j);
            let mean = col.iter().sum::<f64>() / 500.0;
            let var = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 500.0;
            assert!(mean.abs() < 1e-10);
            assert!((var - 1.0).abs() < 1e-10);
        }
        let bmean = ds.b.iter().sum::<f64>() / 500.0;
        assert!(bmean.abs() < 1e-10);
    }
}
