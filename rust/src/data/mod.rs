//! Datasets: synthetic generators with controlled spectra, simulated UCI
//! workloads, sparse (CSR) generation and libsvm ingestion, normalization,
//! and binary/CSV IO.

pub mod blocks;
pub mod synthetic;
pub mod sparse_gen;
pub mod uci_sim;
pub mod io;
pub mod libsvm;

pub use blocks::{
    default_block_nnz, default_block_rows, CsrBlock, CsrBlocks, RowBlock, RowBlocks,
};

use crate::linalg::{blas, CsrMat, Mat};

/// A regression problem instance: `min_{x in W} ||Ax - b||^2`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub a: Mat,
    /// CSR payload when this dataset is sparse (libsvm ingest, sparse
    /// synthetic generation). INVARIANT: when present, `a` holds the dense
    /// materialization `csr.to_dense()` — dense-only stages (QR ground
    /// truth, the HD transform's FWHT, normalization) read `a`, while the
    /// flop-heavy paths (sketching, mini-batch gradients, objective
    /// evaluation) route through `csr` in O(nnz). See DESIGN.md §10 for the
    /// representation contract and the memory caveat.
    pub csr: Option<CsrMat>,
    pub b: Vec<f64>,
    /// Planted solution when known (synthetic data): for diagnostics only.
    pub x_star_planted: Option<Vec<f64>>,
}

impl Dataset {
    /// Build a sparse dataset from a CSR payload (the dense mirror is
    /// materialized eagerly; see the `csr` field invariant).
    pub fn from_csr(
        name: impl Into<String>,
        csr: CsrMat,
        b: Vec<f64>,
        x_star_planted: Option<Vec<f64>>,
    ) -> Dataset {
        assert_eq!(csr.rows, b.len());
        let a = csr.to_dense();
        Dataset {
            name: name.into(),
            a,
            csr: Some(csr),
            b,
            x_star_planted,
        }
    }

    pub fn n(&self) -> usize {
        self.a.rows
    }

    pub fn d(&self) -> usize {
        self.a.cols
    }

    /// Whether the CSR fast paths are active.
    pub fn is_sparse(&self) -> bool {
        self.csr.is_some()
    }

    /// Stored entries: nnz for sparse datasets, n*d for dense ones.
    pub fn nnz(&self) -> usize {
        match &self.csr {
            Some(c) => c.nnz(),
            None => self.a.rows * self.a.cols,
        }
    }

    /// nnz / (n*d); exactly 1.0 for dense datasets.
    pub fn density(&self) -> f64 {
        match &self.csr {
            Some(c) => c.density(),
            None => 1.0,
        }
    }

    /// f(x) = ||Ax - b||^2 — O(nnz) on sparse datasets.
    pub fn objective(&self, x: &[f64]) -> f64 {
        match &self.csr {
            Some(c) => c.residual_sq(&self.b, x),
            None => blas::residual_sq(&self.a, &self.b, x),
        }
    }

    /// `A_i · x` — O(nnz(row)) on sparse datasets; on dense ones this is
    /// exactly `blas::dot(a.row(i), x)` (bit-identical to the pre-sparse
    /// code path).
    #[inline]
    pub fn row_dot(&self, i: usize, x: &[f64]) -> f64 {
        match &self.csr {
            Some(c) => c.row_dot(i, x),
            None => blas::dot(self.a.row(i), x),
        }
    }

    /// `out += coef * A_i` — O(nnz(row)) on sparse datasets; bit-identical
    /// `blas::axpy` on dense ones.
    #[inline]
    pub fn row_axpy(&self, i: usize, coef: f64, out: &mut [f64]) {
        match &self.csr {
            Some(c) => c.row_axpy(i, coef, out),
            None => blas::axpy(coef, self.a.row(i), out),
        }
    }

    /// `coef * A_i` as a dense vector (pwSGD's variance probe).
    pub fn row_scaled(&self, i: usize, coef: f64) -> Vec<f64> {
        match &self.csr {
            Some(c) => {
                let mut out = vec![0.0; self.d()];
                c.row_axpy(i, coef, &mut out);
                out
            }
            None => self.a.row(i).iter().map(|v| coef * v).collect(),
        }
    }

    /// Contiguous row shards of `A` without copying. `block_rows = None`
    /// picks the cache/thread heuristic for this shape.
    pub fn row_blocks(&self, block_rows: Option<usize>) -> RowBlocks<'_> {
        match block_rows {
            Some(br) => RowBlocks::new(&self.a, br),
            None => RowBlocks::auto(&self.a),
        }
    }

    /// nnz-sharded CSR shards (sparse datasets only). An explicit
    /// `block_rows` tuning knob is translated into an nnz budget via the
    /// mean row occupancy, so `--block-rows` means "about this many rows
    /// per shard" in both representations.
    pub fn csr_blocks(&self, block_rows: Option<usize>) -> Option<CsrBlocks<'_>> {
        let c = self.csr.as_ref()?;
        Some(match block_rows {
            Some(br) => CsrBlocks::new(c, c.nnz_budget_for_rows(br)),
            None => CsrBlocks::auto(c),
        })
    }

    /// Normalize features to zero mean / unit variance and b to unit
    /// variance (the paper normalizes datasets for the low-precision
    /// solvers). Returns the per-column (mean, std) used.
    ///
    /// Mean-centering fills in every zero, so a sparse dataset is densified
    /// here: the CSR payload is dropped (with a warning) and the dataset
    /// continues on the dense paths.
    pub fn normalize(&mut self) -> Vec<(f64, f64)> {
        if self.csr.take().is_some() {
            crate::log_warn!(
                "normalize({}): mean-centering densifies — dropping the CSR payload",
                self.name
            );
        }
        let n = self.n() as f64;
        let d = self.d();
        let mut stats = Vec::with_capacity(d + 1);
        for j in 0..d {
            let mut mean = 0.0;
            for i in 0..self.a.rows {
                mean += self.a.at(i, j);
            }
            mean /= n;
            let mut var = 0.0;
            for i in 0..self.a.rows {
                let v = self.a.at(i, j) - mean;
                var += v * v;
            }
            var /= n;
            let std = var.sqrt().max(1e-300);
            for i in 0..self.a.rows {
                let v = self.a.at(i, j);
                *self.a.at_mut(i, j) = (v - mean) / std;
            }
            stats.push((mean, std));
        }
        // scale b only (keep affine relationship simple)
        let bmean = self.b.iter().sum::<f64>() / n;
        let bvar = self.b.iter().map(|v| (v - bmean) * (v - bmean)).sum::<f64>() / n;
        let bstd = bvar.sqrt().max(1e-300);
        for v in &mut self.b {
            *v = (*v - bmean) / bstd;
        }
        stats.push((bmean, bstd));
        self.x_star_planted = None; // invalidated by the affine change
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn objective_matches_manual() {
        let a = Mat::from_vec(2, 1, vec![1.0, 2.0]);
        let ds = Dataset {
            name: "t".into(),
            a,
            csr: None,
            b: vec![1.0, 0.0],
            x_star_planted: None,
        };
        // x = 1 -> residuals (0, 2) -> f = 4
        assert!((ds.objective(&[1.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn row_blocks_expose_a_without_copying() {
        let mut rng = Rng::new(2);
        let ds = Dataset {
            name: "t".into(),
            a: Mat::gaussian(10, 2, &mut rng),
            csr: None,
            b: vec![0.0; 10],
            x_star_planted: None,
        };
        let view = ds.row_blocks(Some(4));
        assert_eq!(view.num_blocks(), 3);
        let covered: usize = view.iter().map(|blk| blk.rows).sum();
        assert_eq!(covered, ds.n());
        assert!(std::ptr::eq(
            view.block(0).data.as_ptr(),
            ds.a.row(0).as_ptr()
        ));
        // heuristic variant resolves to a valid tiling too
        assert!(ds.row_blocks(None).num_blocks() >= 1);
        // dense datasets have no CSR shards
        assert!(ds.csr_blocks(None).is_none());
        assert!(!ds.is_sparse());
        assert_eq!(ds.nnz(), 20);
        assert_eq!(ds.density(), 1.0);
    }

    #[test]
    fn sparse_dataset_routes_csr_and_mirrors_dense() {
        let mut rng = Rng::new(3);
        let dense = Mat::from_fn(12, 4, |_, _| {
            if rng.uniform() < 0.4 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let b = rng.gaussians(12);
        let csr = CsrMat::from_dense(&dense);
        let nnz = csr.nnz();
        let ds = Dataset::from_csr("sp", csr, b.clone(), None);
        assert!(ds.is_sparse());
        assert_eq!(ds.a, dense, "dense mirror must match the CSR payload");
        assert_eq!(ds.nnz(), nnz);
        assert!(ds.density() < 1.0);
        let x = rng.gaussians(4);
        let f_sparse = ds.objective(&x);
        let f_dense = blas::residual_sq(&dense, &b, &x);
        assert!((f_sparse - f_dense).abs() < 1e-10 * (1.0 + f_dense));
        // row helpers agree with the dense mirror
        for i in 0..12 {
            assert!((ds.row_dot(i, &x) - blas::dot(dense.row(i), &x)).abs() < 1e-12);
        }
        // nnz-sharded view exists and tiles the rows
        let view = ds.csr_blocks(Some(3)).unwrap();
        let covered: usize = view.iter().map(|b| b.rows).sum();
        assert_eq!(covered, 12);
    }

    #[test]
    fn normalize_zeroes_means_and_unit_vars() {
        let mut rng = Rng::new(1);
        let mut a = Mat::gaussian(500, 3, &mut rng);
        for i in 0..a.rows {
            *a.at_mut(i, 1) = a.at(i, 1) * 100.0 + 5.0; // wildly scaled col
        }
        let b: Vec<f64> = (0..500).map(|_| rng.gaussian() * 10.0 + 3.0).collect();
        let mut ds = Dataset {
            name: "t".into(),
            a,
            csr: None,
            b,
            x_star_planted: None,
        };
        ds.normalize();
        for j in 0..3 {
            let col = ds.a.col(j);
            let mean = col.iter().sum::<f64>() / 500.0;
            let var = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 500.0;
            assert!(mean.abs() < 1e-10);
            assert!((var - 1.0).abs() < 1e-10);
        }
        let bmean = ds.b.iter().sum::<f64>() / 500.0;
        assert!(bmean.abs() < 1e-10);
    }

    #[test]
    fn normalize_drops_csr_payload() {
        let mut rng = Rng::new(4);
        let dense = Mat::from_fn(50, 3, |_, _| {
            if rng.uniform() < 0.5 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let b = rng.gaussians(50);
        let mut ds = Dataset::from_csr("sp", CsrMat::from_dense(&dense), b, None);
        assert!(ds.is_sparse());
        ds.normalize();
        assert!(!ds.is_sparse(), "centering densifies");
        assert_eq!(ds.density(), 1.0);
    }
}
