//! Datasets: synthetic generators with controlled spectra, simulated UCI
//! workloads, sparse (CSR) generation and libsvm ingestion, normalization,
//! and binary/CSV IO.

pub mod blocks;
pub mod chunked;
pub mod design;
pub mod mmap;
pub mod out_of_core;
pub mod synthetic;
pub mod sparse_gen;
pub mod uci_sim;
pub mod io;
pub mod libsvm;

pub use blocks::{
    default_block_nnz, default_block_rows, CsrBlock, CsrBlocks, RowBlock, RowBlocks,
};
pub use design::{DenseView, DesignMatrix, Repr};
pub use out_of_core::OnDiskDesign;

use crate::linalg::{blas, CsrMat, Mat};
use crate::util::mem::{MemBudget, MemError};
use std::sync::Arc;

/// A regression problem instance: `min_{x in W} ||Ax - b||^2`.
///
/// The design matrix is representation-polymorphic ([`DesignMatrix`]):
/// dense datasets behave exactly as before, while CSR datasets carry *no*
/// dense mirror until a stage explicitly requests one through the
/// budget-accounted capability calls ([`Dataset::materialize_dense`] /
/// [`Dataset::dense_scoped`]). See DESIGN.md §11.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Human-readable dataset name (reports, cache keys, logs).
    pub name: String,
    /// The design matrix `A`, in whichever representation it arrived in.
    pub design: DesignMatrix,
    /// The response vector `b` (length `n`).
    pub b: Vec<f64>,
    /// Planted solution when known (synthetic data): for diagnostics only.
    pub x_star_planted: Option<Vec<f64>>,
}

impl Dataset {
    /// Build a dense dataset.
    pub fn dense(
        name: impl Into<String>,
        a: Mat,
        b: Vec<f64>,
        x_star_planted: Option<Vec<f64>>,
    ) -> Dataset {
        assert_eq!(a.rows, b.len());
        Dataset {
            name: name.into(),
            design: DesignMatrix::from_dense(a),
            b,
            x_star_planted,
        }
    }

    /// Build a sparse dataset from a CSR payload. NO dense mirror is
    /// materialized — a dense view is a budget-accounted capability request
    /// (see [`DesignMatrix`]), and step-1-only sparse pipelines never make
    /// one.
    pub fn from_csr(
        name: impl Into<String>,
        csr: CsrMat,
        b: Vec<f64>,
        x_star_planted: Option<Vec<f64>>,
    ) -> Dataset {
        assert_eq!(csr.rows, b.len());
        Dataset {
            name: name.into(),
            design: DesignMatrix::from_csr(csr),
            b,
            x_star_planted,
        }
    }

    /// Build a disk-backed dataset: the design streams through the shard
    /// cache bound inside `od`; only `b` (copied out at open) is resident.
    pub fn from_on_disk(name: impl Into<String>, od: Arc<OnDiskDesign>) -> Dataset {
        let b = od.b().to_vec();
        Dataset {
            name: name.into(),
            design: DesignMatrix::from_on_disk(od),
            b,
            x_star_planted: None,
        }
    }

    /// Number of rows (samples) in the design matrix.
    pub fn n(&self) -> usize {
        self.design.rows()
    }

    /// Number of columns (features) in the design matrix.
    pub fn d(&self) -> usize {
        self.design.cols()
    }

    /// Whether a CSR payload is *resident* (the in-memory sparse fast
    /// paths). On-disk datasets report `false` here even when their
    /// arithmetic is sparse; see [`Dataset::sparse_arith`].
    pub fn is_sparse(&self) -> bool {
        self.design.repr() == Repr::Csr
    }

    /// Whether kernels run CSR-style arithmetic on this dataset (resident
    /// CSR or the chunked on-disk flavor) — what the cost model, step-2
    /// routing and metrics actually key on.
    pub fn sparse_arith(&self) -> bool {
        self.design.sparse_arith()
    }

    /// The CSR payload when this dataset is sparse.
    pub fn csr(&self) -> Option<&CsrMat> {
        self.design.csr()
    }

    /// The disk-backed design when this dataset is out-of-core.
    pub fn on_disk(&self) -> Option<&Arc<OnDiskDesign>> {
        self.design.on_disk()
    }

    /// Stored entries: nnz for sparse datasets, n*d for dense ones.
    pub fn nnz(&self) -> usize {
        self.design.nnz()
    }

    /// nnz / (n*d); exactly 1.0 for dense datasets.
    pub fn density(&self) -> f64 {
        self.design.density()
    }

    /// A dense view that already exists (dense dataset, or a materialized
    /// mirror) — never allocates. Dense-only consumers on the hot path use
    /// this; it is always `Some` for dense datasets.
    pub fn dense_if_ready(&self) -> Option<&Mat> {
        self.design.dense_if_ready()
    }

    /// Capability call: the dense view, lazily materialized through the
    /// budget (charged + counted + logged with `stage`; `Err` over budget).
    pub fn materialize_dense(
        &self,
        budget: &Arc<MemBudget>,
        stage: &str,
    ) -> Result<&Mat, MemError> {
        self.design.materialize_dense(budget, stage)
    }

    /// Drop-after-use dense view for one-shot consumers (charge and copy
    /// released when the view drops; never cached). Fallible two ways: an
    /// over-budget charge ([`MemError`]) or, for on-disk designs, a shard
    /// read failure — both structured, never a panic.
    pub fn dense_scoped(
        &self,
        budget: &Arc<MemBudget>,
        stage: &str,
    ) -> anyhow::Result<DenseView<'_>> {
        self.design.dense_scoped(budget, stage)
    }

    /// Mutable dense access for dense datasets (generator post-processing).
    pub fn dense_mut(&mut self) -> Option<&mut Mat> {
        self.design.dense_mut()
    }

    /// Fresh dense copy — diagnostics/tests/serialization references only
    /// (un-tracked, un-cached; see [`DesignMatrix::dense_clone`]).
    pub fn dense_clone(&self) -> Mat {
        self.design.dense_clone()
    }

    /// The dense view a dense-only code path may assume (dense datasets
    /// only; CSR callers must hold a capability view instead).
    fn dense_ref(&self) -> &Mat {
        self.design.dense_if_ready().expect(
            "dense-only path reached a dataset without a resident dense view \
             (CSR or on-disk): use the capability / try_* accessors",
        )
    }

    /// f(x) = ||Ax - b||^2 — O(nnz) on sparse datasets. In-memory datasets
    /// only; on-disk callers use the fallible [`Dataset::try_objective`].
    pub fn objective(&self, x: &[f64]) -> f64 {
        match self.csr() {
            Some(c) => c.residual_sq(&self.b, x),
            None => blas::residual_sq(self.dense_ref(), &self.b, x),
        }
    }

    /// Fallible [`Dataset::objective`]: routes on-disk datasets through the
    /// shard-streamed kernel (bitwise equal to the resident twin's), where a
    /// failed disk read or refused shard charge is a structured error.
    pub fn try_objective(&self, x: &[f64]) -> anyhow::Result<f64> {
        match self.on_disk() {
            Some(od) => od.residual_sq(&self.b, x),
            None => Ok(self.objective(x)),
        }
    }

    /// Fallible batched objective: `||A x_k - b||^2` per iterate in one
    /// pass, bitwise per column to [`Dataset::try_objective`].
    pub fn try_objective_multi(&self, xs: &[Vec<f64>]) -> anyhow::Result<Vec<f64>> {
        match self.on_disk() {
            Some(od) => od.residual_sq_multi(&self.b, xs),
            None => Ok(match self.csr() {
                Some(c) => c.residual_sq_multi(&self.b, xs),
                None => blas::residual_sq_multi(self.dense_ref(), &self.b, xs),
            }),
        }
    }

    /// Mean squared row entry `sum_ij a_ij^2 / n` — the row-second-moment
    /// scale the SGD-family step sizes derive from. O(nnz) on sparse
    /// datasets; the dense branch is bit-identical to summing the dense
    /// payload (skipped zeros are exact no-ops in IEEE addition).
    pub fn row_mean_sq(&self) -> f64 {
        let n = self.n() as f64;
        let sum: f64 = match self.csr() {
            Some(c) => c.values.iter().map(|v| v * v).sum(),
            None => self.dense_ref().data.iter().map(|v| v * v).sum(),
        };
        sum / n
    }

    /// Fallible [`Dataset::row_mean_sq`]: the on-disk stream sums in the
    /// same entry order as the resident representation, so the result is
    /// bitwise identical.
    pub fn try_row_mean_sq(&self) -> anyhow::Result<f64> {
        match self.on_disk() {
            Some(od) => Ok(od.sum_sq()? / self.n() as f64),
            None => Ok(self.row_mean_sq()),
        }
    }

    /// `A_i · x` — O(nnz(row)) on sparse datasets; on dense ones this is
    /// exactly `blas::dot(a.row(i), x)` (bit-identical to the pre-sparse
    /// code path).
    #[inline]
    pub fn row_dot(&self, i: usize, x: &[f64]) -> f64 {
        match self.csr() {
            Some(c) => c.row_dot(i, x),
            None => blas::dot(self.dense_ref().row(i), x),
        }
    }

    /// `out += coef * A_i` — O(nnz(row)) on sparse datasets; bit-identical
    /// `blas::axpy` on dense ones.
    #[inline]
    pub fn row_axpy(&self, i: usize, coef: f64, out: &mut [f64]) {
        match self.csr() {
            Some(c) => c.row_axpy(i, coef, out),
            None => blas::axpy(coef, self.dense_ref().row(i), out),
        }
    }

    /// `coef * A_i` as a dense vector (pwSGD's variance probe).
    pub fn row_scaled(&self, i: usize, coef: f64) -> Vec<f64> {
        match self.csr() {
            Some(c) => {
                let mut out = vec![0.0; self.d()];
                c.row_axpy(i, coef, &mut out);
                out
            }
            None => self.dense_ref().row(i).iter().map(|v| coef * v).collect(),
        }
    }

    /// Fallible [`Dataset::row_dot`]: on-disk rows come through the shard
    /// cache (a miss may fault a shard in — or fail, structurally).
    #[inline]
    pub fn try_row_dot(&self, i: usize, x: &[f64]) -> anyhow::Result<f64> {
        match self.on_disk() {
            Some(od) => od.try_row_dot(i, x),
            None => Ok(self.row_dot(i, x)),
        }
    }

    /// Fallible [`Dataset::row_axpy`].
    #[inline]
    pub fn try_row_axpy(&self, i: usize, coef: f64, out: &mut [f64]) -> anyhow::Result<()> {
        match self.on_disk() {
            Some(od) => od.try_row_axpy(i, coef, out),
            None => {
                self.row_axpy(i, coef, out);
                Ok(())
            }
        }
    }

    /// Fallible [`Dataset::row_scaled`].
    pub fn try_row_scaled(&self, i: usize, coef: f64) -> anyhow::Result<Vec<f64>> {
        match self.on_disk() {
            Some(od) => od.try_row_scaled(i, coef),
            None => Ok(self.row_scaled(i, coef)),
        }
    }

    /// Contiguous row shards of the dense view without copying (dense
    /// datasets; CSR callers shard with [`Dataset::csr_blocks`]).
    /// `block_rows = None` picks the cache/thread heuristic for this shape.
    pub fn row_blocks(&self, block_rows: Option<usize>) -> RowBlocks<'_> {
        let a = self.dense_ref();
        match block_rows {
            Some(br) => RowBlocks::new(a, br),
            None => RowBlocks::auto(a),
        }
    }

    /// nnz-sharded CSR shards (sparse datasets only). An explicit
    /// `block_rows` tuning knob is translated into an nnz budget via the
    /// mean row occupancy, so `--block-rows` means "about this many rows
    /// per shard" in both representations.
    pub fn csr_blocks(&self, block_rows: Option<usize>) -> Option<CsrBlocks<'_>> {
        let c = self.csr()?;
        Some(match block_rows {
            Some(br) => CsrBlocks::new(c, c.nnz_budget_for_rows(br)),
            None => CsrBlocks::auto(c),
        })
    }

    /// Normalize for the low-precision solvers (the paper normalizes its
    /// datasets). Dense datasets keep the historical semantics — zero mean /
    /// unit variance per column, b to unit variance. Sparse datasets route
    /// to the sparsity-preserving [`Dataset::normalize_scale_only`] mode
    /// (mean-centering would fill in every stored zero); the routing is
    /// logged. Returns the per-column (mean, scale) used (+ b's last).
    pub fn normalize(&mut self) -> Vec<(f64, f64)> {
        if self.on_disk().is_some() {
            // the scheduler rejects normalize+on-disk requests up front;
            // this guard keeps a direct library call a no-op, not a panic
            crate::log_warn!(
                "normalize({}): on-disk dataset — unsupported, skipped",
                self.name
            );
            return Vec::new();
        }
        if self.is_sparse() {
            crate::log_info!(
                "normalize({}): CSR dataset — scale-only mode (no centering, sparsity preserved)",
                self.name
            );
            return self.normalize_scale_only();
        }
        self.normalize_center_scale()
    }

    /// Scale-only normalization: divide column j by its 2-norm scale
    /// `s_j = ||A_:j||_2 / sqrt(n)` (the centering mode's variance scale
    /// without the mean subtraction) and b by its own 2-norm scale. Zeros
    /// stay zeros, so CSR payloads keep their structure exactly. Works on
    /// both representations; the dense arithmetic per stored entry is
    /// identical to the CSR arithmetic, so a CSR dataset and its dense twin
    /// normalize to the same values (parity-tested).
    pub fn normalize_scale_only(&mut self) -> Vec<(f64, f64)> {
        let n = self.n() as f64;
        let d = self.d();
        let mut sumsq = vec![0.0; d];
        match self.csr() {
            Some(c) => {
                for (j, v) in c.indices.iter().zip(&c.values) {
                    sumsq[*j as usize] += v * v;
                }
            }
            None => {
                let a = self.dense_ref();
                for i in 0..a.rows {
                    for (j, v) in a.row(i).iter().enumerate() {
                        sumsq[j] += v * v;
                    }
                }
            }
        }
        let mut stats = Vec::with_capacity(d + 1);
        let mut inv = Vec::with_capacity(d);
        for &sq in &sumsq {
            let s = (sq / n).sqrt().max(1e-300);
            stats.push((0.0, s));
            inv.push(1.0 / s);
        }
        self.design.scale_columns(&inv);
        let bsq: f64 = self.b.iter().map(|v| v * v).sum();
        let bs = (bsq / n).sqrt().max(1e-300);
        let binv = 1.0 / bs;
        for v in &mut self.b {
            *v *= binv;
        }
        stats.push((0.0, bs));
        self.x_star_planted = None; // column scaling reweights the problem
        stats
    }

    /// The historical dense normalization: zero mean / unit variance per
    /// column and b to unit variance.
    fn normalize_center_scale(&mut self) -> Vec<(f64, f64)> {
        let n = self.n() as f64;
        let d = self.d();
        let mut stats = Vec::with_capacity(d + 1);
        let a = self.design.dense_mut().expect("center-scale is dense-only");
        for j in 0..d {
            let mut mean = 0.0;
            for i in 0..a.rows {
                mean += a.at(i, j);
            }
            mean /= n;
            let mut var = 0.0;
            for i in 0..a.rows {
                let v = a.at(i, j) - mean;
                var += v * v;
            }
            var /= n;
            let std = var.sqrt().max(1e-300);
            for i in 0..a.rows {
                let v = a.at(i, j);
                *a.at_mut(i, j) = (v - mean) / std;
            }
            stats.push((mean, std));
        }
        // scale b only (keep affine relationship simple)
        let bmean = self.b.iter().sum::<f64>() / n;
        let bvar = self.b.iter().map(|v| (v - bmean) * (v - bmean)).sum::<f64>() / n;
        let bstd = bvar.sqrt().max(1e-300);
        for v in &mut self.b {
            *v = (*v - bmean) / bstd;
        }
        stats.push((bmean, bstd));
        self.x_star_planted = None; // invalidated by the affine change
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn objective_matches_manual() {
        let a = Mat::from_vec(2, 1, vec![1.0, 2.0]);
        let ds = Dataset::dense("t", a, vec![1.0, 0.0], None);
        // x = 1 -> residuals (0, 2) -> f = 4
        assert!((ds.objective(&[1.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn row_blocks_expose_a_without_copying() {
        let mut rng = Rng::new(2);
        let ds = Dataset::dense("t", Mat::gaussian(10, 2, &mut rng), vec![0.0; 10], None);
        let view = ds.row_blocks(Some(4));
        assert_eq!(view.num_blocks(), 3);
        let covered: usize = view.iter().map(|blk| blk.rows).sum();
        assert_eq!(covered, ds.n());
        assert!(std::ptr::eq(
            view.block(0).data.as_ptr(),
            ds.dense_if_ready().unwrap().row(0).as_ptr()
        ));
        // heuristic variant resolves to a valid tiling too
        assert!(ds.row_blocks(None).num_blocks() >= 1);
        // dense datasets have no CSR shards
        assert!(ds.csr_blocks(None).is_none());
        assert!(!ds.is_sparse());
        assert_eq!(ds.nnz(), 20);
        assert_eq!(ds.density(), 1.0);
    }

    #[test]
    fn sparse_dataset_routes_csr_without_a_mirror() {
        let mut rng = Rng::new(3);
        let dense = Mat::from_fn(12, 4, |_, _| {
            if rng.uniform() < 0.4 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let b = rng.gaussians(12);
        let csr = CsrMat::from_dense(&dense);
        let nnz = csr.nnz();
        let ds = Dataset::from_csr("sp", csr, b.clone(), None);
        assert!(ds.is_sparse());
        assert!(
            ds.dense_if_ready().is_none(),
            "the dense mirror must NOT exist until requested"
        );
        assert_eq!(ds.nnz(), nnz);
        assert!(ds.density() < 1.0);
        let x = rng.gaussians(4);
        let f_sparse = ds.objective(&x);
        let f_dense = blas::residual_sq(&dense, &b, &x);
        assert!((f_sparse - f_dense).abs() < 1e-10 * (1.0 + f_dense));
        // row helpers agree with the dense data
        for i in 0..12 {
            assert!((ds.row_dot(i, &x) - blas::dot(dense.row(i), &x)).abs() < 1e-12);
        }
        // nnz-sharded view exists and tiles the rows
        let view = ds.csr_blocks(Some(3)).unwrap();
        let covered: usize = view.iter().map(|b| b.rows).sum();
        assert_eq!(covered, 12);
        // the capability call materializes the exact dense twin, once
        let budget = crate::util::mem::MemBudget::unlimited();
        let m = ds.materialize_dense(&budget, "test").unwrap();
        assert_eq!(*m, dense);
        assert_eq!(budget.densify_events(), 1);
        assert!(ds.dense_if_ready().is_some());
    }

    #[test]
    fn normalize_zeroes_means_and_unit_vars() {
        let mut rng = Rng::new(1);
        let mut a = Mat::gaussian(500, 3, &mut rng);
        for i in 0..a.rows {
            *a.at_mut(i, 1) = a.at(i, 1) * 100.0 + 5.0; // wildly scaled col
        }
        let b: Vec<f64> = (0..500).map(|_| rng.gaussian() * 10.0 + 3.0).collect();
        let mut ds = Dataset::dense("t", a, b, None);
        ds.normalize();
        let a = ds.dense_if_ready().unwrap();
        for j in 0..3 {
            let col = a.col(j);
            let mean = col.iter().sum::<f64>() / 500.0;
            let var = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 500.0;
            assert!(mean.abs() < 1e-10);
            assert!((var - 1.0).abs() < 1e-10);
        }
        let bmean = ds.b.iter().sum::<f64>() / 500.0;
        assert!(bmean.abs() < 1e-10);
    }

    #[test]
    fn normalize_on_csr_preserves_sparsity() {
        let mut rng = Rng::new(4);
        let dense = Mat::from_fn(200, 4, |_, _| {
            if rng.uniform() < 0.3 {
                rng.gaussian() * 50.0
            } else {
                0.0
            }
        });
        let b: Vec<f64> = (0..200).map(|_| rng.gaussian() * 7.0).collect();
        let mut ds = Dataset::from_csr("sp", CsrMat::from_dense(&dense), b, None);
        let nnz = ds.nnz();
        let stats = ds.normalize(); // routes to scale-only for CSR
        assert!(ds.is_sparse(), "normalization must NOT densify CSR data");
        assert_eq!(ds.nnz(), nnz, "sparsity structure preserved");
        assert!(ds.dense_if_ready().is_none(), "still no mirror");
        // every column now has unit RMS over all n entries (zeros included)
        let c = ds.csr().unwrap();
        let mut sumsq = vec![0.0; 4];
        for (j, v) in c.indices.iter().zip(&c.values) {
            sumsq[*j as usize] += v * v;
        }
        for (j, sq) in sumsq.iter().enumerate() {
            assert!(((sq / 200.0).sqrt() - 1.0).abs() < 1e-12, "col {j}");
        }
        let brms = (ds.b.iter().map(|v| v * v).sum::<f64>() / 200.0).sqrt();
        assert!((brms - 1.0).abs() < 1e-12);
        // reported stats: zero means, positive scales
        assert!(stats.iter().all(|&(m, s)| m == 0.0 && s > 0.0));
    }

    #[test]
    fn scale_only_parity_with_dense_twin() {
        let mut rng = Rng::new(9);
        let dense = Mat::from_fn(300, 5, |_, _| {
            if rng.uniform() < 0.25 {
                rng.gaussian() * 10.0
            } else {
                0.0
            }
        });
        let b = rng.gaussians(300);
        let mut sp = Dataset::from_csr("sp", CsrMat::from_dense(&dense), b.clone(), None);
        let mut dn = Dataset::dense("dn", dense, b, None);
        let s1 = sp.normalize_scale_only();
        let s2 = dn.normalize_scale_only();
        assert_eq!(s1, s2, "identical scales on both representations");
        let sp_dense = sp.dense_clone();
        let dn_dense = dn.dense_clone();
        assert!(
            sp_dense.max_abs_diff(&dn_dense) < 1e-12,
            "scale-only CSR must match its dense twin"
        );
        assert_eq!(sp.b, dn.b);
        assert!(sp.is_sparse());
    }

    #[test]
    fn row_mean_sq_routes_by_representation() {
        let mut rng = Rng::new(11);
        let dense = Mat::from_fn(40, 3, |_, _| {
            if rng.uniform() < 0.5 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let b = vec![0.0; 40];
        let sp = Dataset::from_csr("sp", CsrMat::from_dense(&dense), b.clone(), None);
        let dn = Dataset::dense("dn", dense, b, None);
        // zeros are exact no-ops in the sum, so the two agree bitwise
        assert_eq!(sp.row_mean_sq().to_bits(), dn.row_mean_sq().to_bits());
    }
}
