//! libsvm / svmlight text ingestion — the sparse serve-path workload class.
//!
//! One row per line: `<label> <index>:<value> <index>:<value> ... # comment`.
//! The loader is deliberately liberal where the ecosystem is inconsistent
//! and strict where silent acceptance would corrupt data:
//!
//! * **1-based vs 0-based indices**: auto-detected over the whole file — if
//!   any row uses index 0 the file is 0-based, otherwise the libsvm
//!   standard 1-based convention applies.
//! * **Out-of-order features**: accepted (sorted on ingest); real exports
//!   produce them.
//! * **Duplicate feature indices** within a row: rejected with the line
//!   number — "last wins" and "sum" are both plausible, so guessing would
//!   silently change the regression.
//! * **Trailing comments** (`# ...`) and blank lines: stripped/skipped.
//! * **Empty rows** (label only): kept as all-zero feature rows.
//! * **Column count**: inferred as `max index + 1 - base` — which would
//!   silently shrink a matrix whose trailing columns hold no entries — so
//!   [`to_text`] writes (and the parser honors) a `# hdpw: cols=<d>`
//!   header comment declaring the true dimension. Foreign files without
//!   the header fall back to inference; a declared dimension acts as a
//!   floor (data may still widen it).
//! * **Malformed anything** (bad numbers, missing `:`, negative or
//!   non-integer indices, non-finite values): `Err` with the line number —
//!   never a panic, so a serve worker surfaces it as a job error.
//!
//! [`load`] streams the file line-by-line through `BufRead` — a multi-GB
//! libsvm file is never slurped into one `String`, so loading cannot
//! itself blow the memory budget the sparse pipeline exists to respect
//! (peak transient is one line + the growing CSR arrays).

use super::Dataset;
use crate::linalg::CsrMat;
use anyhow::{bail, Context, Result};
use std::io::BufRead;
use std::path::Path;

/// The dimension-declaration header [`to_text`] writes: `# hdpw: cols=<d>`.
const COLS_HEADER: &str = "hdpw: cols=";

/// Incremental libsvm parser: feed lines one at a time, finish into a
/// [`Dataset`]. Shared by the in-memory [`parse_str`] and the streaming
/// [`load`], so both have identical validation and line-numbered errors.
#[derive(Default)]
struct Parser {
    rows: Vec<(f64, Vec<(u64, f64)>)>,
    saw_zero_index: bool,
    max_index: u64,
    any_feature: bool,
    declared_cols: usize,
}

impl Parser {
    fn feed(&mut self, line_no: usize, raw: &str) -> Result<()> {
        // dimension declaration (a comment to every other libsvm reader)
        if let Some(rest) = raw.trim().strip_prefix('#') {
            if let Some(v) = rest.trim().strip_prefix(COLS_HEADER) {
                let cols: usize = v.trim().parse().map_err(|_| {
                    anyhow::anyhow!("line {line_no}: bad cols declaration {v:?}")
                })?;
                self.declared_cols = self.declared_cols.max(cols);
            }
        }
        // strip trailing comment, then surrounding whitespace
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            return Ok(());
        }
        let mut toks = line.split_whitespace();
        let label_tok = toks.next().expect("non-empty line has a first token");
        let label: f64 = label_tok
            .parse()
            .map_err(|_| anyhow::anyhow!("line {line_no}: bad label {label_tok:?}"))?;
        if !label.is_finite() {
            bail!("line {line_no}: non-finite label {label_tok:?}");
        }
        let mut feats: Vec<(u64, f64)> = Vec::new();
        for tok in toks {
            let (idx_s, val_s) = tok
                .split_once(':')
                .with_context(|| format!("line {line_no}: expected index:value, got {tok:?}"))?;
            let idx: u64 = idx_s.parse().map_err(|_| {
                anyhow::anyhow!("line {line_no}: bad feature index {idx_s:?} in {tok:?}")
            })?;
            // bound indices up front so the d = max+1-base arithmetic and
            // the u32 CSR column type can never overflow/panic downstream
            // (the parser's contract is Err, never panic)
            if idx > u32::MAX as u64 {
                bail!("line {line_no}: feature index {idx} out of supported range (max {})", u32::MAX);
            }
            let val: f64 = val_s.parse().map_err(|_| {
                anyhow::anyhow!("line {line_no}: bad feature value {val_s:?} in {tok:?}")
            })?;
            if !val.is_finite() {
                bail!("line {line_no}: non-finite feature value in {tok:?}");
            }
            feats.push((idx, val));
        }
        // out-of-order indices are fine; duplicates are ambiguous
        feats.sort_unstable_by_key(|f| f.0);
        for w in feats.windows(2) {
            if w[0].0 == w[1].0 {
                bail!("line {line_no}: duplicate feature index {}", w[0].0);
            }
        }
        for &(idx, _) in &feats {
            self.saw_zero_index |= idx == 0;
            self.max_index = self.max_index.max(idx);
            self.any_feature = true;
        }
        self.rows.push((label, feats));
        Ok(())
    }

    fn finish(self, name: &str) -> Result<Dataset> {
        if self.rows.is_empty() {
            bail!("libsvm {name:?}: no data rows");
        }
        // index convention: any 0 => 0-based, else the libsvm-standard 1-based
        let base: u64 = if self.saw_zero_index { 0 } else { 1 };
        // max_index <= u32::MAX (checked per token), so this cannot overflow;
        // a declared dimension widens the inferred one (empty trailing columns
        // have no stored entries to infer from)
        let inferred = if self.any_feature {
            (self.max_index + 1 - base) as usize
        } else {
            0
        };
        let d = inferred.max(self.declared_cols);
        if d == 0 {
            bail!("libsvm {name:?}: no features in any row");
        }
        if d > u32::MAX as usize {
            bail!("libsvm {name:?}: feature dimension {d} out of supported range");
        }
        let n = self.rows.len();
        let mut indptr = Vec::with_capacity(n + 1);
        let mut indices = Vec::with_capacity(self.rows.iter().map(|r| r.1.len()).sum());
        let mut values = Vec::with_capacity(indices.capacity());
        let mut b = Vec::with_capacity(n);
        indptr.push(0);
        for (label, feats) in self.rows {
            for (idx, val) in feats {
                indices.push((idx - base) as u32);
                values.push(val);
            }
            indptr.push(indices.len());
            b.push(label);
        }
        let csr = CsrMat::new(n, d, indptr, indices, values);
        Ok(Dataset::from_csr(name, csr, b, None))
    }

    /// Finish into raw CSR arrays under a FORCED index base and column
    /// count — the chunked loader's reload path. Per-shard auto-detection
    /// can never diverge from the open-time scan this way: the scan decides
    /// base/d once over all shards, and every reload is told the answer. A
    /// shard that contradicts the forced convention (a 0 index under a
    /// 1-based set, an index past the declared dimension) is corruption —
    /// the file changed between scan and reload — and errors out.
    fn finish_forced(self, name: &str, base: u64, cols: usize) -> Result<(CsrMat, Vec<f64>)> {
        if self.rows.is_empty() {
            bail!("libsvm shard {name:?}: no data rows");
        }
        if self.saw_zero_index && base != 0 {
            bail!("libsvm shard {name:?}: 0-based feature index in a 1-based chunk set");
        }
        if self.any_feature && (self.max_index + 1 - base) as usize > cols {
            bail!(
                "libsvm shard {name:?}: feature index {} exceeds declared dimension {cols}",
                self.max_index
            );
        }
        let n = self.rows.len();
        let mut indptr = Vec::with_capacity(n + 1);
        let mut indices = Vec::with_capacity(self.rows.iter().map(|r| r.1.len()).sum());
        let mut values = Vec::with_capacity(indices.capacity());
        let mut b = Vec::with_capacity(n);
        indptr.push(0);
        for (label, feats) in self.rows {
            for (idx, val) in feats {
                indices.push((idx - base) as u32);
                values.push(val);
            }
            indptr.push(indices.len());
            b.push(label);
        }
        Ok((CsrMat::new(n, cols, indptr, indices, values), b))
    }
}

/// Metadata summary of one chunk file — everything the chunked loader's
/// open-time validation pass needs (labels, per-row occupancy for the
/// nnz-balanced shard plan, and the index-convention evidence), without
/// keeping any feature payload resident.
#[derive(Debug)]
pub struct ShardScan {
    /// Labels (the shard's slice of `b`), in row order.
    pub labels: Vec<f64>,
    /// Stored entries per row, in row order.
    pub row_nnz: Vec<usize>,
    /// Whether any feature used index 0 (forces the whole set 0-based).
    pub saw_zero_index: bool,
    /// Largest feature index seen (0 when the shard has no features).
    pub max_index: u64,
    /// The `# hdpw: cols=` declaration, or 0 when the header is absent.
    pub declared_cols: usize,
}

/// Validation-pass scan of one chunk: full parse (every row validated with
/// line-numbered errors, exactly like [`load`]) but only metadata is kept.
pub fn scan_shard(name: &str, reader: impl BufRead) -> Result<ShardScan> {
    let parser = feed_reader(name, reader)?;
    Ok(ShardScan {
        row_nnz: parser.rows.iter().map(|r| r.1.len()).collect(),
        labels: parser.rows.iter().map(|r| r.0).collect(),
        saw_zero_index: parser.saw_zero_index,
        max_index: parser.max_index,
        declared_cols: parser.declared_cols,
    })
}

/// Reload one chunk into its CSR payload + labels under the chunk set's
/// already-decided index base and column count — forcing the convention is
/// what keeps reloads bitwise consistent with the open-time scan (a shard
/// that contradicts it errors as corruption, it is never re-guessed).
pub fn parse_shard(
    name: &str,
    reader: impl BufRead,
    base: u64,
    cols: usize,
) -> Result<(CsrMat, Vec<f64>)> {
    feed_reader(name, reader)?.finish_forced(name, base, cols)
}

/// Stream a reader through the incremental parser with line-numbered,
/// name-contextualized errors (shared by [`scan_shard`]/[`parse_shard`]).
fn feed_reader(name: &str, reader: impl BufRead) -> Result<Parser> {
    let mut parser = Parser::default();
    for (lineno, line) in reader.lines().enumerate() {
        let line =
            line.with_context(|| format!("read libsvm shard {name:?} (line {})", lineno + 1))?;
        parser
            .feed(lineno + 1, &line)
            .with_context(|| format!("parse libsvm shard {name:?}"))?;
    }
    Ok(parser)
}

/// Parse libsvm text into a sparse [`Dataset`] (labels become `b`).
pub fn parse_str(name: &str, text: &str) -> Result<Dataset> {
    let mut parser = Parser::default();
    for (lineno, raw) in text.lines().enumerate() {
        parser.feed(lineno + 1, raw)?;
    }
    parser.finish(name)
}

/// Load a libsvm file from disk, line by line through `BufRead` — the
/// whole file is never resident as one `String` (a multi-GB load holds one
/// line + the CSR arrays under construction). Errors keep line numbers.
pub fn load(path: &Path) -> Result<Dataset> {
    let file = std::fs::File::open(path).with_context(|| format!("read libsvm file {path:?}"))?;
    let reader = std::io::BufReader::new(file);
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "libsvm".into());
    let mut parser = Parser::default();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.with_context(|| {
            format!("read libsvm file {path:?} (line {})", lineno + 1)
        })?;
        parser
            .feed(lineno + 1, &line)
            .with_context(|| format!("parse libsvm file {path:?}"))?;
    }
    parser
        .finish(&name)
        .with_context(|| format!("parse libsvm file {path:?}"))
}

/// Serialize a dataset as libsvm text (1-based indices; shortest-roundtrip
/// float formatting; a `# hdpw: cols=<d>` header pins the column count even
/// when trailing columns hold no stored entries — so `parse(to_text(ds))`
/// reproduces shape and payload bit-for-bit). Dense datasets are written
/// row by row with zeros elided.
pub fn to_text(ds: &Dataset) -> String {
    let mut out = format!("# {COLS_HEADER}{}\n", ds.d());
    for i in 0..ds.n() {
        out.push_str(&ds.b[i].to_string());
        match ds.csr() {
            Some(c) => {
                let (cols, vals) = c.row(i);
                for (cidx, v) in cols.iter().zip(vals) {
                    out.push_str(&format!(" {}:{}", *cidx as u64 + 1, v));
                }
            }
            None => {
                let a = ds.dense_if_ready().expect("dense dataset");
                for (j, v) in a.row(i).iter().enumerate() {
                    if *v != 0.0 {
                        out.push_str(&format!(" {}:{}", j + 1, v));
                    }
                }
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::sparse_gen::{generate_sparse, SparseSpec};
    use crate::util::rng::Rng;

    #[test]
    fn parses_standard_one_based_rows() {
        let ds = parse_str("t", "1.5 1:2.0 3:4.0\n-0.5 2:1.0\n").unwrap();
        assert_eq!((ds.n(), ds.d()), (2, 3));
        assert_eq!(ds.b, vec![1.5, -0.5]);
        let a = ds.dense_clone();
        assert_eq!(a.row(0), &[2.0, 0.0, 4.0]);
        assert_eq!(a.row(1), &[0.0, 1.0, 0.0]);
        assert!(ds.is_sparse());
        assert!(ds.dense_if_ready().is_none(), "parsing must not densify");
        assert_eq!(ds.nnz(), 3);
    }

    #[test]
    fn detects_zero_based_indexing() {
        let ds = parse_str("t", "1 0:7.0 2:8.0\n2 1:9.0\n").unwrap();
        assert_eq!(ds.d(), 3);
        let a = ds.dense_clone();
        assert_eq!(a.row(0), &[7.0, 0.0, 8.0]);
        assert_eq!(a.row(1), &[0.0, 9.0, 0.0]);
    }

    #[test]
    fn out_of_order_indices_are_sorted() {
        let ds = parse_str("t", "1 3:30 1:10 2:20\n").unwrap();
        assert_eq!(ds.dense_clone().row(0), &[10.0, 20.0, 30.0]);
        let (cols, _) = ds.csr().unwrap().row(0);
        assert_eq!(cols, &[0, 1, 2]);
    }

    #[test]
    fn comments_blank_lines_and_empty_rows() {
        let text = "# header comment\n1 1:5 # trailing\n\n2\n3 2:6\n";
        let ds = parse_str("t", text).unwrap();
        assert_eq!(ds.n(), 3, "blank lines skipped, label-only row kept");
        assert_eq!(ds.b, vec![1.0, 2.0, 3.0]);
        assert_eq!(ds.csr().unwrap().row_nnz(1), 0, "empty row");
        assert_eq!(ds.dense_clone().row(2), &[0.0, 6.0]);
    }

    #[test]
    fn malformed_lines_error_with_line_numbers() {
        for (text, needle) in [
            ("abc 1:2\n", "line 1"),                 // bad label
            ("1 x:2\n", "bad feature index"),        // non-numeric index
            ("1 -1:2\n", "bad feature index"),       // negative index
            ("1 1:zz\n", "bad feature value"),       // non-numeric value
            ("1 12\n", "expected index:value"),      // missing colon
            ("1 1:2 1:3\n", "duplicate feature"),    // duplicate index
            ("1 1:nan\n", "non-finite"),             // NaN value
            ("nan 1:2\n", "non-finite"),             // NaN label
            ("", "no data rows"),                    // empty file
            ("1\n2\n", "no features"),               // rows but zero features
            // huge index must Err, never overflow/panic (serve contract)
            ("1 0:1 18446744073709551615:2\n", "out of supported range"),
            ("1 4294967296:2\n", "out of supported range"),
        ] {
            let err = parse_str("t", text).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "{text:?}: {msg}");
        }
        // line numbers point at the offending row
        let err = parse_str("t", "1 1:2\n2 1:oops\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
    }

    #[test]
    fn roundtrip_preserves_payload_bit_for_bit() {
        let mut rng = Rng::new(11);
        let ds = generate_sparse(
            &SparseSpec {
                name: "rt".into(),
                n: 64,
                d: 12,
                density: 0.3,
                kappa: 1e3,
                noise: 0.1,
                signal_scale: 1.0,
            },
            &mut rng,
        );
        let text = to_text(&ds);
        let back = parse_str("rt", &text).unwrap();
        assert_eq!(back.csr(), ds.csr(), "CSR payload must survive the round trip");
        assert_eq!(back.b, ds.b);
    }

    #[test]
    fn dense_dataset_serializes_with_zeros_elided() {
        let a = crate::linalg::Mat::from_vec(2, 3, vec![1.0, 0.0, 2.0, 0.0, 0.0, 3.0]);
        let ds = Dataset::dense("t", a, vec![9.0, 8.0], None);
        let text = to_text(&ds);
        assert_eq!(text, "# hdpw: cols=3\n9 1:1 3:2\n8 3:3\n");
        let back = parse_str("t", &text).unwrap();
        assert_eq!(back.dense_clone(), ds.dense_clone());
        assert_eq!(back.b, ds.b);
    }

    #[test]
    fn roundtrip_preserves_empty_trailing_columns() {
        // last column has no stored entries: inference alone would shrink
        // d; the cols header must pin the true shape
        let a = crate::linalg::Mat::from_vec(2, 4, vec![1.0, 0.0, 0.0, 0.0, 0.0, 2.0, 0.0, 0.0]);
        let ds = Dataset::from_csr("t", CsrMat::from_dense(&a), vec![5.0, 6.0], None);
        let back = parse_str("t", &to_text(&ds)).unwrap();
        assert_eq!(back.d(), 4, "declared dimension survives the round trip");
        assert_eq!(back.csr(), ds.csr());
        // an all-empty-rows dataset round-trips too (header supplies d)
        let hollow = Dataset::from_csr(
            "h",
            CsrMat::new(3, 2, vec![0; 4], vec![], vec![]),
            vec![1.0, 2.0, 3.0],
            None,
        );
        let back2 = parse_str("h", &to_text(&hollow)).unwrap();
        assert_eq!((back2.n(), back2.d()), (3, 2));
        assert_eq!(back2.nnz(), 0);
        // foreign files without the header still infer, and a declared
        // floor never shrinks real data
        let widened = parse_str("t", "# hdpw: cols=2\n1 5:9\n").unwrap();
        assert_eq!(widened.d(), 5);
        // malformed declaration errors cleanly
        assert!(parse_str("t", "# hdpw: cols=abc\n1 1:2\n").is_err());
    }

    #[test]
    fn streamed_load_matches_parse_str_with_line_errors() {
        let dir = std::env::temp_dir().join(format!("hdpw_libsvm_rt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ok.svm");
        let text = "# hdpw: cols=4\n1.5 1:2 4:-3.25\n-1 2:0.5\n2\n";
        std::fs::write(&path, text).unwrap();
        let streamed = load(&path).unwrap();
        let in_memory = parse_str("ok", text).unwrap();
        assert_eq!(streamed.csr(), in_memory.csr(), "BufRead path must parse identically");
        assert_eq!(streamed.b, in_memory.b);
        assert_eq!(streamed.name, "ok");
        // malformed content keeps the line number through the streaming path
        let bad = dir.join("bad.svm");
        std::fs::write(&bad, "1 1:2\n2 1:oops\n").unwrap();
        let err = load(&bad).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("bad.svm"), "{msg}");
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn shard_scan_reports_metadata_and_shard_parse_honors_forced_convention() {
        let text = "# hdpw: cols=6\n1.5 1:2 4:-3.25\n-1 2:0.5\n2\n";
        let scan = scan_shard("s0", text.as_bytes()).unwrap();
        assert_eq!(scan.labels, vec![1.5, -1.0, 2.0]);
        assert_eq!(scan.row_nnz, vec![2, 1, 0]);
        assert!(!scan.saw_zero_index);
        assert_eq!(scan.max_index, 4);
        assert_eq!(scan.declared_cols, 6);
        // forced parse under the detected convention (1-based, 6 cols)
        let (csr, b) = parse_shard("s0", text.as_bytes(), 1, 6).unwrap();
        assert_eq!((csr.rows, csr.cols), (3, 6));
        assert_eq!(b, scan.labels);
        assert_eq!(csr.row(0), (&[0u32, 3][..], &[2.0, -3.25][..]));
        // a WIDER forced dimension is fine (another shard widened d)
        let (wide, _) = parse_shard("s0", text.as_bytes(), 1, 9).unwrap();
        assert_eq!(wide.cols, 9);
        // forcing base 0 shifts columns (the set saw a zero index elsewhere)
        let (zb, _) = parse_shard("s0", text.as_bytes(), 0, 6).unwrap();
        assert_eq!(zb.row(0).0, &[1, 4]);
        // contradiction = corruption: 0 index under a 1-based set
        let err = parse_shard("sz", "1 0:7\n".as_bytes(), 1, 4).unwrap_err();
        assert!(format!("{err:#}").contains("0-based"), "{err:#}");
        // index past the declared dimension
        let err = parse_shard("sd", "1 9:7\n".as_bytes(), 1, 4).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds declared dimension"), "{err:#}");
        // empty shard
        assert!(parse_shard("se", "".as_bytes(), 1, 4).is_err());
        // missing header is visible to the caller (short-header fault class)
        let bare = scan_shard("sb", "1 1:2\n".as_bytes()).unwrap();
        assert_eq!(bare.declared_cols, 0);
        // malformed rows keep line numbers + shard name through the scan
        let err = scan_shard("sm", "1 1:2\n2 1:oops\n".as_bytes()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2") && msg.contains("sm"), "{msg}");
    }

    #[test]
    fn load_surfaces_missing_file_as_error() {
        let err = load(Path::new("/nonexistent/definitely_missing.svm")).unwrap_err();
        assert!(format!("{err:#}").contains("libsvm"), "{err:#}");
    }
}
