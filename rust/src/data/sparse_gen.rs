//! Seeded sparse synthetic generator — density + spectrum controls.
//!
//! The dense generator ([`super::synthetic`]) realizes an *exact* condition
//! number via `A = Q diag(sigma) V^T`, but that construction is inherently
//! dense. The sparse analog controls conditioning through the same
//! log-spaced column scales applied to sparse gaussian rows: each row draws
//! `max(1, round(density * d))` distinct columns with `N(0,1) * sigma_j`
//! values, which keeps nnz exactly budgeted and puts the singular-value
//! spread in the `~kappa` regime (approximately — the random sparsity
//! pattern perturbs the extremes, which is what real sparse data does too).
//!
//! Full column rank is guaranteed deterministically: row `i < d` always
//! contains column `i`, so QR ground truth and the preconditioner are well
//! defined at any density.

use super::synthetic::{log_spaced_spectrum, SynSpec};
use super::Dataset;
use crate::linalg::CsrMat;
use crate::util::rng::Rng;

/// Default nnz fraction for generated sparse variants (`--density 0` /
/// unset): d/10 entries per row, at least one.
pub const DEFAULT_DENSITY: f64 = 0.1;

/// Parameters for a sparse synthetic instance.
#[derive(Clone, Debug)]
pub struct SparseSpec {
    /// Dataset name carried into the generated [`Dataset`].
    pub name: String,
    /// Number of rows (samples).
    pub n: usize,
    /// Number of columns (features).
    pub d: usize,
    /// Target nnz fraction; each row stores `max(1, round(density * d))`
    /// entries, so the realized density is `that / d`.
    pub density: f64,
    /// Column-scale spread: column j is scaled by the log-spaced spectrum
    /// 1 .. 1/kappa, driving the conditioning the preconditioner must fix.
    pub kappa: f64,
    /// std-dev of the gaussian noise e in b = A x* + e.
    pub noise: f64,
    /// Scale of the planted solution (see [`SynSpec::signal_auto`]).
    pub signal_scale: f64,
}

/// Generate a sparse dataset: CSR payload + planted x* (no dense mirror —
/// a dense view is a budget-accounted capability request, DESIGN.md §11).
pub fn generate_sparse(spec: &SparseSpec, rng: &mut Rng) -> Dataset {
    let (n, d) = (spec.n, spec.d);
    assert!(n > d && d >= 2, "need n > d >= 2");
    assert!(spec.density > 0.0 && spec.density <= 1.0);
    assert!(spec.kappa >= 1.0);
    let nnz_row = ((spec.density * d as f64).round() as usize).clamp(1, d);
    let sigmas = log_spaced_spectrum(d, spec.kappa);
    let mut indptr = Vec::with_capacity(n + 1);
    let mut indices: Vec<u32> = Vec::with_capacity(n * nnz_row);
    let mut values: Vec<f64> = Vec::with_capacity(n * nnz_row);
    indptr.push(0);
    // distinct columns via partial Fisher-Yates over a persistent deck:
    // O(nnz_row) per row at ANY density (rejection sampling degrades to
    // coupon-collector cost as density -> 1). Exactly nnz_row draws per
    // row keeps generation deterministic.
    let mut deck: Vec<u32> = (0..d as u32).collect();
    let mut scratch: Vec<u32> = Vec::with_capacity(nnz_row);
    for i in 0..n {
        for t in 0..nnz_row {
            let j = t + rng.below(d - t);
            deck.swap(t, j);
        }
        scratch.clear();
        scratch.extend_from_slice(&deck[..nnz_row]);
        // rank guarantee: the first d rows each cover their own column
        if i < d && !scratch.contains(&(i as u32)) {
            scratch[0] = i as u32;
        }
        scratch.sort_unstable();
        for &c in &scratch {
            indices.push(c);
            values.push(rng.gaussian() * sigmas[c as usize]);
        }
        indptr.push(indices.len());
    }
    let csr = CsrMat::new(n, d, indptr, indices, values);
    let x_star: Vec<f64> = rng
        .gaussians(d)
        .into_iter()
        .map(|v| v * spec.signal_scale)
        .collect();
    let mut b = Vec::with_capacity(n);
    for i in 0..n {
        b.push(csr.row_dot(i, &x_star) + spec.noise * rng.gaussian());
    }
    Dataset::from_csr(spec.name.clone(), csr, b, Some(x_star))
}

/// Sparse variant of a built-in named dataset (`--format sparse|libsvm`):
/// same d and conditioning regime as the dense generator, at the requested
/// density. Returns None for unknown names (same contract as
/// [`super::uci_sim::by_name`]).
pub fn named_sparse(name: &str, n: usize, density: f64, rng: &mut Rng) -> Option<Dataset> {
    let (d, kappa) = match name {
        "syn1" => (20, 1e8),
        "syn2" => (20, 1e3),
        "year" => (90, 3e3),
        "buzz" => (77, 1e6),
        "pjrt8k" => (32, 1e6),
        _ => return None,
    };
    Some(generate_sparse(
        &SparseSpec {
            name: name.into(),
            n,
            d,
            density: if density > 0.0 { density } else { DEFAULT_DENSITY },
            kappa,
            noise: 0.1,
            signal_scale: SynSpec::signal_auto(n),
        },
        rng,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigen;
    use crate::solvers::exact::ground_truth;

    fn spec(n: usize, d: usize, density: f64, kappa: f64) -> SparseSpec {
        SparseSpec {
            name: "t".into(),
            n,
            d,
            density,
            kappa,
            noise: 0.05,
            signal_scale: 1.0,
        }
    }

    #[test]
    fn density_and_shape_budgeted_exactly() {
        let mut rng = Rng::new(1);
        let ds = generate_sparse(&spec(400, 20, 0.1, 1e3), &mut rng);
        assert_eq!((ds.n(), ds.d()), (400, 20));
        assert!(ds.is_sparse());
        // 0.1 * 20 = 2 entries per row exactly
        assert_eq!(ds.nnz(), 400 * 2);
        assert!((ds.density() - 0.1).abs() < 1e-12);
        let csr = ds.csr().unwrap();
        for i in 0..ds.n() {
            assert_eq!(csr.row_nnz(i), 2, "row {i}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let s = spec(200, 12, 0.25, 1e4);
        let d1 = generate_sparse(&s, &mut Rng::new(7));
        let d2 = generate_sparse(&s, &mut Rng::new(7));
        assert_eq!(d1.csr(), d2.csr());
        assert_eq!(d1.b, d2.b);
    }

    #[test]
    fn full_column_rank_at_minimal_density() {
        // 1 entry per row — the degenerate regime where random columns alone
        // would likely miss some column entirely
        let mut rng = Rng::new(2);
        let ds = generate_sparse(&spec(300, 20, 0.01, 1e3), &mut rng);
        assert_eq!(ds.nnz(), 300); // max(1, round(0.01*20)) = 1
        let gt = ground_truth(&ds);
        assert!(gt.f_star.is_finite() && gt.f_star >= 0.0);
        assert!(gt.x_star.iter().all(|v| v.is_finite()));
        // every column is covered (rows 0..d guarantee it)
        let csr = ds.csr().unwrap();
        let mut seen = vec![false; 20];
        for &c in &csr.indices {
            seen[c as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "column coverage");
    }

    #[test]
    fn kappa_controls_conditioning() {
        let mut rng = Rng::new(3);
        let tame = generate_sparse(&spec(600, 10, 0.5, 1.0), &mut rng);
        let harsh = generate_sparse(&spec(600, 10, 0.5, 1e6), &mut rng);
        let k_tame = eigen::cond(&tame.dense_clone());
        let k_harsh = eigen::cond(&harsh.dense_clone());
        assert!(k_tame < 100.0, "kappa=1 generated cond {k_tame}");
        assert!(
            k_harsh > 1e3 * k_tame,
            "kappa=1e6 cond {k_harsh} vs kappa=1 cond {k_tame}"
        );
    }

    #[test]
    fn named_variants_match_dense_shapes() {
        let mut rng = Rng::new(4);
        let ds = named_sparse("syn2", 256, 0.0, &mut rng).unwrap();
        assert_eq!(ds.d(), 20);
        assert!((ds.density() - DEFAULT_DENSITY).abs() < 0.05);
        assert!(named_sparse("year", 256, 0.2, &mut Rng::new(5)).unwrap().d() == 90);
        assert!(named_sparse("mystery", 256, 0.1, &mut Rng::new(6)).is_none());
    }

    #[test]
    fn planted_solution_nearly_fits() {
        let mut rng = Rng::new(5);
        let ds = generate_sparse(&spec(500, 10, 0.4, 10.0), &mut rng);
        let xs = ds.x_star_planted.clone().unwrap();
        let f_star = ds.objective(&xs);
        let expect = 0.05 * 0.05 * 500.0;
        assert!(f_star < 4.0 * expect, "f* {f_star} vs {expect}");
    }
}
