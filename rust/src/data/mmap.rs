//! The `mmapdense` on-disk format: a dense row-major design + response in
//! one binary file, read shard-by-shard so the full matrix is never
//! resident.
//!
//! Layout (all little-endian):
//!
//! ```text
//! magic  8 B   b"HDPWOD01"
//! rows   8 B   u64
//! cols   8 B   u64
//! A      rows * cols * 8 B   f64, row-major
//! b      rows * 8 B          f64
//! ```
//!
//! The total file length is validated at open, so a truncated payload is a
//! structured error before any solver runs. Despite the format's name
//! (kept aligned with the `dataset: "mmapdense:<path>"` request syntax),
//! access goes through positioned reads (`FileExt::read_exact_at`), not a
//! real `mmap(2)`: a page fault on a truncated or yanked mapping raises
//! SIGBUS, which no worker can turn into a structured job error, while a
//! failed `pread` is an ordinary `io::Error` that flows up the fallible
//! shard-load path. Positioned reads also need no `&mut self`, so shard
//! loads from concurrent workers share one `File`.
//!
//! Every shard read re-checks finiteness: a corrupt payload (NaN/Inf bytes)
//! surfaces as an error naming the row, never as a silently poisoned solve.

use crate::linalg::Mat;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::Write;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

/// Magic bytes identifying an hdpw on-disk dense file (version 01).
pub const MAGIC: [u8; 8] = *b"HDPWOD01";

/// Header length: magic + rows + cols.
const HEADER: u64 = 24;

/// An opened `mmapdense` file: validated header + shared read handle. The
/// matrix payload stays on disk; only `b` (n doubles, the same footprint
/// the in-memory [`crate::data::Dataset`] keeps untracked) is eager.
#[derive(Debug)]
pub struct MmapDense {
    file: File,
    path: PathBuf,
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
}

impl MmapDense {
    /// Open and validate: magic, sane dimensions, exact file length.
    pub fn open(path: &Path) -> Result<MmapDense> {
        let file =
            File::open(path).with_context(|| format!("open mmapdense file {path:?}"))?;
        let mut head = [0u8; HEADER as usize];
        file.read_exact_at(&mut head, 0)
            .with_context(|| format!("read mmapdense header of {path:?}"))?;
        if head[..8] != MAGIC {
            bail!("mmapdense file {path:?}: bad magic (not an HDPWOD01 file)");
        }
        let rows = u64::from_le_bytes(head[8..16].try_into().unwrap());
        let cols = u64::from_le_bytes(head[16..24].try_into().unwrap());
        if rows == 0 || cols == 0 {
            bail!("mmapdense file {path:?}: empty shape {rows}x{cols}");
        }
        let want = HEADER
            .checked_add(rows.checked_mul(cols).and_then(|c| c.checked_mul(8)).unwrap_or(u64::MAX))
            .and_then(|v| v.checked_add(rows * 8));
        let len = file
            .metadata()
            .with_context(|| format!("stat mmapdense file {path:?}"))?
            .len();
        match want {
            Some(w) if w == len => {}
            _ => bail!(
                "mmapdense file {path:?}: truncated or oversized ({len} B on disk, \
                 {rows}x{cols} header implies {} B)",
                want.map(|w| w.to_string()).unwrap_or_else(|| "overflowing".into())
            ),
        }
        Ok(MmapDense {
            file,
            path: path.to_path_buf(),
            rows: rows as usize,
            cols: cols as usize,
        })
    }

    /// Read rows `[start, start + rows)` of `A` into a fresh [`Mat`],
    /// validating finiteness (a NaN/Inf names the offending global row).
    pub fn read_rows(&self, start: usize, rows: usize) -> Result<Mat> {
        assert!(start + rows <= self.rows, "shard out of range");
        let mut bytes = vec![0u8; rows * self.cols * 8];
        let off = HEADER + (start * self.cols * 8) as u64;
        self.file
            .read_exact_at(&mut bytes, off)
            .with_context(|| format!("read rows {start}..{} of {:?}", start + rows, self.path))?;
        let data = decode_f64s(&bytes);
        for (k, v) in data.iter().enumerate() {
            if !v.is_finite() {
                bail!(
                    "mmapdense file {:?}: non-finite payload at row {} col {}",
                    self.path,
                    start + k / self.cols,
                    k % self.cols
                );
            }
        }
        Ok(Mat::from_vec(rows, self.cols, data))
    }

    /// Read the full response vector `b` (the tail of the file).
    pub fn read_b(&self) -> Result<Vec<f64>> {
        let mut bytes = vec![0u8; self.rows * 8];
        let off = HEADER + (self.rows * self.cols * 8) as u64;
        self.file
            .read_exact_at(&mut bytes, off)
            .with_context(|| format!("read response vector of {:?}", self.path))?;
        let b = decode_f64s(&bytes);
        for (i, v) in b.iter().enumerate() {
            if !v.is_finite() {
                bail!("mmapdense file {:?}: non-finite response at row {i}", self.path);
            }
        }
        Ok(b)
    }

    /// The file path (error labels, cache keys).
    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Write a dense dataset to `path` in the `mmapdense` format — the writer
/// the synthetic generators, the CLI and the tests share.
pub fn write(path: &Path, a: &Mat, b: &[f64]) -> Result<()> {
    assert_eq!(a.rows, b.len());
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("create directory for mmapdense file {path:?}"))?;
    }
    let mut f = std::io::BufWriter::new(
        File::create(path).with_context(|| format!("create mmapdense file {path:?}"))?,
    );
    let ctx = || format!("write mmapdense file {path:?}");
    f.write_all(&MAGIC).with_context(ctx)?;
    f.write_all(&(a.rows as u64).to_le_bytes()).with_context(ctx)?;
    f.write_all(&(a.cols as u64).to_le_bytes()).with_context(ctx)?;
    for v in &a.data {
        f.write_all(&v.to_le_bytes()).with_context(ctx)?;
    }
    for v in b {
        f.write_all(&v.to_le_bytes()).with_context(ctx)?;
    }
    f.flush().with_context(ctx)?;
    Ok(())
}

fn decode_f64s(bytes: &[u8]) -> Vec<f64> {
    bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("hdpw_mmap_{}_{name}", std::process::id()))
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let mut rng = Rng::new(1);
        let a = Mat::gaussian(37, 5, &mut rng);
        let b = rng.gaussians(37);
        let path = tmp("rt.bin");
        write(&path, &a, &b).unwrap();
        let od = MmapDense::open(&path).unwrap();
        assert_eq!((od.rows, od.cols), (37, 5));
        // whole read, partial reads, and the tail all round-trip bitwise
        assert_eq!(od.read_rows(0, 37).unwrap(), a);
        let mid = od.read_rows(10, 7).unwrap();
        for k in 0..7 {
            assert_eq!(mid.row(k), a.row(10 + k));
        }
        assert_eq!(od.read_b().unwrap(), b);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn corrupt_files_error_structurally() {
        let mut rng = Rng::new(2);
        let a = Mat::gaussian(8, 3, &mut rng);
        let b = rng.gaussians(8);
        // bad magic
        let p1 = tmp("magic.bin");
        write(&p1, &a, &b).unwrap();
        let mut raw = std::fs::read(&p1).unwrap();
        raw[0] = b'X';
        std::fs::write(&p1, &raw).unwrap();
        let err = MmapDense::open(&p1).unwrap_err();
        assert!(format!("{err:#}").contains("bad magic"), "{err:#}");
        // truncated payload
        let p2 = tmp("trunc.bin");
        write(&p2, &a, &b).unwrap();
        let raw = std::fs::read(&p2).unwrap();
        std::fs::write(&p2, &raw[..raw.len() - 9]).unwrap();
        let err = MmapDense::open(&p2).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
        // non-finite payload entry (row 1, col 2 of the 3-wide matrix)
        let p3 = tmp("nan.bin");
        let mut poisoned = a.clone();
        *poisoned.at_mut(1, 2) = f64::NAN;
        write(&p3, &poisoned, &b).unwrap();
        let od = MmapDense::open(&p3).unwrap();
        let err = od.read_rows(0, 4).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("non-finite") && msg.contains("row 1"), "{msg}");
        // ...but a shard that avoids the poisoned row reads fine
        assert!(od.read_rows(2, 2).is_ok());
        // non-finite response
        let p4 = tmp("nanb.bin");
        let mut bb = b.clone();
        bb[3] = f64::INFINITY;
        write(&p4, &a, &bb).unwrap();
        let err = MmapDense::open(&p4).unwrap().read_b().unwrap_err();
        assert!(format!("{err:#}").contains("non-finite response"), "{err:#}");
        // missing file
        assert!(MmapDense::open(Path::new("/nonexistent/x.bin")).is_err());
        for p in [p1, p2, p3, p4] {
            std::fs::remove_file(p).unwrap();
        }
    }
}
