//! Figures 3-6: the (simulated) UCI datasets.
//!
//! * Fig 3 — Year, high precision: unconstrained / l1 / l2.
//! * Fig 4 — Buzz, unconstrained: low- and high-precision panels.
//! * Fig 5 — Buzz, high precision: l1 / l2.
//! * Fig 6 — Buzz, low precision: l1 / l2.
//!
//! All reuse the solver lineups of [`super::fig2`]; only the dataset and
//! constraint grids differ, exactly as in the paper.

use super::fig2::{high_precision_lineup, low_precision_lineup};
use super::ExpCtx;
use crate::util::plot::Figure;

fn one_panel(
    ctx: &ExpCtx,
    dataset: &str,
    constraint: &str,
    high: bool,
) -> anyhow::Result<Figure> {
    let precision = if high { "high" } else { "low" };
    let mut fig = Figure::new(
        format!("{dataset} ({constraint}): {precision}-precision solvers"),
        "seconds",
        "relative error",
        true,
    );
    let lineup = if high {
        high_precision_lineup(ctx, dataset, constraint)
    } else {
        low_precision_lineup(ctx, dataset, constraint)
    };
    for (label, req) in lineup {
        let (_, by_time, _) = ctx.run_series(&req, &label)?;
        fig.add(by_time);
    }
    Ok(fig)
}

/// Fig 3: Year high precision — unc, l1, l2.
pub fn fig3(ctx: &ExpCtx) -> anyhow::Result<Vec<Figure>> {
    ["unc", "l1", "l2"]
        .iter()
        .map(|c| one_panel(ctx, "year", c, true))
        .collect()
}

/// Fig 4: Buzz unconstrained — low + high panels.
pub fn fig4(ctx: &ExpCtx) -> anyhow::Result<Vec<Figure>> {
    Ok(vec![
        one_panel(ctx, "buzz", "unc", false)?,
        one_panel(ctx, "buzz", "unc", true)?,
    ])
}

/// Fig 5: Buzz high precision — l1, l2.
pub fn fig5(ctx: &ExpCtx) -> anyhow::Result<Vec<Figure>> {
    ["l1", "l2"]
        .iter()
        .map(|c| one_panel(ctx, "buzz", c, true))
        .collect()
}

/// Fig 6: Buzz low precision — l1, l2.
pub fn fig6(ctx: &ExpCtx) -> anyhow::Result<Vec<Figure>> {
    ["l1", "l2"]
        .iter()
        .map(|c| one_panel(ctx, "buzz", c, false))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn year_high_precision_panel_tiny() {
        let mut ctx = ExpCtx::new(true);
        ctx.n = 2048;
        ctx.trials = 1;
        ctx.budget = 20.0;
        let fig = one_panel(&ctx, "year", "unc", true).unwrap();
        assert_eq!(fig.series.len(), 4);
        // pwGradient should get furthest down
        let floor = |s: &crate::util::plot::Series| {
            s.ys.iter().cloned().fold(f64::INFINITY, f64::min)
        };
        let pw = floor(&fig.series[0]);
        assert!(pw < 1e-7, "pwGradient floor on year-sim: {pw}");
    }
}
