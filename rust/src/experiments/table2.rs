//! Table 2: cost of computing the preconditioner R per sketch construction,
//! plus the achieved kappa(A R^{-1}).
//!
//! The paper lists Gaussian / SRHT / CountSketch / Sparse-l2 with their
//! asymptotic costs and kappa = O(1); we measure wall time (sketch + QR)
//! and the actual condition number on a Syn-style matrix.

use super::ExpCtx;
use crate::data::uci_sim;
use crate::linalg::{blas, eigen};
use crate::precond::precondition;
use crate::sketch::{default_sketch_size, SketchKind};
use crate::util::rng::Rng;

/// One sketch family's measured preconditioner cost and quality.
pub struct Table2Row {
    /// sketch family name (gaussian / srht / countsketch / sparse-l2)
    pub sketch: &'static str,
    /// best-of-trials wall time to apply S*A
    pub sketch_secs: f64,
    /// best-of-trials wall time for the QR of the sketch
    pub qr_secs: f64,
    /// achieved kappa(A R^{-1})
    pub kappa_preconditioned: f64,
}

/// All of Table 2: the testbed description plus one row per sketch family.
pub struct Table2Output {
    /// dataset name the preconditioners were measured on
    pub dataset: String,
    /// dataset rows
    pub n: usize,
    /// dataset columns
    pub d: usize,
    /// condition number of the raw (unpreconditioned) matrix
    pub kappa_raw: f64,
    /// sketch row count s used for every family
    pub sketch_rows: usize,
    /// one measured row per sketch family
    pub rows: Vec<Table2Row>,
}

/// Measure sketch + QR cost and achieved kappa for each sketch family.
pub fn run(ctx: &ExpCtx) -> anyhow::Result<Table2Output> {
    let mut rng = Rng::new(ctx.seed);
    let ds = uci_sim::by_name("syn1", ctx.n, &mut rng).expect("syn1");
    let a = ds.dense_if_ready().expect("dense generator output");
    let gram = blas::gram(a);
    let kappa_raw = {
        let evs = eigen::sym_eigenvalues(&gram);
        let lmin = evs.first().copied().unwrap_or(0.0).max(1e-300);
        (evs.last().copied().unwrap_or(0.0) / lmin).sqrt()
    };
    let s = default_sketch_size(ds.n(), ds.d());
    let mut rows = Vec::new();
    for kind in [
        SketchKind::Gaussian,
        SketchKind::Srht,
        SketchKind::CountSketch,
        SketchKind::SparseEmbed,
    ] {
        // best of `trials` runs (timing stability), kappa from the last
        let mut best_sketch = f64::INFINITY;
        let mut best_qr = f64::INFINITY;
        let mut kappa = f64::INFINITY;
        for _ in 0..ctx.trials.max(1) {
            let pre = precondition(a, kind, s, &mut rng);
            best_sketch = best_sketch.min(pre.sketch_secs);
            best_qr = best_qr.min(pre.qr_secs);
            kappa = eigen::cond_preconditioned(&gram, &pre.r);
        }
        rows.push(Table2Row {
            sketch: kind.name(),
            sketch_secs: best_sketch,
            qr_secs: best_qr,
            kappa_preconditioned: kappa,
        });
    }
    Ok(Table2Output {
        dataset: ds.name.clone(),
        n: ds.n(),
        d: ds.d(),
        kappa_raw,
        sketch_rows: s,
        rows,
    })
}

/// Render the measured rows as the ASCII Table 2.
pub fn render(out: &Table2Output) -> String {
    let mut s = format!(
        "Table 2: preconditioner cost on {} (n={}, d={}, kappa(A)={:.2e}, s={})\n",
        out.dataset, out.n, out.d, out.kappa_raw, out.sketch_rows
    );
    s.push_str(&format!(
        "{:<14} {:>12} {:>12} {:>12} {:>16}\n",
        "sketch", "S*A time", "QR time", "total", "kappa(AR^-1)"
    ));
    for row in &out.rows {
        s.push_str(&format!(
            "{:<14} {:>12} {:>12} {:>12} {:>16.4}\n",
            row.sketch,
            crate::util::stats::fmt_duration(row.sketch_secs),
            crate::util::stats::fmt_duration(row.qr_secs),
            crate::util::stats::fmt_duration(row.sketch_secs + row.qr_secs),
            row.kappa_preconditioned,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_sketches_achieve_o1_kappa_on_syn1() {
        let mut ctx = ExpCtx::new(true);
        ctx.n = 4096;
        ctx.trials = 1;
        let out = run(&ctx).unwrap();
        assert_eq!(out.rows.len(), 4);
        assert!(out.kappa_raw > 1e6, "syn1 should be ill-conditioned");
        for row in &out.rows {
            assert!(
                row.kappa_preconditioned < 5.0,
                "{}: kappa {}",
                row.sketch,
                row.kappa_preconditioned
            );
        }
        // countsketch must beat gaussian on sketch time (O(nnz) vs O(nd^2))
        let t = |name: &str| {
            out.rows
                .iter()
                .find(|r| r.sketch == name)
                .map(|r| r.sketch_secs)
                .unwrap()
        };
        assert!(
            t("countsketch") < t("gaussian"),
            "countsketch {:.4}s vs gaussian {:.4}s",
            t("countsketch"),
            t("gaussian")
        );
        let rendered = render(&out);
        assert!(rendered.contains("srht"));
    }
}
