//! Experiment drivers: one module per table/figure of the paper.
//!
//! Each driver builds its workload, runs the paper's protocol (best-of-k
//! trials through the [`Coordinator`]), and returns [`Figure`]s /
//! formatted tables. The `rust/benches/*` binaries are thin wrappers that
//! call these drivers, print the ASCII rendering and save the CSV series
//! under `out/`.

pub mod fig1;
pub mod fig2;
pub mod figs_real;
pub mod table1;
pub mod table2;

use crate::backend::Backend;
use crate::coordinator::{Coordinator, CoordinatorConfig, JobRequest};
use crate::util::plot::{Figure, Series};
use std::path::PathBuf;
use std::sync::Arc;

/// Shared experiment context.
pub struct ExpCtx {
    /// coordinator every driver submits its jobs through (1 worker: figures
    /// time solvers, so no co-tenancy)
    pub coord: Arc<Coordinator>,
    /// directory CSV series are saved under (default `out/`)
    pub out_dir: PathBuf,
    /// row count for generated datasets (quick mode shrinks this)
    pub n: usize,
    /// best-of-k trials per job, per the paper's protocol
    pub trials: usize,
    /// base RNG seed threaded into every job request
    pub seed: u64,
    /// time budget per solver run (seconds)
    pub budget: f64,
}

impl ExpCtx {
    /// Standard context: PJRT backend when artifacts exist, else native.
    /// `quick` shrinks workloads for CI-speed runs.
    pub fn new(quick: bool) -> ExpCtx {
        let backend = Backend::auto();
        let coord = Arc::new(Coordinator::new(
            backend,
            CoordinatorConfig {
                workers: 1, // figures time solvers: no co-tenancy
                max_queue: 4,
                ..CoordinatorConfig::default()
            },
        ));
        ExpCtx {
            coord,
            out_dir: PathBuf::from("out"),
            n: if quick { 8_192 } else { 65_536 },
            trials: if quick { 3 } else { 10 },
            seed: 20180201, // AAAI-18
            budget: if quick { 10.0 } else { 60.0 },
        }
    }

    /// Base job for a dataset/solver pair.
    pub fn job(&self, dataset: &str, solver: &str) -> JobRequest {
        let mut req = JobRequest::default();
        // the paper protocol normalizes datasets in-process, and normalize
        // is rejected for on-disk representations; when the session default
        // format (HDPW_FORMAT) is an on-disk one, run the experiments on the
        // resident representation instead
        if matches!(req.format.as_str(), "mmapdense" | "libsvm-chunked") {
            req.format = String::new();
        }
        req.dataset = dataset.into();
        req.n = self.n;
        req.solver = solver.into();
        req.trials = self.trials;
        req.seed = self.seed;
        req.time_budget = self.budget;
        req
    }

    /// Run a job and convert its best trace into two figure series:
    /// (relative error vs iterations, relative error vs seconds).
    pub fn run_series(
        &self,
        req: &JobRequest,
        label: &str,
    ) -> anyhow::Result<(Series, Series, f64)> {
        let res = self.coord.run_job(req)?;
        let mut by_iter = Series::new(label);
        let mut by_time = Series::new(label);
        for (it, secs, rel) in res.best.rel_errors(res.f_star) {
            let clamped = rel.max(1e-16);
            by_iter.push(it, clamped);
            by_time.push(secs, clamped);
        }
        Ok((by_iter, by_time, res.f_star))
    }

    /// Save a figure's CSV series under [`ExpCtx::out_dir`] and return its
    /// ASCII rendering (save errors are ignored: rendering still works when
    /// the output directory is not writable).
    pub fn save_and_render(&self, fig: &Figure, stem: &str) -> String {
        let _ = fig.save_csv(&self.out_dir, stem);
        fig.ascii(72, 18)
    }
}

/// Format a markdown-style table row.
pub fn table_row(cells: &[String], widths: &[usize]) -> String {
    let mut s = String::from("|");
    for (c, w) in cells.iter().zip(widths) {
        s.push_str(&format!(" {c:<w$} |"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_quick_builds_and_runs_tiny_job() {
        let mut ctx = ExpCtx::new(true);
        ctx.n = 1024;
        ctx.trials = 1;
        let mut req = ctx.job("syn2", "exact");
        req.max_iters = 5;
        let (si, st, fstar) = ctx.run_series(&req, "exact").unwrap();
        assert!(fstar > 0.0);
        assert!(!si.is_empty());
        assert_eq!(si.len(), st.len());
    }

    #[test]
    fn table_row_formats() {
        let row = table_row(&["a".into(), "bb".into()], &[4, 6]);
        assert_eq!(row, "| a    | bb     |");
    }
}
