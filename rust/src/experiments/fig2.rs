//! Figure 2: Syn1 (unconstrained), low- and high-precision solver races.
//!
//! Left panel: relative error vs wall-clock for the low-precision solvers
//! (HDpwBatchSGD at several batch sizes, pwSGD, SGD, Adagrad) on the
//! normalized dataset.
//! Right panel: log relative error vs wall-clock for the high-precision
//! solvers (pwGradient, IHS, pwSVRG at two batch sizes).

use super::ExpCtx;
use crate::coordinator::JobRequest;
use crate::util::plot::Figure;

/// The two panels of a solver race: one figure per precision regime.
pub struct RacePanels {
    /// low-precision panel (relative error vs wall-clock)
    pub low: Figure,
    /// high-precision panel (log relative error vs wall-clock)
    pub high: Figure,
}

/// The standard low-precision lineup (paper Figures 2/4/6).
pub fn low_precision_lineup(ctx: &ExpCtx, dataset: &str, constraint: &str) -> Vec<(String, JobRequest)> {
    let mut jobs = Vec::new();
    for r in [64usize, 256] {
        let mut req = ctx.job(dataset, "hdpwbatchsgd");
        req.batch_size = r;
        req.constraint = constraint.into();
        req.normalize = true;
        req.max_iters = 50_000;
        jobs.push((format!("HDpwBatchSGD r={r}"), req));
    }
    let mut req = ctx.job(dataset, "pwsgd");
    req.batch_size = 1;
    req.constraint = constraint.into();
    req.normalize = true;
    req.max_iters = 50_000;
    jobs.push(("pwSGD".into(), req));
    for solver in ["sgd", "adagrad"] {
        let mut req = ctx.job(dataset, solver);
        req.batch_size = 64;
        req.constraint = constraint.into();
        req.normalize = true;
        req.max_iters = 50_000;
        jobs.push((solver.to_uppercase(), req));
    }
    jobs
}

/// The standard high-precision lineup (paper Figures 2/3/4/5).
pub fn high_precision_lineup(ctx: &ExpCtx, dataset: &str, constraint: &str) -> Vec<(String, JobRequest)> {
    let mut jobs = Vec::new();
    let mut req = ctx.job(dataset, "pwgradient");
    req.constraint = constraint.into();
    req.max_iters = 400;
    req.target_rel_err = 1e-12;
    jobs.push(("pwGradient".into(), req));
    let mut req = ctx.job(dataset, "ihs");
    req.constraint = constraint.into();
    req.max_iters = 400;
    req.target_rel_err = 1e-12;
    jobs.push(("IHS".into(), req));
    for r in [16usize, 256] {
        let mut req = ctx.job(dataset, "pwsvrg");
        req.batch_size = r;
        req.constraint = constraint.into();
        req.max_iters = 60_000;
        req.target_rel_err = 1e-12;
        jobs.push((format!("pwSVRG r={r}"), req));
    }
    jobs
}

/// Run both panels for one dataset/constraint (Figure 2 = syn1/"unc").
pub fn run_panels(ctx: &ExpCtx, dataset: &str, constraint: &str) -> anyhow::Result<RacePanels> {
    let mut low = Figure::new(
        format!("{dataset} ({constraint}): low-precision solvers"),
        "seconds",
        "relative error",
        true,
    );
    for (label, req) in low_precision_lineup(ctx, dataset, constraint) {
        let (_, by_time, _) = ctx.run_series(&req, &label)?;
        low.add(by_time);
    }
    let mut high = Figure::new(
        format!("{dataset} ({constraint}): high-precision solvers"),
        "seconds",
        "relative error",
        true,
    );
    for (label, req) in high_precision_lineup(ctx, dataset, constraint) {
        let (_, by_time, _) = ctx.run_series(&req, &label)?;
        high.add(by_time);
    }
    Ok(RacePanels { low, high })
}

/// Figure 2 proper: the unconstrained syn1 race.
pub fn run(ctx: &ExpCtx) -> anyhow::Result<RacePanels> {
    run_panels(ctx, "syn1", "unc")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lineups_cover_paper_solvers() {
        let ctx = ExpCtx::new(true);
        let low = low_precision_lineup(&ctx, "syn1", "unc");
        let names: Vec<&str> = low.iter().map(|(_, r)| r.solver.as_str()).collect();
        assert!(names.contains(&"hdpwbatchsgd"));
        assert!(names.contains(&"pwsgd"));
        assert!(names.contains(&"sgd"));
        assert!(names.contains(&"adagrad"));
        let high = high_precision_lineup(&ctx, "syn1", "unc");
        let names: Vec<&str> = high.iter().map(|(_, r)| r.solver.as_str()).collect();
        assert!(names.contains(&"pwgradient"));
        assert!(names.contains(&"ihs"));
        assert!(names.contains(&"pwsvrg"));
    }

    #[test]
    fn tiny_high_precision_panel_runs() {
        let mut ctx = ExpCtx::new(true);
        ctx.n = 2048;
        ctx.trials = 1;
        ctx.budget = 15.0;
        let mut fig = Figure::new("t", "s", "e", true);
        for (label, mut req) in high_precision_lineup(&ctx, "syn2", "unc") {
            req.max_iters = req.max_iters.min(300);
            let (_, by_time, _) = ctx.run_series(&req, &label).unwrap();
            fig.add(by_time);
        }
        // pwGradient must reach at least 1e-8 relative error in this regime
        let pw = &fig.series[0];
        let min_err = pw.ys.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min_err < 1e-8, "pwGradient floor {min_err}");
    }
}
