//! Figure 1: HDpwBatchSGD iteration complexity vs batch size r on Syn1 and
//! Syn2 (unconstrained).
//!
//! The paper's claim: doubling r halves the iteration count to a given
//! relative error — the *optimal* speed-up (Theorem 3's T = Theta(d log n /
//! (r eps^2))). One relative-error-vs-iterations curve per batch size.

use super::ExpCtx;
use crate::util::plot::Figure;

/// Batch sizes swept per dataset (one curve each).
pub const BATCH_SIZES: [usize; 5] = [1, 2, 4, 8, 16];

/// Everything Figure 1 produces: the two curve plots plus the quantitative
/// iterations-to-eps rows behind [`render_table`].
pub struct Fig1Output {
    /// one relative-error-vs-iterations figure per dataset (syn1, syn2)
    pub figures: Vec<Figure>,
    /// (dataset, r, iterations to reach eps) rows
    pub speedup_rows: Vec<(String, usize, Option<usize>)>,
    /// the relative-error threshold the speed-up rows are measured at
    pub eps: f64,
}

/// Run the Figure 1 protocol: HDpwBatchSGD over [`BATCH_SIZES`] on syn1 and
/// syn2, equal work budget per curve.
pub fn run(ctx: &ExpCtx) -> anyhow::Result<Fig1Output> {
    // quick-mode-reachable threshold: the paper's Fig 1 tracks the 1e-1 ..
    // 1e-2 band; at the bench's reduced n the variance floor sits near 5e-2.
    let eps = 1e-1;
    let mut figures = Vec::new();
    let mut rows = Vec::new();
    for dataset in ["syn1", "syn2"] {
        let mut fig = Figure::new(
            format!("Fig 1: HDpwBatchSGD batch-size speed-up on {dataset}"),
            "iterations",
            "relative error",
            true,
        );
        for r in BATCH_SIZES {
            let mut req = ctx.job(dataset, "hdpwbatchsgd");
            req.batch_size = r;
            req.normalize = true; // paper normalizes for low precision
            req.max_iters = 200_000 / r.max(1); // same work budget per curve
            req.target_rel_err = eps / 2.0;
            let res = ctx.coord.run_job(&req)?;
            let mut series = crate::util::plot::Series::new(format!("r={r}"));
            let mut hit: Option<usize> = None;
            for (it, _, rel) in res.best.rel_errors(res.f_star) {
                series.push(it, rel.max(1e-16));
                if hit.is_none() && rel <= eps {
                    hit = Some(it as usize);
                }
            }
            rows.push((dataset.to_string(), r, hit));
            fig.add(series);
        }
        figures.push(fig);
    }
    Ok(Fig1Output {
        figures,
        speedup_rows: rows,
        eps,
    })
}

/// Render the iterations-to-eps table (the quantitative form of Fig 1).
pub fn render_table(out: &Fig1Output) -> String {
    let mut s = format!(
        "iterations to relative error <= {:.0e} (— = not reached)\n",
        out.eps
    );
    s.push_str(&format!(
        "{:<8} {:>6} {:>12} {:>10}\n",
        "dataset", "r", "iters", "speed-up"
    ));
    let mut base: Option<f64> = None;
    let mut last_ds = String::new();
    for (ds, r, hit) in &out.speedup_rows {
        if *ds != last_ds {
            base = hit.map(|h| h as f64);
            last_ds = ds.clone();
        }
        let (iters_s, speedup_s) = match hit {
            Some(h) => (
                h.to_string(),
                base.map(|b| format!("{:.2}x", b / *h as f64))
                    .unwrap_or_else(|| "-".into()),
            ),
            None => ("—".into(), "-".into()),
        };
        s.push_str(&format!("{ds:<8} {r:>6} {iters_s:>12} {speedup_s:>10}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_run_shows_speedup_trend() {
        let mut ctx = ExpCtx::new(true);
        ctx.n = 4096;
        ctx.trials = 1;
        ctx.budget = 30.0;
        let out = run(&ctx).unwrap();
        assert_eq!(out.figures.len(), 2);
        // syn2 rows: the largest batch should need fewer iters than r=1
        let syn2: Vec<_> = out
            .speedup_rows
            .iter()
            .filter(|(ds, _, _)| ds == "syn2")
            .collect();
        let first = syn2.first().and_then(|(_, _, h)| *h);
        let last = syn2.last().and_then(|(_, _, h)| *h);
        if let (Some(a), Some(b)) = (first, last) {
            assert!(b < a, "r=16 ({b}) should need fewer iters than r=1 ({a})");
        }
        let table = render_table(&out);
        assert!(table.contains("syn1"));
        assert!(table.contains("syn2"));
    }
}
