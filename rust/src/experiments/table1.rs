//! Table 1: empirical verification of the complexity claims.
//!
//! We cannot measure asymptotic O(.) directly; instead we verify the three
//! scaling laws that distinguish the rows of Table 1 on this testbed:
//!
//! 1. **HDpwBatchSGD**: iterations to eps scale ~ 1/(r eps^2) — batch-size
//!    speed-up is linear (the paper's optimality claim).
//! 2. **pwGradient / IHS**: iterations to eps scale ~ log(1/eps) (linear
//!    convergence), and pwGradient's *per-iteration* cost is lower than
//!    IHS's by the re-sketching cost.
//! 3. **HDpwAccBatchSGD**: iterations to eps scale ~ 1/(r eps), better than
//!    HDpwBatchSGD's 1/(r eps^2) at small eps.

use super::ExpCtx;

/// One measured (solver, eps, r) cell of the empirical Table 1.
pub struct Table1Row {
    /// registry name of the solver
    pub solver: String,
    /// target relative error
    pub eps: f64,
    /// batch size (0 for the non-stochastic solvers)
    pub r: usize,
    /// iterations to reach `eps`, if reached within the budget
    pub iters: Option<usize>,
    /// wall-clock seconds to reach `eps`, if reached
    pub secs: Option<f64>,
}

/// All measured rows of the empirical Table 1.
pub struct Table1Output {
    /// one row per (solver, eps, r) combination swept by [`run`]
    pub rows: Vec<Table1Row>,
}

/// Run the Table 1 sweeps: eps/batch-size grids for the stochastic solvers,
/// eps grid for the linearly-convergent ones.
pub fn run(ctx: &ExpCtx) -> anyhow::Result<Table1Output> {
    let mut rows = Vec::new();
    // stochastic solvers: eps sweep at fixed r, r sweep at fixed eps
    for solver in ["hdpwbatchsgd", "hdpwaccbatchsgd"] {
        for (eps, r) in [
            (4e-2, 16),
            (2e-2, 16),
            (1e-2, 16),
            (1e-2, 4),
            (1e-2, 64),
        ] {
            let mut req = ctx.job("syn2", solver);
            req.batch_size = r;
            req.normalize = true;
            req.max_iters = 200_000;
            req.target_rel_err = eps;
            let res = ctx.coord.run_job(&req)?;
            let iters = res.best.iters_to_rel_err(res.f_star, eps);
            let secs = res.best.time_to_rel_err(res.f_star, eps);
            rows.push(Table1Row {
                solver: solver.into(),
                eps,
                r,
                iters,
                secs,
            });
        }
    }
    // high-precision solvers: eps sweep must show log(1/eps) iterations
    for solver in ["pwgradient", "ihs"] {
        for eps in [1e-4, 1e-6, 1e-8] {
            let mut req = ctx.job("syn2", solver);
            req.max_iters = 500;
            req.target_rel_err = eps;
            let res = ctx.coord.run_job(&req)?;
            rows.push(Table1Row {
                solver: solver.into(),
                eps,
                r: 0,
                iters: res.best.iters_to_rel_err(res.f_star, eps),
                secs: res.best.time_to_rel_err(res.f_star, eps),
            });
        }
    }
    Ok(Table1Output { rows })
}

/// Render the measured rows as the ASCII Table 1.
pub fn render(out: &Table1Output) -> String {
    let mut s = String::from(
        "Table 1 (empirical scaling): iterations/time to reach relative eps\n",
    );
    s.push_str(&format!(
        "{:<18} {:>9} {:>5} {:>10} {:>12}\n",
        "solver", "eps", "r", "iters", "secs"
    ));
    for row in &out.rows {
        s.push_str(&format!(
            "{:<18} {:>9.0e} {:>5} {:>10} {:>12}\n",
            row.solver,
            row.eps,
            if row.r == 0 {
                "-".to_string()
            } else {
                row.r.to_string()
            },
            row.iters
                .map(|i| i.to_string())
                .unwrap_or_else(|| "—".into()),
            row.secs
                .map(crate::util::stats::fmt_duration)
                .unwrap_or_else(|| "—".into()),
        ));
    }
    s
}

/// Check the scaling laws hold (used by tests and the bench's verdict line).
pub struct ScalingVerdict {
    /// growing r from 4 to 64 cut HDpw iterations by > 3x
    pub batch_speedup_ok: bool,
    /// pwGradient iterations grew ~linearly in log(1/eps)
    pub linear_convergence_ok: bool,
}

/// Evaluate the two scaling laws over the measured rows.
pub fn verdict(out: &Table1Output) -> ScalingVerdict {
    // batch speed-up: hdpw at eps=1e-2, r=4 vs r=64 => >= 4x fewer iters
    let find = |solver: &str, eps: f64, r: usize| {
        out.rows
            .iter()
            .find(|row| row.solver == solver && row.eps == eps && row.r == r)
            .and_then(|row| row.iters)
    };
    // either solver family demonstrating a >= 3x iteration reduction from
    // r=4 to r=64 (16x batch growth) passes; the plain variant can hit its
    // iteration cap at r=4 in quick mode (T ~ 1/(r eps^2) is the claim).
    let pair_ok = |solver: &str| match (find(solver, 1e-2, 4), find(solver, 1e-2, 64)) {
        (Some(slow), Some(fast)) => slow as f64 / fast as f64 > 3.0,
        _ => false,
    };
    let batch_speedup_ok = pair_ok("hdpwbatchsgd") || pair_ok("hdpwaccbatchsgd");
    // linear convergence: pwgradient iters grow ~ linearly in log(1/eps):
    // iters(1e-8) <= 3 * iters(1e-4) (would be ~2x for exactly linear)
    let pw = |eps: f64| {
        out.rows
            .iter()
            .find(|row| row.solver == "pwgradient" && row.eps == eps)
            .and_then(|row| row.iters)
    };
    let linear_convergence_ok = match (pw(1e-4), pw(1e-8)) {
        (Some(a), Some(b)) => b as f64 <= 3.0 * a as f64 + 2.0,
        _ => false,
    };
    ScalingVerdict {
        batch_speedup_ok,
        linear_convergence_ok,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_table1_has_expected_shape() {
        let mut ctx = ExpCtx::new(true);
        ctx.n = 2048;
        ctx.trials = 1;
        ctx.budget = 20.0;
        let out = run(&ctx).unwrap();
        assert_eq!(out.rows.len(), 16);
        let rendered = render(&out);
        assert!(rendered.contains("hdpwbatchsgd"));
        assert!(rendered.contains("pwgradient"));
        let v = verdict(&out);
        assert!(v.linear_convergence_ok, "{rendered}");
    }
}
