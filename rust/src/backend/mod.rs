//! Numerical backend facade: a priority-ordered registry of [`Executor`]s.
//!
//! Every solver expresses its numerics through [`Backend`], so the same
//! solver code runs (a) fully native at arbitrary shapes, (b) through the
//! arch-dispatched SIMD microkernels ([`SimdExecutor`], `crate::simd`), and
//! (c) through the AOT-compiled L1/L2 graphs at the canonical shapes — and
//! further executors can be registered without touching any solver. Per op
//! call the facade computes the canonical op key ([`executor::opkey`]),
//! checks projection eligibility (PJRT artifacts implement the Euclidean
//! unc/l1/l2 projections only, so metric projections and every other
//! constraint set skip executors whose
//! [`Executor::handles_all_projections`] is false — see
//! [`crate::constraints::ConstraintSet::accel_eligible`]), and routes to
//! the first eligible executor whose registry claims the op; the native
//! catch-all claims everything. Registry order is pjrt → simd → native.
//! The paths are cross-validated in `rust/tests/pjrt_parity.rs` (bitwise)
//! and `rust/tests/simd_parity.rs` (documented tolerance; native stays the
//! bit-exact reference).

/// The [`Executor`] trait, op-key naming scheme, dispatch counters, and the
/// three built-in executors (native, simd, PJRT).
pub mod executor;

pub use executor::{DispatchStats, ExecClass, Executor, NativeExecutor, PjrtExecutor, SimdExecutor};

use crate::constraints::ConstraintSet;
use crate::linalg::{CsrMat, Mat};
use crate::prox::metric::MetricProjector;
use crate::runtime::{Engine, EngineHandle};
use crate::sketch::Sketch;
use executor::opkey;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// The pluggable-executor numerical backend (thin facade).
#[derive(Clone)]
pub struct Backend {
    /// Priority-ordered op registry; the native catch-all is always last.
    executors: Vec<Arc<dyn Executor>>,
    /// Typed handle to the catch-all for block-aware native entry points.
    native: Arc<NativeExecutor>,
    stats: Arc<DispatchStats>,
    /// Construction inputs, kept so `fork_stats` can rebuild the registry
    /// around a fresh, isolated `DispatchStats`.
    engine: Option<EngineHandle>,
    threads: usize,
    default_block_rows: Option<usize>,
    /// Whether the registry includes the simd executor (ahead of native).
    simd: bool,
}

impl Backend {
    fn assemble(
        engine: Option<EngineHandle>,
        threads: Option<usize>,
        block_rows: Option<usize>,
        simd: bool,
        stats: Arc<DispatchStats>,
    ) -> Backend {
        let t = threads.unwrap_or_else(crate::util::threadpool::default_threads);
        let native = Arc::new(executor::NativeExecutor::with_tuning(
            Arc::clone(&stats),
            t,
            block_rows,
        ));
        let mut executors: Vec<Arc<dyn Executor>> = Vec::new();
        if let Some(e) = &engine {
            executors.push(Arc::new(PjrtExecutor::new(e.clone())));
        }
        if simd {
            executors.push(Arc::new(executor::SimdExecutor::with_tuning(
                Arc::clone(&stats),
                t,
                block_rows,
            )));
        }
        executors.push(Arc::clone(&native) as Arc<dyn Executor>);
        Backend {
            executors,
            native,
            stats,
            engine,
            threads: t,
            default_block_rows: block_rows,
            simd,
        }
    }

    /// A backend sharing this one's engine and tuning but with fresh,
    /// isolated dispatch counters — so a single request's dispatch mix can
    /// be inspected without interference from concurrent jobs on the shared
    /// backend. The recorded PJRT fallback reason (a property of the
    /// engine-load attempt, still true in the fork) carries over. Executors
    /// registered via [`Backend::push_executor`] do NOT carry over.
    pub fn fork_stats(&self) -> Backend {
        let stats = Arc::new(DispatchStats::default());
        if let Some(reason) = self.stats.fallback_reason() {
            stats.set_fallback_reason(reason);
        }
        Backend::assemble(
            self.engine.clone(),
            Some(self.threads),
            self.default_block_rows,
            self.simd,
            stats,
        )
    }

    /// A native-only backend inheriting this one's tuning (thread count,
    /// default shard height) with fresh counters — per-request native
    /// pinning must not escape the operator's resource limits.
    pub fn fork_native(&self) -> Backend {
        Backend::assemble(
            None,
            Some(self.threads),
            self.default_block_rows,
            false,
            Arc::new(DispatchStats::default()),
        )
    }

    /// A simd-preferring backend (no PJRT) inheriting this one's tuning
    /// with fresh counters — per-request `--executor simd` pinning. Always
    /// registers the simd executor, even on a scalar-only arch (the scalar
    /// fallback is bit-faithful to the 4-lane kernels, so a pinned request
    /// behaves identically everywhere, just without the speedup).
    pub fn fork_simd(&self) -> Backend {
        Backend::assemble(
            None,
            Some(self.threads),
            self.default_block_rows,
            true,
            Arc::new(DispatchStats::default()),
        )
    }

    /// Native-only backend (no artifacts needed). This is the bit-exact
    /// reference configuration the golden fixtures are sealed against.
    pub fn native() -> Backend {
        Backend::assemble(None, None, None, false, Arc::new(DispatchStats::default()))
    }

    /// Native-only backend with explicit worker count / default shard height
    /// (coordinator per-request tuning; `block_rows = None` = heuristic).
    pub fn native_with(threads: usize, block_rows: Option<usize>) -> Backend {
        Backend::assemble(
            None,
            Some(threads),
            block_rows,
            false,
            Arc::new(DispatchStats::default()),
        )
    }

    /// Backend with a loaded PJRT engine; falls back to native off-manifest.
    pub fn with_engine(engine: EngineHandle) -> Backend {
        Backend::assemble(
            Some(engine),
            None,
            None,
            false,
            Arc::new(DispatchStats::default()),
        )
    }

    /// Try to load artifacts from the default dir; native fallback if absent.
    /// The fallback reason is logged and recorded in [`DispatchStats`] —
    /// a silent native fallback looks identical to a healthy PJRT deploy in
    /// throughput dashboards, so the serve loop must be able to tell.
    ///
    /// Prefers the simd executor for off-manifest ops whenever runtime
    /// detection found a real vector unit ([`crate::simd::preferred`]); on
    /// scalar-only hardware the registry is pjrt → native, exactly as
    /// before.
    pub fn auto() -> Backend {
        let stats = Arc::new(DispatchStats::default());
        let simd = crate::simd::preferred();
        match EngineHandle::spawn(&Engine::default_dir()) {
            Ok(e) => Backend::assemble(Some(e), None, None, simd, stats),
            Err(err) => {
                let reason = format!("{err:#}");
                crate::log_warn!(
                    "PJRT engine unavailable, using the native executor: {reason}"
                );
                stats.set_fallback_reason(reason);
                Backend::assemble(None, None, None, simd, stats)
            }
        }
    }

    /// Register an additional executor ahead of the native catch-all (and
    /// behind any PJRT executor already present). New backends slot in here
    /// without touching solver code.
    pub fn push_executor(&mut self, exec: Arc<dyn Executor>) {
        let at = self.executors.len() - 1; // native stays last
        self.executors.insert(at, exec);
    }

    /// Whether a PJRT engine is actually loaded (not inferrable/spoofable
    /// from executor names).
    pub fn has_pjrt(&self) -> bool {
        self.engine.is_some()
    }

    /// Whether the registry includes the simd executor.
    pub fn has_simd(&self) -> bool {
        self.simd
    }

    /// Ops served by a compiled PJRT executable.
    pub fn pjrt_calls(&self) -> usize {
        self.stats.pjrt_calls.load(Ordering::Relaxed)
    }

    /// Ops served by the native catch-all executor.
    pub fn native_calls(&self) -> usize {
        self.stats.native_calls.load(Ordering::Relaxed)
    }

    /// Ops served by the simd executor.
    pub fn simd_calls(&self) -> usize {
        self.stats.simd_calls.load(Ordering::Relaxed)
    }

    /// Row shards folded by native block-streamed paths.
    pub fn native_block_calls(&self) -> usize {
        self.stats.native_block_calls.load(Ordering::Relaxed)
    }

    /// Why `Backend::auto()` fell back to native, if it did.
    pub fn pjrt_fallback_reason(&self) -> Option<String> {
        self.stats.fallback_reason()
    }

    /// The backend's dispatch counters (for absorbing a fork's counts back
    /// into a parent, or direct inspection).
    pub fn stats(&self) -> &DispatchStats {
        &self.stats
    }

    /// Route an op: the first *eligible* executor claiming `op` wins, else
    /// the native catch-all. `projection_ok = false` (an active R-metric
    /// projector or a non-artifact constraint set) skips executors that
    /// cannot run the shared projection code
    /// ([`Executor::handles_all_projections`] — PJRT); the simd and native
    /// executors run it verbatim and stay eligible.
    fn route(&self, op: &str, projection_ok: bool) -> &dyn Executor {
        for e in &self.executors {
            if (projection_ok || e.handles_all_projections()) && e.supports(op) {
                self.stats.mark(e.class());
                return e.as_ref();
            }
        }
        self.stats.mark(ExecClass::Native);
        self.native.as_ref()
    }

    /// Constrained calls may only reach projection-restricted executors
    /// (PJRT) when the set itself is artifact-implemented
    /// ([`ConstraintSet::accel_eligible`] — today: unc/l1/l2 Euclidean
    /// projections) *and* no R-metric projector is active (the artifacts
    /// implement Euclidean projections only). Executors running the shared
    /// scalar projection code (simd, native) are always eligible.
    fn projection_eligible(cons: &dyn ConstraintSet, metric: Option<&MetricProjector>) -> bool {
        let metric_active = metric.is_some() && !cons.is_unconstrained();
        cons.accel_eligible() && !metric_active
    }

    // ---------------------------------------------------------------------
    // ops
    // ---------------------------------------------------------------------

    /// Randomized-Hadamard transform of the packed [A | b] (rows must be a
    /// power of two). Artifact: `hd_transform_n{n}_c{cols}`.
    pub fn hd_transform(&self, aug: &Mat, signs: &[f64]) -> Mat {
        let op = opkey::hd_transform(aug.rows, aug.cols);
        self.route(&op, true).hd_transform(aug, signs)
    }

    /// In-place randomized-Hadamard of the owned padded [A | b] — the
    /// streaming pipeline's entry point. On the native route the buffer is
    /// transformed where it sits (zero extra copies); a PJRT route follows
    /// artifact semantics and swaps in the returned buffer.
    pub fn hd_transform_mut(&self, aug: &mut Mat, signs: &[f64]) {
        let op = opkey::hd_transform(aug.rows, aug.cols);
        self.route(&op, true).hd_transform_mut(aug, signs)
    }

    /// Mini-batch gradient c = scale * M^T (M x - v). Artifact:
    /// `batch_grad_r{r}_d{d}`.
    pub fn batch_grad(&self, m: &Mat, v: &[f64], x: &[f64], scale: f64) -> Vec<f64> {
        let op = opkey::batch_grad(m.rows, m.cols);
        self.route(&op, true).batch_grad(m, v, x, scale)
    }

    /// Full gradient g = 2 A^T (A x - b). Artifact: `full_grad_n{n}_d{d}`.
    pub fn full_grad(&self, a: &Mat, b: &[f64], x: &[f64]) -> Vec<f64> {
        let op = opkey::full_grad(a.rows, a.cols);
        self.route(&op, true).full_grad(a, b, x)
    }

    /// f(x) = ||Ax - b||^2. Artifact: `residual_sq_n{n}_d{d}`.
    pub fn residual_sq(&self, a: &Mat, b: &[f64], x: &[f64]) -> f64 {
        let op = opkey::residual_sq(a.rows, a.cols);
        self.route(&op, true).residual_sq(a, b, x)
    }

    /// f(x_k) = ||A x_k - b||^2 for a batch of iterates, routed on the same
    /// op key as the serial call so every column lands on the same executor
    /// a serial [`Backend::residual_sq`] would pick — each column is
    /// bitwise-equal to the serial call (see
    /// [`Executor::residual_sq_multi`]). Artifact: `residual_sq_n{n}_d{d}`.
    pub fn residual_sq_multi(&self, a: &Mat, b: &[f64], xs: &[Vec<f64>]) -> Vec<f64> {
        let op = opkey::residual_sq(a.rows, a.cols);
        self.route(&op, true).residual_sq_multi(a, b, xs)
    }

    /// One preconditioned gradient step x <- P_W(x - eta * pinv g).
    ///
    /// `metric`: when Some, constrained steps use the R-metric projection
    /// (the paper's Step-6 quadratic subproblem — see prox::metric); the
    /// PJRT artifacts implement the Euclidean-projection variant, so metric
    /// projections always take the native path.
    /// Artifact: `gd_step_{cons}_d{d}`.
    pub fn gd_step(
        &self,
        x: &[f64],
        pinv: &Mat,
        g: &[f64],
        eta: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> Vec<f64> {
        let op = opkey::gd_step(cons, x.len());
        self.route(&op, Self::projection_eligible(cons, metric))
            .gd_step(x, pinv, g, eta, cons, metric)
    }

    /// T fused mini-batch SGD steps (Algorithm 2, steps 3-7).
    /// `idx` is (T x r) i.i.d. uniform indices. Returns (x_T, sum of x_t).
    /// Artifact: `sgd_chunk_{cons}_n{n}_d{d}_r{r}_t{T}`.
    #[allow(clippy::too_many_arguments)]
    pub fn sgd_chunk(
        &self,
        hda: &Mat,
        hdb: &[f64],
        x0: &[f64],
        pinv: &Mat,
        idx: &[Vec<usize>],
        eta: f64,
        scale: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> (Vec<f64>, Vec<f64>) {
        let t = idx.len();
        let r = idx.first().map(|v| v.len()).unwrap_or(0);
        let op = opkey::sgd_chunk(cons, hda.rows, hda.cols, r, t);
        self.route(&op, Self::projection_eligible(cons, metric))
            .sgd_chunk(hda, hdb, x0, pinv, idx, eta, scale, cons, metric)
    }

    /// T fused accelerated (Ghadimi-Lan) mini-batch steps (Algorithm 6).
    /// Returns (x_T, xhat_T). Artifact: `acc_chunk_{cons}_n{n}_d{d}_r{r}_t{T}`.
    #[allow(clippy::too_many_arguments)]
    pub fn acc_chunk(
        &self,
        hda: &Mat,
        hdb: &[f64],
        x0: &[f64],
        xhat0: &[f64],
        pinv: &Mat,
        idx: &[Vec<usize>],
        alphas: &[f64],
        qs: &[f64],
        etas: &[f64],
        mu: f64,
        scale: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> (Vec<f64>, Vec<f64>) {
        let t = idx.len();
        let r = idx.first().map(|v| v.len()).unwrap_or(0);
        let op = opkey::acc_chunk(cons, hda.rows, hda.cols, r, t);
        self.route(&op, Self::projection_eligible(cons, metric)).acc_chunk(
            hda, hdb, x0, xhat0, pinv, idx, alphas, qs, etas, mu, scale, cons, metric,
        )
    }

    /// T fused pwGradient steps (Algorithm 4). Artifact:
    /// `pw_gradient_chunk_{cons}_n{n}_d{d}_t{T}`.
    #[allow(clippy::too_many_arguments)]
    pub fn pw_gradient_chunk(
        &self,
        a: &Mat,
        b: &[f64],
        x0: &[f64],
        pinv: &Mat,
        eta: f64,
        t: usize,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> Vec<f64> {
        let op = opkey::pw_gradient_chunk(cons, a.rows, a.cols, t);
        self.route(&op, Self::projection_eligible(cons, metric))
            .pw_gradient_chunk(a, b, x0, pinv, eta, t, cons, metric)
    }

    /// Compute `S A` for the preconditioner. Routed through the registry
    /// like every other op (no PJRT artifact exists today, so the native
    /// executor streams row shards and counts them in
    /// [`DispatchStats::native_block_calls`]; a registered executor may
    /// claim `sketch_apply_s{s}_n{n}_d{d}` to take over the setup phase).
    pub fn sketch_apply(
        &self,
        sk: &(dyn Sketch + Send + Sync),
        a: &Mat,
        block_rows: Option<usize>,
    ) -> Mat {
        let op = opkey::sketch_apply(sk.rows(), a.rows, a.cols);
        self.route(&op, true).sketch_apply(sk, a, block_rows)
    }

    /// Compute `S A` for a CSR matrix — the O(nnz) setup path for sparse
    /// datasets. The caller's `block_rows` tuning knob (a row count, shared
    /// with the dense pipeline) is translated here into a per-shard nnz
    /// budget via the mean row occupancy, so `--block-rows` means "about
    /// this many rows per shard" in both representations. Routed through
    /// the registry like every op; no PJRT artifact exists for sparse
    /// inputs today, so the native executor streams nnz-balanced shards and
    /// counts them in [`DispatchStats::native_block_calls`].
    pub fn sketch_apply_csr(
        &self,
        sk: &(dyn Sketch + Send + Sync),
        a: &CsrMat,
        block_rows: Option<usize>,
    ) -> Mat {
        let op = opkey::sketch_apply_csr(sk.rows(), a.nnz(), a.cols);
        let block_nnz = block_rows.map(|br| a.nnz_budget_for_rows(br));
        self.route(&op, true).sketch_apply_csr(sk, a, block_nnz)
    }

    /// Compute `S A` for a disk-backed design — the out-of-core setup path.
    /// No executor can claim a matrix that is never resident, so this entry
    /// bypasses the registry and folds shard-cache scratch blocks through
    /// [`crate::sketch::apply_streamed_ondisk`] with this backend's tuning
    /// (thread count, default shard height) and, when the simd executor is
    /// registered, its row-scatter kernels — the same ops the in-memory
    /// dense fold would get. Shards folded count as native block calls like
    /// every streamed fold. Fallible: a shard I/O error or refused cache
    /// charge propagates as the job's structured error, never a worker
    /// panic.
    pub fn sketch_apply_ondisk(
        &self,
        sk: &(dyn Sketch + Send + Sync),
        od: &crate::data::OnDiskDesign,
        block_rows: Option<usize>,
    ) -> anyhow::Result<Mat> {
        let ops = if self.simd {
            crate::simd::row_ops()
        } else {
            crate::sketch::RowOps::SCALAR
        };
        let br = block_rows.or(self.default_block_rows);
        let (sa, shards) =
            crate::sketch::apply_streamed_ondisk(sk, od, br, self.threads, &ops)?;
        if shards > 1 {
            self.stats.add_block_calls(shards);
        }
        Ok(sa)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::util::rng::Rng;

    fn setup(n: usize, d: usize) -> (Mat, Vec<f64>, Vec<f64>, Mat, Rng) {
        let mut rng = Rng::new(42);
        let a = Mat::gaussian(n, d, &mut rng);
        let b = rng.gaussians(n);
        let x = rng.gaussians(d);
        // a simple SPD pinv: identity (keeps tests about plumbing, not math)
        let pinv = Mat::eye(d);
        (a, b, x, pinv, rng)
    }

    #[test]
    fn native_batch_grad_matches_fused() {
        let (a, b, x, _, _) = setup(32, 5);
        let be = Backend::native();
        let got = be.batch_grad(&a, &b, &x, 3.0);
        let want = blas::fused_grad(&a, &b, &x, 3.0);
        assert_eq!(got, want);
        assert_eq!(be.native_calls(), 1);
        assert_eq!(be.pjrt_calls(), 0);
    }

    #[test]
    fn native_full_grad_and_residual() {
        let (a, b, x, _, _) = setup(64, 4);
        let be = Backend::native();
        let g = be.full_grad(&a, &b, &x);
        assert_eq!(g, blas::fused_grad(&a, &b, &x, 2.0));
        let f = be.residual_sq(&a, &b, &x);
        assert!((f - blas::residual_sq(&a, &b, &x)).abs() < 1e-12);
    }

    #[test]
    fn native_gd_step_projects() {
        let (_, _, _, pinv, mut rng) = setup(4, 4);
        let be = Backend::native();
        let x = rng.gaussians(4);
        let g = rng.gaussians(4);
        let cons = crate::constraints::L2Ball { radius: 0.1 };
        let out = be.gd_step(&x, &pinv, &g, 0.5, &cons, None);
        assert!(cons.contains(&out, 1e-12));
        // unconstrained matches manual update
        let unc = be.gd_step(&x, &pinv, &g, 0.5, &crate::constraints::Unconstrained, None);
        for j in 0..4 {
            assert!((unc[j] - (x[j] - 0.5 * g[j])).abs() < 1e-12);
        }
    }

    #[test]
    fn native_sgd_chunk_decreases_objective() {
        let (a, _, xtrue, _, mut rng) = setup(256, 6);
        // planted solution with small noise so the optimum is well below f(0)
        let mut b = blas::gemv(&a, &xtrue);
        for v in &mut b {
            *v += 0.01 * rng.gaussian();
        }
        // well-conditioned gaussian problem: pinv = (A^T A)^{-1} via QR
        let r = crate::linalg::qr::qr_r(&a);
        let pinv = crate::linalg::tri::pinv_dense(&r);
        let be = Backend::native();
        let x0 = vec![0.0; 6];
        let t = 100;
        let rr = 8;
        let idx: Vec<Vec<usize>> = (0..t).map(|_| rng.indices(rr, 256)).collect();
        let scale = 2.0 * 256.0 / rr as f64;
        let (xt, xsum) = be.sgd_chunk(
            &a,
            &b,
            &x0,
            &pinv,
            &idx,
            0.05,
            scale,
            &crate::constraints::Unconstrained,
            None,
        );
        let f0 = blas::residual_sq(&a, &b, &x0);
        let ft = blas::residual_sq(&a, &b, &xt);
        assert!(
            ft < 0.2 * f0,
            "sgd made too little progress: {ft} vs {f0}"
        );
        assert_eq!(xsum.len(), 6);
    }

    #[test]
    fn native_pw_gradient_converges_linearly() {
        let (a, b, _, _, _) = setup(512, 5);
        let r = crate::linalg::qr::qr_r(&a);
        let pinv = crate::linalg::tri::pinv_dense(&r);
        let be = Backend::native();
        let x0 = vec![0.0; 5];
        let x10 =
            be.pw_gradient_chunk(
                &a,
                &b,
                &x0,
                &pinv,
                0.5,
                10,
                &crate::constraints::Unconstrained,
                None,
            );
        // exact preconditioner + eta=1/2 solves in ONE step (Newton); after
        // 10 it must be at machine precision of the LS optimum
        let xstar = crate::linalg::qr::lstsq(&a, &b);
        for (u, v) in x10.iter().zip(&xstar) {
            assert!((u - v).abs() < 1e-8, "{u} vs {v}");
        }
    }

    #[test]
    fn native_acc_chunk_runs_and_projects() {
        let (a, b, _, _, mut rng) = setup(128, 4);
        let r = crate::linalg::qr::qr_r(&a);
        let pinv = crate::linalg::tri::pinv_dense(&r);
        let be = Backend::native();
        let t = 20;
        let rr = 4;
        let idx: Vec<Vec<usize>> = (0..t).map(|_| rng.indices(rr, 128)).collect();
        let alphas: Vec<f64> = (1..=t).map(|k| 2.0 / (k as f64 + 1.0)).collect();
        let qs = alphas.clone();
        let etas = vec![0.05; t];
        let cons = crate::constraints::L2Ball { radius: 0.5 };
        let (x, xhat) = be.acc_chunk(
            &a,
            &b,
            &vec![0.0; 4],
            &vec![0.0; 4],
            &pinv,
            &idx,
            &alphas,
            &qs,
            &etas,
            2.0,
            2.0 * 128.0 / rr as f64,
            &cons,
            None,
        );
        assert!(cons.contains(&x, 1e-9));
        assert_eq!(xhat.len(), 4);
    }

    // -------------------------------------------------------------------
    // facade / registry behavior
    // -------------------------------------------------------------------

    /// A toy accelerator that claims exactly one op and doubles its output —
    /// proves the registry routes by op key and leaves everything else to
    /// the native catch-all, without any solver-code changes.
    struct DoublingExecutor {
        claimed: String,
    }

    #[allow(clippy::too_many_arguments)]
    impl Executor for DoublingExecutor {
        fn name(&self) -> &'static str {
            "doubling"
        }

        fn supports(&self, op: &str) -> bool {
            op == self.claimed
        }

        fn hd_transform(&self, aug: &Mat, _signs: &[f64]) -> Mat {
            aug.clone()
        }

        fn batch_grad(&self, m: &Mat, v: &[f64], x: &[f64], scale: f64) -> Vec<f64> {
            blas::fused_grad(m, v, x, 2.0 * scale)
        }

        fn full_grad(&self, a: &Mat, b: &[f64], x: &[f64]) -> Vec<f64> {
            blas::fused_grad(a, b, x, 2.0)
        }

        fn residual_sq(&self, a: &Mat, b: &[f64], x: &[f64]) -> f64 {
            blas::residual_sq(a, b, x)
        }

        fn gd_step(
            &self,
            x: &[f64],
            _pinv: &Mat,
            _g: &[f64],
            _eta: f64,
            _cons: &dyn ConstraintSet,
            _metric: Option<&MetricProjector>,
        ) -> Vec<f64> {
            x.to_vec()
        }

        fn sgd_chunk(
            &self,
            _hda: &Mat,
            _hdb: &[f64],
            x0: &[f64],
            _pinv: &Mat,
            _idx: &[Vec<usize>],
            _eta: f64,
            _scale: f64,
            _cons: &dyn ConstraintSet,
            _metric: Option<&MetricProjector>,
        ) -> (Vec<f64>, Vec<f64>) {
            (x0.to_vec(), x0.to_vec())
        }

        fn acc_chunk(
            &self,
            _hda: &Mat,
            _hdb: &[f64],
            x0: &[f64],
            xhat0: &[f64],
            _pinv: &Mat,
            _idx: &[Vec<usize>],
            _alphas: &[f64],
            _qs: &[f64],
            _etas: &[f64],
            _mu: f64,
            _scale: f64,
            _cons: &dyn ConstraintSet,
            _metric: Option<&MetricProjector>,
        ) -> (Vec<f64>, Vec<f64>) {
            (x0.to_vec(), xhat0.to_vec())
        }

        fn pw_gradient_chunk(
            &self,
            _a: &Mat,
            _b: &[f64],
            x0: &[f64],
            _pinv: &Mat,
            _eta: f64,
            _t: usize,
            _cons: &dyn ConstraintSet,
            _metric: Option<&MetricProjector>,
        ) -> Vec<f64> {
            x0.to_vec()
        }
    }

    #[test]
    fn registry_routes_by_op_key() {
        let (a, b, x, _, _) = setup(32, 5);
        let mut be = Backend::native();
        be.push_executor(Arc::new(DoublingExecutor {
            claimed: executor::opkey::batch_grad(32, 5),
        }));
        // claimed op goes to the toy executor (doubled scale)
        let got = be.batch_grad(&a, &b, &x, 1.0);
        let doubled = blas::fused_grad(&a, &b, &x, 2.0);
        assert_eq!(got, doubled);
        // unclaimed op (different shape key) falls through to native
        let (a2, b2, x2, _, _) = setup(16, 3);
        let got2 = be.batch_grad(&a2, &b2, &x2, 1.0);
        assert_eq!(got2, blas::fused_grad(&a2, &b2, &x2, 1.0));
        // neither routed through pjrt
        assert_eq!(be.pjrt_calls(), 0);
    }

    #[test]
    fn sketch_apply_counts_block_calls() {
        let mut rng = Rng::new(9);
        let a = Mat::gaussian(512, 6, &mut rng);
        let sk = crate::sketch::SketchKind::CountSketch.build(64, 512, &mut rng);
        let be = Backend::native_with(4, Some(64));
        let sa = be.sketch_apply(sk.as_ref(), &a, None);
        assert!(sa.max_abs_diff(&sk.apply(&a)) < 1e-12);
        assert_eq!(be.native_block_calls(), 512 / 64);
    }

    #[test]
    fn sketch_apply_csr_counts_block_calls_and_matches_dense() {
        let mut rng = Rng::new(13);
        let dense = Mat::from_fn(512, 6, |_, _| {
            if rng.uniform() < 0.2 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let csr = crate::linalg::CsrMat::from_dense(&dense);
        let sk = crate::sketch::SketchKind::CountSketch.build(64, 512, &mut rng);
        let be = Backend::native_with(4, None);
        // block_rows = 64 rows/shard translates to ~64 * avg_nnz per shard
        let sa = be.sketch_apply_csr(sk.as_ref(), &csr, Some(64));
        assert!(sa.max_abs_diff(&sk.apply(&dense)) < 1e-12);
        assert!(
            be.native_block_calls() > 1,
            "expected the nnz-sharded streamed path"
        );
    }

    #[test]
    fn fork_stats_isolates_counters() {
        let (a, b, x, _, _) = setup(32, 5);
        let be = Backend::native_with(2, Some(32));
        let _ = be.residual_sq(&a, &b, &x);
        assert_eq!(be.native_calls(), 1);
        let fork = be.fork_stats();
        assert_eq!(fork.native_calls(), 0, "fork must start clean");
        let _ = fork.residual_sq(&a, &b, &x);
        assert_eq!(fork.native_calls(), 1);
        assert_eq!(be.native_calls(), 1, "original unaffected by fork");
        assert!(!fork.has_pjrt());
    }

    #[test]
    fn native_backend_has_no_fallback_reason() {
        // explicit native choice is not a "fallback" — only auto() records one
        let be = Backend::native();
        assert!(be.pjrt_fallback_reason().is_none());
        assert!(!be.has_pjrt());
        assert!(!be.has_simd());
    }

    #[test]
    fn fork_simd_routes_ops_to_the_simd_executor() {
        let (a, b, x, pinv, mut rng) = setup(64, 5);
        let be = Backend::native_with(2, None).fork_simd();
        assert!(be.has_simd());
        assert!(!be.has_pjrt());
        let g = be.full_grad(&a, &b, &x);
        let want = blas::fused_grad(&a, &b, &x, 2.0);
        for (s, n) in g.iter().zip(&want) {
            assert!((s - n).abs() <= 1e-12 * (1.0 + n.abs()), "{s} vs {n}");
        }
        assert_eq!(be.simd_calls(), 1);
        assert_eq!(be.native_calls(), 0);
        // projection-restricted calls stay on simd (it runs the shared
        // scalar projection code) — unlike PJRT they are not forced native
        let cons = crate::constraints::CoordBox {
            lo: vec![-0.1; 5],
            hi: vec![0.1; 5],
        };
        let gv = rng.gaussians(5);
        let out = be.gd_step(&x, &pinv, &gv, 0.5, &cons, None);
        assert!(cons.contains(&out, 1e-12));
        assert_eq!(be.simd_calls(), 2);
        assert_eq!(be.native_calls(), 0);
        // counters survive absorb into a parent's stats
        let parent = Backend::native();
        parent.stats().absorb(be.stats());
        assert_eq!(parent.simd_calls(), 2);
    }

    #[test]
    fn fork_stats_preserves_simd_registry() {
        let be = Backend::native_with(2, None).fork_simd();
        let fork = be.fork_stats();
        assert!(fork.has_simd(), "fork_stats must rebuild the same registry");
        assert_eq!(fork.simd_calls(), 0);
    }
}
