//! Numerical backend: PJRT artifacts when shapes match the manifest,
//! from-scratch native kernels otherwise.
//!
//! Every solver expresses its numerics through this interface, so the same
//! solver code runs (a) fully native at arbitrary shapes and (b) through the
//! AOT-compiled L1/L2 graphs at the canonical shapes. The two paths are
//! cross-validated in `rust/tests/pjrt_parity.rs`.

use crate::linalg::{blas, Mat};
use crate::prox::metric::MetricProjector;
use crate::prox::Constraint;
use crate::runtime::literal::Value;
use crate::runtime::{Engine, EngineHandle};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Dispatch counters (observability + tests).
#[derive(Debug, Default)]
pub struct DispatchStats {
    pub pjrt_calls: AtomicUsize,
    pub native_calls: AtomicUsize,
}

/// The dual-path numerical backend.
#[derive(Clone)]
pub struct Backend {
    engine: Option<EngineHandle>,
    force_native: bool,
    stats: Arc<DispatchStats>,
}

impl Backend {
    /// Native-only backend (no artifacts needed).
    pub fn native() -> Backend {
        Backend {
            engine: None,
            force_native: true,
            stats: Arc::new(DispatchStats::default()),
        }
    }

    /// Backend with a loaded PJRT engine; falls back to native off-manifest.
    pub fn with_engine(engine: EngineHandle) -> Backend {
        Backend {
            engine: Some(engine),
            force_native: false,
            stats: Arc::new(DispatchStats::default()),
        }
    }

    /// Try to load artifacts from the default dir; native fallback if absent.
    pub fn auto() -> Backend {
        match EngineHandle::spawn(&Engine::default_dir()) {
            Ok(e) => Backend::with_engine(e),
            Err(_) => Backend::native(),
        }
    }

    pub fn has_pjrt(&self) -> bool {
        self.engine.is_some() && !self.force_native
    }

    pub fn pjrt_calls(&self) -> usize {
        self.stats.pjrt_calls.load(Ordering::Relaxed)
    }

    pub fn native_calls(&self) -> usize {
        self.stats.native_calls.load(Ordering::Relaxed)
    }

    fn engine_with(&self, op: &str) -> Option<&EngineHandle> {
        if self.force_native {
            return None;
        }
        let e = self.engine.as_ref()?;
        e.has_op(op).then_some(e)
    }

    fn mark(&self, pjrt: bool) {
        if pjrt {
            self.stats.pjrt_calls.fetch_add(1, Ordering::Relaxed);
        } else {
            self.stats.native_calls.fetch_add(1, Ordering::Relaxed);
        }
    }

    // ---------------------------------------------------------------------
    // ops
    // ---------------------------------------------------------------------

    /// Randomized-Hadamard transform of the packed [A | b] (rows must be a
    /// power of two). Artifact: `hd_transform_n{n}_c{cols}`.
    pub fn hd_transform(&self, aug: &Mat, signs: &[f64]) -> Mat {
        let op = format!("hd_transform_n{}_c{}", aug.rows, aug.cols);
        if let Some(e) = self.engine_with(&op) {
            self.mark(true);
            let out = e
                .execute(&op, vec![Value::Mat(aug.clone()), Value::Vec(signs.to_vec())])
                .expect("hd_transform artifact");
            return Mat::from_vec(aug.rows, aug.cols, out.into_iter().next().unwrap());
        }
        self.mark(false);
        let mut m = aug.clone();
        crate::sketch::fwht::randomized_hadamard(&mut m, signs);
        m
    }

    /// Mini-batch gradient c = scale * M^T (M x - v). Artifact:
    /// `batch_grad_r{r}_d{d}`.
    pub fn batch_grad(&self, m: &Mat, v: &[f64], x: &[f64], scale: f64) -> Vec<f64> {
        let op = format!("batch_grad_r{}_d{}", m.rows, m.cols);
        if let Some(e) = self.engine_with(&op) {
            self.mark(true);
            let out = e
                .execute(
                    &op,
                    vec![
                        Value::Mat(m.clone()),
                        Value::Vec(v.to_vec()),
                        Value::Vec(x.to_vec()),
                        Value::Scalar(scale),
                    ],
                )
                .expect("batch_grad artifact");
            return out.into_iter().next().unwrap();
        }
        self.mark(false);
        blas::fused_grad(m, v, x, scale)
    }

    /// Full gradient g = 2 A^T (A x - b). Artifact: `full_grad_n{n}_d{d}`.
    pub fn full_grad(&self, a: &Mat, b: &[f64], x: &[f64]) -> Vec<f64> {
        let op = format!("full_grad_n{}_d{}", a.rows, a.cols);
        if let Some(e) = self.engine_with(&op) {
            self.mark(true);
            let out = e
                .execute(
                    &op,
                    vec![
                        Value::Mat(a.clone()),
                        Value::Vec(b.to_vec()),
                        Value::Vec(x.to_vec()),
                    ],
                )
                .expect("full_grad artifact");
            return out.into_iter().next().unwrap();
        }
        self.mark(false);
        blas::fused_grad(a, b, x, 2.0)
    }

    /// f(x) = ||Ax - b||^2. Artifact: `residual_sq_n{n}_d{d}`.
    pub fn residual_sq(&self, a: &Mat, b: &[f64], x: &[f64]) -> f64 {
        let op = format!("residual_sq_n{}_d{}", a.rows, a.cols);
        if let Some(e) = self.engine_with(&op) {
            self.mark(true);
            let out = e
                .execute(
                    &op,
                    vec![
                        Value::Mat(a.clone()),
                        Value::Vec(b.to_vec()),
                        Value::Vec(x.to_vec()),
                    ],
                )
                .expect("residual_sq artifact");
            return out[0][0];
        }
        self.mark(false);
        blas::residual_sq(a, b, x)
    }

    /// One preconditioned gradient step x <- P_W(x - eta * pinv g).
    ///
    /// `metric`: when Some, constrained steps use the R-metric projection
    /// (the paper's Step-6 quadratic subproblem — see prox::metric); the
    /// PJRT artifacts implement the Euclidean-projection variant, so metric
    /// projections always take the native path.
    /// Artifact: `gd_step_{cons}_d{d}`.
    pub fn gd_step(
        &self,
        x: &[f64],
        pinv: &Mat,
        g: &[f64],
        eta: f64,
        cons: &Constraint,
        metric: Option<&MetricProjector>,
    ) -> Vec<f64> {
        let op = format!("gd_step_{}_d{}", cons.tag(), x.len());
        let metric_active = metric.is_some() && cons.tag() != "unc";
        if cons.tag() != "box" && !metric_active {
            if let Some(e) = self.engine_with(&op) {
                self.mark(true);
                let out = e
                    .execute(
                        &op,
                        vec![
                            Value::Vec(x.to_vec()),
                            Value::Mat(pinv.clone()),
                            Value::Vec(g.to_vec()),
                            Value::Scalar(eta),
                            Value::Scalar(cons.radius()),
                        ],
                    )
                    .expect("gd_step artifact");
                return out.into_iter().next().unwrap();
            }
        }
        self.mark(false);
        let step = blas::gemv(pinv, g);
        let mut out = x.to_vec();
        for (o, s) in out.iter_mut().zip(&step) {
            *o -= eta * s;
        }
        match metric {
            Some(m) => m.project(&out, cons),
            None => {
                cons.project(&mut out);
                out
            }
        }
    }

    /// T fused mini-batch SGD steps (Algorithm 2, steps 3-7).
    /// `idx` is (T x r) i.i.d. uniform indices. Returns (x_T, sum of x_t).
    /// Artifact: `sgd_chunk_{cons}_n{n}_d{d}_r{r}_t{T}`.
    #[allow(clippy::too_many_arguments)]
    pub fn sgd_chunk(
        &self,
        hda: &Mat,
        hdb: &[f64],
        x0: &[f64],
        pinv: &Mat,
        idx: &[Vec<usize>],
        eta: f64,
        scale: f64,
        cons: &Constraint,
        metric: Option<&MetricProjector>,
    ) -> (Vec<f64>, Vec<f64>) {
        let t = idx.len();
        let r = idx.first().map(|v| v.len()).unwrap_or(0);
        let op = format!(
            "sgd_chunk_{}_n{}_d{}_r{}_t{}",
            cons.tag(),
            hda.rows,
            hda.cols,
            r,
            t
        );
        let metric_active = metric.is_some() && cons.tag() != "unc";
        if cons.tag() != "box" && !metric_active {
            if let Some(e) = self.engine_with(&op) {
                self.mark(true);
                let flat: Vec<i32> = idx
                    .iter()
                    .flat_map(|row| row.iter().map(|&i| i as i32))
                    .collect();
                let out = e
                    .execute(
                        &op,
                        vec![
                            Value::Mat(hda.clone()),
                            Value::Vec(hdb.to_vec()),
                            Value::Vec(x0.to_vec()),
                            Value::Mat(pinv.clone()),
                            Value::MatI32 {
                                rows: t,
                                cols: r,
                                data: flat,
                            },
                            Value::Scalar(eta),
                            Value::Scalar(scale),
                            Value::Scalar(cons.radius()),
                        ],
                    )
                    .expect("sgd_chunk artifact");
                let mut it = out.into_iter();
                return (it.next().unwrap(), it.next().unwrap());
            }
        }
        self.mark(false);
        let d = hda.cols;
        let mut x = x0.to_vec();
        let mut xsum = vec![0.0; d];
        let mut mbuf = Mat::zeros(r, d);
        let mut vbuf = vec![0.0; r];
        for tau in idx {
            for (k, &i) in tau.iter().enumerate() {
                mbuf.row_mut(k).copy_from_slice(hda.row(i));
                vbuf[k] = hdb[i];
            }
            let c = blas::fused_grad(&mbuf, &vbuf, &x, scale);
            let step = blas::gemv(pinv, &c);
            for (xi, si) in x.iter_mut().zip(&step) {
                *xi -= eta * si;
            }
            match metric {
                Some(m) => x = m.project(&x, cons),
                None => cons.project(&mut x),
            }
            for (s, xi) in xsum.iter_mut().zip(&x) {
                *s += xi;
            }
        }
        (x, xsum)
    }

    /// T fused accelerated (Ghadimi-Lan) mini-batch steps (Algorithm 6).
    /// Returns (x_T, xhat_T). Artifact: `acc_chunk_{cons}_n{n}_d{d}_r{r}_t{T}`.
    #[allow(clippy::too_many_arguments)]
    pub fn acc_chunk(
        &self,
        hda: &Mat,
        hdb: &[f64],
        x0: &[f64],
        xhat0: &[f64],
        pinv: &Mat,
        idx: &[Vec<usize>],
        alphas: &[f64],
        qs: &[f64],
        etas: &[f64],
        mu: f64,
        scale: f64,
        cons: &Constraint,
        metric: Option<&MetricProjector>,
    ) -> (Vec<f64>, Vec<f64>) {
        let t = idx.len();
        let r = idx.first().map(|v| v.len()).unwrap_or(0);
        let op = format!(
            "acc_chunk_{}_n{}_d{}_r{}_t{}",
            cons.tag(),
            hda.rows,
            hda.cols,
            r,
            t
        );
        let metric_active = metric.is_some() && cons.tag() != "unc";
        if cons.tag() != "box" && !metric_active {
            if let Some(e) = self.engine_with(&op) {
                self.mark(true);
                let flat: Vec<i32> = idx
                    .iter()
                    .flat_map(|row| row.iter().map(|&i| i as i32))
                    .collect();
                let out = e
                    .execute(
                        &op,
                        vec![
                            Value::Mat(hda.clone()),
                            Value::Vec(hdb.to_vec()),
                            Value::Vec(x0.to_vec()),
                            Value::Vec(xhat0.to_vec()),
                            Value::Mat(pinv.clone()),
                            Value::MatI32 {
                                rows: t,
                                cols: r,
                                data: flat,
                            },
                            Value::Vec(alphas.to_vec()),
                            Value::Vec(qs.to_vec()),
                            Value::Vec(etas.to_vec()),
                            Value::Scalar(mu),
                            Value::Scalar(scale),
                            Value::Scalar(cons.radius()),
                        ],
                    )
                    .expect("acc_chunk artifact");
                let mut it = out.into_iter();
                return (it.next().unwrap(), it.next().unwrap());
            }
        }
        self.mark(false);
        let d = hda.cols;
        let mut x = x0.to_vec();
        let mut xhat = xhat0.to_vec();
        let mut mbuf = Mat::zeros(r, d);
        let mut vbuf = vec![0.0; r];
        for (step_i, tau) in idx.iter().enumerate() {
            let (a_t, q_t, eta_t) = (alphas[step_i], qs[step_i], etas[step_i]);
            // x~ = (1 - q) xhat + q x
            let xtilde: Vec<f64> = xhat
                .iter()
                .zip(&x)
                .map(|(h, xi)| (1.0 - q_t) * h + q_t * xi)
                .collect();
            for (k, &i) in tau.iter().enumerate() {
                mbuf.row_mut(k).copy_from_slice(hda.row(i));
                vbuf[k] = hdb[i];
            }
            let c = blas::fused_grad(&mbuf, &vbuf, &xtilde, scale);
            let pc = blas::gemv(pinv, &c);
            let denom = 1.0 + eta_t * mu;
            let mut xn: Vec<f64> = (0..d)
                .map(|j| (eta_t * mu * xtilde[j] + x[j] - eta_t * pc[j]) / denom)
                .collect();
            match metric {
                Some(m) => xn = m.project(&xn, cons),
                None => cons.project(&mut xn),
            }
            for j in 0..d {
                xhat[j] = (1.0 - a_t) * xhat[j] + a_t * xn[j];
            }
            x = xn;
        }
        (x, xhat)
    }

    /// T fused pwGradient steps (Algorithm 4). Artifact:
    /// `pw_gradient_chunk_{cons}_n{n}_d{d}_t{T}`.
    #[allow(clippy::too_many_arguments)]
    pub fn pw_gradient_chunk(
        &self,
        a: &Mat,
        b: &[f64],
        x0: &[f64],
        pinv: &Mat,
        eta: f64,
        t: usize,
        cons: &Constraint,
        metric: Option<&MetricProjector>,
    ) -> Vec<f64> {
        let op = format!(
            "pw_gradient_chunk_{}_n{}_d{}_t{}",
            cons.tag(),
            a.rows,
            a.cols,
            t
        );
        let metric_active = metric.is_some() && cons.tag() != "unc";
        if cons.tag() != "box" && !metric_active {
            if let Some(e) = self.engine_with(&op) {
                self.mark(true);
                let out = e
                    .execute(
                        &op,
                        vec![
                            Value::Mat(a.clone()),
                            Value::Vec(b.to_vec()),
                            Value::Vec(x0.to_vec()),
                            Value::Mat(pinv.clone()),
                            Value::Scalar(eta),
                            Value::Scalar(cons.radius()),
                        ],
                    )
                    .expect("pw_gradient_chunk artifact");
                return out.into_iter().next().unwrap();
            }
        }
        self.mark(false);
        let mut x = x0.to_vec();
        for _ in 0..t {
            let g = blas::fused_grad(a, b, &x, 2.0);
            let step = blas::gemv(pinv, &g);
            for (xi, si) in x.iter_mut().zip(&step) {
                *xi -= eta * si;
            }
            match metric {
                Some(m) => x = m.project(&x, cons),
                None => cons.project(&mut x),
            }
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(n: usize, d: usize) -> (Mat, Vec<f64>, Vec<f64>, Mat, Rng) {
        let mut rng = Rng::new(42);
        let a = Mat::gaussian(n, d, &mut rng);
        let b = rng.gaussians(n);
        let x = rng.gaussians(d);
        // a simple SPD pinv: identity (keeps tests about plumbing, not math)
        let pinv = Mat::eye(d);
        (a, b, x, pinv, rng)
    }

    #[test]
    fn native_batch_grad_matches_fused() {
        let (a, b, x, _, _) = setup(32, 5);
        let be = Backend::native();
        let got = be.batch_grad(&a, &b, &x, 3.0);
        let want = blas::fused_grad(&a, &b, &x, 3.0);
        assert_eq!(got, want);
        assert_eq!(be.native_calls(), 1);
        assert_eq!(be.pjrt_calls(), 0);
    }

    #[test]
    fn native_full_grad_and_residual() {
        let (a, b, x, _, _) = setup(64, 4);
        let be = Backend::native();
        let g = be.full_grad(&a, &b, &x);
        assert_eq!(g, blas::fused_grad(&a, &b, &x, 2.0));
        let f = be.residual_sq(&a, &b, &x);
        assert!((f - blas::residual_sq(&a, &b, &x)).abs() < 1e-12);
    }

    #[test]
    fn native_gd_step_projects() {
        let (_, _, _, pinv, mut rng) = setup(4, 4);
        let be = Backend::native();
        let x = rng.gaussians(4);
        let g = rng.gaussians(4);
        let cons = Constraint::L2Ball { radius: 0.1 };
        let out = be.gd_step(&x, &pinv, &g, 0.5, &cons, None);
        assert!(cons.contains(&out, 1e-12));
        // unconstrained matches manual update
        let unc = be.gd_step(&x, &pinv, &g, 0.5, &Constraint::Unconstrained, None);
        for j in 0..4 {
            assert!((unc[j] - (x[j] - 0.5 * g[j])).abs() < 1e-12);
        }
    }

    #[test]
    fn native_sgd_chunk_decreases_objective() {
        let (a, _, xtrue, _, mut rng) = setup(256, 6);
        // planted solution with small noise so the optimum is well below f(0)
        let mut b = blas::gemv(&a, &xtrue);
        for v in &mut b {
            *v += 0.01 * rng.gaussian();
        }
        // well-conditioned gaussian problem: pinv = (A^T A)^{-1} via QR
        let r = crate::linalg::qr::qr_r(&a);
        let pinv = crate::linalg::tri::pinv_dense(&r);
        let be = Backend::native();
        let x0 = vec![0.0; 6];
        let t = 100;
        let rr = 8;
        let idx: Vec<Vec<usize>> = (0..t).map(|_| rng.indices(rr, 256)).collect();
        let scale = 2.0 * 256.0 / rr as f64;
        let (xt, xsum) = be.sgd_chunk(
            &a,
            &b,
            &x0,
            &pinv,
            &idx,
            0.05,
            scale,
            &Constraint::Unconstrained,
            None,
        );
        let f0 = blas::residual_sq(&a, &b, &x0);
        let ft = blas::residual_sq(&a, &b, &xt);
        assert!(
            ft < 0.2 * f0,
            "sgd made too little progress: {ft} vs {f0}"
        );
        assert_eq!(xsum.len(), 6);
    }

    #[test]
    fn native_pw_gradient_converges_linearly() {
        let (a, b, _, _, _) = setup(512, 5);
        let r = crate::linalg::qr::qr_r(&a);
        let pinv = crate::linalg::tri::pinv_dense(&r);
        let be = Backend::native();
        let x0 = vec![0.0; 5];
        let x10 =
            be.pw_gradient_chunk(&a, &b, &x0, &pinv, 0.5, 10, &Constraint::Unconstrained, None);
        // exact preconditioner + eta=1/2 solves in ONE step (Newton); after
        // 10 it must be at machine precision of the LS optimum
        let xstar = crate::linalg::qr::lstsq(&a, &b);
        for (u, v) in x10.iter().zip(&xstar) {
            assert!((u - v).abs() < 1e-8, "{u} vs {v}");
        }
    }

    #[test]
    fn native_acc_chunk_runs_and_projects() {
        let (a, b, _, _, mut rng) = setup(128, 4);
        let r = crate::linalg::qr::qr_r(&a);
        let pinv = crate::linalg::tri::pinv_dense(&r);
        let be = Backend::native();
        let t = 20;
        let rr = 4;
        let idx: Vec<Vec<usize>> = (0..t).map(|_| rng.indices(rr, 128)).collect();
        let alphas: Vec<f64> = (1..=t).map(|k| 2.0 / (k as f64 + 1.0)).collect();
        let qs = alphas.clone();
        let etas = vec![0.05; t];
        let cons = Constraint::L2Ball { radius: 0.5 };
        let (x, xhat) = be.acc_chunk(
            &a,
            &b,
            &vec![0.0; 4],
            &vec![0.0; 4],
            &pinv,
            &idx,
            &alphas,
            &qs,
            &etas,
            2.0,
            2.0 * 128.0 / rr as f64,
            &cons,
            None,
        );
        assert!(cons.contains(&x, 1e-9));
        assert_eq!(xhat.len(), 4);
    }
}
