//! The executor layer: pluggable numerical backends behind one trait.
//!
//! [`Executor`] is the op-level contract every backend implements. The
//! [`crate::backend::Backend`] facade owns a priority-ordered registry of
//! executors and routes each op to the first one whose registry claims it
//! ([`Executor::supports`] keyed by the canonical op-name strings in
//! [`opkey`]). Two implementations ship today:
//!
//! * [`NativeExecutor`] — from-scratch kernels, parallel and block-aware:
//!   dense linear algebra goes through the row-block-parallel [`blas`]
//!   kernels, and sketch application streams [`crate::data::RowBlocks`] shards through
//!   worker threads (`sketch::apply_streamed`), counting every shard folded
//!   in [`DispatchStats::native_block_calls`]. Supports every op.
//! * [`SimdExecutor`] — the same op surface served by the arch-dispatched
//!   register-tiled kernels in [`crate::simd`] (AVX2/AVX-512/NEON with a
//!   bit-faithful scalar fallback). Native stays the bit-exact reference;
//!   this executor agrees within the parity suite's documented tolerance.
//!   Supports every op, including metric projections (the projection code
//!   itself is shared scalar code — only the kernels differ).
//! * [`PjrtExecutor`] — dispatches to AOT-compiled PJRT artifacts when the
//!   op name is in the manifest. Claims nothing else.
//!
//! The shared per-step control flow (gradient step, SGD/accelerated/pw
//! chunk loops) lives in private `*_driver` functions parameterized by the
//! two kernels that differ (`fused_grad`, `gemv`): native and simd run the
//! *same* projection/update code, so their only divergence is floating-point
//! re-association inside the kernels.
//!
//! A fourth backend (GPU, remote) plugs in by implementing this trait and
//! registering with the facade — no solver code changes.

// The op signatures mirror the PJRT artifact calling conventions; several
// ops legitimately take >7 scalars/arrays.
#![allow(clippy::too_many_arguments)]

use crate::constraints::ConstraintSet;
use crate::linalg::{blas, CsrMat, Mat};
use crate::prox::metric::MetricProjector;
use crate::runtime::literal::Value;
use crate::runtime::EngineHandle;
use crate::simd;
use crate::sketch::{apply_streamed, apply_streamed_csr, apply_streamed_with, Sketch};
use crate::util::threadpool::default_threads;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Canonical op-name keys: the shared vocabulary between the facade's
/// registry lookups and the PJRT manifest.
pub mod opkey {
    use crate::constraints::ConstraintSet;

    /// Key for the randomized-Hadamard transform of the packed `[A | b]`.
    pub fn hd_transform(n: usize, cols: usize) -> String {
        format!("hd_transform_n{n}_c{cols}")
    }

    /// Key for the mini-batch gradient at batch size `r`.
    pub fn batch_grad(r: usize, d: usize) -> String {
        format!("batch_grad_r{r}_d{d}")
    }

    /// Key for the full gradient `2 A^T (A x - b)`.
    pub fn full_grad(n: usize, d: usize) -> String {
        format!("full_grad_n{n}_d{d}")
    }

    /// Key for the residual objective `||Ax - b||^2`.
    pub fn residual_sq(n: usize, d: usize) -> String {
        format!("residual_sq_n{n}_d{d}")
    }

    /// Key for one projected gradient step under `cons`.
    pub fn gd_step(cons: &dyn ConstraintSet, d: usize) -> String {
        format!("gd_step_{}_d{}", cons.tag(), d)
    }

    /// Key for `t` fused mini-batch SGD steps (Algorithm 2).
    pub fn sgd_chunk(cons: &dyn ConstraintSet, n: usize, d: usize, r: usize, t: usize) -> String {
        format!("sgd_chunk_{}_n{}_d{}_r{}_t{}", cons.tag(), n, d, r, t)
    }

    /// Key for `t` fused accelerated mini-batch steps (Algorithm 6).
    pub fn acc_chunk(cons: &dyn ConstraintSet, n: usize, d: usize, r: usize, t: usize) -> String {
        format!("acc_chunk_{}_n{}_d{}_r{}_t{}", cons.tag(), n, d, r, t)
    }

    /// Key for `t` fused pwGradient steps (Algorithm 4).
    pub fn pw_gradient_chunk(cons: &dyn ConstraintSet, n: usize, d: usize, t: usize) -> String {
        format!("pw_gradient_chunk_{}_n{}_d{}_t{}", cons.tag(), n, d, t)
    }

    /// Key for the dense sketch application `S A`.
    pub fn sketch_apply(s: usize, n: usize, d: usize) -> String {
        format!("sketch_apply_s{s}_n{n}_d{d}")
    }

    /// Key for the CSR sketch application (keyed by nnz, not rows).
    pub fn sketch_apply_csr(s: usize, nnz: usize, d: usize) -> String {
        format!("sketch_apply_csr_s{s}_nnz{nnz}_d{d}")
    }
}

/// Which [`DispatchStats`] bucket an executor's dispatches land in.
/// Third-party executors pick a class instead of spoofing a name.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecClass {
    /// Bit-exact reference kernels ([`DispatchStats::native_calls`]).
    Native,
    /// Arch-dispatched SIMD kernels ([`DispatchStats::simd_calls`]).
    Simd,
    /// Offloaded/compiled artifacts ([`DispatchStats::pjrt_calls`]).
    Accelerated,
}

/// Dispatch counters (observability + tests).
#[derive(Debug, Default)]
pub struct DispatchStats {
    /// Ops served by the PJRT executor.
    pub pjrt_calls: AtomicUsize,
    /// Ops served by the native executor.
    pub native_calls: AtomicUsize,
    /// Ops served by the simd executor.
    pub simd_calls: AtomicUsize,
    /// Row shards folded by block-streamed paths (sketch folds), native or
    /// simd.
    pub native_block_calls: AtomicUsize,
    /// Why `Backend::auto()` fell back to native (None when PJRT loaded).
    pub pjrt_fallback_reason: Mutex<Option<String>>,
}

impl DispatchStats {
    /// Count one dispatched op in `class`'s bucket.
    pub fn mark(&self, class: ExecClass) {
        match class {
            ExecClass::Accelerated => self.pjrt_calls.fetch_add(1, Ordering::Relaxed),
            ExecClass::Simd => self.simd_calls.fetch_add(1, Ordering::Relaxed),
            ExecClass::Native => self.native_calls.fetch_add(1, Ordering::Relaxed),
        };
    }

    /// Count `shards` row shards folded by a block-streamed path.
    pub fn add_block_calls(&self, shards: usize) {
        self.native_block_calls.fetch_add(shards, Ordering::Relaxed);
    }

    /// Record why `Backend::auto()` fell back to native.
    pub fn set_fallback_reason(&self, reason: String) {
        *self.pjrt_fallback_reason.lock().unwrap() = Some(reason);
    }

    /// The recorded fallback reason, if any.
    pub fn fallback_reason(&self) -> Option<String> {
        self.pjrt_fallback_reason.lock().unwrap().clone()
    }

    /// Fold another stats block's counters into this one. Per-request
    /// backend forks are absorbed into the shared backend's stats after the
    /// job, so service-level dashboards see pinned-executor work too.
    pub fn absorb(&self, other: &DispatchStats) {
        self.pjrt_calls
            .fetch_add(other.pjrt_calls.load(Ordering::Relaxed), Ordering::Relaxed);
        self.native_calls
            .fetch_add(other.native_calls.load(Ordering::Relaxed), Ordering::Relaxed);
        self.simd_calls
            .fetch_add(other.simd_calls.load(Ordering::Relaxed), Ordering::Relaxed);
        self.native_block_calls.fetch_add(
            other.native_block_calls.load(Ordering::Relaxed),
            Ordering::Relaxed,
        );
    }
}

/// One numerical backend: executes ops it `supports`.
///
/// Constrained-step caveat: the PJRT artifacts implement the Euclidean
/// unc/l1/l2 projections only, so the facade never routes a call with an
/// active R-metric projector (or a set whose
/// [`ConstraintSet::accel_eligible`] is false — boxes, the simplex, the
/// orthant, elastic-net balls, affine equalities) to an executor whose
/// [`Executor::handles_all_projections`] is false — such implementations
/// may assume `metric` is inactive.
pub trait Executor: Send + Sync {
    /// Registry identity ("native", "simd", "pjrt", ...) — display only,
    /// never used for dispatch or stats decisions.
    fn name(&self) -> &'static str;

    /// Which stats bucket dispatches served by this executor land in.
    /// Third-party executors pick a class here instead of spoofing a name.
    fn class(&self) -> ExecClass {
        ExecClass::Native
    }

    /// Whether this executor implements every constraint projection and the
    /// R-metric projector (i.e. runs the shared scalar projection code).
    /// The facade routes projection-restricted calls only to executors that
    /// return true; artifact backends with baked-in Euclidean projections
    /// return false.
    fn handles_all_projections(&self) -> bool {
        true
    }

    /// Op-registry membership for a canonical [`opkey`] string.
    fn supports(&self, op: &str) -> bool;

    /// Randomized-Hadamard transform of the packed [A | b] (rows must be a
    /// power of two).
    fn hd_transform(&self, aug: &Mat, signs: &[f64]) -> Mat;

    /// In-place randomized-Hadamard for the streaming pipeline. Default:
    /// delegates to [`Executor::hd_transform`] (artifact semantics produce a
    /// fresh buffer); memory-aware executors override to transform in place
    /// so the padded [A | b] is the *only* materialization.
    fn hd_transform_mut(&self, aug: &mut Mat, signs: &[f64]) {
        *aug = self.hd_transform(aug, signs);
    }

    /// Mini-batch gradient c = scale * M^T (M x - v).
    fn batch_grad(&self, m: &Mat, v: &[f64], x: &[f64], scale: f64) -> Vec<f64>;

    /// Full gradient g = 2 A^T (A x - b).
    fn full_grad(&self, a: &Mat, b: &[f64], x: &[f64]) -> Vec<f64>;

    /// f(x) = ||Ax - b||^2.
    fn residual_sq(&self, a: &Mat, b: &[f64], x: &[f64]) -> f64;

    /// f(x_k) = ||A x_k - b||^2 for a batch of iterates. Default: one
    /// [`Executor::residual_sq`] call per iterate, making every column
    /// trivially bitwise-equal to the serial call. Executors with a fused
    /// multi-iterate kernel may override, but the override must preserve
    /// each column's per-row operation order — the fused-trials driver's
    /// bit-identity contract depends on it.
    fn residual_sq_multi(&self, a: &Mat, b: &[f64], xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.residual_sq(a, b, x)).collect()
    }

    /// One preconditioned gradient step x <- P_W(x - eta * pinv g).
    fn gd_step(
        &self,
        x: &[f64],
        pinv: &Mat,
        g: &[f64],
        eta: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> Vec<f64>;

    /// T fused mini-batch SGD steps (Algorithm 2, steps 3-7); returns
    /// (x_T, sum of x_t).
    fn sgd_chunk(
        &self,
        hda: &Mat,
        hdb: &[f64],
        x0: &[f64],
        pinv: &Mat,
        idx: &[Vec<usize>],
        eta: f64,
        scale: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> (Vec<f64>, Vec<f64>);

    /// T fused accelerated (Ghadimi-Lan) mini-batch steps (Algorithm 6);
    /// returns (x_T, xhat_T).
    fn acc_chunk(
        &self,
        hda: &Mat,
        hdb: &[f64],
        x0: &[f64],
        xhat0: &[f64],
        pinv: &Mat,
        idx: &[Vec<usize>],
        alphas: &[f64],
        qs: &[f64],
        etas: &[f64],
        mu: f64,
        scale: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> (Vec<f64>, Vec<f64>);

    /// T fused pwGradient steps (Algorithm 4).
    fn pw_gradient_chunk(
        &self,
        a: &Mat,
        b: &[f64],
        x0: &[f64],
        pinv: &Mat,
        eta: f64,
        t: usize,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> Vec<f64>;

    /// Compute `S A` for the preconditioner. Default: dense single pass;
    /// block-aware executors override to stream shards.
    fn sketch_apply(
        &self,
        sk: &(dyn Sketch + Send + Sync),
        a: &Mat,
        block_rows: Option<usize>,
    ) -> Mat {
        let _ = block_rows;
        sk.apply(a)
    }

    /// Compute `S A` for a CSR matrix — the input-sparsity-time setup path.
    /// Default: the sketch's own `apply_csr` single pass (O(nnz) for hash
    /// sketches); block-aware executors override to stream nnz-balanced
    /// shards. `block_nnz` is the per-shard stored-entry budget (None =
    /// heuristic).
    fn sketch_apply_csr(
        &self,
        sk: &(dyn Sketch + Send + Sync),
        a: &CsrMat,
        block_nnz: Option<usize>,
    ) -> Mat {
        let _ = block_nnz;
        sk.apply_csr(a)
    }
}

// ---------------------------------------------------------------------------
// shared chunk drivers
// ---------------------------------------------------------------------------
//
// The CPU executors (native, simd) differ only in which fused-gradient and
// gemv kernels they call; the step/projection control flow is identical and
// lives here exactly once. Native passes the `blas` kernels, so extracting
// these drivers is bit-preserving for the golden fixtures.

/// `scale * M^T (M x - v)` kernel signature shared by the CPU executors.
type FusedGradFn<'a> = &'a (dyn Fn(&Mat, &[f64], &[f64], f64) -> Vec<f64> + 'a);
/// `M x` kernel signature shared by the CPU executors.
type GemvFn<'a> = &'a (dyn Fn(&Mat, &[f64]) -> Vec<f64> + 'a);

fn gd_step_driver(
    gemv: GemvFn,
    x: &[f64],
    pinv: &Mat,
    g: &[f64],
    eta: f64,
    cons: &dyn ConstraintSet,
    metric: Option<&MetricProjector>,
) -> Vec<f64> {
    let step = gemv(pinv, g);
    let mut out = x.to_vec();
    for (o, s) in out.iter_mut().zip(&step) {
        *o -= eta * s;
    }
    match metric {
        Some(m) => m.project(&out, cons),
        None => {
            cons.project(&mut out);
            out
        }
    }
}

fn sgd_chunk_driver(
    fused_grad: FusedGradFn,
    gemv: GemvFn,
    hda: &Mat,
    hdb: &[f64],
    x0: &[f64],
    pinv: &Mat,
    idx: &[Vec<usize>],
    eta: f64,
    scale: f64,
    cons: &dyn ConstraintSet,
    metric: Option<&MetricProjector>,
) -> (Vec<f64>, Vec<f64>) {
    let r = idx.first().map(|v| v.len()).unwrap_or(0);
    let d = hda.cols;
    let mut x = x0.to_vec();
    let mut xsum = vec![0.0; d];
    let mut mbuf = Mat::zeros(r, d);
    let mut vbuf = vec![0.0; r];
    for tau in idx {
        for (k, &i) in tau.iter().enumerate() {
            mbuf.row_mut(k).copy_from_slice(hda.row(i));
            vbuf[k] = hdb[i];
        }
        let c = fused_grad(&mbuf, &vbuf, &x, scale);
        let step = gemv(pinv, &c);
        for (xi, si) in x.iter_mut().zip(&step) {
            *xi -= eta * si;
        }
        match metric {
            Some(m) => x = m.project(&x, cons),
            None => cons.project(&mut x),
        }
        for (s, xi) in xsum.iter_mut().zip(&x) {
            *s += xi;
        }
    }
    (x, xsum)
}

fn acc_chunk_driver(
    fused_grad: FusedGradFn,
    gemv: GemvFn,
    hda: &Mat,
    hdb: &[f64],
    x0: &[f64],
    xhat0: &[f64],
    pinv: &Mat,
    idx: &[Vec<usize>],
    alphas: &[f64],
    qs: &[f64],
    etas: &[f64],
    mu: f64,
    scale: f64,
    cons: &dyn ConstraintSet,
    metric: Option<&MetricProjector>,
) -> (Vec<f64>, Vec<f64>) {
    let r = idx.first().map(|v| v.len()).unwrap_or(0);
    let d = hda.cols;
    let mut x = x0.to_vec();
    let mut xhat = xhat0.to_vec();
    let mut mbuf = Mat::zeros(r, d);
    let mut vbuf = vec![0.0; r];
    for (step_i, tau) in idx.iter().enumerate() {
        let (a_t, q_t, eta_t) = (alphas[step_i], qs[step_i], etas[step_i]);
        // x~ = (1 - q) xhat + q x
        let xtilde: Vec<f64> = xhat
            .iter()
            .zip(&x)
            .map(|(h, xi)| (1.0 - q_t) * h + q_t * xi)
            .collect();
        for (k, &i) in tau.iter().enumerate() {
            mbuf.row_mut(k).copy_from_slice(hda.row(i));
            vbuf[k] = hdb[i];
        }
        let c = fused_grad(&mbuf, &vbuf, &xtilde, scale);
        let pc = gemv(pinv, &c);
        let denom = 1.0 + eta_t * mu;
        let mut xn: Vec<f64> = (0..d)
            .map(|j| (eta_t * mu * xtilde[j] + x[j] - eta_t * pc[j]) / denom)
            .collect();
        match metric {
            Some(m) => xn = m.project(&xn, cons),
            None => cons.project(&mut xn),
        }
        for j in 0..d {
            xhat[j] = (1.0 - a_t) * xhat[j] + a_t * xn[j];
        }
        x = xn;
    }
    (x, xhat)
}

fn pw_gradient_chunk_driver(
    fused_grad: FusedGradFn,
    gemv: GemvFn,
    a: &Mat,
    b: &[f64],
    x0: &[f64],
    pinv: &Mat,
    eta: f64,
    t: usize,
    cons: &dyn ConstraintSet,
    metric: Option<&MetricProjector>,
) -> Vec<f64> {
    let mut x = x0.to_vec();
    for _ in 0..t {
        let g = fused_grad(a, b, &x, 2.0);
        let step = gemv(pinv, &g);
        for (xi, si) in x.iter_mut().zip(&step) {
            *xi -= eta * si;
        }
        match metric {
            Some(m) => x = m.project(&x, cons),
            None => cons.project(&mut x),
        }
    }
    x
}

// ---------------------------------------------------------------------------
// NativeExecutor
// ---------------------------------------------------------------------------

/// The from-scratch backend: parallel, block-aware, supports every op.
pub struct NativeExecutor {
    threads: usize,
    /// Default shard height for streamed ops (None = per-shape heuristic);
    /// a per-call `block_rows` overrides it.
    block_rows: Option<usize>,
    stats: Arc<DispatchStats>,
}

impl NativeExecutor {
    /// Native executor with default thread count and heuristic shard height.
    pub fn new(stats: Arc<DispatchStats>) -> NativeExecutor {
        NativeExecutor {
            threads: default_threads(),
            block_rows: None,
            stats,
        }
    }

    /// Override the worker count and default shard height (tests, tuning).
    pub fn with_tuning(
        stats: Arc<DispatchStats>,
        threads: usize,
        block_rows: Option<usize>,
    ) -> NativeExecutor {
        NativeExecutor {
            threads: threads.max(1),
            block_rows,
            stats,
        }
    }
}

impl Executor for NativeExecutor {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports(&self, _op: &str) -> bool {
        true
    }

    fn hd_transform(&self, aug: &Mat, signs: &[f64]) -> Mat {
        let mut m = aug.clone();
        crate::sketch::fwht::randomized_hadamard(&mut m, signs);
        m
    }

    fn hd_transform_mut(&self, aug: &mut Mat, signs: &[f64]) {
        crate::sketch::fwht::randomized_hadamard(aug, signs);
    }

    fn batch_grad(&self, m: &Mat, v: &[f64], x: &[f64], scale: f64) -> Vec<f64> {
        blas::fused_grad(m, v, x, scale)
    }

    fn full_grad(&self, a: &Mat, b: &[f64], x: &[f64]) -> Vec<f64> {
        blas::fused_grad(a, b, x, 2.0)
    }

    fn residual_sq(&self, a: &Mat, b: &[f64], x: &[f64]) -> f64 {
        blas::residual_sq(a, b, x)
    }

    /// Fused multi-iterate objective: one pass over `A`, each column
    /// bitwise-equal to the serial `blas::residual_sq` (see that kernel's
    /// docs for the ordering contract).
    fn residual_sq_multi(&self, a: &Mat, b: &[f64], xs: &[Vec<f64>]) -> Vec<f64> {
        blas::residual_sq_multi(a, b, xs)
    }

    fn gd_step(
        &self,
        x: &[f64],
        pinv: &Mat,
        g: &[f64],
        eta: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> Vec<f64> {
        gd_step_driver(&blas::gemv, x, pinv, g, eta, cons, metric)
    }

    fn sgd_chunk(
        &self,
        hda: &Mat,
        hdb: &[f64],
        x0: &[f64],
        pinv: &Mat,
        idx: &[Vec<usize>],
        eta: f64,
        scale: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> (Vec<f64>, Vec<f64>) {
        sgd_chunk_driver(
            &blas::fused_grad,
            &blas::gemv,
            hda,
            hdb,
            x0,
            pinv,
            idx,
            eta,
            scale,
            cons,
            metric,
        )
    }

    fn acc_chunk(
        &self,
        hda: &Mat,
        hdb: &[f64],
        x0: &[f64],
        xhat0: &[f64],
        pinv: &Mat,
        idx: &[Vec<usize>],
        alphas: &[f64],
        qs: &[f64],
        etas: &[f64],
        mu: f64,
        scale: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> (Vec<f64>, Vec<f64>) {
        acc_chunk_driver(
            &blas::fused_grad,
            &blas::gemv,
            hda,
            hdb,
            x0,
            xhat0,
            pinv,
            idx,
            alphas,
            qs,
            etas,
            mu,
            scale,
            cons,
            metric,
        )
    }

    fn pw_gradient_chunk(
        &self,
        a: &Mat,
        b: &[f64],
        x0: &[f64],
        pinv: &Mat,
        eta: f64,
        t: usize,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> Vec<f64> {
        pw_gradient_chunk_driver(
            &blas::fused_grad,
            &blas::gemv,
            a,
            b,
            x0,
            pinv,
            eta,
            t,
            cons,
            metric,
        )
    }

    /// Block-streamed sketch application: shards are folded on worker
    /// threads and merged deterministically; every shard folded is counted
    /// in `DispatchStats::native_block_calls`. Dense-fallback passes (SRHT,
    /// single shard, empty input) fold zero shards and count zero — the
    /// counter means "the block-streamed path ran", nothing else.
    fn sketch_apply(
        &self,
        sk: &(dyn Sketch + Send + Sync),
        a: &Mat,
        block_rows: Option<usize>,
    ) -> Mat {
        let br = block_rows.or(self.block_rows);
        let (sa, shards) = apply_streamed(sk, a, br, self.threads);
        if shards > 1 {
            self.stats.add_block_calls(shards);
        }
        sa
    }

    /// nnz-sharded streamed CSR sketch application; shards folded count in
    /// `DispatchStats::native_block_calls` exactly like the dense path.
    /// When no explicit nnz budget arrives, the executor's default row
    /// tuning (if any) is translated via the mean row occupancy, so
    /// per-backend `block_rows` tuning means the same thing in both
    /// representations.
    fn sketch_apply_csr(
        &self,
        sk: &(dyn Sketch + Send + Sync),
        a: &CsrMat,
        block_nnz: Option<usize>,
    ) -> Mat {
        let bn = block_nnz.or_else(|| self.block_rows.map(|br| a.nnz_budget_for_rows(br)));
        let (sa, shards) = apply_streamed_csr(sk, a, bn, self.threads);
        if shards > 1 {
            self.stats.add_block_calls(shards);
        }
        sa
    }
}

// ---------------------------------------------------------------------------
// SimdExecutor
// ---------------------------------------------------------------------------

/// The arch-dispatched SIMD backend: every op native supports, served by
/// the register-tiled kernels in [`crate::simd`] (AVX2/AVX-512/NEON,
/// bit-faithful scalar fallback).
///
/// Shares the `*_driver` control flow with [`NativeExecutor`], so the only
/// divergence from the bit-exact native reference is floating-point
/// re-association inside the kernels — gated by the `simd_parity` suite at
/// a documented relative tolerance. Handles all projections (that code is
/// shared and scalar).
pub struct SimdExecutor {
    threads: usize,
    /// Default shard height for streamed ops (None = per-shape heuristic);
    /// a per-call `block_rows` overrides it.
    block_rows: Option<usize>,
    stats: Arc<DispatchStats>,
}

impl SimdExecutor {
    /// Simd executor with default thread count and heuristic shard height.
    pub fn new(stats: Arc<DispatchStats>) -> SimdExecutor {
        SimdExecutor {
            threads: default_threads(),
            block_rows: None,
            stats,
        }
    }

    /// Override the worker count and default shard height (tests, tuning).
    pub fn with_tuning(
        stats: Arc<DispatchStats>,
        threads: usize,
        block_rows: Option<usize>,
    ) -> SimdExecutor {
        SimdExecutor {
            threads: threads.max(1),
            block_rows,
            stats,
        }
    }
}

impl Executor for SimdExecutor {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn class(&self) -> ExecClass {
        ExecClass::Simd
    }

    fn supports(&self, _op: &str) -> bool {
        true
    }

    fn hd_transform(&self, aug: &Mat, signs: &[f64]) -> Mat {
        let mut m = aug.clone();
        simd::randomized_hadamard(&mut m, signs, self.threads);
        m
    }

    fn hd_transform_mut(&self, aug: &mut Mat, signs: &[f64]) {
        simd::randomized_hadamard(aug, signs, self.threads);
    }

    fn batch_grad(&self, m: &Mat, v: &[f64], x: &[f64], scale: f64) -> Vec<f64> {
        simd::fused_grad(m, v, x, scale, self.threads)
    }

    fn full_grad(&self, a: &Mat, b: &[f64], x: &[f64]) -> Vec<f64> {
        simd::fused_grad(a, b, x, 2.0, self.threads)
    }

    fn residual_sq(&self, a: &Mat, b: &[f64], x: &[f64]) -> f64 {
        simd::residual_sq(a, b, x, self.threads)
    }

    fn gd_step(
        &self,
        x: &[f64],
        pinv: &Mat,
        g: &[f64],
        eta: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> Vec<f64> {
        gd_step_driver(
            &|m, v| simd::gemv(m, v, self.threads),
            x,
            pinv,
            g,
            eta,
            cons,
            metric,
        )
    }

    fn sgd_chunk(
        &self,
        hda: &Mat,
        hdb: &[f64],
        x0: &[f64],
        pinv: &Mat,
        idx: &[Vec<usize>],
        eta: f64,
        scale: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> (Vec<f64>, Vec<f64>) {
        sgd_chunk_driver(
            &|m, v, x, s| simd::fused_grad(m, v, x, s, self.threads),
            &|m, v| simd::gemv(m, v, self.threads),
            hda,
            hdb,
            x0,
            pinv,
            idx,
            eta,
            scale,
            cons,
            metric,
        )
    }

    fn acc_chunk(
        &self,
        hda: &Mat,
        hdb: &[f64],
        x0: &[f64],
        xhat0: &[f64],
        pinv: &Mat,
        idx: &[Vec<usize>],
        alphas: &[f64],
        qs: &[f64],
        etas: &[f64],
        mu: f64,
        scale: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> (Vec<f64>, Vec<f64>) {
        acc_chunk_driver(
            &|m, v, x, s| simd::fused_grad(m, v, x, s, self.threads),
            &|m, v| simd::gemv(m, v, self.threads),
            hda,
            hdb,
            x0,
            xhat0,
            pinv,
            idx,
            alphas,
            qs,
            etas,
            mu,
            scale,
            cons,
            metric,
        )
    }

    fn pw_gradient_chunk(
        &self,
        a: &Mat,
        b: &[f64],
        x0: &[f64],
        pinv: &Mat,
        eta: f64,
        t: usize,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> Vec<f64> {
        pw_gradient_chunk_driver(
            &|m, v, x, s| simd::fused_grad(m, v, x, s, self.threads),
            &|m, v| simd::gemv(m, v, self.threads),
            a,
            b,
            x0,
            pinv,
            eta,
            t,
            cons,
            metric,
        )
    }

    /// Block-streamed sketch application with the simd row-scatter
    /// primitives threaded through (`sketch::apply_streamed_with`). Shards
    /// folded count in `DispatchStats::native_block_calls` exactly like the
    /// native path — the counter means "the block-streamed path ran".
    fn sketch_apply(
        &self,
        sk: &(dyn Sketch + Send + Sync),
        a: &Mat,
        block_rows: Option<usize>,
    ) -> Mat {
        let br = block_rows.or(self.block_rows);
        let (sa, shards) = apply_streamed_with(sk, a, br, self.threads, &simd::row_ops());
        if shards > 1 {
            self.stats.add_block_calls(shards);
        }
        sa
    }

    /// nnz-sharded streamed CSR sketch application. The CSR scatter is an
    /// irregular per-entry update that does not vectorize profitably, so
    /// this is the same scalar path native runs (and bit-identical to it).
    fn sketch_apply_csr(
        &self,
        sk: &(dyn Sketch + Send + Sync),
        a: &CsrMat,
        block_nnz: Option<usize>,
    ) -> Mat {
        let bn = block_nnz.or_else(|| self.block_rows.map(|br| a.nnz_budget_for_rows(br)));
        let (sa, shards) = apply_streamed_csr(sk, a, bn, self.threads);
        if shards > 1 {
            self.stats.add_block_calls(shards);
        }
        sa
    }
}

// ---------------------------------------------------------------------------
// PjrtExecutor
// ---------------------------------------------------------------------------

/// The artifact backend: executes ops whose canonical name is in the loaded
/// PJRT manifest. The facade guarantees eligibility (no metric projection,
/// no box constraints) before routing here.
pub struct PjrtExecutor {
    engine: EngineHandle,
}

impl PjrtExecutor {
    /// Artifact executor over a loaded PJRT engine.
    pub fn new(engine: EngineHandle) -> PjrtExecutor {
        PjrtExecutor { engine }
    }

    /// The underlying engine handle (manifest inspection, tests).
    pub fn engine(&self) -> &EngineHandle {
        &self.engine
    }

    fn flat_idx(idx: &[Vec<usize>]) -> Vec<i32> {
        idx.iter()
            .flat_map(|row| row.iter().map(|&i| i as i32))
            .collect()
    }
}

impl Executor for PjrtExecutor {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn class(&self) -> ExecClass {
        ExecClass::Accelerated
    }

    fn handles_all_projections(&self) -> bool {
        // artifacts bake in the Euclidean unc/l1/l2 projections only
        false
    }

    fn supports(&self, op: &str) -> bool {
        self.engine.has_op(op)
    }

    fn hd_transform(&self, aug: &Mat, signs: &[f64]) -> Mat {
        let op = opkey::hd_transform(aug.rows, aug.cols);
        let out = self
            .engine
            .execute(&op, vec![Value::Mat(aug.clone()), Value::Vec(signs.to_vec())])
            .expect("hd_transform artifact");
        Mat::from_vec(aug.rows, aug.cols, out.into_iter().next().unwrap())
    }

    fn batch_grad(&self, m: &Mat, v: &[f64], x: &[f64], scale: f64) -> Vec<f64> {
        let op = opkey::batch_grad(m.rows, m.cols);
        let out = self
            .engine
            .execute(
                &op,
                vec![
                    Value::Mat(m.clone()),
                    Value::Vec(v.to_vec()),
                    Value::Vec(x.to_vec()),
                    Value::Scalar(scale),
                ],
            )
            .expect("batch_grad artifact");
        out.into_iter().next().unwrap()
    }

    fn full_grad(&self, a: &Mat, b: &[f64], x: &[f64]) -> Vec<f64> {
        let op = opkey::full_grad(a.rows, a.cols);
        let out = self
            .engine
            .execute(
                &op,
                vec![
                    Value::Mat(a.clone()),
                    Value::Vec(b.to_vec()),
                    Value::Vec(x.to_vec()),
                ],
            )
            .expect("full_grad artifact");
        out.into_iter().next().unwrap()
    }

    fn residual_sq(&self, a: &Mat, b: &[f64], x: &[f64]) -> f64 {
        let op = opkey::residual_sq(a.rows, a.cols);
        let out = self
            .engine
            .execute(
                &op,
                vec![
                    Value::Mat(a.clone()),
                    Value::Vec(b.to_vec()),
                    Value::Vec(x.to_vec()),
                ],
            )
            .expect("residual_sq artifact");
        out[0][0]
    }

    fn gd_step(
        &self,
        x: &[f64],
        pinv: &Mat,
        g: &[f64],
        eta: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> Vec<f64> {
        debug_assert!(
            metric.is_none() || cons.is_unconstrained(),
            "facade must not route metric projections to PJRT"
        );
        let op = opkey::gd_step(cons, x.len());
        let out = self
            .engine
            .execute(
                &op,
                vec![
                    Value::Vec(x.to_vec()),
                    Value::Mat(pinv.clone()),
                    Value::Vec(g.to_vec()),
                    Value::Scalar(eta),
                    Value::Scalar(cons.radius()),
                ],
            )
            .expect("gd_step artifact");
        out.into_iter().next().unwrap()
    }

    fn sgd_chunk(
        &self,
        hda: &Mat,
        hdb: &[f64],
        x0: &[f64],
        pinv: &Mat,
        idx: &[Vec<usize>],
        eta: f64,
        scale: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> (Vec<f64>, Vec<f64>) {
        debug_assert!(metric.is_none() || cons.is_unconstrained());
        let t = idx.len();
        let r = idx.first().map(|v| v.len()).unwrap_or(0);
        let op = opkey::sgd_chunk(cons, hda.rows, hda.cols, r, t);
        let out = self
            .engine
            .execute(
                &op,
                vec![
                    Value::Mat(hda.clone()),
                    Value::Vec(hdb.to_vec()),
                    Value::Vec(x0.to_vec()),
                    Value::Mat(pinv.clone()),
                    Value::MatI32 {
                        rows: t,
                        cols: r,
                        data: Self::flat_idx(idx),
                    },
                    Value::Scalar(eta),
                    Value::Scalar(scale),
                    Value::Scalar(cons.radius()),
                ],
            )
            .expect("sgd_chunk artifact");
        let mut it = out.into_iter();
        (it.next().unwrap(), it.next().unwrap())
    }

    fn acc_chunk(
        &self,
        hda: &Mat,
        hdb: &[f64],
        x0: &[f64],
        xhat0: &[f64],
        pinv: &Mat,
        idx: &[Vec<usize>],
        alphas: &[f64],
        qs: &[f64],
        etas: &[f64],
        mu: f64,
        scale: f64,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> (Vec<f64>, Vec<f64>) {
        debug_assert!(metric.is_none() || cons.is_unconstrained());
        let t = idx.len();
        let r = idx.first().map(|v| v.len()).unwrap_or(0);
        let op = opkey::acc_chunk(cons, hda.rows, hda.cols, r, t);
        let out = self
            .engine
            .execute(
                &op,
                vec![
                    Value::Mat(hda.clone()),
                    Value::Vec(hdb.to_vec()),
                    Value::Vec(x0.to_vec()),
                    Value::Vec(xhat0.to_vec()),
                    Value::Mat(pinv.clone()),
                    Value::MatI32 {
                        rows: t,
                        cols: r,
                        data: Self::flat_idx(idx),
                    },
                    Value::Vec(alphas.to_vec()),
                    Value::Vec(qs.to_vec()),
                    Value::Vec(etas.to_vec()),
                    Value::Scalar(mu),
                    Value::Scalar(scale),
                    Value::Scalar(cons.radius()),
                ],
            )
            .expect("acc_chunk artifact");
        let mut it = out.into_iter();
        (it.next().unwrap(), it.next().unwrap())
    }

    fn pw_gradient_chunk(
        &self,
        a: &Mat,
        b: &[f64],
        x0: &[f64],
        pinv: &Mat,
        eta: f64,
        t: usize,
        cons: &dyn ConstraintSet,
        metric: Option<&MetricProjector>,
    ) -> Vec<f64> {
        debug_assert!(metric.is_none() || cons.is_unconstrained());
        let op = opkey::pw_gradient_chunk(cons, a.rows, a.cols, t);
        let out = self
            .engine
            .execute(
                &op,
                vec![
                    Value::Mat(a.clone()),
                    Value::Vec(b.to_vec()),
                    Value::Vec(x0.to_vec()),
                    Value::Mat(pinv.clone()),
                    Value::Scalar(eta),
                    Value::Scalar(cons.radius()),
                ],
            )
            .expect("pw_gradient_chunk artifact");
        out.into_iter().next().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn native_supports_everything_and_counts_blocks() {
        let stats = Arc::new(DispatchStats::default());
        let ex = NativeExecutor::with_tuning(Arc::clone(&stats), 4, Some(16));
        assert!(ex.supports("anything_at_all"));
        assert_eq!(ex.name(), "native");
        let mut rng = Rng::new(1);
        let a = Mat::gaussian(200, 4, &mut rng);
        let sk = crate::sketch::SketchKind::CountSketch.build(32, 200, &mut rng);
        let sa = ex.sketch_apply(sk.as_ref(), &a, None);
        let dense = sk.apply(&a);
        assert!(sa.max_abs_diff(&dense) < 1e-12);
        // 200 rows / 16-row shards = 13 shards folded
        assert_eq!(stats.native_block_calls.load(Ordering::Relaxed), 13);
    }

    #[test]
    fn dense_fallback_does_not_count_block_calls() {
        let stats = Arc::new(DispatchStats::default());
        let ex = NativeExecutor::with_tuning(Arc::clone(&stats), 4, Some(16));
        let mut rng = Rng::new(5);
        let a = Mat::gaussian(200, 4, &mut rng);
        // SRHT: documented dense fallback — folds zero shards
        let srht = crate::sketch::SketchKind::Srht.build(32, 200, &mut rng);
        let _ = ex.sketch_apply(srht.as_ref(), &a, None);
        // single-shard streamable sketch: also a dense pass
        let cs = crate::sketch::SketchKind::CountSketch.build(32, 200, &mut rng);
        let _ = ex.sketch_apply(cs.as_ref(), &a, Some(4096));
        assert_eq!(stats.native_block_calls.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn per_call_block_rows_overrides_executor_default() {
        let stats = Arc::new(DispatchStats::default());
        let ex = NativeExecutor::with_tuning(Arc::clone(&stats), 2, Some(64));
        let mut rng = Rng::new(2);
        let a = Mat::gaussian(128, 3, &mut rng);
        let sk = crate::sketch::SketchKind::SparseEmbed.build(24, 128, &mut rng);
        let _ = ex.sketch_apply(sk.as_ref(), &a, Some(32));
        assert_eq!(stats.native_block_calls.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn simd_executor_matches_native_within_tolerance() {
        let stats = Arc::new(DispatchStats::default());
        let native = NativeExecutor::with_tuning(Arc::clone(&stats), 2, None);
        let simd_ex = SimdExecutor::with_tuning(Arc::clone(&stats), 2, None);
        assert_eq!(simd_ex.name(), "simd");
        assert_eq!(simd_ex.class(), ExecClass::Simd);
        assert!(simd_ex.handles_all_projections());
        assert!(simd_ex.supports("anything_at_all"));
        let mut rng = Rng::new(7);
        let a = Mat::gaussian(128, 9, &mut rng);
        let b = rng.gaussians(128);
        let x = rng.gaussians(9);
        let gn = native.full_grad(&a, &b, &x);
        let gs = simd_ex.full_grad(&a, &b, &x);
        for (s, n) in gs.iter().zip(&gn) {
            assert!((s - n).abs() <= 1e-12 * (1.0 + n.abs()), "{s} vs {n}");
        }
        let fn_ = native.residual_sq(&a, &b, &x);
        let fs = simd_ex.residual_sq(&a, &b, &x);
        assert!((fs - fn_).abs() <= 1e-12 * (1.0 + fn_.abs()));
        let signs: Vec<f64> = (0..128).map(|i| if i % 3 == 0 { -1.0 } else { 1.0 }).collect();
        let hn = native.hd_transform(&a, &signs);
        let hs = simd_ex.hd_transform(&a, &signs);
        assert!(hs.max_abs_diff(&hn) < 1e-10);
    }

    #[test]
    fn residual_sq_multi_matches_serial_bitwise_on_both_cpu_executors() {
        let stats = Arc::new(DispatchStats::default());
        let native = NativeExecutor::with_tuning(Arc::clone(&stats), 2, None);
        let simd_ex = SimdExecutor::with_tuning(Arc::clone(&stats), 2, None);
        let mut rng = Rng::new(11);
        let a = Mat::gaussian(128, 9, &mut rng);
        let b = rng.gaussians(128);
        let xs: Vec<Vec<f64>> = (0..3).map(|_| rng.gaussians(9)).collect();
        for ex in [&native as &dyn Executor, &simd_ex as &dyn Executor] {
            let multi = ex.residual_sq_multi(&a, &b, &xs);
            assert_eq!(multi.len(), 3);
            for (k, x) in xs.iter().enumerate() {
                let serial = ex.residual_sq(&a, &b, x);
                assert_eq!(
                    multi[k].to_bits(),
                    serial.to_bits(),
                    "{} column {k}",
                    ex.name()
                );
            }
        }
    }

    #[test]
    fn simd_executor_streams_sketch_blocks() {
        let stats = Arc::new(DispatchStats::default());
        let ex = SimdExecutor::with_tuning(Arc::clone(&stats), 4, Some(16));
        let mut rng = Rng::new(3);
        let a = Mat::gaussian(200, 4, &mut rng);
        let sk = crate::sketch::SketchKind::CountSketch.build(32, 200, &mut rng);
        let sa = ex.sketch_apply(sk.as_ref(), &a, None);
        let dense = sk.apply(&a);
        // CountSketch scatter is add/sub only — bit-identical on every arch
        assert!(sa.max_abs_diff(&dense) < 1e-12);
        assert_eq!(stats.native_block_calls.load(Ordering::Relaxed), 13);
    }

    #[test]
    fn mark_routes_to_class_buckets() {
        let stats = DispatchStats::default();
        stats.mark(ExecClass::Native);
        stats.mark(ExecClass::Simd);
        stats.mark(ExecClass::Simd);
        stats.mark(ExecClass::Accelerated);
        assert_eq!(stats.native_calls.load(Ordering::Relaxed), 1);
        assert_eq!(stats.simd_calls.load(Ordering::Relaxed), 2);
        assert_eq!(stats.pjrt_calls.load(Ordering::Relaxed), 1);
        let agg = DispatchStats::default();
        agg.absorb(&stats);
        assert_eq!(agg.simd_calls.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn dispatch_stats_fallback_reason_roundtrip() {
        let stats = DispatchStats::default();
        assert!(stats.fallback_reason().is_none());
        stats.set_fallback_reason("no artifacts".into());
        assert_eq!(stats.fallback_reason().as_deref(), Some("no artifacts"));
    }

    #[test]
    fn opkeys_match_manifest_grammar() {
        assert_eq!(opkey::hd_transform(8192, 33), "hd_transform_n8192_c33");
        assert_eq!(opkey::batch_grad(64, 32), "batch_grad_r64_d32");
        let unc = crate::constraints::Unconstrained;
        assert_eq!(opkey::gd_step(&unc, 32), "gd_step_unc_d32");
        assert_eq!(
            opkey::sgd_chunk(&unc, 8192, 32, 64, 50),
            "sgd_chunk_unc_n8192_d32_r64_t50"
        );
    }
}
