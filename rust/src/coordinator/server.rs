//! Serve mode: line-delimited JSON over TCP (or an in-process connection
//! for tests). One `JobRequest` JSON object per line in; one `JobResult`
//! JSON object (or `{"error": ...}`) per line out, in completion order.
//!
//! Protocol extras:
//!   {"cmd": "metrics"} -> one-line metrics snapshot
//!   {"cmd": "ping"}    -> {"ok": true}
//!   {"cmd": "quit"}    -> closes the connection

use super::job::{is_shed_error, JobRequest};
use super::scheduler::Coordinator;
use crate::util::json::Json;
use crate::util::threadpool::Lane;
use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::sync::Arc;

/// Handle one connection (blocking). Returns when the peer closes or sends
/// {"cmd": "quit"}.
pub fn handle_connection<R: BufRead, W: Write + Send + 'static>(
    coord: &Arc<Coordinator>,
    reader: R,
    mut writer: W,
) -> Result<()> {
    // writer is owned by a dedicated thread; completions stream through a
    // channel so concurrent jobs cannot interleave partial lines.
    let (tx, rx) = mpsc::channel::<String>();
    let writer_thread = std::thread::spawn(move || {
        while let Ok(line) = rx.recv() {
            if writer.write_all(line.as_bytes()).is_err() {
                break;
            }
            if writer.write_all(b"\n").is_err() {
                break;
            }
            let _ = writer.flush();
        }
    });

    for line in reader.lines() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let parsed = match Json::parse(trimmed) {
            Ok(j) => j,
            Err(e) => {
                let _ = tx
                    .send(Json::obj(vec![("error", Json::str(format!("bad json: {e}")))]).to_string());
                continue;
            }
        };
        if let Some(cmd) = parsed.get("cmd").and_then(Json::as_str) {
            match cmd {
                "ping" => {
                    let _ = tx.send("{\"ok\":true}".to_string());
                }
                "metrics" => {
                    // dispatch-mix visibility: a native fallback must be
                    // distinguishable from a healthy PJRT deploy over the wire
                    let be = coord.backend();
                    let cache = coord.precond_cache();
                    let mut fields = vec![
                        ("metrics", Json::str(coord.metrics.snapshot())),
                        ("pjrt", Json::Bool(be.has_pjrt())),
                        ("pjrt_calls", Json::num(be.pjrt_calls() as f64)),
                        ("simd_calls", Json::num(be.simd_calls() as f64)),
                        ("native_calls", Json::num(be.native_calls() as f64)),
                        (
                            "native_block_calls",
                            Json::num(be.native_block_calls() as f64),
                        ),
                        // precond-cache health: all-zero = reuse never
                        // requested; misses with no hits = cold (or broken
                        // keying); evictions = byte budget too small
                        ("precond_hits", Json::num(cache.hits() as f64)),
                        ("precond_misses", Json::num(cache.misses() as f64)),
                        ("precond_evictions", Json::num(cache.evictions() as f64)),
                        ("precond_entries", Json::num(cache.entries() as f64)),
                        ("precond_bytes", Json::num(cache.bytes() as f64)),
                        (
                            "warm_starts",
                            Json::num(coord.metrics.warm_starts.load(
                                std::sync::atomic::Ordering::Relaxed,
                            ) as f64),
                        ),
                        // sparse workload accounting: how many jobs ran on
                        // CSR data and how many stored entries they carried
                        (
                            "sparse_jobs",
                            Json::num(coord.metrics.sparse_jobs.load(
                                std::sync::atomic::Ordering::Relaxed,
                            ) as f64),
                        ),
                        (
                            "sparse_nnz",
                            Json::num(coord.metrics.sparse_nnz.load(
                                std::sync::atomic::Ordering::Relaxed,
                            ) as f64),
                        ),
                        // constrained-workload accounting: projection-oracle
                        // invocations across all jobs (0 = only
                        // unconstrained work so far)
                        (
                            "projections",
                            Json::num(coord.metrics.projections.load(
                                std::sync::atomic::Ordering::Relaxed,
                            ) as f64),
                        ),
                        // memory-budget health: densify_events says how
                        // often a stage requested a dense view, rejections
                        // how often the budget refused one; limit 0 means
                        // unlimited
                        (
                            "mem_used_bytes",
                            Json::num(coord.mem_budget().used() as f64),
                        ),
                        (
                            "mem_peak_bytes",
                            Json::num(coord.mem_budget().peak() as f64),
                        ),
                        (
                            "mem_limit_bytes",
                            Json::num(
                                coord.mem_budget().limit_bytes().unwrap_or(0) as f64
                            ),
                        ),
                        (
                            "densify_events",
                            Json::num(coord.mem_budget().densify_events() as f64),
                        ),
                        (
                            "mem_rejections",
                            Json::num(coord.mem_budget().rejections() as f64),
                        ),
                        // out-of-core health: shard faults (cold block
                        // reads), evictions (budget pressure on the block
                        // cache), transient I/O retries the reader absorbed,
                        // and the bytes currently resident in shard caches
                        (
                            "shard_faults",
                            Json::num(coord.mem_budget().shard_faults() as f64),
                        ),
                        (
                            "shard_evictions",
                            Json::num(coord.mem_budget().shard_evictions() as f64),
                        ),
                        (
                            "shard_io_retries",
                            Json::num(coord.mem_budget().io_retries() as f64),
                        ),
                        (
                            "shard_resident_bytes",
                            Json::num(coord.mem_budget().shard_resident_bytes() as f64),
                        ),
                    ];
                    // serve-tier QoS: shed/coalesce totals plus one nested
                    // object per priority lane (counts, live queue depth,
                    // end-to-end percentiles; -1 = no samples yet) and the
                    // stealing pool's migration count
                    let load = |v: usize| Json::num(v as f64);
                    let m = &coord.metrics;
                    let ord = std::sync::atomic::Ordering::Relaxed;
                    fields.push(("jobs_shed", load(m.jobs_shed.load(ord))));
                    fields.push(("coalesced_jobs", load(m.coalesced_jobs.load(ord))));
                    fields.push((
                        "coalesce_batch_max",
                        load(m.coalesce_batch_max.load(ord)),
                    ));
                    // batched-execution visibility: fused trial count, how
                    // many requests rode a shared execution, and the largest
                    // group observed
                    fields.push(("fused_trials", load(m.fused_trials.load(ord))));
                    fields.push(("fused_requests", load(m.fused_requests.load(ord))));
                    fields.push(("fuse_batch_max", load(m.fuse_batch_max.load(ord))));
                    fields.push(("pool_steals", load(coord.pool_steals())));
                    fields.push((
                        "precond_wait_joins",
                        load(cache.wait_joins()),
                    ));
                    let lane_obj = |lane: Lane| {
                        let lm = &m.lanes[lane.idx()];
                        let pct = |p: f64| {
                            m.lane_latency_percentile(lane, p)
                                .map(|secs| secs * 1e3)
                                .unwrap_or(-1.0)
                        };
                        Json::obj(vec![
                            ("submitted", load(lm.submitted.load(ord))),
                            ("completed", load(lm.completed.load(ord))),
                            ("shed", load(lm.shed.load(ord))),
                            ("queued", load(coord.queue_depth(lane))),
                            ("p50_ms", Json::num(pct(50.0))),
                            ("p95_ms", Json::num(pct(95.0))),
                            ("p99_ms", Json::num(pct(99.0))),
                        ])
                    };
                    fields.push(("lane_high", lane_obj(Lane::High)));
                    fields.push(("lane_normal", lane_obj(Lane::Normal)));
                    fields.push(("lane_batch", lane_obj(Lane::Batch)));
                    if let Some(reason) = be.pjrt_fallback_reason() {
                        fields.push(("pjrt_fallback", Json::str(reason)));
                    }
                    let _ = tx.send(Json::obj(fields).to_string());
                }
                "quit" => break,
                other => {
                    let _ = tx.send(
                        Json::obj(vec![("error", Json::str(format!("unknown cmd {other}")))])
                            .to_string(),
                    );
                }
            }
            continue;
        }
        match JobRequest::from_json(&parsed) {
            Ok(req) => {
                let tx = tx.clone();
                let id = req.id;
                coord.submit(req, move |res| {
                    let line = match res {
                        Ok(r) => r.to_json().to_string(),
                        Err(e) => {
                            // full chain ({:#}): a shed's cause line carries
                            // the estimate-vs-deadline numbers clients need
                            let mut fields = vec![
                                ("error", Json::str(format!("{e:#}"))),
                                ("id", Json::num(id as f64)),
                            ];
                            if is_shed_error(&e) {
                                // structured flag: clients retry sheds on a
                                // slower lane; real errors they surface
                                fields.push(("shed", Json::Bool(true)));
                            }
                            Json::obj(fields).to_string()
                        }
                    };
                    let _ = tx.send(line);
                });
            }
            Err(e) => {
                let _ = tx.send(Json::obj(vec![("error", Json::str(format!("{e}")))]).to_string());
            }
        }
    }
    coord.drain();
    drop(tx);
    let _ = writer_thread.join();
    Ok(())
}

/// Blocking TCP accept loop on `addr` (e.g. "127.0.0.1:7878").
pub fn serve_tcp(coord: Arc<Coordinator>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    crate::log_info!("hdpw serving on {addr}");
    for stream in listener.incoming() {
        let stream: TcpStream = stream?;
        let peer = stream.peer_addr()?;
        crate::log_info!("connection from {peer}");
        let reader = BufReader::new(stream.try_clone()?);
        let coord = Arc::clone(&coord);
        std::thread::spawn(move || {
            if let Err(e) = handle_connection(&coord, reader, stream) {
                crate::log_warn!("connection {peer} error: {e}");
            }
        });
    }
    Ok(())
}

/// stdin/stdout loop (`hdpw serve --stdio`).
pub fn serve_stdio(coord: Arc<Coordinator>) -> Result<()> {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    handle_connection(&coord, stdin.lock(), stdout)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::Backend;
    use crate::coordinator::scheduler::CoordinatorConfig;
    use std::io::Cursor;
    use std::sync::Mutex;

    #[derive(Clone)]
    struct VecWriter(Arc<Mutex<Vec<u8>>>);

    impl Write for VecWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    fn run_session(input: &str) -> Vec<Json> {
        // a private budget: the per-job densify/peak assertions must not
        // race other tests charging the shared process budget
        let coord = Arc::new(Coordinator::new(
            Backend::native(),
            CoordinatorConfig {
                mem_budget: crate::util::mem::MemBudget::unlimited(),
                ..CoordinatorConfig::default()
            },
        ));
        let out = Arc::new(Mutex::new(Vec::new()));
        handle_connection(&coord, Cursor::new(input.to_string()), VecWriter(Arc::clone(&out)))
            .unwrap();
        let bytes = out.lock().unwrap().clone();
        String::from_utf8(bytes)
            .unwrap()
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| Json::parse(l).unwrap())
            .collect()
    }

    #[test]
    fn ping_and_metrics() {
        let out = run_session("{\"cmd\":\"ping\"}\n{\"cmd\":\"metrics\"}\n");
        assert_eq!(out[0].get("ok").and_then(Json::as_bool), Some(true));
        assert!(out[1].get("metrics").is_some());
        // backend status rides along so operators can spot a native fallback
        assert_eq!(out[1].get("pjrt").and_then(Json::as_bool), Some(false));
        assert!(out[1].get("native_calls").is_some());
        assert!(out[1].get("simd_calls").is_some());
        // precond-cache + warm-start counters ride along too (a cold cache
        // must be distinguishable from a broken one in dashboards)
        for field in [
            "precond_hits",
            "precond_misses",
            "precond_evictions",
            "precond_entries",
            "precond_bytes",
            "precond_wait_joins",
            "warm_starts",
            "sparse_jobs",
            "sparse_nnz",
            "projections",
            "mem_used_bytes",
            "mem_peak_bytes",
            "mem_limit_bytes",
            "densify_events",
            "mem_rejections",
            "shard_faults",
            "shard_evictions",
            "shard_io_retries",
            "shard_resident_bytes",
            "jobs_shed",
            "coalesced_jobs",
            "coalesce_batch_max",
            "fused_trials",
            "fused_requests",
            "fuse_batch_max",
            "pool_steals",
        ] {
            assert!(out[1].get(field).and_then(Json::as_f64).is_some(), "{field}");
        }
        // one nested QoS object per priority lane
        for lane in ["lane_high", "lane_normal", "lane_batch"] {
            let obj = out[1].get(lane).unwrap_or_else(|| panic!("{lane} missing"));
            for sub in [
                "submitted",
                "completed",
                "shed",
                "queued",
                "p50_ms",
                "p95_ms",
                "p99_ms",
            ] {
                assert!(obj.get(sub).and_then(Json::as_f64).is_some(), "{lane}.{sub}");
            }
        }
    }

    #[test]
    fn deadline_shed_over_wire_is_structured() {
        // deadline well under any queue+dispatch latency: the job is shed
        // (submit- or start-time), never run, and the error line is marked
        let req = r#"{"id":7,"solver":"exact","dataset":"syn2","n":512,"priority":"batch","deadline_ms":0.0001}"#;
        let out = run_session(&format!("{req}\n"));
        assert_eq!(out.len(), 1, "{out:?}");
        let line = &out[0];
        assert_eq!(line.get("shed").and_then(Json::as_bool), Some(true), "{line:?}");
        assert_eq!(line.get("id").and_then(Json::as_f64), Some(7.0));
        let msg = line.get("error").and_then(Json::as_str).unwrap();
        assert!(msg.contains("deadline"), "{msg}");
        assert!(msg.contains("batch"), "shed names its lane: {msg}");
        // a genuine job error is NOT flagged as a shed
        let bad = r#"{"id":8,"solver":"exact","dataset":"mystery"}"#;
        let out2 = run_session(&format!("{bad}\n"));
        assert!(out2[0].get("error").is_some());
        assert_eq!(out2[0].get("id").and_then(Json::as_f64), Some(8.0));
        assert!(out2[0].get("shed").is_none(), "{out2:?}");
    }

    #[test]
    fn priority_field_routes_over_wire() {
        let hi = r#"{"solver":"exact","dataset":"syn2","n":512,"priority":"high"}"#;
        let ba = r#"{"solver":"exact","dataset":"syn2","n":512,"priority":"batch"}"#;
        let out = run_session(&format!("{hi}\n{ba}\n{{\"cmd\":\"metrics\"}}\n"));
        assert_eq!(out.len(), 3, "{out:?}");
        // both jobs solve; the metrics cmd is inline so we assert lane
        // submit counts (recorded synchronously at submit) only
        let metrics = out
            .iter()
            .find(|j| j.get("lane_high").is_some())
            .expect("metrics line");
        let sub = |lane: &str| {
            metrics
                .get(lane)
                .and_then(|o| o.get("submitted"))
                .and_then(Json::as_f64)
                .unwrap()
        };
        assert_eq!(sub("lane_high"), 1.0);
        assert_eq!(sub("lane_batch"), 1.0);
        assert_eq!(sub("lane_normal"), 0.0);
    }

    #[test]
    fn sparse_job_over_wire_reports_density_and_nnz() {
        let req = r#"{"solver":"exact","dataset":"syn2","n":512,"format":"libsvm"}"#;
        let out = run_session(&format!("{req}\n{{\"cmd\":\"metrics\"}}\n"));
        assert_eq!(out.len(), 2);
        let result = out
            .iter()
            .find(|j| j.get("density").is_some())
            .expect("result line with density");
        let density = result.get("density").and_then(Json::as_f64).unwrap();
        assert!(density > 0.0 && density < 0.99, "density {density}");
        assert!(result.get("nnz").and_then(Json::as_f64).unwrap() > 0.0);
        // the representation flag, not density, is the CSR signal
        assert_eq!(result.get("sparse").and_then(Json::as_bool), Some(true));
        // exact on CSR runs the CGLS oracle: zero densifications, and the
        // mem accounting fields ride along on the result line
        assert_eq!(
            result.get("densify_events").and_then(Json::as_f64),
            Some(0.0),
            "{result:?}"
        );
        assert_eq!(result.get("mem_est_bytes").and_then(Json::as_f64), Some(0.0));
        assert!(result.get("mem_peak_bytes").and_then(Json::as_f64).is_some());
        // NOTE: the metrics cmd is handled inline and may run before the
        // async job finishes — assert the counters ride along, not their
        // values (scheduler tests pin the values synchronously)
        let metrics = out
            .iter()
            .find(|j| j.get("sparse_jobs").is_some())
            .expect("metrics line");
        assert!(metrics.get("sparse_nnz").and_then(Json::as_f64).is_some());
        // a malformed libsvm path surfaces as a job error, not a crash
        let bad = r#"{"solver":"exact","dataset":"libsvm:/no/such/file.svm"}"#;
        let out2 = run_session(&format!("{bad}\n"));
        assert!(out2[0].get("error").is_some(), "{out2:?}");
    }

    #[test]
    fn out_of_core_job_over_wire_reports_shard_counters() {
        let req = r#"{"id":3,"solver":"exact","dataset":"syn2","n":512,"format":"libsvm-chunked","chunk_rows":128}"#;
        let out = run_session(&format!("{req}\n{{\"cmd\":\"metrics\"}}\n"));
        assert_eq!(out.len(), 2, "{out:?}");
        let result = out
            .iter()
            .find(|j| j.get("shard_faults").is_some() && j.get("best_f").is_some())
            .expect("result line with shard counters");
        assert!(
            result.get("shard_faults").and_then(Json::as_f64).unwrap() > 0.0,
            "{result:?}"
        );
        assert_eq!(result.get("io_retries").and_then(Json::as_f64), Some(0.0));
        assert_eq!(result.get("sparse").and_then(Json::as_bool), Some(true));
        // the service-level shard gauges ride the metrics line
        let metrics = out
            .iter()
            .find(|j| j.get("shard_resident_bytes").is_some())
            .expect("metrics line");
        assert!(metrics.get("shard_io_retries").and_then(Json::as_f64).is_some());
        // an unreadable on-disk dataset is an id-tagged error line
        let bad = r#"{"id":9,"solver":"exact","dataset":"mmapdense:/no/such/file.hdpw"}"#;
        let out2 = run_session(&format!("{bad}\n"));
        assert!(out2[0].get("error").is_some(), "{out2:?}");
        assert_eq!(out2[0].get("id").and_then(Json::as_f64), Some(9.0));
    }

    #[test]
    fn reused_job_reports_cache_outcome_over_wire() {
        // NOTE: output is in completion order and the metrics cmd is handled
        // inline (possibly before the async jobs finish) — identify lines by
        // content, not position
        let req =
            r#"{"solver":"pwgradient","dataset":"syn2","n":1024,"max_iters":100,"reuse_precond":true}"#;
        let out = run_session(&format!("{req}\n{req}\n{{\"cmd\":\"metrics\"}}\n"));
        assert_eq!(out.len(), 3);
        let mut outcomes: Vec<&str> = out
            .iter()
            .filter_map(|j| j.get("precond_cache").and_then(Json::as_str))
            .collect();
        outcomes.sort_unstable();
        // two job results; single-flight guarantees exactly one computes
        // (miss) and the other is served from the cache (hit), even when
        // the 2-worker pool runs them concurrently
        assert_eq!(outcomes, vec!["hit", "miss"], "{out:?}");
        let metrics_line = out
            .iter()
            .find(|j| j.get("precond_hits").is_some())
            .expect("metrics line present");
        for field in ["precond_misses", "precond_evictions", "precond_bytes"] {
            assert!(metrics_line.get(field).and_then(Json::as_f64).is_some(), "{field}");
        }
    }

    #[test]
    fn solve_job_over_wire() {
        let req = r#"{"solver":"exact","dataset":"syn2","n":512,"max_iters":10}"#;
        let out = run_session(&format!("{req}\n"));
        assert_eq!(out.len(), 1);
        let res = &out[0];
        assert_eq!(res.get("solver").and_then(Json::as_str), Some("exact"));
        assert!(res.get("best_rel_err").and_then(Json::as_f64).unwrap() < 1e-9);
        assert!(res.get("trace").and_then(Json::as_arr).is_some());
    }

    #[test]
    fn bad_input_yields_error_lines_not_crashes() {
        let out = run_session("not json at all\n{\"solver\":\"nope\"}\n{\"cmd\":\"ping\"}\n");
        assert!(out[0].get("error").is_some());
        assert!(out[1].get("error").is_some());
        assert_eq!(out[2].get("ok").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn quit_stops_processing() {
        let out = run_session("{\"cmd\":\"quit\"}\n{\"cmd\":\"ping\"}\n");
        assert!(out.is_empty());
    }
}
