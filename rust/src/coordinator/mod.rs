//! L3 coordinator: the solver *service*.
//!
//! Owns process topology and the request loop. Components:
//!
//! * [`job`] — job specs/results with JSON (de)serialization: the wire and
//!   config format for a solve request.
//! * [`scheduler`] — bounded worker pool running jobs concurrently with
//!   backpressure, best-of-k trial replication (the paper runs every method
//!   10 times and reports the best), and deterministic per-trial seeds.
//! * [`metrics`] — service counters (jobs, solve latencies, dispatch mix).
//! * [`server`] — line-delimited JSON protocol over TCP or stdin; the
//!   `hdpw serve` mode.
//!
//! The coordinator holds one [`Backend`] shared by all workers: artifacts
//! are compiled once at startup and reused across jobs (PJRT executables are
//! thread-safe behind the engine's immutable registry).

pub mod job;
pub mod scheduler;
pub mod metrics;
pub mod server;

pub use job::{JobRequest, JobResult};
pub use scheduler::{Coordinator, CoordinatorConfig};
