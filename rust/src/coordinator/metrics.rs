//! Service metrics: counters + latency histograms for the coordinator.
//!
//! Latency percentiles come from a fixed-bucket log-scale histogram:
//! O(1) record under no lock, O(buckets) percentile — so a load generator
//! (or a dashboard) can poll percentiles at high frequency without
//! perturbing the run. Buckets are quarter-octaves (4 per power of two)
//! from 1 µs, which bounds the percentile's relative error at
//! 2^(1/8) ≈ ±9% while covering 1 µs .. ~1 hour in 128 buckets; exact
//! observed min/max clamp the tails.

use crate::util::threadpool::Lane;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Histogram bucket count: 128 quarter-octave buckets from the 1 µs floor
/// cover latencies up to 2^(127/4) µs ≈ 66 minutes.
const NBUCKETS: usize = 128;
/// Buckets per power of two.
const PER_OCTAVE: f64 = 4.0;
/// Smallest resolvable latency (seconds): everything below lands in
/// bucket 0.
const FLOOR_SECS: f64 = 1e-6;

/// Fixed-bucket log-scale latency histogram: lock-free O(1) `record`,
/// O(buckets) `percentile`. Values are clamped to the observed min/max so
/// constant samples report exactly.
pub struct LatencyHistogram {
    counts: [AtomicU64; NBUCKETS],
    total: AtomicU64,
    /// Observed minimum, stored as f64 bits (bit order == numeric order
    /// for non-negative floats, so `fetch_min` works).
    min_bits: AtomicU64,
    /// Observed maximum, same encoding.
    max_bits: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

impl std::fmt::Debug for LatencyHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LatencyHistogram")
            .field("count", &self.count())
            .field("p50", &self.percentile(50.0))
            .field("p99", &self.percentile(99.0))
            .finish()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            total: AtomicU64::new(0),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(0),
        }
    }

    fn bucket(secs: f64) -> usize {
        // callers sanitize: secs is finite and >= 0 here
        if secs <= FLOOR_SECS {
            return 0;
        }
        (((secs / FLOOR_SECS).log2() * PER_OCTAVE) as usize).min(NBUCKETS - 1)
    }

    /// Record one latency (seconds). Lock-free, O(1).
    pub fn record(&self, secs: f64) {
        let secs = if secs.is_finite() && secs >= 0.0 {
            secs
        } else {
            0.0
        };
        self.counts[Self::bucket(secs)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.min_bits.fetch_min(secs.to_bits(), Ordering::Relaxed);
        self.max_bits.fetch_max(secs.to_bits(), Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// The p-th percentile (nearest-rank over buckets, geometric bucket
    /// midpoint, clamped to the observed min/max). None when empty.
    pub fn percentile(&self, p: f64) -> Option<f64> {
        let total = self.count();
        if total == 0 {
            return None;
        }
        let lo = f64::from_bits(self.min_bits.load(Ordering::Relaxed));
        let hi = f64::from_bits(self.max_bits.load(Ordering::Relaxed));
        // the tail percentiles are exact: p0/p100 are the observed extremes
        // themselves, not a bucket midpoint near them
        if p <= 0.0 {
            return Some(lo);
        }
        if p >= 100.0 {
            return Some(hi);
        }
        let rank = ((p.clamp(0.0, 100.0) / 100.0) * (total - 1) as f64).round() as u64;
        let mut cum = 0u64;
        let mut bucket = NBUCKETS - 1;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum > rank {
                bucket = i;
                break;
            }
        }
        let mid = FLOOR_SECS * ((bucket as f64 + 0.5) / PER_OCTAVE).exp2();
        Some(mid.clamp(lo, hi))
    }
}

/// Per-priority-lane service counters + latency histogram. Lane latency is
/// end-to-end (submit to completion: queue wait + solve), unlike the
/// top-level solve-latency histogram.
#[derive(Debug, Default)]
pub struct LaneMetrics {
    /// Jobs submitted on this lane.
    pub submitted: AtomicUsize,
    /// Jobs completed (ok or error — not shed) on this lane.
    pub completed: AtomicUsize,
    /// Jobs shed on this lane (deadline unmeetable or already missed).
    pub shed: AtomicUsize,
    /// End-to-end latency histogram (queue wait + solve).
    pub latency: LatencyHistogram,
}

/// Service counters + latency histograms for the coordinator.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs accepted onto the worker pool.
    pub jobs_submitted: AtomicUsize,
    /// Jobs that finished successfully.
    pub jobs_completed: AtomicUsize,
    /// Jobs that returned an error.
    pub jobs_failed: AtomicUsize,
    /// Jobs shed by deadline policy (disjoint from failed: a shed is the
    /// scheduler declining work, not the solver breaking).
    pub jobs_shed: AtomicUsize,
    /// Jobs that shared a coalescing group with at least one concurrent
    /// same-key job.
    pub coalesced_jobs: AtomicUsize,
    /// Largest coalescing group observed (peak concurrent same-key jobs).
    pub coalesce_batch_max: AtomicUsize,
    /// Trials executed across all jobs.
    pub trials_run: AtomicUsize,
    /// Trials that advanced in lockstep through a fused objective pass
    /// (best-of-k under `reuse_precond`; disjoint from the serial loop).
    pub fused_trials: AtomicUsize,
    /// Requests that adopted a fused leader's result instead of running
    /// their own solve (includes the leader itself when a group formed).
    pub fused_requests: AtomicUsize,
    /// Largest fused request group observed.
    pub fuse_batch_max: AtomicUsize,
    /// trials that started from a warm iterate (warm_start jobs, trial > 0)
    pub warm_starts: AtomicUsize,
    /// jobs solved on a CSR dataset (the sparse workload class)
    pub sparse_jobs: AtomicUsize,
    /// total stored entries across sparse jobs (throughput accounting for
    /// the O(nnz) pipeline)
    pub sparse_nnz: AtomicU64,
    /// projection-oracle invocations across all jobs (Euclidean + metric;
    /// unconstrained no-ops excluded) — the constrained-workload
    /// throughput signal
    pub projections: AtomicU64,
    /// Per-lane counters + end-to-end latency (indexed by [`Lane::idx`]).
    pub lanes: [LaneMetrics; 3],
    /// total solve nanoseconds (across trials)
    solve_nanos: AtomicU64,
    /// solve-latency histogram (per-job solve seconds)
    latency: LatencyHistogram,
}

impl Metrics {
    /// Fresh, all-zero metrics.
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record one finished job (solve latency, trial count, outcome).
    pub fn record_job(&self, secs: f64, trials: usize, ok: bool) {
        if ok {
            self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.jobs_failed.fetch_add(1, Ordering::Relaxed);
        }
        self.trials_run.fetch_add(trials, Ordering::Relaxed);
        self.solve_nanos
            .fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
        self.latency.record(secs);
    }

    /// Count one job submitted on `lane`.
    pub fn record_lane_submit(&self, lane: Lane) {
        self.lanes[lane.idx()].submitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one job completing on `lane` with end-to-end latency `secs`.
    pub fn record_lane_done(&self, lane: Lane, secs: f64) {
        self.lanes[lane.idx()].completed.fetch_add(1, Ordering::Relaxed);
        self.lanes[lane.idx()].latency.record(secs);
    }

    /// Count one job shed by deadline policy on `lane`.
    pub fn record_shed(&self, lane: Lane) {
        self.jobs_shed.fetch_add(1, Ordering::Relaxed);
        self.lanes[lane.idx()].shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one job leaving a coalescing group whose peak concurrent
    /// membership was `batch` (only called when batch > 1).
    pub fn record_coalesced(&self, batch: usize) {
        self.coalesced_jobs.fetch_add(1, Ordering::Relaxed);
        self.coalesce_batch_max.fetch_max(batch, Ordering::Relaxed);
    }

    /// Count `k` trials that ran through one fused objective pass.
    pub fn record_fused_trials(&self, k: usize) {
        self.fused_trials.fetch_add(k, Ordering::Relaxed);
    }

    /// Record one fused request group of size `k` resolving (leader +
    /// followers; only called when k > 1).
    pub fn record_fused_requests(&self, k: usize) {
        self.fused_requests.fetch_add(k, Ordering::Relaxed);
        self.fuse_batch_max.fetch_max(k, Ordering::Relaxed);
    }

    /// Count one warm-started trial.
    pub fn record_warm_start(&self) {
        self.warm_starts.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one job solved on a CSR dataset, carrying `nnz` entries.
    pub fn record_sparse_job(&self, nnz: usize) {
        self.sparse_jobs.fetch_add(1, Ordering::Relaxed);
        self.sparse_nnz.fetch_add(nnz as u64, Ordering::Relaxed);
    }

    /// Add one job's projection count to the service total.
    pub fn record_projections(&self, count: usize) {
        self.projections.fetch_add(count as u64, Ordering::Relaxed);
    }

    /// Total solve seconds across all jobs.
    pub fn total_solve_secs(&self) -> f64 {
        self.solve_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// The p-th percentile of job solve latencies (None when empty).
    /// Histogram-resolved: exact to within a quarter-octave bucket
    /// (≈ ±9% relative), clamped to the observed min/max.
    pub fn latency_percentile(&self, p: f64) -> Option<f64> {
        self.latency.percentile(p)
    }

    /// The p-th percentile of end-to-end latency on one lane.
    pub fn lane_latency_percentile(&self, lane: Lane, p: f64) -> Option<f64> {
        self.lanes[lane.idx()].latency.percentile(p)
    }

    /// One-line human-readable summary (the serve `metrics` command).
    pub fn snapshot(&self) -> String {
        format!(
            "jobs: submitted={} completed={} failed={} shed={} coalesced={} trials={} fused_trials={} fused_requests={} warm_starts={} sparse_jobs={} sparse_nnz={} projections={} solve_time={:.2}s p50={} p99={}",
            self.jobs_submitted.load(Ordering::Relaxed),
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
            self.jobs_shed.load(Ordering::Relaxed),
            self.coalesced_jobs.load(Ordering::Relaxed),
            self.trials_run.load(Ordering::Relaxed),
            self.fused_trials.load(Ordering::Relaxed),
            self.fused_requests.load(Ordering::Relaxed),
            self.warm_starts.load(Ordering::Relaxed),
            self.sparse_jobs.load(Ordering::Relaxed),
            self.sparse_nnz.load(Ordering::Relaxed),
            self.projections.load(Ordering::Relaxed),
            self.total_solve_secs(),
            self.latency_percentile(50.0)
                .map(crate::util::stats::fmt_duration)
                .unwrap_or_else(|| "-".into()),
            self.latency_percentile(99.0)
                .map(crate::util::stats::fmt_duration)
                .unwrap_or_else(|| "-".into()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.jobs_submitted.fetch_add(3, Ordering::Relaxed);
        m.record_job(1.0, 10, true);
        m.record_job(3.0, 10, true);
        m.record_job(0.5, 1, false);
        assert_eq!(m.jobs_completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.jobs_failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.trials_run.load(Ordering::Relaxed), 21);
        assert!((m.total_solve_secs() - 4.5).abs() < 1e-6);
        // histogram percentile: within a quarter-octave of the true median
        let p50 = m.latency_percentile(50.0).unwrap();
        assert!((p50 - 1.0).abs() < 0.12, "p50={p50}");
        // tails clamp to observed extremes exactly
        assert_eq!(m.latency_percentile(0.0), Some(0.5));
        assert_eq!(m.latency_percentile(100.0), Some(3.0));
        m.record_warm_start();
        m.record_sparse_job(1234);
        m.record_sparse_job(766);
        m.record_projections(500);
        m.record_projections(41);
        let snap = m.snapshot();
        assert!(snap.contains("completed=2"));
        assert!(snap.contains("warm_starts=1"));
        assert!(snap.contains("sparse_jobs=2"), "{snap}");
        assert!(snap.contains("sparse_nnz=2000"), "{snap}");
        assert!(snap.contains("projections=541"), "{snap}");
        assert!(snap.contains("shed=0"), "{snap}");
        assert!(snap.contains("coalesced=0"), "{snap}");
    }

    #[test]
    fn empty_percentile_is_none() {
        let m = Metrics::new();
        assert!(m.latency_percentile(50.0).is_none());
        assert!(m.lane_latency_percentile(Lane::High, 50.0).is_none());
    }

    #[test]
    fn histogram_percentiles_track_known_distribution() {
        let h = LatencyHistogram::new();
        // 100 samples: 1ms .. 100ms uniform
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile(50.0).unwrap();
        assert!(
            (p50 - 0.0505).abs() / 0.0505 < 0.10,
            "p50={p50}, want ~50.5ms within bucket resolution"
        );
        let p99 = h.percentile(99.0).unwrap();
        assert!(
            (0.090..=0.100).contains(&p99),
            "p99={p99}, want ~99ms within bucket resolution"
        );
        // constant distributions are exact (min/max clamping)
        let c = LatencyHistogram::new();
        for _ in 0..32 {
            c.record(0.25);
        }
        assert_eq!(c.percentile(50.0), Some(0.25));
        assert_eq!(c.percentile(99.0), Some(0.25));
    }

    #[test]
    fn histogram_handles_extremes_without_panicking() {
        let h = LatencyHistogram::new();
        h.record(0.0);
        h.record(1e-9); // below floor: bucket 0
        h.record(1e9); // beyond range: clamped to last bucket
        h.record(f64::NAN); // sanitized to 0
        h.record(-1.0); // sanitized to 0
        assert_eq!(h.count(), 5);
        let p100 = h.percentile(100.0).unwrap();
        assert_eq!(p100, 1e9, "max clamp keeps the tail exact");
        assert_eq!(h.percentile(0.0), Some(0.0));
    }

    #[test]
    fn lane_metrics_record_and_report() {
        let m = Metrics::new();
        m.record_lane_submit(Lane::High);
        m.record_lane_submit(Lane::High);
        m.record_lane_submit(Lane::Batch);
        m.record_lane_done(Lane::High, 0.010);
        m.record_lane_done(Lane::High, 0.012);
        m.record_shed(Lane::Batch);
        assert_eq!(m.lanes[Lane::High.idx()].submitted.load(Ordering::Relaxed), 2);
        assert_eq!(m.lanes[Lane::High.idx()].completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.lanes[Lane::Batch.idx()].shed.load(Ordering::Relaxed), 1);
        assert_eq!(m.jobs_shed.load(Ordering::Relaxed), 1);
        assert_eq!(m.jobs_failed.load(Ordering::Relaxed), 0, "shed is not failed");
        let p50 = m.lane_latency_percentile(Lane::High, 50.0).unwrap();
        assert!((0.009..=0.013).contains(&p50), "p50={p50}");
        assert!(m.lane_latency_percentile(Lane::Normal, 50.0).is_none());
    }

    #[test]
    fn coalesce_counters_track_peak() {
        let m = Metrics::new();
        m.record_coalesced(3);
        m.record_coalesced(8);
        m.record_coalesced(2);
        assert_eq!(m.coalesced_jobs.load(Ordering::Relaxed), 3);
        assert_eq!(m.coalesce_batch_max.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn fused_counters_track_trials_and_group_peak() {
        let m = Metrics::new();
        m.record_fused_trials(3);
        m.record_fused_trials(5);
        m.record_fused_requests(4);
        m.record_fused_requests(2);
        assert_eq!(m.fused_trials.load(Ordering::Relaxed), 8);
        assert_eq!(m.fused_requests.load(Ordering::Relaxed), 6);
        assert_eq!(m.fuse_batch_max.load(Ordering::Relaxed), 4);
        let snap = m.snapshot();
        assert!(snap.contains("fused_trials=8"), "{snap}");
        assert!(snap.contains("fused_requests=6"), "{snap}");
    }
}
