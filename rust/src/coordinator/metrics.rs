//! Service metrics: counters + latency histogram for the coordinator.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Service counters + latency histogram for the coordinator.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs accepted onto the worker pool.
    pub jobs_submitted: AtomicUsize,
    /// Jobs that finished successfully.
    pub jobs_completed: AtomicUsize,
    /// Jobs that returned an error.
    pub jobs_failed: AtomicUsize,
    /// Trials executed across all jobs.
    pub trials_run: AtomicUsize,
    /// trials that started from a warm iterate (warm_start jobs, trial > 0)
    pub warm_starts: AtomicUsize,
    /// jobs solved on a CSR dataset (the sparse workload class)
    pub sparse_jobs: AtomicUsize,
    /// total stored entries across sparse jobs (throughput accounting for
    /// the O(nnz) pipeline)
    pub sparse_nnz: AtomicU64,
    /// projection-oracle invocations across all jobs (Euclidean + metric;
    /// unconstrained no-ops excluded) — the constrained-workload
    /// throughput signal
    pub projections: AtomicU64,
    /// total solve nanoseconds (across trials)
    solve_nanos: AtomicU64,
    /// recent job latencies (seconds), bounded ring
    latencies: Mutex<Vec<f64>>,
}

impl Metrics {
    /// Fresh, all-zero metrics.
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record one finished job (latency, trial count, outcome).
    pub fn record_job(&self, secs: f64, trials: usize, ok: bool) {
        if ok {
            self.jobs_completed.fetch_add(1, Ordering::Relaxed);
        } else {
            self.jobs_failed.fetch_add(1, Ordering::Relaxed);
        }
        self.trials_run.fetch_add(trials, Ordering::Relaxed);
        self.solve_nanos
            .fetch_add((secs * 1e9) as u64, Ordering::Relaxed);
        let mut l = self.latencies.lock().unwrap();
        if l.len() >= 4096 {
            l.remove(0);
        }
        l.push(secs);
    }

    /// Count one warm-started trial.
    pub fn record_warm_start(&self) {
        self.warm_starts.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one job solved on a CSR dataset, carrying `nnz` entries.
    pub fn record_sparse_job(&self, nnz: usize) {
        self.sparse_jobs.fetch_add(1, Ordering::Relaxed);
        self.sparse_nnz.fetch_add(nnz as u64, Ordering::Relaxed);
    }

    /// Add one job's projection count to the service total.
    pub fn record_projections(&self, count: usize) {
        self.projections.fetch_add(count as u64, Ordering::Relaxed);
    }

    /// Total solve seconds across all jobs.
    pub fn total_solve_secs(&self) -> f64 {
        self.solve_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// The p-th percentile of recent job latencies (None when empty).
    pub fn latency_percentile(&self, p: f64) -> Option<f64> {
        let l = self.latencies.lock().unwrap();
        if l.is_empty() {
            return None;
        }
        Some(crate::util::stats::percentile(&l, p))
    }

    /// One-line human-readable summary (the serve `metrics` command).
    pub fn snapshot(&self) -> String {
        format!(
            "jobs: submitted={} completed={} failed={} trials={} warm_starts={} sparse_jobs={} sparse_nnz={} projections={} solve_time={:.2}s p50={} p99={}",
            self.jobs_submitted.load(Ordering::Relaxed),
            self.jobs_completed.load(Ordering::Relaxed),
            self.jobs_failed.load(Ordering::Relaxed),
            self.trials_run.load(Ordering::Relaxed),
            self.warm_starts.load(Ordering::Relaxed),
            self.sparse_jobs.load(Ordering::Relaxed),
            self.sparse_nnz.load(Ordering::Relaxed),
            self.projections.load(Ordering::Relaxed),
            self.total_solve_secs(),
            self.latency_percentile(50.0)
                .map(crate::util::stats::fmt_duration)
                .unwrap_or_else(|| "-".into()),
            self.latency_percentile(99.0)
                .map(crate::util::stats::fmt_duration)
                .unwrap_or_else(|| "-".into()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.jobs_submitted.fetch_add(3, Ordering::Relaxed);
        m.record_job(1.0, 10, true);
        m.record_job(3.0, 10, true);
        m.record_job(0.5, 1, false);
        assert_eq!(m.jobs_completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.jobs_failed.load(Ordering::Relaxed), 1);
        assert_eq!(m.trials_run.load(Ordering::Relaxed), 21);
        assert!((m.total_solve_secs() - 4.5).abs() < 1e-6);
        assert_eq!(m.latency_percentile(50.0), Some(1.0));
        m.record_warm_start();
        m.record_sparse_job(1234);
        m.record_sparse_job(766);
        m.record_projections(500);
        m.record_projections(41);
        let snap = m.snapshot();
        assert!(snap.contains("completed=2"));
        assert!(snap.contains("warm_starts=1"));
        assert!(snap.contains("sparse_jobs=2"), "{snap}");
        assert!(snap.contains("sparse_nnz=2000"), "{snap}");
        assert!(snap.contains("projections=541"), "{snap}");
    }

    #[test]
    fn empty_percentile_is_none() {
        let m = Metrics::new();
        assert!(m.latency_percentile(50.0).is_none());
    }
}
