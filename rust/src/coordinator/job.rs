//! Job specs and results — the coordinator's wire format.
//!
//! A `JobRequest` fully describes one solve: dataset (by name + scale, or
//! preloaded), solver, constraint (a [`ConstraintSpec`] — string or JSON
//! object form), accuracy target, trial count. JSON in, JSON out — usable
//! from the CLI, config files, and the serve socket.

use crate::constraints::{ConstraintRef, ConstraintSpec};
use crate::sketch::SketchKind;
use crate::solvers::{SolveReport, SolverOpts};
use crate::util::json::Json;
use crate::util::threadpool::Lane;
use anyhow::{bail, Context, Result};

/// Valid `JobRequest::executor` values — the single authority shared by
/// request validation and the scheduler's backend dispatch.
pub const EXECUTOR_CHOICES: &[&str] = &["", "default", "native", "simd", "auto", "pjrt"];

/// Valid `JobRequest::format` values — the dataset representation:
///   dense          — the paper's dense pipeline (default);
///   sparse         — named generators produce the CSR sparse variant;
///   libsvm         — like sparse, but round-tripped through the libsvm
///                    parser (and `dataset: "libsvm:<path>"` loads a file
///                    directly);
///   mmapdense      — out-of-core dense: the design lives in a row-major
///                    on-disk file read through a budget-charged shard
///                    cache (`dataset: "mmapdense:<path>"` opens a file;
///                    named generators write a spill file first);
///   libsvm-chunked — out-of-core CSR: libsvm text pre-split into row
///                    shards streamed through the same cache
///                    (`dataset: "libsvm-chunked:<path>"`).
pub const FORMAT_CHOICES: &[&str] =
    &["", "dense", "sparse", "libsvm", "mmapdense", "libsvm-chunked"];

/// Valid `JobRequest::priority` values — the scheduler's QoS lanes
/// (served 4:2:1 high:normal:batch). "" means the default (normal).
pub const PRIORITY_CHOICES: &[&str] = &["", "high", "normal", "batch"];

/// Valid `JobRequest::step2` values — the HD-transform representation
/// policy ([`crate::precond::Step2Policy`]):
///   repr     — match the data representation (default; the paper path);
///   dense    — pin the materialized transform (budget-charged on CSR);
///   implicit — pin the signs-only transform (CSR datasets only);
///   auto     — nnz-aware cost model picks per job, never over budget.
pub const STEP2_CHOICES: &[&str] = &["", "repr", "dense", "implicit", "auto"];

/// Error-chain marker for deadline-shed jobs: the scheduler declined the
/// job because its deadline could not (or can no longer) be met. Wire
/// clients and tests detect sheds structurally via [`is_shed_error`]
/// instead of pattern-matching prose.
pub const SHED_ERROR_MARKER: &str = "deadline-shed";

/// Build the structured error a deadline-shed job resolves to. The outer
/// context is the [`SHED_ERROR_MARKER`] so [`is_shed_error`] can classify
/// it; the message carries the numbers an operator needs, including a
/// `retry_after_ms` hint — the shedding lane's backlog drain estimate
/// (queue depth × recent p50), i.e. when an immediate resubmit would stop
/// being shed on the spot.
pub fn shed_error(
    id: u64,
    lane: Lane,
    deadline_ms: f64,
    est_ms: f64,
    retry_after_ms: f64,
) -> anyhow::Error {
    anyhow::anyhow!(
        "job {id} on lane {} missed deadline: estimated {est_ms:.1}ms > deadline {deadline_ms:.1}ms (retry_after_ms={retry_after_ms:.0})",
        lane.name()
    )
    .context(format!("{SHED_ERROR_MARKER}: job {id}"))
}

/// Whether `err` is a deadline shed (vs a solver/validation failure) — the
/// structured check the serve protocol and tests rely on.
pub fn is_shed_error(err: &anyhow::Error) -> bool {
    err.chain().any(|c| c.starts_with(SHED_ERROR_MARKER))
}

/// One solve request (the line format of the serve socket and the record
/// the CLI builds from flags).
#[derive(Clone, Debug)]
pub struct JobRequest {
    /// Caller-chosen id echoed into the result.
    pub id: u64,
    /// dataset name: syn1 | syn2 | year | buzz (or `csv:<path>`)
    pub dataset: String,
    /// rows to generate (simulated datasets)
    pub n: usize,
    /// Solver name (see [`crate::solvers::by_name`]).
    pub solver: String,
    /// The constraint set W — any [`ConstraintSpec`] form ("unc", "l1",
    /// "simplex", `{"box": {...}}`, ...). Radius-bearing specs with
    /// radius 0 derive it from the unconstrained optimum (paper setup),
    /// possibly via the legacy top-level `radius` field.
    pub constraint: ConstraintSpec,
    /// ball radius; 0 = derive from the unconstrained optimum (paper setup)
    pub radius: f64,
    /// Mini-batch size r (stochastic solvers).
    pub batch_size: usize,
    /// Hard iteration cap (inner steps for stochastic solvers).
    pub max_iters: usize,
    /// Wall-clock budget for the solve loop (seconds).
    pub time_budget: f64,
    /// relative-error target (vs exact optimum) to stop at; 0 = none
    pub target_rel_err: f64,
    /// Best-of-k trials (the paper runs 10 and reports the best).
    pub trials: usize,
    /// Job seed; per-trial seeds are forked from it.
    pub seed: u64,
    /// Sketch construction name (see [`SketchKind::parse`]).
    pub sketch: String,
    /// Sketch rows s; 0 = construction-aware default.
    pub sketch_size: usize,
    /// Fixed step size; 0 = solver-specific theory default.
    pub eta: f64,
    /// Normalize the dataset before solving (scale-only on sparse data).
    pub normalize: bool,
    /// Backend for this request: default (coordinator's shared backend) |
    /// native | simd (arch-dispatched microkernels) | auto | pjrt
    /// (pjrt = hard-require artifacts). Default "default"; HDPW_EXECUTOR
    /// overrides the process default (the simd tier-1 CI variant sets
    /// HDPW_EXECUTOR=simd so the whole suite runs through the simd
    /// executor).
    pub executor: String,
    /// Row-shard height for block-streamed setup ops; 0 = heuristic.
    pub block_rows: usize,
    /// Acquire the preconditioner through the coordinator's artifact cache
    /// (keyed by the *job* seed) instead of resampling per trial. Default
    /// off — the paper's fresh-sketch-per-trial protocol — overridable
    /// process-wide with HDPW_REUSE_PRECOND=1.
    pub reuse_precond: bool,
    /// Start trials after the first from the best iterate so far. Default
    /// off (paper protocol); HDPW_WARM_START=1 flips the default.
    pub warm_start: bool,
    /// Dataset representation: dense | sparse | libsvm (see
    /// [`FORMAT_CHOICES`]). Default "dense"; HDPW_FORMAT overrides the
    /// process default (the sparse tier-1 CI variant sets
    /// HDPW_FORMAT=libsvm so the whole suite runs against generated sparse
    /// datasets round-tripped through the parser).
    pub format: String,
    /// Target nnz fraction for generated sparse datasets; 0 = the
    /// generator default (0.1). Ignored for dense format and file loads.
    pub density: f64,
    /// QoS lane: high | normal | batch (see [`PRIORITY_CHOICES`]). The
    /// scheduler serves lanes weighted 4:2:1 and bounds each lane's queue
    /// independently, so a batch backlog never blocks a high submit.
    pub priority: String,
    /// Soft deadline in milliseconds (0 = none). Jobs whose deadline the
    /// scheduler estimates unmeetable — queue depth × recent p50 — are
    /// shed up front with a structured error (see [`shed_error`]) instead
    /// of timing out after consuming a worker.
    pub deadline_ms: f64,
    /// HD-transform representation policy: repr | dense | implicit | auto
    /// (see [`STEP2_CHOICES`]). Default "" = repr, the paper path.
    pub step2: String,
    /// Rows per on-disk shard for the out-of-core formats (mmapdense /
    /// libsvm-chunked); 0 = the format default. Ignored for resident
    /// formats. Larger shards amortize read syscalls, smaller shards
    /// tighten the cache's resident footprint.
    pub chunk_rows: usize,
}

/// Truthy env flag ("1" | "true" | "yes") — the single authority for the
/// HDPW_REUSE_PRECOND / HDPW_WARM_START process defaults (bench-info must
/// report exactly what `JobRequest::default` will do).
pub fn env_flag(name: &str) -> bool {
    matches!(
        std::env::var(name).ok().as_deref(),
        Some("1") | Some("true") | Some("yes")
    )
}

impl Default for JobRequest {
    fn default() -> Self {
        JobRequest {
            id: 0,
            dataset: "syn2".into(),
            n: 16_384,
            solver: "hdpwbatchsgd".into(),
            constraint: ConstraintSpec::Unconstrained,
            radius: 0.0,
            batch_size: 64,
            max_iters: 5_000,
            time_budget: 30.0,
            target_rel_err: 0.0,
            trials: 1,
            seed: 1,
            sketch: "countsketch".into(),
            sketch_size: 0,
            eta: 0.0,
            normalize: false,
            executor: std::env::var("HDPW_EXECUTOR")
                .ok()
                .filter(|v| !v.is_empty())
                .unwrap_or_else(|| "default".into()),
            block_rows: 0,
            reuse_precond: env_flag("HDPW_REUSE_PRECOND"),
            warm_start: env_flag("HDPW_WARM_START"),
            format: std::env::var("HDPW_FORMAT")
                .ok()
                .filter(|v| !v.is_empty())
                .unwrap_or_else(|| "dense".into()),
            density: 0.0,
            priority: "normal".into(),
            deadline_ms: 0.0,
            step2: String::new(),
            chunk_rows: 0,
        }
    }
}

impl JobRequest {
    /// Serialize to the wire form (simple constraints stay plain strings,
    /// so pre-spec clients read the field unchanged).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("dataset", Json::str(self.dataset.clone())),
            ("n", Json::num(self.n as f64)),
            ("solver", Json::str(self.solver.clone())),
            ("constraint", self.constraint.to_json()),
            ("radius", Json::num(self.radius)),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("max_iters", Json::num(self.max_iters as f64)),
            ("time_budget", Json::num(self.time_budget)),
            ("target_rel_err", Json::num(self.target_rel_err)),
            ("trials", Json::num(self.trials as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("sketch", Json::str(self.sketch.clone())),
            ("sketch_size", Json::num(self.sketch_size as f64)),
            ("eta", Json::num(self.eta)),
            ("normalize", Json::Bool(self.normalize)),
            ("executor", Json::str(self.executor.clone())),
            ("block_rows", Json::num(self.block_rows as f64)),
            ("reuse_precond", Json::Bool(self.reuse_precond)),
            ("warm_start", Json::Bool(self.warm_start)),
            ("format", Json::str(self.format.clone())),
            ("density", Json::num(self.density)),
            ("priority", Json::str(self.priority.clone())),
            ("deadline_ms", Json::num(self.deadline_ms)),
            ("step2", Json::str(self.step2.clone())),
            ("chunk_rows", Json::num(self.chunk_rows as f64)),
        ])
    }

    /// The fusion signature: two coalesced requests with the same signature
    /// are computationally identical jobs (same dataset, solver, seeds,
    /// budgets — everything except the echoed id and the scheduling-only
    /// fields), so one execution can serve both. Determinism of the solve
    /// pipeline is what makes this sound: equal signatures ⇒ bitwise-equal
    /// results.
    pub fn fuse_signature(&self) -> String {
        let mut c = self.clone();
        c.id = 0;
        c.priority.clear();
        c.deadline_ms = 0.0;
        c.to_json().to_string()
    }

    /// Parse a request from its JSON form; absent fields default. A
    /// malformed `constraint` spec errors here with the offending path, so
    /// the serve loop reports it on the request's own line.
    pub fn from_json(j: &Json) -> Result<JobRequest> {
        let def = JobRequest::default();
        let get_n = |k: &str, d: f64| j.get(k).and_then(Json::as_f64).unwrap_or(d);
        let get_s = |k: &str, d: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .unwrap_or(d)
                .to_string()
        };
        let constraint = match j.get("constraint") {
            Some(v) => ConstraintSpec::parse_json(v)?,
            None => def.constraint.clone(),
        };
        let req = JobRequest {
            id: get_n("id", 0.0) as u64,
            dataset: get_s("dataset", &def.dataset),
            n: get_n("n", def.n as f64) as usize,
            solver: get_s("solver", &def.solver),
            constraint,
            radius: get_n("radius", def.radius),
            batch_size: get_n("batch_size", def.batch_size as f64) as usize,
            max_iters: get_n("max_iters", def.max_iters as f64) as usize,
            time_budget: get_n("time_budget", def.time_budget),
            target_rel_err: get_n("target_rel_err", def.target_rel_err),
            trials: (get_n("trials", def.trials as f64) as usize).max(1),
            seed: get_n("seed", def.seed as f64) as u64,
            sketch: get_s("sketch", &def.sketch),
            sketch_size: get_n("sketch_size", 0.0) as usize,
            eta: get_n("eta", 0.0),
            normalize: j
                .get("normalize")
                .and_then(Json::as_bool)
                .unwrap_or(def.normalize),
            executor: get_s("executor", &def.executor),
            block_rows: get_n("block_rows", def.block_rows as f64) as usize,
            reuse_precond: j
                .get("reuse_precond")
                .and_then(Json::as_bool)
                .unwrap_or(def.reuse_precond),
            warm_start: j
                .get("warm_start")
                .and_then(Json::as_bool)
                .unwrap_or(def.warm_start),
            format: get_s("format", &def.format),
            density: get_n("density", def.density),
            priority: get_s("priority", &def.priority),
            deadline_ms: get_n("deadline_ms", def.deadline_ms),
            step2: get_s("step2", &def.step2),
            chunk_rows: get_n("chunk_rows", def.chunk_rows as f64) as usize,
        };
        req.validate()?;
        Ok(req)
    }

    /// Cross-field validation (the constraint spec validates at parse).
    pub fn validate(&self) -> Result<()> {
        if crate::solvers::by_name(&self.solver).is_none() {
            bail!(
                "unknown solver {:?}; available: {:?}",
                self.solver,
                crate::solvers::all_names()
            );
        }
        if SketchKind::parse(&self.sketch).is_none() {
            bail!("unknown sketch {:?}", self.sketch);
        }
        if self.batch_size == 0 || self.max_iters == 0 {
            bail!("batch_size and max_iters must be positive");
        }
        if !EXECUTOR_CHOICES.contains(&self.executor.as_str()) {
            bail!(
                "unknown executor {:?} (valid: {:?})",
                self.executor,
                EXECUTOR_CHOICES
            );
        }
        if !FORMAT_CHOICES.contains(&self.format.as_str()) {
            bail!(
                "unknown format {:?} (valid: {:?})",
                self.format,
                FORMAT_CHOICES
            );
        }
        if !(0.0..=1.0).contains(&self.density) {
            bail!("density must be in [0, 1], got {}", self.density);
        }
        if !PRIORITY_CHOICES.contains(&self.priority.as_str()) {
            bail!(
                "unknown priority {:?} (valid: {:?})",
                self.priority,
                PRIORITY_CHOICES
            );
        }
        if !self.deadline_ms.is_finite() || self.deadline_ms < 0.0 {
            bail!("deadline_ms must be a finite value >= 0, got {}", self.deadline_ms);
        }
        if !STEP2_CHOICES.contains(&self.step2.as_str()) {
            bail!(
                "unknown step2 {:?} (valid: {:?})",
                self.step2,
                STEP2_CHOICES
            );
        }
        if self.step2 == "implicit" && matches!(self.format.as_str(), "" | "dense" | "mmapdense") {
            bail!(
                "step2 \"implicit\" requires a sparse dataset (format sparse | libsvm | libsvm-chunked)"
            );
        }
        Ok(())
    }

    /// The scheduler lane this request runs on ([`JobRequest::priority`];
    /// "" maps to normal). Call after `validate` — unknown names fall back
    /// to normal rather than panicking.
    pub fn lane(&self) -> Lane {
        Lane::parse(&self.priority).unwrap_or(Lane::Normal)
    }

    /// The radius a radius-bearing constraint actually runs at: the spec's
    /// embedded radius if positive, else the request's legacy top-level
    /// `radius` field, else the paper-protocol value derived from the
    /// unconstrained optimum's norms (see
    /// [`ConstraintSpec::derived_radius`]). 0 for radius-free sets.
    pub fn resolved_radius(&self, l1_star: f64, l2_star: f64) -> f64 {
        let spec_r = self.constraint.radius_param();
        if spec_r > 0.0 {
            spec_r
        } else if self.radius > 0.0 {
            self.radius
        } else {
            self.constraint.derived_radius(l1_star, l2_star)
        }
    }

    /// Build the constraint set this request solves under, given the
    /// resolved radius (see [`JobRequest::resolved_radius`]).
    pub fn build_constraint(&self, radius: f64) -> Result<ConstraintRef> {
        self.constraint
            .build(radius)
            .with_context(|| format!("constraint {:?}", self.constraint.tag()))
    }

    /// Build SolverOpts given the resolved constraint radius and optimum.
    pub fn solver_opts(&self, radius: f64, f_star: Option<f64>) -> Result<SolverOpts> {
        self.solver_opts_with_constraint(self.build_constraint(radius)?, f_star)
    }

    /// [`JobRequest::solver_opts`] with an already-built constraint set —
    /// the coordinator builds (and counter-wraps) one set per job and
    /// threads it through every trial without rebuilding (an
    /// [`crate::constraints::AffineEquality`] build re-runs its QR).
    pub fn solver_opts_with_constraint(
        &self,
        constraint: ConstraintRef,
        f_star: Option<f64>,
    ) -> Result<SolverOpts> {
        let sketch =
            SketchKind::parse(&self.sketch).context("sketch kind")?;
        Ok(SolverOpts {
            constraint,
            batch_size: self.batch_size,
            max_iters: self.max_iters,
            eps_abs: match (self.target_rel_err, f_star) {
                (e, Some(fs)) if e > 0.0 => Some(e * fs),
                _ => None,
            },
            f_star,
            time_budget: self.time_budget,
            sketch,
            sketch_size: (self.sketch_size > 0).then_some(self.sketch_size),
            eta: (self.eta > 0.0).then_some(self.eta),
            chunk: 50,
            block_rows: (self.block_rows > 0).then_some(self.block_rows),
            seed: self.seed,
            step2: crate::precond::Step2Policy::parse(&self.step2)
                .with_context(|| format!("step2 {:?}", self.step2))?,
            // the cache handle / dataset id / warm iterate are attached by
            // the scheduler, which owns them
            session: Default::default(),
        })
    }
}

/// Result of a job: the best trial's report plus aggregate info.
#[derive(Clone, Debug)]
pub struct JobResult {
    /// The request's id, echoed back.
    pub id: u64,
    /// Solver name the job ran.
    pub solver: String,
    /// Dataset name the job ran against.
    pub dataset: String,
    /// The exact unconstrained optimum's objective.
    pub f_star: f64,
    /// Best trial's final objective.
    pub best_f: f64,
    /// (best_f - f_star) / f_star, clamped at 0.
    pub best_rel_err: f64,
    /// Trials executed.
    pub trials_run: usize,
    /// Wall-clock seconds across all trials.
    pub total_secs: f64,
    /// The active constraint's tag ("unc", "l1", "simplex", ...).
    pub constraint: String,
    /// The active constraint's parameter summary
    /// ([`crate::constraints::ConstraintSet::params`] — e.g.
    /// "radius=0.5", "lo=-1 hi=1"); box bounds and simplex totals survive
    /// into reports instead of flattening to a meaningless scalar.
    pub constraint_params: String,
    /// Projection-oracle invocations across all trials (Euclidean +
    /// metric; no-op unconstrained projections are not counted).
    pub projections: usize,
    /// Stored entries of the solved dataset (n*d when dense).
    pub nnz: usize,
    /// nnz / (n*d). NOTE: a CSR dataset generated at density 1.0 also
    /// reports 1.0 — use `sparse` for the representation, not this value.
    pub density: f64,
    /// Whether the job ran on the CSR pipeline (the representation flag; a
    /// fully dense CSR payload still reports true here).
    pub sparse: bool,
    /// Admission-control estimate of the job's budget-tracked
    /// materialization bytes (HD buffers; 0 for step-1-only solvers).
    pub mem_est_bytes: usize,
    /// Process-budget high-water mark observed at job completion
    /// (`MemBudget::peak` — shared across concurrent jobs, so this is the
    /// worker's view of process pressure, not a per-job isolate).
    pub mem_peak_bytes: usize,
    /// Densifications recorded on the process budget while this job ran
    /// (exact when jobs run serially; an upper bound under concurrency).
    /// A CSR step-1-only solve reports 0 here — the acceptance criterion.
    pub densify_events: usize,
    /// Shard loads from disk recorded on the process budget while this job
    /// ran (0 for resident formats; same delta semantics as
    /// `densify_events`). Cache hits cost nothing and are not counted.
    pub shard_faults: usize,
    /// Shard-cache evictions recorded while this job ran — each one is
    /// resident bytes given back under budget pressure, the out-of-core
    /// analog of a densify event.
    pub shard_evictions: usize,
    /// Transient I/O retries (EINTR/WouldBlock/TimedOut re-reads) absorbed
    /// by the shard reader while this job ran; persistent failures surface
    /// as the job's structured error instead.
    pub io_retries: usize,
    /// Peak size of the coalescing group this job shared its
    /// preconditioner setup with (concurrent same-`PrecondKey` jobs).
    /// 1 = ran alone; > 1 = setup/artifact work was amortized across the
    /// group while per-job trial RNG streams stayed independent.
    pub coalesced_batch: usize,
    /// Trials executed in the fused lockstep driver (one shared objective
    /// pass per step across the stacked iterates). 1 = trials ran serially
    /// (the default paper path, or a solver with no step rule).
    pub batched_trials: usize,
    /// Concurrent identical requests this job's solve execution was shared
    /// with (the degenerate column-stack of cross-request fusion: equal
    /// fuse signatures ⇒ bitwise-equal results ⇒ one execution serves the
    /// group). 1 = executed alone.
    pub batched_requests: usize,
    /// Warm-start outcome of the best trial: "off" (not requested) |
    /// "used" (started from a prior iterate) | "rejected-dim" (a supplied
    /// x0 had the wrong dimension and the trial cold-started — previously
    /// a silent zero fallback).
    pub warm_start: String,
    /// The best trial's full report (iterate, trace, cache outcome).
    pub best: SolveReport,
}

impl JobResult {
    /// Serialize to the wire form (one line of the serve protocol).
    pub fn to_json(&self) -> Json {
        let trace: Vec<Json> = self
            .best
            .trace
            .iter()
            .map(|p| {
                Json::Arr(vec![
                    Json::num(p.iters as f64),
                    Json::num(p.secs),
                    Json::num(p.f),
                ])
            })
            .collect();
        Json::obj(vec![
            ("id", Json::num(self.id as f64)),
            ("solver", Json::str(self.solver.clone())),
            ("dataset", Json::str(self.dataset.clone())),
            ("f_star", Json::num(self.f_star)),
            ("best_f", Json::num(self.best_f)),
            ("best_rel_err", Json::num(self.best_rel_err)),
            ("trials_run", Json::num(self.trials_run as f64)),
            ("total_secs", Json::num(self.total_secs)),
            ("constraint", Json::str(self.constraint.clone())),
            (
                "constraint_params",
                Json::str(self.constraint_params.clone()),
            ),
            ("projections", Json::num(self.projections as f64)),
            ("nnz", Json::num(self.nnz as f64)),
            ("density", Json::num(self.density)),
            ("sparse", Json::Bool(self.sparse)),
            ("mem_est_bytes", Json::num(self.mem_est_bytes as f64)),
            ("mem_peak_bytes", Json::num(self.mem_peak_bytes as f64)),
            ("densify_events", Json::num(self.densify_events as f64)),
            ("shard_faults", Json::num(self.shard_faults as f64)),
            ("shard_evictions", Json::num(self.shard_evictions as f64)),
            ("io_retries", Json::num(self.io_retries as f64)),
            ("coalesced_batch", Json::num(self.coalesced_batch as f64)),
            ("batched_trials", Json::num(self.batched_trials as f64)),
            (
                "batched_requests",
                Json::num(self.batched_requests as f64),
            ),
            ("warm_start", Json::str(self.warm_start.clone())),
            ("step2", Json::str(self.best.step2.clone())),
            ("iters", Json::num(self.best.iters as f64)),
            ("setup_secs", Json::num(self.best.setup_secs)),
            ("solve_secs", Json::num(self.best.solve_secs)),
            // "off" | "miss" | "hit" | "upgrade": a cold cache is
            // distinguishable from a broken one (and from reuse never
            // being requested)
            (
                "precond_cache",
                Json::str(self.best.precond_cache.as_str().to_string()),
            ),
            ("trace", Json::Arr(trace)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut req = JobRequest::default();
        req.id = 7;
        req.solver = "pwgradient".into();
        req.constraint = "l1".into();
        req.trials = 10;
        let j = req.to_json();
        let back = JobRequest::from_json(&j).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.solver, "pwgradient");
        assert_eq!(back.constraint, ConstraintSpec::L1Ball { radius: 0.0 });
        assert_eq!(back.trials, 10);
        assert_eq!(back.n, req.n);
    }

    #[test]
    fn structured_constraints_roundtrip_through_requests() {
        for spec in [
            ConstraintSpec::Simplex { total: 2.0 },
            ConstraintSpec::NonNeg,
            ConstraintSpec::ScalarBox { lo: -1.0, hi: 1.0 },
            ConstraintSpec::CoordBox {
                lo: vec![0.0, -1.0],
                hi: vec![1.0, 1.0],
            },
            ConstraintSpec::ElasticNet {
                alpha: 0.5,
                radius: 1.5,
            },
            ConstraintSpec::AffineEq {
                c: vec![vec![1.0, 1.0, 0.0]],
                e: vec![1.0],
            },
        ] {
            let mut req = JobRequest::default();
            req.constraint = spec.clone();
            let back = JobRequest::from_json(&req.to_json()).unwrap();
            assert_eq!(back.constraint, spec);
        }
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let j = Json::parse(r#"{"solver": "ihs"}"#).unwrap();
        let req = JobRequest::from_json(&j).unwrap();
        assert_eq!(req.solver, "ihs");
        assert_eq!(req.dataset, "syn2");
        assert_eq!(req.trials, 1);
        assert!(req.constraint.is_unconstrained());
    }

    #[test]
    fn validation_rejects_nonsense() {
        let j = Json::parse(r#"{"solver": "nope"}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_err());
        let j = Json::parse(r#"{"constraint": "l7"}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_err());
        let j = Json::parse(r#"{"constraint": {"box": {"lo": [1], "hi": [0]}}}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_err());
        let j = Json::parse(r#"{"sketch": "fourier"}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_err());
    }

    #[test]
    fn executor_and_block_rows_roundtrip() {
        let mut req = JobRequest::default();
        req.executor = "native".into();
        req.block_rows = 4096;
        let back = JobRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.executor, "native");
        assert_eq!(back.block_rows, 4096);
        // missing fields default (HDPW_EXECUTOR overrides the process
        // default, so the simd CI variant expects its own value here)
        let j = Json::parse(r#"{"solver": "exact"}"#).unwrap();
        let d = JobRequest::from_json(&j).unwrap();
        let expect_exec = std::env::var("HDPW_EXECUTOR")
            .ok()
            .filter(|v| !v.is_empty())
            .unwrap_or_else(|| "default".into());
        assert_eq!(d.executor, expect_exec);
        assert_eq!(d.block_rows, 0);
        // bad executor rejected
        let j = Json::parse(r#"{"executor": "gpu9000"}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_err());
        // block_rows threads into SolverOpts
        let opts = back.solver_opts(0.0, None).unwrap();
        assert_eq!(opts.block_rows, Some(4096));
        let opts0 = d.solver_opts(0.0, None).unwrap();
        assert_eq!(opts0.block_rows, None);
    }

    #[test]
    fn reuse_and_warm_start_roundtrip() {
        let mut req = JobRequest::default();
        req.reuse_precond = true;
        req.warm_start = true;
        let back = JobRequest::from_json(&req.to_json()).unwrap();
        assert!(back.reuse_precond);
        assert!(back.warm_start);
        // explicit false survives even if an env default would say true
        let j = Json::parse(r#"{"reuse_precond": false, "warm_start": false}"#).unwrap();
        let d = JobRequest::from_json(&j).unwrap();
        assert!(!d.reuse_precond);
        assert!(!d.warm_start);
        // solver_opts leaves the session for the scheduler to attach
        let opts = back.solver_opts(0.0, None).unwrap();
        assert!(!opts.session.reuse_precond);
        assert!(opts.session.cache.is_none());
    }

    #[test]
    fn format_and_density_roundtrip_and_validate() {
        let mut req = JobRequest::default();
        req.format = "sparse".into();
        req.density = 0.05;
        let back = JobRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.format, "sparse");
        assert!((back.density - 0.05).abs() < 1e-15);
        // bad format rejected
        let j = Json::parse(r#"{"format": "parquet"}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_err());
        // bad density rejected
        let j = Json::parse(r#"{"density": 1.5}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_err());
        // libsvm is a valid format
        let j = Json::parse(r#"{"format": "libsvm"}"#).unwrap();
        assert_eq!(JobRequest::from_json(&j).unwrap().format, "libsvm");
    }

    #[test]
    fn out_of_core_formats_and_chunk_rows_roundtrip() {
        let mut req = JobRequest::default();
        req.format = "mmapdense".into();
        req.chunk_rows = 512;
        let back = JobRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.format, "mmapdense");
        assert_eq!(back.chunk_rows, 512);
        // libsvm-chunked is a valid format; chunk_rows defaults to 0
        let j = Json::parse(r#"{"format": "libsvm-chunked"}"#).unwrap();
        let d = JobRequest::from_json(&j).unwrap();
        assert_eq!(d.format, "libsvm-chunked");
        assert_eq!(d.chunk_rows, 0);
        // chunk_rows is compute-relevant: it separates fuse signatures
        let mut a = JobRequest::default();
        a.chunk_rows = 64;
        let b = JobRequest::default();
        assert_ne!(a.fuse_signature(), b.fuse_signature());
        // step2 implicit stays dense-rejected on the mmap flavor
        let j = Json::parse(r#"{"step2": "implicit", "format": "mmapdense"}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_err());
        let j = Json::parse(r#"{"step2": "implicit", "format": "libsvm-chunked"}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_ok());
    }

    #[test]
    fn solver_opts_mapping() {
        let mut req = JobRequest::default();
        req.constraint = "l2".into();
        req.target_rel_err = 0.01;
        req.eta = 0.5;
        req.sketch_size = 777;
        let opts = req.solver_opts(2.0, Some(100.0)).unwrap();
        assert_eq!(opts.constraint.tag(), "l2");
        assert_eq!(opts.constraint.radius(), 2.0);
        assert_eq!(opts.eps_abs, Some(1.0));
        assert_eq!(opts.eta, Some(0.5));
        assert_eq!(opts.sketch_size, Some(777));
        // no f_star -> no eps_abs
        let opts2 = req.solver_opts(2.0, None).unwrap();
        assert_eq!(opts2.eps_abs, None);
        // a ball with no radius anywhere is a build-time error
        assert!(req.solver_opts(0.0, None).is_err());
    }

    #[test]
    fn priority_and_deadline_roundtrip_and_validate() {
        let mut req = JobRequest::default();
        assert_eq!(req.priority, "normal");
        assert_eq!(req.lane(), Lane::Normal);
        req.priority = "high".into();
        req.deadline_ms = 250.0;
        let back = JobRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.priority, "high");
        assert_eq!(back.lane(), Lane::High);
        assert!((back.deadline_ms - 250.0).abs() < 1e-12);
        // missing fields default to normal / no deadline
        let j = Json::parse(r#"{"solver": "exact"}"#).unwrap();
        let d = JobRequest::from_json(&j).unwrap();
        assert_eq!(d.lane(), Lane::Normal);
        assert_eq!(d.deadline_ms, 0.0);
        // batch is a valid lane
        let j = Json::parse(r#"{"priority": "batch"}"#).unwrap();
        assert_eq!(JobRequest::from_json(&j).unwrap().lane(), Lane::Batch);
        // bad priority rejected
        let j = Json::parse(r#"{"priority": "urgent"}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_err());
        // negative deadline rejected
        let j = Json::parse(r#"{"deadline_ms": -5}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_err());
    }

    #[test]
    fn shed_errors_are_structured() {
        let err = shed_error(42, Lane::Batch, 100.0, 350.0, 220.0);
        assert!(is_shed_error(&err), "{err:#}");
        // the classification survives further wrapping
        let wrapped = err.context("while serving connection");
        assert!(is_shed_error(&wrapped), "{wrapped:#}");
        // ordinary errors are not sheds, even ones mentioning deadlines
        let plain = anyhow::anyhow!("solver blew the deadline budget");
        assert!(!is_shed_error(&plain));
        // the message carries the operator-facing numbers
        let msg = format!("{:#}", shed_error(7, Lane::High, 10.0, 99.0, 88.6));
        assert!(msg.contains("deadline-shed"), "{msg}");
        assert!(msg.contains("10.0ms"), "{msg}");
        assert!(msg.contains("99.0ms"), "{msg}");
        // ...and the backlog-drain retry hint
        assert!(msg.contains("retry_after_ms=89"), "{msg}");
    }

    #[test]
    fn step2_roundtrip_and_validate() {
        let mut req = JobRequest::default();
        assert_eq!(req.step2, "");
        req.step2 = "auto".into();
        req.format = "sparse".into();
        let back = JobRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back.step2, "auto");
        let opts = back.solver_opts(0.0, None).unwrap();
        assert_eq!(opts.step2, crate::precond::Step2Policy::Auto);
        // "" and "repr" both map to the paper default
        let j = Json::parse(r#"{"solver": "exact"}"#).unwrap();
        let d = JobRequest::from_json(&j).unwrap();
        assert_eq!(
            d.solver_opts(0.0, None).unwrap().step2,
            crate::precond::Step2Policy::Repr
        );
        // unknown policy rejected
        let j = Json::parse(r#"{"step2": "sparse"}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_err());
        // implicit on a dense-format request rejected up front
        let j = Json::parse(r#"{"step2": "implicit"}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_err());
        let j = Json::parse(r#"{"step2": "implicit", "format": "sparse"}"#).unwrap();
        assert!(JobRequest::from_json(&j).is_ok());
    }

    #[test]
    fn fuse_signature_ignores_identity_and_scheduling_fields() {
        let mut a = JobRequest::default();
        a.id = 1;
        a.priority = "high".into();
        a.deadline_ms = 50.0;
        let mut b = JobRequest::default();
        b.id = 2;
        b.priority = "batch".into();
        assert_eq!(a.fuse_signature(), b.fuse_signature());
        // any compute-relevant field separates the signatures
        b.seed = 999;
        assert_ne!(a.fuse_signature(), b.fuse_signature());
    }

    #[test]
    fn radius_resolution_precedence() {
        let mut req = JobRequest::default();
        // spec-embedded radius beats the legacy field and the derived value
        req.constraint = ConstraintSpec::L2Ball { radius: 3.0 };
        req.radius = 9.0;
        assert_eq!(req.resolved_radius(1.0, 2.0), 3.0);
        // legacy field beats the derived value
        req.constraint = ConstraintSpec::L2Ball { radius: 0.0 };
        assert_eq!(req.resolved_radius(1.0, 2.0), 9.0);
        // derived value as the paper default
        req.radius = 0.0;
        assert_eq!(req.resolved_radius(1.0, 2.0), 2.0);
        req.constraint = ConstraintSpec::L1Ball { radius: 0.0 };
        assert_eq!(req.resolved_radius(1.0, 2.0), 1.0);
        // radius-free sets resolve to 0 and still build
        req.constraint = ConstraintSpec::Simplex { total: 1.0 };
        assert_eq!(req.resolved_radius(1.0, 2.0), 0.0);
        assert!(req.build_constraint(0.0).is_ok());
    }
}
