//! The coordinator proper: dataset cache, ground-truth cache, best-of-k
//! trial execution, and a bounded-concurrency job runner.
//!
//! The paper's evaluation protocol is encoded here: each method runs
//! `trials` times (paper: 10) with per-trial seeds forked from the job seed,
//! and the best run is reported; constrained radii default to the norms of
//! the unconstrained optimum; datasets are normalized for low-precision
//! solvers when requested.

use super::job::{shed_error, JobRequest, JobResult, EXECUTOR_CHOICES};
use super::metrics::Metrics;
use crate::backend::Backend;
use crate::constraints::{ConstraintRef, ConstraintSet, ProjectionCounter};
use crate::data::{chunked, io, libsvm, mmap, out_of_core, sparse_gen, uci_sim, Dataset, OnDiskDesign};
use crate::precond::{PrecondCache, PrecondKey};
use crate::solvers::driver::SessionCtx;
use crate::solvers::exact::{ground_truth, try_ground_truth, GroundTruth};
use crate::solvers::{SolveReport, Solver, SolverOpts};
use crate::util::mem::MemBudget;
use crate::util::rng::Rng;
use crate::util::stats::Timer;
use crate::util::threadpool::{Lane, ThreadPool};
use anyhow::{anyhow, bail, Result};
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Process-level configuration for a [`Coordinator`].
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// worker threads for concurrent jobs
    pub workers: usize,
    /// queue bound (backpressure threshold)
    pub max_queue: usize,
    /// dataset cache directory (None = no caching)
    pub cache_dir: Option<PathBuf>,
    /// byte budget for the preconditioner artifact cache
    /// (default: HDPW_PRECOND_CACHE_MB, 256 MiB)
    pub precond_cache_bytes: usize,
    /// Memory budget charged by dense materializations (HD buffers, lazy
    /// CSR mirrors). Default: the process budget (`HDPW_MEM_MB`, overridden
    /// by `serve --mem-mb`); tests pass a private budget.
    pub mem_budget: Arc<MemBudget>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            workers: 2,
            max_queue: 16,
            cache_dir: None,
            precond_cache_bytes: PrecondCache::default_budget(),
            mem_budget: MemBudget::process(),
        }
    }
}

/// Dataset + ground truth, cached per (name, n, normalize, seed).
struct Prepared {
    ds: Arc<Dataset>,
    gt: Arc<GroundTruth>,
}

/// One live coalescing episode: the set of in-flight jobs sharing a
/// `PrecondKey`. `members` tracks current occupancy; `peak` is the episode's
/// high-water mark — what every member reports as `coalesced_batch`. The
/// entry is removed when the last member leaves, so a later burst on the
/// same key starts a fresh episode (peaks don't leak across idle gaps).
#[derive(Default)]
struct CoalesceGroup {
    members: usize,
    peak: usize,
}

/// One cross-request fusion slot: concurrent *identical* requests (equal
/// [`JobRequest::fuse_signature`]) elect a leader that executes the job
/// once; followers park on `cv` and adopt the published result. Sound
/// because the `reuse_precond` pipeline is a pure function of the request
/// — equal signatures imply bitwise-equal results, so one execution is the
/// degenerate column-stack of the group's solves.
struct FuseSlot {
    state: Mutex<FuseState>,
    cv: Condvar,
}

#[derive(Default)]
struct FuseState {
    /// Leader finished (successfully or not) and published.
    done: bool,
    /// The leader's result, present on success only — a failed leader
    /// publishes `None`, and every follower falls back to its own run (so
    /// transient failures don't fan out and a genuine error surfaces
    /// per-request).
    result: Option<JobResult>,
    /// Currently registered members (leader + waiting followers).
    members: usize,
    /// Membership at publish time — what the group reports as
    /// `batched_requests`.
    shared: usize,
}

/// The coordinator proper: shared backend, worker pool, caches, metrics.
pub struct Coordinator {
    backend: Backend,
    pool: ThreadPool,
    /// Service counters (jobs, latencies, projections, sparse workload).
    pub metrics: Arc<Metrics>,
    prepared: Mutex<HashMap<String, Arc<Prepared>>>,
    /// Single-flight claims on dataset preparation: concurrent first-time
    /// jobs on one dataset elect one builder (generation + ground-truth QR
    /// are the expensive part); the rest park on `prepare_cv` and adopt the
    /// published entry instead of redoing the work per worker.
    preparing: Mutex<HashSet<String>>,
    prepare_cv: Condvar,
    /// Live request-coalescing episodes, keyed by the same `PrecondKey` the
    /// artifact cache uses. Members share one preconditioner computation
    /// (via the cache's single-flight claim) while their per-trial RNG
    /// streams stay per-job; the episode peak becomes `coalesced_batch`.
    coalesce: Mutex<HashMap<PrecondKey, CoalesceGroup>>,
    /// Live cross-request fusion slots, keyed by [`JobRequest::fuse_signature`]
    /// — identical concurrent `reuse_precond` requests share one execution.
    fuse: Mutex<HashMap<String, Arc<FuseSlot>>>,
    /// Shared preconditioner artifacts, keyed by (dataset, sketch, s, seed,
    /// block_rows) — the setup-amortization layer for `reuse_precond` jobs.
    precond_cache: Arc<PrecondCache>,
    /// The memory budget every solve's dense materializations charge; also
    /// the admission-control authority for jobs whose materialization
    /// estimate would bust the cap.
    mem: Arc<MemBudget>,
    /// Scratch directory for named datasets spilled to an on-disk format
    /// (`format: "mmapdense" | "libsvm-chunked"`): generated once per
    /// prepared-cache key, then re-opened disk-backed against `mem`.
    /// Unique per coordinator instance so concurrent coordinators (tests,
    /// multiple serve processes) never race on a path; removed on drop —
    /// spills are scratch, not a cache.
    spill_dir: PathBuf,
    config: CoordinatorConfig,
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // best-effort: nothing references the spilled files once the
        // prepared map (dropped with us) releases its OnDiskDesign handles;
        // on Linux open handles keep working even if removal wins the race
        let _ = std::fs::remove_dir_all(&self.spill_dir);
    }
}

impl Coordinator {
    /// Build a coordinator around a shared backend.
    pub fn new(backend: Backend, config: CoordinatorConfig) -> Self {
        static SPILL_SEQ: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);
        let spill_dir = std::env::temp_dir().join(format!(
            "hdpw_spill_{}_{}",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        Coordinator {
            backend,
            pool: ThreadPool::new(config.workers.max(1), config.max_queue.max(1)),
            metrics: Arc::new(Metrics::new()),
            prepared: Mutex::new(HashMap::new()),
            preparing: Mutex::new(HashSet::new()),
            prepare_cv: Condvar::new(),
            coalesce: Mutex::new(HashMap::new()),
            fuse: Mutex::new(HashMap::new()),
            precond_cache: Arc::new(PrecondCache::new(config.precond_cache_bytes)),
            mem: Arc::clone(&config.mem_budget),
            spill_dir,
            config,
        }
    }

    /// The shared backend (serve metrics, tests).
    pub fn backend(&self) -> &Backend {
        &self.backend
    }

    /// The shared preconditioner artifact cache.
    pub fn precond_cache(&self) -> &Arc<PrecondCache> {
        &self.precond_cache
    }

    /// The coordinator's memory budget (serve metrics, tests).
    pub fn mem_budget(&self) -> &Arc<MemBudget> {
        &self.mem
    }

    /// Total tasks migrated between workers by the stealing pool
    /// (serve metrics: nonzero means the load balancer is actually working).
    pub fn pool_steals(&self) -> usize {
        self.pool.steals()
    }

    /// Tasks submitted to `lane` but not yet started — the backlog signal
    /// the deadline estimator reads (serve metrics).
    pub fn queue_depth(&self, lane: Lane) -> usize {
        self.pool.queued(lane)
    }

    /// Backlog-drain estimate for `lane`: queued work at or above the lane,
    /// divided across the workers, priced at the recent p50 job latency.
    /// This is what deadline sheds hand back as `retry_after_ms` — a client
    /// that waits roughly this long retries into a drained queue instead of
    /// hammering a backlogged one. 0 when no latency history exists yet.
    fn retry_hint_ms(&self, lane: Lane) -> f64 {
        let p50 = self.metrics.latency_percentile(50.0).unwrap_or(0.0);
        (self.pool.queued_at_or_above(lane) as f64 / self.config.workers.max(1) as f64)
            * p50
            * 1e3
    }

    /// Admission-control estimate of a job's budget-tracked materialization
    /// bytes: the HD solvers on *dense* datasets charge one padded `[A | b]`
    /// FWHT buffer ([`crate::precond::hd_buffer_bytes`] — the same formula
    /// the actual charge uses) per resident artifact. On CSR datasets the
    /// HD step is held implicitly (signs only, sampled rows evaluated on
    /// demand), so those jobs charge nothing — estimating the dense buffer
    /// for them would reject sparse jobs the budget trivially fits. Every
    /// other solver is step-1-only (or CGLS exact) and charges nothing. The
    /// estimate deliberately ignores untracked allocations (iterates,
    /// sketches — O(sd + d^2), negligible next to the n-sized buffer).
    ///
    /// `step2` is the job's *resolved* step-2 mode: a CSR job normally
    /// holds HD implicitly and charges nothing, but one pinned (or
    /// auto-crossed-over) to `Step2Mode::Dense` materializes the same
    /// padded buffer a dense job does and must be admitted against it.
    pub fn job_mem_estimate(
        solver: &str,
        n: usize,
        d: usize,
        sparse: bool,
        step2: crate::precond::Step2Mode,
    ) -> usize {
        if sparse && step2 != crate::precond::Step2Mode::Dense {
            return 0;
        }
        let canonical = crate::solvers::by_name(solver)
            .map(|s| s.name().to_string())
            .unwrap_or_default();
        match canonical.as_str() {
            "hdpwbatchsgd" | "hdpwaccbatchsgd" => crate::precond::hd_buffer_bytes(n, d),
            _ => 0,
        }
    }

    /// Resolve the backend serving one request (the serve loop's
    /// per-request executor selection):
    ///   default -> the coordinator's shared backend;
    ///   native  -> a fresh native backend (isolated dispatch stats);
    ///   simd    -> a fresh simd+native backend (isolated stats; registers
    ///              the SIMD executor even on scalar-only hosts, where it
    ///              runs the bit-faithful scalar lane type);
    ///   auto    -> shared backend (it already made the auto decision);
    ///   pjrt    -> a stats-isolated fork of the shared backend that
    ///              *hard-requires* artifacts — missing engine errors here,
    ///              and off-manifest shapes are caught after the solve
    ///              (zero PJRT dispatches on the fork = the request silently
    ///              ran native, which this mode exists to forbid).
    fn backend_for(&self, req: &JobRequest) -> Result<Backend> {
        match req.executor.as_str() {
            "" | "default" | "auto" => Ok(self.backend.clone()),
            // inherits the shared backend's thread/shard tuning, drops pjrt
            "native" => Ok(self.backend.fork_native()),
            "simd" => Ok(self.backend.fork_simd()),
            "pjrt" => {
                // constrained solves activate the R-metric projection, which
                // the artifacts don't implement — the iteration loop would
                // silently run native, defeating the hard-require contract
                if !req.constraint.is_unconstrained() {
                    bail!(
                        "executor \"pjrt\" supports unconstrained jobs only: \
                         constrained solves use the native-only R-metric projection"
                    );
                }
                if self.backend.has_pjrt() {
                    // fresh counters: concurrent jobs on the shared backend
                    // must not mask this request's dispatch mix
                    Ok(self.backend.fork_stats())
                } else {
                    bail!(
                        "executor \"pjrt\" requested but no PJRT engine is loaded: {}",
                        self.backend
                            .pjrt_fallback_reason()
                            .unwrap_or_else(|| "backend was constructed native-only".into())
                    );
                }
            }
            // unreachable after validate(); kept as a guard so a choice
            // added to EXECUTOR_CHOICES without a dispatch arm fails loudly
            other => bail!("executor {other:?} validated but has no dispatch arm ({EXECUTOR_CHOICES:?})"),
        }
    }

    /// Dataset identity for the prepared-dataset cache AND the precond
    /// artifact cache key (same string: everything the data depends on).
    /// Non-dense formats extend the key — a sparse syn2 is a different
    /// dataset than the dense syn2 at the same (n, seed); dense keys stay
    /// byte-identical to the pre-sparse scheme so existing on-disk caches
    /// remain valid. File loads (`csv:`/`libsvm:` paths) ignore
    /// format/density in `prepare`, so they must NOT extend the key either
    /// — otherwise identical file data would be re-parsed and re-cached per
    /// format/density variant.
    /// The density a generated-sparse request actually runs at (0 means
    /// "generator default") — keys must use this resolved value, or
    /// `density: 0` and an explicit `density: 0.1` would cache the
    /// identical dataset twice.
    fn effective_density(req: &JobRequest) -> f64 {
        if req.density > 0.0 {
            req.density
        } else {
            sparse_gen::DEFAULT_DENSITY
        }
    }

    /// Whether the request resolves to a disk-backed dataset: an explicit
    /// `mmapdense:<file>` / `libsvm-chunked:<dir>` load, or a named
    /// generator spilled through an on-disk format.
    fn on_disk_request(req: &JobRequest) -> bool {
        req.dataset.starts_with("mmapdense:")
            || req.dataset.starts_with("libsvm-chunked:")
            || matches!(req.format.as_str(), "mmapdense" | "libsvm-chunked")
    }

    fn dataset_key(req: &JobRequest) -> String {
        let mut key = format!(
            "{}_n{}_norm{}_seed{}",
            req.dataset, req.n, req.normalize, req.seed
        );
        let file_load = req.dataset.starts_with("csv:")
            || req.dataset.starts_with("libsvm:")
            || req.dataset.starts_with("mmapdense:")
            || req.dataset.starts_with("libsvm-chunked:");
        if !file_load && !matches!(req.format.as_str(), "" | "dense") {
            key.push_str(&format!(
                "_fmt{}_den{}",
                req.format,
                Self::effective_density(req)
            ));
        }
        if Self::on_disk_request(req) {
            // shard granularity changes the prepared design's cache
            // geometry (resident bytes, fault counts — never its numerics);
            // different chunkings must not share one prepared entry
            key.push_str(&format!("_ck{}", req.chunk_rows));
        }
        key
    }

    /// Resolve (generate or load) the dataset + ground truth for a request.
    ///
    /// Representation dispatch:
    ///   * `csv:<path>`    — dense CSV load (format-independent);
    ///   * `libsvm:<path>` — sparse libsvm file load (format-independent);
    ///   * named + format "sparse" — the seeded CSR generator;
    ///   * named + format "libsvm" — the CSR generator round-tripped
    ///     through libsvm text, so the tier-1 `HDPW_FORMAT=libsvm` variant
    ///     exercises the parser on every coordinator-path test;
    ///   * named + format "dense" — the existing dense path (with the
    ///     binary disk cache, which only holds dense payloads — sparse
    ///     formats deliberately skip it).
    fn prepare(&self, req: &JobRequest) -> Result<Arc<Prepared>> {
        let key = Self::dataset_key(req);
        loop {
            if let Some(p) = self.prepared.lock().unwrap().get(&key) {
                return Ok(Arc::clone(p));
            }
            // single-flight: a burst of first-time jobs on one dataset must
            // not build it once per worker — one claims, the rest wait and
            // re-check. A failed build releases the claim WITHOUT
            // publishing, so each waiter retries and surfaces its own error.
            {
                let mut claims = self.preparing.lock().unwrap();
                if claims.contains(&key) {
                    let _waited = self.prepare_cv.wait(claims).unwrap();
                    continue;
                }
                claims.insert(key.clone());
            }
            // the builder may have published between our map miss and our
            // claim — re-check before doing the expensive work
            if let Some(p) = self.prepared.lock().unwrap().get(&key) {
                self.release_prepare_claim(&key);
                return Ok(Arc::clone(p));
            }
            let built = self.build_prepared(req, &key);
            if let Ok(p) = &built {
                self.prepared
                    .lock()
                    .unwrap()
                    .insert(key.clone(), Arc::clone(p));
            }
            self.release_prepare_claim(&key);
            return built;
        }
    }

    fn release_prepare_claim(&self, key: &str) {
        self.preparing.lock().unwrap().remove(key);
        self.prepare_cv.notify_all();
    }

    /// The expensive half of [`Self::prepare`]: generate/load the dataset,
    /// normalize, and compute ground truth. Callers hold the single-flight
    /// claim for `key`; this function itself touches only the disk cache.
    fn build_prepared(&self, req: &JobRequest, key: &str) -> Result<Arc<Prepared>> {
        let on_disk_format = matches!(req.format.as_str(), "mmapdense" | "libsvm-chunked");
        let sparse_format = !on_disk_format && !matches!(req.format.as_str(), "" | "dense");
        let mut ds = if let Some(path) = req.dataset.strip_prefix("mmapdense:") {
            let od = OnDiskDesign::open_mmap(
                std::path::Path::new(path),
                Arc::clone(&self.mem),
                req.chunk_rows,
            )?;
            Dataset::from_on_disk(req.dataset.clone(), od)
        } else if let Some(dir) = req.dataset.strip_prefix("libsvm-chunked:") {
            let od = OnDiskDesign::open_chunked(
                std::path::Path::new(dir),
                Arc::clone(&self.mem),
                req.chunk_rows,
            )?;
            Dataset::from_on_disk(req.dataset.clone(), od)
        } else if on_disk_format {
            self.spill_and_open(req, key)?
        } else if let Some(path) = req.dataset.strip_prefix("csv:") {
            io::load_csv(std::path::Path::new(path), true)?
        } else if let Some(path) = req.dataset.strip_prefix("libsvm:") {
            libsvm::load(std::path::Path::new(path))?
        } else if sparse_format {
            let mut rng = Rng::new(req.seed ^ 0xDA7A);
            let made = sparse_gen::named_sparse(
                &req.dataset,
                req.n,
                Self::effective_density(req),
                &mut rng,
            );
            let generated = match made {
                Some(ds) => ds,
                None => bail!("unknown dataset {:?}", req.dataset),
            };
            if req.format == "libsvm" {
                // round-trip through the parser: text serialization uses
                // shortest-roundtrip floats, so the payload is preserved
                // bit-for-bit while the whole parse path gets exercised
                let text = libsvm::to_text(&generated);
                let mut parsed = libsvm::parse_str(&generated.name, &text)?;
                parsed.x_star_planted = generated.x_star_planted.clone();
                parsed
            } else {
                generated
            }
        } else {
            let make = || {
                let mut rng = Rng::new(req.seed ^ 0xDA7A);
                uci_sim::by_name(&req.dataset, req.n, &mut rng)
            };
            match &self.config.cache_dir {
                Some(dir) => {
                    let made = io::load_or_generate(dir, key, || {
                        make().expect("dataset name validated")
                    });
                    match made {
                        Ok(ds) => ds,
                        Err(_) => match make() {
                            Some(ds) => ds,
                            None => bail!("unknown dataset {:?}", req.dataset),
                        },
                    }
                }
                None => match make() {
                    Some(ds) => ds,
                    None => bail!("unknown dataset {:?}", req.dataset),
                },
            }
        };
        if req.normalize {
            if ds.on_disk().is_some() {
                // center/scale would rewrite every stored entry of a design
                // the process deliberately does not hold — reject up front
                // rather than silently skipping the paper's preprocessing
                bail!(
                    "normalize is unsupported for on-disk datasets \
                     ({:?}): pre-normalize the file or drop normalize",
                    req.dataset
                );
            }
            ds.normalize();
        }
        // on-disk ground truth streams shards through charged scopes: a
        // failed read or refused charge is a structured error, not a panic
        let gt = match ds.on_disk() {
            Some(_) => try_ground_truth(&ds)?,
            None => ground_truth(&ds),
        };
        Ok(Arc::new(Prepared {
            ds: Arc::new(ds),
            gt: Arc::new(gt),
        }))
    }

    /// Generate the named dataset and spill it into [`Self::spill_dir`] in
    /// the requested on-disk format, then re-open it disk-backed against
    /// the coordinator budget. Generation itself is in-memory (the
    /// synthetic generators are) — the point of the spill path is
    /// exercising the out-of-core *solve* machinery end-to-end through the
    /// coordinator; truly budget-exceeding data arrives via the
    /// `mmapdense:<path>` / `libsvm-chunked:<dir>` load prefixes instead.
    fn spill_and_open(&self, req: &JobRequest, key: &str) -> Result<Dataset> {
        let chunk = if req.chunk_rows > 0 {
            req.chunk_rows
        } else {
            out_of_core::DEFAULT_CHUNK_ROWS
        };
        if req.format == "mmapdense" {
            let mut rng = Rng::new(req.seed ^ 0xDA7A);
            let generated = match uci_sim::by_name(&req.dataset, req.n, &mut rng) {
                Some(ds) => ds,
                None => bail!("unknown dataset {:?}", req.dataset),
            };
            let a = generated
                .design
                .dense_if_ready()
                .expect("dense generator yields a resident dense design");
            let path = self.spill_dir.join(format!("{key}.hdpw"));
            mmap::write(&path, a, &generated.b)?;
            let od = OnDiskDesign::open_mmap(&path, Arc::clone(&self.mem), req.chunk_rows)?;
            Ok(Dataset::from_on_disk(generated.name.clone(), od))
        } else {
            let mut rng = Rng::new(req.seed ^ 0xDA7A);
            let made = sparse_gen::named_sparse(
                &req.dataset,
                req.n,
                Self::effective_density(req),
                &mut rng,
            );
            let generated = match made {
                Some(ds) => ds,
                None => bail!("unknown dataset {:?}", req.dataset),
            };
            let csr = generated.csr().expect("sparse generator yields CSR");
            let dir = self.spill_dir.join(key);
            chunked::write_chunks(&dir, csr, &generated.b, chunk)?;
            let od = OnDiskDesign::open_chunked(&dir, Arc::clone(&self.mem), req.chunk_rows)?;
            Ok(Dataset::from_on_disk(generated.name.clone(), od))
        }
    }

    /// Join the coalescing episode for `key` (one in-flight job).
    fn coalesce_join(&self, key: &PrecondKey) {
        let mut groups = self.coalesce.lock().unwrap();
        let group = groups.entry(key.clone()).or_default();
        group.members += 1;
        group.peak = group.peak.max(group.members);
    }

    /// Leave the episode for `key`; returns the episode's peak membership
    /// (this job's `coalesced_batch`). The last member out removes the
    /// entry so the next burst starts a fresh episode.
    fn coalesce_leave(&self, key: &PrecondKey) -> usize {
        let mut groups = self.coalesce.lock().unwrap();
        let Some(group) = groups.get_mut(key) else {
            return 1;
        };
        group.members -= 1;
        let peak = group.peak;
        if group.members == 0 {
            groups.remove(key);
        }
        peak
    }

    /// Run one job synchronously: `trials` runs, report the best
    /// (paper protocol: "we test every method 10 times and take the best").
    ///
    /// Cross-request fusion: identical concurrent `reuse_precond` requests
    /// (equal [`JobRequest::fuse_signature`] — id, priority and deadline
    /// are excluded) share one execution. The leader runs the job; the
    /// followers adopt the published result, which is bitwise what they
    /// would have computed (the reuse pipeline is a pure function of the
    /// request — `reuse_precond_hits_cache_on_second_job` pins exactly
    /// that), and the whole group reports its size as `batched_requests`.
    /// The default paper path samples its sketch from the session RNG
    /// mid-solve and must not share anything, so it bypasses fusion.
    pub fn run_job(&self, req: &JobRequest) -> Result<JobResult> {
        req.validate()?;
        if !req.reuse_precond {
            return self.run_job_core(req);
        }
        let timer = Timer::start();
        let sig = req.fuse_signature();
        let (slot, leader) = self.fuse_join(&sig);
        if leader {
            let mut result = self.run_job_core(req);
            let shared = {
                let mut st = slot.state.lock().unwrap();
                st.done = true;
                st.shared = st.members;
                st.result = result.as_ref().ok().cloned();
                slot.cv.notify_all();
                st.shared
            };
            {
                // close the slot so later arrivals start a fresh episode;
                // remove-if-same guards against a racing replacement
                let mut map = self.fuse.lock().unwrap();
                if map.get(&sig).is_some_and(|cur| Arc::ptr_eq(cur, &slot)) {
                    map.remove(&sig);
                }
            }
            if shared > 1 {
                if let Ok(r) = result.as_mut() {
                    r.batched_requests = shared;
                    // the fused group is a (perfectly shared) coalescing
                    // episode: report it as one so the batch observability
                    // contract holds whichever layer deduplicated the work
                    r.coalesced_batch = r.coalesced_batch.max(shared);
                }
                self.metrics.record_fused_requests(shared);
                self.metrics.record_coalesced(shared);
            }
            result
        } else {
            let wait = Duration::from_secs_f64(req.time_budget.clamp(1.0, 600.0));
            match self.fuse_wait(&slot, wait) {
                Some((mut r, shared)) => {
                    r.id = req.id;
                    r.total_secs = timer.secs();
                    r.batched_requests = shared;
                    r.coalesced_batch = r.coalesced_batch.max(shared);
                    // an adopted result is a completed job from the
                    // service's point of view
                    self.metrics.record_job(r.total_secs, req.trials, true);
                    Ok(r)
                }
                // leader failed or the wait timed out: run (and account)
                // our own solve — errors surface per-request, never fanned
                // out from the leader
                None => self.run_job_core(req),
            }
        }
    }

    /// Join (or open) the fusion slot for `sig`; returns the slot and
    /// whether this caller is the leader (= must execute).
    fn fuse_join(&self, sig: &str) -> (Arc<FuseSlot>, bool) {
        let mut map = self.fuse.lock().unwrap();
        if let Some(slot) = map.get(sig) {
            let mut st = slot.state.lock().unwrap();
            if !st.done {
                st.members += 1;
                let joined = Arc::clone(slot);
                drop(st);
                return (joined, false);
            }
            // published slot still in the map (the leader is between
            // publishing and removing): fall through to a fresh episode
        }
        let slot = Arc::new(FuseSlot {
            state: Mutex::new(FuseState {
                members: 1,
                ..FuseState::default()
            }),
            cv: Condvar::new(),
        });
        map.insert(sig.to_string(), Arc::clone(&slot));
        (slot, true)
    }

    /// Follower wait: the leader's published result and the group size, or
    /// None on leader failure / timeout (caller falls back to its own run).
    fn fuse_wait(&self, slot: &FuseSlot, wait: Duration) -> Option<(JobResult, usize)> {
        let deadline = Instant::now() + wait;
        let mut st = slot.state.lock().unwrap();
        while !st.done {
            let now = Instant::now();
            if now >= deadline {
                // withdraw so the publish count doesn't include a member
                // that went its own way
                st.members -= 1;
                return None;
            }
            let (guard, _) = slot.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        st.result.clone().map(|r| (r, st.shared))
    }

    /// The unfused job pipeline: prepare, admit, coalesce, run trials.
    fn run_job_core(&self, req: &JobRequest) -> Result<JobResult> {
        let timer = Timer::start();
        let prepared = self.prepare(req)?;
        let ds = &prepared.ds;
        let gt = &prepared.gt;
        // paper setup: radius-bearing sets derive their radius from the
        // unconstrained optimum unless the request pins one
        let radius = req.resolved_radius(gt.l1_radius, gt.l2_radius);
        // one constraint set per job, dimension-checked against the
        // prepared dataset and wrapped in a projection counter so the
        // result can report projection-oracle throughput
        let counted = ProjectionCounter::wrap(req.build_constraint(radius)?);
        counted.check_dim(ds.d())?;
        let counted_ref: ConstraintRef = counted.clone();
        // built once per job: trials only vary seed/session, and rebuilding
        // the constraint per trial would redo e.g. AffineEquality's QR
        let mut base_opts =
            req.solver_opts_with_constraint(Arc::clone(&counted_ref), Some(gt.f_star))?;
        // attach the coordinator budget before any key or estimate is
        // derived: the step-2 crossover consults it, and the artifact key
        // ("+hd" tag) must be computed against the same budget the solve
        // itself will charge
        base_opts.session.mem = Some(Arc::clone(&self.mem));
        let solver = crate::solvers::by_name(&req.solver).expect("validated");
        let backend = self.backend_for(req)?;
        let dataset_id = Self::dataset_key(req);
        let step2_mode = crate::solvers::driver::resolved_step2(&base_opts, ds).0;
        // the artifact identity this job resolves to — the coalescing-group
        // key AND the admission peek's probe. None on the default paper
        // path (no reuse => nothing shareable).
        let coalesce_key = req.reuse_precond.then(|| {
            crate::solvers::driver::precond_key(
                &backend,
                ds,
                &base_opts,
                dataset_id.clone(),
                req.seed,
            )
        });
        // admission control: a job whose materialization estimate can never
        // fit is rejected up front; one that would fit but not *now* queues
        // (bounded by its own time budget) for headroom instead of racing
        // other jobs into the budget and failing mid-solve.
        let mut mem_est =
            Self::job_mem_estimate(&req.solver, ds.n(), ds.d(), ds.sparse_arith(), step2_mode);
        if let Some(key) = coalesce_key.as_ref().filter(|_| mem_est > 0) {
            // cache-aware: a resident two-step artifact (whose HD bytes are
            // already charged for as long as it is cached) means this job
            // acquires by reference and materializes nothing new — without
            // this, repeat HD jobs would queue against their own cached
            // bytes until a timeout. Counter-neutral peek: admission probes
            // must not pollute the hit/miss dashboards. Eviction between
            // the peek and the solve just degrades to the ordinary
            // charge-at-capability path.
            if self.precond_cache.peek_has_hd(key) == Some(true) {
                mem_est = 0;
            }
        }
        if let Some(limit) = self.mem.limit_bytes() {
            if mem_est > limit {
                bail!(
                    "admission control: job needs ~{mem_est} B of dense materialization \
                     but the memory budget is {limit} B (HDPW_MEM_MB / serve --mem-mb)"
                );
            }
            if mem_est > 0 {
                // memory pressure sheds idle cached artifacts: their HD
                // charges release when the last Arc drops, and the precond
                // cache's own byte budget would otherwise pin them forever
                // from this budget's point of view. Entries a running solve
                // still holds release later — the wait below covers that.
                while !self.mem.would_fit(mem_est) && self.precond_cache.evict_coldest() {}
                let wait = Duration::from_secs_f64(req.time_budget.clamp(1.0, 60.0));
                if !self.mem.wait_for_headroom(mem_est, wait) {
                    bail!(
                        "admission control: timed out waiting for {mem_est} B of \
                         memory-budget headroom ({} B in use, limit {limit} B)",
                        self.mem.used()
                    );
                }
            }
        }
        let densify_before = self.mem.densify_events();
        // shard-cache deltas, same semantics as densify_events: what THIS
        // job's solve span added to the process counters (concurrent jobs
        // on the shared budget blur attribution the same way for both)
        let shard_faults_before = self.mem.shard_faults();
        let shard_evictions_before = self.mem.shard_evictions();
        let io_retries_before = self.mem.io_retries();
        // request coalescing: concurrent jobs resolving to the same
        // PrecondKey run as one episode — the artifact cache's keyed
        // single-flight means exactly one member computes the sketch+QR
        // setup while the whole batch shares it, and per-trial RNG streams
        // (forked from each job's OWN seed) keep every member's solve
        // bit-identical to running alone. Gated on reuse_precond: the
        // default paper path samples sketches from the session RNG and must
        // not share artifacts.
        if let Some(key) = &coalesce_key {
            self.coalesce_join(key);
        }
        let trials_result =
            self.run_trials(req, ds, &base_opts, solver.as_ref(), &backend, &dataset_id);
        let coalesced_batch = match &coalesce_key {
            Some(key) => self.coalesce_leave(key),
            None => 1,
        };
        if coalesced_batch > 1 {
            self.metrics.record_coalesced(coalesced_batch);
        }
        let (best, batched_trials) = trials_result?;
        let total_secs = timer.secs();
        let rel = ((best.f_final - gt.f_star) / gt.f_star.max(1e-300)).max(0.0);
        self.metrics.record_job(total_secs, req.trials, true);
        self.metrics.record_projections(counted.count());
        if ds.sparse_arith() {
            self.metrics.record_sparse_job(ds.nnz());
        }
        Ok(JobResult {
            id: req.id,
            solver: req.solver.clone(),
            dataset: req.dataset.clone(),
            f_star: gt.f_star,
            best_f: best.f_final,
            best_rel_err: rel,
            trials_run: req.trials,
            total_secs,
            constraint: counted.tag().to_string(),
            constraint_params: counted.params(),
            projections: counted.count(),
            nnz: ds.nnz(),
            density: ds.density(),
            sparse: ds.sparse_arith(),
            mem_est_bytes: mem_est,
            mem_peak_bytes: self.mem.peak(),
            densify_events: self.mem.densify_events() - densify_before,
            shard_faults: self.mem.shard_faults() - shard_faults_before,
            shard_evictions: self.mem.shard_evictions() - shard_evictions_before,
            io_retries: self.mem.io_retries() - io_retries_before,
            coalesced_batch,
            batched_trials,
            batched_requests: 1,
            warm_start: best.warm_start.clone(),
            best,
        })
    }

    /// The best-of-k trial loop, factored out of [`Self::run_job`] so the
    /// coalescing bookkeeping wraps exactly the span during which a job can
    /// hold (or wait on) the shared preconditioner artifact. Returns the
    /// best report and `batched_trials` (the fused lockstep batch size; 1
    /// when the trials ran serially).
    fn run_trials(
        &self,
        req: &JobRequest,
        ds: &Arc<Dataset>,
        base_opts: &SolverOpts,
        solver: &dyn Solver,
        backend: &Backend,
        dataset_id: &str,
    ) -> Result<(SolveReport, usize)> {
        // Cross-trial fusion: under reuse_precond the trials share one
        // artifact and differ only in their forked RNG streams, so they can
        // advance in lockstep and share each chunk boundary's objective
        // pass (one fused residual sweep prices every trial's iterate).
        // Excluded: warm-start jobs (trial k starts from trial k-1's best —
        // a sequential dependency) and solvers with no step rule (exact).
        // The fused reports are bitwise-identical to the serial loop's
        // (`drive_fused_trials` documents the contract;
        // tests/implicit_gather.rs replays both paths).
        if req.reuse_precond && req.trials > 1 && !req.warm_start && solver.step_rule().is_some()
        {
            return self.run_trials_fused(req, ds, base_opts, solver, backend, dataset_id);
        }
        let mut seed_rng = Rng::new(req.seed);
        let mut best: Option<SolveReport> = None;
        let mut hard_require_err: Option<anyhow::Error> = None;
        for trial in 0..req.trials {
            let mut opts = base_opts.clone();
            opts.seed = seed_rng.fork(trial as u64).next_u64();
            if req.reuse_precond || req.warm_start {
                // session state the paper protocol doesn't have: the shared
                // artifact cache (keyed by the JOB seed, so trials share one
                // preconditioner) and the warm-start iterate
                let warm_x = req
                    .warm_start
                    .then(|| best.as_ref().map(|b| b.x.clone()))
                    .flatten();
                if warm_x.is_some() {
                    self.metrics.record_warm_start();
                }
                opts.session = SessionCtx {
                    reuse_precond: req.reuse_precond,
                    warm_start: req.warm_start,
                    cache: req.reuse_precond.then(|| Arc::clone(&self.precond_cache)),
                    dataset_id: Some(dataset_id.to_string()),
                    artifact_seed: req.seed,
                    x0: warm_x,
                    mem: None, // attached below for every trial
                };
            }
            opts.session.mem = Some(Arc::clone(&self.mem));
            let rep = match solver.solve(backend, ds, &opts) {
                Ok(r) => r,
                Err(e) => {
                    // keep the dispatch-mix metrics truthful even for a
                    // failed pinned-executor job before surfacing the error
                    if matches!(req.executor.as_str(), "native" | "simd" | "pjrt") {
                        self.backend.stats().absorb(backend.stats());
                    }
                    return Err(e);
                }
            };
            // pjrt hard-require: the fork's counters see only this job. Check
            // after the FIRST trial (dispatch mix is identical across trials)
            // so off-manifest jobs fail fast instead of burning all trials.
            // A solver that dispatched nothing at all (e.g. exact QR runs
            // entirely in-process) has nothing to enforce.
            if trial == 0
                && req.executor == "pjrt"
                && backend.pjrt_calls() == 0
                && backend.native_calls() + backend.simd_calls() > 0
            {
                hard_require_err = Some(anyhow!(
                    "executor \"pjrt\" requested but no op of this job hit the \
                     manifest (n={}, solver {:?}); the solve ran fully native",
                    ds.n(),
                    req.solver
                ));
                break;
            }
            let better = match &best {
                None => true,
                Some(b) => rep.f_final < b.f_final,
            };
            if better {
                best = Some(rep);
            }
        }
        // pinned-executor jobs ran on a private backend; fold their dispatch
        // counters into the shared stats so the serve loop's metrics line
        // reflects every request — including ones about to fail the
        // hard-require check (that misrouted work is exactly what the
        // metrics exist to expose)
        if matches!(req.executor.as_str(), "native" | "simd" | "pjrt") {
            self.backend.stats().absorb(backend.stats());
        }
        if let Some(err) = hard_require_err {
            return Err(err);
        }
        Ok((best.expect("at least one trial"), 1))
    }

    /// The fused cross-trial path of [`Self::run_trials`]: every trial's
    /// opts are built field-for-field as the serial loop builds them (same
    /// seed-fork order, same session), then
    /// [`crate::solvers::drive_fused_trials`] advances them in lockstep.
    fn run_trials_fused(
        &self,
        req: &JobRequest,
        ds: &Arc<Dataset>,
        base_opts: &SolverOpts,
        solver: &dyn Solver,
        backend: &Backend,
        dataset_id: &str,
    ) -> Result<(SolveReport, usize)> {
        let mut seed_rng = Rng::new(req.seed);
        let opts_list: Vec<SolverOpts> = (0..req.trials)
            .map(|trial| {
                let mut opts = base_opts.clone();
                opts.seed = seed_rng.fork(trial as u64).next_u64();
                opts.session = SessionCtx {
                    reuse_precond: true,
                    warm_start: false,
                    cache: Some(Arc::clone(&self.precond_cache)),
                    dataset_id: Some(dataset_id.to_string()),
                    artifact_seed: req.seed,
                    x0: None,
                    mem: Some(Arc::clone(&self.mem)),
                };
                opts
            })
            .collect();
        let reports = match crate::solvers::drive_fused_trials(solver, backend, ds, &opts_list)
        {
            Ok(r) => r,
            Err(e) => {
                if matches!(req.executor.as_str(), "native" | "simd" | "pjrt") {
                    self.backend.stats().absorb(backend.stats());
                }
                return Err(e);
            }
        };
        self.metrics.record_fused_trials(req.trials);
        // pjrt hard-require, same contract as the serial loop — the
        // dispatch mix is identical across trials, so the batch-level check
        // is the serial loop's trial-0 check
        let hard_require = req.executor == "pjrt"
            && backend.pjrt_calls() == 0
            && backend.native_calls() + backend.simd_calls() > 0;
        if matches!(req.executor.as_str(), "native" | "simd" | "pjrt") {
            self.backend.stats().absorb(backend.stats());
        }
        if hard_require {
            bail!(
                "executor \"pjrt\" requested but no op of this job hit the \
                 manifest (n={}, solver {:?}); the solve ran fully native",
                ds.n(),
                req.solver
            );
        }
        // best-of-k: first strictly better wins — the serial loop's order
        let mut best: Option<SolveReport> = None;
        for rep in reports {
            let better = match &best {
                None => true,
                Some(b) => rep.f_final < b.f_final,
            };
            if better {
                best = Some(rep);
            }
        }
        Ok((best.expect("at least one trial"), req.trials))
    }

    /// Submit a job to the worker pool; the callback fires on completion
    /// (or on a deadline shed — see below). Blocks when the request's lane
    /// is full (per-lane backpressure).
    ///
    /// QoS: `req.priority` routes to the matching lane of the stealing
    /// pool; when `req.deadline_ms > 0`, the job is shed — callback gets a
    /// structured [`shed_error`], never a timeout — at two points:
    ///   * submit time, if backlog-ahead × recent p50 / workers already
    ///     exceeds the deadline (cheap decline before burning queue space);
    ///   * start time, if the deadline expired while the job sat queued.
    /// Sheds count in `jobs_shed` + the lane's counter, NOT `jobs_failed`.
    pub fn submit(
        self: &Arc<Self>,
        req: JobRequest,
        on_done: impl FnOnce(Result<JobResult>) + Send + 'static,
    ) {
        let lane = req.lane();
        self.metrics
            .jobs_submitted
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics.record_lane_submit(lane);
        if req.deadline_ms > 0.0 {
            if let Some(p50_secs) = self.metrics.latency_percentile(50.0) {
                let ahead = self.pool.queued_at_or_above(lane);
                let workers = self.config.workers.max(1);
                let est_ms = (ahead as f64 / workers as f64) * p50_secs * 1e3;
                if est_ms > req.deadline_ms {
                    self.metrics.record_shed(lane);
                    // the drain estimate doubles as the retry hint: by the
                    // time it elapses the backlog ahead has been served
                    on_done(Err(shed_error(req.id, lane, req.deadline_ms, est_ms, est_ms)));
                    return;
                }
            }
        }
        let me = Arc::clone(self);
        let submitted = Instant::now();
        self.pool.submit_lane(lane, move || {
            let waited_ms = submitted.elapsed().as_secs_f64() * 1e3;
            if req.deadline_ms > 0.0 && waited_ms > req.deadline_ms {
                me.metrics.record_shed(lane);
                let retry_ms = me.retry_hint_ms(lane);
                on_done(Err(shed_error(
                    req.id,
                    lane,
                    req.deadline_ms,
                    waited_ms,
                    retry_ms,
                )));
                return;
            }
            let result = me.run_job(&req);
            if result.is_err() {
                me.metrics.record_job(0.0, 0, false);
            }
            // end-to-end lane latency (queue wait + solve) — the signal the
            // deadline estimator feeds on must include queueing delay
            me.metrics
                .record_lane_done(lane, submitted.elapsed().as_secs_f64());
            on_done(result);
        });
    }

    /// Wait for all submitted jobs to finish.
    pub fn drain(&self) {
        self.pool.wait_idle();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn coord() -> Arc<Coordinator> {
        Arc::new(Coordinator::new(
            Backend::native(),
            CoordinatorConfig {
                workers: 2,
                max_queue: 8,
                ..CoordinatorConfig::default()
            },
        ))
    }

    fn small_req(solver: &str) -> JobRequest {
        let mut req = JobRequest::default();
        req.dataset = "syn2".into();
        req.n = 1024;
        req.solver = solver.into();
        req.max_iters = 400;
        req.batch_size = 16;
        req.time_budget = 20.0;
        req
    }

    #[test]
    fn runs_single_job_and_reports_rel_err() {
        let c = coord();
        let res = c.run_job(&small_req("pwgradient")).unwrap();
        assert!(res.best_rel_err < 1e-6, "rel {}", res.best_rel_err);
        assert!(res.f_star > 0.0);
        assert_eq!(res.trials_run, 1);
    }

    #[test]
    fn best_of_k_is_no_worse_than_single() {
        let c = coord();
        let mut req = small_req("hdpwbatchsgd");
        req.max_iters = 300;
        let single = c.run_job(&req).unwrap();
        req.trials = 5;
        let multi = c.run_job(&req).unwrap();
        assert!(multi.best_f <= single.best_f + 1e-9);
        assert_eq!(multi.trials_run, 5);
    }

    #[test]
    fn constrained_radius_defaults_to_optimum_norm() {
        let c = coord();
        let mut req = small_req("pwgradient");
        req.constraint = "l2".into();
        let res = c.run_job(&req).unwrap();
        // x* is feasible at that radius, so the constrained optimum equals
        // the unconstrained one
        assert!(res.best_rel_err < 1e-6, "rel {}", res.best_rel_err);
    }

    #[test]
    fn dataset_cache_reused_across_jobs() {
        let c = coord();
        let r1 = c.run_job(&small_req("exact")).unwrap();
        let r2 = c.run_job(&small_req("exact")).unwrap();
        // identical dataset -> identical optimum
        assert_eq!(r1.f_star, r2.f_star);
        assert_eq!(c.prepared.lock().unwrap().len(), 1);
    }

    #[test]
    fn async_submit_and_drain() {
        let c = coord();
        let done = Arc::new(AtomicUsize::new(0));
        for i in 0..6 {
            let mut req = small_req("exact");
            req.id = i;
            let d = Arc::clone(&done);
            c.submit(req, move |res| {
                assert!(res.is_ok());
                d.fetch_add(1, Ordering::Relaxed);
            });
        }
        c.drain();
        assert_eq!(done.load(Ordering::Relaxed), 6);
        assert_eq!(
            c.metrics.jobs_completed.load(Ordering::Relaxed),
            6
        );
    }

    #[test]
    fn per_request_executor_selection() {
        let c = coord();
        // explicit native executor works and solves
        let mut req = small_req("pwgradient");
        req.executor = "native".into();
        req.block_rows = 128;
        let res = c.run_job(&req).unwrap();
        assert!(res.best_rel_err < 1e-6);
        // pjrt required but the coordinator is native-only -> clean error
        let mut req2 = small_req("exact");
        req2.executor = "pjrt".into();
        let err = c.run_job(&req2).unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
        // simd executor always dispatches (scalar lanes on plain hosts) and
        // folds its fork's counters into the shared metrics
        let mut req3 = small_req("pwgradient");
        req3.executor = "simd".into();
        let res = c.run_job(&req3).unwrap();
        assert!(res.best_rel_err < 1e-6);
        assert!(
            c.backend().simd_calls() > 0,
            "simd fork's dispatches were not absorbed into shared stats"
        );
    }

    #[test]
    fn reuse_precond_hits_cache_on_second_job() {
        let c = coord();
        let mut req = small_req("pwgradient");
        req.reuse_precond = true;
        req.seed = 11;
        let r1 = c.run_job(&req).unwrap();
        assert_eq!(
            r1.best.precond_cache,
            crate::precond::CacheOutcome::Miss,
            "cold cache: first job computes"
        );
        let misses_after_first = c.precond_cache().misses();
        let r2 = c.run_job(&req).unwrap();
        assert_eq!(r2.best.precond_cache, crate::precond::CacheOutcome::Hit);
        assert_eq!(
            c.precond_cache().misses(),
            misses_after_first,
            "second job must not miss"
        );
        // cache-keyed artifacts are pure functions of the key: both jobs
        // solve identically
        assert_eq!(r1.best.x, r2.best.x);
        assert_eq!(r1.best_f, r2.best_f);
    }

    #[test]
    fn trials_share_one_artifact_under_reuse() {
        let c = coord();
        let mut req = small_req("hdpwbatchsgd");
        req.reuse_precond = true;
        req.trials = 3;
        req.max_iters = 200;
        let _ = c.run_job(&req).unwrap();
        // trial 0 misses (1 get + 1 insert), trials 1-2 hit
        assert_eq!(c.precond_cache().misses(), 1);
        assert_eq!(c.precond_cache().hits(), 2);
        assert_eq!(c.precond_cache().entries(), 1);
    }

    #[test]
    fn warm_start_counts_and_stays_correct() {
        let c = coord();
        let mut req = small_req("pwgradient");
        req.warm_start = true;
        req.trials = 3;
        let res = c.run_job(&req).unwrap();
        assert!(res.best_rel_err < 1e-6, "rel {}", res.best_rel_err);
        assert_eq!(
            c.metrics
                .warm_starts
                .load(std::sync::atomic::Ordering::Relaxed),
            2,
            "trials 1 and 2 start warm"
        );
    }

    #[test]
    fn default_path_never_touches_the_cache() {
        let c = coord();
        // explicit, not relying on JobRequest::default(): the CI variant
        // flips the default with HDPW_REUSE_PRECOND=1
        let mut r1 = small_req("pwgradient");
        r1.reuse_precond = false;
        let mut r2 = small_req("hdpwbatchsgd");
        r2.reuse_precond = false;
        let _ = c.run_job(&r1).unwrap();
        let _ = c.run_job(&r2).unwrap();
        assert_eq!(c.precond_cache().hits(), 0);
        assert_eq!(c.precond_cache().misses(), 0);
        assert_eq!(c.precond_cache().entries(), 0);
    }

    #[test]
    fn admission_rejects_impossible_jobs_and_reports_mem_fields() {
        // a coordinator with a 1 MiB budget: an HD solver on n=16384 x 20
        // needs a ~2.6 MiB padded buffer — rejected up front, cleanly
        let c = Arc::new(Coordinator::new(
            Backend::native(),
            CoordinatorConfig {
                workers: 1,
                max_queue: 4,
                mem_budget: crate::util::mem::MemBudget::with_limit_mb(1),
                ..CoordinatorConfig::default()
            },
        ));
        // pinned dense: only the dense HD path materializes the charged
        // buffer this test exercises (the sparse CI variant flips the
        // default format, where the estimate is rightly 0)
        let mut req = small_req("hdpwbatchsgd");
        req.format = "dense".into();
        req.n = 16_384;
        let err = c.run_job(&req).unwrap_err();
        assert!(
            format!("{err:#}").contains("admission control"),
            "{err:#}"
        );
        // a step-1-only solver estimates 0 and runs inside the same budget
        let mut ok = small_req("pwgradient");
        ok.format = "dense".into();
        ok.n = 1024;
        let res = c.run_job(&ok).unwrap();
        assert_eq!(res.mem_est_bytes, 0);
        assert_eq!(res.densify_events, 0);
        // the estimate matches the HD buffer formula
        use crate::precond::Step2Mode;
        assert_eq!(
            Coordinator::job_mem_estimate("hdpw", 1000, 20, false, Step2Mode::Repr),
            1024 * 21 * 8
        );
        assert_eq!(
            Coordinator::job_mem_estimate("sgd", 1000, 20, false, Step2Mode::Repr),
            0
        );
        assert_eq!(
            Coordinator::job_mem_estimate("exact", 1000, 20, false, Step2Mode::Repr),
            0
        );
        // CSR datasets hold HD implicitly: no buffer, no estimate
        assert_eq!(
            Coordinator::job_mem_estimate("hdpw", 1000, 20, true, Step2Mode::Repr),
            0
        );
        assert_eq!(
            Coordinator::job_mem_estimate("hdpw", 1000, 20, true, Step2Mode::Implicit),
            0
        );
        // ...unless step 2 resolved to a dense materialization, which
        // charges exactly the dense job's buffer
        assert_eq!(
            Coordinator::job_mem_estimate("hdpw", 1000, 20, true, Step2Mode::Dense),
            1024 * 21 * 8
        );
    }

    #[test]
    fn admission_is_cache_aware_for_repeat_hd_jobs() {
        // budget fits ONE hd artifact (n=4096, d=20: 4096*21*8 = 688128 B
        // of 1 MiB); the cached artifact keeps those bytes charged, so a
        // naive estimate would queue the repeat job against its own cache
        // until the admission timeout — the counter-neutral peek must see
        // the resident artifact and admit immediately with estimate 0.
        let c = Arc::new(Coordinator::new(
            Backend::native(),
            CoordinatorConfig {
                workers: 1,
                max_queue: 4,
                mem_budget: crate::util::mem::MemBudget::with_limit_mb(1),
                ..CoordinatorConfig::default()
            },
        ));
        let mut req = small_req("hdpwbatchsgd");
        req.format = "dense".into();
        req.n = 4096;
        req.max_iters = 100;
        req.reuse_precond = true;
        req.time_budget = 5.0;
        let r1 = c.run_job(&req).unwrap();
        assert_eq!(r1.best.precond_cache, crate::precond::CacheOutcome::Miss);
        assert!(r1.mem_est_bytes > 0);
        assert!(c.mem_budget().used() > 0, "cached artifact keeps its charge");
        let hits_before = c.precond_cache().hits();
        let started = std::time::Instant::now();
        let r2 = c.run_job(&req).unwrap();
        assert_eq!(r2.best.precond_cache, crate::precond::CacheOutcome::Hit);
        assert_eq!(
            r2.mem_est_bytes, 0,
            "cache-aware admission: a resident artifact materializes nothing"
        );
        assert!(
            started.elapsed() < std::time::Duration::from_secs(4),
            "repeat job must not queue against its own cached bytes"
        );
        // the admission peek itself counted no cache traffic
        assert_eq!(c.precond_cache().hits(), hits_before + 1, "one hit: the solve's");
    }

    #[test]
    fn admission_charges_nothing_for_hd_jobs_on_csr() {
        // the pre-fix estimate charged n_pad*(d+1)*8 for ANY HD job: a
        // sparse n=16384 job (whose implicit step 2 materializes nothing)
        // would be rejected by a 1 MiB budget it trivially fits. The
        // representation-aware estimate admits it with estimate 0, and the
        // solve really does densify nothing.
        let c = Arc::new(Coordinator::new(
            Backend::native(),
            CoordinatorConfig {
                workers: 1,
                max_queue: 4,
                mem_budget: crate::util::mem::MemBudget::with_limit_mb(1),
                ..CoordinatorConfig::default()
            },
        ));
        let mut req = small_req("hdpwbatchsgd");
        req.format = "sparse".into();
        req.n = 16_384;
        req.max_iters = 100;
        let res = c.run_job(&req).unwrap();
        assert!(res.sparse);
        assert_eq!(res.mem_est_bytes, 0, "implicit HD estimates nothing");
        assert_eq!(res.densify_events, 0, "and the solve densifies nothing");
        assert_eq!(c.mem_budget().used(), 0);
    }

    #[test]
    fn admission_sheds_idle_cached_artifacts_under_pressure() {
        // different-key HD jobs: job A's cached artifact pins ~688 KB of a
        // 1 MiB budget; job B (different seed => different key) cannot fit
        // beside it. Admission must evict the idle artifact — whose charge
        // releases with its last Arc — instead of queueing B against bytes
        // nothing would ever free.
        let c = Arc::new(Coordinator::new(
            Backend::native(),
            CoordinatorConfig {
                workers: 1,
                max_queue: 4,
                mem_budget: crate::util::mem::MemBudget::with_limit_mb(1),
                ..CoordinatorConfig::default()
            },
        ));
        let mut req_a = small_req("hdpwbatchsgd");
        req_a.format = "dense".into();
        req_a.n = 4096;
        req_a.max_iters = 100;
        req_a.reuse_precond = true;
        req_a.time_budget = 5.0;
        c.run_job(&req_a).unwrap();
        assert!(c.mem_budget().used() > 0, "A's artifact pins its HD bytes");
        let mut req_b = req_a.clone();
        req_b.seed = 2; // different artifact key
        let started = std::time::Instant::now();
        let rb = c.run_job(&req_b).unwrap();
        assert_eq!(rb.best.precond_cache, crate::precond::CacheOutcome::Miss);
        assert!(
            started.elapsed() < std::time::Duration::from_secs(4),
            "B must be admitted by shedding, not by timing out"
        );
        assert!(c.precond_cache().evictions() >= 1, "A's artifact was shed");
    }

    #[test]
    fn unknown_dataset_fails_cleanly() {
        let c = coord();
        let mut req = small_req("exact");
        req.dataset = "mystery".into();
        assert!(c.run_job(&req).is_err());
        // sparse formats share the unknown-name contract
        let mut req2 = small_req("exact");
        req2.dataset = "mystery".into();
        req2.format = "sparse".into();
        assert!(c.run_job(&req2).is_err());
    }

    #[test]
    fn sparse_format_reports_density_and_solves() {
        let c = coord();
        let mut req = small_req("pwgradient");
        req.format = "sparse".into();
        req.density = 0.2;
        let res = c.run_job(&req).unwrap();
        assert!(res.best_rel_err < 1e-6, "rel {}", res.best_rel_err);
        assert!(res.sparse, "representation flag must report CSR");
        assert!(res.density < 0.99, "density {} should be sparse", res.density);
        assert!(res.nnz < 1024 * 20);
        assert_eq!(
            c.metrics
                .sparse_jobs
                .load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        // the dense twin of the same request reports density 1.0 and does
        // NOT alias the sparse prepared dataset
        let mut dense = small_req("pwgradient");
        dense.format = "dense".into();
        let dres = c.run_job(&dense).unwrap();
        assert_eq!(dres.density, 1.0);
        assert!(!dres.sparse);
        assert_eq!(c.prepared.lock().unwrap().len(), 2);
    }

    #[test]
    fn libsvm_format_roundtrips_through_the_parser() {
        let c = coord();
        let mut req = small_req("pwgradient");
        req.format = "libsvm".into();
        let r1 = c.run_job(&req).unwrap();
        assert!(r1.best_rel_err < 1e-6, "rel {}", r1.best_rel_err);
        assert!(r1.density < 0.99);
        // deterministic: the round trip preserves the payload bit-for-bit
        let r2 = c.run_job(&req).unwrap();
        assert_eq!(r1.best.x, r2.best.x);
        // and the sparse/libsvm variants of the same seed agree exactly
        // (the parser reproduces the generator's payload)
        let mut sp = small_req("pwgradient");
        sp.format = "sparse".into();
        let r3 = c.run_job(&sp).unwrap();
        assert_eq!(r1.best.x, r3.best.x);
        assert_eq!(r1.nnz, r3.nnz);
        // density 0 ("use the default") and the explicit default value key
        // the SAME prepared dataset — no duplicate cache entries
        let before = c.prepared.lock().unwrap().len();
        let mut explicit = small_req("pwgradient");
        explicit.format = "sparse".into();
        explicit.density = crate::data::sparse_gen::DEFAULT_DENSITY;
        let r4 = c.run_job(&explicit).unwrap();
        assert_eq!(c.prepared.lock().unwrap().len(), before);
        assert_eq!(r3.best.x, r4.best.x);
    }

    #[test]
    fn libsvm_file_errors_surface_as_job_errors() {
        let c = coord();
        let mut req = small_req("exact");
        req.dataset = "libsvm:/nonexistent/missing.svm".into();
        let err = c.run_job(&req).unwrap_err();
        assert!(format!("{err:#}").contains("libsvm"), "{err:#}");
        // malformed file content: parse error carries the line number
        let dir = std::env::temp_dir().join(format!("hdpw_libsvm_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.svm");
        std::fs::write(&path, "1 1:2\n2 1:oops\n").unwrap();
        let mut req2 = small_req("exact");
        req2.dataset = format!("libsvm:{}", path.display());
        let err2 = c.run_job(&req2).unwrap_err();
        assert!(format!("{err2:#}").contains("line 2"), "{err2:#}");
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn on_disk_formats_prepare_solve_and_report_counters() {
        let c = coord();
        // chunked-CSR spill: sparse-arith routing, shard counters live
        let mut req = small_req("pwgradient");
        req.format = "libsvm-chunked".into();
        req.chunk_rows = 256;
        let r1 = c.run_job(&req).unwrap();
        assert!(r1.best_rel_err < 1e-6, "rel {}", r1.best_rel_err);
        assert!(r1.sparse, "chunked flavor runs CSR arithmetic");
        assert!(r1.shard_faults > 0, "the solve must stream shards");
        assert_eq!(r1.io_retries, 0, "healthy files retry nothing");
        // bitwise parity with the resident sparse twin of the same seed:
        // the spill round-trips through shortest-roundtrip text and the
        // shard-streamed kernels replay the resident arithmetic exactly
        let mut twin = small_req("pwgradient");
        twin.format = "sparse".into();
        let rt = c.run_job(&twin).unwrap();
        assert_eq!(r1.best.x, rt.best.x, "on-disk CSR diverged from resident");
        assert_eq!(r1.f_star.to_bits(), rt.f_star.to_bits());
        // mmapdense spill: dense-like routing, dense-twin parity
        let mut dreq = small_req("pwgradient");
        dreq.format = "mmapdense".into();
        let r2 = c.run_job(&dreq).unwrap();
        assert!(!r2.sparse, "mmapdense flavor runs dense arithmetic");
        assert!(r2.shard_faults > 0);
        let mut dtwin = small_req("pwgradient");
        dtwin.format = "dense".into();
        let rd = c.run_job(&dtwin).unwrap();
        assert_eq!(r2.best.x, rd.best.x, "on-disk dense diverged from resident");
        assert_eq!(r2.f_star.to_bits(), rd.f_star.to_bits());
        // chunk_rows is part of the dataset identity: a different shard
        // geometry prepares its own entry instead of aliasing the first
        let entries_before = c.prepared.lock().unwrap().len();
        let mut rechunk = req.clone();
        rechunk.chunk_rows = 64;
        let r3 = c.run_job(&rechunk).unwrap();
        assert_eq!(r1.best.x, r3.best.x, "chunk size must never change numerics");
        assert_eq!(c.prepared.lock().unwrap().len(), entries_before + 1);
        // normalize cannot rewrite a design the process never holds
        let mut bad = small_req("exact");
        bad.format = "mmapdense".into();
        bad.normalize = true;
        let err = c.run_job(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("normalize"), "{err:#}");
    }

    #[test]
    fn on_disk_path_loads_open_and_missing_files_error_cleanly() {
        let c = coord();
        // a real mmapdense file written out of band, loaded by path
        let dir = std::env::temp_dir().join(format!("hdpw_sched_od_{}", std::process::id()));
        let mut rng = crate::util::rng::Rng::new(9);
        let a = crate::linalg::Mat::gaussian(256, 6, &mut rng);
        let b: Vec<f64> = (0..256).map(|i| i as f64 * 0.25).collect();
        let path = dir.join("by_path.hdpw");
        crate::data::mmap::write(&path, &a, &b).unwrap();
        let mut req = small_req("exact");
        req.dataset = format!("mmapdense:{}", path.display());
        let res = c.run_job(&req).unwrap();
        assert!(res.best_rel_err < 1e-9, "rel {}", res.best_rel_err);
        assert!(!res.sparse);
        // missing file: a structured job error, never a panic
        let mut missing = small_req("exact");
        missing.dataset = "mmapdense:/nonexistent/nope.hdpw".into();
        let err = c.run_job(&missing).unwrap_err();
        assert!(format!("{err:#}").contains("mmapdense"), "{err:#}");
        let mut missing2 = small_req("exact");
        missing2.dataset = "libsvm-chunked:/nonexistent/dir".into();
        assert!(c.run_job(&missing2).is_err());
        std::fs::remove_dir_all(dir).unwrap();
    }

    #[test]
    fn solo_jobs_report_coalesced_batch_of_one() {
        let c = coord();
        // default path: no key, batch is definitionally 1
        let r1 = c.run_job(&small_req("pwgradient")).unwrap();
        assert_eq!(r1.coalesced_batch, 1);
        // reuse path with nothing concurrent: episode of one
        let mut req = small_req("pwgradient");
        req.reuse_precond = true;
        let r2 = c.run_job(&req).unwrap();
        assert_eq!(r2.coalesced_batch, 1);
        // episodes are scoped: the map must not leak entries
        assert!(c.coalesce.lock().unwrap().is_empty());
        assert_eq!(
            c.metrics.coalesced_jobs.load(Ordering::Relaxed),
            0,
            "solo episodes are not coalescing events"
        );
    }

    #[test]
    fn concurrent_same_key_jobs_share_one_coalescing_episode() {
        // 4 threads enter run_job on the SAME reuse key behind a barrier;
        // the artifact cache's single-flight holds late arrivals inside the
        // episode while the first member computes, so a shared peak > 1 is
        // observed. Retry a few rounds to be robust to pathological
        // scheduling (a thread sleeping through the whole episode).
        let c = coord();
        let mut req = small_req("hdpwbatchsgd");
        req.reuse_precond = true;
        req.max_iters = 200;
        for round in 0..5 {
            let mut seeded = req.clone();
            seeded.seed = 100 + round; // fresh key => fresh episode + artifact
            // uncoalesced reference: the same request alone on a fresh
            // coordinator — coalesced members must match it bit-for-bit
            let serial = coord().run_job(&seeded).unwrap();
            assert_eq!(serial.coalesced_batch, 1);
            let barrier = Arc::new(std::sync::Barrier::new(4));
            let results: Vec<JobResult> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..4)
                    .map(|_| {
                        let c = Arc::clone(&c);
                        let r = seeded.clone();
                        let b = Arc::clone(&barrier);
                        s.spawn(move || {
                            b.wait();
                            c.run_job(&r).unwrap()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            // members of one episode share the artifact yet stay bit-
            // identical to uncoalesced execution, whatever peak was observed
            for r in &results {
                assert_eq!(r.best.x, serial.best.x, "coalescing changed the solve");
                assert_eq!(r.best_f.to_bits(), serial.best_f.to_bits());
            }
            if results.iter().any(|r| r.coalesced_batch > 1) {
                assert!(c.coalesce.lock().unwrap().is_empty(), "episode must close");
                assert!(c.metrics.coalesced_jobs.load(Ordering::Relaxed) > 0);
                return;
            }
        }
        panic!("4 barrier-synchronized same-key jobs never overlapped in 5 rounds");
    }

    #[test]
    fn fused_trials_report_batch_and_match_serial_replay() {
        let c = coord();
        let mut req = small_req("hdpwbatchsgd");
        req.reuse_precond = true;
        req.trials = 3;
        req.max_iters = 200;
        let fused = c.run_job(&req).unwrap();
        assert_eq!(fused.batched_trials, 3, "reuse trials must run fused");
        assert_eq!(fused.batched_requests, 1);
        assert_eq!(
            c.metrics
                .fused_trials
                .load(std::sync::atomic::Ordering::Relaxed),
            3
        );
        // serial replay of the same trials: rebuild each trial's opts
        // exactly as the serial loop would and drive them one at a time —
        // the fused best must be bitwise equal
        let prepared = c.prepare(&req).unwrap();
        let ds = &prepared.ds;
        let radius = req.resolved_radius(prepared.gt.l1_radius, prepared.gt.l2_radius);
        let counted = ProjectionCounter::wrap(req.build_constraint(radius).unwrap());
        let cref: ConstraintRef = counted.clone();
        let base_opts = req
            .solver_opts_with_constraint(cref, Some(prepared.gt.f_star))
            .unwrap();
        let solver = crate::solvers::by_name(&req.solver).unwrap();
        let mut seed_rng = Rng::new(req.seed);
        let mut best: Option<SolveReport> = None;
        for trial in 0..req.trials {
            let mut opts = base_opts.clone();
            opts.seed = seed_rng.fork(trial as u64).next_u64();
            opts.session = SessionCtx {
                reuse_precond: true,
                warm_start: false,
                cache: Some(Arc::clone(c.precond_cache())),
                dataset_id: Some(Coordinator::dataset_key(&req)),
                artifact_seed: req.seed,
                x0: None,
                mem: Some(Arc::clone(c.mem_budget())),
            };
            let rep = solver.solve(c.backend(), ds, &opts).unwrap();
            let better = match &best {
                None => true,
                Some(b) => rep.f_final < b.f_final,
            };
            if better {
                best = Some(rep);
            }
        }
        let serial = best.unwrap();
        assert_eq!(fused.best.x, serial.x, "fusion changed the solve");
        assert_eq!(fused.best_f.to_bits(), serial.f_final.to_bits());
        assert_eq!(fused.best.iters, serial.iters);
    }

    #[test]
    fn concurrent_identical_requests_fuse_into_one_execution() {
        // 4 threads submit the SAME reuse request behind a barrier: one
        // leads, the rest adopt the published result with their own id
        // echoed back. Retry rounds guard against pathological scheduling
        // (the leader publishing before any follower arrives).
        let c = coord();
        let mut req = small_req("pwgradient");
        req.reuse_precond = true;
        for round in 0..5 {
            let mut seeded = req.clone();
            seeded.seed = 300 + round;
            let serial = coord().run_job(&seeded).unwrap();
            let barrier = Arc::new(std::sync::Barrier::new(4));
            let results: Vec<JobResult> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..4u64)
                    .map(|i| {
                        let c = Arc::clone(&c);
                        let mut r = seeded.clone();
                        r.id = i; // identity is excluded from the signature
                        let b = Arc::clone(&barrier);
                        s.spawn(move || {
                            b.wait();
                            c.run_job(&r).unwrap()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            for (i, r) in results.iter().enumerate() {
                assert_eq!(r.id, i as u64, "adopted results echo the caller's id");
                assert_eq!(r.best.x, serial.best.x, "fusion changed the solve");
                assert_eq!(r.best_f.to_bits(), serial.best_f.to_bits());
            }
            if results.iter().any(|r| r.batched_requests > 1) {
                assert!(c.fuse.lock().unwrap().is_empty(), "slot must close");
                assert!(
                    c.metrics
                        .fused_requests
                        .load(std::sync::atomic::Ordering::Relaxed)
                        > 0
                );
                return;
            }
        }
        panic!("4 barrier-synchronized identical jobs never fused in 5 rounds");
    }

    #[test]
    fn lanes_route_and_record_per_lane_metrics() {
        let c = coord();
        let lane_of = |p: &str| {
            let mut r = small_req("exact");
            r.priority = p.into();
            r
        };
        let done = Arc::new(AtomicUsize::new(0));
        for p in ["high", "normal", "batch", "batch"] {
            let d = Arc::clone(&done);
            c.submit(lane_of(p), move |res| {
                assert!(res.is_ok());
                d.fetch_add(1, Ordering::Relaxed);
            });
        }
        c.drain();
        assert_eq!(done.load(Ordering::Relaxed), 4);
        let lane = |l: Lane| &c.metrics.lanes[l.idx()];
        assert_eq!(lane(Lane::High).submitted.load(Ordering::Relaxed), 1);
        assert_eq!(lane(Lane::Normal).submitted.load(Ordering::Relaxed), 1);
        assert_eq!(lane(Lane::Batch).submitted.load(Ordering::Relaxed), 2);
        assert_eq!(lane(Lane::Batch).completed.load(Ordering::Relaxed), 2);
        assert!(c.metrics.lane_latency_percentile(Lane::High, 50.0).is_some());
        assert_eq!(c.metrics.jobs_shed.load(Ordering::Relaxed), 0);
        assert_eq!(c.queue_depth(Lane::Batch), 0, "drained queue is empty");
    }

    #[test]
    fn deadline_shed_returns_structured_error_not_timeout() {
        use std::sync::mpsc;
        let c = Arc::new(Coordinator::new(
            Backend::native(),
            CoordinatorConfig {
                workers: 1,
                max_queue: 8,
                ..CoordinatorConfig::default()
            },
        ));
        // seed the p50 estimate the submit-time estimator reads
        c.run_job(&small_req("pwgradient")).unwrap();
        // pile work onto the single worker so the shed job queues behind it
        for _ in 0..4 {
            c.submit(small_req("exact"), |res| assert!(res.is_ok()));
        }
        let mut doomed = small_req("exact");
        doomed.deadline_ms = 1e-4; // expires before any queue can drain
        let (tx, rx) = mpsc::channel();
        let started = std::time::Instant::now();
        c.submit(doomed, move |res| tx.send(res).unwrap());
        let res = rx.recv().unwrap();
        c.drain();
        let err = res.unwrap_err();
        assert!(
            super::super::job::is_shed_error(&err),
            "shed must be structurally recognizable: {err:#}"
        );
        assert!(format!("{err:#}").contains("deadline"), "{err:#}");
        assert!(
            started.elapsed() < std::time::Duration::from_secs(5),
            "shedding is a fast decline, not a timeout"
        );
        assert_eq!(c.metrics.jobs_shed.load(Ordering::Relaxed), 1);
        assert_eq!(
            c.metrics.lanes[Lane::Normal.idx()].shed.load(Ordering::Relaxed),
            1
        );
        assert_eq!(
            c.metrics.jobs_failed.load(Ordering::Relaxed),
            0,
            "a shed is a QoS decline, not a failure"
        );
        // jobs with slack (no deadline pressure) still run to completion
        let mut ok = small_req("exact");
        ok.deadline_ms = 60_000.0;
        let r = c.run_job(&ok).unwrap();
        assert!(r.best_rel_err < 1e-6);
    }
}
