//! Shared infrastructure substrates, all implemented from scratch.
//!
//! Nothing in this tree depends on external crates (only `std`): the build
//! environment vendors exactly the `xla` crate closure, so the PRNG, CLI
//! parser, config format, JSON parser, thread pool, stats and plotting
//! utilities that a framework normally pulls from crates.io are implemented
//! here and unit-tested in place.

pub mod alloc;
pub mod rng;
pub mod mem;
pub mod json;
pub mod cli;
pub mod config;
pub mod stats;
pub mod plot;
pub mod csv;
pub mod threadpool;
pub mod logging;
