//! Process memory budget for capability-gated densification.
//!
//! The input-sparsity-time claim is about *memory* as much as flops: a
//! 1M x 100 CSR design at 1% density must not silently pay the 100x dense
//! footprint just because some stage wanted a dense view. [`MemBudget`] is
//! the accounting authority every such materialization goes through:
//!
//! * every dense materialization (CSR mirror, HD-transform buffer, scoped
//!   QR copy) charges its bytes *before* allocating and can **fail** with a
//!   structured [`MemError`] when the budget is exhausted — a serve worker
//!   surfaces that as a job error instead of OOM-killing the process;
//! * charges are RAII ([`MemCharge`]): dropping the owner releases the
//!   bytes and wakes admission-control waiters;
//! * the high-water mark (`peak`), densification count (`densify_events`,
//!   each logged with the requesting stage) and rejection count are exported
//!   to job results, the serve metrics line and `bench-info`.
//!
//! The process-wide budget is configured with `HDPW_MEM_MB` (0 / unset =
//! unlimited) and overridden by `hdpw serve --mem-mb` / `hdpw solve
//! --mem-mb`; tests construct private budgets so they never race the
//! process one.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};
use std::time::{Duration, Instant};

/// Byte-accounted memory budget (see module docs). `usize::MAX` = unlimited.
#[derive(Debug)]
pub struct MemBudget {
    limit: AtomicUsize,
    used: AtomicUsize,
    peak: AtomicUsize,
    densify_events: AtomicUsize,
    rejections: AtomicUsize,
    /// Shard loads performed by out-of-core block caches (a cache miss that
    /// went to disk) — the shard-cache analogue of `densify_events`.
    shard_faults: AtomicUsize,
    /// Resident shards evicted by out-of-core block caches under pressure.
    shard_evictions: AtomicUsize,
    /// Transient I/O errors retried (once) by out-of-core readers.
    io_retries: AtomicUsize,
    /// Bytes currently held resident by out-of-core shard caches (a subset
    /// of `used`; observability only — the charge itself flows through
    /// [`MemBudget::try_charge`] like any other materialization).
    shard_resident_bytes: AtomicUsize,
    /// Pairs with `cv` so admission control can wait for headroom; the
    /// mutex guards nothing by itself (counters are atomic).
    waiters: Mutex<()>,
    cv: Condvar,
    /// Self-handle (`Arc::new_cyclic`) so a plain `&self` charge can hand
    /// out an owning RAII [`MemCharge`]. Budgets only exist behind `Arc`.
    me: Weak<MemBudget>,
}

/// Structured over-budget error — the serve loop reports this as a job
/// error; it must never surface as a panic.
#[derive(Clone, Debug)]
pub struct MemError {
    /// The stage that requested the materialization (logged + reported).
    pub stage: String,
    /// Bytes the failed charge asked for.
    pub requested: usize,
    /// Bytes already charged when the request was refused.
    pub used: usize,
    /// The configured cap in bytes at refusal time.
    pub limit: usize,
}

impl std::fmt::Display for MemError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "memory budget exceeded in {}: requested {} B with {} B in use (limit {} B; \
             raise HDPW_MEM_MB / --mem-mb or use a sparse-only solver)",
            self.stage, self.requested, self.used, self.limit
        )
    }
}

impl std::error::Error for MemError {}

/// RAII charge against a [`MemBudget`]: the bytes stay accounted exactly as
/// long as the charged allocation is alive; dropping releases them and
/// wakes admission-control waiters.
pub struct MemCharge {
    budget: Arc<MemBudget>,
    bytes: usize,
}

impl MemCharge {
    /// Bytes this charge holds against the budget.
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

impl std::fmt::Debug for MemCharge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MemCharge").field("bytes", &self.bytes).finish()
    }
}

impl Drop for MemCharge {
    fn drop(&mut self) {
        self.budget.release(self.bytes);
    }
}

static PROCESS: OnceLock<Arc<MemBudget>> = OnceLock::new();

impl MemBudget {
    fn with_limit_bytes(limit: usize) -> Arc<MemBudget> {
        Arc::new_cyclic(|me| MemBudget {
            limit: AtomicUsize::new(limit),
            used: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
            densify_events: AtomicUsize::new(0),
            rejections: AtomicUsize::new(0),
            shard_faults: AtomicUsize::new(0),
            shard_evictions: AtomicUsize::new(0),
            io_retries: AtomicUsize::new(0),
            shard_resident_bytes: AtomicUsize::new(0),
            waiters: Mutex::new(()),
            cv: Condvar::new(),
            me: me.clone(),
        })
    }

    /// A budget that never rejects (but still counts peak bytes and
    /// densification events) — the default when `HDPW_MEM_MB` is unset.
    pub fn unlimited() -> Arc<MemBudget> {
        MemBudget::with_limit_bytes(usize::MAX)
    }

    /// A budget capped at `mb` MiB; `mb == 0` means unlimited.
    pub fn with_limit_mb(mb: usize) -> Arc<MemBudget> {
        let limit = if mb == 0 {
            usize::MAX
        } else {
            mb.saturating_mul(1 << 20)
        };
        MemBudget::with_limit_bytes(limit)
    }

    /// The process-wide budget, initialized once from `HDPW_MEM_MB`
    /// (0 / unset / unparsable = unlimited). `--mem-mb` CLI overrides call
    /// [`MemBudget::set_limit_mb`] on this same instance.
    pub fn process() -> Arc<MemBudget> {
        Arc::clone(PROCESS.get_or_init(|| {
            let mb = std::env::var("HDPW_MEM_MB")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(0);
            MemBudget::with_limit_mb(mb)
        }))
    }

    /// Re-limit a live budget (serve/solve `--mem-mb`); `mb == 0` lifts the
    /// cap. Existing charges are untouched.
    pub fn set_limit_mb(&self, mb: usize) {
        let limit = if mb == 0 {
            usize::MAX
        } else {
            mb.saturating_mul(1 << 20)
        };
        self.limit.store(limit, Ordering::Relaxed);
        self.notify_waiters();
    }

    /// The configured cap; `None` when unlimited.
    pub fn limit_bytes(&self) -> Option<usize> {
        match self.limit.load(Ordering::Relaxed) {
            usize::MAX => None,
            v => Some(v),
        }
    }

    /// Currently charged bytes.
    pub fn used(&self) -> usize {
        self.used.load(Ordering::Relaxed)
    }

    /// High-water mark of charged bytes (never resets).
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Densifications performed through this budget so far.
    pub fn densify_events(&self) -> usize {
        self.densify_events.load(Ordering::Relaxed)
    }

    /// Charges refused for lack of budget.
    pub fn rejections(&self) -> usize {
        self.rejections.load(Ordering::Relaxed)
    }

    /// Shard-cache misses that went to disk (see [`MemBudget::note_shard_load`]).
    pub fn shard_faults(&self) -> usize {
        self.shard_faults.load(Ordering::Relaxed)
    }

    /// Resident shards evicted under budget pressure.
    pub fn shard_evictions(&self) -> usize {
        self.shard_evictions.load(Ordering::Relaxed)
    }

    /// Transient I/O errors retried by out-of-core readers.
    pub fn io_retries(&self) -> usize {
        self.io_retries.load(Ordering::Relaxed)
    }

    /// Bytes currently resident in out-of-core shard caches.
    pub fn shard_resident_bytes(&self) -> usize {
        self.shard_resident_bytes.load(Ordering::Relaxed)
    }

    /// Record a shard load (cache miss → disk read), `bytes` now resident.
    /// The shard's budget charge is separate ([`MemBudget::try_charge`]);
    /// this only maintains the observability counters.
    pub fn note_shard_load(&self, stage: &str, bytes: usize) {
        self.shard_faults.fetch_add(1, Ordering::Relaxed);
        self.shard_resident_bytes.fetch_add(bytes, Ordering::Relaxed);
        crate::log_info!("mem budget: shard fault {bytes} B for {stage}");
    }

    /// Record a shard eviction, `bytes` no longer resident.
    pub fn note_shard_evict(&self, stage: &str, bytes: usize) {
        self.shard_evictions.fetch_add(1, Ordering::Relaxed);
        self.shard_resident_bytes.fetch_sub(bytes, Ordering::Relaxed);
        crate::log_info!("mem budget: shard evict {bytes} B for {stage}");
    }

    /// Record that a shard cache released `bytes` of residency without an
    /// eviction (cache drop / shutdown).
    pub fn note_shard_release(&self, bytes: usize) {
        self.shard_resident_bytes.fetch_sub(bytes, Ordering::Relaxed);
    }

    /// Record one transient-I/O retry (`Interrupted` / `TimedOut` / …).
    pub fn note_io_retry(&self, stage: &str) {
        self.io_retries.fetch_add(1, Ordering::Relaxed);
        crate::log_warn!("mem budget: transient I/O retried for {stage}");
    }

    /// Reserve `bytes` or fail with a structured error. The returned charge
    /// releases on drop (and keeps the budget alive through its
    /// self-handle).
    pub fn try_charge(&self, bytes: usize, stage: &str) -> Result<MemCharge, MemError> {
        let mut cur = self.used.load(Ordering::Relaxed);
        loop {
            let limit = self.limit.load(Ordering::Relaxed);
            let next = cur.saturating_add(bytes);
            if next > limit {
                self.rejections.fetch_add(1, Ordering::Relaxed);
                crate::log_warn!(
                    "mem budget: rejected {bytes} B for {stage} ({cur} B in use, limit {limit} B)"
                );
                return Err(MemError {
                    stage: stage.to_string(),
                    requested: bytes,
                    used: cur,
                    limit,
                });
            }
            match self.used.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.bump_peak(next);
                    return Ok(MemCharge {
                        budget: self.me.upgrade().expect("budgets live behind Arc"),
                        bytes,
                    });
                }
                Err(now) => cur = now,
            }
        }
    }

    /// Record a densification (counted + logged with the requesting stage).
    /// Callers invoke this exactly once per dense materialization, *after*
    /// the charge succeeded.
    pub fn note_densify(&self, stage: &str, bytes: usize) {
        self.densify_events.fetch_add(1, Ordering::Relaxed);
        crate::log_info!("mem budget: densify {bytes} B for {stage}");
    }

    /// Whether a charge of `bytes` would currently fit.
    pub fn would_fit(&self, bytes: usize) -> bool {
        self.used
            .load(Ordering::Relaxed)
            .saturating_add(bytes)
            <= self.limit.load(Ordering::Relaxed)
    }

    /// Admission control: block until `bytes` would fit or `timeout`
    /// elapses. Returns whether headroom appeared. This is a *gate*, not a
    /// reservation — the eventual `try_charge` still decides.
    pub fn wait_for_headroom(&self, bytes: usize, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut guard = self.waiters.lock().unwrap();
        loop {
            if self.would_fit(bytes) {
                return true;
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (g, _) = self.cv.wait_timeout(guard, deadline - now).unwrap();
            guard = g;
        }
    }

    fn bump_peak(&self, candidate: usize) {
        let mut cur = self.peak.load(Ordering::Relaxed);
        while candidate > cur {
            match self.peak.compare_exchange_weak(
                cur,
                candidate,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(now) => cur = now,
            }
        }
    }

    fn release(&self, bytes: usize) {
        self.used.fetch_sub(bytes, Ordering::Relaxed);
        self.notify_waiters();
    }

    /// Wake admission waiters. The (empty) critical section orders this
    /// notify after any waiter's headroom check: without it, a release
    /// landing between a waiter's `would_fit == false` and its
    /// `wait_timeout` park would be lost and the waiter would sleep out
    /// its whole timeout despite headroom having appeared.
    fn notify_waiters(&self) {
        drop(self.waiters.lock().unwrap());
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_accounts_and_releases_on_drop() {
        let b = MemBudget::with_limit_mb(1); // 1 MiB
        let c1 = b.try_charge(400_000, "t1").unwrap();
        assert_eq!(b.used(), 400_000);
        let c2 = b.try_charge(400_000, "t2").unwrap();
        assert_eq!(b.used(), 800_000);
        assert_eq!(b.peak(), 800_000);
        drop(c1);
        assert_eq!(b.used(), 400_000);
        assert_eq!(b.peak(), 800_000, "peak never shrinks");
        drop(c2);
        assert_eq!(b.used(), 0);
        assert_eq!(b.rejections(), 0);
    }

    #[test]
    fn over_budget_charge_is_a_structured_error() {
        let b = MemBudget::with_limit_mb(1);
        let _held = b.try_charge(1_000_000, "big").unwrap();
        let err = b.try_charge(100_000, "straw").unwrap_err();
        assert_eq!(err.stage, "straw");
        assert_eq!(err.requested, 100_000);
        assert_eq!(err.limit, 1 << 20);
        let msg = err.to_string();
        assert!(msg.contains("memory budget exceeded in straw"), "{msg}");
        assert_eq!(b.rejections(), 1);
        assert_eq!(b.used(), 1_000_000, "failed charge must not leak bytes");
    }

    #[test]
    fn unlimited_budget_never_rejects_but_still_tracks() {
        let b = MemBudget::unlimited();
        assert!(b.limit_bytes().is_none());
        let c = b.try_charge(usize::MAX / 2, "huge").unwrap();
        assert!(b.peak() >= usize::MAX / 2);
        drop(c);
        b.note_densify("t", 8);
        assert_eq!(b.densify_events(), 1);
    }

    #[test]
    fn concurrent_charges_never_oversubscribe() {
        let b = MemBudget::with_limit_mb(1); // 1 MiB = 1048576 B
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let b = Arc::clone(&b);
                std::thread::spawn(move || {
                    let mut granted = 0usize;
                    for _ in 0..64 {
                        if let Ok(c) = b.try_charge(100_000, "race") {
                            granted += 1;
                            assert!(b.used() <= 1 << 20, "oversubscribed");
                            drop(c);
                        }
                    }
                    granted
                })
            })
            .collect();
        let total: usize = threads.into_iter().map(|t| t.join().unwrap()).sum();
        assert!(total > 0);
        assert_eq!(b.used(), 0, "all charges released");
        assert!(b.peak() <= 1 << 20);
    }

    #[test]
    fn headroom_wait_unblocks_on_release() {
        let b = MemBudget::with_limit_mb(1);
        let held = b.try_charge(1_000_000, "holder").unwrap();
        assert!(!b.wait_for_headroom(500_000, Duration::from_millis(30)));
        let waiter = {
            let b = Arc::clone(&b);
            std::thread::spawn(move || b.wait_for_headroom(500_000, Duration::from_secs(10)))
        };
        std::thread::sleep(Duration::from_millis(20));
        drop(held); // releases + notifies
        assert!(waiter.join().unwrap(), "waiter must observe the release");
    }

    #[test]
    fn shard_counters_track_residency_and_events() {
        let b = MemBudget::with_limit_mb(1);
        b.note_shard_load("cache", 4096);
        b.note_shard_load("cache", 4096);
        assert_eq!(b.shard_faults(), 2);
        assert_eq!(b.shard_resident_bytes(), 8192);
        b.note_shard_evict("cache", 4096);
        assert_eq!(b.shard_evictions(), 1);
        assert_eq!(b.shard_resident_bytes(), 4096);
        b.note_shard_release(4096);
        assert_eq!(b.shard_resident_bytes(), 0);
        b.note_io_retry("reader");
        assert_eq!(b.io_retries(), 1);
        // counters are observability-only: the budget itself is untouched
        assert_eq!(b.used(), 0);
        assert_eq!(b.rejections(), 0);
    }

    #[test]
    fn relimit_applies_to_future_charges() {
        let b = MemBudget::with_limit_mb(1);
        assert!(b.try_charge(2 << 20, "big").is_err());
        b.set_limit_mb(4);
        let c = b.try_charge(2 << 20, "big").unwrap();
        drop(c);
        b.set_limit_mb(0);
        assert!(b.limit_bytes().is_none());
    }
}
