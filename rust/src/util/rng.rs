//! Deterministic pseudo-random number generation.
//!
//! Xoshiro256++ seeded through SplitMix64 (the reference seeding procedure
//! recommended by the xoshiro authors), plus the sampling primitives the
//! solvers need: uniform indices, Rademacher signs, Box–Muller gaussians and
//! Fisher–Yates shuffles. Every job in the coordinator owns an independent
//! `Rng` derived from the experiment seed, so whole benchmark suites replay
//! bit-identically.

/// SplitMix64 step: used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second gaussian from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-trial/per-worker rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output of the xoshiro256++ state machine.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased uniform integer in [0, n) (Lemire's multiply-shift with
    /// rejection in the biased low region).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Rademacher +-1.
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Vector of i.i.d. uniform indices below n.
    pub fn indices(&mut self, count: usize, n: usize) -> Vec<usize> {
        (0..count).map(|_| self.below(n)).collect()
    }

    /// Vector of i.i.d. standard gaussians.
    pub fn gaussians(&mut self, count: usize) -> Vec<f64> {
        (0..count).map(|_| self.gaussian()).collect()
    }

    /// Vector of Rademacher signs.
    pub fn signs(&mut self, count: usize) -> Vec<f64> {
        (0..count).map(|_| self.sign()).collect()
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from an (unnormalized) weight table in O(n).
    /// Used by pwSGD's leverage-score sampling when no alias table is built.
    pub fn weighted(&mut self, weights: &[f64], total: f64) -> usize {
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Walker alias table for O(1) weighted sampling — pwSGD draws one
/// leverage-score-weighted index per iteration, so the O(n) scan in
/// [`Rng::weighted`] would dominate; the alias method makes it constant.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<u32>,
}

impl AliasTable {
    /// Build the table from an unnormalized positive weight vector
    /// (O(n) construction via Vose's small/large worklists).
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0 && n < u32::MAX as usize);
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "alias table needs positive total weight");
        let scale = n as f64 / total;
        let mut prob: Vec<f64> = weights.iter().map(|w| w * scale).collect();
        let mut alias = vec![0u32; n];
        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        for (i, &p) in prob.iter().enumerate() {
            if p < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            alias[s as usize] = l;
            prob[l as usize] = (prob[l as usize] + prob[s as usize]) - 1.0;
            if prob[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // numerical leftovers: force to 1
        for &i in small.iter().chain(large.iter()) {
            prob[i as usize] = 1.0;
        }
        AliasTable { prob, alias }
    }

    /// Draw one index with probability proportional to its weight — O(1).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.below(self.prob.len());
        if rng.uniform() < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 10;
        let mut counts = vec![0usize; n];
        let draws = 100_000;
        for _ in 0..draws {
            counts[r.below(n)] += 1;
        }
        let expect = draws as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt());
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let xs = r.gaussians(200_000);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn signs_are_pm_one_and_balanced() {
        let mut r = Rng::new(5);
        let s = r.signs(100_000);
        assert!(s.iter().all(|&x| x == 1.0 || x == -1.0));
        let sum: f64 = s.iter().sum();
        assert!(sum.abs() < 3.0 * (s.len() as f64).sqrt());
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(13);
        let mut c1 = parent.fork(0);
        let mut c2 = parent.fork(1);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn alias_table_matches_weights() {
        let weights = [1.0, 2.0, 3.0, 4.0];
        let table = AliasTable::new(&weights);
        let mut r = Rng::new(21);
        let draws = 200_000;
        let mut counts = [0usize; 4];
        for _ in 0..draws {
            counts[table.sample(&mut r)] += 1;
        }
        let total: f64 = weights.iter().sum();
        for i in 0..4 {
            let expect = draws as f64 * weights[i] / total;
            assert!(
                (counts[i] as f64 - expect).abs() < 5.0 * expect.sqrt(),
                "bucket {i}: {} vs {expect}",
                counts[i]
            );
        }
    }

    #[test]
    fn weighted_scan_matches_weights() {
        let weights = [5.0, 1.0, 1.0];
        let total = 7.0;
        let mut r = Rng::new(33);
        let mut c0 = 0usize;
        let draws = 50_000;
        for _ in 0..draws {
            if r.weighted(&weights, total) == 0 {
                c0 += 1;
            }
        }
        let expect = draws as f64 * 5.0 / 7.0;
        assert!((c0 as f64 - expect).abs() < 6.0 * expect.sqrt());
    }
}
