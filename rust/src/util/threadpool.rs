//! Scoped data-parallel helpers over std::thread.
//!
//! Two primitives cover every parallel site in the codebase:
//! * [`parallel_for_chunks`] — split a mutable slice into contiguous chunks
//!   and process them on worker threads (gemm row blocks, FWHT column
//!   panels, dataset generation).
//! * [`ThreadPool`] — a long-lived work-stealing task scheduler used by the
//!   coordinator to run solver jobs concurrently with bounded parallelism,
//!   per-lane backpressure, and weighted priority dispatch.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Number of worker threads to use by default: respects
/// `HDPW_THREADS` env var, otherwise available_parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("HDPW_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Process `data` in contiguous chunks of at most `chunk` elements, calling
/// `f(chunk_start_index, chunk_slice)` from up to `threads` workers.
/// Falls back to sequential execution for a single thread or single chunk.
pub fn parallel_for_chunks<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Send + Sync,
{
    assert!(chunk > 0);
    let n_chunks = data.len().div_ceil(chunk.max(1));
    if threads <= 1 || n_chunks <= 1 {
        for (ci, sl) in data.chunks_mut(chunk).enumerate() {
            f(ci * chunk, sl);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let chunks: Vec<(usize, &mut [T])> = data
        .chunks_mut(chunk)
        .enumerate()
        .map(|(ci, sl)| (ci * chunk, sl))
        .collect();
    let chunks = Mutex::new(chunks.into_iter().map(Some).collect::<Vec<_>>());
    thread::scope(|scope| {
        for _ in 0..threads.min(n_chunks) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let item = {
                    let mut guard = chunks.lock().unwrap();
                    if i >= guard.len() {
                        return;
                    }
                    guard[i].take()
                };
                if let Some((start, sl)) = item {
                    f(start, sl);
                }
            });
        }
    });
}

/// Run `f(i)` for i in 0..n across worker threads, work-stealing by an
/// atomic counter. Used where iterations are independent and index-addressed.
///
/// PERF: dispatches to a lazily-started *persistent* worker pool — spawning
/// OS threads per call costs ~1-3 ms at 32 threads, which dominated mid-size
/// gemv/fused_grad calls (see EXPERIMENTS.md section Perf). If the pool is
/// busy with another caller's loop, this falls back to inline serial
/// execution (deadlock-free by construction); the fallback is counted in
/// [`StaticPool::serial_fallbacks`] so the perf cliff is observable.
pub fn parallel_for_each_index<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Send + Sync,
{
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    static_pool().run(n, &f);
}

// ---------------------------------------------------------------------------
// persistent data-parallel pool
// ---------------------------------------------------------------------------

struct PoolJob {
    /// type-erased &(dyn Fn(usize) + Sync); valid until `active` hits 0 and
    /// the submitter (who owns the closure) observes completion
    f: *const (dyn Fn(usize) + Sync),
    n: usize,
    next: Arc<AtomicUsize>,
    /// submitter + workers currently inside the job (condvar-signaled so
    /// the submitter sleeps instead of spinning while stragglers drain)
    active: Arc<(Mutex<usize>, Condvar)>,
}

unsafe impl Send for PoolJob {}

struct StaticPoolState {
    job: Option<PoolJob>,
    epoch: u64,
}

/// The persistent data-parallel worker pool behind
/// [`parallel_for_each_index`]. One job runs at a time; workers idle on a
/// condvar between jobs.
pub struct StaticPool {
    state: Mutex<StaticPoolState>,
    work_cv: Condvar,
    /// How often `run` found the pool occupied and executed serially
    /// inline (nested parallelism or caller contention) — the observable
    /// perf cliff `bench-info` reports.
    serial_fallbacks: AtomicUsize,
}

static STATIC_POOL: std::sync::OnceLock<&'static StaticPool> = std::sync::OnceLock::new();

/// The process-wide data-parallel pool (workers = default_threads - 1;
/// the submitting thread always participates).
pub fn static_pool() -> &'static StaticPool {
    STATIC_POOL.get_or_init(|| {
        let pool: &'static StaticPool = Box::leak(Box::new(StaticPool {
            state: Mutex::new(StaticPoolState {
                job: None,
                epoch: 0,
            }),
            work_cv: Condvar::new(),
            serial_fallbacks: AtomicUsize::new(0),
        }));
        let workers = default_threads().saturating_sub(1).min(64);
        for _ in 0..workers {
            thread::Builder::new()
                .name("hdpw-pool".into())
                .spawn(move || pool.worker_loop())
                .expect("spawn pool worker");
        }
        pool
    })
}

impl StaticPool {
    fn worker_loop(&self) {
        let mut seen_epoch = 0u64;
        loop {
            // wait for a job with a fresh epoch
            let job = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if st.epoch != seen_epoch {
                        if let Some(j) = &st.job {
                            seen_epoch = st.epoch;
                            if j.next.load(Ordering::Relaxed) < j.n {
                                // register under the state lock: the
                                // submitter cannot observe active == 0
                                // between our claim and our increment
                                *j.active.0.lock().unwrap() += 1;
                                break PoolJob {
                                    f: j.f,
                                    n: j.n,
                                    next: Arc::clone(&j.next),
                                    active: Arc::clone(&j.active),
                                };
                            }
                        } else {
                            seen_epoch = st.epoch;
                        }
                    }
                    st = self.work_cv.wait(st).unwrap();
                }
            };
            // process
            let f = unsafe { &*job.f };
            loop {
                let i = job.next.fetch_add(1, Ordering::Relaxed);
                if i >= job.n {
                    break;
                }
                f(i);
            }
            let (lock, cv) = &*job.active;
            let mut a = lock.lock().unwrap();
            *a -= 1;
            if *a == 0 {
                cv.notify_all();
            }
        }
    }

    /// How often the busy-pool serial fallback has fired process-wide.
    pub fn serial_fallbacks(&self) -> usize {
        self.serial_fallbacks.load(Ordering::Relaxed)
    }

    /// Run f(0..n) with pool help; the caller participates and blocks until
    /// every index is done. Falls back to serial if the pool is occupied.
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        let next = Arc::new(AtomicUsize::new(0));
        let active = Arc::new((Mutex::new(1usize), Condvar::new())); // the submitter
        {
            let mut st = self.state.lock().unwrap();
            if st.job.is_some() {
                drop(st);
                // pool busy (another caller or nested parallelism): serial
                self.serial_fallbacks.fetch_add(1, Ordering::Relaxed);
                for i in 0..n {
                    f(i);
                }
                return;
            }
            st.job = Some(PoolJob {
                // erase the lifetime: we do not return until next >= n and
                // active == 0, so the borrow outlives every use
                f: unsafe {
                    std::mem::transmute::<
                        *const (dyn Fn(usize) + Sync),
                        *const (dyn Fn(usize) + Sync),
                    >(f as *const _)
                },
                n,
                next: Arc::clone(&next),
                active: Arc::clone(&active),
            });
            st.epoch += 1;
            self.work_cv.notify_all();
        }
        // the submitter works too
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            f(i);
        }
        // wait for stragglers (sleeping, not spinning), then clear the slot
        {
            let (lock, cv) = &*active;
            let mut a = lock.lock().unwrap();
            *a -= 1;
            while *a > 0 {
                a = cv.wait(a).unwrap();
            }
        }
        let mut st = self.state.lock().unwrap();
        st.job = None;
        st.epoch += 1;
    }
}

// ---------------------------------------------------------------------------
// work-stealing task pool (the coordinator's scheduler substrate)
// ---------------------------------------------------------------------------

/// Priority lane of a task: the scheduler serves lanes weighted 4:2:1
/// (high:normal:batch) so a batch backlog cannot starve interactive jobs,
/// while batch still makes progress under sustained high-lane load.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Lane {
    /// Interactive / latency-sensitive jobs.
    High = 0,
    /// The default lane.
    Normal = 1,
    /// Bulk / best-effort jobs.
    Batch = 2,
}

/// All lanes in priority order (high first).
pub const LANES: [Lane; 3] = [Lane::High, Lane::Normal, Lane::Batch];

/// Weighted dispatch pattern: 4 high : 2 normal : 1 batch per 7-tick cycle.
/// A tick whose preferred lane is empty falls through in priority order, so
/// the weights only bite under contention.
const LANE_PATTERN: [Lane; 7] = [
    Lane::High,
    Lane::High,
    Lane::Normal,
    Lane::High,
    Lane::Normal,
    Lane::High,
    Lane::Batch,
];

/// Max items a worker moves in one injector grab / steal (keeps latecomer
/// lanes responsive: nobody hoards the whole backlog).
const GRAB_CAP: usize = 8;

impl Lane {
    /// Parse a wire/CLI lane name; "" means the default (normal).
    pub fn parse(s: &str) -> Option<Lane> {
        match s {
            "high" => Some(Lane::High),
            "" | "normal" => Some(Lane::Normal),
            "batch" => Some(Lane::Batch),
            _ => None,
        }
    }

    /// Canonical lane name ("high" | "normal" | "batch").
    pub fn name(self) -> &'static str {
        match self {
            Lane::High => "high",
            Lane::Normal => "normal",
            Lane::Batch => "batch",
        }
    }

    /// Array index (priority order: high = 0).
    pub fn idx(self) -> usize {
        self as usize
    }
}

struct WorkItem {
    lane: Lane,
    f: Box<dyn FnOnce() + Send>,
}

struct Shared {
    /// Global injection queues, one per lane: submit lands here; workers
    /// grab batches out into their local deques.
    injector: Mutex<[VecDeque<WorkItem>; 3]>,
    /// Per-worker local deques. The owner pops the front; thieves take
    /// half from the back.
    locals: Vec<Mutex<VecDeque<WorkItem>>>,
    /// Tasks submitted but not yet *started*, per lane (injector + local
    /// residents) — the deadline estimator's queue-depth signal.
    queued: [AtomicUsize; 3],
    /// Tasks submitted but not yet *finished*, per lane — the bounded
    /// backpressure state. Per-lane bounds are what make priority lanes
    /// real: a full batch lane never blocks a high-lane submit.
    inflight: Mutex<[usize; 3]>,
    inflight_cv: Condvar,
    /// Parking lot: workers sleep here when every queue is empty. The bool
    /// is the shutdown flag.
    park: Mutex<bool>,
    work_cv: Condvar,
    /// Weighted-dispatch clock (advances only when an injector grab
    /// actually happens, so idle periods don't skew the weights).
    tick: AtomicUsize,
    /// Successful steal operations (observability).
    steals: AtomicUsize,
}

impl Shared {
    fn total_queued(&self) -> usize {
        self.queued.iter().map(|q| q.load(Ordering::Acquire)).sum()
    }
}

/// A work-stealing task scheduler with priority lanes and bounded per-lane
/// backpressure. Tasks are injected into per-lane global queues; each worker
/// grabs half a queue (capped) into a private deque, runs it front-to-back,
/// and steals from siblings' backs when starved. Idle workers park on a
/// condvar — no busy spins. `submit` blocks while the task's lane is at
/// capacity — this is the coordinator's backpressure mechanism (jobs arrive
/// faster than solvers finish).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
    max_queue: usize,
}

impl ThreadPool {
    /// Spawn `threads` workers; each lane's submitted-not-finished count is
    /// bounded at `max_queue` tasks.
    pub fn new(threads: usize, max_queue: usize) -> Self {
        assert!(threads > 0);
        let shared = Arc::new(Shared {
            injector: Mutex::new([VecDeque::new(), VecDeque::new(), VecDeque::new()]),
            locals: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            queued: [
                AtomicUsize::new(0),
                AtomicUsize::new(0),
                AtomicUsize::new(0),
            ],
            inflight: Mutex::new([0; 3]),
            inflight_cv: Condvar::new(),
            park: Mutex::new(false),
            work_cv: Condvar::new(),
            tick: AtomicUsize::new(0),
            steals: AtomicUsize::new(0),
        });
        let mut workers = Vec::with_capacity(threads);
        for wid in 0..threads {
            let shared = Arc::clone(&shared);
            workers.push(
                thread::Builder::new()
                    .name(format!("hdpw-serve-{wid}"))
                    .spawn(move || Self::worker_loop(&shared, wid))
                    .expect("spawn pool worker"),
            );
        }
        ThreadPool {
            shared,
            workers,
            max_queue,
        }
    }

    fn worker_loop(shared: &Shared, wid: usize) {
        loop {
            if let Some(item) = Self::find_work(shared, wid) {
                Self::run_item(shared, item);
                continue;
            }
            // park until new work is injected (or shutdown). The submitter
            // raises `queued` *before* taking the park lock and notifying,
            // so either we see the count here or we are woken — no lost
            // wakeups, no spinning.
            let mut guard = shared.park.lock().unwrap();
            loop {
                if *guard {
                    return; // shutdown
                }
                if shared.total_queued() > 0 {
                    break;
                }
                guard = shared.work_cv.wait(guard).unwrap();
            }
        }
    }

    /// One dispatch decision: local-head preemption, local pop, weighted
    /// injector grab, then steal — in that order.
    fn find_work(shared: &Shared, wid: usize) -> Option<WorkItem> {
        // (a) if our local head is outranked by an injected item, serve the
        // higher lane first — a high job never waits behind a worker's
        // private batch backlog
        let local_head = shared.locals[wid].lock().unwrap().front().map(|w| w.lane);
        if let Some(head) = local_head {
            if head != Lane::High {
                let mut inj = shared.injector.lock().unwrap();
                for li in 0..head.idx() {
                    if let Some(item) = inj[li].pop_front() {
                        return Some(item);
                    }
                }
            }
            // (b) run our own queue front-to-back
            if let Some(item) = shared.locals[wid].lock().unwrap().pop_front() {
                return Some(item);
            }
        }
        // (c) grab a batch from the injector, weighted by lane
        if let Some(item) = Self::grab_batch(shared, wid) {
            return Some(item);
        }
        // (d) steal half a sibling's deque (from the back)
        Self::steal(shared, wid)
    }

    /// Take up to half (capped) of the weighted-choice injector lane; run
    /// the first item, stash the rest locally for ourselves and thieves.
    fn grab_batch(shared: &Shared, wid: usize) -> Option<WorkItem> {
        let mut rest = Vec::new();
        let first = {
            let mut inj = shared.injector.lock().unwrap();
            if inj.iter().all(|q| q.is_empty()) {
                return None;
            }
            // consume a tick only when something is actually there, and
            // fall through to priority order when the preferred lane is
            // empty — weights shape contention, never idle the pool
            let t = shared.tick.fetch_add(1, Ordering::Relaxed);
            let pref = LANE_PATTERN[t % LANE_PATTERN.len()];
            let lane = if inj[pref.idx()].is_empty() {
                LANES
                    .into_iter()
                    .find(|l| !inj[l.idx()].is_empty())
                    .expect("some lane non-empty")
            } else {
                pref
            };
            let q = &mut inj[lane.idx()];
            let take = q.len().div_ceil(2).min(GRAB_CAP);
            let first = q.pop_front().expect("chosen lane non-empty");
            for _ in 1..take {
                rest.push(q.pop_front().expect("counted"));
            }
            first
        };
        if !rest.is_empty() {
            let mut loc = shared.locals[wid].lock().unwrap();
            loc.extend(rest);
            drop(loc);
            // invite a parked sibling to steal from us
            let _g = shared.park.lock().unwrap();
            shared.work_cv.notify_one();
        }
        Some(first)
    }

    /// Scan siblings (starting after ourselves) and take half of the first
    /// non-empty deque, from the back — the classic steal-half policy.
    fn steal(shared: &Shared, wid: usize) -> Option<WorkItem> {
        let n = shared.locals.len();
        for off in 1..n {
            let vid = (wid + off) % n;
            let mut grabbed = {
                let mut v = shared.locals[vid].lock().unwrap();
                let take = v.len().div_ceil(2).min(GRAB_CAP);
                if take == 0 {
                    continue;
                }
                let mut g = Vec::with_capacity(take);
                for _ in 0..take {
                    g.push(v.pop_back().expect("counted"));
                }
                g
            };
            shared.steals.fetch_add(1, Ordering::Relaxed);
            grabbed.reverse(); // restore submission order
            let first = grabbed.remove(0);
            if !grabbed.is_empty() {
                shared.locals[wid].lock().unwrap().extend(grabbed);
            }
            return Some(first);
        }
        None
    }

    fn run_item(shared: &Shared, item: WorkItem) {
        shared.queued[item.lane.idx()].fetch_sub(1, Ordering::AcqRel);
        (item.f)();
        let mut inf = shared.inflight.lock().unwrap();
        inf[item.lane.idx()] -= 1;
        drop(inf);
        shared.inflight_cv.notify_all();
    }

    /// Submit a task on the default (normal) lane; blocks while that lane
    /// is at capacity (backpressure).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.submit_lane(Lane::Normal, f);
    }

    /// Submit a task on `lane`; blocks while *that lane* is at capacity.
    /// Lanes are bounded independently, so a saturated batch lane cannot
    /// block a high-priority submit (no priority inversion at admission).
    pub fn submit_lane<F: FnOnce() + Send + 'static>(&self, lane: Lane, f: F) {
        {
            let mut inf = self.shared.inflight.lock().unwrap();
            while inf[lane.idx()] >= self.max_queue {
                inf = self.shared.inflight_cv.wait(inf).unwrap();
            }
            inf[lane.idx()] += 1;
        }
        self.shared.queued[lane.idx()].fetch_add(1, Ordering::Release);
        self.shared.injector.lock().unwrap()[lane.idx()].push_back(WorkItem {
            lane,
            f: Box::new(f),
        });
        // wake one parked worker; `queued` was raised before we take the
        // park lock, so a worker past its check is already awake
        let _g = self.shared.park.lock().unwrap();
        self.shared.work_cv.notify_one();
    }

    /// Block until every submitted task has finished.
    pub fn wait_idle(&self) {
        let mut inf = self.shared.inflight.lock().unwrap();
        while inf.iter().sum::<usize>() > 0 {
            inf = self.shared.inflight_cv.wait(inf).unwrap();
        }
    }

    /// Tasks submitted but not yet finished (all lanes).
    pub fn inflight(&self) -> usize {
        self.shared.inflight.lock().unwrap().iter().sum()
    }

    /// Tasks submitted but not yet finished on one lane.
    pub fn lane_inflight(&self, lane: Lane) -> usize {
        self.shared.inflight.lock().unwrap()[lane.idx()]
    }

    /// Tasks submitted but not yet *started* on one lane.
    pub fn queued(&self, lane: Lane) -> usize {
        self.shared.queued[lane.idx()].load(Ordering::Acquire)
    }

    /// Tasks not yet started on `lane` or any higher-priority lane — the
    /// work that will be served before (or interleaved ahead of) a new
    /// submit on `lane`; the deadline estimator's queue-depth signal.
    pub fn queued_at_or_above(&self, lane: Lane) -> usize {
        (0..=lane.idx())
            .map(|li| self.shared.queued[li].load(Ordering::Acquire))
            .sum()
    }

    /// Successful steal operations since startup (observability).
    pub fn steals(&self) -> usize {
        self.shared.steals.load(Ordering::Relaxed)
    }

    /// Worker thread count.
    pub fn workers(&self) -> usize {
        self.shared.locals.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.wait_idle();
        {
            let mut shutdown = self.shared.park.lock().unwrap();
            *shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunked_touches_every_element_once() {
        let mut v = vec![0u32; 1000];
        parallel_for_chunks(&mut v, 37, 4, |_, sl| {
            for x in sl {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunked_passes_correct_offsets() {
        let mut v: Vec<usize> = vec![0; 100];
        parallel_for_chunks(&mut v, 7, 3, |start, sl| {
            for (i, x) in sl.iter_mut().enumerate() {
                *x = start + i;
            }
        });
        let want: Vec<usize> = (0..100).collect();
        assert_eq!(v, want);
    }

    #[test]
    fn index_parallel_covers_range() {
        let sum = AtomicU64::new(0);
        parallel_for_each_index(1000, 8, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn sequential_fallback_matches() {
        let mut a = vec![1i64; 64];
        let mut b = vec![1i64; 64];
        parallel_for_chunks(&mut a, 8, 1, |s, sl| {
            for (i, x) in sl.iter_mut().enumerate() {
                *x = (s + i) as i64;
            }
        });
        parallel_for_chunks(&mut b, 8, 4, |s, sl| {
            for (i, x) in sl.iter_mut().enumerate() {
                *x = (s + i) as i64;
            }
        });
        assert_eq!(a, b);
    }

    #[test]
    fn nested_parallel_counts_serial_fallbacks() {
        let before = static_pool().serial_fallbacks();
        let sum = AtomicU64::new(0);
        // the inner loops run while the outer job occupies the pool, so
        // each one takes the counted serial-fallback path — and the result
        // must still be exact
        parallel_for_each_index(4, 8, |i| {
            parallel_for_each_index(100, 8, |j| {
                sum.fetch_add((i * 100 + j) as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(sum.load(Ordering::Relaxed), 399 * 400 / 2);
        assert!(
            static_pool().serial_fallbacks() > before,
            "nested parallelism must count at least one serial fallback"
        );
    }

    #[test]
    fn lane_parse_and_names_roundtrip() {
        for lane in LANES {
            assert_eq!(Lane::parse(lane.name()), Some(lane));
        }
        assert_eq!(Lane::parse(""), Some(Lane::Normal));
        assert_eq!(Lane::parse("urgent"), None);
        assert!(Lane::High < Lane::Batch);
    }

    #[test]
    fn pool_runs_all_tasks() {
        let pool = ThreadPool::new(4, 16);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(pool.inflight(), 0);
        assert_eq!(pool.queued(Lane::Normal), 0);
    }

    #[test]
    fn pool_runs_all_lanes_under_stealing() {
        let pool = ThreadPool::new(4, 256);
        let counter = Arc::new(AtomicUsize::new(0));
        for i in 0..300 {
            let c = Arc::clone(&counter);
            let lane = LANES[i % 3];
            pool.submit_lane(lane, move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 300);
        for lane in LANES {
            assert_eq!(pool.lane_inflight(lane), 0);
            assert_eq!(pool.queued(lane), 0);
        }
    }

    #[test]
    fn pool_backpressure_bounds_inflight() {
        let pool = ThreadPool::new(2, 4);
        for _ in 0..32 {
            pool.submit(move || {
                thread::sleep(std::time::Duration::from_millis(1));
            });
            assert!(pool.inflight() <= 4);
        }
        pool.wait_idle();
    }

    #[test]
    fn high_lane_admitted_and_served_ahead_of_batch_backlog() {
        // one worker, batch lane saturated to its bound: a high-lane submit
        // must (1) not block at admission — lanes are bounded independently
        // — and (2) be dispatched ahead of the worker's batch backlog.
        let pool = ThreadPool::new(1, 4);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let order = Arc::new(Mutex::new(Vec::<&'static str>::new()));
        {
            let gate = Arc::clone(&gate);
            pool.submit_lane(Lane::Batch, move || {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
        }
        for _ in 0..3 {
            let order = Arc::clone(&order);
            pool.submit_lane(Lane::Batch, move || {
                order.lock().unwrap().push("batch");
            });
        }
        assert_eq!(pool.lane_inflight(Lane::Batch), 4, "batch lane full");
        // this returns promptly: the batch lane's bound is not the high
        // lane's bound (a hang here IS the regression this test guards)
        {
            let order = Arc::clone(&order);
            pool.submit_lane(Lane::High, move || {
                order.lock().unwrap().push("high");
            });
        }
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        pool.wait_idle();
        let order = order.lock().unwrap();
        assert_eq!(order.len(), 4);
        let high_pos = order.iter().position(|s| *s == "high").unwrap();
        assert!(
            high_pos <= 1,
            "high job must preempt the batch backlog, ran at {high_pos}: {order:?}"
        );
    }

    #[test]
    fn queued_depth_counts_lanes_at_or_above() {
        let pool = ThreadPool::new(1, 16);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        {
            let gate = Arc::clone(&gate);
            pool.submit_lane(Lane::High, move || {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
        }
        // wait until the worker picked the gate job up (it leaves `queued`)
        while pool.queued(Lane::High) > 0 {
            thread::yield_now();
        }
        pool.submit_lane(Lane::High, || {});
        pool.submit_lane(Lane::Normal, || {});
        pool.submit_lane(Lane::Batch, || {});
        assert_eq!(pool.queued_at_or_above(Lane::High), 1);
        assert_eq!(pool.queued_at_or_above(Lane::Normal), 2);
        assert_eq!(pool.queued_at_or_above(Lane::Batch), 3);
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        pool.wait_idle();
    }

    #[test]
    fn stealing_spreads_a_grabbed_backlog() {
        // 4 workers, one big burst: grabs put batches in private deques and
        // siblings steal from their backs. We can't assert steal counts
        // deterministically, but every task must run exactly once and the
        // counter must be readable.
        let pool = ThreadPool::new(4, 1024);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..512 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
                std::hint::black_box(0u64);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 512);
        let _ = pool.steals(); // observable, whatever its value
    }
}
