//! Scoped data-parallel helpers over std::thread.
//!
//! Two primitives cover every parallel site in the codebase:
//! * [`parallel_for_chunks`] — split a mutable slice into contiguous chunks
//!   and process them on worker threads (gemm row blocks, FWHT column
//!   panels, dataset generation).
//! * [`ThreadPool`] — a long-lived task queue used by the coordinator to run
//!   solver jobs concurrently with bounded parallelism and backpressure.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Number of worker threads to use by default: respects
/// `HDPW_THREADS` env var, otherwise available_parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("HDPW_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Process `data` in contiguous chunks of at most `chunk` elements, calling
/// `f(chunk_start_index, chunk_slice)` from up to `threads` workers.
/// Falls back to sequential execution for a single thread or single chunk.
pub fn parallel_for_chunks<T, F>(data: &mut [T], chunk: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Send + Sync,
{
    assert!(chunk > 0);
    let n_chunks = data.len().div_ceil(chunk.max(1));
    if threads <= 1 || n_chunks <= 1 {
        for (ci, sl) in data.chunks_mut(chunk).enumerate() {
            f(ci * chunk, sl);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    let chunks: Vec<(usize, &mut [T])> = data
        .chunks_mut(chunk)
        .enumerate()
        .map(|(ci, sl)| (ci * chunk, sl))
        .collect();
    let chunks = Mutex::new(chunks.into_iter().map(Some).collect::<Vec<_>>());
    thread::scope(|scope| {
        for _ in 0..threads.min(n_chunks) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let item = {
                    let mut guard = chunks.lock().unwrap();
                    if i >= guard.len() {
                        return;
                    }
                    guard[i].take()
                };
                if let Some((start, sl)) = item {
                    f(start, sl);
                }
            });
        }
    });
}

/// Run `f(i)` for i in 0..n across worker threads, work-stealing by an
/// atomic counter. Used where iterations are independent and index-addressed.
///
/// PERF: dispatches to a lazily-started *persistent* worker pool — spawning
/// OS threads per call costs ~1-3 ms at 32 threads, which dominated mid-size
/// gemv/fused_grad calls (see EXPERIMENTS.md section Perf). If the pool is
/// busy with another caller's loop, this falls back to inline serial
/// execution (deadlock-free by construction).
pub fn parallel_for_each_index<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Send + Sync,
{
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    static_pool().run(n, &f);
}

// ---------------------------------------------------------------------------
// persistent data-parallel pool
// ---------------------------------------------------------------------------

struct PoolJob {
    /// type-erased &(dyn Fn(usize) + Sync); valid until `active` hits 0 and
    /// the submitter (who owns the closure) observes completion
    f: *const (dyn Fn(usize) + Sync),
    n: usize,
    next: Arc<AtomicUsize>,
    /// submitter + workers currently inside the job
    active: Arc<AtomicUsize>,
}

unsafe impl Send for PoolJob {}

struct StaticPoolState {
    job: Option<PoolJob>,
    epoch: u64,
}

/// The persistent data-parallel worker pool behind
/// [`parallel_for_each_index`]. One job runs at a time; workers idle on a
/// condvar between jobs.
pub struct StaticPool {
    state: Mutex<StaticPoolState>,
    work_cv: Condvar,
}

static STATIC_POOL: std::sync::OnceLock<&'static StaticPool> = std::sync::OnceLock::new();

/// The process-wide data-parallel pool (workers = default_threads - 1;
/// the submitting thread always participates).
pub fn static_pool() -> &'static StaticPool {
    STATIC_POOL.get_or_init(|| {
        let pool: &'static StaticPool = Box::leak(Box::new(StaticPool {
            state: Mutex::new(StaticPoolState {
                job: None,
                epoch: 0,
            }),
            work_cv: Condvar::new(),
        }));
        let workers = default_threads().saturating_sub(1).min(64);
        for _ in 0..workers {
            thread::Builder::new()
                .name("hdpw-pool".into())
                .spawn(move || pool.worker_loop())
                .expect("spawn pool worker");
        }
        pool
    })
}

impl StaticPool {
    fn worker_loop(&self) {
        let mut seen_epoch = 0u64;
        loop {
            // wait for a job with a fresh epoch
            let job = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if st.epoch != seen_epoch {
                        if let Some(j) = &st.job {
                            seen_epoch = st.epoch;
                            if j.next.load(Ordering::Relaxed) < j.n {
                                j.active.fetch_add(1, Ordering::AcqRel);
                                break PoolJob {
                                    f: j.f,
                                    n: j.n,
                                    next: Arc::clone(&j.next),
                                    active: Arc::clone(&j.active),
                                };
                            }
                        } else {
                            seen_epoch = st.epoch;
                        }
                    }
                    st = self.work_cv.wait(st).unwrap();
                }
            };
            // process
            let f = unsafe { &*job.f };
            loop {
                let i = job.next.fetch_add(1, Ordering::Relaxed);
                if i >= job.n {
                    break;
                }
                f(i);
            }
            job.active.fetch_sub(1, Ordering::AcqRel);
        }
    }

    /// Run f(0..n) with pool help; the caller participates and blocks until
    /// every index is done. Falls back to serial if the pool is occupied.
    pub fn run(&self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        let next = Arc::new(AtomicUsize::new(0));
        let active = Arc::new(AtomicUsize::new(1)); // the submitter
        {
            let mut st = self.state.lock().unwrap();
            if st.job.is_some() {
                drop(st);
                // pool busy (another caller or nested parallelism): serial
                for i in 0..n {
                    f(i);
                }
                return;
            }
            st.job = Some(PoolJob {
                // erase the lifetime: we do not return until next >= n and
                // active == 0, so the borrow outlives every use
                f: unsafe {
                    std::mem::transmute::<
                        *const (dyn Fn(usize) + Sync),
                        *const (dyn Fn(usize) + Sync),
                    >(f as *const _)
                },
                n,
                next: Arc::clone(&next),
                active: Arc::clone(&active),
            });
            st.epoch += 1;
            self.work_cv.notify_all();
        }
        // the submitter works too
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            f(i);
        }
        active.fetch_sub(1, Ordering::AcqRel);
        // wait for stragglers, then clear the job slot
        while active.load(Ordering::Acquire) > 0 {
            std::hint::spin_loop();
        }
        let mut st = self.state.lock().unwrap();
        st.job = None;
        st.epoch += 1;
    }
}

enum Task {
    Run(Box<dyn FnOnce() + Send>),
    Shutdown,
}

/// A bounded task queue + worker threads. `submit` blocks when
/// `max_queue` tasks are already waiting — this is the coordinator's
/// backpressure mechanism (jobs arrive faster than solvers finish).
pub struct ThreadPool {
    tx: mpsc::Sender<Task>,
    workers: Vec<thread::JoinHandle<()>>,
    inflight: Arc<(Mutex<usize>, Condvar)>,
    max_queue: usize,
}

impl ThreadPool {
    /// Spawn `threads` workers with a queue bounded at `max_queue` tasks.
    pub fn new(threads: usize, max_queue: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let inflight = Arc::new((Mutex::new(0usize), Condvar::new()));
        let mut workers = Vec::with_capacity(threads);
        for _ in 0..threads {
            let rx = Arc::clone(&rx);
            let inflight = Arc::clone(&inflight);
            workers.push(thread::spawn(move || loop {
                let task = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match task {
                    Ok(Task::Run(f)) => {
                        f();
                        let (lock, cv) = &*inflight;
                        let mut n = lock.lock().unwrap();
                        *n -= 1;
                        cv.notify_all();
                    }
                    Ok(Task::Shutdown) | Err(_) => return,
                }
            }));
        }
        ThreadPool {
            tx,
            workers,
            inflight,
            max_queue,
        }
    }

    /// Submit a task; blocks while the queue is at capacity (backpressure).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, cv) = &*self.inflight;
        let mut n = lock.lock().unwrap();
        while *n >= self.max_queue {
            n = cv.wait(n).unwrap();
        }
        *n += 1;
        drop(n);
        self.tx.send(Task::Run(Box::new(f))).expect("pool alive");
    }

    /// Block until every submitted task has finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.inflight;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    /// Tasks submitted but not yet finished.
    pub fn inflight(&self) -> usize {
        *self.inflight.0.lock().unwrap()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.wait_idle();
        for _ in &self.workers {
            let _ = self.tx.send(Task::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunked_touches_every_element_once() {
        let mut v = vec![0u32; 1000];
        parallel_for_chunks(&mut v, 37, 4, |_, sl| {
            for x in sl {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunked_passes_correct_offsets() {
        let mut v: Vec<usize> = vec![0; 100];
        parallel_for_chunks(&mut v, 7, 3, |start, sl| {
            for (i, x) in sl.iter_mut().enumerate() {
                *x = start + i;
            }
        });
        let want: Vec<usize> = (0..100).collect();
        assert_eq!(v, want);
    }

    #[test]
    fn index_parallel_covers_range() {
        let sum = AtomicU64::new(0);
        parallel_for_each_index(1000, 8, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 999 * 1000 / 2);
    }

    #[test]
    fn sequential_fallback_matches() {
        let mut a = vec![1i64; 64];
        let mut b = vec![1i64; 64];
        parallel_for_chunks(&mut a, 8, 1, |s, sl| {
            for (i, x) in sl.iter_mut().enumerate() {
                *x = (s + i) as i64;
            }
        });
        parallel_for_chunks(&mut b, 8, 4, |s, sl| {
            for (i, x) in sl.iter_mut().enumerate() {
                *x = (s + i) as i64;
            }
        });
        assert_eq!(a, b);
    }

    #[test]
    fn pool_runs_all_tasks() {
        let pool = ThreadPool::new(4, 16);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_backpressure_bounds_inflight() {
        let pool = ThreadPool::new(2, 4);
        for _ in 0..32 {
            pool.submit(move || {
                thread::sleep(std::time::Duration::from_millis(1));
            });
            assert!(pool.inflight() <= 4);
        }
        pool.wait_idle();
    }
}
