//! ASCII line plots + series containers for the figure benches.
//!
//! Every figure in the paper is an error-vs-iterations or error-vs-seconds
//! line chart; the benches regenerate them as (a) CSV files under `out/` and
//! (b) terminal ASCII plots so the shape comparison (who wins, crossovers)
//! is visible directly in `cargo bench` output.

use std::fmt::Write as _;

/// One named curve: x (iterations or seconds) vs y (relative error).
#[derive(Clone, Debug)]
pub struct Series {
    /// Legend label.
    pub name: String,
    /// X coordinates, in push order.
    pub xs: Vec<f64>,
    /// Y coordinates, parallel to `xs`.
    pub ys: Vec<f64>,
}

impl Series {
    /// Empty series with a legend label.
    pub fn new(name: impl Into<String>) -> Self {
        Series {
            name: name.into(),
            xs: Vec::new(),
            ys: Vec::new(),
        }
    }

    /// Append one point.
    pub fn push(&mut self, x: f64, y: f64) {
        self.xs.push(x);
        self.ys.push(y);
    }

    /// Number of points.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// Whether the series has no points.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// First x at which y drops to or below the threshold (e.g. time-to-eps).
    pub fn x_at_y_below(&self, thresh: f64) -> Option<f64> {
        self.xs
            .iter()
            .zip(&self.ys)
            .find(|(_, &y)| y <= thresh)
            .map(|(&x, _)| x)
    }
}

/// A figure = several series + axis labels.
#[derive(Clone, Debug)]
pub struct Figure {
    /// Chart title.
    pub title: String,
    /// X axis label.
    pub xlabel: String,
    /// Y axis label.
    pub ylabel: String,
    /// Plot log10(y) instead of y.
    pub logy: bool,
    /// The curves, in add order.
    pub series: Vec<Series>,
}

impl Figure {
    /// Empty figure with axis labels.
    pub fn new(title: impl Into<String>, xlabel: &str, ylabel: &str, logy: bool) -> Self {
        Figure {
            title: title.into(),
            xlabel: xlabel.to_string(),
            ylabel: ylabel.to_string(),
            logy,
            series: Vec::new(),
        }
    }

    /// Add one curve.
    pub fn add(&mut self, s: Series) {
        self.series.push(s);
    }

    /// Render an ASCII chart `width` x `height` characters.
    pub fn ascii(&self, width: usize, height: usize) -> String {
        let marks = ['*', '+', 'o', 'x', '#', '@', '%', '&'];
        let tf = |y: f64| -> f64 {
            if self.logy {
                y.max(1e-300).log10()
            } else {
                y
            }
        };
        let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
        for s in &self.series {
            for (&x, &y) in s.xs.iter().zip(&s.ys) {
                if !x.is_finite() || !y.is_finite() {
                    continue;
                }
                xmin = xmin.min(x);
                xmax = xmax.max(x);
                let ty = tf(y);
                ymin = ymin.min(ty);
                ymax = ymax.max(ty);
            }
        }
        if !xmin.is_finite() || xmin == xmax {
            xmax = xmin + 1.0;
        }
        if !ymin.is_finite() || ymin == ymax {
            ymax = ymin + 1.0;
        }
        let mut grid = vec![vec![' '; width]; height];
        for (si, s) in self.series.iter().enumerate() {
            let mark = marks[si % marks.len()];
            for (&x, &y) in s.xs.iter().zip(&s.ys) {
                if !x.is_finite() || !y.is_finite() {
                    continue;
                }
                let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round()
                    as usize;
                let cy = ((tf(y) - ymin) / (ymax - ymin) * (height - 1) as f64)
                    .round() as usize;
                let row = height - 1 - cy.min(height - 1);
                grid[row][cx.min(width - 1)] = mark;
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let ylab = if self.logy {
            format!("log10({})", self.ylabel)
        } else {
            self.ylabel.clone()
        };
        let _ = writeln!(out, "y: {ylab}  [{ymin:.3} .. {ymax:.3}]");
        for row in &grid {
            let _ = writeln!(out, "|{}|", row.iter().collect::<String>());
        }
        let _ = writeln!(
            out,
            " x: {}  [{:.4} .. {:.4}]",
            self.xlabel, xmin, xmax
        );
        for (si, s) in self.series.iter().enumerate() {
            let _ = writeln!(out, "  {} = {}", marks[si % marks.len()], s.name);
        }
        out
    }

    /// CSV: long format `series,x,y` — one file regenerates one figure.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("series,x,y\n");
        for s in &self.series {
            for (&x, &y) in s.xs.iter().zip(&s.ys) {
                let _ = writeln!(out, "{},{},{}", s.name, x, y);
            }
        }
        out
    }

    /// Write CSV under dir, creating it; returns the path.
    pub fn save_csv(&self, dir: &std::path::Path, stem: &str) -> anyhow::Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{stem}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig() -> Figure {
        let mut f = Figure::new("test", "iter", "relerr", true);
        let mut s = Series::new("solver-a");
        for i in 0..10 {
            s.push(i as f64, 10f64.powi(-i));
        }
        f.add(s);
        f
    }

    #[test]
    fn series_threshold_crossing() {
        let f = fig();
        assert_eq!(f.series[0].x_at_y_below(1e-5), Some(5.0));
        assert_eq!(f.series[0].x_at_y_below(1e-20), None);
    }

    #[test]
    fn ascii_contains_marks_and_legend() {
        let art = fig().ascii(40, 10);
        assert!(art.contains('*'));
        assert!(art.contains("solver-a"));
        assert!(art.contains("log10"));
    }

    #[test]
    fn csv_roundtrip_rows() {
        let csv = fig().to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 11); // header + 10 points
        assert_eq!(lines[0], "series,x,y");
        assert!(lines[1].starts_with("solver-a,0,"));
    }

    #[test]
    fn ascii_handles_degenerate_ranges() {
        let mut f = Figure::new("flat", "x", "y", false);
        let mut s = Series::new("flat");
        s.push(1.0, 2.0);
        f.add(s);
        let art = f.ascii(20, 5);
        assert!(art.contains("flat"));
    }

    #[test]
    fn ascii_skips_nonfinite() {
        let mut f = Figure::new("nan", "x", "y", true);
        let mut s = Series::new("n");
        s.push(0.0, f64::NAN);
        s.push(1.0, 1.0);
        s.push(2.0, 0.1);
        f.add(s);
        let _ = f.ascii(20, 5); // must not panic
    }
}
