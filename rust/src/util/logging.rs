//! Leveled stderr logger with monotonic timestamps.
//!
//! Level is controlled by `HDPW_LOG` (error|warn|info|debug|trace) or
//! programmatically; default `info`. Kept dependency-free and allocation-
//! light: the hot solver loops log only at debug/trace.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log severity, ordered most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable or surprising failures.
    Error = 0,
    /// Degraded-but-continuing conditions.
    Warn = 1,
    /// Normal operational landmarks (default level).
    Info = 2,
    /// Per-job diagnostics.
    Debug = 3,
    /// Per-iteration firehose.
    Trace = 4,
}

impl Level {
    /// Parse a level name (case-insensitive); `None` on unknown names.
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    /// Fixed-width tag for log lines.
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // info
static INIT: std::sync::Once = std::sync::Once::new();
static mut START: Option<Instant> = None;

fn start_instant() -> Instant {
    unsafe {
        INIT.call_once(|| {
            START = Some(Instant::now());
        });
        #[allow(static_mut_refs)]
        START.unwrap()
    }
}

/// Initialise from the environment; call once at program start.
pub fn init_from_env() {
    let _ = start_instant();
    if let Ok(v) = std::env::var("HDPW_LOG") {
        if let Some(l) = Level::from_str(&v) {
            set_level(l);
        }
    }
}

/// Set the process log level.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// The current process log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Whether messages at level `l` are currently emitted.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Emit one log line (used via the `log_*` macros, which add module paths).
pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = start_instant().elapsed().as_secs_f64();
    eprintln!("[{t:10.4}s {} {module}] {msg}", l.tag());
}

/// Log at info level with the caller's module path.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at warn level with the caller's module path.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at debug level with the caller's module path.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("info"), Some(Level::Info));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("bogus"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }

    #[test]
    fn log_does_not_panic() {
        log(Level::Error, "test", format_args!("x = {}", 42));
    }
}
