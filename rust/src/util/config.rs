//! TOML-subset config parser + typed experiment configuration.
//!
//! The coordinator and benches are driven by small config files
//! (`configs/*.toml` style). We support the subset of TOML a config actually
//! uses: `[section]` / `[section.sub]` headers, `key = value` with string,
//! integer, float, boolean and flat-array values, comments and blank lines.

use std::collections::BTreeMap;
use std::fmt;

/// A typed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// An integer (underscore separators allowed).
    Int(i64),
    /// A float.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A flat array of values.
    Arr(Vec<Value>),
}

impl Value {
    /// The string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The number as f64 (ints coerce).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// The integer, if this is an `Int`.
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// The integer as usize, if non-negative.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    /// The boolean, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse error with the 1-based source line.
#[derive(Debug, Clone)]
pub struct ConfigError {
    /// 1-based line number where parsing failed.
    pub line: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

/// Parsed config: dotted-path -> value (e.g. `"dataset.n"`).
#[derive(Clone, Debug, Default)]
pub struct Config {
    /// Every `key = value`, keyed by its dotted section path.
    pub entries: BTreeMap<String, Value>,
}

impl Config {
    /// Parse config text (see module docs for the accepted subset).
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| ConfigError {
                line: lineno + 1,
                msg: msg.to_string(),
            };
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(err("unterminated section header"));
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(err("empty section name"));
                }
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| err("expected key = value"))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(val.trim()).map_err(|m| err(&m))?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.insert(path, value);
        }
        Ok(Config { entries })
    }

    /// Read and parse a config file.
    pub fn load(path: &std::path::Path) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)?;
        Ok(Config::parse(&text)?)
    }

    /// Look up a value by dotted path (e.g. `"dataset.n"`).
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    /// String at `path`, or `default` when absent/mistyped.
    pub fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path)
            .and_then(Value::as_str)
            .unwrap_or(default)
            .to_string()
    }

    /// usize at `path`, or `default` when absent/mistyped.
    pub fn usize_or(&self, path: &str, default: usize) -> usize {
        self.get(path).and_then(Value::as_usize).unwrap_or(default)
    }

    /// f64 at `path` (ints coerce), or `default` when absent/mistyped.
    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_f64).unwrap_or(default)
    }

    /// bool at `path`, or `default` when absent/mistyped.
    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    /// usize array at `path` (non-usize elements dropped), or `default`.
    pub fn usize_list(&self, path: &str, default: &[usize]) -> Vec<usize> {
        self.get(path)
            .and_then(Value::as_arr)
            .map(|a| a.iter().filter_map(Value::as_usize).collect())
            .unwrap_or_else(|| default.to_vec())
    }
}

fn strip_comment(line: &str) -> &str {
    // a '#' inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            return Err("unterminated string".into());
        }
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err("unterminated array".into());
        }
        let inner = s[1..s.len() - 1].trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let mut items = Vec::new();
        for part in inner.split(',') {
            items.push(parse_value(part.trim())?);
        }
        return Ok(Value::Arr(items));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(x) = s.parse::<f64>() {
        return Ok(Value::Float(x));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig1"         # inline comment
seed = 42

[dataset]
n = 100_000
d = 20
kappa = 1e8
normalize = true

[solver]
batch_sizes = [1, 2, 4, 8]
eta = 0.5
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("name", ""), "fig1");
        assert_eq!(c.usize_or("seed", 0), 42);
        assert_eq!(c.usize_or("dataset.n", 0), 100_000);
        assert_eq!(c.f64_or("dataset.kappa", 0.0), 1e8);
        assert!(c.bool_or("dataset.normalize", false));
        assert_eq!(c.usize_list("solver.batch_sizes", &[]), vec![1, 2, 4, 8]);
        assert_eq!(c.f64_or("solver.eta", 0.0), 0.5);
    }

    #[test]
    fn defaults_for_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("nope", 3), 3);
        assert_eq!(c.str_or("nope", "x"), "x");
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let c = Config::parse("s = \"a#b\"").unwrap();
        assert_eq!(c.str_or("s", ""), "a#b");
    }

    #[test]
    fn reports_line_numbers() {
        let err = Config::parse("ok = 1\nbad line\n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("k = ").is_err());
        assert!(Config::parse("k = [1, 2").is_err());
        assert!(Config::parse("k = \"oops").is_err());
    }

    #[test]
    fn ints_vs_floats() {
        let c = Config::parse("a = 3\nb = 3.0").unwrap();
        assert_eq!(c.get("a"), Some(&Value::Int(3)));
        assert_eq!(c.get("b"), Some(&Value::Float(3.0)));
        assert_eq!(c.f64_or("a", 0.0), 3.0); // int coerces to f64
    }
}
