//! Timing and summary statistics for the bench harness and coordinator
//! metrics. `BenchStats` implements the criterion-style protocol used by all
//! `rust/benches/*`: warmup, timed repetitions, robust summaries.

use std::time::{Duration, Instant};

/// Online mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    /// Number of samples seen.
    pub count: usize,
    mean: f64,
    m2: f64,
    /// Smallest sample (`+inf` when empty).
    pub min: f64,
    /// Largest sample (`-inf` when empty).
    pub max: f64,
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Self {
        Summary {
            count: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one sample into the summary.
    pub fn add(&mut self, x: f64) {
        self.count += 1;
        let delta = x - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Running mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance (0 for fewer than two samples).
    pub fn var(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            self.m2 / (self.count - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile over a copy of the samples (nearest-rank).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// 50th percentile (nearest-rank).
pub fn median(samples: &[f64]) -> f64 {
    percentile(samples, 50.0)
}

/// A single timed region.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer {
            start: Instant::now(),
        }
    }

    /// Time since `start()`.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Time since `start()` in seconds.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Criterion-style micro-bench runner: warms up, then runs timed reps and
/// reports median/mean/std. Used by `rust/benches/kernels.rs` and the
/// experiment drivers for preconditioning-cost tables.
pub struct BenchStats {
    /// Bench label (printed in reports).
    pub name: String,
    /// Per-repetition wall times in seconds.
    pub samples_secs: Vec<f64>,
}

impl BenchStats {
    /// Run `f` `warmup` untimed times, then `reps` timed repetitions.
    pub fn run<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> Self {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t = Timer::start();
            f();
            samples.push(t.secs());
        }
        BenchStats {
            name: name.to_string(),
            samples_secs: samples,
        }
    }

    /// Median repetition time in seconds.
    pub fn median_secs(&self) -> f64 {
        median(&self.samples_secs)
    }

    /// Mean repetition time in seconds.
    pub fn mean_secs(&self) -> f64 {
        let mut s = Summary::new();
        for &x in &self.samples_secs {
            s.add(x);
        }
        s.mean()
    }

    /// Standard deviation of repetition times in seconds.
    pub fn std_secs(&self) -> f64 {
        let mut s = Summary::new();
        for &x in &self.samples_secs {
            s.add(x);
        }
        s.std()
    }

    /// One-line human report: median / mean ± std / rep count.
    pub fn report(&self) -> String {
        format!(
            "{:<44} median {:>10} mean {:>10} +-{:>9} ({} reps)",
            self.name,
            fmt_duration(self.median_secs()),
            fmt_duration(self.mean_secs()),
            fmt_duration(self.std_secs()),
            self.samples_secs.len(),
        )
    }
}

/// Human duration: picks ns/us/ms/s.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_direct_computation() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert_eq!(s.count, 5);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let direct_var =
            xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((s.var() - direct_var).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 10.0);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn bench_runs_expected_reps() {
        let mut n = 0;
        let b = BenchStats::run("noop", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(b.samples_secs.len(), 5);
        assert!(b.median_secs() >= 0.0);
    }

    #[test]
    fn fmt_duration_scales() {
        assert!(fmt_duration(2.5e-9).ends_with("ns"));
        assert!(fmt_duration(2.5e-6).ends_with("us"));
        assert!(fmt_duration(2.5e-3).ends_with("ms"));
        assert!(fmt_duration(2.5).ends_with('s'));
    }
}
