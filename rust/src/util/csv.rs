//! Small CSV reader/writer (RFC-4180 subset: quoted fields, embedded commas
//! and newlines in quotes). Used for dataset import/export and the figure
//! series emitted by the benches.

/// Parse CSV text into rows of fields.
pub fn parse(text: &str) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    let mut row = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;
    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => {
                    row.push(std::mem::take(&mut field));
                }
                '\r' => {}
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                c => field.push(c),
            }
        }
    }
    if any && (!field.is_empty() || !row.is_empty()) {
        row.push(field);
        rows.push(row);
    }
    rows
}

/// Escape a field if needed and append.
fn write_field(out: &mut String, f: &str) {
    if f.contains([',', '"', '\n', '\r']) {
        out.push('"');
        for c in f.chars() {
            if c == '"' {
                out.push('"');
            }
            out.push(c);
        }
        out.push('"');
    } else {
        out.push_str(f);
    }
}

/// Serialize rows to CSV text.
pub fn write(rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    for row in rows {
        for (i, f) in row.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            write_field(&mut out, f);
        }
        out.push('\n');
    }
    out
}

/// Parse a numeric CSV (optionally skipping a header row) into an
/// (n_rows, n_cols, data) triple in row-major order. Non-numeric rows error.
pub fn parse_numeric(text: &str, skip_header: bool) -> anyhow::Result<(usize, usize, Vec<f64>)> {
    let rows = parse(text);
    let start = usize::from(skip_header);
    let mut data = Vec::new();
    let mut cols = 0usize;
    let mut n = 0usize;
    for (ri, row) in rows.iter().enumerate().skip(start) {
        if row.len() == 1 && row[0].trim().is_empty() {
            continue;
        }
        if cols == 0 {
            cols = row.len();
        } else if row.len() != cols {
            anyhow::bail!("row {ri}: expected {cols} fields, got {}", row.len());
        }
        for f in row {
            data.push(
                f.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("row {ri}: bad number {f:?}"))?,
            );
        }
        n += 1;
    }
    Ok((n, cols, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simple_rows() {
        let rows = parse("a,b,c\n1,2,3\n");
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], vec!["a", "b", "c"]);
        assert_eq!(rows[1], vec!["1", "2", "3"]);
    }

    #[test]
    fn quoted_fields() {
        let rows = parse("\"a,b\",\"say \"\"hi\"\"\",\"multi\nline\"\n");
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0][0], "a,b");
        assert_eq!(rows[0][1], "say \"hi\"");
        assert_eq!(rows[0][2], "multi\nline");
    }

    #[test]
    fn missing_trailing_newline() {
        let rows = parse("x,y");
        assert_eq!(rows, vec![vec!["x".to_string(), "y".to_string()]]);
    }

    #[test]
    fn roundtrip() {
        let rows = vec![
            vec!["plain".to_string(), "with,comma".to_string()],
            vec!["with \"quote\"".to_string(), "3.14".to_string()],
        ];
        let text = write(&rows);
        assert_eq!(parse(&text), rows);
    }

    #[test]
    fn numeric_parse_with_header() {
        let (n, c, data) = parse_numeric("x,y\n1,2\n3,4\n", true).unwrap();
        assert_eq!((n, c), (2, 2));
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn numeric_parse_rejects_ragged_and_nonnumeric() {
        assert!(parse_numeric("1,2\n3\n", false).is_err());
        assert!(parse_numeric("1,abc\n", false).is_err());
    }

    #[test]
    fn crlf_handled() {
        let rows = parse("a,b\r\nc,d\r\n");
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1], vec!["c", "d"]);
    }
}
