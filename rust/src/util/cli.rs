//! Tiny declarative CLI argument parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands; generates usage text from the declared options.

use std::collections::BTreeMap;

/// One declared option (`--name` or `--name <value>`).
#[derive(Clone, Debug)]
pub struct OptSpec {
    /// Long option name (without the `--`).
    pub name: &'static str,
    /// One-line help shown in usage text.
    pub help: &'static str,
    /// Whether the option consumes a value (`--key v` / `--key=v`).
    pub takes_value: bool,
    /// Default shown in help (informational; accessors carry the real one).
    pub default: Option<&'static str>,
}

/// Parsed arguments: option values + positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Non-option arguments, in order.
    pub positional: Vec<String>,
}

impl Args {
    /// The raw value of `--name`, if given.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// The value of `--name`, or `default` when absent.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Parse `--name` as usize; exits with a usage error on bad input.
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    eprintln!("invalid value for --{name}: {v}");
                    std::process::exit(2);
                })
            })
            .unwrap_or(default)
    }

    /// Parse `--name` as f64; exits with a usage error on bad input.
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    eprintln!("invalid value for --{name}: {v}");
                    std::process::exit(2);
                })
            })
            .unwrap_or(default)
    }

    /// Parse `--name` as u64; exits with a usage error on bad input.
    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    eprintln!("invalid value for --{name}: {v}");
                    std::process::exit(2);
                })
            })
            .unwrap_or(default)
    }

    /// Whether the boolean flag `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Command definition: declared options + parser.
pub struct Command {
    /// Subcommand name (for usage text).
    pub name: &'static str,
    /// One-line description.
    pub about: &'static str,
    /// Declared options, in declaration order.
    pub opts: Vec<OptSpec>,
}

impl Command {
    /// Start a command definition (builder style).
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    /// Declare a value-taking option `--name <v>`.
    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: true,
            default: None,
        });
        self
    }

    /// Declare a boolean flag `--name`.
    pub fn flag_opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    /// Render the usage/help text from the declared options.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let arg = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            s.push_str(&format!("  {arg:<24} {}\n", o.help));
        }
        s
    }

    /// Parse a raw argv slice (without the program/subcommand name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                if key == "help" {
                    return Err(self.usage());
                }
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} needs a value"))?
                        }
                    };
                    out.values.insert(key, val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{key} takes no value"));
                    }
                    out.flags.push(key);
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("solve", "solve a regression job")
            .opt("dataset", "dataset name")
            .opt("eps", "target accuracy")
            .flag_opt("verbose", "chatty output")
    }

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value_both_styles() {
        let a = cmd()
            .parse(&argv(&["--dataset", "syn1", "--eps=0.01"]))
            .unwrap();
        assert_eq!(a.get("dataset"), Some("syn1"));
        assert_eq!(a.get_f64("eps", 1.0), 0.01);
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = cmd().parse(&argv(&["pos1", "--verbose", "pos2"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn rejects_unknown_and_missing_value() {
        assert!(cmd().parse(&argv(&["--nope"])).is_err());
        assert!(cmd().parse(&argv(&["--eps"])).is_err());
        assert!(cmd().parse(&argv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("dataset", "syn2"), "syn2");
    }

    #[test]
    fn help_yields_usage() {
        let err = cmd().parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("solve"));
        assert!(err.contains("--dataset"));
    }
}
