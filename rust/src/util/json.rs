//! Minimal recursive-descent JSON parser and writer.
//!
//! Used to read `artifacts/manifest.json` (written by `python/compile/aot.py`)
//! and to serialize job specs / results on the coordinator's wire protocol.
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept in sorted order (BTreeMap) so
/// serialization is deterministic — important for golden tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (all numbers parse as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, keys sorted.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing data is an error).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as a usize, if this is a non-negative integer `Num`.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// The string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup; `None` on missing key or non-object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj.field` access that errors with the path, for manifest parsing.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing key {key:?}"),
            pos: 0,
        })
    }

    // -- builders -------------------------------------------------------------

    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Build a number value.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

/// Parse error with the byte offset where parsing failed.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset into the input (0 for structural errors like missing keys).
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy the remaining continuation bytes
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("bad utf8"))?;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    /// Compact serialization (no whitespace), deterministic key order.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"n": 8192, "ops": [{"name": "x", "inputs": [[1,2],[3]]}], "f": 0.5}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), v);
    }

    #[test]
    fn as_usize_rejects_fractions() {
        assert_eq!(Json::parse("4").unwrap().as_usize(), Some(4));
        assert_eq!(Json::parse("4.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-4").unwrap().as_usize(), None);
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" \n\t{ \"a\" :\t1 , \"b\" : [ ] }\r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 0);
    }
}
