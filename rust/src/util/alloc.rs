//! 64-byte-aligned `f64` buffers — the shared allocation helper behind
//! [`crate::linalg::Mat`].
//!
//! Every dense matrix in the crate (including the padded `[A | b]` FWHT
//! buffers built by `hstack_col_padded` / `pad_rows`, which route through
//! `Mat::zeros` / this type's `resize`) is backed by an [`AlignedBuf`], so
//! SIMD kernel loads start on a cache-line boundary and never straddle one
//! at row starts for lane-multiple widths. The type is deliberately tiny:
//! it derefs to `[f64]` and the rest of the crate treats it as a slice.
//!
//! A `Vec<f64>` cannot guarantee this: `std::alloc` only promises the
//! allocation is aligned to `align_of::<f64>()` (8). Reconstructing a `Vec`
//! over an over-aligned allocation would be UB on drop (the deallocation
//! `Layout` must match the allocation's), hence a dedicated owner type with
//! matching alloc/dealloc layouts.

use std::alloc::{alloc, alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ptr::NonNull;

/// Cache-line alignment used for every buffer (bytes).
pub const ALIGN: usize = 64;

/// An owned, 64-byte-aligned `f64` buffer that derefs to `[f64]`.
///
/// Semantically a fixed-capacity `Vec<f64>` restricted to the operations
/// the matrix layer needs (`truncate`, `resize`, slicing via `Deref`).
pub struct AlignedBuf {
    ptr: NonNull<f64>,
    len: usize,
    cap: usize,
}

// SAFETY: the buffer exclusively owns its allocation and the payload is
// plain `f64`; moving or sharing it across threads is as safe as for
// `Vec<f64>`.
unsafe impl Send for AlignedBuf {}
// SAFETY: as above — `&AlignedBuf` only exposes `&[f64]`.
unsafe impl Sync for AlignedBuf {}

fn layout_for(cap: usize) -> Layout {
    Layout::from_size_align(cap * std::mem::size_of::<f64>(), ALIGN)
        .expect("aligned buffer layout overflow")
}

fn alloc_cap(cap: usize, zeroed: bool) -> NonNull<f64> {
    if cap == 0 {
        // zero-size layouts may not be passed to the allocator
        return NonNull::dangling();
    }
    let layout = layout_for(cap);
    // SAFETY: `layout` has non-zero size (cap > 0) and valid 64-byte
    // alignment; a null return is routed to `handle_alloc_error`.
    let raw = unsafe {
        if zeroed {
            alloc_zeroed(layout)
        } else {
            alloc(layout)
        }
    };
    match NonNull::new(raw as *mut f64) {
        Some(p) => p,
        None => handle_alloc_error(layout),
    }
}

impl AlignedBuf {
    /// A zero-filled buffer of `len` elements.
    pub fn zeroed(len: usize) -> AlignedBuf {
        AlignedBuf {
            ptr: alloc_cap(len, true),
            len,
            cap: len,
        }
    }

    /// Copy a slice into a fresh aligned buffer.
    pub fn from_slice(src: &[f64]) -> AlignedBuf {
        let ptr = alloc_cap(src.len(), false);
        // SAFETY: `ptr` was just allocated with capacity `src.len()` and the
        // ranges cannot overlap (fresh allocation).
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr(), ptr.as_ptr(), src.len());
        }
        AlignedBuf {
            ptr,
            len: src.len(),
            cap: src.len(),
        }
    }

    /// Move a `Vec` into an aligned buffer (copies: the `Vec`'s allocation
    /// cannot be re-aligned in place).
    pub fn from_vec(src: Vec<f64>) -> AlignedBuf {
        AlignedBuf::from_slice(&src)
    }

    /// Number of elements.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the buffer holds no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Shorten to `len` elements (no-op if already shorter). Capacity is
    /// kept, mirroring `Vec::truncate`.
    pub fn truncate(&mut self, len: usize) {
        if len < self.len {
            self.len = len;
        }
    }

    /// Resize to `new_len`, filling any new tail with `fill`. Grows by
    /// reallocating (the buffer is not amortized — matrix shapes are fixed
    /// at construction; `resize` exists for the pad-rows path).
    pub fn resize(&mut self, new_len: usize, fill: f64) {
        if new_len <= self.len {
            self.len = new_len;
            return;
        }
        if new_len <= self.cap {
            for i in self.len..new_len {
                // SAFETY: `i < cap`, so the write is within the allocation.
                unsafe { self.ptr.as_ptr().add(i).write(fill) };
            }
            self.len = new_len;
            return;
        }
        let ptr = alloc_cap(new_len, false);
        // SAFETY: both regions are valid for `self.len` elements and the
        // destination is a fresh allocation (no overlap); the tail writes
        // stay below `new_len`.
        unsafe {
            std::ptr::copy_nonoverlapping(self.ptr.as_ptr(), ptr.as_ptr(), self.len);
            for i in self.len..new_len {
                ptr.as_ptr().add(i).write(fill);
            }
        }
        let old = std::mem::replace(
            self,
            AlignedBuf {
                ptr,
                len: new_len,
                cap: new_len,
            },
        );
        drop(old);
    }

    /// Copy out to a plain `Vec`.
    pub fn to_vec(&self) -> Vec<f64> {
        self[..].to_vec()
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        if self.cap > 0 {
            // SAFETY: `ptr` was allocated via `alloc_cap` with exactly
            // `layout_for(self.cap)` and has not been freed.
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, layout_for(self.cap)) };
        }
    }
}

impl std::ops::Deref for AlignedBuf {
    type Target = [f64];
    #[inline]
    fn deref(&self) -> &[f64] {
        // SAFETY: `ptr` is valid for `len <= cap` initialized elements (all
        // constructors initialize `..len`).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl std::ops::DerefMut for AlignedBuf {
    #[inline]
    fn deref_mut(&mut self) -> &mut [f64] {
        // SAFETY: as in `deref`, plus `&mut self` guarantees uniqueness.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl Clone for AlignedBuf {
    fn clone(&self) -> AlignedBuf {
        AlignedBuf::from_slice(self)
    }
}

impl std::fmt::Debug for AlignedBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        std::fmt::Debug::fmt(&self[..], f)
    }
}

impl PartialEq for AlignedBuf {
    fn eq(&self, other: &AlignedBuf) -> bool {
        self[..] == other[..]
    }
}

impl PartialEq<Vec<f64>> for AlignedBuf {
    fn eq(&self, other: &Vec<f64>) -> bool {
        self[..] == other[..]
    }
}

impl PartialEq<AlignedBuf> for Vec<f64> {
    fn eq(&self, other: &AlignedBuf) -> bool {
        self[..] == other[..]
    }
}

impl PartialEq<[f64]> for AlignedBuf {
    fn eq(&self, other: &[f64]) -> bool {
        self[..] == *other
    }
}

impl<'a> IntoIterator for &'a AlignedBuf {
    type Item = &'a f64;
    type IntoIter = std::slice::Iter<'a, f64>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter()
    }
}

impl<'a> IntoIterator for &'a mut AlignedBuf {
    type Item = &'a mut f64;
    type IntoIter = std::slice::IterMut<'a, f64>;
    fn into_iter(self) -> Self::IntoIter {
        self.iter_mut()
    }
}

impl From<Vec<f64>> for AlignedBuf {
    fn from(v: Vec<f64>) -> AlignedBuf {
        AlignedBuf::from_vec(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_is_64_bytes() {
        for len in [1usize, 7, 64, 1000] {
            let b = AlignedBuf::zeroed(len);
            assert_eq!(b.as_ptr() as usize % ALIGN, 0, "len {len}");
            assert!(b.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn zero_len_is_valid() {
        let mut b = AlignedBuf::zeroed(0);
        assert!(b.is_empty());
        assert_eq!(&b[..], &[] as &[f64]);
        b.resize(3, 1.5);
        assert_eq!(b, vec![1.5, 1.5, 1.5]);
    }

    #[test]
    fn slice_ops_work_through_deref() {
        let mut b = AlignedBuf::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        b.copy_within(0..2, 2);
        assert_eq!(b, vec![1.0, 2.0, 1.0, 2.0]);
        b[0] = 9.0;
        assert_eq!(b.iter().sum::<f64>(), 14.0);
    }

    #[test]
    fn truncate_resize_roundtrip() {
        let mut b = AlignedBuf::from_vec(vec![1.0, 2.0, 3.0]);
        b.truncate(2);
        assert_eq!(b, vec![1.0, 2.0]);
        // regrow within capacity fills with the given value
        b.resize(3, 7.0);
        assert_eq!(b, vec![1.0, 2.0, 7.0]);
        // grow past capacity reallocates, still aligned
        b.resize(100, 0.5);
        assert_eq!(b.len(), 100);
        assert_eq!(b.as_ptr() as usize % ALIGN, 0);
        assert_eq!(b[99], 0.5);
        assert_eq!(b[0], 1.0);
    }

    #[test]
    fn clone_and_eq() {
        let b = AlignedBuf::from_slice(&[1.0, 2.0]);
        let c = b.clone();
        assert_eq!(b, c);
        assert_ne!(b.as_ptr(), c.as_ptr());
    }
}
