//! Sparse l2 embedding (OSNAP-style): each input row is hashed into `k`
//! output rows with signs, scaled by 1/sqrt(k). With k = O(log d) this gives
//! an oblivious subspace embedding in O(nnz(A) log d) time (Table 2's
//! "Sparse l2 Embedding" row) with better-behaved constants than
//! CountSketch's single hash.

use super::{RowOps, Sketch};
use crate::data::blocks::{CsrBlock, RowBlock};
use crate::linalg::{CsrMat, Mat};
use crate::util::rng::Rng;

/// A sampled sparse l2 embedding: `k` distinct hashed buckets with signs
/// per input row, scaled by `1/sqrt(k)`.
pub struct SparseEmbed {
    s: usize,
    k: usize,
    /// k target rows per input row (n * k entries)
    buckets: Vec<u32>,
    /// matching signs
    signs: Vec<f64>,
}

impl SparseEmbed {
    /// Sample an embedding with `s` output rows, `n` input rows and an
    /// explicit per-row bucket count `k` (requires `1 <= k <= s`).
    pub fn new_with_k(s: usize, n: usize, k: usize, rng: &mut Rng) -> Self {
        assert!(k >= 1 && s >= k);
        let mut buckets = Vec::with_capacity(n * k);
        let signs = rng.signs(n * k);
        // sample k distinct buckets per row (rejection; k << s)
        let mut scratch: Vec<u32> = Vec::with_capacity(k);
        for _ in 0..n {
            scratch.clear();
            while scratch.len() < k {
                let c = rng.below(s) as u32;
                if !scratch.contains(&c) {
                    scratch.push(c);
                }
            }
            buckets.extend_from_slice(&scratch);
        }
        SparseEmbed {
            s,
            k,
            buckets,
            signs,
        }
    }

    /// Sample an embedding with the default `k ~ log2(s)` (clamped to
    /// `[2, 8]`) — the O(log d) sparsity Table 2 assumes.
    pub fn new(s: usize, n: usize, rng: &mut Rng) -> Self {
        // k ~ log2(s), clamped
        let k = (s as f64).log2().ceil().max(2.0) as usize;
        let k = k.min(8).min(s);
        Self::new_with_k(s, n, k, rng)
    }
}

impl Sketch for SparseEmbed {
    fn rows(&self) -> usize {
        self.s
    }

    fn apply(&self, a: &Mat) -> Mat {
        assert_eq!(a.rows * self.k, self.buckets.len());
        let mut out = Mat::zeros(self.s, a.cols);
        let scale = 1.0 / (self.k as f64).sqrt();
        for i in 0..a.rows {
            let row = a.row(i);
            for t in 0..self.k {
                let dst = self.buckets[i * self.k + t] as usize;
                let sg = self.signs[i * self.k + t] * scale;
                let orow = out.row_mut(dst);
                for (o, v) in orow.iter_mut().zip(row) {
                    *o += sg * v;
                }
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "sparse_embed"
    }

    /// Streaming fold: every input row scatters into its k private buckets,
    /// so shards contribute independently, same as CountSketch. Runs the
    /// scalar row kernels — bit-identical to the historical loop.
    fn apply_block(
        &self,
        block: &RowBlock<'_>,
        acc: &mut Mat,
    ) -> Result<(), crate::sketch::StreamUnsupported> {
        self.apply_block_with(block, acc, &RowOps::SCALAR)
    }

    /// The real fold, parameterized by the executor's row-scatter kernels:
    /// the per-bucket scatter is one `axpy` with coefficient `sign/sqrt(k)`.
    /// `RowOps::SCALAR` replays the historical mul-then-add loop exactly;
    /// an FMA kernel set differs by one rounding per element
    /// (tolerance-gated in the parity suite).
    fn apply_block_with(
        &self,
        block: &RowBlock<'_>,
        acc: &mut Mat,
        ops: &RowOps,
    ) -> Result<(), crate::sketch::StreamUnsupported> {
        assert_eq!(acc.rows, self.s);
        assert_eq!(acc.cols, block.cols);
        let scale = 1.0 / (self.k as f64).sqrt();
        for kk in 0..block.rows {
            let i = block.global_row(kk);
            let row = block.row(kk);
            for t in 0..self.k {
                let dst = self.buckets[i * self.k + t] as usize;
                let sg = self.signs[i * self.k + t] * scale;
                let orow = acc.row_mut(dst);
                (ops.axpy)(orow, sg, row);
            }
        }
        Ok(())
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    /// O(nnz(A) * k) on CSR — Table 2's O(nnz log d) with k = O(log d):
    /// every stored entry scatters into its row's k buckets. Delegates to
    /// the shard fold over the whole matrix (one scatter loop to maintain).
    fn apply_csr(&self, a: &CsrMat) -> Mat {
        assert_eq!(a.rows * self.k, self.buckets.len());
        let mut out = Mat::zeros(self.s, a.cols);
        self.apply_csr_block(&CsrBlock::whole(a), &mut out)
            .expect("sparse embedding streams CSR");
        out
    }

    /// Streaming CSR fold: same scatter through global row indices.
    fn apply_csr_block(
        &self,
        block: &CsrBlock<'_>,
        acc: &mut Mat,
    ) -> Result<(), crate::sketch::StreamUnsupported> {
        assert_eq!(acc.rows, self.s);
        assert_eq!(acc.cols, block.cols());
        let scale = 1.0 / (self.k as f64).sqrt();
        for kk in 0..block.rows {
            let i = block.global_row(kk);
            let (cols, vals) = block.row(kk);
            for t in 0..self.k {
                let dst = self.buckets[i * self.k + t] as usize;
                let sg = self.signs[i * self.k + t] * scale;
                let orow = acc.row_mut(dst);
                for (c, v) in cols.iter().zip(vals) {
                    orow[*c as usize] += sg * v;
                }
            }
        }
        Ok(())
    }

    fn supports_csr_streaming(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;

    #[test]
    fn shape_and_k_buckets_per_row() {
        let mut rng = Rng::new(1);
        let se = SparseEmbed::new_with_k(32, 10, 3, &mut rng);
        assert_eq!(se.buckets.len(), 30);
        // distinct buckets within each row
        for i in 0..10 {
            let b = &se.buckets[i * 3..(i + 1) * 3];
            assert_ne!(b[0], b[1]);
            assert_ne!(b[1], b[2]);
            assert_ne!(b[0], b[2]);
        }
        let a = Mat::gaussian(10, 2, &mut rng);
        let sa = se.apply(&a);
        assert_eq!((sa.rows, sa.cols), (32, 2));
    }

    #[test]
    fn single_row_spreads_mass_with_unit_norm() {
        let mut rng = Rng::new(2);
        let se = SparseEmbed::new_with_k(16, 1, 4, &mut rng);
        let a = Mat::from_vec(1, 1, vec![1.0]);
        let sa = se.apply(&a);
        let total_sq: f64 = sa.data.iter().map(|v| v * v).sum();
        assert!((total_sq - 1.0).abs() < 1e-12); // k * (1/sqrt(k))^2 = 1
    }

    #[test]
    fn norm_preserved_in_expectation() {
        let mut rng = Rng::new(3);
        let a = Mat::gaussian(128, 4, &mut rng);
        let x = rng.gaussians(4);
        let ax = blas::gemv(&a, &x);
        let target: f64 = ax.iter().map(|v| v * v).sum();
        let mut acc = 0.0;
        let trials = 100;
        for _ in 0..trials {
            let se = SparseEmbed::new(64, 128, &mut rng);
            let sa = se.apply(&a);
            let sax = blas::gemv(&sa, &x);
            acc += sax.iter().map(|v| v * v).sum::<f64>();
        }
        let mean = acc / trials as f64;
        assert!(
            (mean / target - 1.0).abs() < 0.15,
            "mean {mean} vs target {target}"
        );
    }
}
