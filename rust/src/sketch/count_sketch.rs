//! CountSketch: each input row is hashed to one output row with a random
//! sign. Computing `SA` costs O(nnz(A)) — the fastest construction in
//! Table 2 and the one the paper's own experiments use.

use super::{RowOps, Sketch};
use crate::data::blocks::{CsrBlock, RowBlock};
use crate::linalg::{CsrMat, Mat};
use crate::util::rng::Rng;

/// A sampled CountSketch operator: one hashed bucket and one ±1 sign per
/// input row.
pub struct CountSketch {
    s: usize,
    /// target row for each input row
    bucket: Vec<u32>,
    /// +-1 sign for each input row
    sign: Vec<f64>,
}

impl CountSketch {
    /// Sample a CountSketch with `s` output rows for `n`-row inputs.
    pub fn new(s: usize, n: usize, rng: &mut Rng) -> Self {
        assert!(s > 0 && s <= u32::MAX as usize);
        let bucket = (0..n).map(|_| rng.below(s) as u32).collect();
        let sign = rng.signs(n);
        CountSketch { s, bucket, sign }
    }
}

impl Sketch for CountSketch {
    fn rows(&self) -> usize {
        self.s
    }

    fn apply(&self, a: &Mat) -> Mat {
        assert_eq!(a.rows, self.bucket.len());
        let mut out = Mat::zeros(self.s, a.cols);
        for i in 0..a.rows {
            let dst = self.bucket[i] as usize;
            let sg = self.sign[i];
            let row = a.row(i);
            let orow = out.row_mut(dst);
            if sg > 0.0 {
                for (o, v) in orow.iter_mut().zip(row) {
                    *o += v;
                }
            } else {
                for (o, v) in orow.iter_mut().zip(row) {
                    *o -= v;
                }
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "countsketch"
    }

    /// Streaming fold: each input row touches exactly one bucket, so a shard
    /// contributes its rows' signed sums independently of every other shard.
    /// Runs the scalar row kernels — bit-identical to the historical loop.
    fn apply_block(
        &self,
        block: &RowBlock<'_>,
        acc: &mut Mat,
    ) -> Result<(), crate::sketch::StreamUnsupported> {
        self.apply_block_with(block, acc, &RowOps::SCALAR)
    }

    /// The real fold, parameterized by the executor's row-scatter kernels.
    /// The scatter is pure `+=` / `-=` (no multiply), so *every* kernel set
    /// produces bit-identical output — lanewise add/sub reorders nothing.
    fn apply_block_with(
        &self,
        block: &RowBlock<'_>,
        acc: &mut Mat,
        ops: &RowOps,
    ) -> Result<(), crate::sketch::StreamUnsupported> {
        assert_eq!(acc.rows, self.s);
        assert_eq!(acc.cols, block.cols);
        for k in 0..block.rows {
            let i = block.global_row(k);
            let dst = self.bucket[i] as usize;
            let sg = self.sign[i];
            let row = block.row(k);
            let orow = acc.row_mut(dst);
            if sg > 0.0 {
                (ops.add)(orow, row);
            } else {
                (ops.sub)(orow, row);
            }
        }
        Ok(())
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    /// True O(nnz(A)) on CSR: each stored entry lands in exactly one
    /// accumulator cell — the cost the paper's Table 2 promises, with no
    /// densify step anywhere. One scatter loop exists (the shard fold);
    /// the single pass is the whole matrix as one shard.
    fn apply_csr(&self, a: &CsrMat) -> Mat {
        assert_eq!(a.rows, self.bucket.len());
        let mut out = Mat::zeros(self.s, a.cols);
        self.apply_csr_block(&CsrBlock::whole(a), &mut out)
            .expect("countsketch streams CSR");
        out
    }

    /// Streaming CSR fold: identical scatter, addressed through the shard's
    /// global row indices — O(nnz(shard)).
    fn apply_csr_block(
        &self,
        block: &CsrBlock<'_>,
        acc: &mut Mat,
    ) -> Result<(), crate::sketch::StreamUnsupported> {
        assert_eq!(acc.rows, self.s);
        assert_eq!(acc.cols, block.cols());
        for k in 0..block.rows {
            let i = block.global_row(k);
            let dst = self.bucket[i] as usize;
            let sg = self.sign[i];
            let (cols, vals) = block.row(k);
            let orow = acc.row_mut(dst);
            for (c, v) in cols.iter().zip(vals) {
                orow[*c as usize] += sg * v;
            }
        }
        Ok(())
    }

    fn supports_csr_streaming(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_shape() {
        let mut rng = Rng::new(1);
        let cs = CountSketch::new(16, 100, &mut rng);
        let a = Mat::gaussian(100, 4, &mut rng);
        let sa = cs.apply(&a);
        assert_eq!((sa.rows, sa.cols), (16, 4));
    }

    #[test]
    fn single_row_lands_in_one_bucket_with_sign() {
        let mut rng = Rng::new(2);
        let cs = CountSketch::new(8, 1, &mut rng);
        let a = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let sa = cs.apply(&a);
        let mut nonzero_rows = 0;
        for i in 0..8 {
            let nrm: f64 = sa.row(i).iter().map(|v| v.abs()).sum();
            if nrm > 0.0 {
                nonzero_rows += 1;
                let s = sa.at(i, 0).signum();
                assert_eq!(sa.row(i), &[s * 1.0, s * 2.0, s * 3.0]);
            }
        }
        assert_eq!(nonzero_rows, 1);
    }

    #[test]
    fn linearity() {
        let mut rng = Rng::new(3);
        let cs = CountSketch::new(32, 50, &mut rng);
        let a = Mat::gaussian(50, 3, &mut rng);
        let b = Mat::gaussian(50, 3, &mut rng);
        let mut apb = a.clone();
        for (x, y) in apb.data.iter_mut().zip(&b.data) {
            *x += y;
        }
        let sa = cs.apply(&a);
        let sb = cs.apply(&b);
        let sab = cs.apply(&apb);
        for i in 0..sab.data.len() {
            assert!((sab.data[i] - sa.data[i] - sb.data[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn norm_preserved_in_expectation() {
        // E||SAx||^2 = ||Ax||^2; check the empirical mean over fresh sketches.
        let mut rng = Rng::new(4);
        let a = Mat::gaussian(256, 4, &mut rng);
        let x = rng.gaussians(4);
        let ax = crate::linalg::blas::gemv(&a, &x);
        let target: f64 = ax.iter().map(|v| v * v).sum();
        let mut acc = 0.0;
        let trials = 200;
        for _ in 0..trials {
            let cs = CountSketch::new(64, 256, &mut rng);
            let sa = cs.apply(&a);
            let sax = crate::linalg::blas::gemv(&sa, &x);
            acc += sax.iter().map(|v| v * v).sum::<f64>();
        }
        let mean = acc / trials as f64;
        assert!(
            (mean / target - 1.0).abs() < 0.1,
            "mean {mean} vs target {target}"
        );
    }
}
