//! Oblivious subspace embeddings (sketch matrices) — Algorithm 1, Step 1.
//!
//! A sketch `S in R^{s x n}` satisfies, w.h.p. for all x,
//! `(1-eps)||Ax|| <= ||SAx|| <= (1+eps)||Ax||`. The paper's Table 2 lists
//! four constructions with their costs for computing the preconditioner R;
//! all four are implemented here behind the [`Sketch`] trait:
//!
//! | construction       | time for SA           | module           |
//! |--------------------|------------------------|------------------|
//! | Gaussian           | O(n d^2) (dense gemm)  | [`gaussian`]     |
//! | SRHT               | O(nd log n)            | [`srht`]         |
//! | CountSketch        | O(nnz(A))              | [`count_sketch`] |
//! | Sparse l2 embedding| O(nnz(A) log d)        | [`sparse_embed`] |

pub mod fwht;
pub mod count_sketch;
pub mod gaussian;
pub mod srht;
pub mod sparse_embed;

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// A sampled sketching operator: apply to the (packed) data matrix.
pub trait Sketch {
    /// The sketch output row count `s`.
    fn rows(&self) -> usize;
    /// Compute `S A` for a dense row-major A (n x d) -> (s x d).
    fn apply(&self, a: &Mat) -> Mat;
    /// Name for reports (Table 2 rows).
    fn name(&self) -> &'static str;
}

/// Which sketch construction to use (CLI / config selectable).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SketchKind {
    Gaussian,
    Srht,
    CountSketch,
    SparseEmbed,
}

impl SketchKind {
    pub fn parse(s: &str) -> Option<SketchKind> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" => Some(SketchKind::Gaussian),
            "srht" => Some(SketchKind::Srht),
            "countsketch" | "count_sketch" | "count" => Some(SketchKind::CountSketch),
            "sparse" | "sparse_embed" | "sparse_l2" => Some(SketchKind::SparseEmbed),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SketchKind::Gaussian => "gaussian",
            SketchKind::Srht => "srht",
            SketchKind::CountSketch => "countsketch",
            SketchKind::SparseEmbed => "sparse_embed",
        }
    }

    /// Instantiate a sketch of size s x n.
    pub fn build(self, s: usize, n: usize, rng: &mut Rng) -> Box<dyn Sketch + Send + Sync> {
        match self {
            SketchKind::Gaussian => Box::new(gaussian::GaussianSketch::new(s, n, rng)),
            SketchKind::Srht => Box::new(srht::Srht::new(s, n, rng)),
            SketchKind::CountSketch => Box::new(count_sketch::CountSketch::new(s, n, rng)),
            SketchKind::SparseEmbed => Box::new(sparse_embed::SparseEmbed::new(s, n, rng)),
        }
    }
}

/// Default sketch size for a given d and construction.
///
/// Hash-based sketches (CountSketch, sparse embedding) need s = Omega(d^2)
/// rows for the subspace-embedding property (hence Table 2's O(nnz + d^4)
/// CountSketch cost — the QR on an s x d matrix with s ~ d^2 is d^4);
/// rotation-based sketches (Gaussian, SRHT) need only O(d log d). The
/// paper's Table 3 sketch sizes match: 1000 = 2.5 d^2 for d = 20,
/// 20000 ~ 2.5 d^2 for d = 90.
pub fn default_sketch_size_for(n: usize, d: usize, kind: SketchKind) -> usize {
    let s = match kind {
        SketchKind::CountSketch | SketchKind::SparseEmbed => (5 * d * d / 2).max(20 * d),
        SketchKind::Gaussian | SketchKind::Srht => (20 * d).max(d * d / 8),
    };
    s.clamp(d + 1, n.max(d + 2) - 1)
}

/// Backwards-compatible default assuming a rotation-quality sketch.
pub fn default_sketch_size(n: usize, d: usize) -> usize {
    default_sketch_size_for(n, d, SketchKind::CountSketch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::gemv;

    /// Shared embedding-quality check: for a handful of random x,
    /// ||SAx|| must be within a loose multiplicative band of ||Ax||.
    pub(crate) fn check_embedding(kind: SketchKind, s: usize, n: usize, d: usize, tol: f64) {
        let mut rng = Rng::new(99);
        let a = Mat::gaussian(n, d, &mut rng);
        let sk = kind.build(s, n, &mut rng);
        let sa = sk.apply(&a);
        assert_eq!(sa.rows, s);
        assert_eq!(sa.cols, d);
        for trial in 0..10 {
            let x = rng.gaussians(d);
            let ax = crate::linalg::blas::nrm2(&gemv(&a, &x));
            let sax = crate::linalg::blas::nrm2(&gemv(&sa, &x));
            let ratio = sax / ax;
            assert!(
                (ratio - 1.0).abs() < tol,
                "{} trial {trial}: ratio {ratio} outside 1 +- {tol}",
                kind.name()
            );
        }
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(SketchKind::parse("SRHT"), Some(SketchKind::Srht));
        assert_eq!(SketchKind::parse("countsketch"), Some(SketchKind::CountSketch));
        assert_eq!(SketchKind::parse("nope"), None);
    }

    #[test]
    fn default_size_bounds() {
        let s = default_sketch_size(100_000, 20);
        assert!(s > 20 && s < 100_000);
        // tiny n still yields a valid size
        let s2 = default_sketch_size(64, 20);
        assert!(s2 >= 21 && s2 <= 64);
        // hash sketches need ~d^2; rotations need ~d log d
        let hash = default_sketch_size_for(1_000_000, 90, SketchKind::CountSketch);
        let rot = default_sketch_size_for(1_000_000, 90, SketchKind::Srht);
        assert!(hash >= 90 * 90 * 2, "hash sketch size {hash}");
        assert!(rot < hash, "srht {rot} should need fewer rows than countsketch {hash}");
        // paper's Table 3: d=90 -> sketch 20000; ours is the same scale
        assert!((hash as f64 / 20_000.0) < 2.0 && (hash as f64 / 20_000.0) > 0.5);
    }

    #[test]
    fn all_kinds_embed_gaussian_data() {
        // loose tolerance: these are probabilistic structures
        check_embedding(SketchKind::Gaussian, 400, 2048, 8, 0.35);
        check_embedding(SketchKind::CountSketch, 400, 2048, 8, 0.35);
        check_embedding(SketchKind::Srht, 400, 2048, 8, 0.35);
        check_embedding(SketchKind::SparseEmbed, 400, 2048, 8, 0.35);
    }
}
