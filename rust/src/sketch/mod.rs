//! Oblivious subspace embeddings (sketch matrices) — Algorithm 1, Step 1.
//!
//! A sketch `S in R^{s x n}` satisfies, w.h.p. for all x,
//! `(1-eps)||Ax|| <= ||SAx|| <= (1+eps)||Ax||`. The paper's Table 2 lists
//! four constructions with their costs for computing the preconditioner R;
//! all four are implemented here behind the [`Sketch`] trait:
//!
//! | construction       | time for SA           | module           |
//! |--------------------|------------------------|------------------|
//! | Gaussian           | O(n d^2) (dense gemm)  | [`gaussian`]     |
//! | SRHT               | O(nd log n)            | [`srht`]         |
//! | CountSketch        | O(nnz(A))              | [`count_sketch`] |
//! | Sparse l2 embedding| O(nnz(A) log d)        | [`sparse_embed`] |

pub mod fwht;
pub mod count_sketch;
pub mod gaussian;
pub mod srht;
pub mod sparse_embed;

use crate::data::blocks::{
    default_block_nnz, default_block_rows, CsrBlock, CsrBlocks, RowBlock, RowBlocks,
};
use crate::data::out_of_core::OnDiskDesign;
use crate::linalg::{CsrMat, Mat};
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_for_each_index;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// A sketch was asked to fold a row shard it cannot stream (e.g. a
/// mis-routed SRHT block). Recoverable: callers degrade to the dense
/// single-pass product instead of dying.
#[derive(Clone, Debug)]
pub struct StreamUnsupported {
    /// [`Sketch::name`] of the construction that rejected the shard.
    pub sketch: &'static str,
}

impl std::fmt::Display for StreamUnsupported {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: block streaming not supported (dense fallback)", self.sketch)
    }
}

impl std::error::Error for StreamUnsupported {}

/// The elementwise row-scatter primitives a dense sketch fold runs per
/// input row (`dst += src`, `dst -= src`, `dst += c * src`). Executors
/// inject their kernel set through [`apply_streamed_with`]:
/// [`RowOps::SCALAR`] reproduces the historical inline loops bit-for-bit,
/// while `crate::simd::row_ops()` supplies the arch-dispatched lanewise
/// kernels (add/sub reorder nothing and stay bit-identical; axpy fuses
/// into FMA and is tolerance-gated by the parity suite).
#[derive(Clone, Copy)]
pub struct RowOps {
    /// `dst += src` (equal lengths).
    pub add: fn(&mut [f64], &[f64]),
    /// `dst -= src` (equal lengths).
    pub sub: fn(&mut [f64], &[f64]),
    /// `dst += c * src` (equal lengths).
    pub axpy: fn(&mut [f64], f64, &[f64]),
}

fn scalar_row_add(dst: &mut [f64], src: &[f64]) {
    for (o, v) in dst.iter_mut().zip(src) {
        *o += v;
    }
}

fn scalar_row_sub(dst: &mut [f64], src: &[f64]) {
    for (o, v) in dst.iter_mut().zip(src) {
        *o -= v;
    }
}

fn scalar_row_axpy(dst: &mut [f64], c: f64, src: &[f64]) {
    // mul-then-add on purpose (no mul_add): this must replay the historical
    // inline loop exactly so dense folds under SCALAR stay bit-identical
    for (o, v) in dst.iter_mut().zip(src) {
        *o += c * v;
    }
}

impl RowOps {
    /// The reference scalar loops — exactly the operations the sketch folds
    /// inlined before executors could inject kernels, so every legacy entry
    /// point ([`Sketch::apply_block`], [`apply_streamed`]) remains
    /// bit-identical to its pre-`RowOps` behavior.
    pub const SCALAR: RowOps = RowOps {
        add: scalar_row_add,
        sub: scalar_row_sub,
        axpy: scalar_row_axpy,
    };
}

/// A sampled sketching operator: apply to the (packed) data matrix.
///
/// Streaming contract: for sketches that report `supports_streaming()`,
/// `S` applied to disjoint contiguous row shards is additive —
/// `S A = Σ_j fold(shard_j)` — so [`apply_streamed`] can fold shards on
/// worker threads and [`Sketch::merge`] the partials. SRHT is the documented
/// exception: the Hadamard butterfly mixes *all* rows, so it keeps the dense
/// path (`supports_streaming` stays false).
pub trait Sketch {
    /// The sketch output row count `s`.
    fn rows(&self) -> usize;
    /// Compute `S A` for a dense row-major A (n x d) -> (s x d).
    fn apply(&self, a: &Mat) -> Mat;
    /// Name for reports (Table 2 rows).
    fn name(&self) -> &'static str;

    /// Fold one contiguous row shard into the `s x d` accumulator `acc`.
    /// Rows are addressed globally through `block.global_row`, so folding a
    /// disjoint cover of shards (in any grouping) accumulates exactly the
    /// terms of the dense product. Only called when `supports_streaming()`;
    /// a mis-routed call returns `Err` (never panics — a serve worker must
    /// survive it) and the caller degrades to the dense product.
    fn apply_block(&self, block: &RowBlock<'_>, acc: &mut Mat) -> Result<(), StreamUnsupported> {
        let _ = (block, acc);
        Err(StreamUnsupported { sketch: self.name() })
    }

    /// Fold one contiguous row shard using injected row-scatter kernels.
    /// The default ignores `ops` and delegates to [`Sketch::apply_block`]
    /// (bit-identical historical behavior); sketches whose fold is a dense
    /// per-row scatter (CountSketch, SparseEmbed) override so an executor's
    /// kernels reach the inner loop. Overriders must implement the real fold
    /// here and define `apply_block` as `apply_block_with(.., &RowOps::SCALAR)`
    /// — not the other way around, which would recurse through this default.
    fn apply_block_with(
        &self,
        block: &RowBlock<'_>,
        acc: &mut Mat,
        ops: &RowOps,
    ) -> Result<(), StreamUnsupported> {
        let _ = ops;
        self.apply_block(block, acc)
    }

    /// Merge a partial accumulator into `acc` (elementwise sum).
    fn merge(&self, acc: &mut Mat, partial: &Mat) {
        assert_eq!((acc.rows, acc.cols), (partial.rows, partial.cols));
        for (a, p) in acc.data.iter_mut().zip(&partial.data) {
            *a += p;
        }
    }

    /// Whether [`Sketch::apply_block`] is implemented.
    fn supports_streaming(&self) -> bool {
        false
    }

    /// Compute `S A` for a CSR matrix. Hash sketches (CountSketch,
    /// SparseEmbed) override with true O(nnz) scatters; the default
    /// densifies the whole matrix — the documented fallback for SRHT, whose
    /// Hadamard butterfly needs every row at once.
    fn apply_csr(&self, a: &CsrMat) -> Mat {
        self.apply(&a.to_dense())
    }

    /// Fold one CSR row shard into the `s x d` accumulator — O(nnz(shard))
    /// for hash sketches; Gaussian densifies *per shard* (bounded scratch,
    /// documented fallback). Same additive contract as
    /// [`Sketch::apply_block`]: folding a disjoint cover of shards
    /// accumulates exactly the terms of `S A`. Only called when
    /// `supports_csr_streaming()`; a mis-routed call returns `Err` and the
    /// caller degrades to the dense product.
    fn apply_csr_block(
        &self,
        block: &CsrBlock<'_>,
        acc: &mut Mat,
    ) -> Result<(), StreamUnsupported> {
        let _ = (block, acc);
        Err(StreamUnsupported { sketch: self.name() })
    }

    /// CSR twin of [`Sketch::apply_block_with`]. The default ignores `ops`
    /// and delegates to [`Sketch::apply_csr_block`] — which is also what the
    /// shipped hash sketches do, since the CSR fold is an irregular
    /// per-entry scatter that gains nothing from lanewise kernels. The hook
    /// exists so a sketch with dense-ish CSR rows can opt in later.
    fn apply_csr_block_with(
        &self,
        block: &CsrBlock<'_>,
        acc: &mut Mat,
        ops: &RowOps,
    ) -> Result<(), StreamUnsupported> {
        let _ = ops;
        self.apply_csr_block(block, acc)
    }

    /// Whether [`Sketch::apply_csr_block`] is implemented.
    fn supports_csr_streaming(&self) -> bool {
        false
    }
}

/// Compute `S A` by folding contiguous row shards in parallel.
///
/// Shards are grouped into at most `threads` contiguous ranges; each worker
/// folds its range (in shard order) into a private partial, and partials are
/// merged in range order. The result is therefore deterministic for a fixed
/// (block size, thread count) and equal to the dense `apply` up to
/// floating-point re-association (verified to 1e-12 in
/// `tests/streaming_sketch.rs`). Peak extra memory is
/// `min(threads, blocks) * s * d` — partials, never a second copy of `A`.
///
/// Returns `(SA, shards_folded)`; `shards_folded == 1` means the dense path
/// ran (streaming unsupported, single shard, or empty input).
pub fn apply_streamed(
    sk: &(dyn Sketch + Send + Sync),
    a: &Mat,
    block_rows: Option<usize>,
    threads: usize,
) -> (Mat, usize) {
    apply_streamed_with(sk, a, block_rows, threads, &RowOps::SCALAR)
}

/// [`apply_streamed`] with an injected row-scatter kernel set: shards fold
/// through [`Sketch::apply_block_with`], so an executor's lanewise
/// `add`/`sub`/`axpy` reach the inner scatter loops of the hash sketches.
/// With [`RowOps::SCALAR`] this is exactly `apply_streamed` (bit-identical);
/// the simd executor passes `crate::simd::row_ops()`.
pub fn apply_streamed_with(
    sk: &(dyn Sketch + Send + Sync),
    a: &Mat,
    block_rows: Option<usize>,
    threads: usize,
    ops: &RowOps,
) -> (Mat, usize) {
    if !sk.supports_streaming() || a.rows == 0 {
        return (sk.apply(a), 1);
    }
    let view = match block_rows {
        Some(br) => RowBlocks::new(a, br),
        None => RowBlocks::auto(a),
    };
    let nb = view.num_blocks();
    if nb <= 1 {
        return (sk.apply(a), 1);
    }
    let (s, d) = (sk.rows(), a.cols);
    let workers = threads.max(1).min(nb);
    // one partial per worker range, each written by exactly one task
    let partials: Vec<std::sync::Mutex<Mat>> =
        (0..workers).map(|_| std::sync::Mutex::new(Mat::zeros(s, d))).collect();
    let failed = AtomicBool::new(false);
    parallel_for_each_index(workers, workers, |w| {
        let lo = w * nb / workers;
        let hi = (w + 1) * nb / workers;
        let mut acc = partials[w].lock().unwrap();
        for bi in lo..hi {
            if failed.load(Ordering::Relaxed) {
                return;
            }
            let block = view.block(bi);
            if sk.apply_block_with(&block, &mut acc, ops).is_err() {
                failed.store(true, Ordering::Relaxed);
                return;
            }
        }
    });
    if failed.load(Ordering::Relaxed) {
        // a sketch that advertises streaming but rejects shards (or a
        // mis-routed SRHT) degrades to the dense product instead of killing
        // the worker; partials are discarded, so the result is exact
        crate::log_warn!(
            "{}: shard fold rejected despite supports_streaming(); degrading to the dense product",
            sk.name()
        );
        return (sk.apply(a), 1);
    }
    let mut out = Mat::zeros(s, d);
    for p in &partials {
        let guard = p.lock().unwrap();
        sk.merge(&mut out, &guard);
    }
    (out, nb)
}

/// Compute `S A` for a CSR matrix by folding nnz-balanced row shards in
/// parallel — the sparse twin of [`apply_streamed`]. Shards are grouped
/// into at most `threads` contiguous ranges; each worker folds its range
/// into a private partial and partials merge in range order, so the result
/// is deterministic for a fixed (nnz budget, thread count) and equals the
/// dense product up to floating-point re-association (1e-10 acceptance in
/// `tests/sparse_parity.rs`). Cost is O(nnz) for hash sketches
/// (CountSketch, SparseEmbed); Gaussian densifies per shard; SRHT reports
/// no CSR streaming and takes the whole-matrix densify fallback.
///
/// Returns `(SA, shards_folded)`; `shards_folded == 1` means the dense
/// fallback ran (CSR streaming unsupported, single shard, or empty input).
pub fn apply_streamed_csr(
    sk: &(dyn Sketch + Send + Sync),
    a: &CsrMat,
    block_nnz: Option<usize>,
    threads: usize,
) -> (Mat, usize) {
    if !sk.supports_csr_streaming() || a.rows == 0 {
        return (sk.apply_csr(a), 1);
    }
    let view = match block_nnz {
        Some(bn) => CsrBlocks::new(a, bn),
        None => CsrBlocks::auto(a),
    };
    let nb = view.num_blocks();
    if nb <= 1 {
        return (sk.apply_csr(a), 1);
    }
    let (s, d) = (sk.rows(), a.cols);
    let workers = threads.max(1).min(nb);
    let partials: Vec<std::sync::Mutex<Mat>> =
        (0..workers).map(|_| std::sync::Mutex::new(Mat::zeros(s, d))).collect();
    let failed = AtomicBool::new(false);
    parallel_for_each_index(workers, workers, |w| {
        let lo = w * nb / workers;
        let hi = (w + 1) * nb / workers;
        let mut acc = partials[w].lock().unwrap();
        for bi in lo..hi {
            if failed.load(Ordering::Relaxed) {
                return;
            }
            let block = view.block(bi);
            if sk.apply_csr_block(&block, &mut acc).is_err() {
                failed.store(true, Ordering::Relaxed);
                return;
            }
        }
    });
    if failed.load(Ordering::Relaxed) {
        // same degradation contract as the dense fold: partials are
        // discarded and the single-pass product runs instead of killing a
        // serve worker
        crate::log_warn!(
            "{}: CSR shard fold rejected despite supports_csr_streaming(); degrading to the dense product",
            sk.name()
        );
        return (sk.apply_csr(a), 1);
    }
    let mut out = Mat::zeros(s, d);
    for p in &partials {
        let guard = p.lock().unwrap();
        sk.merge(&mut out, &guard);
    }
    (out, nb)
}

/// Compute `S A` for a disk-backed design by folding shard-cache-gathered
/// scratch blocks — the out-of-core twin of [`apply_streamed_with`] (dense
/// flavor) and [`apply_streamed_csr`] (chunked flavor). The block
/// partition, worker ranges and merge order replicate the in-memory
/// streamed paths exactly — same [`default_block_rows`] heuristic / greedy
/// nnz boundaries, same `w * nb / workers` ranges, same in-order partial
/// merge — so for a fixed (block size, thread count) the result is bitwise
/// identical to streaming a resident twin. Each block's payload is a
/// transient scratch gather (bounded like the fold accumulators, not
/// charged); consumers that cannot stream (SRHT, single-shard inputs)
/// fall back to a budget-*charged* whole-matrix materialization.
///
/// Fallible like every disk access: a shard I/O error or refused charge
/// propagates as a structured error instead of panicking a fold worker.
/// Returns `(SA, shards_folded)`; `shards_folded == 1` means a
/// materialized single pass ran.
pub fn apply_streamed_ondisk(
    sk: &(dyn Sketch + Send + Sync),
    od: &OnDiskDesign,
    block_rows: Option<usize>,
    threads: usize,
    ops: &RowOps,
) -> anyhow::Result<(Mat, usize)> {
    if od.sparse_arith() {
        apply_streamed_ondisk_csr(sk, od, block_rows, threads)
    } else {
        apply_streamed_ondisk_dense(sk, od, block_rows, threads, ops)
    }
}

fn ondisk_dense_fallback(
    sk: &(dyn Sketch + Send + Sync),
    od: &OnDiskDesign,
) -> anyhow::Result<(Mat, usize)> {
    let (mat, _charge) = od.dense_scoped(&format!("sketch_apply[{}]", sk.name()))?;
    Ok((sk.apply(&mat), 1))
}

fn ondisk_csr_fallback(
    sk: &(dyn Sketch + Send + Sync),
    od: &OnDiskDesign,
) -> anyhow::Result<(Mat, usize)> {
    let (mat, _charge) = od.csr_scoped(&format!("sketch_apply_csr[{}]", sk.name()))?;
    Ok((sk.apply_csr(&mat), 1))
}

fn apply_streamed_ondisk_dense(
    sk: &(dyn Sketch + Send + Sync),
    od: &OnDiskDesign,
    block_rows: Option<usize>,
    threads: usize,
    ops: &RowOps,
) -> anyhow::Result<(Mat, usize)> {
    let (rows, cols) = (od.rows(), od.cols());
    if !sk.supports_streaming() || rows == 0 {
        return ondisk_dense_fallback(sk, od);
    }
    let br = block_rows
        .unwrap_or_else(|| default_block_rows(rows, cols))
        .max(1);
    let nb = rows.div_ceil(br);
    if nb <= 1 {
        return ondisk_dense_fallback(sk, od);
    }
    let (s, d) = (sk.rows(), cols);
    let workers = threads.max(1).min(nb);
    let partials: Vec<Mutex<Mat>> =
        (0..workers).map(|_| Mutex::new(Mat::zeros(s, d))).collect();
    let failed = AtomicBool::new(false);
    let io_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    parallel_for_each_index(workers, workers, |w| {
        let lo = w * nb / workers;
        let hi = (w + 1) * nb / workers;
        let mut acc = partials[w].lock().unwrap();
        for bi in lo..hi {
            if failed.load(Ordering::Relaxed) {
                return;
            }
            let start = bi * br;
            let take = br.min(rows - start);
            let idx: Vec<usize> = (start..start + take).collect();
            let scratch = match od.gather_rows(&idx) {
                Ok((m, _b)) => m,
                Err(e) => {
                    *io_err.lock().unwrap() = Some(e);
                    failed.store(true, Ordering::Relaxed);
                    return;
                }
            };
            let block = RowBlock {
                start,
                rows: take,
                cols,
                data: &scratch.data[..],
            };
            if sk.apply_block_with(&block, &mut acc, ops).is_err() {
                failed.store(true, Ordering::Relaxed);
                return;
            }
        }
    });
    if let Some(e) = io_err.lock().unwrap().take() {
        return Err(e);
    }
    if failed.load(Ordering::Relaxed) {
        crate::log_warn!(
            "{}: on-disk shard fold rejected despite supports_streaming(); \
             degrading to the materialized dense product",
            sk.name()
        );
        return ondisk_dense_fallback(sk, od);
    }
    let mut out = Mat::zeros(s, d);
    for p in &partials {
        let guard = p.lock().unwrap();
        sk.merge(&mut out, &guard);
    }
    Ok((out, nb))
}

fn apply_streamed_ondisk_csr(
    sk: &(dyn Sketch + Send + Sync),
    od: &OnDiskDesign,
    block_rows: Option<usize>,
    threads: usize,
) -> anyhow::Result<(Mat, usize)> {
    let rows = od.rows();
    if !sk.supports_csr_streaming() || rows == 0 {
        return ondisk_csr_fallback(sk, od);
    }
    let cc = od.chunked().expect("sparse_arith implies the chunked flavor");
    let nnz = od.nnz();
    // the same row-knob translation as CsrMat::nnz_budget_for_rows / the
    // same heuristic as CsrBlocks::auto
    let block_nnz = match block_rows {
        Some(br) => br.saturating_mul((nnz / rows.max(1)).max(1)).max(1),
        None => default_block_nnz(nnz),
    };
    // CsrBlocks::new's greedy boundaries, computed from the nnz prefix the
    // chunked loader built at open (no resident matrix required)
    let prefix = cc.row_nnz_prefix();
    let mut bounds = vec![0usize];
    let mut shard_start_off = 0usize;
    for i in 0..rows {
        let end_off = prefix[i + 1];
        if end_off - shard_start_off >= block_nnz && i + 1 < rows {
            bounds.push(i + 1);
            shard_start_off = end_off;
        }
    }
    bounds.push(rows);
    let nb = bounds.len() - 1;
    if nb <= 1 {
        return ondisk_csr_fallback(sk, od);
    }
    let (s, d) = (sk.rows(), od.cols());
    let workers = threads.max(1).min(nb);
    let partials: Vec<Mutex<Mat>> =
        (0..workers).map(|_| Mutex::new(Mat::zeros(s, d))).collect();
    let failed = AtomicBool::new(false);
    let io_err: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    parallel_for_each_index(workers, workers, |w| {
        let lo = w * nb / workers;
        let hi = (w + 1) * nb / workers;
        let mut acc = partials[w].lock().unwrap();
        for bi in lo..hi {
            if failed.load(Ordering::Relaxed) {
                return;
            }
            let (row_lo, row_hi) = (bounds[bi], bounds[bi + 1]);
            let scratch = match od.csr_range_scratch(row_lo, row_hi) {
                Ok(m) => m,
                Err(e) => {
                    *io_err.lock().unwrap() = Some(e);
                    failed.store(true, Ordering::Relaxed);
                    return;
                }
            };
            let block = CsrBlock::from_scratch(&scratch, row_lo);
            if sk.apply_csr_block(&block, &mut acc).is_err() {
                failed.store(true, Ordering::Relaxed);
                return;
            }
        }
    });
    if let Some(e) = io_err.lock().unwrap().take() {
        return Err(e);
    }
    if failed.load(Ordering::Relaxed) {
        crate::log_warn!(
            "{}: on-disk CSR shard fold rejected despite supports_csr_streaming(); \
             degrading to the materialized product",
            sk.name()
        );
        return ondisk_csr_fallback(sk, od);
    }
    let mut out = Mat::zeros(s, d);
    for p in &partials {
        let guard = p.lock().unwrap();
        sk.merge(&mut out, &guard);
    }
    Ok((out, nb))
}

/// Which sketch construction to use (CLI / config selectable).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SketchKind {
    /// Dense i.i.d. N(0, 1/s) projection — O(n d^2), the quality baseline.
    Gaussian,
    /// Subsampled randomized Hadamard transform — O(nd log n).
    Srht,
    /// One hashed row per input row, ±1 signs — O(nnz(A)).
    CountSketch,
    /// OSNAP-style sparse l2 embedding, k hashed rows per input row —
    /// O(nnz(A) log d).
    SparseEmbed,
}

impl SketchKind {
    /// Parse a CLI/config spelling (case-insensitive; accepts the aliases
    /// `count`/`count_sketch` and `sparse`/`sparse_l2`). `None` if unknown.
    pub fn parse(s: &str) -> Option<SketchKind> {
        match s.to_ascii_lowercase().as_str() {
            "gaussian" => Some(SketchKind::Gaussian),
            "srht" => Some(SketchKind::Srht),
            "countsketch" | "count_sketch" | "count" => Some(SketchKind::CountSketch),
            "sparse" | "sparse_embed" | "sparse_l2" => Some(SketchKind::SparseEmbed),
            _ => None,
        }
    }

    /// Canonical name as reported in results and parsed back by
    /// [`SketchKind::parse`].
    pub fn name(self) -> &'static str {
        match self {
            SketchKind::Gaussian => "gaussian",
            SketchKind::Srht => "srht",
            SketchKind::CountSketch => "countsketch",
            SketchKind::SparseEmbed => "sparse_embed",
        }
    }

    /// Instantiate a sketch of size s x n.
    pub fn build(self, s: usize, n: usize, rng: &mut Rng) -> Box<dyn Sketch + Send + Sync> {
        match self {
            SketchKind::Gaussian => Box::new(gaussian::GaussianSketch::new(s, n, rng)),
            SketchKind::Srht => Box::new(srht::Srht::new(s, n, rng)),
            SketchKind::CountSketch => Box::new(count_sketch::CountSketch::new(s, n, rng)),
            SketchKind::SparseEmbed => Box::new(sparse_embed::SparseEmbed::new(s, n, rng)),
        }
    }
}

/// Default sketch size for a given d and construction.
///
/// Hash-based sketches (CountSketch, sparse embedding) need s = Omega(d^2)
/// rows for the subspace-embedding property (hence Table 2's O(nnz + d^4)
/// CountSketch cost — the QR on an s x d matrix with s ~ d^2 is d^4);
/// rotation-based sketches (Gaussian, SRHT) need only O(d log d). The
/// paper's Table 3 sketch sizes match: 1000 = 2.5 d^2 for d = 20,
/// 20000 ~ 2.5 d^2 for d = 90.
pub fn default_sketch_size_for(n: usize, d: usize, kind: SketchKind) -> usize {
    let s = match kind {
        SketchKind::CountSketch | SketchKind::SparseEmbed => (5 * d * d / 2).max(20 * d),
        SketchKind::Gaussian | SketchKind::Srht => (20 * d).max(d * d / 8),
    };
    s.clamp(d + 1, n.max(d + 2) - 1)
}

/// Backwards-compatible default assuming a rotation-quality sketch.
pub fn default_sketch_size(n: usize, d: usize) -> usize {
    default_sketch_size_for(n, d, SketchKind::CountSketch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::gemv;

    /// Shared embedding-quality check: for a handful of random x,
    /// ||SAx|| must be within a loose multiplicative band of ||Ax||.
    pub(crate) fn check_embedding(kind: SketchKind, s: usize, n: usize, d: usize, tol: f64) {
        let mut rng = Rng::new(99);
        let a = Mat::gaussian(n, d, &mut rng);
        let sk = kind.build(s, n, &mut rng);
        let sa = sk.apply(&a);
        assert_eq!(sa.rows, s);
        assert_eq!(sa.cols, d);
        for trial in 0..10 {
            let x = rng.gaussians(d);
            let ax = crate::linalg::blas::nrm2(&gemv(&a, &x));
            let sax = crate::linalg::blas::nrm2(&gemv(&sa, &x));
            let ratio = sax / ax;
            assert!(
                (ratio - 1.0).abs() < tol,
                "{} trial {trial}: ratio {ratio} outside 1 +- {tol}",
                kind.name()
            );
        }
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(SketchKind::parse("SRHT"), Some(SketchKind::Srht));
        assert_eq!(SketchKind::parse("countsketch"), Some(SketchKind::CountSketch));
        assert_eq!(SketchKind::parse("nope"), None);
    }

    #[test]
    fn default_size_bounds() {
        let s = default_sketch_size(100_000, 20);
        assert!(s > 20 && s < 100_000);
        // tiny n still yields a valid size
        let s2 = default_sketch_size(64, 20);
        assert!(s2 >= 21 && s2 <= 64);
        // hash sketches need ~d^2; rotations need ~d log d
        let hash = default_sketch_size_for(1_000_000, 90, SketchKind::CountSketch);
        let rot = default_sketch_size_for(1_000_000, 90, SketchKind::Srht);
        assert!(hash >= 90 * 90 * 2, "hash sketch size {hash}");
        assert!(rot < hash, "srht {rot} should need fewer rows than countsketch {hash}");
        // paper's Table 3: d=90 -> sketch 20000; ours is the same scale
        assert!((hash as f64 / 20_000.0) < 2.0 && (hash as f64 / 20_000.0) > 0.5);
    }

    #[test]
    fn all_kinds_embed_gaussian_data() {
        // loose tolerance: these are probabilistic structures
        check_embedding(SketchKind::Gaussian, 400, 2048, 8, 0.35);
        check_embedding(SketchKind::CountSketch, 400, 2048, 8, 0.35);
        check_embedding(SketchKind::Srht, 400, 2048, 8, 0.35);
        check_embedding(SketchKind::SparseEmbed, 400, 2048, 8, 0.35);
    }

    #[test]
    fn streaming_support_flags() {
        let mut rng = Rng::new(17);
        for (kind, streaming) in [
            (SketchKind::Gaussian, true),
            (SketchKind::CountSketch, true),
            (SketchKind::SparseEmbed, true),
            (SketchKind::Srht, false), // documented dense fallback
        ] {
            let sk = kind.build(32, 128, &mut rng);
            assert_eq!(sk.supports_streaming(), streaming, "{}", kind.name());
            // the CSR contract mirrors the dense one: hash sketches stream
            // in O(nnz), Gaussian streams via per-shard densify, SRHT keeps
            // the whole-matrix densify fallback
            assert_eq!(
                sk.supports_csr_streaming(),
                streaming,
                "{} (csr)",
                kind.name()
            );
        }
    }

    /// Random CSR matrix with ~density nonzeros (plus its dense twin).
    fn sparse_pair(n: usize, d: usize, density: f64, seed: u64) -> (CsrMat, Mat) {
        let mut rng = Rng::new(seed);
        let dense = Mat::from_fn(n, d, |_, _| {
            if rng.uniform() < density {
                rng.gaussian()
            } else {
                0.0
            }
        });
        (CsrMat::from_dense(&dense), dense)
    }

    #[test]
    fn csr_apply_matches_dense_all_kinds() {
        let (csr, dense) = sparse_pair(301, 6, 0.15, 41);
        for kind in [
            SketchKind::CountSketch,
            SketchKind::SparseEmbed,
            SketchKind::Gaussian,
            SketchKind::Srht,
        ] {
            let mut rng = Rng::new(43);
            let sk = kind.build(48, 301, &mut rng);
            let want = sk.apply(&dense);
            let got = sk.apply_csr(&csr);
            assert!(
                got.max_abs_diff(&want) < 1e-12,
                "{}: apply_csr != apply",
                kind.name()
            );
        }
    }

    #[test]
    fn csr_streamed_matches_dense_and_reports_shards() {
        let (csr, dense) = sparse_pair(257, 5, 0.2, 47);
        for kind in [
            SketchKind::CountSketch,
            SketchKind::SparseEmbed,
            SketchKind::Gaussian,
            SketchKind::Srht,
        ] {
            let mut rng = Rng::new(51);
            let sk = kind.build(32, 257, &mut rng);
            let want = sk.apply(&dense);
            let (got, shards) = apply_streamed_csr(sk.as_ref(), &csr, Some(16), 4);
            assert!(
                got.max_abs_diff(&want) < 1e-12,
                "{}: streamed csr != dense",
                kind.name()
            );
            if sk.supports_csr_streaming() {
                assert!(shards > 1, "{}: expected multiple shards", kind.name());
            } else {
                assert_eq!(shards, 1, "{}: densify fallback expected", kind.name());
            }
        }
    }

    #[test]
    fn csr_streamed_deterministic_across_thread_counts() {
        let (csr, _) = sparse_pair(400, 4, 0.3, 53);
        let mut rng = Rng::new(59);
        let sk = SketchKind::CountSketch.build(24, 400, &mut rng);
        let (one, _) = apply_streamed_csr(sk.as_ref(), &csr, Some(20), 1);
        let (eight, _) = apply_streamed_csr(sk.as_ref(), &csr, Some(20), 8);
        assert!(one.max_abs_diff(&eight) < 1e-12);
    }

    #[test]
    fn csr_misrouted_shard_degrades_to_dense() {
        /// Claims CSR streaming but rejects every shard.
        struct LyingCsr(srht::Srht);
        impl Sketch for LyingCsr {
            fn rows(&self) -> usize {
                self.0.rows()
            }
            fn apply(&self, a: &Mat) -> Mat {
                self.0.apply(a)
            }
            fn name(&self) -> &'static str {
                "lying_csr"
            }
            // no apply_csr_block override: the default returns Err
            fn supports_csr_streaming(&self) -> bool {
                true
            }
        }
        let (csr, dense) = sparse_pair(128, 4, 0.25, 61);
        let mut rng = Rng::new(67);
        let lying = LyingCsr(srht::Srht::new(16, 128, &mut rng));
        let want = lying.apply(&dense);
        let (got, shards) = apply_streamed_csr(&lying, &csr, Some(8), 4);
        assert_eq!(shards, 1, "fallback must report the dense single pass");
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn streamed_matches_dense_small() {
        // the heavyweight sweep lives in tests/streaming_sketch.rs; this is
        // the in-crate smoke check
        let mut rng = Rng::new(23);
        let a = Mat::gaussian(301, 5, &mut rng);
        for kind in [
            SketchKind::Gaussian,
            SketchKind::CountSketch,
            SketchKind::SparseEmbed,
            SketchKind::Srht,
        ] {
            let sk = kind.build(64, 301, &mut rng);
            let dense = sk.apply(&a);
            let (streamed, shards) = apply_streamed(sk.as_ref(), &a, Some(37), 4);
            assert!(
                streamed.max_abs_diff(&dense) < 1e-12,
                "{}: streamed != dense",
                kind.name()
            );
            if sk.supports_streaming() {
                assert!(shards > 1, "{}: expected multiple shards", kind.name());
            } else {
                assert_eq!(shards, 1, "{}: dense fallback expected", kind.name());
            }
        }
    }

    /// A sketch that *claims* streaming but rejects every shard — the
    /// mis-routed-SRHT failure mode. The streamed path must degrade to the
    /// dense product instead of panicking a worker.
    struct LyingSrht(srht::Srht);

    impl Sketch for LyingSrht {
        fn rows(&self) -> usize {
            self.0.rows()
        }
        fn apply(&self, a: &Mat) -> Mat {
            self.0.apply(a)
        }
        fn name(&self) -> &'static str {
            "lying_srht"
        }
        // no apply_block override: the default returns Err
        fn supports_streaming(&self) -> bool {
            true
        }
    }

    #[test]
    fn misrouted_shard_degrades_to_dense_instead_of_panicking() {
        let mut rng = Rng::new(31);
        let a = Mat::gaussian(257, 5, &mut rng);
        let lying = LyingSrht(srht::Srht::new(64, 257, &mut rng));
        let dense = lying.apply(&a);
        let (streamed, shards) = apply_streamed(&lying, &a, Some(32), 4);
        assert_eq!(shards, 1, "fallback must report the dense single pass");
        assert_eq!(streamed.max_abs_diff(&dense), 0.0);
    }

    #[test]
    fn default_apply_block_reports_unsupported() {
        let mut rng = Rng::new(37);
        let sk = SketchKind::Srht.build(16, 64, &mut rng);
        let a = Mat::gaussian(64, 3, &mut rng);
        let view = RowBlocks::new(&a, 16);
        let mut acc = Mat::zeros(16, 3);
        let err = sk.apply_block(&view.block(0), &mut acc).unwrap_err();
        assert!(err.to_string().contains("srht"), "{err}");
    }

    #[test]
    fn streamed_deterministic_across_thread_counts() {
        let mut rng = Rng::new(29);
        let a = Mat::gaussian(257, 4, &mut rng);
        let sk = SketchKind::CountSketch.build(48, 257, &mut rng);
        let (one, _) = apply_streamed(sk.as_ref(), &a, Some(16), 1);
        let (eight, _) = apply_streamed(sk.as_ref(), &a, Some(16), 8);
        // grouping is by fixed worker ranges, so differing thread counts may
        // regroup partials; equality must still hold to f64 noise
        assert!(one.max_abs_diff(&eight) < 1e-12);
    }
}
