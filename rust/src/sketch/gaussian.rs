//! Dense Gaussian sketch: S_ij ~ N(0, 1/s). The classical JL embedding —
//! O(n d s) to apply (a gemm), listed in Table 2 as the slow-but-simple
//! baseline construction.

use super::Sketch;
use crate::data::blocks::{CsrBlock, RowBlock};
use crate::linalg::{blas, Mat};
use crate::util::rng::Rng;

/// A sampled dense Gaussian sketch matrix with entries `N(0, 1/s)`.
pub struct GaussianSketch {
    mat: Mat, // s x n, pre-scaled by 1/sqrt(s)
}

impl GaussianSketch {
    /// Sample an `s x n` Gaussian sketch, pre-scaled by `1/sqrt(s)`.
    pub fn new(s: usize, n: usize, rng: &mut Rng) -> Self {
        let mut mat = Mat::gaussian(s, n, rng);
        let scale = 1.0 / (s as f64).sqrt();
        mat.scale(scale);
        GaussianSketch { mat }
    }
}

impl Sketch for GaussianSketch {
    fn rows(&self) -> usize {
        self.mat.rows
    }

    fn apply(&self, a: &Mat) -> Mat {
        blas::gemm(&self.mat, a)
    }

    fn name(&self) -> &'static str {
        "gaussian"
    }

    /// Streaming fold: SA restricted to a row shard is the rank-`rows`
    /// update `S[:, start..start+rows] · block`, accumulated as saxpy rows
    /// (the contiguous `block.row(k)` is the inner loop, so the fold is
    /// cache- and vectorizer-friendly despite the strided column access
    /// into S).
    fn apply_block(
        &self,
        block: &RowBlock<'_>,
        acc: &mut Mat,
    ) -> Result<(), crate::sketch::StreamUnsupported> {
        assert_eq!(acc.rows, self.mat.rows);
        assert_eq!(acc.cols, block.cols);
        assert!(block.start + block.rows <= self.mat.cols);
        for i in 0..self.mat.rows {
            let srow = self.mat.row(i);
            let orow = acc.row_mut(i);
            for k in 0..block.rows {
                let coef = srow[block.start + k];
                for (o, v) in orow.iter_mut().zip(block.row(k)) {
                    *o += coef * v;
                }
            }
        }
        Ok(())
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    /// DENSIFY-PER-SHARD FALLBACK (documented): the Gaussian fold is a
    /// dense rank-`rows` update, so a CSR shard is materialized into a
    /// `shard_rows x d` scratch and folded through the dense
    /// [`Sketch::apply_block`] arithmetic. Scratch memory is bounded by one
    /// shard — never the whole matrix — so the streaming pipeline still
    /// avoids a full densify; the flop count stays O(s * rows * d) because
    /// a dense gaussian S has no sparsity to exploit.
    fn apply_csr_block(
        &self,
        block: &CsrBlock<'_>,
        acc: &mut Mat,
    ) -> Result<(), crate::sketch::StreamUnsupported> {
        let dense = block.to_dense();
        let rb = RowBlock {
            start: block.start,
            rows: block.rows,
            cols: block.cols(),
            data: &dense.data[..],
        };
        self.apply_block(&rb, acc)
    }

    fn supports_csr_streaming(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_scaling() {
        let mut rng = Rng::new(1);
        let g = GaussianSketch::new(64, 256, &mut rng);
        assert_eq!(g.rows(), 64);
        // entries should have variance ~ 1/s
        let var = g.mat.data.iter().map(|v| v * v).sum::<f64>() / g.mat.data.len() as f64;
        assert!((var - 1.0 / 64.0).abs() < 0.2 / 64.0);
    }

    #[test]
    fn preserves_norms_on_average() {
        let mut rng = Rng::new(2);
        let a = Mat::gaussian(512, 6, &mut rng);
        let g = GaussianSketch::new(300, 512, &mut rng);
        let sa = g.apply(&a);
        let x = rng.gaussians(6);
        let ax = blas::nrm2(&blas::gemv(&a, &x));
        let sax = blas::nrm2(&blas::gemv(&sa, &x));
        assert!((sax / ax - 1.0).abs() < 0.25);
    }
}
