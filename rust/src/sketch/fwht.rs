//! In-place fast Walsh-Hadamard transform — the native counterpart of the
//! Pallas `fwht` kernel (python/compile/kernels/fwht.py).
//!
//! The orthonormal convention matches the paper's Definition 2:
//! H = H_n / sqrt(n), so `fwht` is an involution and preserves l2 norms.
//! The matrix variant transforms all columns at once by processing whole
//! rows per butterfly (row-major friendly: the inner loop is a contiguous
//! row +- row operation, vectorizable and parallel over column panels).

use crate::linalg::Mat;
use crate::util::threadpool::{default_threads, parallel_for_each_index};

/// In-place FWHT of a single vector (len must be a power of two), including
/// the 1/sqrt(n) normalization.
pub fn fwht_vec(x: &mut [f64]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "fwht length must be a power of two");
    let mut h = 1;
    while h < n {
        let step = 2 * h;
        for i in (0..n).step_by(step) {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h = step;
    }
    let scale = 1.0 / (n as f64).sqrt();
    for v in x {
        *v *= scale;
    }
}

/// In-place FWHT along axis 0 of a row-major matrix (rows must be a power of
/// two): every column is transformed. The butterfly works on whole rows, so
/// the inner loop is contiguous; columns are implicitly vectorized.
pub fn fwht_mat(a: &mut Mat) {
    let n = a.rows;
    let d = a.cols;
    assert!(n.is_power_of_two(), "fwht rows must be a power of two");
    let threads = if n * d > 1 << 15 { default_threads() } else { 1 };
    if threads <= 1 || d < 2 {
        fwht_rows(&mut a.data, n, d, 0, d);
        let scale = 1.0 / (n as f64).sqrt();
        for v in &mut a.data {
            *v *= scale;
        }
        return;
    }
    // parallel over column panels: each worker transforms a [0..n) x panel
    // strip independently (butterflies never mix columns).
    let panel = d.div_ceil(threads).max(8);
    let npanels = d.div_ceil(panel);
    struct SendPtr(*mut f64);
    unsafe impl Send for SendPtr {}
    unsafe impl Sync for SendPtr {}
    impl SendPtr {
        fn get(&self) -> *mut f64 {
            self.0
        }
    }
    let ptr = SendPtr(a.data.as_mut_ptr());
    parallel_for_each_index(npanels, threads, |pi| {
        let lo = pi * panel;
        let hi = (lo + panel).min(d);
        let data = unsafe { std::slice::from_raw_parts_mut(ptr.get(), n * d) };
        fwht_rows(data, n, d, lo, hi);
        let scale = 1.0 / (n as f64).sqrt();
        for i in 0..n {
            for v in &mut data[i * d + lo..i * d + hi] {
                *v *= scale;
            }
        }
    });
}

/// Butterfly over rows restricted to columns [c0, c1).
///
/// PERF: radix-4 — two radix-2 stages fused per pass, halving the number of
/// sweeps over the matrix (the transform is memory-bound; see
/// EXPERIMENTS.md §Perf). For odd log2(n) a single radix-2 stage runs first.
fn fwht_rows(data: &mut [f64], n: usize, d: usize, c0: usize, c1: usize) {
    let mut h = 1;
    // leading radix-2 stage when log2(n) is odd
    if n.trailing_zeros() % 2 == 1 {
        for j in (0..n).step_by(2) {
            let (r0, r1) = (j * d, (j + 1) * d);
            for c in c0..c1 {
                let a = data[r0 + c];
                let b = data[r1 + c];
                data[r0 + c] = a + b;
                data[r1 + c] = a - b;
            }
        }
        h = 2;
    }
    // radix-4 stages: combine butterflies at distance h and 2h
    while h < n {
        let step = 4 * h;
        for i in (0..n).step_by(step) {
            for j in i..i + h {
                let (r0, r1, r2, r3) =
                    (j * d, (j + h) * d, (j + 2 * h) * d, (j + 3 * h) * d);
                for c in c0..c1 {
                    let a = data[r0 + c];
                    let b = data[r1 + c];
                    let cc = data[r2 + c];
                    let dd = data[r3 + c];
                    let apb = a + b;
                    let amb = a - b;
                    let cpd = cc + dd;
                    let cmd = cc - dd;
                    data[r0 + c] = apb + cpd;
                    data[r1 + c] = amb + cmd;
                    data[r2 + c] = apb - cpd;
                    data[r3 + c] = amb - cmd;
                }
            }
        }
        h = step;
    }
}

/// The paper's Randomized Hadamard Transform HD: flip row signs by the
/// Rademacher vector, then FWHT. Operates in place.
pub fn randomized_hadamard(a: &mut Mat, signs: &[f64]) {
    assert_eq!(a.rows, signs.len());
    for i in 0..a.rows {
        let s = signs[i];
        if s < 0.0 {
            for v in a.row_mut(i) {
                *v = -*v;
            }
        }
    }
    fwht_mat(a);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn vec_matches_explicit_h2() {
        let mut x = vec![1.0, 2.0];
        fwht_vec(&mut x);
        let s = 1.0 / 2f64.sqrt();
        assert!((x[0] - 3.0 * s).abs() < 1e-15);
        assert!((x[1] - (-1.0) * s).abs() < 1e-15);
    }

    #[test]
    fn involution_preserves_input() {
        let mut rng = Rng::new(1);
        let orig = rng.gaussians(256);
        let mut x = orig.clone();
        fwht_vec(&mut x);
        fwht_vec(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn preserves_l2_norm() {
        let mut rng = Rng::new(2);
        let mut x = rng.gaussians(512);
        let before = crate::linalg::blas::nrm2(&x);
        fwht_vec(&mut x);
        let after = crate::linalg::blas::nrm2(&x);
        assert!((before - after).abs() < 1e-10);
    }

    #[test]
    fn mat_transform_matches_per_column_vec_transform() {
        let mut rng = Rng::new(3);
        let mut m = Mat::gaussian(128, 5, &mut rng);
        let cols: Vec<Vec<f64>> = (0..5).map(|j| m.col(j)).collect();
        fwht_mat(&mut m);
        for (j, col) in cols.into_iter().enumerate() {
            let mut c = col;
            fwht_vec(&mut c);
            for i in 0..128 {
                assert!((m.at(i, j) - c[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn mat_parallel_path_matches_serial() {
        let mut rng = Rng::new(4);
        let big = Mat::gaussian(1024, 64, &mut rng); // crosses the parallel threshold
        let mut par = big.clone();
        fwht_mat(&mut par);
        // serial reference: per column
        for j in 0..big.cols {
            let mut c = big.col(j);
            fwht_vec(&mut c);
            for i in 0..big.rows {
                assert!((par.at(i, j) - c[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn randomized_hadamard_is_orthogonal() {
        let mut rng = Rng::new(5);
        let a = Mat::gaussian(256, 4, &mut rng);
        let signs = rng.signs(256);
        let mut hd = a.clone();
        randomized_hadamard(&mut hd, &signs);
        // norms of each column preserved
        for j in 0..4 {
            let n0 = crate::linalg::blas::nrm2(&a.col(j));
            let n1 = crate::linalg::blas::nrm2(&hd.col(j));
            assert!((n0 - n1).abs() < 1e-10);
        }
    }

    #[test]
    fn randomized_hadamard_flattens_row_norms() {
        // Theorem 1: after HD, max row norm of an orthogonal-ish matrix is
        // O(sqrt(d/n) * log n). Build a spiky matrix (identity block) and
        // check the max row norm drops dramatically.
        let n = 1024;
        let d = 8;
        let mut a = Mat::zeros(n, d);
        for j in 0..d {
            *a.at_mut(j, j) = 1.0; // all mass on the first d rows
        }
        let mut rng = Rng::new(6);
        let signs = rng.signs(n);
        let max_before = (0..n)
            .map(|i| crate::linalg::blas::nrm2(a.row(i)))
            .fold(0.0, f64::max);
        randomized_hadamard(&mut a, &signs);
        let max_after = (0..n)
            .map(|i| crate::linalg::blas::nrm2(a.row(i)))
            .fold(0.0, f64::max);
        assert!((max_before - 1.0).abs() < 1e-12);
        // perfectly spread would be sqrt(d/n) ~ 0.088; allow the log factor
        assert!(
            max_after < 0.5,
            "HD failed to spread rows: max row norm {max_after}"
        );
    }

    #[test]
    #[should_panic]
    fn non_pow2_panics() {
        let mut x = vec![0.0; 3];
        fwht_vec(&mut x);
    }
}
