//! Subsampled Randomized Hadamard Transform (Tropp 2011):
//! S = sqrt(n/s) * P H D, where D is a Rademacher diagonal, H the
//! orthonormal Walsh-Hadamard matrix and P samples s rows uniformly.
//! Applying to an n x d matrix costs O(nd log n) via the FWHT.

use super::fwht::randomized_hadamard;
use super::Sketch;
use crate::linalg::matrix::next_pow2;
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// A sampled SRHT operator `sqrt(n_pad/s) * P H D` for inputs with `n`
/// rows (padded internally to the next power of two).
pub struct Srht {
    s: usize,
    n: usize,
    n_pad: usize,
    signs: Vec<f64>,
    picked: Vec<usize>,
}

impl Srht {
    /// Sample an SRHT with `s` output rows for `n`-row inputs: one
    /// Rademacher sign per (padded) row and `s` uniform row picks.
    pub fn new(s: usize, n: usize, rng: &mut Rng) -> Self {
        let n_pad = next_pow2(n);
        let signs = rng.signs(n_pad);
        let picked = (0..s).map(|_| rng.below(n_pad)).collect();
        Srht {
            s,
            n,
            n_pad,
            signs,
            picked,
        }
    }
}

impl Sketch for Srht {
    // STREAMING FALLBACK (documented): the Hadamard butterfly mixes every
    // input row with every other row, so `S A` does not decompose into
    // independent row-shard contributions the way hash/Gaussian sketches do.
    // A streaming SRHT would need a distributed FWHT (log n block-exchange
    // rounds); until an executor provides one, SRHT keeps the trait's
    // default `supports_streaming() == false` and `apply_streamed` routes
    // it through this dense path.
    //
    // CSR FALLBACK (documented): for the same reason SRHT keeps the
    // trait's default `supports_csr_streaming() == false` and
    // `apply_csr` densifies the WHOLE matrix before the FWHT — a sparse
    // input gains nothing from SRHT (the transform destroys sparsity in
    // its first butterfly round anyway). `apply_streamed_csr` reports one
    // shard so callers/metrics can see the fallback ran.
    fn rows(&self) -> usize {
        self.s
    }

    fn apply(&self, a: &Mat) -> Mat {
        assert_eq!(a.rows, self.n);
        // pad to a power of two (H is only defined for 2^k)
        let mut padded = if self.n_pad == self.n {
            a.clone()
        } else {
            a.pad_rows(self.n_pad)
        };
        randomized_hadamard(&mut padded, &self.signs);
        let mut out = padded.gather_rows(&self.picked);
        // variance correction: uniform row sampling of an orthonormal mixing
        out.scale((self.n_pad as f64 / self.s as f64).sqrt());
        out
    }

    fn name(&self) -> &'static str {
        "srht"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;

    #[test]
    fn shape_with_padding() {
        let mut rng = Rng::new(1);
        let srht = Srht::new(50, 300, &mut rng); // 300 pads to 512
        let a = Mat::gaussian(300, 4, &mut rng);
        let sa = srht.apply(&a);
        assert_eq!((sa.rows, sa.cols), (50, 4));
    }

    #[test]
    fn norm_preserved_in_expectation() {
        let mut rng = Rng::new(2);
        let a = Mat::gaussian(256, 4, &mut rng);
        let x = rng.gaussians(4);
        let target: f64 = {
            let ax = blas::gemv(&a, &x);
            ax.iter().map(|v| v * v).sum()
        };
        let mut acc = 0.0;
        let trials = 100;
        for _ in 0..trials {
            let srht = Srht::new(128, 256, &mut rng);
            let sa = srht.apply(&a);
            let sax = blas::gemv(&sa, &x);
            acc += sax.iter().map(|v| v * v).sum::<f64>();
        }
        let mean = acc / trials as f64;
        assert!(
            (mean / target - 1.0).abs() < 0.15,
            "mean {mean} vs target {target}"
        );
    }

    #[test]
    fn works_when_n_is_pow2() {
        let mut rng = Rng::new(3);
        let srht = Srht::new(64, 512, &mut rng);
        let a = Mat::gaussian(512, 3, &mut rng);
        let sa = srht.apply(&a);
        assert_eq!(sa.rows, 64);
    }
}
