//! HDpwAccBatchSGD — Algorithm 6: two-step preconditioning + multi-epoch
//! stochastic accelerated gradient descent (Ghadimi & Lan 2013).
//!
//! After preconditioning, the problem is L = O(1)-smooth and mu = O(1)-
//! strongly convex in the R-metric, so the multi-epoch scheme of Algorithm 5
//! applies with epoch lengths N_s = max(4 sqrt(2L/mu), 64 sigma^2 / (3 mu
//! V0 2^{-s})) and per-epoch step sizes eta_s = min(1/(4L),
//! sqrt(3 V0 2^{-(s-1)} / (2 mu sigma^2 N_s (N_s+1)^2))) — Theorem 5 gives
//! O(log(V0/eps) + d log n / (r eps)) total iterations.

use super::driver::{drive, SolveSession, StepRule};
use super::{estimate_sigma_sq, Solver, SolveReport, SolverOpts};
use crate::backend::Backend;
use crate::data::Dataset;
use crate::precond::PrecondArtifact;
use crate::prox::metric::MetricProjector;
use anyhow::Result;
use std::sync::Arc;

/// Algorithm 6: two-step preconditioning + accelerated mini-batch SGD.
pub struct HdpwAccBatchSgd;

/// Algorithm 6 as a step rule. The multi-epoch structure maps onto the
/// driver loop: `pre_chunk` opens an epoch (computes N_s and eta_s from the
/// measured gap, untimed — schedule work, not solve work), `chunk_len`
/// bounds chunks to the epoch remainder, and `post_eval` restarts from the
/// aggregated iterate when the epoch completes.
#[derive(Default)]
struct HdpwAccRule {
    art: Option<Arc<PrecondArtifact>>,
    metric: Option<Arc<MetricProjector>>,
    scale: f64,
    n_pad: usize,
    r: usize,
    l_smooth: f64,
    mu: f64,
    sigma_sq: f64,
    v0: f64,
    x: Vec<f64>,
    xhat: Vec<f64>,
    epoch: usize,
    t_done: usize,
    n_s: usize,
    eta_s: f64,
    exhausted: bool,
}

impl StepRule for HdpwAccRule {
    fn name(&self) -> &'static str {
        "hdpwaccbatchsgd"
    }

    fn setup(&mut self, sess: &mut SolveSession) -> Result<()> {
        let art = sess.precond(true)?;
        self.metric = sess.metric(&art);
        self.art = Some(art);
        Ok(())
    }

    fn init(&mut self, sess: &mut SolveSession, x0: &[f64], f0: f64) -> Result<()> {
        let art = self.art.as_ref().expect("setup ran");
        let hd = art.hd_view(sess.ds).expect("two-step artifact");
        let r = sess.opts.batch_size.max(1);
        self.n_pad = hd.n_pad();
        self.scale = 2.0 * self.n_pad as f64 / r as f64;
        self.r = r;
        // constants of the preconditioned problem (kappa(U) = O(1))
        self.l_smooth = 2.0;
        self.mu = 2.0;
        // the sigma^2 probe gathers rows — fallible on disk-backed views
        self.sigma_sq =
            estimate_sigma_sq(sess.backend, &hd, &art.r, x0, &mut sess.rng)? / r as f64;
        // V0 >= f(x0) - f* ; f* >= 0 so f0 is a valid bound
        self.v0 = f0.max(1e-300);
        self.x = x0.to_vec();
        self.xhat = x0.to_vec();
        Ok(())
    }

    fn pre_chunk(&mut self, sess: &mut SolveSession, f: f64) -> Result<Option<f64>> {
        if self.exhausted || self.t_done > 0 {
            return Ok(None); // mid-epoch: schedule already fixed
        }
        // Algorithm 5 sets V_s = V0 2^{-s}, assuming each epoch halves
        // the gap; with an *estimated* sigma^2 that faith-based schedule
        // can collapse eta_s while the gap is still large. We bound the
        // current gap by the measured objective (valid since f* >= 0),
        // which self-corrects the schedule; the theoretical 2^{-s}
        // decay remains its lower envelope.
        let vs = f.min(self.v0).max(1e-300);
        let n_s = (4.0 * (2.0 * self.l_smooth / self.mu).sqrt())
            .max(64.0 * self.sigma_sq / (3.0 * self.mu * vs))
            .ceil() as usize;
        self.n_s = n_s.clamp(4, 100_000);
        // base step of the epoch; the per-iteration step grows linearly
        // (eta_t = eta_s * t), the Ghadimi-Lan AC-SA schedule that gives
        // the accelerated rate. At t = N_s the step equals
        // sqrt(3 V_s / (2 mu sigma^2 N_s)) capped at 1/(4L).
        self.eta_s = sess.opts.eta.unwrap_or_else(|| {
            (3.0 * vs
                / (2.0 * self.mu
                    * self.sigma_sq.max(1e-300)
                    * self.n_s as f64
                    * (self.n_s as f64 + 1.0).powi(2)))
            .sqrt()
        });
        Ok(None) // schedule work is untimed (it was outside the timed region)
    }

    fn chunk_len(&self, sess: &SolveSession, _f: f64) -> usize {
        if self.exhausted {
            0
        } else {
            sess.opts.chunk.min(self.n_s - self.t_done)
        }
    }

    fn step(&mut self, sess: &mut SolveSession, t: usize) -> Result<()> {
        let art = self.art.as_ref().expect("setup ran");
        let hd = art.hd_view(sess.ds).expect("two-step artifact");
        // alpha_t = q_t = 2/(t+1), restarting each epoch
        let idx: Vec<Vec<usize>> = (0..t)
            .map(|_| sess.rng.indices(self.r, self.n_pad))
            .collect();
        let alphas: Vec<f64> = (0..t)
            .map(|k| 2.0 / ((self.t_done + k + 1) as f64 + 1.0))
            .collect();
        let qs = alphas.clone();
        let etas: Vec<f64> = (0..t)
            .map(|k| {
                let t_in_epoch = (self.t_done + k + 1) as f64;
                if let Some(e) = sess.opts.eta {
                    e
                } else {
                    (self.eta_s * t_in_epoch).min(1.0 / (4.0 * self.l_smooth) * 2.0)
                }
            })
            .collect();
        // Same routing as HdpwBatchRule::step: dense artifacts dispatch on
        // the materialized transform; implicit (sparse) artifacts evaluate
        // the chunk's sampled rows on demand and dispatch on local indices.
        let (xn, xh) = match &hd {
            crate::precond::HdView::Dense(h) => sess.backend.acc_chunk(
                &h.hda,
                &h.hdb,
                &self.x,
                &self.xhat,
                &art.pinv,
                &idx,
                &alphas,
                &qs,
                &etas,
                self.mu,
                self.scale,
                sess.opts.constraint.as_ref(),
                self.metric.as_deref(),
            ),
            crate::precond::HdView::Implicit { .. }
            | crate::precond::HdView::ImplicitOnDisk { .. } => {
                let flat: Vec<usize> = idx.iter().flatten().copied().collect();
                // blocked at the batch size: every mini-batch is one CSR
                // pass (or one shard-streamed pass on disk) instead of r
                // per-row passes (same arithmetic)
                let (ma, mb) = hd.gather_blocked(&flat, self.r)?;
                let local: Vec<Vec<usize>> = (0..t)
                    .map(|k| (k * self.r..(k + 1) * self.r).collect())
                    .collect();
                sess.backend.acc_chunk(
                    &ma,
                    &mb,
                    &self.x,
                    &self.xhat,
                    &art.pinv,
                    &local,
                    &alphas,
                    &qs,
                    &etas,
                    self.mu,
                    self.scale,
                    sess.opts.constraint.as_ref(),
                    self.metric.as_deref(),
                )
            }
        };
        self.x = xn;
        self.xhat = xh;
        self.t_done += t;
        Ok(())
    }

    fn eval_x(&self, _sess: &SolveSession) -> Vec<f64> {
        self.xhat.clone()
    }

    fn post_eval(&mut self, _sess: &mut SolveSession, _f: f64) {
        if self.t_done >= self.n_s && self.n_s > 0 {
            // epoch restart from the aggregated iterate p_s = xhat_{N_s}
            self.x = self.xhat.clone();
            self.t_done = 0;
            self.epoch += 1;
            if self.epoch > 60 {
                self.exhausted = true; // V0 2^-60: beyond f64 resolution
            }
        }
    }
}

impl Solver for HdpwAccBatchSgd {
    fn name(&self) -> &'static str {
        "hdpwaccbatchsgd"
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> Result<SolveReport> {
        drive(&mut HdpwAccRule::default(), backend, ds, opts)
    }

    fn step_rule(&self) -> Option<Box<dyn StepRule>> {
        Some(Box::new(HdpwAccRule::default()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::{self, ConstraintSet};
    use crate::linalg::{blas, Mat};
    use crate::solvers::exact::ground_truth;
    use crate::util::rng::Rng;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 1.0 * rng.gaussian();
        }
        Dataset::dense("t", a, b, Some(xt))
    }

    #[test]
    fn converges_unconstrained() {
        let ds = dataset(2048, 8, 1);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 32;
        opts.max_iters = 4000;
        opts.chunk = 100;
        let rep = HdpwAccBatchSgd.solve(&Backend::native(), &ds, &opts).unwrap();
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 0.05, "relative error {rel}");
    }

    #[test]
    fn feasible_under_l1() {
        let ds = dataset(1024, 6, 2);
        let gt = ground_truth(&ds);
        let cons = constraints::l1_ball(gt.l1_radius);
        let mut opts = SolverOpts::default();
        opts.constraint = cons.clone();
        opts.batch_size = 16;
        opts.max_iters = 1000;
        opts.chunk = 100;
        let rep = HdpwAccBatchSgd.solve(&Backend::native(), &ds, &opts).unwrap();
        assert!(cons.contains(&rep.x, 1e-6));
    }

    #[test]
    fn acceleration_no_slower_than_plain_on_iterations() {
        use crate::solvers::hdpw_batch::HdpwBatchSgd;
        let ds = dataset(4096, 8, 3);
        let gt = ground_truth(&ds);
        let eps = 0.02;
        let run = |acc: bool| {
            let mut opts = SolverOpts::default();
            opts.batch_size = 32;
            opts.max_iters = 30_000;
            opts.chunk = 100;
            opts.f_star = Some(gt.f_star);
            opts.eps_abs = Some(eps * gt.f_star);
            let rep = if acc {
                HdpwAccBatchSgd.solve(&Backend::native(), &ds, &opts).unwrap()
            } else {
                HdpwBatchSgd.solve(&Backend::native(), &ds, &opts).unwrap()
            };
            rep.iters_to_rel_err(gt.f_star, eps)
                .unwrap_or(rep.iters.max(1)) as f64
        };
        let it_acc = run(true);
        let it_plain = run(false);
        assert!(
            it_acc <= 3.0 * it_plain,
            "acc {it_acc} vs plain {it_plain}"
        );
    }
}
