//! HDpwAccBatchSGD — Algorithm 6: two-step preconditioning + multi-epoch
//! stochastic accelerated gradient descent (Ghadimi & Lan 2013).
//!
//! After preconditioning, the problem is L = O(1)-smooth and mu = O(1)-
//! strongly convex in the R-metric, so the multi-epoch scheme of Algorithm 5
//! applies with epoch lengths N_s = max(4 sqrt(2L/mu), 64 sigma^2 / (3 mu
//! V0 2^{-s})) and per-epoch step sizes eta_s = min(1/(4L),
//! sqrt(3 V0 2^{-(s-1)} / (2 mu sigma^2 N_s (N_s+1)^2))) — Theorem 5 gives
//! O(log(V0/eps) + d log n / (r eps)) total iterations.

use super::{estimate_sigma_sq, timed, Solver, SolveReport, SolverOpts, TraceRecorder};
use crate::backend::Backend;
use crate::data::Dataset;
use crate::precond::{hd_transform_with, precondition_with};
use crate::sketch::default_sketch_size_for;
use crate::util::rng::Rng;
use crate::util::stats::Timer;

pub struct HdpwAccBatchSgd;

impl Solver for HdpwAccBatchSgd {
    fn name(&self) -> &'static str {
        "hdpwaccbatchsgd"
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> SolveReport {
        let mut rng = Rng::new(opts.seed);
        let d = ds.d();
        let r = opts.batch_size.max(1);
        let s_rows = opts
            .sketch_size
            .unwrap_or_else(|| default_sketch_size_for(ds.n(), d, opts.sketch));

        // ---- setup ---------------------------------------------------------
        let setup_timer = Timer::start();
        let pre =
            precondition_with(backend, &ds.a, opts.sketch, s_rows, &mut rng, opts.block_rows);
        let hd = hd_transform_with(backend, &ds.a, &ds.b, &mut rng);
        let metric = match opts.constraint {
            crate::prox::Constraint::Unconstrained => None,
            _ => Some(crate::prox::metric::MetricProjector::from_r(&pre.r)),
        };
        let setup_secs = setup_timer.secs();

        let n_pad = hd.n_pad;
        let scale = 2.0 * n_pad as f64 / r as f64;
        let x0 = vec![0.0; d];
        let f0 = backend.residual_sq(&ds.a, &ds.b, &x0);

        // constants of the preconditioned problem (kappa(U) = O(1))
        let l_smooth: f64 = 2.0;
        let mu: f64 = 2.0;
        let sigma_sq =
            estimate_sigma_sq(backend, &hd.hda, &hd.hdb, &pre.r, &x0, n_pad, &mut rng)
                / r as f64;
        // V0 >= f(x0) - f* ; f* >= 0 so f0 is a valid bound
        let v0 = f0.max(1e-300);

        let mut rec = TraceRecorder::new(setup_secs, f0);
        let mut x = x0.clone();
        let mut xhat = x0;
        let mut f_cur = f0;
        let mut epoch = 0usize;
        'outer: while !rec.should_stop(opts, f_cur) {
            // Algorithm 5 sets V_s = V0 2^{-s}, assuming each epoch halves
            // the gap; with an *estimated* sigma^2 that faith-based schedule
            // can collapse eta_s while the gap is still large. We bound the
            // current gap by the measured objective (valid since f* >= 0),
            // which self-corrects the schedule; the theoretical 2^{-s}
            // decay remains its lower envelope.
            let vs = f_cur.min(v0).max(1e-300);
            let n_s = (4.0 * (2.0 * l_smooth / mu).sqrt())
                .max(64.0 * sigma_sq / (3.0 * mu * vs))
                .ceil() as usize;
            let n_s = n_s.clamp(4, 100_000);
            // base step of the epoch; the per-iteration step grows linearly
            // (eta_t = eta_s * t), the Ghadimi-Lan AC-SA schedule that gives
            // the accelerated rate. At t = N_s the step equals
            // sqrt(3 V_s / (2 mu sigma^2 N_s)) capped at 1/(4L).
            let eta_s = opts.eta.unwrap_or_else(|| {
                (3.0 * vs
                    / (2.0 * mu
                        * sigma_sq.max(1e-300)
                        * n_s as f64
                        * (n_s as f64 + 1.0).powi(2)))
                .sqrt()
            });
            // run the epoch in chunks; alpha_t = q_t = 2/(t+1) restart each epoch
            let mut t_done = 0usize;
            while t_done < n_s {
                let t_chunk = opts
                    .chunk
                    .min(n_s - t_done)
                    .min(opts.max_iters.saturating_sub(rec.iters()))
                    .max(1);
                let idx: Vec<Vec<usize>> =
                    (0..t_chunk).map(|_| rng.indices(r, n_pad)).collect();
                let alphas: Vec<f64> = (0..t_chunk)
                    .map(|k| 2.0 / ((t_done + k + 1) as f64 + 1.0))
                    .collect();
                let qs = alphas.clone();
                let etas: Vec<f64> = (0..t_chunk)
                    .map(|k| {
                        let t_in_epoch = (t_done + k + 1) as f64;
                        if let Some(e) = opts.eta {
                            e
                        } else {
                            (eta_s * t_in_epoch).min(1.0 / (4.0 * l_smooth) * 2.0)
                        }
                    })
                    .collect();
                let ((xn, xh), secs) = timed(|| {
                    backend.acc_chunk(
                        &hd.hda,
                        &hd.hdb,
                        &x,
                        &xhat,
                        &pre.pinv,
                        &idx,
                        &alphas,
                        &qs,
                        &etas,
                        mu,
                        scale,
                        &opts.constraint,
                        metric.as_ref(),
                    )
                });
                x = xn;
                xhat = xh;
                t_done += t_chunk;
                f_cur = backend.residual_sq(&ds.a, &ds.b, &xhat);
                rec.record(t_chunk, secs, f_cur);
                if rec.should_stop(opts, f_cur) {
                    break 'outer;
                }
            }
            // epoch restart from the aggregated iterate p_s = xhat_{N_s}
            x = xhat.clone();
            epoch += 1;
            if epoch > 60 {
                break; // V0 2^-60: beyond f64 resolution
            }
        }
        let f = backend.residual_sq(&ds.a, &ds.b, &xhat);
        rec.finish("hdpwaccbatchsgd", xhat, f, setup_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{blas, Mat};
    use crate::prox::Constraint;
    use crate::solvers::exact::ground_truth;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 1.0 * rng.gaussian();
        }
        Dataset {
            name: "t".into(),
            a,
            b,
            x_star_planted: Some(xt),
        }
    }

    #[test]
    fn converges_unconstrained() {
        let ds = dataset(2048, 8, 1);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 32;
        opts.max_iters = 4000;
        opts.chunk = 100;
        let rep = HdpwAccBatchSgd.solve(&Backend::native(), &ds, &opts);
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 0.05, "relative error {rel}");
    }

    #[test]
    fn feasible_under_l1() {
        let ds = dataset(1024, 6, 2);
        let gt = ground_truth(&ds);
        let cons = Constraint::L1Ball {
            radius: gt.l1_radius,
        };
        let mut opts = SolverOpts::default();
        opts.constraint = cons;
        opts.batch_size = 16;
        opts.max_iters = 1000;
        opts.chunk = 100;
        let rep = HdpwAccBatchSgd.solve(&Backend::native(), &ds, &opts);
        assert!(cons.contains(&rep.x, 1e-6));
    }

    #[test]
    fn acceleration_no_slower_than_plain_on_iterations() {
        use crate::solvers::hdpw_batch::HdpwBatchSgd;
        let ds = dataset(4096, 8, 3);
        let gt = ground_truth(&ds);
        let eps = 0.02;
        let run = |acc: bool| {
            let mut opts = SolverOpts::default();
            opts.batch_size = 32;
            opts.max_iters = 30_000;
            opts.chunk = 100;
            opts.f_star = Some(gt.f_star);
            opts.eps_abs = Some(eps * gt.f_star);
            let rep = if acc {
                HdpwAccBatchSgd.solve(&Backend::native(), &ds, &opts)
            } else {
                HdpwBatchSgd.solve(&Backend::native(), &ds, &opts)
            };
            rep.iters_to_rel_err(gt.f_star, eps)
                .unwrap_or(rep.iters.max(1)) as f64
        };
        let it_acc = run(true);
        let it_plain = run(false);
        assert!(
            it_acc <= 3.0 * it_plain,
            "acc {it_acc} vs plain {it_plain}"
        );
    }
}
