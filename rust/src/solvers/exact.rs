//! Exact least-squares solver (dense QR) — the ground-truth oracle.
//!
//! Supplies f(x*) for the relative-error y-axes of every figure. For the
//! constrained cases the paper sets the ball radius to the norm of the
//! *unconstrained* optimum, making x* feasible and f* identical — so the
//! unconstrained QR solution doubles as the constrained ground truth in the
//! paper's experimental setup.

use super::{Solver, SolveReport, SolverOpts, TracePoint};
use crate::backend::Backend;
use crate::data::Dataset;
use crate::linalg::qr;
use crate::util::stats::Timer;

pub struct ExactQr;

impl Solver for ExactQr {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn solve(&self, _backend: &Backend, ds: &Dataset, _opts: &SolverOpts) -> SolveReport {
        let t = Timer::start();
        let x = qr::lstsq(&ds.a, &ds.b);
        let secs = t.secs();
        let f = ds.objective(&x);
        SolveReport {
            solver: "exact".into(),
            f_final: f,
            iters: 1,
            setup_secs: 0.0,
            solve_secs: secs,
            trace: vec![TracePoint {
                iters: 1,
                secs,
                f,
            }],
            x,
            precond_cache: crate::precond::CacheOutcome::Off,
        }
    }
}

/// Compute the paper's experimental setup for a dataset: the unconstrained
/// optimum x*, its objective f*, and the l1/l2 radii used for the
/// constrained variants ("we first generate the optimal solution for the
/// unconstrained case, and then set it as the radius of balls").
pub struct GroundTruth {
    pub x_star: Vec<f64>,
    pub f_star: f64,
    pub l1_radius: f64,
    pub l2_radius: f64,
}

pub fn ground_truth(ds: &Dataset) -> GroundTruth {
    let x_star = qr::lstsq(&ds.a, &ds.b);
    let f_star = ds.objective(&x_star);
    let l1_radius = x_star.iter().map(|v| v.abs()).sum();
    let l2_radius = crate::linalg::blas::nrm2(&x_star);
    GroundTruth {
        x_star,
        f_star,
        l1_radius,
        l2_radius,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{blas, Mat};
    use crate::util::rng::Rng;

    fn ds() -> Dataset {
        let mut rng = Rng::new(3);
        let a = Mat::gaussian(200, 6, &mut rng);
        let xt = rng.gaussians(6);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 0.05 * rng.gaussian();
        }
        Dataset {
            name: "t".into(),
            a,
            csr: None,
            b,
            x_star_planted: Some(xt),
        }
    }

    #[test]
    fn exact_achieves_minimum_gradient() {
        let d = ds();
        let rep = ExactQr.solve(&Backend::native(), &d, &SolverOpts::default());
        let g = blas::fused_grad(&d.a, &d.b, &rep.x, 2.0);
        for v in g {
            assert!(v.abs() < 1e-8, "gradient at optimum: {v}");
        }
    }

    #[test]
    fn ground_truth_radii_consistent() {
        let d = ds();
        let gt = ground_truth(&d);
        assert!((gt.l2_radius - blas::nrm2(&gt.x_star)).abs() < 1e-12);
        assert!(gt.l1_radius >= gt.l2_radius); // l1 >= l2 norm always
        assert!(gt.f_star >= 0.0);
        // x* is feasible for both balls at these radii
        use crate::prox::Constraint;
        assert!(Constraint::L1Ball { radius: gt.l1_radius }.contains(&gt.x_star, 1e-9));
        assert!(Constraint::L2Ball { radius: gt.l2_radius }.contains(&gt.x_star, 1e-9));
    }
}
