//! Exact least-squares solver — the ground-truth oracle.
//!
//! Supplies f(x*) for the relative-error y-axes of every figure. For the
//! constrained cases the paper sets the ball radius to the norm of the
//! *unconstrained* optimum, making x* feasible and f* identical — so the
//! unconstrained solution doubles as the constrained ground truth in the
//! paper's experimental setup.
//!
//! Representation routing: dense datasets take Householder QR exactly as
//! before (bit-identical). CSR datasets take [`sparse_lstsq`] — a
//! sketch-preconditioned CGLS that runs in O(nnz) per iteration and **never
//! densifies**: the paper's own step 1 (kappa(AR^{-1}) = O(1)) is what makes
//! plain CGLS converge to machine precision in tens of iterations even at
//! kappa(A) ~ 1e8, where raw normal equations (kappa^2) would be garbage
//! and a dense QR would cost exactly the mirror this refactor removed.

use super::{Solver, SolveReport, SolverOpts, TracePoint};
use crate::backend::Backend;
use crate::data::Dataset;
use crate::linalg::{qr, tri, CsrMat};
use crate::sketch::SketchKind;
use crate::util::rng::Rng;
use crate::util::stats::Timer;
use anyhow::Result;

/// The exact (QR / preconditioned-CGLS) ground-truth oracle.
pub struct ExactQr;

impl Solver for ExactQr {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn solve(
        &self,
        _backend: &Backend,
        ds: &Dataset,
        _opts: &SolverOpts,
    ) -> Result<SolveReport> {
        let t = Timer::start();
        let x = try_lstsq_ds(ds)?;
        let secs = t.secs();
        let f = ds.try_objective(&x)?;
        Ok(SolveReport {
            solver: "exact".into(),
            f_final: f,
            iters: 1,
            setup_secs: 0.0,
            solve_secs: secs,
            trace: vec![TracePoint {
                iters: 1,
                secs,
                f,
            }],
            x,
            precond_cache: crate::precond::CacheOutcome::Off,
            warm_start: "off".into(),
            step2: "off".into(),
        })
    }
}

/// Representation-routed unconstrained least squares (resident datasets).
fn lstsq_ds(ds: &Dataset) -> Vec<f64> {
    match ds.csr() {
        Some(c) => sparse_lstsq(c, &ds.b),
        None => qr::lstsq(ds.dense_if_ready().expect("dense dataset"), &ds.b),
    }
}

/// Fallible routed least squares that also covers on-disk datasets: the
/// oracle is a direct factorization, so the design is materialized through a
/// *charged* scope (the borrow is accounted against the memory budget and
/// released when the solve returns) in the representation matching the
/// flavor — chunked CSR shards reassemble into a CSR matrix for the
/// never-densify [`sparse_lstsq`] route, mmap'd dense files into a dense
/// matrix for Householder QR. Either route is bitwise identical to the
/// resident oracle on the same data.
fn try_lstsq_ds(ds: &Dataset) -> Result<Vec<f64>> {
    if let Some(od) = ds.on_disk() {
        if od.sparse_arith() {
            let (c, _charge) = od.csr_scoped("ground_truth")?;
            return Ok(sparse_lstsq(&c, &ds.b));
        }
        let (a, _charge) = od.dense_scoped("ground_truth")?;
        return Ok(qr::lstsq(&a, &ds.b));
    }
    Ok(lstsq_ds(ds))
}

/// Fixed seed for the oracle's sketch: the ground truth must be a pure,
/// deterministic function of the data (goldens and best-of-k trials rely
/// on it), independent of any job seed.
const ORACLE_SEED: u64 = 0x6D5F_C615_0A17_3E2B;

/// Input-sparsity-time least squares: CountSketch-QR preconditioner (the
/// paper's Algorithm 1, O(nnz) + O(s d^2)), then CGLS on the implicitly
/// preconditioned system `min_y ||A R^{-1} y - b||`. Each iteration is one
/// `A v` and one `A^T v` pass (O(nnz)) plus two d x d triangular solves;
/// with kappa(A R^{-1}) = O(1) the iteration contracts geometrically with a
/// condition-independent rate, reaching f64 resolution in tens of steps.
/// Crucially it never forms A^T A (no kappa^2 squaring) and never builds a
/// dense view of A (zero densify events on the serve path).
pub fn sparse_lstsq(csr: &CsrMat, b: &[f64]) -> Vec<f64> {
    let (n, d) = (csr.rows, csr.cols);
    assert_eq!(n, b.len());
    assert!(n > 0 && d > 0);
    let mut rng = Rng::new(ORACLE_SEED);
    let s = crate::sketch::default_sketch_size_for(n, d, SketchKind::CountSketch);
    let sk = SketchKind::CountSketch.build(s, n, &mut rng);
    let sa = sk.apply_csr(csr);
    let r_f = qr::qr_r(&sa);
    // CGLS in the y = Rx metric
    let mut y = vec![0.0; d];
    let mut res = b.to_vec(); // r_0 = b - (AR^{-1}) y_0, y_0 = 0
    let mut s_vec = tri::solve_upper_t(&r_f, &csr.t_mul_vec(&res));
    let mut p = s_vec.clone();
    let mut gamma: f64 = s_vec.iter().map(|v| v * v).sum();
    let gamma0 = gamma.max(1e-300);
    let maxit = (2 * d + 100).max(200);
    for _ in 0..maxit {
        // ||R^{-T} A^T r||^2 at f64 resolution: converged; a NaN'd gamma
        // (breakdown) bails too
        if gamma.is_nan() || gamma <= 1e-30 * gamma0 {
            break;
        }
        let rp = tri::solve_upper(&r_f, &p);
        let q: Vec<f64> = (0..n).map(|i| csr.row_dot(i, &rp)).collect();
        let qq: f64 = q.iter().map(|v| v * v).sum();
        if qq == 0.0 || !qq.is_finite() {
            break;
        }
        let alpha = gamma / qq;
        for (yi, pi) in y.iter_mut().zip(&p) {
            *yi += alpha * pi;
        }
        for (ri, qi) in res.iter_mut().zip(&q) {
            *ri -= alpha * qi;
        }
        s_vec = tri::solve_upper_t(&r_f, &csr.t_mul_vec(&res));
        let gamma_new: f64 = s_vec.iter().map(|v| v * v).sum();
        let beta = gamma_new / gamma.max(1e-300);
        gamma = gamma_new;
        for (pi, si) in p.iter_mut().zip(&s_vec) {
            *pi = si + beta * *pi;
        }
    }
    tri::solve_upper(&r_f, &y)
}

/// Compute the paper's experimental setup for a dataset: the unconstrained
/// optimum x*, its objective f*, and the l1/l2 radii used for the
/// constrained variants ("we first generate the optimal solution for the
/// unconstrained case, and then set it as the radius of balls").
pub struct GroundTruth {
    /// The unconstrained optimum.
    pub x_star: Vec<f64>,
    /// f at the unconstrained optimum.
    pub f_star: f64,
    /// ||x*||_1 — the paper's derived l1-ball radius.
    pub l1_radius: f64,
    /// ||x*||_2 — the paper's derived l2-ball radius.
    pub l2_radius: f64,
}

/// Compute the [`GroundTruth`] for a dataset (representation-routed).
/// Panics on a disk-backed dataset — those must use [`try_ground_truth`].
pub fn ground_truth(ds: &Dataset) -> GroundTruth {
    assert!(
        ds.on_disk().is_none(),
        "on-disk dataset: use try_ground_truth for fallible shard reads"
    );
    let x_star = lstsq_ds(ds);
    let f_star = ds.objective(&x_star);
    let l1_radius = x_star.iter().map(|v| v.abs()).sum();
    let l2_radius = crate::linalg::blas::nrm2(&x_star);
    GroundTruth {
        x_star,
        f_star,
        l1_radius,
        l2_radius,
    }
}

/// Fallible [`ground_truth`] covering disk-backed datasets: shard reads (or
/// the charged materialization scope) can fail, and that failure propagates
/// as a structured error instead of a panic.
pub fn try_ground_truth(ds: &Dataset) -> Result<GroundTruth> {
    let x_star = try_lstsq_ds(ds)?;
    let f_star = ds.try_objective(&x_star)?;
    let l1_radius = x_star.iter().map(|v| v.abs()).sum();
    let l2_radius = crate::linalg::blas::nrm2(&x_star);
    Ok(GroundTruth {
        x_star,
        f_star,
        l1_radius,
        l2_radius,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{blas, Mat};
    use crate::util::rng::Rng;

    fn ds() -> Dataset {
        let mut rng = Rng::new(3);
        let a = Mat::gaussian(200, 6, &mut rng);
        let xt = rng.gaussians(6);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 0.05 * rng.gaussian();
        }
        Dataset::dense("t", a, b, Some(xt))
    }

    #[test]
    fn exact_achieves_minimum_gradient() {
        let d = ds();
        let rep = ExactQr
            .solve(&Backend::native(), &d, &SolverOpts::default())
            .unwrap();
        let g = blas::fused_grad(d.dense_if_ready().unwrap(), &d.b, &rep.x, 2.0);
        for v in g {
            assert!(v.abs() < 1e-8, "gradient at optimum: {v}");
        }
    }

    #[test]
    fn ground_truth_radii_consistent() {
        let d = ds();
        let gt = ground_truth(&d);
        assert!((gt.l2_radius - blas::nrm2(&gt.x_star)).abs() < 1e-12);
        assert!(gt.l1_radius >= gt.l2_radius); // l1 >= l2 norm always
        assert!(gt.f_star >= 0.0);
        // x* is feasible for both balls at these radii
        use crate::constraints::{ConstraintSet, L1Ball, L2Ball};
        assert!(L1Ball { radius: gt.l1_radius }.contains(&gt.x_star, 1e-9));
        assert!(L2Ball { radius: gt.l2_radius }.contains(&gt.x_star, 1e-9));
    }

    fn sparse_pair(n: usize, d: usize, kappa: f64, seed: u64) -> (Dataset, Mat) {
        // kappa-controlled sparse data via log-spaced column scales; the
        // i % d == j diagonal band guarantees full column rank
        let scales = crate::data::synthetic::log_spaced_spectrum(d, kappa);
        let mut rng = Rng::new(seed);
        let dense = Mat::from_fn(n, d, |i, j| {
            if i % d == j || rng.uniform() < 0.2 {
                rng.gaussian() * scales[j]
            } else {
                0.0
            }
        });
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&dense, &xt);
        for v in &mut b {
            *v += 0.1 * rng.gaussian();
        }
        let dsp = Dataset::from_csr("sp", crate::linalg::CsrMat::from_dense(&dense), b, None);
        (dsp, dense)
    }

    #[test]
    fn sparse_lstsq_matches_dense_qr_without_densifying() {
        for (kappa, tol) in [(1.0, 1e-9), (1e4, 1e-7), (1e8, 1e-4)] {
            let (dsp, dense) = sparse_pair(600, 8, kappa, 11);
            let x_sparse = sparse_lstsq(dsp.csr().unwrap(), &dsp.b);
            let x_dense = qr::lstsq(&dense, &dsp.b);
            let scale = blas::nrm2(&x_dense).max(1.0);
            for (u, v) in x_sparse.iter().zip(&x_dense) {
                assert!(
                    (u - v).abs() < tol * scale,
                    "kappa={kappa}: {u} vs {v} (tol {tol})"
                );
            }
            // the objective gap is second-order in the iterate gap: even the
            // kappa=1e8 solve must pin f* to high relative accuracy
            let f_sparse = dsp.objective(&x_sparse);
            let f_dense = dsp.objective(&x_dense);
            assert!(
                (f_sparse - f_dense).abs() <= 1e-8 * (1.0 + f_dense),
                "kappa={kappa}: f {f_sparse} vs {f_dense}"
            );
            assert!(
                dsp.dense_if_ready().is_none(),
                "the sparse oracle must never materialize a dense view"
            );
        }
    }

    #[test]
    fn sparse_ground_truth_is_deterministic_and_routed() {
        let (dsp, _) = sparse_pair(400, 6, 1e3, 21);
        let g1 = ground_truth(&dsp);
        let g2 = ground_truth(&dsp);
        assert_eq!(g1.x_star, g2.x_star, "oracle is a pure function of the data");
        assert_eq!(g1.f_star.to_bits(), g2.f_star.to_bits());
        // the exact "solver" takes the same sparse route
        let rep = ExactQr
            .solve(&Backend::native(), &dsp, &SolverOpts::default())
            .unwrap();
        assert_eq!(rep.x, g1.x_star);
        assert!(dsp.dense_if_ready().is_none());
    }
}
