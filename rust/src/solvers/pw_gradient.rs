//! pwGradient — Algorithm 4, the paper's high-precision contribution.
//!
//! One sketch, one QR, then preconditioned projected gradient descent:
//!     x_{t+1} = P_W(x_t - 2 eta R^{-1} R^{-T} A^T (A x_t - b)).
//! Because kappa(A R^{-1}) = O(1), plain GD converges linearly (Theorem 6);
//! with eta = 1/2 each step is *exactly* one Iterative Hessian Sketch
//! iteration with the sketch frozen — the paper's key observation that one
//! sketch suffices, removing IHS's per-iteration re-sketching cost.

use super::driver::{drive, SolveSession, StepRule};
use super::{Solver, SolveReport, SolverOpts};
use crate::backend::Backend;
use crate::constraints::ConstraintSet;
use crate::data::Dataset;
use crate::linalg::blas;
use crate::precond::PrecondArtifact;
use crate::prox::metric::MetricProjector;
use anyhow::Result;
use std::sync::Arc;

/// Algorithm 4: one-sketch preconditioned projected gradient descent.
pub struct PwGradient;

/// Algorithm 4 as a step rule: setup acquires ONE sketch-QR artifact (the
/// whole point vs IHS — and exactly what the preconditioner cache reuses),
/// then every chunk is plain preconditioned projected gradient descent.
#[derive(Default)]
struct PwGradientRule {
    art: Option<Arc<PrecondArtifact>>,
    metric: Option<Arc<MetricProjector>>,
    eta: f64,
    x: Vec<f64>,
}

impl StepRule for PwGradientRule {
    fn name(&self) -> &'static str {
        "pwgradient"
    }

    fn setup(&mut self, sess: &mut SolveSession) -> Result<()> {
        let art = sess.precond(false)?;
        self.metric = sess.metric(&art);
        self.art = Some(art);
        Ok(())
    }

    fn init(&mut self, sess: &mut SolveSession, x0: &[f64], _f0: f64) -> Result<()> {
        // eta = 1/2 realizes the IHS-equivalent step (paper's default).
        self.eta = sess.opts.eta.unwrap_or(0.5);
        self.x = x0.to_vec();
        Ok(())
    }

    fn chunk_len(&self, sess: &SolveSession, _f: f64) -> usize {
        // full-gradient steps are expensive; trace every few steps
        sess.opts.chunk.clamp(1, 10)
    }

    fn step(&mut self, sess: &mut SolveSession, t: usize) -> Result<()> {
        let art = self.art.as_ref().expect("setup ran");
        if let Some(od) = sess.ds.on_disk() {
            // shard-streamed full gradient; the rest of the update is the
            // same arithmetic order as the native executor's chunk (fused
            // gradient, pinv apply, axpy, project), so traces stay bitwise
            // comparable to the resident runs
            for _ in 0..t {
                let g = od.fused_grad(&sess.ds.b, &self.x, 2.0)?;
                let step = blas::gemv(&art.pinv, &g);
                for (xi, si) in self.x.iter_mut().zip(&step) {
                    *xi -= self.eta * si;
                }
                match self.metric.as_deref() {
                    Some(m) => self.x = m.project(&self.x, sess.opts.constraint.as_ref()),
                    None => sess.opts.constraint.project(&mut self.x),
                }
            }
            return Ok(());
        }
        match sess.ds.csr() {
            // O(nnz) per step straight off the sparse rows: the same
            // arithmetic order as the native executor's chunk (fused
            // gradient, pinv apply, axpy, project) with zero densification
            Some(csr) => {
                for _ in 0..t {
                    let g = csr.fused_grad(&sess.ds.b, &self.x, 2.0);
                    let step = blas::gemv(&art.pinv, &g);
                    for (xi, si) in self.x.iter_mut().zip(&step) {
                        *xi -= self.eta * si;
                    }
                    match self.metric.as_deref() {
                        Some(m) => self.x = m.project(&self.x, sess.opts.constraint.as_ref()),
                        None => sess.opts.constraint.project(&mut self.x),
                    }
                }
            }
            None => {
                self.x = sess.backend.pw_gradient_chunk(
                    sess.ds.dense_if_ready().expect("dense dataset"),
                    &sess.ds.b,
                    &self.x,
                    &art.pinv,
                    self.eta,
                    t,
                    sess.opts.constraint.as_ref(),
                    self.metric.as_deref(),
                );
            }
        }
        Ok(())
    }

    fn eval_x(&self, _sess: &SolveSession) -> Vec<f64> {
        self.x.clone()
    }
}

impl Solver for PwGradient {
    fn name(&self) -> &'static str {
        "pwgradient"
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> Result<SolveReport> {
        drive(&mut PwGradientRule::default(), backend, ds, opts)
    }

    fn step_rule(&self) -> Option<Box<dyn StepRule>> {
        Some(Box::new(PwGradientRule::default()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints;
    use crate::linalg::{blas, Mat};
    use crate::solvers::exact::ground_truth;
    use crate::util::rng::Rng;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 0.05 * rng.gaussian();
        }
        Dataset::dense("t", a, b, Some(xt))
    }

    #[test]
    fn reaches_high_precision_unconstrained() {
        let ds = dataset(2048, 10, 1);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.max_iters = 200;
        opts.f_star = Some(gt.f_star);
        opts.eps_abs = Some(1e-10 * gt.f_star);
        let rep = PwGradient.solve(&Backend::native(), &ds, &opts).unwrap();
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 1e-9, "relative error {rel}");
    }

    #[test]
    fn linear_convergence_rate() {
        // successive trace points must show geometric decrease of f - f*
        let ds = dataset(2048, 8, 2);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.max_iters = 40;
        opts.chunk = 2;
        let rep = PwGradient.solve(&Backend::native(), &ds, &opts).unwrap();
        let errs: Vec<f64> = rep
            .trace
            .iter()
            .map(|p| (p.f - gt.f_star).max(1e-300))
            .collect();
        // compare error at consecutive checkpoints until the f64 floor
        let mut ratios = Vec::new();
        for w in errs.windows(2) {
            if w[0] > 1e-10 * gt.f_star && w[1] > 0.0 {
                ratios.push(w[1] / w[0]);
            }
        }
        assert!(!ratios.is_empty());
        let worst = ratios.iter().cloned().fold(0.0, f64::max);
        assert!(worst < 0.9, "not linear: worst ratio {worst} ({ratios:?})");
    }

    #[test]
    fn handles_ill_conditioned_data() {
        // kappa = 1e6 synthetic — raw GD would crawl; pwGradient must not.
        let spec = crate::data::synthetic::SynSpec {
            name: "ill".into(),
            n: 2048,
            d: 8,
            kappa: 1e6,
            noise: 0.01,
            signal_scale: 1.0,
        };
        let ds = crate::data::synthetic::generate(&spec, &mut Rng::new(5));
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.max_iters = 150;
        opts.f_star = Some(gt.f_star);
        opts.eps_abs = Some(1e-8 * gt.f_star.max(1e-12));
        let rep = PwGradient.solve(&Backend::native(), &ds, &opts).unwrap();
        let rel = (rep.f_final - gt.f_star) / gt.f_star.max(1e-12);
        assert!(rel < 1e-6, "relative error {rel}");
    }

    #[test]
    fn constrained_l2_converges_and_feasible() {
        let ds = dataset(1024, 6, 3);
        let gt = ground_truth(&ds);
        // radius set to HALF the unconstrained optimum: active constraint
        let cons = constraints::l2_ball(0.5 * gt.l2_radius);
        let mut opts = SolverOpts::default();
        opts.constraint = cons.clone();
        opts.max_iters = 300;
        let rep = PwGradient.solve(&Backend::native(), &ds, &opts).unwrap();
        assert!(cons.contains(&rep.x, 1e-9));
        // the last ~5 trace values should have stabilized (projected GD
        // converges to the constrained optimum)
        let tail: Vec<f64> = rep.trace.iter().rev().take(5).map(|p| p.f).collect();
        let spread = tail.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            - tail.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread < 1e-6 * tail[0], "not stabilized: {tail:?}");
        // and must beat the best unconstrained-infeasible value projected
        assert!(rep.f_final >= gt.f_star);
    }
}
