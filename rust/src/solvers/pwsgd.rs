//! pwSGD (Yang, Chow, Ré & Mahoney 2016) — the paper's low-precision
//! state-of-the-art baseline.
//!
//! Shares step 1 with HDpwBatchSGD (sketch-QR preconditioner R), but instead
//! of the second Hadamard preconditioning step it performs *weighted* SGD:
//! rows are sampled with probability proportional to their (approximate)
//! leverage scores l_i = ||A_i R^{-1}||^2, with importance-weighted
//! unbiased gradients. Leverage scores are approximated with a JL projection
//! (A R^{-1} G for gaussian G in R^{d x k}), the estimator Yang et al.'s
//! complexity analysis assumes; set `exact_scores: true` to reproduce their
//! experimental variant (exact scores, O(nd^2) — what the paper notes the
//! authors actually used in experiments).

use super::{timed, Solver, SolveReport, SolverOpts, TraceRecorder};
use crate::backend::Backend;
use crate::data::Dataset;
use crate::linalg::{blas, tri, Mat};
use crate::precond::precondition_with;
use crate::sketch::default_sketch_size_for;
use crate::util::rng::{AliasTable, Rng};
use crate::util::stats::Timer;

pub struct PwSgd;

/// JL sketch width for approximate leverage scores.
const JL_K: usize = 8;

/// Compute approximate leverage scores l_i ~ ||A_i R^{-1}||^2 via
/// G-projection: l_i = ||A_i (R^{-1} G)||^2 * (d / k) with G d x k gaussian.
pub fn approx_leverage_scores(a: &Mat, r_factor: &Mat, rng: &mut Rng) -> Vec<f64> {
    let d = a.cols;
    let k = JL_K.min(d);
    // R^{-1} G: k triangular solves
    let mut rg = Mat::zeros(d, k);
    for j in 0..k {
        let g: Vec<f64> = rng.gaussians(d);
        let col = tri::solve_upper(r_factor, &g);
        for i in 0..d {
            *rg.at_mut(i, j) = col[i];
        }
    }
    let proj = blas::gemm(a, &rg); // n x k
    let correction = 1.0 / k as f64;
    (0..a.rows)
        .map(|i| {
            let row = proj.row(i);
            row.iter().map(|v| v * v).sum::<f64>() * correction
        })
        .collect()
}

/// Exact leverage scores ||A_i R^{-1}||^2 (O(nd^2); experiment parity mode).
pub fn exact_leverage_scores(a: &Mat, r_factor: &Mat) -> Vec<f64> {
    let rinv = tri::inv_upper(r_factor);
    let u = blas::gemm(a, &rinv);
    (0..u.rows)
        .map(|i| u.row(i).iter().map(|v| v * v).sum())
        .collect()
}

impl Solver for PwSgd {
    fn name(&self) -> &'static str {
        "pwsgd"
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> SolveReport {
        let mut rng = Rng::new(opts.seed);
        let n = ds.n();
        let d = ds.d();
        let s = opts
            .sketch_size
            .unwrap_or_else(|| default_sketch_size_for(n, d, opts.sketch));

        // ---- setup: preconditioner + leverage scores + alias table ---------
        let setup_timer = Timer::start();
        let pre = precondition_with(backend, &ds.a, opts.sketch, s, &mut rng, opts.block_rows);
        let scores = approx_leverage_scores(&ds.a, &pre.r, &mut rng);
        let total: f64 = scores.iter().sum();
        let probs: Vec<f64> = scores.iter().map(|l| (l / total).max(1e-300)).collect();
        let alias = AliasTable::new(&scores);
        let metric = match opts.constraint {
            crate::prox::Constraint::Unconstrained => None,
            _ => Some(crate::prox::metric::MetricProjector::from_r(&pre.r)),
        };
        let setup_secs = setup_timer.secs();

        let x0 = vec![0.0; d];
        let f0 = backend.residual_sq(&ds.a, &ds.b, &x0);
        // Yang et al. run r = 1 (their mini-batch variant has no guarantee);
        // we honor opts.batch_size but default figures use 1.
        let r = opts.batch_size.max(1);
        // step size: same theory scale as HDpw (the preconditioned problem
        // is O(1)-smooth); variance estimated from a few weighted draws.
        let mut sig = 0.0;
        for _ in 0..16 {
            let i = alias.sample(&mut rng);
            // single-draw estimator: grad = (1/p_i) * grad f_i, so the
            // coefficient on A_i is 2 * residual_i / p_i
            let gi = 2.0 * (blas::dot(ds.a.row(i), &x0) - ds.b[i]) / probs[i];
            let c: Vec<f64> = ds.a.row(i).iter().map(|v| gi * v).collect();
            let y = tri::solve_upper_t(&pre.r, &c);
            sig += blas::dot(&y, &y);
        }
        let sigma_sq = sig / 15.0 / r as f64;
        let eta =
            super::theory_step_size(opts, sigma_sq, f0, opts.max_iters, pre.r.frob_norm());

        let mut rec = TraceRecorder::new(setup_secs, f0);
        let mut x = x0;
        let mut xsum = vec![0.0; d];
        let mut total_t = 0usize;
        let mut f = f0;
        while !rec.should_stop(opts, f) {
            let t_chunk = opts.chunk.min(opts.max_iters - rec.iters()).max(1);
            let (_, secs) = timed(|| {
                for _ in 0..t_chunk {
                    // weighted sample of r rows; importance-weighted gradient
                    let mut c = vec![0.0; d];
                    for _ in 0..r {
                        let i = alias.sample(&mut rng);
                        let w = 1.0 / (n as f64 * probs[i] * r as f64);
                        let gi =
                            2.0 * n as f64 * w * (blas::dot(ds.a.row(i), &x) - ds.b[i]);
                        blas::axpy(gi, ds.a.row(i), &mut c);
                    }
                    let step = blas::gemv(&pre.pinv, &c);
                    for (xi, si) in x.iter_mut().zip(&step) {
                        *xi -= eta * si;
                    }
                    match &metric {
                        Some(m) => x = m.project(&x, &opts.constraint),
                        None => opts.constraint.project(&mut x),
                    }
                    for (acc, xi) in xsum.iter_mut().zip(&x) {
                        *acc += xi;
                    }
                    total_t += 1;
                }
            });
            let xavg: Vec<f64> = xsum.iter().map(|v| v / total_t as f64).collect();
            f = backend.residual_sq(&ds.a, &ds.b, &xavg);
            rec.record(t_chunk, secs, f);
        }
        let xavg: Vec<f64> = xsum
            .iter()
            .map(|v| v / total_t.max(1) as f64)
            .collect();
        let f = backend.residual_sq(&ds.a, &ds.b, &xavg);
        rec.finish("pwsgd", xavg, f, setup_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::exact::ground_truth;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 1.0 * rng.gaussian();
        }
        Dataset {
            name: "t".into(),
            a,
            b,
            x_star_planted: Some(xt),
        }
    }

    #[test]
    fn approx_scores_track_exact() {
        // spiky data: 10 rows carry 30x the scale, so their leverage scores
        // dominate and the JL approximation must surface them.
        let mut rng = Rng::new(1);
        let mut a = Mat::gaussian(500, 8, &mut rng);
        for i in 0..10 {
            for v in a.row_mut(i) {
                *v *= 30.0;
            }
        }
        let r = crate::linalg::qr::qr_r(&a);
        let approx = approx_leverage_scores(&a, &r, &mut rng);
        let exact = exact_leverage_scores(&a, &r);
        // totals must agree within JL error; total exact = d
        let ta: f64 = approx.iter().sum();
        let te: f64 = exact.iter().sum();
        assert!((te - 8.0).abs() < 1e-8, "sum of leverage scores = d");
        assert!((ta / te - 1.0).abs() < 0.5, "JL total off: {ta} vs {te}");
        // the 10 spiky rows must all rank in the approx top-20
        let mut idx: Vec<usize> = (0..approx.len()).collect();
        idx.sort_by(|&i, &j| approx[j].partial_cmp(&approx[i]).unwrap());
        let top20 = &idx[..20];
        for i in 0..10 {
            assert!(top20.contains(&i), "spiky row {i} not in approx top-20");
        }
    }

    #[test]
    fn converges_low_precision() {
        let ds = dataset(2048, 8, 2);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 1;
        opts.max_iters = 6000;
        opts.chunk = 500;
        let rep = PwSgd.solve(&Backend::native(), &ds, &opts);
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 0.1, "relative error {rel}");
    }

    #[test]
    fn handles_spiky_leverage_data() {
        // pwSGD's whole point: weighted sampling copes with spiky rows.
        let mut rng = Rng::new(3);
        let mut a = Mat::gaussian(1024, 6, &mut rng);
        for j in 0..50 {
            for v in a.row_mut(j) {
                *v *= 30.0;
            }
        }
        let xt = rng.gaussians(6);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 1.0 * rng.gaussian();
        }
        let ds = Dataset {
            name: "spiky".into(),
            a,
            b,
            x_star_planted: None,
        };
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 1;
        opts.max_iters = 20_000;
        opts.chunk = 1000;
        let rep = PwSgd.solve(&Backend::native(), &ds, &opts);
        let rel = (rep.f_final - gt.f_star) / gt.f_star.max(1e-12);
        assert!(rel < 0.5, "relative error {rel}");
    }
}
