//! pwSGD (Yang, Chow, Ré & Mahoney 2016) — the paper's low-precision
//! state-of-the-art baseline.
//!
//! Shares step 1 with HDpwBatchSGD (sketch-QR preconditioner R), but instead
//! of the second Hadamard preconditioning step it performs *weighted* SGD:
//! rows are sampled with probability proportional to their (approximate)
//! leverage scores l_i = ||A_i R^{-1}||^2, with importance-weighted
//! unbiased gradients. Leverage scores are approximated with a JL projection
//! (A R^{-1} G for gaussian G in R^{d x k}), the estimator Yang et al.'s
//! complexity analysis assumes; set `exact_scores: true` to reproduce their
//! experimental variant (exact scores, O(nd^2) — what the paper notes the
//! authors actually used in experiments).

use super::driver::{drive, SolveSession, StepRule};
use super::{Solver, SolveReport, SolverOpts};
use crate::backend::Backend;
use crate::constraints::ConstraintSet;
use crate::data::Dataset;
use crate::linalg::{blas, tri, Mat};
use crate::precond::PrecondArtifact;
use crate::prox::metric::MetricProjector;
use crate::util::rng::{AliasTable, Rng};
use anyhow::Result;
use std::sync::Arc;

/// Leverage-score weighted SGD (Yang et al. 2016 baseline).
pub struct PwSgd;

/// JL sketch width for approximate leverage scores.
const JL_K: usize = 8;

/// The JL projection matrix `R^{-1} G` (d x k) — the rng draws are made in
/// a fixed order regardless of data representation, so dense and sparse
/// score paths consume identical rng streams.
fn jl_projection(d: usize, r_factor: &Mat, rng: &mut Rng) -> Mat {
    let k = JL_K.min(d);
    // R^{-1} G: k triangular solves
    let mut rg = Mat::zeros(d, k);
    for j in 0..k {
        let g: Vec<f64> = rng.gaussians(d);
        let col = tri::solve_upper(r_factor, &g);
        for i in 0..d {
            *rg.at_mut(i, j) = col[i];
        }
    }
    rg
}

/// Scores from the projected rows: l_i = ||(A rg)_i||^2 / k.
fn scores_from_projection(proj: &Mat, k: usize) -> Vec<f64> {
    let correction = 1.0 / k as f64;
    (0..proj.rows)
        .map(|i| {
            let row = proj.row(i);
            row.iter().map(|v| v * v).sum::<f64>() * correction
        })
        .collect()
}

/// Compute approximate leverage scores l_i ~ ||A_i R^{-1}||^2 via
/// G-projection: l_i = ||A_i (R^{-1} G)||^2 * (d / k) with G d x k gaussian.
pub fn approx_leverage_scores(a: &Mat, r_factor: &Mat, rng: &mut Rng) -> Vec<f64> {
    let k = JL_K.min(a.cols);
    let rg = jl_projection(a.cols, r_factor, rng);
    let proj = blas::gemm(a, &rg); // n x k
    scores_from_projection(&proj, k)
}

/// Representation-aware leverage scores: sparse datasets project via the
/// O(nnz * k) CSR spmm instead of the dense O(n d k) gemm; the dense branch
/// is the exact pre-sparse arithmetic; on-disk datasets stream the A·(R⁻¹G)
/// product shard by shard (the one fallible route — resident arms never
/// return `Err`).
pub fn approx_leverage_scores_ds(
    ds: &Dataset,
    r_factor: &Mat,
    rng: &mut Rng,
) -> Result<Vec<f64>> {
    let k = JL_K.min(ds.d());
    let rg = jl_projection(ds.d(), r_factor, rng);
    let proj = if let Some(od) = ds.on_disk() {
        od.mul_dense(&rg)?
    } else {
        match ds.csr() {
            Some(c) => c.spmm_dense(&rg),
            None => blas::gemm(ds.dense_if_ready().expect("dense dataset"), &rg),
        }
    };
    Ok(scores_from_projection(&proj, k))
}

/// Exact leverage scores ||A_i R^{-1}||^2 (O(nd^2); experiment parity mode).
pub fn exact_leverage_scores(a: &Mat, r_factor: &Mat) -> Vec<f64> {
    let rinv = tri::inv_upper(r_factor);
    let u = blas::gemm(a, &rinv);
    (0..u.rows)
        .map(|i| u.row(i).iter().map(|v| v * v).sum())
        .collect()
}

/// Yang et al.'s leverage-score weighted SGD as a step rule. Setup acquires
/// the step-1 artifact, then derives the per-trial sampling machinery
/// (approximate scores via a JL projection, alias table) — the scores are
/// rng-dependent, so they stay per-trial even when the artifact is cached.
#[derive(Default)]
struct PwSgdRule {
    art: Option<Arc<PrecondArtifact>>,
    metric: Option<Arc<MetricProjector>>,
    probs: Vec<f64>,
    alias: Option<AliasTable>,
    eta: f64,
    r: usize,
    n: usize,
    x: Vec<f64>,
    x0: Vec<f64>,
    xsum: Vec<f64>,
    total_t: usize,
}

impl StepRule for PwSgdRule {
    fn name(&self) -> &'static str {
        "pwsgd"
    }

    fn setup(&mut self, sess: &mut SolveSession) -> Result<()> {
        // preconditioner + leverage scores + alias table, all on the setup
        // clock (the scores are what pwSGD pays beyond HDpw's setup);
        // sparse datasets project scores in O(nnz * k)
        let art = sess.precond(false)?;
        let scores = approx_leverage_scores_ds(sess.ds, &art.r, &mut sess.rng)?;
        let total: f64 = scores.iter().sum();
        self.probs = scores.iter().map(|l| (l / total).max(1e-300)).collect();
        self.alias = Some(AliasTable::new(&scores));
        self.metric = sess.metric(&art);
        self.art = Some(art);
        Ok(())
    }

    fn init(&mut self, sess: &mut SolveSession, x0: &[f64], f0: f64) -> Result<()> {
        let art = self.art.as_ref().expect("setup ran");
        let alias = self.alias.as_ref().expect("setup ran");
        let n = sess.ds.n();
        // Yang et al. run r = 1 (their mini-batch variant has no guarantee);
        // we honor opts.batch_size but default figures use 1.
        let r = sess.opts.batch_size.max(1);
        // step size: same theory scale as HDpw (the preconditioned problem
        // is O(1)-smooth); variance estimated from a few weighted draws.
        let mut sig = 0.0;
        for _ in 0..16 {
            let i = alias.sample(&mut sess.rng);
            // single-draw estimator: grad = (1/p_i) * grad f_i, so the
            // coefficient on A_i is 2 * residual_i / p_i; row access is
            // O(nnz(row)) on sparse datasets (try_row_dot/try_row_scaled
            // are bit-identical blas calls on dense ones and fallible
            // shard-cache gathers on disk)
            let gi = 2.0 * (sess.ds.try_row_dot(i, x0)? - sess.ds.b[i]) / self.probs[i];
            let c = sess.ds.try_row_scaled(i, gi)?;
            let y = tri::solve_upper_t(&art.r, &c);
            sig += blas::dot(&y, &y);
        }
        let sigma_sq = sig / 15.0 / r as f64;
        self.eta = super::theory_step_size(
            sess.opts,
            sigma_sq,
            f0,
            sess.opts.max_iters,
            art.r.frob_norm(),
        );
        self.r = r;
        self.n = n;
        self.x = x0.to_vec();
        self.x0 = x0.to_vec();
        self.xsum = vec![0.0; x0.len()];
        Ok(())
    }

    fn chunk_len(&self, sess: &SolveSession, _f: f64) -> usize {
        sess.opts.chunk
    }

    fn step(&mut self, sess: &mut SolveSession, t: usize) -> Result<()> {
        let art = self.art.as_ref().expect("setup ran");
        let alias = self.alias.as_ref().expect("setup ran");
        let d = self.x.len();
        let n = self.n as f64;
        for _ in 0..t {
            // weighted sample of r rows; importance-weighted gradient —
            // row dot + scatter are O(nnz(row)) on sparse datasets
            let mut c = vec![0.0; d];
            for _ in 0..self.r {
                let i = alias.sample(&mut sess.rng);
                let w = 1.0 / (n * self.probs[i] * self.r as f64);
                let gi = 2.0 * n * w * (sess.ds.try_row_dot(i, &self.x)? - sess.ds.b[i]);
                sess.ds.try_row_axpy(i, gi, &mut c)?;
            }
            let step = blas::gemv(&art.pinv, &c);
            for (xi, si) in self.x.iter_mut().zip(&step) {
                *xi -= self.eta * si;
            }
            match self.metric.as_deref() {
                Some(m) => self.x = m.project(&self.x, sess.opts.constraint.as_ref()),
                None => sess.opts.constraint.project(&mut self.x),
            }
            for (acc, xi) in self.xsum.iter_mut().zip(&self.x) {
                *acc += xi;
            }
            self.total_t += 1;
        }
        Ok(())
    }

    fn eval_x(&self, _sess: &SolveSession) -> Vec<f64> {
        if self.total_t == 0 {
            self.x0.clone()
        } else {
            self.xsum
                .iter()
                .map(|v| v / self.total_t as f64)
                .collect()
        }
    }
}

impl Solver for PwSgd {
    fn name(&self) -> &'static str {
        "pwsgd"
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> Result<SolveReport> {
        drive(&mut PwSgdRule::default(), backend, ds, opts)
    }

    fn step_rule(&self) -> Option<Box<dyn StepRule>> {
        Some(Box::new(PwSgdRule::default()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::exact::ground_truth;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 1.0 * rng.gaussian();
        }
        Dataset::dense("t", a, b, Some(xt))
    }

    #[test]
    fn ds_scores_match_plain_scores_on_both_representations() {
        use crate::linalg::CsrMat;
        let mut rng = Rng::new(31);
        let a = Mat::from_fn(300, 8, |_, _| {
            if rng.uniform() < 0.3 {
                rng.gaussian()
            } else {
                0.0
            }
        });
        let r = crate::linalg::qr::qr_r(&a);
        let b = rng.gaussians(300);
        let dense_ds = Dataset::dense("t", a.clone(), b.clone(), None);
        let sparse_ds = Dataset::from_csr("t", CsrMat::from_dense(&a), b, None);
        // identical rng streams: dense branch is bit-identical to the plain
        // helper; sparse branch matches within fp re-association
        let plain = approx_leverage_scores(&a, &r, &mut Rng::new(7));
        let via_dense = approx_leverage_scores_ds(&dense_ds, &r, &mut Rng::new(7)).unwrap();
        let via_sparse = approx_leverage_scores_ds(&sparse_ds, &r, &mut Rng::new(7)).unwrap();
        assert_eq!(plain, via_dense, "dense path must be bit-identical");
        for (p, s) in plain.iter().zip(&via_sparse) {
            assert!((p - s).abs() < 1e-10 * (1.0 + p.abs()), "{p} vs {s}");
        }
    }

    #[test]
    fn approx_scores_track_exact() {
        // spiky data: 10 rows carry 30x the scale, so their leverage scores
        // dominate and the JL approximation must surface them.
        let mut rng = Rng::new(1);
        let mut a = Mat::gaussian(500, 8, &mut rng);
        for i in 0..10 {
            for v in a.row_mut(i) {
                *v *= 30.0;
            }
        }
        let r = crate::linalg::qr::qr_r(&a);
        let approx = approx_leverage_scores(&a, &r, &mut rng);
        let exact = exact_leverage_scores(&a, &r);
        // totals must agree within JL error; total exact = d
        let ta: f64 = approx.iter().sum();
        let te: f64 = exact.iter().sum();
        assert!((te - 8.0).abs() < 1e-8, "sum of leverage scores = d");
        assert!((ta / te - 1.0).abs() < 0.5, "JL total off: {ta} vs {te}");
        // the 10 spiky rows must all rank in the approx top-20
        let mut idx: Vec<usize> = (0..approx.len()).collect();
        idx.sort_by(|&i, &j| approx[j].partial_cmp(&approx[i]).unwrap());
        let top20 = &idx[..20];
        for i in 0..10 {
            assert!(top20.contains(&i), "spiky row {i} not in approx top-20");
        }
    }

    #[test]
    fn converges_low_precision() {
        let ds = dataset(2048, 8, 2);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 1;
        opts.max_iters = 6000;
        opts.chunk = 500;
        let rep = PwSgd.solve(&Backend::native(), &ds, &opts).unwrap();
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 0.1, "relative error {rel}");
    }

    #[test]
    fn handles_spiky_leverage_data() {
        // pwSGD's whole point: weighted sampling copes with spiky rows.
        let mut rng = Rng::new(3);
        let mut a = Mat::gaussian(1024, 6, &mut rng);
        for j in 0..50 {
            for v in a.row_mut(j) {
                *v *= 30.0;
            }
        }
        let xt = rng.gaussians(6);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 1.0 * rng.gaussian();
        }
        let ds = Dataset::dense("spiky", a, b, None);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 1;
        opts.max_iters = 20_000;
        opts.chunk = 1000;
        let rep = PwSgd.solve(&Backend::native(), &ds, &opts).unwrap();
        let rel = (rep.f_final - gt.f_star) / gt.f_star.max(1e-12);
        assert!(rel < 0.5, "relative error {rel}");
    }
}
