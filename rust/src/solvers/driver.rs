//! The unified solve-session driver.
//!
//! Every solver used to re-implement the same frame: seed an rng, time a
//! setup phase (sketch-QR, HD transform, metric projector), evaluate f at
//! x0, then loop chunks against the eps/time/iter stopping rules while
//! recording a trace. [`SolveSession`] owns that frame once; the solvers
//! shrink to [`StepRule`]s — just the arithmetic that advances iterates —
//! and [`drive`] runs the shared loop.
//!
//! The session also owns *artifact acquisition*. On the default path
//! (`SessionCtx::reuse_precond == false`) it computes the preconditioner
//! inline from the session rng, consuming the stream in exactly the order
//! the pre-driver solvers did — traces are bit-compatible with the paper's
//! fresh-sketch-per-trial protocol. With reuse enabled it consults the
//! coordinator's [`PrecondCache`]: artifacts are keyed, sampled from
//! key-derived rng streams (trial streams never observe cache state), and
//! `setup_secs` collapses to the lookup cost on a hit.
//!
//! Acquisition is fallible: artifact construction materializes through the
//! session's [`MemBudget`] (the HD transform's padded buffer — the only
//! dense object a sparse dataset's setup ever builds), so an over-budget
//! request propagates out of [`drive`] as a structured error the serve
//! loop reports — never a panic, never an OOM.

use super::{timed, SolveReport, SolverOpts, TraceRecorder};
use crate::backend::Backend;
use crate::constraints::ConstraintSet;
use crate::data::Dataset;
use crate::precond::{
    precondition_ds_budgeted, resolve_step2, CacheOutcome, Lookup, PrecondArtifact, PrecondCache,
    PrecondKey, Precondition, Step2Mode,
};
use crate::prox::metric::MetricProjector;
use crate::sketch::default_sketch_size_for;
use crate::util::mem::MemBudget;
use crate::util::rng::Rng;
use crate::util::stats::Timer;
use anyhow::Result;
use std::sync::Arc;

/// Per-request session context threaded from the coordinator into
/// [`SolverOpts`]. The default (everything off) reproduces the paper's
/// protocol: fresh sketch per trial, cold start, no shared state.
#[derive(Clone, Debug, Default)]
pub struct SessionCtx {
    /// Acquire the preconditioner cache-or-compute instead of resampling.
    pub reuse_precond: bool,
    /// Start from `x0` (a previous trial's best iterate) instead of zeros.
    pub warm_start: bool,
    /// The coordinator's shared artifact cache (None = always compute).
    pub cache: Option<Arc<PrecondCache>>,
    /// Cache-key dataset identity (the coordinator's prepared-dataset key).
    pub dataset_id: Option<String>,
    /// Artifact sampling seed — the *job* seed, shared by all trials.
    pub artifact_seed: u64,
    /// Warm-start iterate (used only when `warm_start` is set).
    pub x0: Option<Vec<f64>>,
    /// Memory budget charged by dense materializations (HD buffers, scoped
    /// dense views). `None` = the process budget (`HDPW_MEM_MB`).
    pub mem: Option<Arc<MemBudget>>,
}

impl SessionCtx {
    fn reuse_enabled(&self) -> bool {
        self.reuse_precond && self.cache.is_some() && self.dataset_id.is_some()
    }
}

/// Resolve the request's step-2 policy for this job: the nnz-aware cost
/// model ([`resolve_step2`]) runs against the session budget (or the
/// process budget when none is attached) with `max_iters × batch_size` as
/// the expected sampled-row volume. Both the session's acquisition path and
/// the coordinator's admission/key computation resolve through this one
/// helper, so the key tag and the built artifact cannot drift apart.
pub fn resolved_step2(opts: &SolverOpts, ds: &Dataset) -> (Step2Mode, String) {
    let budget = opts
        .session
        .mem
        .clone()
        .unwrap_or_else(MemBudget::process);
    let total_rows = opts.max_iters.saturating_mul(opts.batch_size.max(1));
    resolve_step2(opts.step2, ds, total_rows, &budget)
}

/// The cache key a job's artifacts live under — the ONE constructor shared
/// by the session's acquisition path and the coordinator's cache-aware
/// admission estimate, so the two can never drift apart.
pub fn precond_key(
    backend: &Backend,
    ds: &Dataset,
    opts: &SolverOpts,
    dataset_id: String,
    artifact_seed: u64,
) -> PrecondKey {
    let sketch_rows = opts
        .sketch_size
        .unwrap_or_else(|| default_sketch_size_for(ds.n(), ds.d(), opts.sketch));
    // on-disk designs key by flavor ("mmapdense" / "libsvm-chunked"), not
    // the bare "ondisk" tag: the two flavors run different arithmetic
    // (dense block plans vs sequential CSR) and must not alias
    let mut repr: String = match ds.on_disk() {
        Some(od) => od.flavor_tag().into(),
        None => ds.design.repr().tag().into(),
    };
    if ds.sparse_arith() && resolved_step2(opts, ds).0 == Step2Mode::Dense {
        // a dense-step2 artifact on CSR holds a materialized HD buffer and
        // must not alias the implicit artifact the same key would otherwise
        // produce
        repr.push_str("+hd");
    }
    PrecondKey {
        dataset_id,
        sketch: opts.sketch,
        sketch_rows,
        seed: artifact_seed,
        block_rows: opts.block_rows.unwrap_or(0),
        // artifacts are a function of the executing backend's numerics:
        // per-request executors must not alias...
        backend: (if backend.has_pjrt() { "pjrt" } else { "native" }).into(),
        // ...and of the data representation: the CSR fold re-associates the
        // sketch sum, so dense and sparse artifacts must not alias either
        repr,
    }
}

/// Owns the cross-cutting state of one solve: rng, setup clock, artifact
/// acquisition, warm start, trace, and stopping rules.
pub struct SolveSession<'a> {
    /// The numerical backend every op dispatches through.
    pub backend: &'a Backend,
    /// The dataset being solved.
    pub ds: &'a Dataset,
    /// The solve options (constraint, budgets, sketch, session context).
    pub opts: &'a SolverOpts,
    /// The per-trial stream (seeded from `opts.seed`); step rules draw
    /// batch indices etc. from here.
    pub rng: Rng,
    /// The memory budget materializations charge (session override or the
    /// process default).
    mem: Arc<MemBudget>,
    /// Started lazily on the first acquisition, so solvers without a setup
    /// phase report exactly 0 — and a cache hit reports only lookup cost.
    setup_timer: Option<Timer>,
    setup_secs: f64,
    outcome: CacheOutcome,
    /// Warm-start outcome ("off" | "used" | "rejected-dim"), reported on
    /// the [`SolveReport`] so a misconfigured serve request is visible.
    warm_start: &'static str,
    /// The resolved step-2 mode artifacts are built with (see
    /// [`resolved_step2`]).
    step2: Step2Mode,
    /// The resolution report (`dense | implicit | auto→…`), surfaced on the
    /// [`SolveReport`] once a step-2 acquisition actually happens.
    step2_report: String,
    step2_used: bool,
    rec: Option<TraceRecorder>,
}

impl<'a> SolveSession<'a> {
    /// Open a session (seeds the trial rng; nothing is acquired yet).
    pub fn new(backend: &'a Backend, ds: &'a Dataset, opts: &'a SolverOpts) -> SolveSession<'a> {
        let mem = opts
            .session
            .mem
            .clone()
            .unwrap_or_else(MemBudget::process);
        let (step2, step2_report) = resolved_step2(opts, ds);
        SolveSession {
            backend,
            ds,
            opts,
            rng: Rng::new(opts.seed),
            mem,
            setup_timer: None,
            setup_secs: 0.0,
            outcome: CacheOutcome::Off,
            warm_start: "off",
            step2,
            step2_report,
            step2_used: false,
            rec: None,
        }
    }

    /// Sketch rows s for this job (explicit or construction-aware default).
    pub fn sketch_rows(&self) -> usize {
        self.opts
            .sketch_size
            .unwrap_or_else(|| default_sketch_size_for(self.ds.n(), self.ds.d(), self.opts.sketch))
    }

    /// The memory budget this solve charges against.
    pub fn mem(&self) -> &Arc<MemBudget> {
        &self.mem
    }

    fn touch_setup(&mut self) {
        if self.setup_timer.is_none() {
            self.setup_timer = Some(Timer::start());
        }
    }

    /// Acquire the two-step preconditioner (with the HD transform when
    /// `with_hd`): cache-or-compute under reuse, inline from the session
    /// rng otherwise. Runs on the setup clock. Fails with the structured
    /// memory-budget error when the HD materialization would bust the
    /// budget (a step-1-only request on CSR charges nothing and cannot
    /// fail this way).
    pub fn precond(&mut self, with_hd: bool) -> Result<Arc<PrecondArtifact>> {
        self.touch_setup();
        if with_hd {
            self.step2_used = true;
        }
        let step2 = self.step2;
        let s = self.sketch_rows();
        let sc = &self.opts.session;
        if sc.reuse_enabled() {
            let cache = Arc::clone(sc.cache.as_ref().expect("reuse_enabled"));
            let key = precond_key(
                self.backend,
                self.ds,
                self.opts,
                sc.dataset_id.clone().expect("reuse_enabled"),
                sc.artifact_seed,
            );
            loop {
                match cache.lookup_or_claim(&key) {
                    Lookup::Found(art) => {
                        if !with_hd || art.has_step2() {
                            self.outcome = CacheOutcome::Hit;
                            return Ok(art);
                        }
                        // step-2 upgrade: the cached artifact lacks the HD
                        // parts; fill them from the key stream and re-insert.
                        // Step 1 (the expensive sketch-QR) is still reused,
                        // but the HD cost is real — reported as Upgrade, not
                        // Hit, so "hit == lookup cost" stays true.
                        let art = Arc::new(art.with_hd(
                            self.backend,
                            self.ds,
                            &key,
                            step2,
                            &self.mem,
                        )?);
                        cache.insert(key, Arc::clone(&art));
                        self.outcome = CacheOutcome::Upgrade;
                        return Ok(art);
                    }
                    Lookup::Claimed(claim) => {
                        // single-flight: this caller owns the compute;
                        // concurrent identical jobs wait instead of
                        // duplicating the O(nnz + d^3) setup. An over-budget
                        // failure drops the claim, so a waiter re-claims
                        // (and fails or succeeds on its own budget state)
                        // instead of hanging.
                        let art = Arc::new(PrecondArtifact::compute_keyed(
                            self.backend,
                            self.ds,
                            &key,
                            self.opts.block_rows,
                            with_hd,
                            step2,
                            &self.mem,
                        )?);
                        claim.publish(Arc::clone(&art));
                        self.outcome = CacheOutcome::Miss;
                        return Ok(art);
                    }
                    Lookup::Busy => cache.wait_for(&key),
                }
            }
        }
        // paper-fidelity path: sample from the session rng in the exact
        // order the pre-driver solvers did
        Ok(Arc::new(PrecondArtifact::compute_inline(
            self.backend,
            self.ds,
            self.opts.sketch,
            s,
            &mut self.rng,
            self.opts.block_rows,
            with_hd,
            step2,
            &self.mem,
        )?))
    }

    /// An always-fresh step-1 preconditioner sampled from the session rng —
    /// IHS's per-iteration re-sketch. Never cached, never on the setup
    /// clock (the re-sketching cost is the method's signature cost and
    /// belongs inside the timed step). Representation-aware: on a sparse
    /// dataset a CountSketch/SparseEmbed re-sketch is O(nnz) per iteration —
    /// exactly the cost the input-sparsity-time IHS literature promises —
    /// and never densifies. The one sketch without a CSR kernel (SRHT)
    /// takes a *charged*, scoped densify through the session's
    /// [`MemBudget`], so an over-budget iteration surfaces here as a
    /// structured error the step propagates instead of an untracked
    /// allocation.
    pub fn fresh_precond(&mut self) -> Result<Precondition> {
        let s = self.sketch_rows();
        let mem = Arc::clone(&self.mem);
        Ok(precondition_ds_budgeted(
            self.backend,
            self.ds,
            self.opts.sketch,
            s,
            &mut self.rng,
            self.opts.block_rows,
            &mem,
        )?)
    }

    /// The R-metric projector for constrained solves (None when
    /// unconstrained) — shared through the artifact, so a cached artifact
    /// amortizes the H = R^T R eigendecomposition too.
    pub fn metric(&mut self, art: &PrecondArtifact) -> Option<Arc<MetricProjector>> {
        if self.opts.constraint.is_unconstrained() {
            None
        } else {
            self.touch_setup();
            Some(art.metric())
        }
    }

    /// The start iterate: zeros, or the session's warm-start vector when
    /// enabled and dimension-compatible. A wrong-dimension `x0` is loudly
    /// rejected — warned on the log and reported as `rejected-dim` — so a
    /// misconfigured serve request never *silently* cold-starts.
    pub fn start_x(&mut self) -> Vec<f64> {
        let d = self.ds.d();
        if self.opts.session.warm_start {
            if let Some(x0) = &self.opts.session.x0 {
                if x0.len() == d {
                    self.warm_start = "used";
                    return x0.clone();
                }
                crate::log_warn!(
                    "warm-start x0 rejected: dimension {} != d {} (dataset {}); cold-starting",
                    x0.len(),
                    d,
                    self.ds.name
                );
                self.warm_start = "rejected-dim";
            }
        }
        vec![0.0; d]
    }

    fn end_setup(&mut self) {
        if let Some(t) = self.setup_timer.take() {
            self.setup_secs = t.secs();
        }
    }

    /// f(x) off the solve clock (trace evaluation, mirrors the paper) —
    /// O(nnz) on sparse datasets, backend-routed on dense ones, a streamed
    /// shard fold on disk-backed ones (bitwise the resident bits; fallible
    /// like every disk access — resident datasets never return `Err`).
    pub fn objective(&self, x: &[f64]) -> Result<f64> {
        if let Some(od) = self.ds.on_disk() {
            return od.residual_sq(&self.ds.b, x);
        }
        Ok(match self.ds.csr() {
            Some(c) => c.residual_sq(&self.ds.b, x),
            None => self.backend.residual_sq(
                self.ds.dense_if_ready().expect("dense dataset"),
                &self.ds.b,
                x,
            ),
        })
    }

    /// Full gradient `2 A^T (A x - b)` — O(nnz) on sparse datasets (SVRG
    /// snapshots, IHS/pwGradient steps), backend-routed on dense ones so
    /// PJRT deployments keep their artifact dispatch, a streamed shard fold
    /// on disk-backed ones (fallible like every disk access).
    pub fn full_grad(&self, x: &[f64]) -> Result<Vec<f64>> {
        if let Some(od) = self.ds.on_disk() {
            return od.fused_grad(&self.ds.b, x, 2.0);
        }
        Ok(match self.ds.csr() {
            Some(c) => c.fused_grad(&self.ds.b, x, 2.0),
            None => self.backend.full_grad(
                self.ds.dense_if_ready().expect("dense dataset"),
                &self.ds.b,
                x,
            ),
        })
    }

    fn start_trace(&mut self, f0: f64) {
        self.rec = Some(TraceRecorder::new(self.setup_secs, f0));
    }

    /// Inner iterations completed so far.
    pub fn iters(&self) -> usize {
        self.rec.as_ref().map(|r| r.iters()).unwrap_or(0)
    }

    /// Whether the stop rules (iters / time / eps) fire at objective `f`.
    pub fn should_stop(&self, f: f64) -> bool {
        self.rec
            .as_ref()
            .map(|r| r.should_stop(self.opts, f))
            .unwrap_or(false)
    }

    fn cap_chunk(&self, want: usize) -> usize {
        want.min(self.opts.max_iters.saturating_sub(self.iters()))
            .max(1)
    }

    /// Record a chunk on the trace (`iters` steps, `secs` on the clock).
    pub fn record(&mut self, iters: usize, secs: f64, f: f64) {
        self.rec
            .as_mut()
            .expect("trace started")
            .record(iters, secs, f);
    }

    fn finish(self, name: &str, x: Vec<f64>, f: f64) -> SolveReport {
        let setup = self.setup_secs;
        let outcome = self.outcome;
        let warm = self.warm_start;
        let step2 = if self.step2_used {
            self.step2_report.clone()
        } else {
            "off".into()
        };
        let mut rep = self.rec.expect("trace started").finish(name, x, f, setup);
        rep.precond_cache = outcome;
        rep.warm_start = warm.into();
        rep.step2 = step2;
        rep
    }
}

/// A solver reduced to its arithmetic: how to set up, how far to step, and
/// which iterate to evaluate. The shared frame (rng, clocks, trace, stop
/// rules, artifact acquisition) lives in [`SolveSession`] / [`drive`].
pub trait StepRule {
    /// Canonical solver name this rule reports as.
    fn name(&self) -> &'static str;

    /// Acquire artifacts through the session (runs on the setup clock).
    /// Fallible: an over-budget materialization surfaces here as a
    /// structured error, which [`drive`] propagates as the job error.
    fn setup(&mut self, sess: &mut SolveSession) -> Result<()> {
        let _ = sess;
        Ok(())
    }

    /// Untimed initialization after setup: step sizes, variance probes,
    /// state allocation. `x0`/`f0` are the session's start point. Fallible:
    /// probes on a disk-backed dataset read shards (row-mean-square scans,
    /// sigma^2 gathers), and a shard I/O error surfaces here as the job's
    /// structured error exactly like a failing [`StepRule::step`].
    fn init(&mut self, sess: &mut SolveSession, x0: &[f64], f0: f64) -> Result<()>;

    /// Desired iterations for the next chunk given the current objective;
    /// 0 = rule-initiated stop. The driver clamps to the remaining
    /// iteration budget.
    fn chunk_len(&self, sess: &SolveSession, f: f64) -> usize;

    /// Solve-clock work at a chunk boundary *before* stepping (SVRG
    /// snapshots, epoch schedules). `Ok(Some(secs))` is recorded as a
    /// 0-iteration trace point; `Ok(None)` records nothing. Fallible for
    /// the same reason as [`StepRule::step`]: boundary work may
    /// materialize through the budget.
    fn pre_chunk(&mut self, sess: &mut SolveSession, f: f64) -> Result<Option<f64>> {
        let _ = (sess, f);
        Ok(None)
    }

    /// Advance exactly `t` iterations (the driver times this call).
    /// Fallible: in-loop materializations (IHS's per-iteration re-sketch,
    /// any budget-charged dense view) surface as a structured error that
    /// [`drive`] propagates as the job's error — mid-solve memory pressure
    /// is a reported failure, never a panic or an untracked allocation.
    fn step(&mut self, sess: &mut SolveSession, t: usize) -> Result<()>;

    /// The iterate to evaluate f at — and to report at the end (averaged
    /// iterate for the SGD family, xhat for the accelerated scheme).
    fn eval_x(&self, sess: &SolveSession) -> Vec<f64>;

    /// Hook after the off-clock evaluation (epoch restarts).
    fn post_eval(&mut self, sess: &mut SolveSession, f: f64) {
        let _ = (sess, f);
    }
}

/// Run a [`StepRule`] through the shared solve loop. Setup *and step*
/// failures (e.g. an over-budget HD materialization, an over-budget
/// in-loop re-sketch) propagate as the job's error.
pub fn drive<R: StepRule>(
    rule: &mut R,
    backend: &Backend,
    ds: &Dataset,
    opts: &SolverOpts,
) -> Result<SolveReport> {
    let mut sess = SolveSession::new(backend, ds, opts);
    rule.setup(&mut sess)?;
    sess.end_setup();
    let x0 = sess.start_x();
    let f0 = sess.objective(&x0)?;
    rule.init(&mut sess, &x0, f0)?;
    sess.start_trace(f0);
    let mut f = f0;
    // the iterate last evaluated; nothing mutates it between the final
    // record and loop exit, so the closing report reuses it instead of
    // paying another full O(nd) residual pass
    let mut last: Option<Vec<f64>> = None;
    while !sess.should_stop(f) {
        if let Some(secs) = rule.pre_chunk(&mut sess, f)? {
            sess.record(0, secs, f);
        }
        let want = rule.chunk_len(&sess, f);
        if want == 0 {
            break;
        }
        let t = sess.cap_chunk(want);
        let (res, secs) = timed(|| rule.step(&mut sess, t));
        res?;
        let x = rule.eval_x(&sess);
        f = sess.objective(&x)?;
        sess.record(t, secs, f);
        rule.post_eval(&mut sess, f);
        last = Some(x);
    }
    let (x, f_final) = match last {
        Some(x) => (x, f),
        None => {
            // no chunk ran (stopped at f0): evaluate the start iterate
            let x = rule.eval_x(&sess);
            let fx = sess.objective(&x)?;
            (x, fx)
        }
    };
    Ok(sess.finish(rule.name(), x, f_final))
}

/// The fused cross-trial objective pass: one sweep over the data evaluates
/// f at every stacked iterate. Per column the arithmetic is pinned to the
/// serial [`SolveSession::objective`] routing — the CSR pass mirrors
/// [`CsrMat::residual_sq`](crate::linalg::CsrMat::residual_sq) row-for-row,
/// and the dense pass routes through [`Backend::residual_sq_multi`] on the
/// *same op key* as the serial `residual_sq`, so each column lands on the
/// same executor (and therefore the same bit pattern) a lone trial would
/// have used.
fn fused_objectives(backend: &Backend, ds: &Dataset, xs: &[Vec<f64>]) -> Result<Vec<f64>> {
    if let Some(od) = ds.on_disk() {
        return od.residual_sq_multi(&ds.b, xs);
    }
    Ok(match ds.csr() {
        Some(c) => c.residual_sq_multi(&ds.b, xs),
        None => backend.residual_sq_multi(
            ds.dense_if_ready().expect("dense dataset"),
            &ds.b,
            xs,
        ),
    })
}

/// Per-trial state of the fused lockstep driver.
struct FusedTrial<'a> {
    rule: Box<dyn StepRule>,
    sess: SolveSession<'a>,
    f: f64,
    last: Option<Vec<f64>>,
    /// A stepped-but-not-yet-evaluated chunk: (iters, step secs, iterate).
    pend: Option<(usize, f64, Vec<f64>)>,
    done: bool,
}

/// Run `opts_list.len()` trials of one solver in lockstep, sharing the
/// chunk-boundary objective pass: every trial advances one chunk, the
/// pending iterates are stacked column-wise, and a single fused residual
/// sweep ([`fused_objectives`]) prices all of them in one pass over `A` —
/// the cross-trial GEMM fusion of the batched hot path.
///
/// **Bit-identity contract.** Each trial owns its `SolverOpts` (seed,
/// session) and its own [`SolveSession`], so the per-trial rng streams and
/// step arithmetic are *untouched* by fusion — the only shared computation
/// is the objective pass, and that is pinned per column to the serial
/// routing (see [`fused_objectives`]). Every report this returns is
/// therefore bitwise equal to what a serial [`drive`] of the same opts
/// would have produced; `tests/implicit_gather.rs` replays both paths and
/// asserts it. Setup runs trial-by-trial in submission order, preserving
/// the serial path's cache miss/hit/upgrade sequence under `reuse_precond`.
///
/// Errors: a failing setup or step aborts the whole batch with that
/// trial's error — exactly the serial loop's behavior (it would have
/// abandoned the remaining trials too).
pub fn drive_fused_trials(
    solver: &dyn super::Solver,
    backend: &Backend,
    ds: &Dataset,
    opts_list: &[SolverOpts],
) -> Result<Vec<SolveReport>> {
    let mut trials: Vec<FusedTrial> = Vec::with_capacity(opts_list.len());
    for opts in opts_list {
        let mut rule = solver.step_rule().ok_or_else(|| {
            anyhow::anyhow!("solver {} has no step rule to fuse", solver.name())
        })?;
        let mut sess = SolveSession::new(backend, ds, opts);
        rule.setup(&mut sess)?;
        sess.end_setup();
        let x0 = sess.start_x();
        let f0 = sess.objective(&x0)?;
        rule.init(&mut sess, &x0, f0)?;
        sess.start_trace(f0);
        trials.push(FusedTrial {
            rule,
            sess,
            f: f0,
            last: None,
            pend: None,
            done: false,
        });
    }
    loop {
        // advance every live trial one chunk (identical per-trial op
        // sequence to the serial loop; rng streams are per-session)
        for tr in trials.iter_mut().filter(|t| !t.done) {
            if tr.sess.should_stop(tr.f) {
                tr.done = true;
                continue;
            }
            let f = tr.f;
            let rule = &mut tr.rule;
            let sess = &mut tr.sess;
            if let Some(secs) = rule.pre_chunk(sess, f)? {
                sess.record(0, secs, f);
            }
            let want = rule.chunk_len(sess, f);
            if want == 0 {
                tr.done = true;
                continue;
            }
            let t = sess.cap_chunk(want);
            let (res, secs) = timed(|| rule.step(sess, t));
            res?;
            tr.pend = Some((t, secs, tr.rule.eval_x(&tr.sess)));
        }
        // one fused pass prices every pending iterate
        let live: Vec<usize> = trials
            .iter()
            .enumerate()
            .filter(|(_, t)| t.pend.is_some())
            .map(|(i, _)| i)
            .collect();
        if live.is_empty() {
            break;
        }
        let xs: Vec<Vec<f64>> = live
            .iter()
            .map(|&i| trials[i].pend.as_ref().expect("pending").2.clone())
            .collect();
        let fs = fused_objectives(backend, ds, &xs)?;
        for (&i, f) in live.iter().zip(fs) {
            let tr = &mut trials[i];
            let (t, secs, x) = tr.pend.take().expect("pending");
            tr.f = f;
            tr.sess.record(t, secs, f);
            tr.rule.post_eval(&mut tr.sess, f);
            tr.last = Some(x);
        }
    }
    trials
        .into_iter()
        .map(|tr| {
            let (x, f_final) = match tr.last {
                Some(x) => (x, tr.f),
                None => {
                    let x = tr.rule.eval_x(&tr.sess);
                    let fx = tr.sess.objective(&x)?;
                    (x, fx)
                }
            };
            Ok(tr.sess.finish(tr.rule.name(), x, f_final))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{blas, Mat};
    use crate::precond::precondition_with;
    use crate::sketch::SketchKind;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 0.05 * rng.gaussian();
        }
        Dataset::dense("t", a, b, Some(xt))
    }

    fn reuse_opts(cache: &Arc<PrecondCache>, seed: u64) -> SolverOpts {
        let mut opts = SolverOpts::default();
        opts.seed = seed;
        opts.session = SessionCtx {
            reuse_precond: true,
            warm_start: false,
            cache: Some(Arc::clone(cache)),
            dataset_id: Some("ds-test".into()),
            artifact_seed: 99,
            x0: None,
            mem: None,
        };
        opts
    }

    #[test]
    fn inline_acquisition_consumes_session_rng_like_legacy() {
        let ds = dataset(512, 6, 1);
        let be = Backend::native();
        let opts = SolverOpts::default();
        let mut sess = SolveSession::new(&be, &ds, &opts);
        let art = sess.precond(true).unwrap();
        // legacy sequence with the same seed
        let mut rng = Rng::new(opts.seed);
        let s = default_sketch_size_for(ds.n(), ds.d(), opts.sketch);
        let a_ref = ds.dense_if_ready().unwrap();
        let pre = precondition_with(&be, a_ref, opts.sketch, s, &mut rng, None);
        let hd = crate::precond::hd_transform_with(&be, a_ref, &ds.b, &mut rng);
        assert_eq!(art.r.max_abs_diff(&pre.r), 0.0);
        assert_eq!(art.hd.as_ref().unwrap().hda.max_abs_diff(&hd.hda), 0.0);
        // session rng continues where the legacy stream would
        assert_eq!(sess.rng.next_u64(), rng.next_u64());
    }

    #[test]
    fn cached_acquisition_leaves_session_rng_untouched() {
        let ds = dataset(400, 5, 2);
        let be = Backend::native();
        let cache = Arc::new(PrecondCache::new(1 << 30));
        let opts = reuse_opts(&cache, 7);
        // miss path
        let mut s1 = SolveSession::new(&be, &ds, &opts);
        let a1 = s1.precond(false).unwrap();
        let draw_after_miss = s1.rng.next_u64();
        // hit path: same key, fresh session
        let mut s2 = SolveSession::new(&be, &ds, &opts);
        let a2 = s2.precond(false).unwrap();
        let draw_after_hit = s2.rng.next_u64();
        assert_eq!(a1.r.max_abs_diff(&a2.r), 0.0);
        assert_eq!(
            draw_after_miss, draw_after_hit,
            "trial stream must not observe cache state"
        );
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn hd_upgrade_on_cached_step1_artifact() {
        let ds = dataset(300, 4, 3);
        let be = Backend::native();
        let cache = Arc::new(PrecondCache::new(1 << 30));
        let opts = reuse_opts(&cache, 5);
        // first acquisition: step 1 only (a pwgradient-style solver)
        let mut s1 = SolveSession::new(&be, &ds, &opts);
        let a1 = s1.precond(false).unwrap();
        assert!(a1.hd.is_none());
        // second acquisition wants HD: upgrade, same R
        let mut s2 = SolveSession::new(&be, &ds, &opts);
        let a2 = s2.precond(true).unwrap();
        assert!(a2.hd.is_some());
        assert_eq!(a1.r.max_abs_diff(&a2.r), 0.0);
        // third acquisition finds the upgraded artifact directly
        let mut s3 = SolveSession::new(&be, &ds, &opts);
        let a3 = s3.precond(true).unwrap();
        assert!(Arc::ptr_eq(&a2, &a3) || a3.hd.is_some());
        assert_eq!(cache.hits(), 2);
    }

    #[test]
    fn over_budget_acquisition_surfaces_as_error_not_panic() {
        let ds = dataset(512, 6, 9);
        let be = Backend::native();
        let tight = MemBudget::with_limit_mb(1);
        let _hog = tight.try_charge((1 << 20) - 64, "hog").unwrap();
        let mut opts = SolverOpts::default();
        opts.session.mem = Some(Arc::clone(&tight));
        let mut sess = SolveSession::new(&be, &ds, &opts);
        let err = sess.precond(true).unwrap_err();
        assert!(
            format!("{err:#}").contains("memory budget exceeded"),
            "{err:#}"
        );
        // step-1-only acquisition charges nothing and succeeds
        let mut sess2 = SolveSession::new(&be, &ds, &opts);
        assert!(sess2.precond(false).is_ok());
    }

    #[test]
    fn warm_start_uses_session_x0() {
        let ds = dataset(128, 4, 4);
        let be = Backend::native();
        let mut opts = SolverOpts::default();
        opts.session.warm_start = true;
        opts.session.x0 = Some(vec![1.0, 2.0, 3.0, 4.0]);
        let mut sess = SolveSession::new(&be, &ds, &opts);
        assert_eq!(sess.start_x(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(sess.warm_start, "used");
        // dimension mismatch falls back to zeros — loudly
        opts.session.x0 = Some(vec![1.0]);
        let mut sess = SolveSession::new(&be, &ds, &opts);
        assert_eq!(sess.start_x(), vec![0.0; 4]);
        assert_eq!(sess.warm_start, "rejected-dim");
        // warm_start off ignores x0
        opts.session.warm_start = false;
        opts.session.x0 = Some(vec![1.0, 2.0, 3.0, 4.0]);
        let mut sess = SolveSession::new(&be, &ds, &opts);
        assert_eq!(sess.start_x(), vec![0.0; 4]);
        assert_eq!(sess.warm_start, "off");
    }

    #[test]
    fn setup_clock_is_zero_without_acquisitions() {
        let ds = dataset(64, 3, 5);
        let be = Backend::native();
        let opts = SolverOpts::default();

        /// A do-nothing rule: one empty chunk, then stop.
        struct Noop {
            x: Vec<f64>,
            stepped: bool,
        }
        impl StepRule for Noop {
            fn name(&self) -> &'static str {
                "noop"
            }
            fn init(&mut self, _s: &mut SolveSession, x0: &[f64], _f0: f64) -> Result<()> {
                self.x = x0.to_vec();
                Ok(())
            }
            fn chunk_len(&self, _s: &SolveSession, _f: f64) -> usize {
                if self.stepped {
                    0
                } else {
                    1
                }
            }
            fn step(&mut self, _s: &mut SolveSession, _t: usize) -> Result<()> {
                self.stepped = true;
                Ok(())
            }
            fn eval_x(&self, _s: &SolveSession) -> Vec<f64> {
                self.x.clone()
            }
        }

        let mut rule = Noop { x: vec![], stepped: false };
        let rep = drive(&mut rule, &be, &ds, &opts).unwrap();
        assert_eq!(rep.setup_secs, 0.0, "no acquisition => setup exactly 0");
        assert_eq!(rep.iters, 1);
        assert_eq!(rep.trace.len(), 2);
        assert_eq!(rep.precond_cache, CacheOutcome::Off);
    }

    #[test]
    fn sketch_size_override_respected() {
        let ds = dataset(256, 4, 6);
        let be = Backend::native();
        let mut opts = SolverOpts::default();
        opts.sketch = SketchKind::Gaussian;
        opts.sketch_size = Some(77);
        let sess = SolveSession::new(&be, &ds, &opts);
        assert_eq!(sess.sketch_rows(), 77);
    }
}
