//! Plain (projected) mini-batch SGD on the raw problem — no preconditioning.
//!
//! The classical baseline in Figures 2/4/6. Step size follows the standard
//! O(1/sqrt(t)) decay eta_t = eta0 / sqrt(1 + t / t0); on the ill-conditioned
//! datasets of Table 3 this stalls far above the preconditioned methods,
//! which is precisely the paper's point.

use super::driver::{drive, SolveSession, StepRule};
use super::{Solver, SolveReport, SolverOpts};
use crate::backend::Backend;
use crate::constraints::ConstraintSet;
use crate::data::Dataset;
use crate::linalg::{blas, Mat};
use anyhow::Result;

/// Plain projected mini-batch SGD (classical baseline).
pub struct Sgd;

/// Decaying-step mini-batch SGD as a step rule: no setup phase, O(1/sqrt(t))
/// decay anchored at the iteration count the session has already recorded.
#[derive(Default)]
struct SgdRule {
    x: Vec<f64>,
    eta0: f64,
    t0: f64,
    scale: f64,
    r: usize,
    n: usize,
    mbuf: Mat,
    vbuf: Vec<f64>,
}

impl StepRule for SgdRule {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn init(&mut self, sess: &mut SolveSession, x0: &[f64], _f0: f64) -> Result<()> {
        let (n, d) = (sess.ds.n(), sess.ds.d());
        let r = sess.opts.batch_size.max(1);
        // eta0 from the inverse row second moment: a safe scale for
        // E||A_i||^2-smooth stochastic gradients. Representation-routed:
        // O(nnz) on CSR, streamed over shards on disk, bit-identical dense
        // sum otherwise.
        let row_ms: f64 = sess.ds.try_row_mean_sq()?;
        self.eta0 = sess
            .opts
            .eta
            .unwrap_or(0.25 / (2.0 * n as f64 * row_ms.max(1e-300)));
        self.t0 = 100.0;
        self.scale = 2.0 * n as f64 / r as f64;
        self.r = r;
        self.n = n;
        self.mbuf = Mat::zeros(r, d);
        self.vbuf = vec![0.0; r];
        self.x = x0.to_vec();
        Ok(())
    }

    fn chunk_len(&self, sess: &SolveSession, _f: f64) -> usize {
        sess.opts.chunk
    }

    fn step(&mut self, sess: &mut SolveSession, t: usize) -> Result<()> {
        let base_t = sess.iters();
        let ds = sess.ds;
        for k in 0..t {
            let idx = sess.rng.indices(self.r, self.n);
            let g = if let Some(od) = ds.on_disk() {
                // on-disk row gather routed through the shard cache; reads
                // are fallible and surface as structured job errors
                od.batch_grad(&idx, &ds.b, &self.x, self.scale)?
            } else {
                match ds.csr() {
                    // sparse row-gather gradient: O(nnz(batch)) — no dense
                    // row copies, residual + scatter touch only stored
                    // entries
                    Some(csr) => csr.batch_grad(&idx, &ds.b, &self.x, self.scale),
                    None => {
                        let a = ds.dense_if_ready().expect("dense dataset");
                        for (row, &i) in idx.iter().enumerate() {
                            self.mbuf.row_mut(row).copy_from_slice(a.row(i));
                            self.vbuf[row] = ds.b[i];
                        }
                        blas::fused_grad(&self.mbuf, &self.vbuf, &self.x, self.scale)
                    }
                }
            };
            let eta = self.eta0 / (1.0 + (base_t + k) as f64 / self.t0).sqrt();
            for (xi, gi) in self.x.iter_mut().zip(&g) {
                *xi -= eta * gi;
            }
            sess.opts.constraint.project(&mut self.x);
        }
        Ok(())
    }

    fn eval_x(&self, _sess: &SolveSession) -> Vec<f64> {
        self.x.clone()
    }
}

impl Solver for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> Result<SolveReport> {
        drive(&mut SgdRule::default(), backend, ds, opts)
    }

    fn step_rule(&self) -> Option<Box<dyn StepRule>> {
        Some(Box::new(SgdRule::default()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints;
    use crate::solvers::exact::ground_truth;
    use crate::util::rng::Rng;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 0.05 * rng.gaussian();
        }
        Dataset::dense("t", a, b, Some(xt))
    }

    #[test]
    fn sparse_gradient_path_tracks_dense() {
        // same data in both representations, same seed: the CSR batch
        // gradient only re-associates sums, so the runs track each other
        use crate::linalg::CsrMat;
        let dense_ds = {
            let mut rng = Rng::new(9);
            let a = Mat::from_fn(1024, 6, |_, _| {
                if rng.uniform() < 0.3 {
                    rng.gaussian()
                } else {
                    0.0
                }
            });
            let xt = rng.gaussians(6);
            let mut b = blas::gemv(&a, &xt);
            for v in &mut b {
                *v += 0.05 * rng.gaussian();
            }
            Dataset::dense("t", a, b, None)
        };
        let sparse_ds = Dataset::from_csr(
            "t",
            CsrMat::from_dense(dense_ds.dense_if_ready().unwrap()),
            dense_ds.b.clone(),
            None,
        );
        let mut opts = SolverOpts::default();
        opts.batch_size = 8;
        opts.max_iters = 400;
        opts.chunk = 100;
        opts.time_budget = 1e9;
        let rd = Sgd.solve(&Backend::native(), &dense_ds, &opts).unwrap();
        let rs = Sgd.solve(&Backend::native(), &sparse_ds, &opts).unwrap();
        assert_eq!(rd.iters, rs.iters);
        assert!(
            (rd.f_final - rs.f_final).abs() < 1e-8 * (1.0 + rd.f_final),
            "dense {} vs sparse {}",
            rd.f_final,
            rs.f_final
        );
    }

    #[test]
    fn makes_progress_on_well_conditioned_data() {
        let ds = dataset(2048, 8, 1);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 16;
        opts.max_iters = 4000;
        opts.chunk = 200;
        let rep = Sgd.solve(&Backend::native(), &ds, &opts).unwrap();
        let rel0 = (rep.trace[0].f - gt.f_star) / gt.f_star;
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 0.3 * rel0, "no progress: {rel} vs {rel0}");
    }

    #[test]
    fn stalls_on_ill_conditioned_data_where_hdpw_does_not() {
        // The paper's headline qualitative claim in one test.
        use crate::solvers::hdpw_batch::HdpwBatchSgd;
        let spec = crate::data::synthetic::SynSpec {
            name: "ill".into(),
            n: 2048,
            d: 8,
            kappa: 1e6,
            noise: 0.05,
            signal_scale: 1.0,
        };
        let ds = crate::data::synthetic::generate(&spec, &mut Rng::new(2));
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 16;
        opts.max_iters = 2000;
        opts.chunk = 200;
        let sgd = Sgd.solve(&Backend::native(), &ds, &opts).unwrap();
        let hdpw = HdpwBatchSgd.solve(&Backend::native(), &ds, &opts).unwrap();
        let rel_sgd = (sgd.f_final - gt.f_star) / gt.f_star.max(1e-12);
        let rel_hdpw = (hdpw.f_final - gt.f_star) / gt.f_star.max(1e-12);
        assert!(
            rel_hdpw < 0.2 * rel_sgd,
            "hdpw {rel_hdpw} should beat sgd {rel_sgd} by far on kappa=1e6"
        );
    }

    #[test]
    fn projection_respected() {
        let ds = dataset(512, 5, 3);
        let cons = constraints::l1_ball(0.5);
        let mut opts = SolverOpts::default();
        opts.constraint = cons.clone();
        opts.max_iters = 300;
        opts.chunk = 100;
        let rep = Sgd.solve(&Backend::native(), &ds, &opts).unwrap();
        assert!(cons.contains(&rep.x, 1e-9));
    }
}
