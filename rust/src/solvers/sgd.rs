//! Plain (projected) mini-batch SGD on the raw problem — no preconditioning.
//!
//! The classical baseline in Figures 2/4/6. Step size follows the standard
//! O(1/sqrt(t)) decay eta_t = eta0 / sqrt(1 + t / t0); on the ill-conditioned
//! datasets of Table 3 this stalls far above the preconditioned methods,
//! which is precisely the paper's point.

use super::{timed, Solver, SolveReport, SolverOpts, TraceRecorder};
use crate::backend::Backend;
use crate::data::Dataset;
use crate::linalg::{blas, Mat};
use crate::util::rng::Rng;

pub struct Sgd;

impl Solver for Sgd {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> SolveReport {
        let mut rng = Rng::new(opts.seed);
        let n = ds.n();
        let d = ds.d();
        let r = opts.batch_size.max(1);
        let scale = 2.0 * n as f64 / r as f64;
        let x0 = vec![0.0; d];
        let f0 = backend.residual_sq(&ds.a, &ds.b, &x0);
        // eta0 from the inverse row second moment: a safe scale for
        // E||A_i||^2-smooth stochastic gradients.
        let row_ms: f64 = ds.a.data.iter().map(|v| v * v).sum::<f64>() / n as f64;
        let eta0 = opts.eta.unwrap_or(0.25 / (2.0 * n as f64 * row_ms.max(1e-300)));
        let t0 = 100.0;

        let mut rec = TraceRecorder::new(0.0, f0);
        let mut x = x0;
        let mut f = f0;
        let mut mbuf = Mat::zeros(r, d);
        let mut vbuf = vec![0.0; r];
        while !rec.should_stop(opts, f) {
            let t_chunk = opts.chunk.min(opts.max_iters - rec.iters()).max(1);
            let base_t = rec.iters();
            let (_, secs) = timed(|| {
                for k in 0..t_chunk {
                    let idx = rng.indices(r, n);
                    for (row, &i) in idx.iter().enumerate() {
                        mbuf.row_mut(row).copy_from_slice(ds.a.row(i));
                        vbuf[row] = ds.b[i];
                    }
                    let g = blas::fused_grad(&mbuf, &vbuf, &x, scale);
                    let eta = eta0 / (1.0 + (base_t + k) as f64 / t0).sqrt();
                    for (xi, gi) in x.iter_mut().zip(&g) {
                        *xi -= eta * gi;
                    }
                    opts.constraint.project(&mut x);
                }
            });
            f = backend.residual_sq(&ds.a, &ds.b, &x);
            rec.record(t_chunk, secs, f);
        }
        rec.finish("sgd", x, f, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prox::Constraint;
    use crate::solvers::exact::ground_truth;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 0.05 * rng.gaussian();
        }
        Dataset {
            name: "t".into(),
            a,
            b,
            x_star_planted: Some(xt),
        }
    }

    #[test]
    fn makes_progress_on_well_conditioned_data() {
        let ds = dataset(2048, 8, 1);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 16;
        opts.max_iters = 4000;
        opts.chunk = 200;
        let rep = Sgd.solve(&Backend::native(), &ds, &opts);
        let rel0 = (rep.trace[0].f - gt.f_star) / gt.f_star;
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 0.3 * rel0, "no progress: {rel} vs {rel0}");
    }

    #[test]
    fn stalls_on_ill_conditioned_data_where_hdpw_does_not() {
        // The paper's headline qualitative claim in one test.
        use crate::solvers::hdpw_batch::HdpwBatchSgd;
        let spec = crate::data::synthetic::SynSpec {
            name: "ill".into(),
            n: 2048,
            d: 8,
            kappa: 1e6,
            noise: 0.05,
            signal_scale: 1.0,
        };
        let ds = crate::data::synthetic::generate(&spec, &mut Rng::new(2));
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 16;
        opts.max_iters = 2000;
        opts.chunk = 200;
        let sgd = Sgd.solve(&Backend::native(), &ds, &opts);
        let hdpw = HdpwBatchSgd.solve(&Backend::native(), &ds, &opts);
        let rel_sgd = (sgd.f_final - gt.f_star) / gt.f_star.max(1e-12);
        let rel_hdpw = (hdpw.f_final - gt.f_star) / gt.f_star.max(1e-12);
        assert!(
            rel_hdpw < 0.2 * rel_sgd,
            "hdpw {rel_hdpw} should beat sgd {rel_sgd} by far on kappa=1e6"
        );
    }

    #[test]
    fn projection_respected() {
        let ds = dataset(512, 5, 3);
        let cons = Constraint::L1Ball { radius: 0.5 };
        let mut opts = SolverOpts::default();
        opts.constraint = cons;
        opts.max_iters = 300;
        opts.chunk = 100;
        let rep = Sgd.solve(&Backend::native(), &ds, &opts);
        assert!(cons.contains(&rep.x, 1e-9));
    }
}
