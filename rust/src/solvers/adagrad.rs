//! Adagrad (Duchi, Hazan & Singer 2011) — per-coordinate adaptive step
//! sizes on the raw problem. Baseline in the paper's low-precision figures
//! (via the SGDLibrary implementation the authors used).

use super::{timed, Solver, SolveReport, SolverOpts, TraceRecorder};
use crate::backend::Backend;
use crate::data::Dataset;
use crate::linalg::{blas, Mat};
use crate::util::rng::Rng;

pub struct Adagrad;

impl Solver for Adagrad {
    fn name(&self) -> &'static str {
        "adagrad"
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> SolveReport {
        let mut rng = Rng::new(opts.seed);
        let n = ds.n();
        let d = ds.d();
        let r = opts.batch_size.max(1);
        let scale = 2.0 * n as f64 / r as f64;
        let x0 = vec![0.0; d];
        let f0 = backend.residual_sq(&ds.a, &ds.b, &x0);
        // global learning rate: scale-free thanks to the G_t normalization
        let eta = opts.eta.unwrap_or(0.1);
        let eps = 1e-10;

        let mut rec = TraceRecorder::new(0.0, f0);
        let mut x = x0;
        let mut f = f0;
        let mut gsq = vec![0.0; d]; // accumulated squared gradients
        let mut mbuf = Mat::zeros(r, d);
        let mut vbuf = vec![0.0; r];
        while !rec.should_stop(opts, f) {
            let t_chunk = opts.chunk.min(opts.max_iters - rec.iters()).max(1);
            let (_, secs) = timed(|| {
                for _ in 0..t_chunk {
                    let idx = rng.indices(r, n);
                    for (row, &i) in idx.iter().enumerate() {
                        mbuf.row_mut(row).copy_from_slice(ds.a.row(i));
                        vbuf[row] = ds.b[i];
                    }
                    let g = blas::fused_grad(&mbuf, &vbuf, &x, scale);
                    for j in 0..d {
                        gsq[j] += g[j] * g[j];
                        x[j] -= eta * g[j] / (gsq[j].sqrt() + eps);
                    }
                    opts.constraint.project(&mut x);
                }
            });
            f = backend.residual_sq(&ds.a, &ds.b, &x);
            rec.record(t_chunk, secs, f);
        }
        rec.finish("adagrad", x, f, 0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prox::Constraint;
    use crate::solvers::exact::ground_truth;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 0.05 * rng.gaussian();
        }
        Dataset {
            name: "t".into(),
            a,
            b,
            x_star_planted: Some(xt),
        }
    }

    #[test]
    fn converges_on_well_conditioned_data() {
        let ds = dataset(2048, 8, 1);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 16;
        opts.max_iters = 6000;
        opts.chunk = 500;
        let rep = Adagrad.solve(&Backend::native(), &ds, &opts);
        let rel0 = (rep.trace[0].f - gt.f_star) / gt.f_star;
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 0.3 * rel0, "adagrad no progress: {rel} vs {rel0}");
    }

    #[test]
    fn adapts_to_badly_scaled_columns_better_than_sgd() {
        use crate::solvers::sgd::Sgd;
        // column scales spanning 1e3: Adagrad's per-coordinate normalization
        // should cope; plain SGD's single step size cannot.
        let mut rng = Rng::new(2);
        let mut a = Mat::gaussian(1024, 6, &mut rng);
        for i in 0..a.rows {
            for j in 0..a.cols {
                *a.at_mut(i, j) *= 10f64.powi(j as i32 - 3);
            }
        }
        let xt = rng.gaussians(6);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 0.01 * rng.gaussian();
        }
        let ds = Dataset {
            name: "scaled".into(),
            a,
            b,
            x_star_planted: None,
        };
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 16;
        opts.max_iters = 3000;
        opts.chunk = 500;
        let ada = Adagrad.solve(&Backend::native(), &ds, &opts);
        let sgd = Sgd.solve(&Backend::native(), &ds, &opts);
        let rel_ada = (ada.f_final - gt.f_star) / gt.f_star.max(1e-12);
        let rel_sgd = (sgd.f_final - gt.f_star) / gt.f_star.max(1e-12);
        assert!(
            rel_ada < rel_sgd,
            "adagrad {rel_ada} should beat sgd {rel_sgd} on scaled columns"
        );
    }

    #[test]
    fn feasibility_under_l2() {
        let ds = dataset(512, 5, 3);
        let cons = Constraint::L2Ball { radius: 0.4 };
        let mut opts = SolverOpts::default();
        opts.constraint = cons;
        opts.max_iters = 200;
        opts.chunk = 100;
        let rep = Adagrad.solve(&Backend::native(), &ds, &opts);
        assert!(cons.contains(&rep.x, 1e-9));
    }
}
