//! Adagrad (Duchi, Hazan & Singer 2011) — per-coordinate adaptive step
//! sizes on the raw problem. Baseline in the paper's low-precision figures
//! (via the SGDLibrary implementation the authors used).

use super::driver::{drive, SolveSession, StepRule};
use super::{Solver, SolveReport, SolverOpts};
use crate::backend::Backend;
use crate::constraints::ConstraintSet;
use crate::data::Dataset;
use crate::linalg::{blas, Mat};
use anyhow::Result;

/// Per-coordinate adaptive-step SGD (classical baseline).
pub struct Adagrad;

/// Per-coordinate adaptive steps as a step rule: no setup phase; the G_t
/// accumulator persists across chunks.
#[derive(Default)]
struct AdagradRule {
    x: Vec<f64>,
    gsq: Vec<f64>,
    eta: f64,
    scale: f64,
    r: usize,
    n: usize,
    mbuf: Mat,
    vbuf: Vec<f64>,
}

impl StepRule for AdagradRule {
    fn name(&self) -> &'static str {
        "adagrad"
    }

    fn init(&mut self, sess: &mut SolveSession, x0: &[f64], _f0: f64) -> Result<()> {
        let (n, d) = (sess.ds.n(), sess.ds.d());
        let r = sess.opts.batch_size.max(1);
        // global learning rate: scale-free thanks to the G_t normalization
        self.eta = sess.opts.eta.unwrap_or(0.1);
        self.scale = 2.0 * n as f64 / r as f64;
        self.r = r;
        self.n = n;
        self.gsq = vec![0.0; d];
        self.mbuf = Mat::zeros(r, d);
        self.vbuf = vec![0.0; r];
        self.x = x0.to_vec();
        Ok(())
    }

    fn chunk_len(&self, sess: &SolveSession, _f: f64) -> usize {
        sess.opts.chunk
    }

    fn step(&mut self, sess: &mut SolveSession, t: usize) -> Result<()> {
        let eps = 1e-10;
        let d = self.x.len();
        let ds = sess.ds;
        for _ in 0..t {
            let idx = sess.rng.indices(self.r, self.n);
            let g = if let Some(od) = ds.on_disk() {
                // on-disk row gather through the shard cache (fallible reads)
                od.batch_grad(&idx, &ds.b, &self.x, self.scale)?
            } else {
                match ds.csr() {
                    // sparse row-gather gradient: O(nnz(batch)) — the G_t
                    // update stays dense (it is d-dimensional regardless)
                    Some(csr) => csr.batch_grad(&idx, &ds.b, &self.x, self.scale),
                    None => {
                        let a = ds.dense_if_ready().expect("dense dataset");
                        for (row, &i) in idx.iter().enumerate() {
                            self.mbuf.row_mut(row).copy_from_slice(a.row(i));
                            self.vbuf[row] = ds.b[i];
                        }
                        blas::fused_grad(&self.mbuf, &self.vbuf, &self.x, self.scale)
                    }
                }
            };
            for j in 0..d {
                self.gsq[j] += g[j] * g[j];
                self.x[j] -= self.eta * g[j] / (self.gsq[j].sqrt() + eps);
            }
            sess.opts.constraint.project(&mut self.x);
        }
        Ok(())
    }

    fn eval_x(&self, _sess: &SolveSession) -> Vec<f64> {
        self.x.clone()
    }
}

impl Solver for Adagrad {
    fn name(&self) -> &'static str {
        "adagrad"
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> Result<SolveReport> {
        drive(&mut AdagradRule::default(), backend, ds, opts)
    }

    fn step_rule(&self) -> Option<Box<dyn StepRule>> {
        Some(Box::new(AdagradRule::default()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints;
    use crate::solvers::exact::ground_truth;
    use crate::util::rng::Rng;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 0.05 * rng.gaussian();
        }
        Dataset::dense("t", a, b, Some(xt))
    }

    #[test]
    fn converges_on_well_conditioned_data() {
        let ds = dataset(2048, 8, 1);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 16;
        opts.max_iters = 6000;
        opts.chunk = 500;
        let rep = Adagrad.solve(&Backend::native(), &ds, &opts).unwrap();
        let rel0 = (rep.trace[0].f - gt.f_star) / gt.f_star;
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 0.3 * rel0, "adagrad no progress: {rel} vs {rel0}");
    }

    #[test]
    fn adapts_to_badly_scaled_columns_better_than_sgd() {
        use crate::solvers::sgd::Sgd;
        // column scales spanning 1e3: Adagrad's per-coordinate normalization
        // should cope; plain SGD's single step size cannot.
        let mut rng = Rng::new(2);
        let mut a = Mat::gaussian(1024, 6, &mut rng);
        for i in 0..a.rows {
            for j in 0..a.cols {
                *a.at_mut(i, j) *= 10f64.powi(j as i32 - 3);
            }
        }
        let xt = rng.gaussians(6);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 0.01 * rng.gaussian();
        }
        let ds = Dataset::dense("scaled", a, b, None);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 16;
        opts.max_iters = 3000;
        opts.chunk = 500;
        let ada = Adagrad.solve(&Backend::native(), &ds, &opts).unwrap();
        let sgd = Sgd.solve(&Backend::native(), &ds, &opts).unwrap();
        let rel_ada = (ada.f_final - gt.f_star) / gt.f_star.max(1e-12);
        let rel_sgd = (sgd.f_final - gt.f_star) / gt.f_star.max(1e-12);
        assert!(
            rel_ada < rel_sgd,
            "adagrad {rel_ada} should beat sgd {rel_sgd} on scaled columns"
        );
    }

    #[test]
    fn feasibility_under_l2() {
        let ds = dataset(512, 5, 3);
        let cons = constraints::l2_ball(0.4);
        let mut opts = SolverOpts::default();
        opts.constraint = cons.clone();
        opts.max_iters = 200;
        opts.chunk = 100;
        let rep = Adagrad.solve(&Backend::native(), &ds, &opts).unwrap();
        assert!(cons.contains(&rep.x, 1e-9));
    }
}
