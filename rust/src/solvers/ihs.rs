//! Iterative Hessian Sketch (Pilanci & Wainwright 2016) — Algorithm 3.
//!
//! The high-precision baseline pwGradient improves on: every iteration draws
//! a *fresh* sketch S^{t+1}, forms M = S^{t+1} A, QR-factors it and takes
//! the Newton-like step
//!     x_{t+1} = P_W(x_t - (R_t^T R_t)^{-1} A^T (A x_t - b)).
//! The re-sketching (O(nnz(A)) or O(nd log n) per iteration, plus a d^2
//! QR) is exactly the cost pwGradient's frozen sketch removes; the benches
//! surface this as the per-iteration time gap.

use super::driver::{drive, SolveSession, StepRule};
use super::{Solver, SolveReport, SolverOpts};
use crate::backend::Backend;
use crate::constraints::ConstraintSet;
use crate::data::Dataset;
use anyhow::Result;

/// Iterative Hessian Sketch (Pilanci & Wainwright 2016 baseline).
pub struct Ihs;

/// IHS as a step rule with NO setup phase: the fresh sketch + QR recurs
/// inside every timed step — the method's signature cost, and exactly what
/// the artifact cache must never short-circuit (so the rule goes through
/// [`SolveSession::fresh_precond`], which bypasses the cache by contract).
#[derive(Default)]
struct IhsRule {
    x: Vec<f64>,
}

impl StepRule for IhsRule {
    fn name(&self) -> &'static str {
        "ihs"
    }

    fn init(&mut self, _sess: &mut SolveSession, x0: &[f64], _f0: f64) -> Result<()> {
        self.x = x0.to_vec();
        Ok(())
    }

    fn chunk_len(&self, _sess: &SolveSession, _f: f64) -> usize {
        1 // trace every (expensive) iteration
    }

    fn step(&mut self, sess: &mut SolveSession, t: usize) -> Result<()> {
        for _ in 0..t {
            // fresh sketch + QR every iteration (the method's signature
            // cost, kept inside the timed region deliberately). Budget-
            // routed: CountSketch/SparseEmbed re-sketch CSR in O(nnz);
            // SRHT's whole-matrix fallback is a charged scoped densify, so
            // an over-budget iteration propagates as the job's structured
            // error instead of an untracked allocation.
            let pre = sess.fresh_precond()?;
            let metric = if sess.opts.constraint.is_unconstrained() {
                None
            } else {
                Some(crate::prox::metric::MetricProjector::from_r(&pre.r))
            };
            // representation-routed: O(nnz) fused gradient on CSR (no
            // dense mirror), streamed over shards on disk, the same backend
            // dispatch as before on dense
            let g = sess.full_grad(&self.x)?;
            // full_grad returns 2 A^T r; the IHS step applies
            // (R^T R)^{-1} A^T r, i.e. gd_step with eta = 1/2.
            self.x = sess.backend.gd_step(
                &self.x,
                &pre.pinv,
                &g,
                0.5,
                sess.opts.constraint.as_ref(),
                metric.as_ref(),
            );
        }
        Ok(())
    }

    fn eval_x(&self, _sess: &SolveSession) -> Vec<f64> {
        self.x.clone()
    }
}

impl Solver for Ihs {
    fn name(&self) -> &'static str {
        "ihs"
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> Result<SolveReport> {
        drive(&mut IhsRule::default(), backend, ds, opts)
    }

    fn step_rule(&self) -> Option<Box<dyn StepRule>> {
        Some(Box::new(IhsRule::default()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{blas, Mat};
    use crate::solvers::exact::ground_truth;
    use crate::solvers::pw_gradient::PwGradient;
    use crate::util::rng::Rng;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 0.05 * rng.gaussian();
        }
        Dataset::dense("t", a, b, Some(xt))
    }

    #[test]
    fn converges_linearly_to_high_precision() {
        let ds = dataset(2048, 8, 1);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.max_iters = 60;
        opts.f_star = Some(gt.f_star);
        opts.eps_abs = Some(1e-10 * gt.f_star);
        let rep = Ihs.solve(&Backend::native(), &ds, &opts).unwrap();
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 1e-9, "relative error {rel}");
    }

    #[test]
    fn per_iteration_cost_exceeds_pw_gradient() {
        // The paper's complexity claim, observable on a single box: IHS pays
        // a sketch + QR every step, pwGradient only pays the gradient.
        let ds = dataset(8192, 16, 2);
        let mut opts = SolverOpts::default();
        opts.max_iters = 12;
        opts.chunk = 1;
        let ihs = Ihs.solve(&Backend::native(), &ds, &opts).unwrap();
        let pw = PwGradient.solve(&Backend::native(), &ds, &opts).unwrap();
        // compare marginal per-iteration time (exclude pw's setup, which is
        // already excluded by construction of the comparison: setup is in
        // trace[0] for pw, while ihs amortizes nothing)
        let ihs_per_it = ihs.solve_secs / ihs.iters.max(1) as f64;
        let pw_per_it = (pw.solve_secs - pw.setup_secs) / pw.iters.max(1) as f64;
        assert!(
            ihs_per_it > 1.2 * pw_per_it,
            "ihs {ihs_per_it}s/it vs pw {pw_per_it}s/it"
        );
    }

    #[test]
    fn pw_gradient_with_eta_half_matches_ihs_fixed_point() {
        // Both must land on the same optimum (the LS solution).
        let ds = dataset(1024, 6, 3);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.max_iters = 50;
        let ihs = Ihs.solve(&Backend::native(), &ds, &opts).unwrap();
        let pw = PwGradient.solve(&Backend::native(), &ds, &opts).unwrap();
        for j in 0..ds.d() {
            assert!(
                (ihs.x[j] - gt.x_star[j]).abs() < 1e-6,
                "ihs coord {j}"
            );
            assert!((pw.x[j] - gt.x_star[j]).abs() < 1e-6, "pw coord {j}");
        }
    }
}
