//! HDpwBatchSGD — Algorithm 2, the paper's low-precision contribution.
//!
//! Two-step preconditioning (sketch-QR for R, then the Randomized Hadamard
//! Transform on [A | b]) followed by *uniform* mini-batch SGD in the
//! R-metric. Theorem 3: T = Theta(d log n / (r eps^2)) iterations — the
//! iteration count divides by the batch size r, the paper's optimal
//! speed-up property (Figure 1).
//!
//! The output iterate is the running average x_T^avg = (1/T) sum x_t, as in
//! the algorithm statement; the trace reports f at the averaged iterate.

use super::driver::{drive, SolveSession, StepRule};
use super::{estimate_sigma_sq, theory_step_size, Solver, SolveReport, SolverOpts};
use crate::backend::Backend;
use crate::data::Dataset;
use crate::precond::PrecondArtifact;
use crate::prox::metric::MetricProjector;
use anyhow::Result;
use std::sync::Arc;

/// Algorithm 2: two-step preconditioning + uniform mini-batch SGD.
pub struct HdpwBatchSgd;

/// Algorithm 2 as a step rule. Setup acquires the full two-step artifact
/// (sketch-QR + HD transform — both stream through the backend's executor);
/// the untimed init estimates sigma^2 and fixes the Theorem-2 step; every
/// chunk is a fused uniform mini-batch SGD dispatch. The reported iterate
/// is the running average x_T^avg, as in the algorithm statement.
#[derive(Default)]
struct HdpwBatchRule {
    art: Option<Arc<PrecondArtifact>>,
    metric: Option<Arc<MetricProjector>>,
    eta: f64,
    scale: f64,
    n_pad: usize,
    r: usize,
    x: Vec<f64>,
    x0: Vec<f64>,
    xsum: Vec<f64>,
    total_t: usize,
}

impl StepRule for HdpwBatchRule {
    fn name(&self) -> &'static str {
        "hdpwbatchsgd"
    }

    fn setup(&mut self, sess: &mut SolveSession) -> Result<()> {
        // the HD materialization charges the session's memory budget: over
        // budget this is the structured job error, not an OOM
        let art = sess.precond(true)?;
        // constrained runs need the R-metric projector (Step 6's quadratic
        // subproblem); its eigendecomposition is part of setup — and shared
        // through the artifact when the cache is on.
        self.metric = sess.metric(&art);
        self.art = Some(art);
        Ok(())
    }

    fn init(&mut self, sess: &mut SolveSession, x0: &[f64], f0: f64) -> Result<()> {
        let art = self.art.as_ref().expect("setup ran");
        let hd = art.hd_view(sess.ds).expect("two-step artifact");
        let r = sess.opts.batch_size.max(1);
        self.n_pad = hd.n_pad();
        self.scale = 2.0 * self.n_pad as f64 / r as f64;
        self.r = r;
        // Theorem-2 fixed step: sigma^2 of single-row gradients, divided by r
        // for the batch (Lemma: sigma_batch^2 <= sigma^2 / r). The probe
        // gathers rows — fallible on disk-backed views.
        let sigma_sq = estimate_sigma_sq(sess.backend, &hd, &art.r, x0, &mut sess.rng)?;
        let r_norm = art.r.frob_norm();
        self.eta = theory_step_size(
            sess.opts,
            sigma_sq / r as f64,
            f0,
            sess.opts.max_iters,
            r_norm,
        );
        self.x = x0.to_vec();
        self.x0 = x0.to_vec();
        self.xsum = vec![0.0; x0.len()];
        Ok(())
    }

    fn chunk_len(&self, sess: &SolveSession, _f: f64) -> usize {
        sess.opts.chunk
    }

    fn step(&mut self, sess: &mut SolveSession, t: usize) -> Result<()> {
        let art = self.art.as_ref().expect("setup ran");
        let hd = art.hd_view(sess.ds).expect("two-step artifact");
        let idx: Vec<Vec<usize>> = (0..t)
            .map(|_| sess.rng.indices(self.r, self.n_pad))
            .collect();
        // On a dense artifact the chunk samples the materialized transform
        // directly. On an implicit (sparse) artifact the chunk's t*r sampled
        // rows are evaluated on demand into one batch-sized block — the only
        // dense object the sparse path ever builds — and the executor runs
        // on local row positions; the uniform-sampling scale 2*n_pad/r is
        // index-independent, so the arithmetic is unchanged.
        let (xt, xs) = match &hd {
            crate::precond::HdView::Dense(h) => sess.backend.sgd_chunk(
                &h.hda,
                &h.hdb,
                &self.x,
                &art.pinv,
                &idx,
                self.eta,
                self.scale,
                sess.opts.constraint.as_ref(),
                self.metric.as_deref(),
            ),
            crate::precond::HdView::Implicit { .. }
            | crate::precond::HdView::ImplicitOnDisk { .. } => {
                let flat: Vec<usize> = idx.iter().flatten().copied().collect();
                // blocked at the batch size: every mini-batch is one CSR
                // pass (or one shard-streamed pass on disk) instead of r
                // per-row passes (same arithmetic)
                let (ma, mb) = hd.gather_blocked(&flat, self.r)?;
                let local: Vec<Vec<usize>> = (0..t)
                    .map(|k| (k * self.r..(k + 1) * self.r).collect())
                    .collect();
                sess.backend.sgd_chunk(
                    &ma,
                    &mb,
                    &self.x,
                    &art.pinv,
                    &local,
                    self.eta,
                    self.scale,
                    sess.opts.constraint.as_ref(),
                    self.metric.as_deref(),
                )
            }
        };
        self.x = xt;
        for (acc, v) in self.xsum.iter_mut().zip(&xs) {
            *acc += v;
        }
        self.total_t += t;
        Ok(())
    }

    fn eval_x(&self, _sess: &SolveSession) -> Vec<f64> {
        // the averaged iterate (the algorithm's output); before any step,
        // the start iterate itself
        if self.total_t == 0 {
            self.x0.clone()
        } else {
            average(&self.xsum, self.total_t)
        }
    }
}

impl Solver for HdpwBatchSgd {
    fn name(&self) -> &'static str {
        "hdpwbatchsgd"
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> Result<SolveReport> {
        drive(&mut HdpwBatchRule::default(), backend, ds, opts)
    }

    fn step_rule(&self) -> Option<Box<dyn StepRule>> {
        Some(Box::new(HdpwBatchRule::default()))
    }
}

fn average(xsum: &[f64], t: usize) -> Vec<f64> {
    let inv = 1.0 / t.max(1) as f64;
    xsum.iter().map(|v| v * inv).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::{self, ConstraintSet};
    use crate::linalg::blas;
    use crate::linalg::Mat;
    use crate::solvers::exact::ground_truth;
    use crate::util::rng::Rng;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 1.0 * rng.gaussian();
        }
        Dataset::dense("t", a, b, Some(xt))
    }

    #[test]
    fn converges_to_low_precision_unconstrained() {
        let ds = dataset(2048, 8, 1);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 32;
        opts.max_iters = 3000;
        opts.chunk = 100;
        opts.seed = 7;
        let rep = HdpwBatchSgd.solve(&Backend::native(), &ds, &opts).unwrap();
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 0.05, "relative error {rel}");
        assert!(rep.trace.len() > 2);
    }

    #[test]
    fn constrained_iterates_stay_feasible() {
        let ds = dataset(1024, 6, 2);
        let gt = ground_truth(&ds);
        for cons in [
            constraints::l2_ball(gt.l2_radius),
            constraints::l1_ball(gt.l1_radius),
        ] {
            let mut opts = SolverOpts::default();
            opts.constraint = cons.clone();
            opts.batch_size = 16;
            opts.max_iters = 800;
            opts.chunk = 100;
            let rep = HdpwBatchSgd.solve(&Backend::native(), &ds, &opts).unwrap();
            assert!(cons.contains(&rep.x, 1e-6), "{} violated", cons.tag());
            let rel = (rep.f_final - gt.f_star) / gt.f_star;
            assert!(rel < 0.5, "{}: rel {rel}", cons.tag());
        }
    }

    #[test]
    fn batch_size_speedup_on_iterations() {
        // Figure 1's property: iterations-to-eps roughly halves as r doubles.
        let ds = dataset(4096, 8, 3);
        let gt = ground_truth(&ds);
        let eps = 0.05;
        let mut iters = Vec::new();
        for r in [4usize, 16, 64] {
            let mut opts = SolverOpts::default();
            opts.batch_size = r;
            opts.max_iters = 20_000;
            opts.chunk = 50;
            opts.seed = 11;
            opts.f_star = Some(gt.f_star);
            opts.eps_abs = Some(eps * gt.f_star);
            let rep = HdpwBatchSgd.solve(&Backend::native(), &ds, &opts).unwrap();
            let it = rep
                .iters_to_rel_err(gt.f_star, eps)
                .unwrap_or(rep.iters.max(1));
            iters.push(it as f64);
        }
        // r x16 => expect >= ~4x fewer iterations (allow generous slack for
        // stochastic noise and chunk quantization)
        assert!(
            iters[0] / iters[2] > 2.0,
            "no speed-up with batch size: {iters:?}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = dataset(512, 5, 4);
        let mut opts = SolverOpts::default();
        opts.max_iters = 200;
        opts.chunk = 50;
        let r1 = HdpwBatchSgd.solve(&Backend::native(), &ds, &opts).unwrap();
        let r2 = HdpwBatchSgd.solve(&Backend::native(), &ds, &opts).unwrap();
        assert_eq!(r1.x, r2.x);
        assert_eq!(r1.iters, r2.iters);
    }
}
