//! HDpwBatchSGD — Algorithm 2, the paper's low-precision contribution.
//!
//! Two-step preconditioning (sketch-QR for R, then the Randomized Hadamard
//! Transform on [A | b]) followed by *uniform* mini-batch SGD in the
//! R-metric. Theorem 3: T = Theta(d log n / (r eps^2)) iterations — the
//! iteration count divides by the batch size r, the paper's optimal
//! speed-up property (Figure 1).
//!
//! The output iterate is the running average x_T^avg = (1/T) sum x_t, as in
//! the algorithm statement; the trace reports f at the averaged iterate.

use super::{
    estimate_sigma_sq, theory_step_size, timed, Solver, SolveReport, SolverOpts, TraceRecorder,
};
use crate::backend::Backend;
use crate::data::Dataset;
use crate::precond::{hd_transform_with, precondition_with};
use crate::sketch::default_sketch_size_for;
use crate::util::rng::Rng;
use crate::util::stats::Timer;

pub struct HdpwBatchSgd;

impl Solver for HdpwBatchSgd {
    fn name(&self) -> &'static str {
        "hdpwbatchsgd"
    }

    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> SolveReport {
        let mut rng = Rng::new(opts.seed);
        let d = ds.d();
        let r = opts.batch_size.max(1);
        let s = opts
            .sketch_size
            .unwrap_or_else(|| default_sketch_size_for(ds.n(), d, opts.sketch));

        // ---- setup: two-step preconditioning (on the solve clock) --------
        // both steps stream through the backend's executor: the sketch folds
        // row shards in parallel, the HD transform owns its single padded
        // buffer (no dense [A | b] clone)
        let setup_timer = Timer::start();
        let pre = precondition_with(backend, &ds.a, opts.sketch, s, &mut rng, opts.block_rows);
        let hd = hd_transform_with(backend, &ds.a, &ds.b, &mut rng);
        // constrained runs need the R-metric projector (Step 6's quadratic
        // subproblem); its eigendecomposition is part of setup.
        let metric = match opts.constraint {
            crate::prox::Constraint::Unconstrained => None,
            _ => Some(crate::prox::metric::MetricProjector::from_r(&pre.r)),
        };
        let setup_secs = setup_timer.secs();

        let n_pad = hd.n_pad;
        let scale = 2.0 * n_pad as f64 / r as f64;
        let x0 = vec![0.0; d];
        let f0 = backend.residual_sq(&ds.a, &ds.b, &x0);

        // Theorem-2 fixed step: sigma^2 of single-row gradients, divided by r
        // for the batch (Lemma: sigma_batch^2 <= sigma^2 / r).
        let sigma_sq = estimate_sigma_sq(
            backend, &hd.hda, &hd.hdb, &pre.r, &x0, n_pad, &mut rng,
        );
        let r_norm = pre.r.frob_norm();
        let eta = theory_step_size(opts, sigma_sq / r as f64, f0, opts.max_iters, r_norm);

        let mut rec = TraceRecorder::new(setup_secs, f0);
        let mut x = x0;
        let mut xsum = vec![0.0; d];
        let mut total_t = 0usize;
        while !rec.should_stop(opts, current_f(backend, ds, &xsum, total_t, &x)) {
            let t_chunk = opts.chunk.min(opts.max_iters - rec.iters()).max(1);
            let idx: Vec<Vec<usize>> =
                (0..t_chunk).map(|_| rng.indices(r, n_pad)).collect();
            let ((xt, xs), secs) = timed(|| {
                backend.sgd_chunk(
                    &hd.hda,
                    &hd.hdb,
                    &x,
                    &pre.pinv,
                    &idx,
                    eta,
                    scale,
                    &opts.constraint,
                    metric.as_ref(),
                )
            });
            x = xt;
            for (acc, v) in xsum.iter_mut().zip(&xs) {
                *acc += v;
            }
            total_t += t_chunk;
            // evaluate at the averaged iterate (off the clock)
            let xavg = average(&xsum, total_t);
            let f = backend.residual_sq(&ds.a, &ds.b, &xavg);
            rec.record(t_chunk, secs, f);
        }
        let xavg = average(&xsum, total_t.max(1));
        let f = backend.residual_sq(&ds.a, &ds.b, &xavg);
        rec.finish("hdpwbatchsgd", xavg, f, setup_secs)
    }
}

fn average(xsum: &[f64], t: usize) -> Vec<f64> {
    let inv = 1.0 / t.max(1) as f64;
    xsum.iter().map(|v| v * inv).collect()
}

fn current_f(
    backend: &Backend,
    ds: &Dataset,
    xsum: &[f64],
    t: usize,
    x: &[f64],
) -> f64 {
    if t == 0 {
        backend.residual_sq(&ds.a, &ds.b, x)
    } else {
        backend.residual_sq(&ds.a, &ds.b, &average(xsum, t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas;
    use crate::linalg::Mat;
    use crate::prox::Constraint;
    use crate::solvers::exact::ground_truth;

    fn dataset(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let a = Mat::gaussian(n, d, &mut rng);
        let xt = rng.gaussians(d);
        let mut b = blas::gemv(&a, &xt);
        for v in &mut b {
            *v += 1.0 * rng.gaussian();
        }
        Dataset {
            name: "t".into(),
            a,
            b,
            x_star_planted: Some(xt),
        }
    }

    #[test]
    fn converges_to_low_precision_unconstrained() {
        let ds = dataset(2048, 8, 1);
        let gt = ground_truth(&ds);
        let mut opts = SolverOpts::default();
        opts.batch_size = 32;
        opts.max_iters = 3000;
        opts.chunk = 100;
        opts.seed = 7;
        let rep = HdpwBatchSgd.solve(&Backend::native(), &ds, &opts);
        let rel = (rep.f_final - gt.f_star) / gt.f_star;
        assert!(rel < 0.05, "relative error {rel}");
        assert!(rep.trace.len() > 2);
    }

    #[test]
    fn constrained_iterates_stay_feasible() {
        let ds = dataset(1024, 6, 2);
        let gt = ground_truth(&ds);
        for cons in [
            Constraint::L2Ball { radius: gt.l2_radius },
            Constraint::L1Ball { radius: gt.l1_radius },
        ] {
            let mut opts = SolverOpts::default();
            opts.constraint = cons;
            opts.batch_size = 16;
            opts.max_iters = 800;
            opts.chunk = 100;
            let rep = HdpwBatchSgd.solve(&Backend::native(), &ds, &opts);
            assert!(cons.contains(&rep.x, 1e-6), "{} violated", cons.tag());
            let rel = (rep.f_final - gt.f_star) / gt.f_star;
            assert!(rel < 0.5, "{}: rel {rel}", cons.tag());
        }
    }

    #[test]
    fn batch_size_speedup_on_iterations() {
        // Figure 1's property: iterations-to-eps roughly halves as r doubles.
        let ds = dataset(4096, 8, 3);
        let gt = ground_truth(&ds);
        let eps = 0.05;
        let mut iters = Vec::new();
        for r in [4usize, 16, 64] {
            let mut opts = SolverOpts::default();
            opts.batch_size = r;
            opts.max_iters = 20_000;
            opts.chunk = 50;
            opts.seed = 11;
            opts.f_star = Some(gt.f_star);
            opts.eps_abs = Some(eps * gt.f_star);
            let rep = HdpwBatchSgd.solve(&Backend::native(), &ds, &opts);
            let it = rep
                .iters_to_rel_err(gt.f_star, eps)
                .unwrap_or(rep.iters.max(1));
            iters.push(it as f64);
        }
        // r x16 => expect >= ~4x fewer iterations (allow generous slack for
        // stochastic noise and chunk quantization)
        assert!(
            iters[0] / iters[2] > 2.0,
            "no speed-up with batch size: {iters:?}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = dataset(512, 5, 4);
        let mut opts = SolverOpts::default();
        opts.max_iters = 200;
        opts.chunk = 50;
        let r1 = HdpwBatchSgd.solve(&Backend::native(), &ds, &opts);
        let r2 = HdpwBatchSgd.solve(&Backend::native(), &ds, &opts);
        assert_eq!(r1.x, r2.x);
        assert_eq!(r1.iters, r2.iters);
    }
}
