//! The solver family: the paper's algorithms plus every baseline its
//! evaluation compares against.
//!
//! | solver              | paper role                          | module        |
//! |---------------------|-------------------------------------|---------------|
//! | HDpwBatchSGD        | Algorithm 2 (low precision)         | [`hdpw_batch`]|
//! | HDpwAccBatchSGD     | Algorithm 6 (accelerated)           | [`hdpw_acc`]  |
//! | pwGradient          | Algorithm 4 (high precision)        | [`pw_gradient`]|
//! | IHS                 | Algorithm 3 baseline (P&W 2016)     | [`ihs`]       |
//! | pwSGD               | Yang et al. 2016 baseline           | [`pwsgd`]     |
//! | SGD                 | classical baseline                  | [`sgd`]       |
//! | Adagrad             | classical baseline                  | [`adagrad`]   |
//! | pwSVRG / SVRG       | high-precision stochastic baseline  | [`svrg`]      |
//! | Exact (QR)          | ground truth f(x*)                  | [`exact`]     |
//!
//! Every solver implements [`Solver`]: it receives a [`Backend`] (PJRT or
//! native), a [`Dataset`] and [`SolverOpts`], and produces a [`SolveReport`]
//! with a convergence trace sampled at chunk boundaries (evaluation time is
//! excluded from the solve clock, mirroring how the paper measures).
//!
//! The iterative solvers are [`StepRule`]s run by the shared
//! [`driver::SolveSession`] loop, which owns rng seeding, artifact
//! acquisition (cache-or-compute through the coordinator's
//! [`crate::precond::PrecondCache`]), warm starts, trace recording and the
//! stopping rules. `ExactQr` is the one exception: a closed-form oracle
//! with no iteration loop to drive.

pub mod driver;
pub mod exact;
pub mod sgd;
pub mod adagrad;
pub mod pwsgd;
pub mod svrg;
pub mod hdpw_batch;
pub mod hdpw_acc;
pub mod pw_gradient;
pub mod ihs;

pub use adagrad::Adagrad;
pub use driver::{drive, drive_fused_trials, SessionCtx, SolveSession, StepRule};
pub use exact::ExactQr;
pub use hdpw_acc::HdpwAccBatchSgd;
pub use hdpw_batch::HdpwBatchSgd;
pub use ihs::Ihs;
pub use pw_gradient::PwGradient;
pub use pwsgd::PwSgd;
pub use sgd::Sgd;
pub use svrg::Svrg;

use crate::backend::Backend;
use crate::constraints::{self, ConstraintRef, ConstraintSet};
use crate::data::Dataset;
use crate::sketch::SketchKind;
use crate::util::stats::Timer;
use anyhow::Result;

/// Options shared by all solvers.
#[derive(Clone, Debug)]
pub struct SolverOpts {
    /// The constraint set W every iterate is projected onto (shared,
    /// type-erased; [`crate::constraints::unconstrained`] by default). The
    /// coordinator builds it from the request's
    /// [`crate::constraints::ConstraintSpec`].
    pub constraint: ConstraintRef,
    /// Mini-batch size r (stochastic solvers).
    pub batch_size: usize,
    /// Hard cap on iterations (inner steps for stochastic solvers).
    pub max_iters: usize,
    /// Stop when f(x) - f_star <= eps_abs (needs f_star).
    pub eps_abs: Option<f64>,
    /// Known optimum value (for stopping + relative-error traces).
    pub f_star: Option<f64>,
    /// Wall-clock budget for the solve loop (seconds).
    pub time_budget: f64,
    /// Sketch construction for preconditioned solvers.
    pub sketch: SketchKind,
    /// Sketch rows s; default derived from d when None.
    pub sketch_size: Option<usize>,
    /// Fixed step size; solver-specific theory default when None.
    pub eta: Option<f64>,
    /// Iterations per trace point (and per PJRT chunk dispatch).
    pub chunk: usize,
    /// Row-shard height for block-streamed setup ops (sketch folds);
    /// None = per-shape cache/thread heuristic (data::default_block_rows).
    pub block_rows: Option<usize>,
    /// Per-trial rng seed (the coordinator forks one per trial from the
    /// job seed).
    pub seed: u64,
    /// Step-2 representation policy: pin the HD transform dense/implicit,
    /// let the nnz-aware cost model choose (`Auto`), or match the data
    /// representation (`Repr`, the default and the paper path).
    pub step2: crate::precond::Step2Policy,
    /// Session context (precond reuse, warm start) threaded by the
    /// coordinator; the default reproduces the paper's fresh-per-trial
    /// protocol exactly.
    pub session: SessionCtx,
}

impl Default for SolverOpts {
    fn default() -> Self {
        SolverOpts {
            constraint: constraints::unconstrained(),
            batch_size: 64,
            max_iters: 20_000,
            eps_abs: None,
            f_star: None,
            time_budget: 60.0,
            sketch: SketchKind::CountSketch,
            sketch_size: None,
            eta: None,
            chunk: 50,
            block_rows: None,
            seed: 1,
            step2: crate::precond::Step2Policy::default(),
            session: SessionCtx::default(),
        }
    }
}

/// One convergence-trace sample.
#[derive(Clone, Copy, Debug)]
pub struct TracePoint {
    /// Inner iterations completed.
    pub iters: usize,
    /// Cumulative solve seconds (setup included once at iter 0; objective
    /// evaluations excluded).
    pub secs: f64,
    /// f(x) at this point.
    pub f: f64,
}

/// Result of one solve.
#[derive(Clone, Debug)]
pub struct SolveReport {
    /// Canonical solver name (the registry key).
    pub solver: String,
    /// The final iterate (averaged iterate for the SGD family).
    pub x: Vec<f64>,
    /// f at the final iterate.
    pub f_final: f64,
    /// Inner iterations completed.
    pub iters: usize,
    /// Preconditioning / sketching setup cost, already included in trace[0].
    pub setup_secs: f64,
    /// Total solve seconds (setup + all chunks; objective evals excluded).
    pub solve_secs: f64,
    /// Convergence trace sampled at chunk boundaries.
    pub trace: Vec<TracePoint>,
    /// How the preconditioner was acquired (off / miss / hit) — lets a
    /// serve response distinguish a reused artifact from a fresh one.
    pub precond_cache: crate::precond::CacheOutcome,
    /// Warm-start outcome: `"off"` (not requested), `"used"` (the session
    /// `x0` seeded the solve), or `"rejected-dim"` (an `x0` with the wrong
    /// dimension was refused and the solve cold-started).
    pub warm_start: String,
    /// Resolved step-2 representation: `"off"` (no step-2 acquisition),
    /// `"dense"`, `"implicit"`, or the cost-model verdict
    /// (`"auto→dense"` / `"auto→implicit"`).
    pub step2: String,
}

impl SolveReport {
    /// Relative error trace against a known optimum: (iters, secs, relerr).
    pub fn rel_errors(&self, f_star: f64) -> Vec<(f64, f64, f64)> {
        self.trace
            .iter()
            .map(|p| {
                (
                    p.iters as f64,
                    p.secs,
                    ((p.f - f_star) / f_star.max(1e-300)).max(0.0),
                )
            })
            .collect()
    }

    /// First time at which relative error drops below eps (None if never).
    pub fn time_to_rel_err(&self, f_star: f64, eps: f64) -> Option<f64> {
        self.rel_errors(f_star)
            .into_iter()
            .find(|&(_, _, e)| e <= eps)
            .map(|(_, s, _)| s)
    }

    /// First iteration count at which relative error drops below eps.
    pub fn iters_to_rel_err(&self, f_star: f64, eps: f64) -> Option<usize> {
        self.rel_errors(f_star)
            .into_iter()
            .find(|&(_, _, e)| e <= eps)
            .map(|(i, _, _)| i as usize)
    }
}

/// A regression solver. `solve` is fallible: setup-time materializations
/// go through the session's memory budget, and an over-budget request is a
/// structured error the coordinator reports as a job error (never a panic,
/// never an OOM).
pub trait Solver: Send + Sync {
    /// Canonical solver name (the registry key in [`by_name`]).
    fn name(&self) -> &'static str;
    /// Run one solve of `ds` under `opts` on `backend`.
    fn solve(&self, backend: &Backend, ds: &Dataset, opts: &SolverOpts) -> Result<SolveReport>;
    /// A fresh instance of the solver's [`StepRule`] for the fused lockstep
    /// driver ([`driver::drive_fused_trials`]); `None` for closed-form
    /// solvers (exact QR), which have no iteration loop to fuse.
    fn step_rule(&self) -> Option<Box<dyn StepRule>> {
        None
    }
}

/// Solver registry (CLI / coordinator dispatch).
pub fn by_name(name: &str) -> Option<Box<dyn Solver>> {
    match name.to_ascii_lowercase().as_str() {
        "hdpwbatchsgd" | "hdpw_batch_sgd" | "hdpw" => Some(Box::new(HdpwBatchSgd)),
        "hdpwaccbatchsgd" | "hdpw_acc_batch_sgd" | "hdpw_acc" => {
            Some(Box::new(HdpwAccBatchSgd))
        }
        "pwgradient" | "pw_gradient" => Some(Box::new(PwGradient)),
        "ihs" => Some(Box::new(Ihs)),
        "pwsgd" | "pw_sgd" => Some(Box::new(PwSgd)),
        "sgd" => Some(Box::new(Sgd)),
        "adagrad" => Some(Box::new(Adagrad)),
        "svrg" => Some(Box::new(Svrg { preconditioned: false })),
        "pwsvrg" | "pw_svrg" => Some(Box::new(Svrg { preconditioned: true })),
        "exact" | "qr" => Some(Box::new(ExactQr)),
        _ => None,
    }
}

/// Every canonical solver name (CLI help, exhaustive test loops).
pub fn all_names() -> &'static [&'static str] {
    &[
        "hdpwbatchsgd",
        "hdpwaccbatchsgd",
        "pwgradient",
        "ihs",
        "pwsgd",
        "sgd",
        "adagrad",
        "svrg",
        "pwsvrg",
        "exact",
    ]
}

// ---------------------------------------------------------------------------
// shared solve-loop machinery
// ---------------------------------------------------------------------------

/// Tracks the solve clock (setup + per-chunk compute, excluding objective
/// evaluations) and assembles the trace.
pub struct TraceRecorder {
    /// The trace so far (trace[0] is the setup point at iteration 0).
    pub trace: Vec<TracePoint>,
    solve_secs: f64,
    iters: usize,
}

impl TraceRecorder {
    /// Start a trace at f(x0) = `f0` with `setup_secs` already on the clock.
    pub fn new(setup_secs: f64, f0: f64) -> Self {
        TraceRecorder {
            trace: vec![TracePoint {
                iters: 0,
                secs: setup_secs,
                f: f0,
            }],
            solve_secs: setup_secs,
            iters: 0,
        }
    }

    /// Record a chunk: `secs` of solve time advancing `iters` iterations,
    /// reaching objective value `f`.
    pub fn record(&mut self, iters: usize, secs: f64, f: f64) {
        self.iters += iters;
        self.solve_secs += secs;
        self.trace.push(TracePoint {
            iters: self.iters,
            secs: self.solve_secs,
            f,
        });
    }

    /// Inner iterations recorded so far.
    pub fn iters(&self) -> usize {
        self.iters
    }

    /// Solve seconds recorded so far (setup included).
    pub fn secs(&self) -> f64 {
        self.solve_secs
    }

    /// Stop condition shared by all solve loops.
    pub fn should_stop(&self, opts: &SolverOpts, f: f64) -> bool {
        if self.iters >= opts.max_iters {
            return true;
        }
        if self.solve_secs >= opts.time_budget {
            return true;
        }
        if let (Some(eps), Some(fs)) = (opts.eps_abs, opts.f_star) {
            if f - fs <= eps {
                return true;
            }
        }
        false
    }

    /// Close the trace into a [`SolveReport`].
    pub fn finish(self, solver: &str, x: Vec<f64>, f: f64, setup_secs: f64) -> SolveReport {
        SolveReport {
            solver: solver.to_string(),
            f_final: f,
            iters: self.iters,
            setup_secs,
            solve_secs: self.solve_secs,
            trace: self.trace,
            x,
            precond_cache: crate::precond::CacheOutcome::Off,
            warm_start: "off".into(),
            step2: "off".into(),
        }
    }
}

/// Estimate the stochastic-gradient variance sigma^2 of the *preconditioned*
/// problem at x0 by sampling K single-row gradients y_i = R^{-T} c_i and
/// computing their empirical variance. Used by the theory step size
/// (Theorem 2: eta = min(1/(2L), sqrt(D^2 / (2 T sigma^2)))).
///
/// Samples rows through the step-2 [`crate::precond::HdView`], so the same
/// probe runs off the materialized transform (dense datasets, bit-identical
/// to the historical direct-gather form: identical `rng` draws, identical
/// gathered rows), the implicit one (sparse datasets, rows evaluated on
/// demand), or the on-disk implicit one (rows streamed through the shard
/// cache). Fallible because the on-disk gathers read shards; resident views
/// never return `Err`.
pub fn estimate_sigma_sq(
    backend: &Backend,
    hd: &crate::precond::HdView<'_>,
    r_factor: &crate::linalg::Mat,
    x0: &[f64],
    rng: &mut crate::util::rng::Rng,
) -> Result<f64> {
    let k = 24usize;
    let d = r_factor.cols;
    let n_universe = hd.n_pad();
    let mut grads: Vec<Vec<f64>> = Vec::with_capacity(k);
    for _ in 0..k {
        let i = rng.below(n_universe);
        let (m, v) = hd.gather(&[i])?;
        let c = backend.batch_grad(&m, &v, x0, 2.0 * n_universe as f64);
        // transform to the y-metric: g = R^{-T} c
        let g = crate::linalg::tri::solve_upper_t(r_factor, &c);
        grads.push(g);
    }
    let mut mean = vec![0.0; d];
    for g in &grads {
        for (m, v) in mean.iter_mut().zip(g) {
            *m += v / k as f64;
        }
    }
    let mut var = 0.0;
    for g in &grads {
        for (m, v) in mean.iter().zip(g) {
            var += (v - m) * (v - m);
        }
    }
    Ok(var / (k as f64 - 1.0))
}

/// Theorem-2 style fixed step for the preconditioned problem: the
/// L-smoothness of g(y) = ||Uy - HDb||^2 with kappa(U) = O(1) is ~2, so
/// 1/(2L) = 1/4; the variance term uses the estimated sigma^2 and the
/// constraint diameter (or an f(x0)-based surrogate when unconstrained).
pub fn theory_step_size(
    opts: &SolverOpts,
    sigma_sq_batch: f64,
    f0: f64,
    t_planned: usize,
    r_norm: f64,
) -> f64 {
    if let Some(eta) = opts.eta {
        return eta;
    }
    let l: f64 = 2.0;
    // The diameter D_W' lives in the y = Rx metric: a ball of radius rho in
    // x-space maps to an ellipsoid with radii up to sigma_max(R) * rho, so
    // the x-space diameter is scaled by `r_norm` (an upper bound on
    // sigma_max(R), e.g. ||R||_F). The unconstrained surrogate sqrt(f0) is
    // already in the y-metric (mu ~ 2 strong convexity of g(y) bounds
    // ||y0 - y*|| <= sqrt(2 (g(y0) - g*) / mu) <= sqrt(f0)).
    let d_w = opts
        .constraint
        .diameter()
        .map(|d| d * r_norm.max(1.0))
        .unwrap_or_else(|| f0.sqrt());
    let var_term =
        (d_w * d_w / (2.0 * t_planned.max(1) as f64 * sigma_sq_batch.max(1e-300))).sqrt();
    (1.0 / (2.0 * l)).min(var_term)
}

/// Timer wrapper for a solve chunk.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_names() {
        for name in all_names() {
            assert!(by_name(name).is_some(), "missing {name}");
        }
        assert!(by_name("bogus").is_none());
    }

    #[test]
    fn registry_aliases() {
        assert_eq!(by_name("hdpw").unwrap().name(), "hdpwbatchsgd");
        assert_eq!(by_name("pw_gradient").unwrap().name(), "pwgradient");
        assert_eq!(by_name("pwsvrg").unwrap().name(), "pwsvrg");
    }

    #[test]
    fn trace_recorder_accumulates() {
        let mut tr = TraceRecorder::new(0.5, 100.0);
        tr.record(10, 0.2, 50.0);
        tr.record(10, 0.2, 25.0);
        assert_eq!(tr.iters(), 20);
        assert!((tr.secs() - 0.9).abs() < 1e-12);
        let rep = tr.finish("t", vec![], 25.0, 0.5);
        assert_eq!(rep.trace.len(), 3);
        assert_eq!(rep.trace[0].iters, 0);
        assert!((rep.trace[2].secs - 0.9).abs() < 1e-12);
    }

    #[test]
    fn stop_conditions() {
        let mut opts = SolverOpts::default();
        opts.max_iters = 15;
        opts.time_budget = 1e9;
        let mut tr = TraceRecorder::new(0.0, 1.0);
        tr.record(10, 0.0, 1.0);
        assert!(!tr.should_stop(&opts, 1.0));
        tr.record(10, 0.0, 1.0);
        assert!(tr.should_stop(&opts, 1.0)); // iters
        let mut opts2 = SolverOpts::default();
        opts2.eps_abs = Some(0.1);
        opts2.f_star = Some(1.0);
        let tr2 = TraceRecorder::new(0.0, 2.0);
        assert!(tr2.should_stop(&opts2, 1.05)); // f close enough
        assert!(!tr2.should_stop(&opts2, 1.5));
    }

    #[test]
    fn report_rel_error_helpers() {
        let rep = SolveReport {
            solver: "t".into(),
            x: vec![],
            f_final: 1.1,
            iters: 20,
            setup_secs: 0.0,
            solve_secs: 2.0,
            precond_cache: crate::precond::CacheOutcome::Off,
            warm_start: "off".into(),
            step2: "off".into(),
            trace: vec![
                TracePoint {
                    iters: 0,
                    secs: 0.0,
                    f: 3.0,
                },
                TracePoint {
                    iters: 10,
                    secs: 1.0,
                    f: 2.0,
                },
                TracePoint {
                    iters: 20,
                    secs: 2.0,
                    f: 1.1,
                },
            ],
        };
        let errs = rep.rel_errors(1.0);
        assert!((errs[0].2 - 2.0).abs() < 1e-12);
        assert_eq!(rep.time_to_rel_err(1.0, 0.5), Some(2.0));
        assert_eq!(rep.iters_to_rel_err(1.0, 0.5), Some(20));
        assert_eq!(rep.time_to_rel_err(1.0, 0.01), None);
    }

    #[test]
    fn theory_step_caps_at_quarter() {
        let opts = SolverOpts::default();
        // tiny variance -> variance term huge -> cap at 1/4
        assert!((theory_step_size(&opts, 1e-12, 1.0, 100, 1.0) - 0.25).abs() < 1e-12);
        // huge variance -> small step
        let eta = theory_step_size(&opts, 1e12, 1.0, 100, 1.0);
        assert!(eta < 1e-4);
        // explicit override wins
        let mut o2 = SolverOpts::default();
        o2.eta = Some(0.123);
        assert_eq!(theory_step_size(&o2, 1.0, 1.0, 10, 1.0), 0.123);
        // constrained diameter scales with the R-metric norm
        let mut o3 = SolverOpts::default();
        o3.constraint = constraints::l2_ball(1.0);
        let small = theory_step_size(&o3, 1e6, 1.0, 100, 1.0);
        let big = theory_step_size(&o3, 1e6, 1.0, 100, 100.0);
        assert!(big > 10.0 * small, "metric scaling missing: {small} vs {big}");
    }
}
